"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Sections:
  * SSSP-Del paper tables/figures (benchmarks/bench_sssp.py) with Dijkstra
    oracle cross-checks — one function per paper table/figure — plus the
    beyond-paper sections: backend_shootout, hub_shootout, dist_engine,
    ``serving`` (batched multi-source trace replay with the
    latency/stability/throughput record, DESIGN.md §8) and
    ``obs_overhead`` (the §10.4 observability overhead contract:
    instrumented vs uninstrumented ingest on the same stream);
  * kernel micro-benchmarks (Pallas interpret-mode vs jnp reference);
  * roofline table distilled from the dry-run reports (if reports/ exists).

``--small`` shrinks graphs for CI-speed runs; ``--only <prefix>`` filters
(unknown names are an error — exit 2); ``--list`` prints the sections.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from benchmarks import common as C


def section_names() -> list[str]:
    from benchmarks import bench_sssp
    return [fn.__name__ for fn in bench_sssp.ALL]


def _token_matches(tok: str, name: str) -> bool:
    """THE --only matching rule (substring), shared by the pre-run
    validation and the section filter so the two can never drift."""
    return bool(tok) and tok in name


def check_only(only: str | None) -> list[str]:
    """Validate --only tokens against the section list; returns the unknown
    tokens (each token must match at least one section)."""
    names = section_names()
    return [tok for tok in (only.split(",") if only else [])
            if not any(_token_matches(tok, name) for name in names)]


def run_sssp(sink: C.CsvSink, small: bool, only: str | None) -> None:
    from benchmarks import bench_sssp
    wanted = only.split(",") if only else None
    for fn in bench_sssp.ALL:
        if wanted and not any(_token_matches(tok, fn.__name__)
                              for tok in wanted):
            continue
        t0 = time.perf_counter()
        fn(sink, small)
        sink.emit("section_done", name=fn.__name__,
                  wall_s=f"{time.perf_counter() - t0:.1f}")


def run_kernels(sink: C.CsvSink, small: bool) -> None:
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.kernels.relax import ops as relax_ops
    from repro.kernels.spmm import ops as spmm_ops
    from repro.kernels.embed_bag import ops as eb_ops
    rng = np.random.default_rng(0)

    n, k = (256, 16) if small else (1024, 32)
    nbr = jnp.asarray(rng.integers(0, n, (n, k)), jnp.int32)
    w = jnp.asarray(rng.random((n, k)).astype(np.float32))
    dist = jnp.asarray(rng.random(n).astype(np.float32))
    parent = jnp.full((n,), -1, jnp.int32)
    for name, use_kernel in (("pallas_interp", True), ("jnp_ref", False)):
        t0 = time.perf_counter()
        out = relax_ops.relax_wave(dist, parent, nbr, w,
                                   use_kernel=use_kernel)
        jax.block_until_ready(out)
        sink.emit("kernel_relax", impl=name, n=n, k=k,
                  ms=f"{(time.perf_counter()-t0)*1e3:.1f}")

    feats = jnp.asarray(rng.random((n, 64)).astype(np.float32))
    msk = jnp.asarray(rng.random((n, k)) < 0.8)
    for name, use_kernel in (("pallas_interp", True), ("jnp_ref", False)):
        t0 = time.perf_counter()
        jax.block_until_ready(spmm_ops.neighbor_reduce(
            feats, nbr, msk, agg="sum", use_kernel=use_kernel))
        sink.emit("kernel_spmm", impl=name, n=n, k=k,
                  ms=f"{(time.perf_counter()-t0)*1e3:.1f}")

    table = jnp.asarray(rng.random((4096, 32)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 4096, (n, 8)), jnp.int32)
    for name, use_kernel in (("pallas_interp", True), ("jnp_ref", False)):
        t0 = time.perf_counter()
        jax.block_until_ready(eb_ops.bag_lookup(table, idx, agg="sum",
                                                use_kernel=use_kernel))
        sink.emit("kernel_embed_bag", impl=name, bags=n,
                  ms=f"{(time.perf_counter()-t0)*1e3:.1f}")


def run_roofline_table(sink: C.CsvSink) -> None:
    shown = 0
    for base, variant in (("reports/dryrun", "baseline"),
                          ("reports/perf/flash_vjp", "flash_vjp"),
                          ("reports/perf/opt", "opt")):
        if not os.path.isdir(base):
            continue
        for mesh in sorted(os.listdir(base)):
            d = os.path.join(base, mesh)
            if not os.path.isdir(d):
                continue
            for f in sorted(os.listdir(d)):
                if not f.endswith(".json"):
                    continue
                rec = json.load(open(os.path.join(d, f)))
                if not rec.get("ok"):
                    continue
                r = rec["roofline"]
                sink.emit("roofline", variant=variant, mesh=mesh,
                          cell=f[:-5], dominant=r["dominant"],
                          compute_s=f"{r['compute_s']:.3e}",
                          memory_s=f"{r['memory_s']:.3e}",
                          collective_s=f"{r['collective_s']:.3e}",
                          peak_gb=f"{rec['memory']['peak_per_device_gb']:.2f}")
                shown += 1
    if not shown:
        sink.emit("roofline", note="no reports found; run "
                  "PYTHONPATH=src python -m repro.launch.dryrun --all first")


def write_bench_json(sink: C.CsvSink, args, wall_s: float,
                     path: str = "BENCH_sssp.json") -> None:
    """Machine-readable artifact so the perf trajectory is tracked across
    PRs (CI runs ``--small`` and archives this file)."""
    import platform

    import jax

    payload = {
        "schema": 1,
        "suite": "sssp_del",
        "small": bool(args.small),
        "only": args.only,
        "wall_s": round(wall_s, 2),
        "env": {
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "python": platform.python_version(),
        },
        "records": sink.records,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    print(f"wrote {path} ({len(sink.records)} records)", flush=True)


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--small", action="store_true")
    p.add_argument("--only", help="comma-separated name substrings, e.g. "
                                  "'backend_shootout,dist_engine'")
    p.add_argument("--skip-kernels", action="store_true")
    p.add_argument("--json", default="BENCH_sssp.json",
                   help="machine-readable output path ('' disables)")
    p.add_argument("--list", action="store_true",
                   help="print available section names and exit")
    args = p.parse_args()
    if args.list:
        for name in section_names():
            print(name)
        return 0
    unknown = check_only(args.only)
    if unknown:
        print(f"error: unknown --only section(s): {','.join(unknown)}; "
              f"--list prints the available names", file=sys.stderr)
        return 2
    sink = C.CsvSink()
    t0 = time.perf_counter()
    run_sssp(sink, args.small, args.only)
    if not args.skip_kernels and not args.only:
        run_kernels(sink, args.small)
    if not args.only:
        run_roofline_table(sink)
    wall = time.perf_counter() - t0
    sink.emit("all_done", wall_s=f"{wall:.1f}", rows=len(sink.rows))
    if args.json:
        write_bench_json(sink, args, wall, args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())
