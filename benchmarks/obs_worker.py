"""Sharded obs-overhead worker: the P=8 leg of the ``obs_overhead``
section, measured in a FRESH process.

Run by benchmarks/bench_sssp.py via ``python -m benchmarks.obs_worker``;
a subprocess because ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
must be set BEFORE jax initializes, and the parent bench process has long
since imported jax.

Same contract as the single-device leg (DESIGN.md §10.4), on the sharded
engine over an 8-device mesh: the identical power-law stream ingested
with telemetry off and on in interleaved passes (1 warm + best-of-2), a
default-threshold watchdog armed on the instrumented passes (it must stay
silent — §10.8), and in-run asserts pinning bit-identical (dist, parent,
rounds, messages), span==counter agreement, histogram-total==counter
consistency (§10.6) and per-partition attribution sums (§10.5).

Emits one ``OBSROW {json}`` line per bench record on stdout; the parent
re-emits them through its CsvSink so check_regression gates the sharded
on/off ratio exactly like the single-device one.  ``--trace-out PATH``
additionally saves the instrumented engine's Perfetto Chrome trace (the
CI build artifact).
"""
from __future__ import annotations

import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import json
import time

import numpy as np


def emit(bench: str, **kv) -> None:
    print("OBSROW " + json.dumps({"bench": bench, **kv}), flush=True)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--trace-out", default=None)
    args = ap.parse_args()

    import jax

    from repro.core.dist_engine import ShardedEngineConfig, \
        ShardedSSSPDelEngine
    from repro.graphs import generators as gen
    from repro.graphs import window as win
    from repro.core import events as ev
    from repro.obs import WatchdogConfig

    P = len(jax.devices())
    n = (1 << 9) if args.small else (1 << 10)
    m = 4 * n
    nv, src, dst, w = gen.power_law_hubs(n, m, n_hubs=4, seed=31,
                                         orientation="in")
    source = int(gen.top_in_degree_sources(nv, dst)[0])
    log = ev.interleave_queries(
        win.sliding_window_stream(src, dst, w, window=len(src) // 3,
                                  delta=0.3, seed=0),
        max(1, len(src) // 12))

    def mk(obs_on):
        return ShardedSSSPDelEngine(ShardedEngineConfig(
            num_vertices=nv, edges_per_part=m, source=source,
            relax_backend="sliced", sliced_slice_rows=32, sliced_hub_k=4,
            sliced_init_k=2, observability=obs_on,
            # default thresholds: only multi-second stalls fire — the
            # gated bench asserts the watchdog stays silent (§10.8)
            obs_watchdog=WatchdogConfig() if obs_on else None))

    best = {False: 0.0, True: 0.0}
    final = {}
    for _ in range(3):                      # 1 warm + best-of-2 timed
        for obs_on in (False, True):        # interleaved passes
            eng = mk(obs_on)
            t0 = time.perf_counter()
            eng.ingest_log(log)
            jax.block_until_ready(eng.dist)
            eps = len(log) / (time.perf_counter() - t0)
            if eps > best[obs_on]:
                best[obs_on], final[obs_on] = eps, eng

    # §10 invariants: telemetry free of algorithmic effect, three views
    # of the same events in agreement, histogram totals == flat counters
    q_off, q_on = final[False].query(), final[True].query()
    np.testing.assert_array_equal(q_off.dist, q_on.dist)
    np.testing.assert_array_equal(q_off.parent, q_on.parent)
    on = final[True]
    snap = on.metrics_snapshot()
    assert int(snap["rounds"]) == int(on.n_rounds)
    assert int(final[False].n_rounds) == int(on.n_rounds)
    sp, ct = snap["spans"], snap["counters"]
    for kind, name in (("add_epoch", "add_epochs"),
                       ("del_epoch", "del_epochs"), ("query", "queries")):
        assert sp.get(kind, 0) == ct.get(name, 0), (kind, sp, ct)
    h = snap["histograms"]
    assert h["latency_us"]["count"] == ct["queries"]
    assert h["frontier_occupancy"]["count"] == ct["add_epochs"]
    assert h["waves_per_epoch"]["count"] == ct["add_epochs"] + ct["del_epochs"]
    att = snap["attribution"]["partition"]
    assert int(np.sum(att["adds_per_part"])) == on.n_adds
    assert int(np.sum(att["frontier_per_part"])) == ct["frontier"]
    assert int(np.sum(att["updates_per_part"])) >= 0
    # silent watchdog on the gated bench (§10.8)
    assert "watchdog_warnings" not in ct, ct.get("watchdog_warnings")

    from benchmarks import common as C
    for obs_on in (False, True):
        eng = final[obs_on]
        s = eng.metrics_snapshot()
        emit("obs_overhead", dataset="plaw", n=nv, edges=m,
             backend="sliced", engine="sharded", parts=P,
             observability=obs_on, events=len(log),
             events_per_s=round(best[obs_on], 1), epochs=eng.n_epochs,
             rounds=int(s["rounds"]), messages=int(s["messages"]),
             spans=sum(s["spans"].values()),
             **(C.hist_fields(s) if obs_on else {}))
    emit("obs_overhead_summary", backend="sliced", engine="sharded",
         parts=P,
         on_vs_off=round(best[True] / max(best[False], 1e-9), 3),
         identical=True)

    if args.trace_out:
        on.obs.tracer.save_chrome(args.trace_out)
        print(f"chrome trace -> {args.trace_out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
