"""CI scale smoke (DESIGN.md §11): the paper-scale ingest pipeline end to
end at N=64k, exercised exactly the way a user reaches it —

  1. write an RMAT(16) edge list to disk in SNAP text format;
  2. run the real-dataset loader CLI path (graphs/datasets.py): parse,
     compact ids, synthesize the sliding-window dynamic portion, write a
     version-2 CHUNKED trace;
  3. stream the trace back through ``open_trace`` -> ``replay_trace``
     (O(chunk) peak memory) into a ``repro.make_engine`` engine;
  4. cross-check the final converged tree bit-for-bit shape-wise against
     the Dijkstra oracle on the engine's own live-edge mirror.

Run: ``PYTHONPATH=src python -m benchmarks.scale_smoke [--scale 16]``
Exit 0 on parity, 1 on divergence — wired as a CI step on both jax legs.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=16,
                    help="RMAT scale (N = 2**scale)")
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--delta", type=float, default=0.2)
    ap.add_argument("--chunk-events", type=int, default=65536)
    args = ap.parse_args()

    import repro
    from repro.core import oracle
    from repro.graphs import datasets as ds
    from repro.graphs import generators as gen
    from repro.serving.replay import replay_trace

    n, src, dst, w = gen.rmat(args.scale, edge_factor=args.edge_factor,
                              seed=3)
    with tempfile.TemporaryDirectory() as d:
        edges_path = os.path.join(d, "rmat.txt")
        with open(edges_path, "w") as f:
            f.write("# synthetic RMAT edge list (scale smoke)\n")
            f.write("\n".join(f"{u} {v} {x:.4f}"
                              for u, v, x in zip(src, dst, w)))
            f.write("\n")
        trace_path = os.path.join(d, "rmat.trace")
        rc = ds.main([edges_path, trace_path, "--delta", str(args.delta),
                      "--window-frac", "0.5",
                      "--chunk-events", str(args.chunk_events)])
        assert rc == 0

        n_ids, _, cdst = ds.compact_ids(src, dst)
        source = int(gen.top_in_degree_sources(n_ids, cdst)[0])
        eng = repro.make_engine(
            num_vertices=n_ids, edge_capacity=len(src) + 64, source=source,
            batch_deletions=True, wave_schedule="buckets",
            bucket_width=float("inf"))
        t0 = time.perf_counter()
        with repro.open_trace(trace_path) as reader:
            assert reader.n_chunks > 1, (
                f"expected a chunked trace, got {reader.n_chunks} chunk(s)")
            report = replay_trace(eng, reader)
        res = eng.query()
        wall = time.perf_counter() - t0

    lsrc, ldst, lw = eng.alloc.active_coo()
    dist_ref, _ = oracle.dijkstra(n_ids, lsrc, ldst, lw, source)
    dist = np.asarray(res.dist)
    ok = bool(np.allclose(np.where(np.isfinite(dist), dist, -1),
                          np.where(np.isfinite(dist_ref), dist_ref, -1),
                          rtol=1e-5, atol=1e-5))
    print(f"scale_smoke: n={n_ids} events={report.events} "
          f"(topo={report.topology_events}) replay={wall:.1f}s "
          f"events/s={report.events_per_s:.0f} live={len(lsrc)} "
          f"oracle_match={ok}")
    if not ok:
        print("scale_smoke: engine diverged from Dijkstra oracle",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
