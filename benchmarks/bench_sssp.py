"""SSSP-Del paper benchmarks — one function per paper table/figure.

  table2_static_baseline  — Galois-analogue static solve (Conv/Load/SP) vs
                            streaming ingest + on-demand solve (paper Table 2)
  fig1_query_latency      — SSSP-Del vs ReMo-from-scratch across
                            (window x delta) configs (paper Fig. 1)
  fig2_latency_over_time  — latency growth along the stream (paper Fig. 2)
  fig3_source_selection   — latency across datasets x top-3 sources (Fig. 3)
  fig4_stability          — predecessor stability vs baseline (Fig. 4)
  fig5_throughput         — ingest events/s vs delete probability (Fig. 5)
  fig6_batch_bsp          — GraphBolt-model batch engine vs on-demand
                            queries at matched intervals (Fig. 6)

Every run cross-checks the final tree against the Dijkstra oracle.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common as C
from repro.core import events as ev
from repro.core import oracle
from repro.core.baseline import BatchedBSPEngine, ReMoBaseline, StaticSolver
from repro.core.engine import EngineConfig, SSSPDelEngine


def _engine(ds: C.Dataset, source: int, cap_mult: float = 1.3,
            **kw) -> SSSPDelEngine:
    cap = int(len(ds.src) * cap_mult) + 64
    return SSSPDelEngine(EngineConfig(num_vertices=ds.n, edge_capacity=cap,
                                      source=int(source), **kw))


def _check_oracle(eng: SSSPDelEngine, sink: C.CsvSink, tag: str) -> None:
    e = eng.state.edges
    src, dst, w = (np.asarray(e.src), np.asarray(e.dst), np.asarray(e.w))
    act = np.asarray(e.active)
    dist_ref, _ = oracle.dijkstra(eng.cfg.num_vertices, src[act], dst[act],
                                  w[act], eng.cfg.source)
    dist = np.asarray(eng.state.sssp.dist)
    ok = bool(np.allclose(np.where(np.isfinite(dist), dist, -1),
                          np.where(np.isfinite(dist_ref), dist_ref, -1),
                          rtol=1e-5, atol=1e-5))
    sink.emit(tag, oracle_match=ok)
    assert ok, f"{tag}: engine diverged from Dijkstra oracle"


def table2_static_baseline(sink: C.CsvSink, small: bool) -> None:
    for ds in C.datasets(small):
        log = C.stream_for(ds, window_frac=1.0, delta=0.0, query_every=10**9)
        # static path (Galois analogue): convert -> solve
        solver = StaticSolver(ds.n)
        conv_s = solver.convert(log)
        rep = solver.solve(int(ds.sources[0]))
        # streaming path: ingest while maintaining the tree, then query
        eng = _engine(ds, ds.sources[0])
        t0 = time.perf_counter()
        res = eng.ingest_log(log)
        ingest_s = time.perf_counter() - t0
        q = eng.query()
        match = bool(np.allclose(
            np.where(np.isfinite(q.dist), q.dist, -1),
            np.where(np.isfinite(rep.dist), rep.dist, -1)))
        sink.emit("table2", dataset=ds.name, conv_s=f"{conv_s:.3f}",
                  static_sp_ms=f"{rep.solve_s * 1e3:.1f}",
                  ingest_s=f"{ingest_s:.3f}",
                  dyn_query_ms=f"{q.latency_s * 1e3:.3f}",
                  static_vs_dyn_match=match)


def fig1_query_latency(sink: C.CsvSink, small: bool) -> None:
    ds = C.datasets(small)[1]  # web-Google-like
    for wf in (0.1, 0.4):
        for delta in (0.1, 0.5):
            q_every = max(1, int(len(ds.src) * wf / 10))
            log = C.stream_for(ds, window_frac=wf, delta=delta,
                               query_every=q_every)
            eng = _engine(ds, ds.sources[0])
            ours = [r.latency_s for r in eng.ingest_log(log)]
            base = ReMoBaseline(ds.n, int(len(ds.src) * 1.3) + 64,
                                int(ds.sources[0]))
            theirs = [r.latency_s for r in base.ingest_log(log)]
            speedup = C.pctile(theirs, 50) / max(C.pctile(ours, 50), 1e-9)
            sink.emit("fig1", dataset=ds.name, window_frac=wf, delta=delta,
                      ours_p50_ms=f"{C.pctile(ours, 50)*1e3:.3f}",
                      base_p50_ms=f"{C.pctile(theirs, 50)*1e3:.3f}",
                      median_speedup=f"{speedup:.1f}x")
            _check_oracle(eng, sink, "fig1_oracle")


def fig2_latency_over_time(sink: C.CsvSink, small: bool) -> None:
    ds = C.datasets(small)[1]
    q_every = max(1, len(ds.src) // 12)
    log = C.stream_for(ds, window_frac=0.4, delta=0.5, query_every=q_every)
    eng = _engine(ds, ds.sources[0])
    ours = [r.latency_s for r in eng.ingest_log(log)]
    base = ReMoBaseline(ds.n, int(len(ds.src) * 1.3) + 64, int(ds.sources[0]))
    theirs = [r.latency_s for r in base.ingest_log(log)]
    for i, (a, b) in enumerate(zip(ours, theirs)):
        sink.emit("fig2", query_idx=i, ours_ms=f"{a*1e3:.3f}",
                  base_ms=f"{b*1e3:.3f}",
                  speedup=f"{b / max(a, 1e-9):.1f}x")


def fig3_source_selection(sink: C.CsvSink, small: bool) -> None:
    for ds in C.datasets(small):
        for rank, s in enumerate(ds.sources):
            q_every = max(1, len(ds.src) // 6)
            log = C.stream_for(ds, window_frac=0.3, delta=0.2,
                               query_every=q_every)
            eng = _engine(ds, s)
            ours = [r.latency_s for r in eng.ingest_log(log)]
            base = ReMoBaseline(ds.n, int(len(ds.src) * 1.3) + 64, int(s))
            theirs = [r.latency_s for r in base.ingest_log(log)]
            sink.emit("fig3", dataset=f"{ds.name}-{rank+1}",
                      ours_p25_ms=f"{C.pctile(ours,25)*1e3:.3f}",
                      ours_p50_ms=f"{C.pctile(ours,50)*1e3:.3f}",
                      ours_p75_ms=f"{C.pctile(ours,75)*1e3:.3f}",
                      base_p50_ms=f"{C.pctile(theirs,50)*1e3:.3f}")


def fig4_stability(sink: C.CsvSink, small: bool) -> None:
    """Paper §5.4: with UNIT weights (the paper's preprocessing for real
    graphs) many equally valid trees exist; the incremental engine keeps
    predecessors unless forced to change, while a from-scratch solver
    re-resolves every tie per query (randomize_ties models the async
    runtime's arbitrariness)."""
    ds0 = C.datasets(small)[0]
    import dataclasses as _dc
    ds = _dc.replace(ds0, w=np.ones_like(ds0.w))
    q_every = max(1, len(ds.src) // 10)
    log = C.stream_for(ds, window_frac=0.3, delta=0.3, query_every=q_every)
    eng = _engine(ds, ds.sources[0])
    base = ReMoBaseline(ds.n, int(len(ds.src) * 1.3) + 64, int(ds.sources[0]),
                        randomize_ties=True)
    ours_res = eng.ingest_log(log)
    base_res = base.ingest_log(log)
    for i, (a, b) in enumerate(zip(ours_res, base_res)):
        sa = eng.stability_vs_prev(a.parent)
        sb = base.stability_vs_prev(b.parent)
        sink.emit("fig4", query_idx=i,
                  ours_stability=f"{sa:.4f}", base_stability=f"{sb:.4f}",
                  ours_ms=f"{a.latency_s*1e3:.3f}",
                  base_ms=f"{b.latency_s*1e3:.3f}")
    _check_oracle(eng, sink, "fig4_oracle")


def fig5_throughput(sink: C.CsvSink, small: bool) -> None:
    """Paper Fig. 5 + a beyond-paper variant: the paper enforces one
    stop-the-world epoch PER deletion; ``batch_deletions=True`` coalesces a
    run of consecutive deletions into one invalidation+recompute epoch
    (correctness: Appendix A Case 2 covers the union of subtrees — see
    DESIGN.md §3), trading epoch count for throughput."""
    for ds in C.datasets(small):
        for delta in (0.01, 0.1, 0.5, 1.0):
            for batched in (False, True):
                log = C.stream_for(ds, window_frac=0.3, delta=delta,
                                   query_every=10**9)
                eng = _engine(ds, ds.sources[0], batch_deletions=batched)
                t0 = time.perf_counter()
                eng.ingest_log(log)
                dt = time.perf_counter() - t0
                _check_oracle(eng, sink, "fig5_oracle")
                sink.emit("fig5", dataset=ds.name, delta=delta,
                          mode="batched-del" if batched else "paper-faithful",
                          events=len(log), events_per_s=f"{len(log)/dt:.0f}",
                          epochs=eng.n_epochs, rounds=eng.n_rounds,
                          rounds_per_event=round(
                              int(eng.n_rounds) / len(log), 3))


def fig6_batch_bsp(sink: C.CsvSink, small: bool) -> None:
    ds = C.datasets(small)[1]
    base_log = C.stream_for(ds, window_frac=0.2, delta=0.1,
                            query_every=10**9)
    n_events = len(base_log)
    for n_queries in (4, 16, 64):
        batch = max(1, n_events // n_queries)
        # GraphBolt processing model: reconverge once per batch
        bsp = BatchedBSPEngine(ds.n, int(len(ds.src) * 1.3) + 64,
                               int(ds.sources[0]), batch)
        lat_bsp = []
        for i in range(0, n_events, batch):
            bsp.push(base_log[i:i + batch])
            dt = bsp.maybe_flush()
            if dt is not None:
                lat_bsp.append(dt)
        rest = bsp.force_flush()
        if rest:
            lat_bsp.append(rest)
        # our engine: ingest continuously, query at the same intervals
        log_q = ev.interleave_queries(base_log, batch)
        eng = _engine(ds, ds.sources[0])
        lat_ours = [r.latency_s for r in eng.ingest_log(log_q)]
        sink.emit("fig6", n_queries=n_queries, batch=batch,
                  bsp_p50_ms=f"{C.pctile(lat_bsp,50)*1e3:.2f}",
                  ours_p50_ms=f"{C.pctile(lat_ours,50)*1e3:.3f}",
                  reduction=f"{C.pctile(lat_bsp,50)/max(C.pctile(lat_ours,50),1e-9):.1f}x")


def backend_shootout(sink: C.CsvSink, small: bool) -> None:
    """Beyond-paper: segment (COO scatter-min) vs ellpack (dense gather +
    row-min over the incrementally maintained ELL block) on fig5-style
    dynamic ingest.  Bounded-degree streams — the regime the flat ELL layout
    targets; power-law hubs run the sliced/hybrid path instead (DESIGN.md
    §6, ``hub_shootout``).

    Emits events/s per backend plus query p50 — the acceptance gate for the
    ELL backend is events/s >= segment with <10% query-latency regression.
    """
    import jax
    from repro.graphs import generators as gen

    n, m = (1 << 11, 1 << 13) if small else (1 << 13, 1 << 15)
    nv, src, dst, w = gen.erdos_renyi(n, m, seed=13)
    source = int(gen.top_in_degree_sources(nv, dst, 1)[0])
    for delta in (0.1, 0.5):
        log = C.stream_for(
            C.Dataset("er", nv, src, dst, w,
                      gen.top_in_degree_sources(nv, dst)),
            window_frac=1 / 3, delta=delta, query_every=10**9)
        eps: dict[str, float] = {}
        engines: dict[str, SSSPDelEngine] = {}
        for backend in ("segment", "ellpack"):
            for _timed in (False, True):  # first pass warms every jit shape
                eng = SSSPDelEngine(EngineConfig(
                    num_vertices=nv, edge_capacity=m + 64, source=source,
                    relax_backend=backend))
                t0 = time.perf_counter()
                eng.ingest_log(log)
                jax.block_until_ready(eng.state.sssp.dist)
                ingest_s = time.perf_counter() - t0
            eps[backend] = len(log) / ingest_s
            engines[backend] = eng
        # query = device->host readback (µs scale): interleave the reps
        # across backends so clock/GC drift cancels, report p50
        q_lat: dict[str, list[float]] = {b: [] for b in engines}
        for _rep in range(105):
            for b, eng in engines.items():
                q_lat[b].append(eng.query().latency_s)
        for backend, eng in engines.items():
            _check_oracle(eng, sink, "backend_shootout_oracle")
            planner = getattr(eng.backend, "planner", None)
            sink.emit("backend_shootout", dataset="er", n=nv, edges=m,
                      delta=delta, backend=backend, events=len(log),
                      events_per_s=round(eps[backend], 1),
                      query_p50_ms=round(C.pctile(q_lat[backend][5:], 50) * 1e3, 4),
                      rounds=eng.n_rounds,
                      rounds_per_event=round(int(eng.n_rounds) / len(log), 3),
                      ell_rebuilds=getattr(planner, "rebuilds", 0),
                      ell_k=getattr(planner, "k", 0))
        sink.emit("backend_shootout_summary", delta=delta,
                  ell_speedup=round(eps["ellpack"] / eps["segment"], 3))


def hub_shootout(sink: C.CsvSink, small: bool) -> None:
    """Beyond-paper (DESIGN.md §6): the three relaxation backends on an
    in-degree power-law hub stream — the regime the sliced/hybrid layout
    exists for.  Dense ELL pads every row to the (huge) global max
    in-degree; the sliced backend pays per-slice K plus a COO overflow lane
    for hub surplus.  Emits ingest events/s, query p50, and the device
    32-bit value count of each layout (memory proxy) per backend.

    The acceptance gate (benchmarks/check_regression.py) is sliced ingest
    >= 0.8x segment on these streams with query p50 within noise and the
    sliced layout strictly smaller than dense ELL; the sliced-vs-ellpack
    ratio is the headline the layout was built for.
    """
    import jax
    from repro.graphs import generators as gen

    n = (1 << 10) if small else (1 << 12)
    m = 8 * n
    nv, src, dst, w = gen.power_law_hubs(n, m, n_hubs=4, seed=23,
                                         orientation="in")
    source = int(gen.top_in_degree_sources(nv, dst, 1)[0])
    max_indeg = int(np.bincount(dst, minlength=nv).max())
    backends = ("segment", "ellpack", "sliced")
    for delta in (0.1, 0.5):
        log = C.stream_for(
            C.Dataset("plaw", nv, src, dst, w,
                      gen.top_in_degree_sources(nv, dst)),
            window_frac=1 / 3, delta=delta, query_every=10**9)
        eps: dict[str, float] = {}
        engines: dict[str, SSSPDelEngine] = {}
        for backend in backends:
            # first pass warms every jit shape; every backend then takes
            # best-of-2 timed passes (one-sided noise on a shared runner
            # only ever slows a pass down — best-of is the stable ratio
            # estimator, and all ratios compare like for like)
            rates = []
            for timed in (False, True, True):
                eng = SSSPDelEngine(EngineConfig(
                    num_vertices=nv, edge_capacity=m + 64, source=source,
                    relax_backend=backend))
                t0 = time.perf_counter()
                eng.ingest_log(log)
                jax.block_until_ready(eng.state.sssp.dist)
                if timed:
                    rates.append(len(log) / (time.perf_counter() - t0))
            eps[backend] = max(rates)
            engines[backend] = eng
        q_lat: dict[str, list[float]] = {b: [] for b in engines}
        for _rep in range(55):
            for b, eng in engines.items():
                q_lat[b].append(eng.query().latency_s)
        # layout memory proxy in 32-bit VALUES, not cells: an ELL cell is
        # (idx, w) = 2, an overflow/pool entry (src, dst, w) = 3
        sell = engines["sliced"].backend.state
        cells = {
            "segment": 3 * (m + 64),
            "ellpack": 2 * int(engines["ellpack"].backend.state.nbr_w.size),
            "sliced": 2 * int(sell.flat_w.size) + 3 * int(sell.ow.size),
        }
        for backend, eng in engines.items():
            _check_oracle(eng, sink, "hub_shootout_oracle")
            planner = getattr(eng.backend, "planner", None)
            sink.emit("hub_shootout", dataset="plaw", n=nv, edges=m,
                      max_indeg=max_indeg, delta=delta, backend=backend,
                      events=len(log), events_per_s=round(eps[backend], 1),
                      query_p50_ms=round(
                          C.pctile(q_lat[backend][5:], 50) * 1e3, 4),
                      rounds=eng.n_rounds,
                      rounds_per_event=round(int(eng.n_rounds) / len(log), 3),
                      device_values=cells[backend],
                      spills=getattr(planner, "spills", 0),
                      rebuilds=getattr(planner, "rebuilds", 0))
        sink.emit("hub_shootout_summary", delta=delta,
                  sliced_vs_segment=round(eps["sliced"] / eps["segment"], 3),
                  sliced_vs_ellpack=round(eps["sliced"] / eps["ellpack"], 3),
                  cells_vs_ellpack=round(
                      cells["sliced"] / max(cells["ellpack"], 1), 4))


def bucket_shootout(sink: C.CsvSink, small: bool) -> None:
    """Beyond-paper (DESIGN.md §9): the lazy bucketed delta-stepping
    schedule vs the eager per-event rounds schedule, raced across all three
    relaxation backends on the two stress streams — the delta=0.5
    round-bound ER stream (half the events are deletions, so the eager
    schedule pays a full converge epoch per event: the "round tax") and the
    in-degree power-law hub stream.  The bucketed legs drain INSIDE the
    timed window, so the ratio measures deferred-and-coalesced settlement,
    not skipped work; final (dist, parent) bit-identity of every leg
    against the eager segment reference is asserted in-run.

    Second half: the fused Pallas sliced-ELL wave kernel (DESIGN.md §9.4)
    vs the unfused three-dispatch composition on the settled hub layout,
    interpret mode, wave-level best-of timing.  The gates
    (benchmarks/check_regression.py): buckets >= 2.0x rounds events/s on
    the delta=0.5 ER stream, fused >= 1.0x unfused wave on hubs.
    """
    import jax
    import jax.numpy as jnp
    from repro.core.backends.sliced import sliced_relax_wave
    from repro.graphs import generators as gen

    n_er, m_er = (1 << 11, 1 << 13) if small else (1 << 13, 1 << 15)
    nv, esrc, edst, ew = gen.erdos_renyi(n_er, m_er, seed=13)
    er = C.Dataset("er", nv, esrc, edst, ew,
                   gen.top_in_degree_sources(nv, edst))
    n_h = (1 << 10) if small else (1 << 12)
    nh, hs, hd, hw = gen.power_law_hubs(n_h, 8 * n_h, n_hubs=4, seed=23,
                                        orientation="in")
    hub = C.Dataset("plaw", nh, hs, hd, hw,
                    gen.top_in_degree_sources(nh, hd))

    delta = 0.5
    backends = ("segment", "ellpack", "sliced")
    hub_engines: dict[tuple[str, str], SSSPDelEngine] = {}
    for ds in (er, hub):
        m = len(ds.src)
        source = int(ds.sources[0])
        log = C.stream_for(ds, window_frac=1 / 3, delta=delta,
                           query_every=10**9)
        eps: dict[tuple[str, str], float] = {}
        engines: dict[tuple[str, str], SSSPDelEngine] = {}
        for backend in backends:
            for sched in ("rounds", "buckets"):
                kw = ({"wave_schedule": "buckets", "bucket_width": 1.0}
                      if sched == "buckets" else {})
                for _timed in (False, True):  # warm pass covers every shape
                    eng = SSSPDelEngine(EngineConfig(
                        num_vertices=ds.n, edge_capacity=m + 64,
                        source=source, relax_backend=backend, **kw))
                    t0 = time.perf_counter()
                    eng.ingest_log(log)
                    eng.drain()   # settle ALL deferred work inside the clock
                    jax.block_until_ready(eng.state.sssp.dist)
                    dt = time.perf_counter() - t0
                eps[(backend, sched)] = len(log) / dt
                engines[(backend, sched)] = eng
                rounds = int(eng.n_rounds)
                sink.emit("bucket_shootout", dataset=ds.name, n=ds.n,
                          edges=m, delta=delta, backend=backend,
                          schedule=sched, events=len(log),
                          events_per_s=round(eps[(backend, sched)], 1),
                          rounds=rounds,
                          rounds_per_event=round(rounds / len(log), 3))
        # the correctness contract, asserted on the benchmark stream
        # (DESIGN.md §9.2): distances are bit-identical across every
        # (backend, schedule) leg; parents too on the ER stream (continuous
        # weights, unique shortest paths).  The hub stream has UNIT weights
        # — equal-cost paths abound, and the keep-parent-on-tie rule makes
        # the winner depend on epoch arrival order, so there the schedules
        # may settle different-but-equally-valid trees: each leg's parent
        # array is instead checked as a valid SSSP tree over the live edges.
        ref = engines[("segment", "rounds")].query()
        for eng in engines.values():
            q = eng.query()
            np.testing.assert_array_equal(ref.dist, q.dist)
            if ds is er:
                np.testing.assert_array_equal(ref.parent, q.parent)
            else:
                e = eng.state.edges
                act = np.asarray(e.active)
                oracle.check_tree(
                    ds.n, np.asarray(e.src)[act], np.asarray(e.dst)[act],
                    np.asarray(e.w)[act], source,
                    np.asarray(q.dist), np.asarray(q.parent))
        _check_oracle(engines[("segment", "buckets")], sink,
                      "bucket_shootout_oracle")
        for backend in backends:
            sink.emit("bucket_shootout_summary", dataset=ds.name,
                      delta=delta, backend=backend,
                      buckets_vs_rounds=round(
                          eps[(backend, "buckets")]
                          / eps[(backend, "rounds")], 3),
                      rounds_saved=round(
                          int(engines[(backend, "rounds")].n_rounds)
                          / max(int(engines[(backend, "buckets")].n_rounds),
                                1), 2),
                      identical=True)
        if ds is hub:
            hub_engines = engines

    # --- fused Pallas wave kernel vs the unfused three-dispatch composition
    # (DESIGN.md §9.4) on the settled hub-stream sliced layout, interpret
    # mode.  Wave-level timing: best-of batches so one-sided scheduler noise
    # cannot sink the parity gate.
    eng = hub_engines[("sliced", "buckets")]
    planner = eng.backend.planner
    # race on the COMPACTED live layout — the geometry the planner builds at
    # every rebuild (spill-doubling triggers them regularly), not the
    # end-of-stream churn state whose overflow lane is mostly tombstones
    lsrc, ldst, lw = eng.alloc.active_coo()
    planner.widths, planner.ocap = planner.required_geometry(ldst)
    st = planner.rebuild(lsrc, ldst, lw)
    dist, parent = eng.state.sssp.dist, eng.state.sssp.parent
    # engine waves are always frontier-masked (converge loops, bucket
    # drains) — race the two paths the way the engine actually calls them
    frontier = jnp.asarray(np.isfinite(np.asarray(dist)))
    kw = dict(widths=tuple(planner.widths), slice_rows=planner.sr,
              num_vertices=eng.cfg.num_vertices, frontier=frontier)
    reps = 20 if small else 40
    wave_us: dict[str, float] = {}
    variants = (("jnp", dict(use_kernel=False, use_fused=False)),
                ("pallas_unfused", dict(use_kernel=True, use_fused=False)),
                ("pallas_fused", dict(use_fused=True)))
    for name, v in variants:
        jax.block_until_ready(
            sliced_relax_wave(dist, parent, st, **v, **kw))
        best = float("inf")
        for _batch in range(5):
            t0 = time.perf_counter()
            for _ in range(reps):
                out = sliced_relax_wave(dist, parent, st, **v, **kw)
            jax.block_until_ready(out)
            best = min(best, (time.perf_counter() - t0) / reps)
        wave_us[name] = best * 1e6
        sink.emit("bucket_shootout_fused", dataset="plaw", impl=name,
                  n=eng.cfg.num_vertices, overflow_cap=int(st.ow.size),
                  wave_us=round(wave_us[name], 1))
    outs = {name: sliced_relax_wave(dist, parent, st, **v, **kw)
            for name, v in variants}
    for name in ("pallas_unfused", "pallas_fused"):
        np.testing.assert_array_equal(np.asarray(outs["jnp"][0]),
                                      np.asarray(outs[name][0]))
        np.testing.assert_array_equal(np.asarray(outs["jnp"][1]),
                                      np.asarray(outs[name][1]))
    # the gate pairing (check_regression): the fused kernel must beat the
    # EXISTING Pallas sliced wave (that is what "interpret mode" is a
    # property of); the jnp three-dispatch path rides along as a loose
    # lower bound — it has no kernel-dispatch emulation cost to pay, so
    # parity-within-overhead (>= 0.8x) is the honest expectation there
    sink.emit("bucket_shootout_fused_summary",
              fused_vs_pallas=round(
                  wave_us["pallas_unfused"] / wave_us["pallas_fused"], 3),
              fused_vs_jnp=round(wave_us["jnp"] / wave_us["pallas_fused"],
                                 3),
              identical=True)


def dist_engine(sink: C.CsvSink, small: bool) -> None:
    """Beyond-paper (DESIGN.md §5): the sharded dynamic engine vs the
    single-device engine on the same mixed ADD/DEL stream — ingest
    throughput and query p50.  P = local device count (1 on the CI runner;
    8 when the process is started with forced host devices), so on one
    device this measures the pure sharding overhead: shard_map epochs plus
    per-partition host planning, with bit-identical results as the gate.

    Second half (DESIGN.md §7.2): the three relaxation backends ON the
    sharded engine, racing ingest over an in-degree power-law hub stream —
    sharded-sliced must hold >= 0.95x sharded-segment with the three-way
    parity record intact.
    """
    import jax
    from repro.core.dist_engine import (ShardedEngineConfig,
                                        ShardedSSSPDelEngine)
    from repro.graphs import generators as gen

    n, m = (1 << 11, 1 << 13) if small else (1 << 13, 1 << 15)
    nv, src, dst, w = gen.erdos_renyi(n, m, seed=17)
    source = int(gen.top_in_degree_sources(nv, dst, 1)[0])
    n_parts = len(jax.devices())

    def _mk_engine(name):
        if name == "single":
            return SSSPDelEngine(EngineConfig(
                num_vertices=nv, edge_capacity=m + 64, source=source))
        return ShardedSSSPDelEngine(ShardedEngineConfig(
            num_vertices=nv, edges_per_part=m + 64, source=source))

    for delta in (0.1, 0.5):
        log = C.stream_for(
            C.Dataset("er", nv, src, dst, w,
                      gen.top_in_degree_sources(nv, dst)),
            window_frac=1 / 3, delta=delta, query_every=10**9)
        eps: dict[str, float] = {}
        engines: dict[str, object] = {}
        for name in ("single", "sharded"):
            for _timed in (False, True):  # first pass warms every jit shape
                eng = _mk_engine(name)
                t0 = time.perf_counter()
                eng.ingest_log(log)
                jax.block_until_ready(
                    eng.state.sssp.dist if name == "single" else eng.dist)
                ingest_s = time.perf_counter() - t0
            eps[name] = len(log) / ingest_s
            engines[name] = eng
        q_lat: dict[str, list[float]] = {b: [] for b in engines}
        res: dict[str, object] = {}
        for _rep in range(55):
            for b, eng in engines.items():
                res[b] = eng.query()
                q_lat[b].append(res[b].latency_s)
        # the equivalence contract, checked on the benchmark stream too
        np.testing.assert_array_equal(res["single"].dist, res["sharded"].dist)
        np.testing.assert_array_equal(res["single"].parent,
                                      res["sharded"].parent)
        _check_oracle(engines["single"], sink, "dist_engine_oracle")
        for name, eng in engines.items():
            sink.emit("dist_engine", dataset="er", n=nv, edges=m,
                      parts=(1 if name == "single" else n_parts),
                      delta=delta, engine=name, events=len(log),
                      events_per_s=round(eps[name], 1),
                      query_p50_ms=round(
                          C.pctile(q_lat[name][5:], 50) * 1e3, 4),
                      rounds=eng.n_rounds,
                      rounds_per_event=round(int(eng.n_rounds) / len(log), 3))
        sink.emit("dist_engine_summary", delta=delta, parts=n_parts,
                  sharded_vs_single=round(eps["sharded"] / eps["single"], 3),
                  identical=True)

    # --- per-backend sharded ingest on an in-degree power-law hub stream
    # (DESIGN.md §7.2): the sliced layout's win must survive sharding.  The
    # gate (benchmarks/check_regression.py) is sharded-sliced ingest >=
    # 0.95x sharded-segment plus the three-way bit-parity record below.
    nh = (1 << 10) if small else (1 << 12)
    mh = 8 * nh
    nv, src, dst, w = gen.power_law_hubs(nh, mh, n_hubs=4, seed=23,
                                         orientation="in")
    source = int(gen.top_in_degree_sources(nv, dst, 1)[0])
    backends = ("segment", "ellpack", "sliced")
    for delta in (0.1, 0.5):
        log = C.stream_for(
            C.Dataset("plaw", nv, src, dst, w,
                      gen.top_in_degree_sources(nv, dst)),
            window_frac=1 / 3, delta=delta, query_every=10**9)
        eps = {}
        engines = {}
        for backend in backends:
            # best-of-2 timed passes after a warming pass (one-sided noise
            # only slows a pass down; best-of is the stable ratio estimator)
            rates = []
            for timed in (False, True, True):
                eng = ShardedSSSPDelEngine(ShardedEngineConfig(
                    num_vertices=nv, edges_per_part=mh + 64, source=source,
                    relax_backend=backend))
                t0 = time.perf_counter()
                eng.ingest_log(log)
                jax.block_until_ready(eng.dist)
                if timed:
                    rates.append(len(log) / (time.perf_counter() - t0))
            eps[backend] = max(rates)
            engines[backend] = eng
        res = {b: e.query() for b, e in engines.items()}
        # the three-way sharded parity record — asserted in-run, gated in
        # check_regression via the summary row
        for other in ("ellpack", "sliced"):
            np.testing.assert_array_equal(res["segment"].dist,
                                          res[other].dist)
            np.testing.assert_array_equal(res["segment"].parent,
                                          res[other].parent)
        # parity alone can't catch a bug shared by all three sharded
        # engines — anchor the trio against the Dijkstra oracle over the
        # live edge set (from the per-partition host mirrors)
        coo = [a.active_coo() for a in engines["segment"].allocs]
        e_src, e_dst, e_w = (np.concatenate([c[i] for c in coo])
                             for i in range(3))
        dist_ref, _ = oracle.dijkstra(nv, e_src, e_dst, e_w, source)
        ok = bool(np.allclose(
            np.where(np.isfinite(res["segment"].dist),
                     res["segment"].dist, -1),
            np.where(np.isfinite(dist_ref), dist_ref, -1),
            rtol=1e-5, atol=1e-5))
        sink.emit("dist_engine_backends_oracle", delta=delta, oracle_match=ok)
        assert ok, "sharded backends diverged from Dijkstra oracle"
        for backend, eng in engines.items():
            sink.emit("dist_engine", dataset="plaw", n=nv, edges=mh,
                      parts=n_parts, delta=delta,
                      engine=f"sharded-{backend}", events=len(log),
                      events_per_s=round(eps[backend], 1),
                      rounds=eng.n_rounds,
                      rounds_per_event=round(int(eng.n_rounds) / len(log), 3))
        sink.emit("dist_engine_backends_summary", delta=delta, parts=n_parts,
                  sliced_vs_segment=round(eps["sliced"] / eps["segment"], 3),
                  ellpack_vs_segment=round(eps["ellpack"] / eps["segment"], 3),
                  identical=True)


def serving(sink: C.CsvSink, small: bool) -> None:
    """Serving layer (DESIGN.md §8): batched multi-source trace replay on a
    power-law stream at S in {1, 4, 16} concurrent sources, measuring the
    paper's three serving metrics — per-query result latency (p50/p95/p99),
    solution stability (per-epoch dist/parent churn) and sustained
    topology-event throughput — via the repro.serving harness, plus the
    sequential baseline the regression gate compares against: 4 independent
    single-source engines replaying the same workload one after another.

    The gate (benchmarks/check_regression.py) is batched S=4 throughput
    >= 2.0x the 4-sequential-replay throughput — the batched [S, N] state's
    reason to exist: one shared graph layout, one fused epoch per batch
    instead of S.  Bit-parity of every batched lane against its
    single-source engine is asserted in-run (summary row ``identical``).
    """
    import jax
    from repro.graphs import generators as gen
    from repro.serving import TraceRecorder, replay_trace

    n = (1 << 10) if small else (1 << 11)
    m = 4 * n
    nv, src, dst, w = gen.power_law_hubs(n, m, n_hubs=4, seed=31,
                                         orientation="in")
    all_sources = [int(s) for s in gen.top_in_degree_sources(nv, dst, 16)]
    delta = 0.3
    log = C.stream_for(
        C.Dataset("plaw", nv, src, dst, w, np.asarray(all_sources[:3])),
        window_frac=1 / 3, delta=delta, query_every=10**9)

    def trace_for(sources):
        """The same topology stream with one query per served source at
        each of 8 evenly spaced collection points."""
        rec = TraceRecorder()
        step = max(1, len(log) // 8)
        for a in range(0, len(log), step):
            rec.extend_from_log(log[a:a + step])
            for s in sources:
                rec.query(source=s)
        return rec.trace()

    def best_of(n_timed, mk, trace):
        """Warm pass + best-of-n timed replays (fresh engine each pass so
        every pass replays the identical trace; one-sided scheduler noise
        only ever slows a pass down).  Returns the best report."""
        best = None
        for timed in (False,) + (True,) * n_timed:
            eng = mk()
            rep = replay_trace(eng, trace)
            jax.block_until_ready(
                eng.state.sssp.dist if hasattr(eng, "state") else eng.dist)
            if timed and (best is None
                          or rep.events_per_s > best[0].events_per_s):
                best = (rep, eng)
        return best

    def mk_batched(sources):
        return SSSPDelEngine(EngineConfig(
            num_vertices=nv, edge_capacity=m + 64, source=sources[0],
            sources=tuple(sources)))

    reports = {}
    engines = {}
    for S in (1, 4, 16):
        srcs = all_sources[:S]
        n_timed = 1 if S == 16 else 2   # S=16 is ungated — one timed pass
        reports[S], engines[S] = best_of(n_timed, lambda: mk_batched(srcs),
                                         trace_for(srcs))
        sink.emit("serving", dataset="plaw", n=nv, edges=m, delta=delta,
                  backend="segment", s=S, **reports[S].to_record())

    # sequential baseline: 4 single-source engines replay the same
    # workload back to back (each answering only its own queries)
    seq_sources = all_sources[:4]
    seq_traces = [trace_for([s]) for s in seq_sources]
    seq_engines = seq_reports = None
    best_seq = None
    for timed in (False, True, True):
        engs = [SSSPDelEngine(EngineConfig(
            num_vertices=nv, edge_capacity=m + 64, source=s))
            for s in seq_sources]
        t0 = time.perf_counter()
        reps = [replay_trace(e, t) for e, t in zip(engs, seq_traces)]
        for e in engs:
            jax.block_until_ready(e.state.sssp.dist)
        wall = time.perf_counter() - t0
        # keep wall, engines AND per-query reports from the SAME (best)
        # pass so the emitted record is internally consistent
        if timed and (best_seq is None or wall < best_seq):
            best_seq, seq_engines, seq_reports = wall, engs, reps
    n_topo = seq_traces[0].n_topology
    seq_eps = n_topo / best_seq
    seq_lat = [l for r in seq_reports for l in r.latencies]
    sink.emit("serving", dataset="plaw", n=nv, edges=m, delta=delta,
              backend="segment", s=4, engine="sequential/segment",
              n_sources=4, events=sum(len(t) for t in seq_traces),
              topology_events=n_topo, queries=sum(r.queries
                                                  for r in seq_reports),
              wall_s=round(best_seq, 4), events_per_s=round(seq_eps, 1),
              latency_p50_ms=round(C.pctile(seq_lat, 50) * 1e3, 4),
              latency_p95_ms=round(C.pctile(seq_lat, 95) * 1e3, 4),
              latency_p99_ms=round(C.pctile(seq_lat, 99) * 1e3, 4))

    # the serving equivalence contract, asserted on the benchmark stream:
    # every batched lane == its single-source engine, bit for bit
    qb = engines[4].query()
    for i, (s, eng) in enumerate(zip(seq_sources, seq_engines)):
        qs = eng.query()
        np.testing.assert_array_equal(qb.dist[i], qs.dist)
        np.testing.assert_array_equal(qb.parent[i], qs.parent)
    _check_oracle(seq_engines[0], sink, "serving_oracle")
    sink.emit("serving_summary", delta=delta, s=4,
              batched_vs_sequential=round(
                  reports[4].events_per_s / max(seq_eps, 1e-9), 3),
              batched16_vs_sequential=round(
                  reports[16].events_per_s / max(seq_eps, 1e-9), 3),
              identical=True)


def obs_overhead(sink: C.CsvSink, small: bool) -> None:
    """Observability overhead contract (DESIGN.md §10.4): the same
    power-law stream ingested with the telemetry layer off and on, passes
    interleaved so scheduler drift hits both variants equally — on the
    single-device engine HERE and on the sharded engine over 8 forced
    host devices in a subprocess (benchmarks/obs_worker.py; XLA_FLAGS
    must precede jax init).  The instrumented passes run with a
    default-threshold watchdog armed, which must stay silent (§10.8).
    In-run asserts pin the §10 invariants — identical (dist, parent)
    trees and bit-identical rounds/messages via ``metrics_snapshot()``
    (counters must not perturb the computation), every span count equal
    to its engine counter, and histogram totals equal to the flat
    counters (§10.6).  The regression gate (benchmarks/
    check_regression.py) holds instrumented throughput at >= 0.95x
    uninstrumented on BOTH legs."""
    import jax
    from repro.graphs import generators as gen
    from repro.obs import WatchdogConfig

    n = (1 << 10) if small else (1 << 11)
    m = 4 * n
    nv, src, dst, w = gen.power_law_hubs(n, m, n_hubs=4, seed=31,
                                         orientation="in")
    source = int(gen.top_in_degree_sources(nv, dst)[0])
    log = C.stream_for(C.Dataset("plaw", nv, src, dst, w,
                                 np.asarray([source])),
                       window_frac=1 / 3, delta=0.3,
                       query_every=max(1, len(src) // 12))

    def mk(obs_on):
        return SSSPDelEngine(EngineConfig(
            num_vertices=nv, edge_capacity=m + 64, source=source,
            relax_backend="sliced", observability=obs_on,
            # default thresholds: only multi-second stalls fire — this
            # gated bench doubles as the watchdog-stays-silent check
            obs_watchdog=WatchdogConfig() if obs_on else None))

    best = {False: 0.0, True: 0.0}
    final = {}
    for _ in range(3):                      # 1 warm + best-of-2 timed
        for obs_on in (False, True):        # interleaved passes
            eng = mk(obs_on)
            t0 = time.perf_counter()
            eng.ingest_log(log)
            jax.block_until_ready(eng.state.sssp.dist)
            eps = len(log) / (time.perf_counter() - t0)
            if eps > best[obs_on]:
                best[obs_on], final[obs_on] = eps, eng
    for obs_on in (False, True):
        eng = final[obs_on]
        snap = eng.metrics_snapshot()
        sink.emit("obs_overhead", dataset="plaw", n=nv, edges=m,
                  backend="sliced", engine="single", observability=obs_on,
                  events=len(log), events_per_s=round(best[obs_on], 1),
                  epochs=eng.n_epochs, rounds=snap["rounds"],
                  messages=snap["messages"],
                  spans=sum(snap["spans"].values()),
                  **(C.hist_fields(snap) if obs_on else {}))

    # §10 invariants on the benchmark stream: telemetry must be free of
    # algorithmic effect and internally consistent
    q_off, q_on = final[False].query(), final[True].query()
    np.testing.assert_array_equal(q_off.dist, q_on.dist)
    np.testing.assert_array_equal(q_off.parent, q_on.parent)
    on = final[True]
    snap = on.metrics_snapshot()
    assert int(snap["rounds"]) == int(on.n_rounds)
    assert int(snap["messages"]) == int(on.n_messages)
    assert int(final[False].n_rounds) == int(on.n_rounds)
    sp, ct = snap["spans"], snap["counters"]
    for kind, name in (("add_epoch", "add_epochs"),
                       ("del_epoch", "del_epochs"),
                       ("drain", "drains"), ("query", "queries")):
        assert sp.get(kind, 0) == ct.get(name, 0), (kind, sp, ct)
    # histogram totals == flat counters (§10.6) and a silent watchdog
    # on a healthy gated run (§10.8)
    h = snap["histograms"]
    assert h["latency_us"]["count"] == ct["queries"]
    assert h["frontier_occupancy"]["count"] == ct["add_epochs"]
    assert h["waves_per_epoch"]["count"] == \
        ct["add_epochs"] + ct["del_epochs"]
    assert "watchdog_warnings" not in ct, ct.get("watchdog_warnings")
    _check_oracle(on, sink, "obs_overhead_oracle")
    sink.emit("obs_overhead_summary", backend="sliced", engine="single",
              on_vs_off=round(best[True] / max(best[False], 1e-9), 3),
              identical=True)

    # ---- sharded leg: P=8 forced host devices in a fresh process (the
    # XLA device-count flag must precede jax init); the worker runs the
    # same interleaved on/off protocol + in-run §10 asserts and emits
    # OBSROW json lines this section re-emits for the regression gate
    import json
    import os
    import subprocess
    import sys

    cmd = [sys.executable, "-m", "benchmarks.obs_worker"]
    if small:
        cmd.append("--small")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    out = subprocess.run(cmd, capture_output=True, text=True, env=env)
    assert out.returncode == 0, (
        f"obs_worker failed:\n{out.stderr[-3000:]}")
    for line in out.stdout.splitlines():
        if line.startswith("OBSROW "):
            rec = json.loads(line[len("OBSROW "):])
            sink.emit(rec.pop("bench"), **rec)


def scale(sink: C.CsvSink, small: bool) -> None:
    """Paper-scale ingest trajectory (DESIGN.md §11): synthetic N-vertex /
    10N-edge ADD streams synthesized and ingested chunk-by-chunk, one
    FRESH subprocess per size so ``resource.getrusage`` peak RSS is an
    honest per-workload number (benchmarks/scale_worker.py documents the
    budget formula: pool-capacity + vertex + O(chunk) terms, never
    O(stream)).  Small mode runs N ∈ {64k, 256k}; the full run adds the
    acceptance point N=1M / E=10M.  The smallest size cross-checks the
    final tree against the Dijkstra oracle; the regression gate
    (check_regression.gate_scale) holds the events/s floor and the RSS
    ceiling from this PR onward."""
    import json
    import os
    import subprocess
    import sys

    sizes = [1 << 16, 1 << 18] + ([] if small else [1 << 20])
    for n in sizes:
        cmd = [sys.executable, "-m", "benchmarks.scale_worker",
               "--n", str(n), "--e", str(10 * n)]
        if n == sizes[0]:
            cmd.append("--check-oracle")
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in ("src", env.get("PYTHONPATH", "")) if p)
        out = subprocess.run(cmd, capture_output=True, text=True, env=env)
        assert out.returncode == 0, (
            f"scale worker n={n} failed:\n{out.stderr[-2000:]}")
        rec = json.loads(out.stdout.strip().splitlines()[-1])
        assert rec["rss_ok"], (
            f"scale n={n}: peak RSS {rec['peak_rss_mb']}MB over budget "
            f"{rec['rss_budget_mb']}MB")
        assert rec.get("oracle_match", True), f"scale n={n}: oracle mismatch"
        sink.emit("scale", **rec)


def sparse_frontier(sink: C.CsvSink, small: bool) -> None:
    """Frontier-compacted sparse epochs (DESIGN.md §12): pay for the
    affected region, not the graph.

    Two legs, both asserting bit-identity in-run (dist, parent, rounds,
    messages — the §12 contract) before emitting any timing:

      * **localized** — an N-vertex / 4N-edge base graph ingested untimed,
        then a timed phase of small ADD batches confined to a 1k-vertex
        window each: the regime the sparse path targets (a handful of
        affected vertices per epoch on a paper-scale graph).  Gate:
        sparse >= 3x dense events/s at the largest N
        (check_regression.gate_sparse_frontier).  Small mode runs
        N=256k; the full run adds the N=1M acceptance point.  Set
        ``REPRO_SCALE_DATASET=soc-livejournal1`` to source the base graph
        from the checksum-cached SNAP download instead of synthetic RMAT
        (graphs/datasets.fetch_dataset; CI stays synthetic).
      * **auto-high-occupancy** — a delta=0.5 sliding-window ER stream
        whose cascades blow past every ladder rung: ``frontier_mode=
        "auto"`` must route these epochs dense from the host-side
        occupancy bound and stay >= 0.95x the dense engine's throughput
        (the routing-overhead gate)."""
    import os

    import jax
    from repro.graphs import generators as gen

    rng = np.random.default_rng(7)

    def localized_base(n: int):
        name = os.environ.get("REPRO_SCALE_DATASET")
        if name:
            from repro.graphs import datasets as ds_mod
            path = ds_mod.fetch_dataset(name)
            s, d, w = ds_mod.parse_edge_list(path)
            _, s, d = ds_mod.compact_ids(s, d)
            keep = (s < n) & (d < n)
            return (s[keep].astype(np.int32), d[keep].astype(np.int32),
                    w[keep])
        _, s, d, w = gen.rmat(int(np.log2(n)), 4, seed=11)
        return s, d, w

    def run_localized(n: int, mode: str, batches: list) -> tuple:
        bs, bd, bw = localized_base(n)
        kw = {} if mode == "dense" else dict(frontier_mode=mode)
        eng = SSSPDelEngine(EngineConfig(
            num_vertices=n, edge_capacity=len(bs) + 8 * len(batches) + 64,
            source=0, **kw))
        eng.ingest_log(ev.adds(bs, bd, bw))          # untimed base build
        eng.ingest_log(batches[0])                   # warm the batch shape
        jax.block_until_ready(eng.state.sssp.dist)
        t0 = time.perf_counter()
        for b in batches[1:]:
            eng.ingest_log(b)
        jax.block_until_ready(eng.state.sssp.dist)
        return eng, time.perf_counter() - t0

    sizes = [1 << 18] + ([] if small else [1 << 20])
    n_batches = 48
    for n in sizes:
        # localized update batches: 8 fresh edges inside a random 1k window
        batches = []
        for _ in range(n_batches):
            ws = int(rng.integers(0, n - 1024))
            u = ws + rng.integers(0, 1024, 8)
            v = ws + rng.integers(0, 1024, 8)
            batches.append(ev.adds(u.astype(np.int64), v.astype(np.int64),
                                   rng.uniform(0.5, 1.5, 8)))
        runs = {}
        for mode in ("dense", "sparse"):
            eng, took = run_localized(n, mode, batches)
            runs[mode] = (eng, took)
        qd, qs = runs["dense"][0].query(), runs["sparse"][0].query()
        np.testing.assert_array_equal(qd.dist, qs.dist)
        np.testing.assert_array_equal(qd.parent, qs.parent)
        assert runs["dense"][0].n_rounds == runs["sparse"][0].n_rounds
        assert runs["dense"][0].n_messages == runs["sparse"][0].n_messages
        ev_count = 8 * (n_batches - 1)
        for mode, (eng, took) in runs.items():
            sink.emit("sparse_frontier", dataset="localized", n=n,
                      mode=mode, batches=n_batches - 1, batch_events=8,
                      ingest_s=round(took, 4),
                      events_per_s=round(ev_count / max(took, 1e-9), 1),
                      rounds=eng.n_rounds)
        sink.emit("sparse_frontier_summary", dataset="localized", n=n,
                  sparse_vs_dense=round(
                      runs["dense"][1] / max(runs["sparse"][1], 1e-9), 3),
                  identical=True)

    # ---- auto routing overhead on a high-occupancy stream ----
    n, m = 1 << 13, 1 << 15
    nv, src, dst, w = gen.erdos_renyi(n, m, seed=17)
    source = int(gen.top_in_degree_sources(nv, dst, 1)[0])
    log = C.stream_for(
        C.Dataset("er", nv, src, dst, w, gen.top_in_degree_sources(nv, dst)),
        window_frac=1 / 3, delta=0.5, query_every=10**9)
    times, engines = {}, {}
    for mode in ("dense", "auto"):
        kw = {} if mode == "dense" else dict(frontier_mode="auto")
        for _timed in (False, True):   # first pass warms every jit shape
            eng = SSSPDelEngine(EngineConfig(
                num_vertices=nv, edge_capacity=m + 64, source=source, **kw))
            t0 = time.perf_counter()
            eng.ingest_log(log)
            jax.block_until_ready(eng.state.sssp.dist)
            times[mode] = time.perf_counter() - t0
        engines[mode] = eng
    qd, qa = engines["dense"].query(), engines["auto"].query()
    np.testing.assert_array_equal(qd.dist, qa.dist)
    np.testing.assert_array_equal(qd.parent, qa.parent)
    assert engines["dense"].n_rounds == engines["auto"].n_rounds
    for mode, eng in engines.items():
        sink.emit("sparse_frontier", dataset="er-hot", n=nv, mode=mode,
                  events=len(log), ingest_s=round(times[mode], 4),
                  events_per_s=round(len(log) / max(times[mode], 1e-9), 1),
                  rounds=eng.n_rounds)
    sink.emit("sparse_frontier_summary", dataset="er-hot", n=nv,
              auto_vs_dense=round(times["dense"] / max(times["auto"], 1e-9),
                                  3),
              identical=True)


ALL = [table2_static_baseline, fig1_query_latency, fig2_latency_over_time,
       fig3_source_selection, fig4_stability, fig5_throughput,
       fig6_batch_bsp, backend_shootout, hub_shootout, bucket_shootout,
       dist_engine, serving, obs_overhead, scale, sparse_frontier]
