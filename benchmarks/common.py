"""Shared benchmark plumbing: dataset construction (paper §5.1.3 sliding
window streams over RMAT / web-like / ER graphs, scaled to this container),
timing helpers, CSV emission.

Scale note: the paper runs 5M-80M edge graphs on a 64-core Xeon; this
container is one CPU device, so the suite uses graphs of 10k-200k edges.
Every TREND the paper reports (latency gap vs from-scratch, stability,
throughput vs delta, batch-size sensitivity) is scale-free; absolute
numbers are not comparable and are not claimed to be.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import events as ev
from repro.graphs import generators as gen
from repro.graphs import window as win
# THE percentile implementation (repro/serving/metrics.py) — shared with
# the serving harness so bench sections and ServingReport can never
# disagree on how a percentile is computed
from repro.serving.metrics import pctile, percentiles  # noqa: F401


def hist_fields(snapshot: dict) -> dict:
    """Flatten an instrumented engine's distribution data (DESIGN.md
    §10.6) into bench-record fields, so BENCH_sssp.json carries the
    waves-per-epoch and frontier-occupancy histograms — raw bucket counts
    (log2 buckets, ``repro.obs.hist.edges()``) plus p50/p99 estimates —
    not just means.  ``snapshot`` is a ``metrics_snapshot()`` dict; an
    uninstrumented snapshot contributes nothing."""
    out: dict = {}
    for name in ("waves_per_epoch", "frontier_occupancy"):
        h = (snapshot.get("histograms") or {}).get(name)
        if not h:
            continue
        out[f"hist_{name}"] = h["counts"]
        out[f"{name}_p50"] = round(h["p50"], 3)
        out[f"{name}_p99"] = round(h["p99"], 3)
    return out


@dataclasses.dataclass(frozen=True)
class Dataset:
    name: str
    n: int
    src: np.ndarray
    dst: np.ndarray
    w: np.ndarray
    sources: np.ndarray   # top-3 in-degree vertices (PageRank proxy)


def datasets(small: bool = False) -> list[Dataset]:
    """web-Google-like, RMAT and wikipedia-growth-like streams (scaled)."""
    out = []
    scale = 11 if small else 13
    n, s, d, w = gen.rmat(scale, edge_factor=8, seed=1)
    out.append(Dataset("rmat", n, s, d, w, gen.top_in_degree_sources(n, d)))
    n2 = 1 << (scale - 1)
    m2 = n2 * 10
    n2, s2, d2, w2 = gen.power_law_hubs(n2, m2, n_hubs=3, seed=2)
    out.append(Dataset("webg", n2, s2, d2, w2,
                       gen.top_in_degree_sources(n2, d2)))
    return out


def stream_for(ds: Dataset, *, window_frac: float, delta: float,
               query_every: int, seed: int = 0) -> ev.EventLog:
    window = max(1, int(len(ds.src) * window_frac))
    log = win.sliding_window_stream(ds.src, ds.dst, ds.w, window=window,
                                    delta=delta, seed=seed)
    return ev.interleave_queries(log, query_every)


class CsvSink:
    """Prints CSV-ish rows AND keeps structured records so the harness can
    serialize a machine-readable artifact (BENCH_sssp.json) at the end."""

    def __init__(self):
        self.rows: list[str] = []
        self.records: list[dict] = []

    def emit(self, bench: str, **kv):
        kvs = ",".join(f"{k}={v}" for k, v in kv.items())
        row = f"{bench},{kvs}"
        self.rows.append(row)
        self.records.append({"bench": bench, **kv})
        print(row, flush=True)
