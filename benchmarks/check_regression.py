"""Perf/parity regression gates over BENCH_sssp.json (the CI artifact).

Replaces the inline heredoc that used to live in ci.yml: one gate function
per benchmark section, stdlib-only, exit 1 on any violation so the workflow
step fails.  Thresholds are deliberately loose (CI runners are noisy); the
sharp correctness gates — oracle cross-checks and sharded-vs-single
bit-identity — are asserted *inside* the benchmark run itself, and this
script additionally refuses to pass if those parity records are missing.

Run: ``python -m benchmarks.check_regression [--json BENCH_sssp.json]
[--sections backend_shootout,dist_engine,hub_shootout,serving]``

Gates (per delta value found in the section):
  * backend_shootout — ellpack ingest >= 0.95x segment; ellpack query p50
    <= 1.5x segment.
  * hub_shootout — sliced ingest >= 0.8x segment on the power-law stream
    (the floor is deliberately loose: the legs run minutes apart and
    shared-CPU drift swings the ratio ±20%; a real regression reads ~0.2x);
    sliced query p50 <= 1.5x segment; sliced device cells < ellpack's
    (the layout's reason to exist).
  * dist_engine — the summary row must report ``identical=True``
    (sharded == single bit-parity was asserted in-run); at P=1 the sharded
    ingest must hold >= 0.9x single-device (pure sharding overhead bound).
    The per-backend half gates sharded-sliced ingest >= 0.95x
    sharded-segment on the power-law hub stream and requires the three-way
    sharded parity record (``dist_engine_backends_summary``) to be present
    and true.
  * serving — batched S=4 multi-source replay throughput >= 2.0x the
    4-sequential-single-source-replay throughput (DESIGN.md §8: one shared
    layout, one fused epoch per batch instead of S), with the per-lane
    bit-parity record (``serving_summary.identical``) present and true and
    the latency/stability metric fields present on every batched row.
  * obs_overhead — instrumented ingest (observability on) must hold
    >= 0.95x the uninstrumented throughput on the same stream (DESIGN.md
    §10.4: lazy device counters + host-side spans stay out of the epoch
    path), with the bit-identity record (``obs_overhead_summary.identical``)
    present and true.
  * bucket_shootout — the lazy bucketed schedule must hold >= 2.0x the
    eager rounds schedule's events/s on the delta=0.5 ER stream for every
    backend (DESIGN.md §9: the round tax), with the final-state parity
    record present and true; the fused Pallas wave (§9.4) must beat the
    existing Pallas sliced wave (>= 1.0x) and stay within dispatch-overhead
    parity of the jnp three-dispatch path (>= 0.8x) on the power-law hub
    layout.
  * sparse_frontier — the frontier-compacted sparse path (DESIGN.md §12)
    must hold >= 3.0x the dense engine's throughput on the localized-update
    stream at the largest N present (the pay-for-the-affected-region win),
    and ``frontier_mode="auto"`` must hold >= 0.95x dense on the
    high-occupancy delta=0.5 ER stream (the routing-overhead bound); both
    summaries must carry the in-run bit-identity record.
  * scale — every paper-scale ingest row (DESIGN.md §11) must hold the
    chunked-ingest events/s floor (absolute, deliberately loose for CI
    hosts) AND stay under its own documented RSS budget
    (benchmarks/scale_worker.py: pool capacity + vertex + O(chunk) terms,
    never O(stream)); the smallest size must carry a passing oracle-parity
    record.
"""
from __future__ import annotations

import argparse
import json
import sys

DEFAULT_SECTIONS = ("backend_shootout", "dist_engine", "hub_shootout",
                    "bucket_shootout", "serving", "obs_overhead", "scale",
                    "sparse_frontier")

# absolute floor for the scale section's chunked ingest (events/s): local
# runs measure 150k-350k across N=64k..1M; CI's shared 2-core runners are
# ~5-10x slower, a real O(batch)->O(stream) control-plane regression is
# >100x at the top size
SCALE_EVENTS_PER_S_FLOOR = 10_000.0


def _rows(records: list[dict], bench: str) -> list[dict]:
    return [r for r in records if r.get("bench") == bench]


def _by(rows: list[dict], *keys: str) -> dict[tuple, dict]:
    return {tuple(r[k] for k in keys): r for r in rows}


def _ratio_gate(errors: list[str], name: str, num: float, den: float,
                floor: float | None = None, ceil: float | None = None
                ) -> float:
    ratio = num / max(den, 1e-9)
    if floor is not None and ratio < floor:
        errors.append(f"{name}: {ratio:.3f}x < required {floor}x")
    if ceil is not None and ratio > ceil:
        errors.append(f"{name}: {ratio:.3f}x > allowed {ceil}x")
    return ratio


def gate_backend_shootout(records: list[dict]) -> list[str]:
    errors: list[str] = []
    rows = _rows(records, "backend_shootout")
    if not rows:
        return ["backend_shootout: no records found"]
    by = _by(rows, "delta", "backend")
    for d in sorted({r["delta"] for r in rows}):
        ing = _ratio_gate(errors, f"backend_shootout d={d} ell/seg ingest",
                          float(by[(d, "ellpack")]["events_per_s"]),
                          float(by[(d, "segment")]["events_per_s"]),
                          floor=0.95)
        q = _ratio_gate(errors, f"backend_shootout d={d} ell/seg query",
                        float(by[(d, "ellpack")]["query_p50_ms"]),
                        float(by[(d, "segment")]["query_p50_ms"]),
                        ceil=1.5)
        print(f"backend_shootout delta={d}: ell/seg ingest {ing:.2f}x, "
              f"query {q:.2f}x")
    return errors


def gate_hub_shootout(records: list[dict]) -> list[str]:
    errors: list[str] = []
    rows = _rows(records, "hub_shootout")
    if not rows:
        return ["hub_shootout: no records found"]
    by = _by(rows, "delta", "backend")
    for d in sorted({r["delta"] for r in rows}):
        # floor 0.8, not 0.95: the two legs run minutes apart and shared-CPU
        # drift between them swings the ratio ±20% run-to-run (interleaved
        # per-epoch microbenches show parity); a real sliced regression
        # shows up as ~0.2x (dense-ELL territory), far below this floor
        ing = _ratio_gate(errors, f"hub_shootout d={d} sliced/seg ingest",
                          float(by[(d, "sliced")]["events_per_s"]),
                          float(by[(d, "segment")]["events_per_s"]),
                          floor=0.8)
        q = _ratio_gate(errors, f"hub_shootout d={d} sliced/seg query",
                        float(by[(d, "sliced")]["query_p50_ms"]),
                        float(by[(d, "segment")]["query_p50_ms"]),
                        ceil=1.5)
        cells = _ratio_gate(errors, f"hub_shootout d={d} sliced/ell values",
                            float(by[(d, "sliced")]["device_values"]),
                            float(by[(d, "ellpack")]["device_values"]),
                            ceil=1.0)
        print(f"hub_shootout delta={d}: sliced/seg ingest {ing:.2f}x, "
              f"query {q:.2f}x, cells vs ellpack {cells:.3f}x")
    return errors


def gate_dist_engine(records: list[dict]) -> list[str]:
    errors: list[str] = []
    rows = _rows(records, "dist_engine")
    summaries = _rows(records, "dist_engine_summary")
    if not rows or not summaries:
        return ["dist_engine: no records found"]
    by = _by(rows, "delta", "engine")
    for s in summaries:
        d = s["delta"]
        if str(s.get("identical")) != "True":
            errors.append(f"dist_engine d={d}: sharded/single parity record "
                          f"missing or false: identical={s.get('identical')}")
        ratio = float(by[(d, "sharded")]["events_per_s"]) \
            / max(float(by[(d, "single")]["events_per_s"]), 1e-9)
        parts = int(s.get("parts", 0))
        if parts == 1 and ratio < 0.9:
            errors.append(f"dist_engine d={d}: sharded P=1 ingest {ratio:.3f}x "
                          f"single < required 0.9x")
        print(f"dist_engine delta={d} P={parts}: sharded/single ingest "
              f"{ratio:.2f}x, identical={s.get('identical')}")
    # per-backend sharded ingest on the power-law hub stream (DESIGN.md
    # §7.2): the three-way parity record must be present and true, and
    # sharded-sliced must hold the hub-stream ingest floor vs
    # sharded-segment
    bk_summaries = _rows(records, "dist_engine_backends_summary")
    if not bk_summaries:
        return errors + ["dist_engine: no sharded per-backend records found "
                         "(dist_engine_backends_summary)"]
    for s in bk_summaries:
        d = s["delta"]
        if str(s.get("identical")) != "True":
            errors.append(f"dist_engine backends d={d}: three-way sharded "
                          f"parity record missing or false: "
                          f"identical={s.get('identical')}")
        ing = _ratio_gate(errors,
                          f"dist_engine backends d={d} sliced/seg ingest",
                          float(by[(d, "sharded-sliced")]["events_per_s"]),
                          float(by[(d, "sharded-segment")]["events_per_s"]),
                          floor=0.95)
        print(f"dist_engine backends delta={d}: sharded sliced/segment "
              f"ingest {ing:.2f}x, identical={s.get('identical')}")
    return errors


def gate_serving(records: list[dict]) -> list[str]:
    errors: list[str] = []
    rows = _rows(records, "serving")
    summaries = _rows(records, "serving_summary")
    if not rows or not summaries:
        return ["serving: no records found"]
    # every batched row must carry the three serving metrics (DESIGN.md §8)
    metric_keys = ("events_per_s", "latency_p50_ms", "latency_p95_ms",
                   "latency_p99_ms", "churn_mean", "stability_parent")
    for r in rows:
        if str(r.get("engine", "")).startswith("sequential"):
            continue
        missing = [k for k in metric_keys if k not in r]
        if missing:
            errors.append(f"serving s={r.get('s')}: metric field(s) "
                          f"missing from record: {missing}")
    for s in summaries:
        if str(s.get("identical")) != "True":
            errors.append(f"serving: batched-lane parity record missing or "
                          f"false: identical={s.get('identical')}")
    # the throughput gate is per-artifact, not per-summary
    by = _by(rows, "engine", "s")
    ratio = _ratio_gate(
        errors, "serving batched-S=4 / 4-sequential ingest",
        float(by[("single/segment", 4)]["events_per_s"]),
        float(by[("sequential/segment", 4)]["events_per_s"]),
        floor=2.0)
    print(f"serving: batched S=4 vs 4x sequential ingest {ratio:.2f}x, "
          f"identical={[str(s.get('identical')) for s in summaries]}")
    return errors


def gate_bucket_shootout(records: list[dict]) -> list[str]:
    errors: list[str] = []
    rows = _rows(records, "bucket_shootout")
    summaries = _rows(records, "bucket_shootout_summary")
    if not rows or not summaries:
        return ["bucket_shootout: no records found"]
    by = _by(rows, "dataset", "backend", "schedule")
    for s in summaries:
        if str(s.get("identical")) != "True":
            errors.append(f"bucket_shootout {s.get('dataset')}/"
                          f"{s.get('backend')}: final-state parity record "
                          f"missing or false: identical={s.get('identical')}")
    # the round-tax gate runs on the ER stream only (the ISSUE's
    # round-bound regime); the hub-stream ratios are informational
    for backend in sorted({r["backend"] for r in rows}):
        ratio = _ratio_gate(
            errors, f"bucket_shootout er {backend} buckets/rounds ingest",
            float(by[("er", backend, "buckets")]["events_per_s"]),
            float(by[("er", backend, "rounds")]["events_per_s"]),
            floor=2.0)
        print(f"bucket_shootout er {backend}: buckets/rounds ingest "
              f"{ratio:.2f}x")
    fused = _rows(records, "bucket_shootout_fused_summary")
    if not fused:
        return errors + ["bucket_shootout: no fused-wave records found "
                         "(bucket_shootout_fused_summary)"]
    for s in fused:
        if str(s.get("identical")) != "True":
            errors.append("bucket_shootout fused: wave parity record "
                          f"missing or false: identical={s.get('identical')}")
        vp = float(s.get("fused_vs_pallas", 0.0))
        vj = float(s.get("fused_vs_jnp", 0.0))
        if vp < 1.0:
            errors.append(f"bucket_shootout fused: {vp:.3f}x < required "
                          f"1.0x vs the existing Pallas sliced wave")
        # loose floor: the jnp path pays no pallas_call overhead, and in
        # interpret mode the fused kernel carries ~35-50us of fixed per-call
        # emulation cost plus the same ±20% shared-CPU drift as the hub
        # gate — the binding requirement is the >= 1.0x vs-Pallas gate above
        if vj < 0.8:
            errors.append(f"bucket_shootout fused: {vj:.3f}x < required "
                          f"0.8x vs the jnp three-dispatch path")
        print(f"bucket_shootout fused: vs pallas {vp:.2f}x, "
              f"vs jnp {vj:.2f}x")
    return errors


def gate_obs_overhead(records: list[dict]) -> list[str]:
    errors: list[str] = []
    rows = _rows(records, "obs_overhead")
    summaries = _rows(records, "obs_overhead_summary")
    if not rows or not summaries:
        return ["obs_overhead: no records found"]
    # two legs since the sharded P=8 worker landed: the single-device
    # engine and the sharded engine each carry their own interleaved
    # on/off pair and must each hold the 0.95x floor (DESIGN.md §10.4)
    engines = sorted({str(r.get("engine", "single")) for r in rows})
    for leg in ("single", "sharded"):
        if leg not in engines:
            errors.append(f"obs_overhead: missing {leg}-engine leg")
    for leg in engines:
        by = _by([r for r in rows
                  if str(r.get("engine", "single")) == leg],
                 "observability")
        if (True,) not in by or (False,) not in by:
            errors.append(f"obs_overhead[{leg}]: missing on/off pair")
            continue
        # instrumented ingest must stay within 5% of uninstrumented; the
        # rounds/messages bit-identity itself is asserted in-run
        ratio = _ratio_gate(errors, f"obs_overhead[{leg}] on/off ingest",
                            float(by[(True,)]["events_per_s"]),
                            float(by[(False,)]["events_per_s"]),
                            floor=0.95)
        print(f"obs_overhead[{leg}]: instrumented/uninstrumented ingest "
              f"{ratio:.2f}x")
    for s in summaries:
        if str(s.get("identical")) != "True":
            errors.append(f"obs_overhead: bit-identity record missing or "
                          f"false: identical={s.get('identical')}")
    sum_engines = {str(s.get("engine", "single")) for s in summaries}
    for leg in ("single", "sharded"):
        if leg not in sum_engines:
            errors.append(f"obs_overhead: missing {leg} summary record")
    return errors


def gate_scale(records: list[dict]) -> list[str]:
    errors: list[str] = []
    rows = _rows(records, "scale")
    if not rows:
        return ["scale: no records found"]
    smallest = min(rows, key=lambda r: int(r["n"]))
    if str(smallest.get("oracle_match")) != "True":
        errors.append(f"scale n={smallest['n']}: oracle parity record "
                      f"missing or false: "
                      f"oracle_match={smallest.get('oracle_match')}")
    for r in sorted(rows, key=lambda r: int(r["n"])):
        n, eps = int(r["n"]), float(r["events_per_s"])
        peak = float(r["peak_rss_mb"])
        budget = float(r["rss_budget_mb"])
        if eps < SCALE_EVENTS_PER_S_FLOOR:
            errors.append(f"scale n={n}: ingest {eps:.0f} events/s < "
                          f"required {SCALE_EVENTS_PER_S_FLOOR:.0f}")
        if peak > budget:
            errors.append(f"scale n={n}: peak RSS {peak:.0f}MB > budget "
                          f"{budget:.0f}MB (O(stream) host state?)")
        print(f"scale n={n}: {eps:.0f} events/s, peak RSS {peak:.0f}MB / "
              f"budget {budget:.0f}MB, waves={r.get('waves')}")
    return errors


def gate_sparse_frontier(records: list[dict]) -> list[str]:
    errors: list[str] = []
    summaries = _rows(records, "sparse_frontier_summary")
    if not summaries:
        return ["sparse_frontier: no records found"]
    for s in summaries:
        if str(s.get("identical")) != "True":
            errors.append(f"sparse_frontier {s.get('dataset')}: bit-identity "
                          f"record missing or false: "
                          f"identical={s.get('identical')}")
    loc = [s for s in summaries if s.get("dataset") == "localized"]
    if not loc:
        errors.append("sparse_frontier: no localized-stream summary found")
    else:
        # the acceptance point is the largest N the run produced (small mode
        # runs 256k; the full run adds N=1M)
        top = max(loc, key=lambda r: int(r["n"]))
        ratio = float(top.get("sparse_vs_dense", 0.0))
        if ratio < 3.0:
            errors.append(f"sparse_frontier localized n={top['n']}: sparse "
                          f"{ratio:.2f}x dense < required 3.0x")
        print(f"sparse_frontier localized n={top['n']}: sparse/dense "
              f"{ratio:.2f}x, identical={top.get('identical')}")
    hot = [s for s in summaries if s.get("dataset") == "er-hot"]
    if not hot:
        errors.append("sparse_frontier: no high-occupancy auto summary found")
    else:
        ratio = float(hot[0].get("auto_vs_dense", 0.0))
        if ratio < 0.95:
            errors.append(f"sparse_frontier er-hot: auto {ratio:.3f}x dense "
                          f"< required 0.95x (routing overhead)")
        print(f"sparse_frontier er-hot: auto/dense {ratio:.2f}x, "
              f"identical={hot[0].get('identical')}")
    return errors


GATES = {
    "backend_shootout": gate_backend_shootout,
    "sparse_frontier": gate_sparse_frontier,
    "scale": gate_scale,
    "bucket_shootout": gate_bucket_shootout,
    "dist_engine": gate_dist_engine,
    "hub_shootout": gate_hub_shootout,
    "obs_overhead": gate_obs_overhead,
    "serving": gate_serving,
}


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--json", default="BENCH_sssp.json")
    p.add_argument("--sections", default=",".join(DEFAULT_SECTIONS),
                   help="comma-separated gate names (default: all)")
    args = p.parse_args()
    sections = [s for s in args.sections.split(",") if s]
    unknown = [s for s in sections if s not in GATES]
    if unknown:
        print(f"error: unknown gate section(s): {','.join(unknown)} "
              f"(known: {','.join(GATES)})", file=sys.stderr)
        return 2
    with open(args.json) as f:
        records = json.load(f)["records"]
    errors: list[str] = []
    for s in sections:
        errors += GATES[s](records)
    for e in errors:
        print(f"REGRESSION: {e}", file=sys.stderr)
    if not errors:
        print(f"all gates passed: {','.join(sections)}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
