"""Scale-bench worker: one (N, E) ingest measured in a FRESH process.

Run by the ``scale`` section of benchmarks/bench_sssp.py via
``python -m benchmarks.scale_worker --n ... --e ...``; a fresh process
per size makes ``resource.getrusage(RUSAGE_SELF).ru_maxrss`` an honest
peak for exactly this workload (no residue from earlier sections).

The workload is the paper-scale ingest path end to end (DESIGN.md §11):
a synthetic E-event ADD stream is SYNTHESIZED chunk-by-chunk (a seeded
rng per chunk — no full-stream materialization anywhere in the process)
and fed through ``StreamEngineBase.ingest_log``'s chunked-iterable path
into an engine on the bucketed wave schedule, which defers convergence
work so ingest cost stays per-batch; one drain at the final query
settles the tree.  Random (u, v) pairs collide on ~E²/2 / (N² ) slots
(≈ 50 rows at every bench size) — duplicates are dropped by the
allocator, exercising its collision path without meaningfully changing
E.

Peak RSS is read BEFORE the optional oracle check (the pure-Python
Dijkstra would dominate the high-water mark) and compared against the
documented budget:

    budget_mb = BASE_MB + EDGE_BYTES * capacity / 1e6
                        + VERTEX_BYTES * n / 1e6 + CHUNK_MB

  BASE_MB     interpreter + numpy + jax/XLA CPU runtime floor
  EDGE_BYTES  per pool slot: host mirror (13 B) + columnar index
              (12 B/cell at ≤ 2x pow2 slack, + the doubling-rebuild
              transient) + free stack (4 B) + the device pool and its
              functional-update double buffer (2 x 13 B)
  VERTEX_BYTES dist/parent/pending + bucket bookkeeping, a few copies
  CHUNK_MB    transient per-chunk arrays + pow2-padded jit batches

The point of the bound: it scales with POOL CAPACITY and CHUNK size
only — a control plane or replay path that held O(stream) Python
objects (the pre-§11 dict planner at E ≥ 10M) blows straight past it.

Emits one JSON line on stdout; benchmarks/bench_sssp.py turns it into a
``scale`` record gated by check_regression (events/s floor, RSS
ceiling, oracle parity at the smallest size).
"""
from __future__ import annotations

import argparse
import json
import resource
import sys
import time

import numpy as np

BASE_MB = 900.0
EDGE_BYTES = 120.0
VERTEX_BYTES = 80.0
CHUNK_MB = 96.0


def rss_budget_mb(n: int, capacity: int) -> float:
    return (BASE_MB + EDGE_BYTES * capacity / 1e6
            + VERTEX_BYTES * n / 1e6 + CHUNK_MB)


def peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, required=True)
    ap.add_argument("--e", type=int, required=True)
    ap.add_argument("--chunk", type=int, default=1 << 16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--alloc-impl", default="columnar")
    ap.add_argument("--check-oracle", action="store_true")
    args = ap.parse_args()

    import repro
    from repro.core import events as ev

    n, e, chunk = args.n, args.e, args.chunk
    cap = e + 64
    eng = repro.make_engine(
        num_vertices=n, edge_capacity=cap, source=0,
        wave_schedule="buckets", bucket_width=float("inf"),
        alloc_impl=args.alloc_impl)

    def synth_chunks():
        done, i = 0, 0
        while done < e:
            m = min(chunk, e - done)
            rng = np.random.default_rng((args.seed << 20) + i)
            src = rng.integers(0, n, m, dtype=np.int64)
            dst = rng.integers(0, n, m, dtype=np.int64)
            w = rng.uniform(0.1, 1.0, m).astype(np.float32)
            yield ev.adds(src, dst, w)
            done += m
            i += 1

    t0 = time.perf_counter()
    eng.ingest_log(synth_chunks())
    ingest_s = time.perf_counter() - t0
    t1 = time.perf_counter()
    res = eng.query()          # one drain settles the deferred waves
    query_s = time.perf_counter() - t1
    peak_mb = peak_rss_mb()    # read BEFORE any oracle bookkeeping
    budget_mb = rss_budget_mb(n, cap)

    oracle_match = None
    if args.check_oracle:
        from repro.core import oracle
        lsrc, ldst, lw = eng.alloc.active_coo()
        dist_ref, _ = oracle.dijkstra(n, lsrc, ldst, lw, 0)
        dist = np.asarray(res.dist)
        oracle_match = bool(np.allclose(
            np.where(np.isfinite(dist), dist, -1),
            np.where(np.isfinite(dist_ref), dist_ref, -1),
            rtol=1e-5, atol=1e-5))

    rec = {
        "n": n, "e": e, "chunk": chunk, "alloc_impl": args.alloc_impl,
        "live_edges": int(eng.alloc.mactive.sum()),
        "events_per_s": round(e / max(ingest_s, 1e-9), 1),
        "ingest_s": round(ingest_s, 3),
        "query_s": round(query_s, 3),
        "waves": int(eng.n_rounds),
        "epochs": int(eng.n_epochs),
        "peak_rss_mb": round(peak_mb, 1),
        "rss_budget_mb": round(budget_mb, 1),
        "rss_ok": bool(peak_mb <= budget_mb),
    }
    if oracle_match is not None:
        rec["oracle_match"] = oracle_match
    print(json.dumps(rec))
    return 0


if __name__ == "__main__":
    sys.exit(main())
