"""End-to-end LM training driver with checkpoint/restart fault tolerance.

Run: PYTHONPATH=src python examples/train_lm.py            (quick, ~1 min)
     PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
                                                (the ~100M-param run)

Demonstrates, end to end on one machine, the exact stack the 256-chip
dry-run lowers: TokenStream data pipeline -> lm_loss -> grad accumulation ->
AdamW -> chunked atomic checkpoints, plus a KILL/RESUME cycle in the middle
(the fault-tolerance contract of train/checkpoint.py).
"""
import argparse
import shutil
import subprocess
import sys
import tempfile


def run(argv, check=True):
    cmd = [sys.executable, "-m", "repro.launch.train"] + argv
    print("+", " ".join(cmd), flush=True)
    return subprocess.run(cmd, check=check).returncode


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--preset", default="smoke", choices=["smoke", "100m"])
    p.add_argument("--steps", type=int, default=60)
    args = p.parse_args()

    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        fail_at = args.steps // 2
        print(f"=== phase 1: train with an injected crash at step {fail_at}")
        rc = run(["--arch", "qwen3-14b", "--preset", args.preset,
                  "--steps", str(args.steps), "--ckpt-dir", ckpt_dir,
                  "--ckpt-every", str(max(args.steps // 6, 1)),
                  "--fail-at-step", str(fail_at)], check=False)
        assert rc == 17, f"expected injected-failure exit 17, got {rc}"

        print("=== phase 2: resume from the last atomic checkpoint")
        run(["--arch", "qwen3-14b", "--preset", args.preset,
             "--steps", str(args.steps), "--ckpt-dir", ckpt_dir,
             "--ckpt-every", str(max(args.steps // 6, 1)), "--resume"])
        print("=== restart cycle complete: loss continued from checkpoint")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
