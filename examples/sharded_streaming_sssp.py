"""Streaming SSSP over a sliding-window event stream, sharded across the
local device mesh (DESIGN.md §5, §7.2).

Run: PYTHONPATH=src python examples/sharded_streaming_sssp.py [--delta 0.3]

Multi-partition on one host (8 forced host devices):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/sharded_streaming_sssp.py

Pick a relaxation backend (one RelaxBackend protocol serves both engines —
the sharded engine runs one shard-local layout per partition and plugs its
wave into the shard_map epochs):

    # portable COO scatter-min (default)
    ... sharded_streaming_sssp.py --backend segment
    # incrementally maintained dense ELL block (DESIGN.md §2)
    ... sharded_streaming_sssp.py --backend ellpack
    # hub-aware sliced-ELL + overflow hybrid for power-law in-degree
    # graphs (DESIGN.md §6) — pair with --hubs for its target workload
    ... sharded_streaming_sssp.py --backend sliced --hubs

Replays an RMAT stream with windowed deletions through the sharded engine
(vertex partition = all local devices flattened), reports the paper's
metrics plus the per-partition edge-pool fill, and cross-checks the final
tree bit-for-bit against the single-device engine *running the same
backend*.  ``--balanced`` relabels vertices so shards own ~equal in-edge
mass (power-law hubs otherwise load a single shard).

Serving-layer trace flags (DESIGN.md §8): ``--record-trace PATH`` saves
the generated workload; ``--replay-trace PATH`` replays a recorded trace
through the sharded engine + metrics harness (missing/incompatible paths
exit with code 2).  ``--dataset PATH`` streams a real SNAP/Konect edge
list through the same pipeline (graphs/datasets.py; bad paths exit 2).
Engines are built through ``repro.make_engine`` (DESIGN.md §11.5).

Observability flags (DESIGN.md §10): ``--trace-out PATH`` writes the
engine's span trace as Chrome trace-event JSON (loads in Perfetto),
``--log-json PATH`` writes spans + the final ``metrics_snapshot`` as
JSONL; either enables the engine's counter registry / flight recorder,
and a nonexistent parent directory exits with code 2.  ``--buckets``
switches both engines to the bucketed delta-stepping wave schedule.
"""
import argparse
import time

import numpy as np

import jax

import repro
from repro.core import events as ev
from repro.core.engine import RELAX_BACKENDS
from repro.graphs import generators as gen
from repro.graphs import partition as part_mod
from repro.graphs import window as win
from repro.obs import out_path_or_exit
from repro.serving import TraceRecorder, load_trace_or_exit, replay_trace

from streaming_sssp import add_obs_flags, dump_obs, obs_paths


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--scale", type=int, default=10)
    p.add_argument("--delta", type=float, default=0.3)
    p.add_argument("--window-frac", type=float, default=0.3)
    p.add_argument("--exchange", choices=("allgather", "delta"),
                   default="allgather")
    p.add_argument("--backend", choices=RELAX_BACKENDS, default="segment",
                   help="relaxation backend for BOTH engines "
                        "(core/backends/, DESIGN.md §7)")
    p.add_argument("--hubs", action="store_true",
                   help="in-degree power-law hub graph instead of RMAT "
                        "(the sliced backend's target workload)")
    p.add_argument("--balanced", action="store_true",
                   help="edge-balanced vertex relabeling "
                        "(graphs/partition.edge_balanced_relabeling)")
    p.add_argument("--dataset", metavar="PATH",
                   help="replay a real SNAP/Konect edge list (graphs/"
                        "datasets.py; bad paths exit 2)")
    p.add_argument("--record-trace", metavar="PATH",
                   help="save the generated workload as a serving trace "
                        "(repro/serving/trace.py, DESIGN.md §8.2)")
    p.add_argument("--replay-trace", metavar="PATH",
                   help="replay a recorded trace through the sharded "
                        "engine and report the serving metrics "
                        "(unknown paths exit 2)")
    p.add_argument("--buckets", action="store_true",
                   help="bucketed delta-stepping wave schedule "
                        "(core/buckets.py, DESIGN.md §9) on both engines")
    add_obs_flags(p)
    args = p.parse_args()
    # fail fast on unwritable observability destinations (exit 2)
    for path in obs_paths(args):
        if path:
            out_path_or_exit(path)
    obs_on = any(obs_paths(args))
    schedule = "buckets" if args.buckets else "rounds"

    if args.dataset:
        n, trace = repro.load_dataset_or_exit(
            args.dataset, window_frac=args.window_frac, delta=args.delta)
        log = ev.interleave_queries(trace.to_log(),
                                    max(1, trace.n_topology // 10))
        trace = repro.ServingTrace.from_log(log)

    if args.replay_trace or args.dataset:
        if args.replay_trace:
            trace = load_trace_or_exit(args.replay_trace)
            topo = trace.kind != ev.QUERY
            n = int(max(trace.src[topo].max(initial=0),
                        trace.dst[topo].max(initial=0))) + 1
        parts = len(jax.devices())
        epp = int(trace.n_topology * 1.3) // max(parts // 2, 1) + 64
        source = int(gen.top_in_degree_sources(
            n, trace.dst[trace.kind == ev.ADD].astype(np.int64))[0])
        eng = repro.make_engine(
            num_vertices=n, edge_capacity=epp * parts, source=source,
            partitions=parts, exchange=args.exchange,
            relax_backend=args.backend, wave_schedule=schedule,
            observability=obs_on)
        report = replay_trace(eng, trace)
        print(f"trace: {args.replay_trace or args.dataset} "
              f"source={source} partitions={parts} schedule={schedule}")
        print(report.summary())
        dump_obs(eng, args)
        return

    if args.hubs:
        n, src, dst, w = gen.power_law_hubs(1 << args.scale,
                                            8 << args.scale, n_hubs=4,
                                            seed=7, orientation="in")
    else:
        n, src, dst, w = gen.rmat(args.scale, edge_factor=8, seed=7)
    source = int(gen.top_in_degree_sources(n, dst)[0])
    window = int(len(src) * args.window_frac)
    log = win.sliding_window_stream(src, dst, w, window=window,
                                    delta=args.delta, seed=0)
    log = ev.interleave_queries(log, window // 10)
    parts = len(jax.devices())
    print(f"graph: n={n} stream={len(log)} events (delta={args.delta}) "
          f"source={source} partitions={parts} backend={args.backend}")

    if args.record_trace:
        rec = TraceRecorder()
        rec.extend_from_log(log)
        rec.trace().save(args.record_trace)
        print(f"recorded trace: {args.record_trace} ({len(log)} events)")

    relabel = None
    if args.balanced:
        relabel = part_mod.edge_balanced_relabeling(n, dst, parts)

    epp = int(len(src) * 1.3) // max(parts // 2, 1) + 64
    eng = repro.make_engine(
        num_vertices=n, edge_capacity=epp * parts, source=source,
        partitions=parts, exchange=args.exchange,
        relax_backend=args.backend, wave_schedule=schedule,
        observability=obs_on, relabel=relabel)
    lat, stab = [], []
    t0 = time.perf_counter()

    def on_query(r):
        lat.append(r.latency_s)
        stab.append(eng.stability_vs_prev(r.parent, source=r.source))

    eng.ingest_log(log, on_query=on_query)
    wall = time.perf_counter() - t0

    fill = eng.partition_fill()
    print(f"queries: {len(lat)}  latency p50 {np.median(lat)*1e3:.3f}ms")
    print(f"stability (predecessor overlap): p50 {np.median(stab):.4f}")
    print(f"ingestion: {len(log)/wall:.0f} events/s "
          f"({eng.n_epochs} epochs, {eng.n_rounds} message waves)")
    print(f"partition fill (live edges/shard): min={fill.min()} "
          f"max={fill.max()} imbalance={fill.max()/max(fill.mean(), 1):.2f}x")

    dump_obs(eng, args)

    # cross-check: the sharded run must equal the single-device engine
    # running the same relaxation backend
    ref = repro.make_engine(num_vertices=n,
                            edge_capacity=int(len(src) * 1.3) + 64,
                            source=source, relax_backend=args.backend,
                            wave_schedule=schedule)
    ref.ingest_log(log)
    q_ref, q = ref.query(), eng.query()
    np.testing.assert_array_equal(q_ref.dist, q.dist)
    if relabel is None:
        np.testing.assert_array_equal(q_ref.parent, q.parent)
    print("single-device equivalence: OK (bit-identical dist"
          f"{', parent' if relabel is None else ''})")


if __name__ == "__main__":
    main()
