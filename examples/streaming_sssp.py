"""Streaming SSSP over a sliding-window event stream (the paper's §5 setup).

Run: PYTHONPATH=src python examples/streaming_sssp.py [--delta 0.3]

Generates an RMAT graph, replays it as a timestamped stream with windowed
deletions (probability --delta), queries every W/10 events, and reports the
paper's three metrics: query latency, tree stability, ingestion rate —
plus a from-scratch ReMo baseline for the latency comparison.

Serving-layer trace flags (DESIGN.md §8):

    # save the generated workload as an on-disk trace
    ... streaming_sssp.py --record-trace /tmp/stream.trace
    # replay a recorded trace through the engine + metrics harness
    # (a missing/incompatible trace path exits with code 2)
    ... streaming_sssp.py --replay-trace /tmp/stream.trace
"""
import argparse
import time

import numpy as np

from repro.core import events as ev
from repro.core.baseline import ReMoBaseline
from repro.core.engine import EngineConfig, SSSPDelEngine
from repro.graphs import generators as gen
from repro.graphs import window as win
from repro.serving import (ServingTrace, TraceRecorder, load_trace_or_exit,
                           replay_trace)


def trace_bounds(trace: ServingTrace) -> tuple[int, int]:
    """(num_vertices, topology_events) implied by a trace."""
    topo = trace.kind != ev.QUERY
    n = int(max(trace.src[topo].max(initial=0),
                trace.dst[topo].max(initial=0))) + 1
    return n, int(topo.sum())


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--scale", type=int, default=11)
    p.add_argument("--delta", type=float, default=0.3)
    p.add_argument("--window-frac", type=float, default=0.3)
    p.add_argument("--backend", choices=("segment", "ellpack", "sliced"),
                   default="segment",
                   help="relaxation backend (DESIGN.md §2/§6; ellpack is "
                        "the bounded-degree fast path, sliced the hub-aware "
                        "hybrid for power-law in-degrees)")
    p.add_argument("--power-law", action="store_true",
                   help="stream in-degree power-law hubs instead of RMAT "
                        "(the sliced backend's target workload)")
    p.add_argument("--record-trace", metavar="PATH",
                   help="save the generated workload as a serving trace "
                        "(repro/serving/trace.py, DESIGN.md §8.2)")
    p.add_argument("--replay-trace", metavar="PATH",
                   help="replay a recorded trace through the engine and "
                        "report the serving metrics (unknown paths exit 2)")
    args = p.parse_args()

    if args.replay_trace:
        trace = load_trace_or_exit(args.replay_trace)
        n, n_topo = trace_bounds(trace)
        cap = int(n_topo * 1.3) + 64
        source = int(gen.top_in_degree_sources(
            n, trace.dst[trace.kind == ev.ADD].astype(np.int64))[0])
        eng = SSSPDelEngine(EngineConfig(n, cap, source,
                                         relax_backend=args.backend))
        report = replay_trace(eng, trace)
        print(f"trace: {args.replay_trace} source={source}")
        print(report.summary())
        return

    if args.power_law:
        n = 1 << args.scale
        n, src, dst, w = gen.power_law_hubs(n, 10 * n, n_hubs=4, seed=7,
                                            orientation="in")
    else:
        n, src, dst, w = gen.rmat(args.scale, edge_factor=8, seed=7)
    source = int(gen.top_in_degree_sources(n, dst)[0])
    window = int(len(src) * args.window_frac)
    log = win.sliding_window_stream(src, dst, w, window=window,
                                    delta=args.delta, seed=0)
    log = ev.interleave_queries(log, window // 10)
    print(f"graph: n={n} stream={len(log)} events "
          f"(delta={args.delta}, window={window}) source={source}")

    if args.record_trace:
        rec = TraceRecorder()
        rec.extend_from_log(log)
        rec.trace().save(args.record_trace)
        print(f"recorded trace: {args.record_trace} ({len(log)} events)")

    cap = int(len(src) * 1.3) + 64
    eng = SSSPDelEngine(EngineConfig(n, cap, source,
                                     relax_backend=args.backend))
    lat, stab = [], []
    t0 = time.perf_counter()

    def on_query(r):
        lat.append(r.latency_s)
        stab.append(eng.stability_vs_prev(r.parent, source=r.source))

    eng.ingest_log(log, on_query=on_query)
    wall = time.perf_counter() - t0

    base = ReMoBaseline(n, cap, source)
    base_lat = [r.latency_s for r in base.ingest_log(log)]

    print(f"queries: {len(lat)}")
    print(f"latency p50: ours {np.median(lat)*1e3:.3f}ms | "
          f"ReMo-from-scratch {np.median(base_lat)*1e3:.3f}ms | "
          f"speedup {np.median(base_lat)/max(np.median(lat),1e-9):.1f}x")
    print(f"stability (predecessor overlap): p50 {np.median(stab):.4f}")
    print(f"ingestion: {len(log)/wall:.0f} events/s "
          f"({eng.n_epochs} epochs, {eng.n_rounds} message waves, "
          f"{eng.n_adds} adds, {eng.n_dels} dels)")


if __name__ == "__main__":
    main()
