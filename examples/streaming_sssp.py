"""Streaming SSSP over a sliding-window event stream (the paper's §5 setup).

Run: PYTHONPATH=src python examples/streaming_sssp.py [--delta 0.3]

Generates an RMAT graph, replays it as a timestamped stream with windowed
deletions (probability --delta), queries every W/10 events, and reports the
paper's three metrics: query latency, tree stability, ingestion rate —
plus a from-scratch ReMo baseline for the latency comparison.

Engines are built through the one public entry point ``repro.make_engine``
(DESIGN.md §11.5).  Real datasets (SNAP/Konect edge lists, .gz ok) stream
through the same pipeline — the loader synthesizes the sliding-window
dynamic portion deterministically and a bad path exits with code 2:

    ... streaming_sssp.py --dataset /path/to/edges.txt

Serving-layer trace flags (DESIGN.md §8):

    # save the generated workload as an on-disk trace
    ... streaming_sssp.py --record-trace /tmp/stream.trace
    # replay a recorded trace through the engine + metrics harness
    # (a missing/incompatible trace path exits with code 2)
    ... streaming_sssp.py --replay-trace /tmp/stream.trace

Observability flags (DESIGN.md §10) — any one enables the engine's span
tracer / counter registry / histograms / flight recorder:

    # Chrome trace-event JSON of every epoch/drain/query span (Perfetto)
    ... streaming_sssp.py --trace-out /tmp/stream.trace.json
    # JSONL spans + a final metrics_snapshot line
    ... streaming_sssp.py --log-json /tmp/stream.jsonl
    # Prometheus exposition text (counters, attribution labels,
    # histogram buckets — §10.7)
    ... streaming_sssp.py --metrics-out /tmp/stream.prom

(a nonexistent parent directory for any path exits with code 2)
"""
import argparse
import time

import numpy as np

import repro
from repro.core import events as ev
from repro.core.baseline import ReMoBaseline
from repro.graphs import generators as gen
from repro.graphs import window as win
from repro.obs import out_path_or_exit, write_log_jsonl
from repro.serving import (ServingTrace, TraceRecorder, load_trace_or_exit,
                           replay_trace)


def add_obs_flags(p: argparse.ArgumentParser) -> None:
    """The shared --trace-out/--log-json/--metrics-out flags (both
    examples)."""
    p.add_argument("--trace-out", metavar="PATH",
                   help="write the engine span trace as Chrome trace-event "
                        "JSON (loads in Perfetto; a missing parent "
                        "directory exits 2)")
    p.add_argument("--log-json", metavar="PATH",
                   help="write spans + the final metrics_snapshot as JSONL "
                        "(a missing parent directory exits 2)")
    p.add_argument("--metrics-out", metavar="PATH",
                   help="write the final metrics_snapshot as Prometheus "
                        "exposition text — counters, per-partition/lane "
                        "attribution labels, native histogram buckets "
                        "(a missing parent directory exits 2)")


def obs_paths(args) -> tuple:
    """Every observability destination an example must validate up front."""
    return (args.trace_out, args.log_json, args.metrics_out)


def dump_obs(eng, args) -> None:
    """Write the requested observability artifacts for a finished engine."""
    if args.trace_out:
        eng.obs.tracer.save_chrome(args.trace_out)
        n_ev = sum(eng.obs.tracer.span_counts().values())
        print(f"wrote chrome trace: {args.trace_out} ({n_ev} events)")
    if args.log_json:
        write_log_jsonl(eng, args.log_json)
        print(f"wrote span/metrics JSONL: {args.log_json}")
    if args.metrics_out:
        from repro.obs.export import write_prometheus
        write_prometheus(args.metrics_out, eng.metrics_snapshot())
        print(f"wrote prometheus metrics: {args.metrics_out}")


def trace_bounds(trace: ServingTrace) -> tuple[int, int]:
    """(num_vertices, topology_events) implied by a trace."""
    topo = trace.kind != ev.QUERY
    n = int(max(trace.src[topo].max(initial=0),
                trace.dst[topo].max(initial=0))) + 1
    return n, int(topo.sum())


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--scale", type=int, default=11)
    p.add_argument("--delta", type=float, default=0.3)
    p.add_argument("--window-frac", type=float, default=0.3)
    p.add_argument("--backend", choices=("segment", "ellpack", "sliced"),
                   default="segment",
                   help="relaxation backend (DESIGN.md §2/§6; ellpack is "
                        "the bounded-degree fast path, sliced the hub-aware "
                        "hybrid for power-law in-degrees)")
    p.add_argument("--power-law", action="store_true",
                   help="stream in-degree power-law hubs instead of RMAT "
                        "(the sliced backend's target workload)")
    p.add_argument("--dataset", metavar="PATH",
                   help="replay a real SNAP/Konect edge list (graphs/"
                        "datasets.py): deterministic sliding-window event "
                        "synthesis + serving metrics (bad paths exit 2)")
    p.add_argument("--record-trace", metavar="PATH",
                   help="save the generated workload as a serving trace "
                        "(repro/serving/trace.py, DESIGN.md §8.2)")
    p.add_argument("--replay-trace", metavar="PATH",
                   help="replay a recorded trace through the engine and "
                        "report the serving metrics (unknown paths exit 2)")
    add_obs_flags(p)
    args = p.parse_args()
    # fail fast on unwritable observability destinations (exit 2)
    for path in obs_paths(args):
        if path:
            out_path_or_exit(path)
    obs_on = any(obs_paths(args))

    if args.dataset:
        n, trace = repro.load_dataset_or_exit(
            args.dataset, window_frac=args.window_frac, delta=args.delta)
        log = ev.interleave_queries(trace.to_log(),
                                    max(1, trace.n_topology // 10))
        trace = ServingTrace.from_log(log)

    if args.replay_trace or args.dataset:
        if args.replay_trace:
            trace = load_trace_or_exit(args.replay_trace)
            n, _ = trace_bounds(trace)
        cap = int(trace.n_topology * 1.3) + 64
        source = int(gen.top_in_degree_sources(
            n, trace.dst[trace.kind == ev.ADD].astype(np.int64))[0])
        eng = repro.make_engine(num_vertices=n, edge_capacity=cap,
                                source=source, relax_backend=args.backend,
                                observability=obs_on)
        report = replay_trace(eng, trace)
        print(f"trace: {args.replay_trace or args.dataset} source={source}")
        print(report.summary())
        dump_obs(eng, args)
        return

    if args.power_law:
        n = 1 << args.scale
        n, src, dst, w = gen.power_law_hubs(n, 10 * n, n_hubs=4, seed=7,
                                            orientation="in")
    else:
        n, src, dst, w = gen.rmat(args.scale, edge_factor=8, seed=7)
    source = int(gen.top_in_degree_sources(n, dst)[0])
    window = int(len(src) * args.window_frac)
    log = win.sliding_window_stream(src, dst, w, window=window,
                                    delta=args.delta, seed=0)
    log = ev.interleave_queries(log, window // 10)
    print(f"graph: n={n} stream={len(log)} events "
          f"(delta={args.delta}, window={window}) source={source}")

    if args.record_trace:
        rec = TraceRecorder()
        rec.extend_from_log(log)
        # version-2 chunked container: replayable at O(chunk) host memory
        rec.trace().save(args.record_trace, chunk_events=65536)
        print(f"recorded trace: {args.record_trace} ({len(log)} events)")

    cap = int(len(src) * 1.3) + 64
    eng = repro.make_engine(num_vertices=n, edge_capacity=cap,
                            source=source, relax_backend=args.backend,
                            observability=obs_on)
    lat, stab = [], []
    t0 = time.perf_counter()

    def on_query(r):
        lat.append(r.latency_s)
        stab.append(eng.stability_vs_prev(r.parent, source=r.source))

    eng.ingest_log(log, on_query=on_query)
    wall = time.perf_counter() - t0

    base = ReMoBaseline(n, cap, source)
    base_lat = [r.latency_s for r in base.ingest_log(log)]

    print(f"queries: {len(lat)}")
    print(f"latency p50: ours {np.median(lat)*1e3:.3f}ms | "
          f"ReMo-from-scratch {np.median(base_lat)*1e3:.3f}ms | "
          f"speedup {np.median(base_lat)/max(np.median(lat),1e-9):.1f}x")
    print(f"stability (predecessor overlap): p50 {np.median(stab):.4f}")
    print(f"ingestion: {len(log)/wall:.0f} events/s "
          f"({eng.n_epochs} epochs, {eng.n_rounds} message waves, "
          f"{eng.n_adds} adds, {eng.n_dels} dels)")
    dump_obs(eng, args)


if __name__ == "__main__":
    main()
