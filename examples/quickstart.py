"""Quickstart: the SSSP-Del engine on a small dynamic graph.

Run: PYTHONPATH=src python examples/quickstart.py

Builds a graph edge by edge, deletes a tree edge (triggering the paper's
invalidation + recomputation epochs), queries the shortest-path tree on
demand, and cross-checks every answer against a textbook Dijkstra oracle.

``repro.make_engine`` is the one public entry point for both engines
(DESIGN.md §11.5): the same call with ``partitions=P`` (or ``mesh=``)
returns the sharded engine instead — ``edge_capacity`` is always the
total pool budget.
"""
import numpy as np

import repro
from repro.core import events as ev
from repro.core import oracle


def main():
    #          1.0      1.0
    #   0 ────────► 1 ────────► 2
    #   │                       ▲
    #   └────────── 5.0 ────────┘         (plus a later shortcut 0->3->2)
    eng = repro.make_engine(num_vertices=8, edge_capacity=64, source=0)
    log = ev.EventLog.concatenate([
        ev.adds([0, 1, 0], [1, 2, 2], [1.0, 1.0, 5.0]),
        ev.query_marker(),                 # tree: 0->1->2 (dist 2)
        ev.dels([1], [2]),                 # delete the tree edge 1->2
        ev.query_marker(),                 # 2 must fall back to dist 5
        ev.adds([0, 3], [3, 2], [1.0, 1.0]),
        ev.query_marker(),                 # new shortcut: 0->3->2 (dist 2)
    ])
    results = eng.ingest_log(log)
    for i, r in enumerate(results):
        print(f"query {i}: dist={np.round(r.dist[:4], 1)} "
              f"parent={r.parent[:4]} latency={r.latency_s*1e3:.2f}ms")

    # oracle check on the final state
    e = eng.state.edges
    act = np.asarray(e.active)
    dist_ref, _ = oracle.dijkstra(8, np.asarray(e.src)[act],
                                  np.asarray(e.dst)[act],
                                  np.asarray(e.w)[act], 0)
    assert np.allclose(np.nan_to_num(results[-1].dist, posinf=-1),
                       np.nan_to_num(dist_ref, posinf=-1))
    print("oracle check: OK")

    assert results[0].dist[2] == 2.0   # via 0->1->2
    assert results[1].dist[2] == 5.0   # direct 0->2 after deletion
    assert results[2].dist[2] == 2.0   # via the new 0->3->2
    print("dynamic deletions + re-additions: OK")


if __name__ == "__main__":
    main()
