"""DIN recsys serving demo: train briefly, then serve batched requests and
run candidate retrieval (the serve_p99 / retrieval_cand shapes, reduced).

Run: PYTHONPATH=src python examples/serve_din.py
"""
import time
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import din as din_cfg
from repro.models import din as din_mod
from repro.train import data as data_mod
from repro.train import optimizer as opt_mod
from repro.train import steps as steps_mod


def main():
    cfg = din_cfg.REDUCED
    stream = data_mod.ClickStream(n_items=cfg.n_items, n_cates=cfg.n_cates,
                                  batch=256, seq_len=cfg.seq_len, seed=0)
    params = din_mod.init_din(jax.random.key(0), cfg)
    step = jax.jit(steps_mod.make_train_step(
        partial(_loss, cfg=cfg), opt_mod.AdamWConfig(lr=3e-3, warmup_steps=10,
                                                     total_steps=400), 1))
    opt_state = opt_mod.adamw_init(params)
    print("training DIN on the synthetic click stream ...")
    acc = None
    for i in range(400):
        batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
        params, opt_state, m = step(params, opt_state, batch)
        acc = float(m["acc"])
        if (i + 1) % 50 == 0:
            print(f"  step {i+1}: loss {float(m['loss']):.4f} acc {acc:.3f}")
    assert acc > 0.55, "DIN failed to learn the planted preference structure"

    # --- batched online scoring (serve_p99 shape, reduced)
    score = jax.jit(partial(din_mod.din_score, cfg=cfg))
    batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()
             if k != "labels"}
    score(params, batch)  # warmup/compile
    lats = []
    for _ in range(20):
        t0 = time.perf_counter()
        jax.block_until_ready(score(params, batch))
        lats.append(time.perf_counter() - t0)
    print(f"serve: batch=256 p50 {np.median(lats)*1e3:.2f}ms "
          f"p99 {np.percentile(lats, 99)*1e3:.2f}ms")

    # --- retrieval: one user vs many candidates, single fused einsum chain
    rng = np.random.default_rng(0)
    n_cand = 50_000
    rbatch = {
        "hist_items": jnp.asarray(rng.integers(0, cfg.n_items, cfg.seq_len),
                                  jnp.int32),
        "hist_cates": jnp.asarray(rng.integers(0, cfg.n_cates, cfg.seq_len),
                                  jnp.int32),
        "hist_mask": jnp.ones((cfg.seq_len,), jnp.bool_),
        "cand_items": jnp.asarray(rng.integers(0, cfg.n_items, n_cand),
                                  jnp.int32),
        "cand_cates": jnp.asarray(rng.integers(0, cfg.n_cates, n_cand),
                                  jnp.int32),
    }
    retr = jax.jit(partial(din_mod.din_retrieval, cfg=cfg))
    scores = jax.block_until_ready(retr(params, rbatch))
    t0 = time.perf_counter()
    scores = jax.block_until_ready(retr(params, rbatch))
    dt = time.perf_counter() - t0
    top = np.argsort(np.asarray(scores))[-5:][::-1]
    print(f"retrieval: {n_cand} candidates in {dt*1e3:.1f}ms "
          f"({n_cand/dt/1e6:.2f}M cand/s); top-5 ids {top.tolist()}")


def _loss(params, batch, cfg):
    return din_mod.din_loss(params, batch, cfg)


if __name__ == "__main__":
    main()
