"""Frontier-compacted sparse epochs (DESIGN.md §12): the compaction
primitive's properties (round-trip, exact count, -1 padding, cap
truncation), the gathered-rows kernel's bit-parity with its jnp reference,
and the engine-level contract — ``frontier_mode="sparse"/"auto"`` must be
bit-identical in (dist, parent) AND equal in (rounds, messages) to the
dense path on any dynamic stream, at any ladder capacity (a tiny
``frontier_cap`` forces the in-``cond`` dense fallback every wave, so the
fallback branch is exercised under the same assertion).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import frontier as frontier_mod
from repro.core.engine import EngineConfig, SSSPDelEngine
from repro.graphs import generators, window
from repro.kernels.relax.gather import (gathered_rows_relax,
                                        gathered_rows_relax_ref)


# ----------------------------------------------------- compaction primitive
@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("n,cap", [(64, 64), (257, 32), (1000, 256)])
def test_compact_mask_roundtrip(seed, n, cap):
    rng = np.random.default_rng(seed)
    mask = rng.random(n) < rng.uniform(0.0, 0.5)
    wl, count = frontier_mod.compact_mask(jnp.asarray(mask), cap=cap)
    wl = np.asarray(wl)
    assert int(count) == int(mask.sum())          # exact occupancy, always
    assert wl.shape == (cap,)
    k = min(int(mask.sum()), cap)
    # kept slots are the first k set vertices in ascending order ...
    np.testing.assert_array_equal(wl[:k], np.flatnonzero(mask)[:k])
    # ... and everything past them is -1 padding
    assert (wl[k:] == -1).all()
    if int(mask.sum()) <= cap:
        back = np.asarray(frontier_mod.worklist_to_mask(jnp.asarray(wl), n))
        np.testing.assert_array_equal(back, mask)  # lossless round-trip


def test_compact_mask_overflow_truncates_and_reports():
    n = 100
    mask = jnp.ones((n,), jnp.bool_)
    wl, count = frontier_mod.compact_mask(mask, cap=16)
    assert int(count) == n      # the ladder's dense-fallback signal
    np.testing.assert_array_equal(np.asarray(wl), np.arange(16))


def test_capacity_ladder_shape():
    for n in (10, 300, 1 << 20):
        caps = frontier_mod.capacity_ladder(n)
        assert caps == tuple(sorted(caps)) and caps[0] >= 1
    assert frontier_mod.capacity_ladder(1 << 20, cap=512) == (256, 512)
    # explicit cap is pow2-rounded and clamped to next_pow2(n)
    assert frontier_mod.capacity_ladder(100, cap=4096)[-1] == 128


# ------------------------------------------------------ gathered-rows kernel
@pytest.mark.parametrize("seed", [0, 3])
def test_gather_kernel_matches_reference(seed):
    rng = np.random.default_rng(seed)
    m, n = 85, 40                   # 1-D compacted edge list
    src = rng.integers(0, n, m).astype(np.int32)
    wd = np.where(rng.random(m) < 0.9,
                  rng.uniform(0, 3, m), np.inf).astype(np.float32)
    nbr = rng.integers(0, n, m).astype(np.int32)
    w = rng.uniform(0.1, 1.0, m).astype(np.float32)
    mask = rng.random(m) < 0.7
    args = (jnp.asarray(wd), jnp.asarray(src), jnp.asarray(nbr),
            jnp.asarray(w), jnp.asarray(mask))
    b_ref, a_ref = gathered_rows_relax_ref(*args, num_rows=n)
    b_krn, a_krn = gathered_rows_relax(*args, num_rows=n, interpret=True)
    np.testing.assert_array_equal(np.asarray(b_ref), np.asarray(b_krn))
    np.testing.assert_array_equal(np.asarray(a_ref), np.asarray(a_krn))


# ----------------------------------------------------- engine-level parity
def _stream(seed, *, n=90, m=520, delta=0.6):
    n, src, dst, w = generators.erdos_renyi(n, m, seed=seed)
    log = window.sliding_window_stream(src, dst, w, window=m // 3,
                                       delta=delta, seed=seed,
                                       query_every=m // 2)
    return n, len(src), log


def _run(n, cap, log, source, **kw):
    eng = SSSPDelEngine(EngineConfig(n, cap + 64, source, **kw))
    eng.ingest_log(log)
    return eng


def _assert_same(ref, eng):
    qr, qe = ref.query(), eng.query()
    np.testing.assert_array_equal(np.asarray(qr.dist), np.asarray(qe.dist))
    np.testing.assert_array_equal(np.asarray(qr.parent),
                                  np.asarray(qe.parent))
    np.testing.assert_array_equal(np.asarray(ref.n_rounds),
                                  np.asarray(eng.n_rounds))
    np.testing.assert_array_equal(np.asarray(ref.n_messages),
                                  np.asarray(eng.n_messages))


@pytest.mark.parametrize("mode", ["sparse", "auto"])
@pytest.mark.parametrize("schedule", ["rounds", "buckets"])
def test_sparse_engine_bit_identical(mode, schedule):
    n, m, log = _stream(seed=31)
    ref = _run(n, m, log, 3, wave_schedule=schedule)
    eng = _run(n, m, log, 3, wave_schedule=schedule, frontier_mode=mode)
    _assert_same(ref, eng)


def test_sparse_tiny_cap_forces_dense_fallback():
    """frontier_cap small enough that real cascades overflow every rung:
    the ladder's final (dense relax_round) branch must carry the epoch and
    stay bit-identical."""
    n, m, log = _stream(seed=32)
    ref = _run(n, m, log, 3)
    eng = _run(n, m, log, 3, frontier_mode="sparse", frontier_cap=8)
    _assert_same(ref, eng)


def test_sparse_pallas_kernel_path():
    n, m, log = _stream(seed=33)
    ref = _run(n, m, log, 3)
    eng = _run(n, m, log, 3, frontier_mode="sparse", frontier_kernel=True)
    _assert_same(ref, eng)


def test_sparse_batched_sources():
    n, m, log = _stream(seed=34)
    srcs = (3, 17, 40)
    ref = _run(n, m, log, 0, sources=srcs)
    eng = _run(n, m, log, 0, sources=srcs, frontier_mode="sparse",
               frontier_cap=16)
    _assert_same(ref, eng)


def test_frontier_occupancy_counter_surfaces():
    n, m, log = _stream(seed=35)
    eng = _run(n, m, log, 3, frontier_mode="sparse", observability=True)
    occ = eng.metrics_snapshot()["counters"].get("frontier_occupancy", 0)
    assert occ > 0   # sparse epochs fold per-wave active counts (§2.4)
    dense = _run(n, m, log, 3, observability=True)
    assert "frontier_occupancy" not in dense.metrics_snapshot()["counters"]


def test_frontier_knob_discipline():
    with pytest.raises(ValueError, match="frontier_mode"):
        EngineConfig(10, 16, 0, frontier_mode="bogus")
    with pytest.raises(ValueError, match="frontier_cap"):
        EngineConfig(10, 16, 0, frontier_cap=64)   # knob without the mode
