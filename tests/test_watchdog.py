"""Stall / divergence watchdog (DESIGN.md §10.8).

Contracts under test:

  * synchronous checks — a finished epoch slower than
    ``max_epoch_wall_s`` or an ADD frontier above ``max_frontier`` emits
    one structured warning (FlightRecorder record + counter + stderr);
  * stall sampling — an armed region older than ``stall_timeout_s``
    fires ONCE from the sampler thread, bumps ``watchdog_stalls`` and
    triggers the one-shot flight-recorder dump while the "engine thread"
    is still blocked;
  * divergence review — a waves-per-epoch histogram whose top occupied
    bucket reaches ``max_drain_waves`` is flagged at most once;
  * a default-config watchdog stays silent on a healthy engine run (the
    property the gated obs_overhead benches rely on).
"""
import time

import numpy as np
import pytest

from repro.core.engine import EngineConfig, SSSPDelEngine
from repro.graphs import generators, window
from repro.obs import EngineObs, WatchdogConfig
from repro.obs import hist
from repro.obs.watchdog import Watchdog


def _obs(cfg: WatchdogConfig) -> EngineObs:
    return EngineObs(enabled=True, watchdog=cfg)


# -------------------------------------------------------- synchronous checks
def test_slow_epoch_warns_once_per_offender(capsys):
    obs = _obs(WatchdogConfig(stall_timeout_s=0.0, max_epoch_wall_s=1e-9))
    with obs.epoch("add_epoch"):
        pass
    err = capsys.readouterr().err
    assert "slow_epoch" in err
    assert obs.watchdog.warnings == 1
    snap = obs.counters.snapshot()
    assert snap["watchdog_warnings"] == 1
    assert "watchdog_stalls" not in snap
    kinds = [r["kind"] for r in obs.recorder.records()]
    assert "watchdog" in kinds


def test_frontier_blowup_threshold():
    obs = _obs(WatchdogConfig(stall_timeout_s=0.0, max_frontier=10))
    obs.watchdog.observe("add_epoch", 0.0, {"frontier": 5})
    assert obs.watchdog.warnings == 0
    obs.watchdog.observe("add_epoch", 0.0, {"frontier": 11})
    assert obs.watchdog.warnings == 1


# ----------------------------------------------------------------- stalls --
def test_stall_fires_once_and_dumps_recorder(capsys):
    obs = _obs(WatchdogConfig(stall_timeout_s=0.05, poll_interval_s=0.01))
    wd = obs.watchdog
    obs.recorder.record("add_epoch", wall_ms=1.0)
    wd.arm("add_epoch")
    try:
        deadline = time.perf_counter() + 5.0
        while wd.warnings == 0 and time.perf_counter() < deadline:
            time.sleep(0.01)
        # hold the region armed past several polls: one firing only
        time.sleep(0.1)
    finally:
        wd.disarm()
        wd.stop()
    assert wd.warnings == 1
    assert obs._dumped
    snap = obs.counters.snapshot()
    assert snap["watchdog_stalls"] == 1
    err = capsys.readouterr().err
    assert "stall" in err and "flight recorder postmortem" in err
    assert "add_epoch" in err


def test_stall_in_engine_epoch_region(capsys):
    """End-to-end through a real engine: a patched backend sleep inside
    the dispatched epoch trips the sampler while the engine thread is
    still inside ``obs.epoch``."""
    n, src, dst, w = generators.erdos_renyi(48, 160, seed=5)
    eng = SSSPDelEngine(EngineConfig(
        n, len(src) + 32, 0, observability=True,
        obs_watchdog=WatchdogConfig(stall_timeout_s=0.05,
                                    poll_interval_s=0.01)))
    stage = eng.backend.apply_adds

    def slow_stage(*a, **kw):
        time.sleep(0.3)
        return stage(*a, **kw)

    eng.backend.apply_adds = slow_stage
    log = window.sliding_window_stream(src, dst, w, window=80, delta=0.5,
                                       seed=5)
    batch = next(iter(log.runs()))
    eng._ingest_adds(batch)
    eng.obs.watchdog.stop()
    snap = eng.metrics_snapshot()
    assert snap["counters"]["watchdog_stalls"] >= 1
    err = capsys.readouterr().err
    assert "stall" in err and "flight recorder postmortem" in err
    # the stall dump did NOT break the run: the epoch completed
    assert snap["counters"]["add_epochs"] == 1


def test_no_stall_when_epochs_are_fast():
    obs = _obs(WatchdogConfig(stall_timeout_s=0.2, poll_interval_s=0.01))
    for _ in range(20):
        with obs.epoch("add_epoch"):
            pass
    time.sleep(0.1)
    obs.watchdog.stop()
    assert obs.watchdog.warnings == 0
    assert "watchdog_warnings" not in obs.counters.snapshot()


# ------------------------------------------------------- divergence review --
def test_review_flags_wave_divergence_once(capsys):
    obs = _obs(WatchdogConfig(stall_timeout_s=0.0, max_drain_waves=64))
    counts = hist.zeros_np()
    counts[3] = 5                                  # top bucket lo = 4 < 64
    obs.watchdog.review({"hist_waves_per_epoch": counts})
    assert obs.watchdog.warnings == 0
    counts[8] = 1                                  # top bucket lo = 128 >= 64
    obs.watchdog.review({"hist_waves_per_epoch": counts})
    assert obs.watchdog.warnings == 1
    obs.watchdog.review({"hist_waves_per_epoch": counts})  # once only
    assert obs.watchdog.warnings == 1
    assert "wave_divergence" in capsys.readouterr().err


def test_review_ignores_missing_or_empty_histogram():
    obs = _obs(WatchdogConfig(stall_timeout_s=0.0, max_drain_waves=4))
    obs.watchdog.review({})
    obs.watchdog.review({"hist_waves_per_epoch": hist.zeros_np()})
    assert obs.watchdog.warnings == 0


# ------------------------------------------------------------ healthy runs --
def test_default_config_watchdog_is_silent_on_healthy_run(capsys):
    n, src, dst, w = generators.erdos_renyi(64, 256, seed=7)
    log = window.sliding_window_stream(src, dst, w, window=128, delta=0.5,
                                       seed=7, query_every=128)
    eng = SSSPDelEngine(EngineConfig(
        n, len(src) + 64, 0, observability=True,
        obs_watchdog=WatchdogConfig()))
    eng.ingest_log(log)
    eng.query()
    snap = eng.metrics_snapshot()
    assert "watchdog_warnings" not in snap["counters"]
    assert eng.obs.watchdog.warnings == 0
    assert "[repro.obs.watchdog]" not in capsys.readouterr().err


def test_watchdog_absent_unless_configured():
    n, src, dst, w = generators.erdos_renyi(48, 128, seed=3)
    eng = SSSPDelEngine(EngineConfig(n, len(src) + 32, 0,
                                     observability=True))
    assert eng.obs.watchdog is None
    off = SSSPDelEngine(EngineConfig(n, len(src) + 32, 0,
                                     obs_watchdog=WatchdogConfig()))
    assert off.obs.watchdog is None      # obs disabled wins


def test_stop_is_idempotent_and_joins_thread():
    obs = _obs(WatchdogConfig(stall_timeout_s=0.05, poll_interval_s=0.01))
    wd = obs.watchdog
    wd.arm("add_epoch")
    wd.disarm()
    assert wd._thread is not None
    wd.stop()
    assert wd._thread is None
    wd.stop()
