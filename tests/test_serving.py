"""Serving layer (DESIGN.md §8): batched multi-source engines, workload
traces, and the latency/stability/throughput metrics harness.

The load-bearing contract is the batched-state equivalence: an engine
constructed with ``sources=(s0, ..., sK)`` must be bit-identical PER LANE —
dist, parent, AND the per-source round/message stats — to K+1 independent
single-source engines on any mixed ADD/DEL/QUERY stream, for every
registered backend on both engines (single-device vmapped epochs, sharded
``*_ms`` leading-dimension epochs at whatever P this process provides), and
the batched ingest path must preserve the no-host-sync rules (§2.4).

The trace tests pin the on-disk format round-trip and the replayer's
determinism: record -> save -> load -> replay twice == identical results.
"""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax

from repro.core import events as ev
from repro.core.dist_engine import ShardedEngineConfig, ShardedSSSPDelEngine
from repro.core.engine import EngineConfig, SSSPDelEngine
from repro.graphs import generators, window
from repro.serving import (ServingTrace, TraceFormatError, TraceRecorder,
                           churn, pctile, replay_trace)

SOURCES = (3, 17, 40)
# tiny layout knobs so rebuild/spill paths run under batched ingest too
BACKEND_KW = {
    "segment": {},
    "ellpack": dict(ell_init_k=2),
    "sliced": dict(sliced_slice_rows=32, sliced_hub_k=4, sliced_init_k=1),
}


def _dynamic_stream(seed: int, *, n=72, m=320, delta=0.5):
    """Smaller than test_backend_equiv's stream on purpose: single-source
    equivalence at full scale is that suite's job; here every run costs
    S trees (and the whole file re-runs on the CI 8-device leg), and this
    scale still triggers the ELL rebuild and sliced spill paths under the
    tiny BACKEND_KW layout knobs (asserted below)."""
    n, src, dst, w = generators.erdos_renyi(n, m, seed=seed)
    log = window.sliding_window_stream(src, dst, w, window=m // 3,
                                       delta=delta, seed=seed,
                                       query_every=m // 2)
    return n, len(src), log


def _mk(engine: str, backend: str, n: int, cap: int, source: int,
        sources=None, **kw):
    if engine == "single":
        return SSSPDelEngine(EngineConfig(
            n, cap, source, relax_backend=backend, sources=sources, **kw))
    return ShardedSSSPDelEngine(ShardedEngineConfig(
        n, cap, source, relax_backend=backend, sources=sources, **kw))


# single-source reference runs are identical across the engine
# parametrization (and across backends, but asserting that is
# test_backend_equiv's job) — compute each once per session
_REF_CACHE: dict = {}


def _ref_result(backend: str, n: int, cap: int, log, source: int):
    key = (backend, source)
    if key not in _REF_CACHE:
        ref = SSSPDelEngine(EngineConfig(
            n, cap, source, relax_backend=backend, **BACKEND_KW[backend]))
        ref.ingest_log(log)
        q = ref.query()
        _REF_CACHE[key] = (q.dist, q.parent, ref.n_rounds, ref.n_messages)
    return _REF_CACHE[key]


# --------------------------------------------------- multi-source parity --
@pytest.mark.parametrize("engine", ["single", "sharded"])
@pytest.mark.parametrize("backend", ["segment", "ellpack", "sliced"])
def test_batched_multi_source_parity(engine, backend):
    """Batched S-source engine == S single-source engines (dist, parent,
    per-lane stats) on a mixed dynamic stream, and routed lane queries
    return exactly that lane's snapshot."""
    n, m, log = _dynamic_stream(seed=11)
    kw = BACKEND_KW[backend]
    bat = _mk(engine, backend, n, m + 64, SOURCES[0], sources=SOURCES, **kw)
    bat.ingest_log(log)
    qb = bat.query()
    assert qb.dist.shape == (len(SOURCES), n)
    for i, s in enumerate(SOURCES):
        r_dist, r_parent, r_rounds, r_msgs = _ref_result(
            backend, n, m + 64, log, s)
        np.testing.assert_array_equal(qb.dist[i], r_dist)
        np.testing.assert_array_equal(qb.parent[i], r_parent)
        assert int(bat.n_rounds[i]) == r_rounds
        assert int(bat.n_messages[i]) == r_msgs
        ql = bat.query(source=s)
        assert ql.source == s and ql.dist.shape == (n,)
        np.testing.assert_array_equal(ql.dist, r_dist)
        np.testing.assert_array_equal(ql.parent, r_parent)
    if engine == "single" and backend == "ellpack":
        assert bat.backend.planner.rebuilds >= 1, \
            "batched ingest must exercise the rebuild path"
    if engine == "single" and backend == "sliced":
        assert bat.backend.planner.spills >= 1, \
            "batched ingest must exercise the hub-spill path"


@pytest.mark.parametrize("engine", ["single", "sharded"])
def test_batched_delta_exchange_and_batched_deletions(engine):
    """The batched epochs compose with the other engine switches: delta
    exchange (sharded only) and coalesced deletion batches."""
    n, m, log = _dynamic_stream(seed=23)
    kw = dict(batch_deletions=True)
    if engine == "sharded":
        kw["exchange"] = "delta"
        kw["delta_cap"] = 32   # force overflow-fallback rounds too
    bat = _mk(engine, "segment", n, m + 64, SOURCES[0],
              sources=SOURCES, **kw)
    bat.ingest_log(log)
    qb = bat.query()
    for i, s in enumerate(SOURCES):
        ref = SSSPDelEngine(EngineConfig(n, m + 64, s,
                                         batch_deletions=True))
        ref.ingest_log(log)
        qr = ref.query()
        np.testing.assert_array_equal(qb.dist[i], qr.dist)
        np.testing.assert_array_equal(qb.parent[i], qr.parent)


def test_batched_query_routing_and_validation():
    n, m, log = _dynamic_stream(seed=7)
    bat = SSSPDelEngine(EngineConfig(n, m + 64, 3, sources=SOURCES))
    bat.ingest_log(log)
    with pytest.raises(ValueError, match="not served"):
        bat.query(source=99)
    single = SSSPDelEngine(EngineConfig(n, m + 64, 3))
    single.ingest_log(log)
    assert single.serves(3) and not single.serves(4)
    with pytest.raises(ValueError, match="not served"):
        single.query(source=4)
    # query markers carrying a served source route to its lane
    res = bat.ingest_log(ev.query_marker(source=SOURCES[1]))
    assert res[0].source == SOURCES[1]
    assert res[0].dist.shape == (n,)
    # unserved/-1 markers read the full stack
    res = bat.ingest_log(ev.query_marker())
    assert res[0].source is None and res[0].dist.shape == (len(SOURCES), n)
    with pytest.raises(ValueError, match="duplicate"):
        SSSPDelEngine(EngineConfig(n, m + 64, 3, sources=(3, 3)))
    with pytest.raises(ValueError, match="sources"):
        EngineConfig(n, m + 64, 3, sources=(n + 5,))
    with pytest.raises(ValueError, match="sources"):
        ShardedEngineConfig(n, m + 64, 3, sources=())


@pytest.mark.parametrize("engine", ["single", "sharded"])
def test_batched_ingest_never_reads_device_values(engine, monkeypatch):
    """DESIGN.md §2.4 holds for batched multi-source ingest: no
    device->host readback between QUERY markers on either engine."""
    n, m, log = _dynamic_stream(seed=13)
    eng = _mk(engine, "ellpack", n, m + 64, SOURCES[0], sources=SOURCES,
              **BACKEND_KW["ellpack"])
    topo = log[np.asarray(log.kind) != ev.QUERY]

    def trap(*a, **k):
        raise AssertionError("device_get during batched ingest (host sync)")

    monkeypatch.setattr(jax, "device_get", trap)
    eng.ingest_log(topo)
    monkeypatch.undo()
    q = eng.query()
    assert q.dist.shape == (len(SOURCES), n)


def test_query_latency_timed_by_stream_base():
    """QueryResult.latency_s is populated by StreamEngineBase.query() for
    both engines (satellite: the shared timing seam)."""
    n, m, log = _dynamic_stream(seed=5)
    for engine in ("single", "sharded"):
        eng = _mk(engine, "segment", n, m + 64, 3)
        results = eng.ingest_log(log)
        assert results, "stream should contain query markers"
        assert all(r.latency_s > 0 for r in results)
        assert all(r.source is None for r in results)


def test_stability_scoped_per_source():
    """Routed lane snapshots from DIFFERENT sources must never be compared
    against each other: alternating per-source queries with no topology
    changes in between must all score stability 1.0."""
    n, m, log = _dynamic_stream(seed=31)
    eng = SSSPDelEngine(EngineConfig(n, m + 64, 3, sources=SOURCES))
    eng.ingest_log(log[np.asarray(log.kind) != ev.QUERY])
    scores = []
    for _round in range(2):
        for s in SOURCES:
            r = eng.query(source=s)
            scores.append(eng.stability_vs_prev(r.parent, source=r.source))
    assert scores == [1.0] * len(scores), scores


# ------------------------------------------------------------ trace tests --
def _multi_source_trace(log, sources, n_points=5):
    rec = TraceRecorder()
    step = max(1, len(log) // n_points)
    for a in range(0, len(log), step):
        rec.extend_from_log(log[a:a + step])
        for s in sources:
            rec.query(source=s)
    return rec.trace()


def test_trace_record_replay_roundtrip_determinism(tmp_path):
    """record -> save -> load preserves every column; two replays of the
    loaded trace on fresh engines are bit-identical; the report carries the
    three serving metrics."""
    n, m, log = _dynamic_stream(seed=19)
    trace = _multi_source_trace(log, SOURCES)
    path = str(tmp_path / "stream.trace")
    trace.save(path)
    loaded = ServingTrace.load(path)
    for col in ("kind", "src", "dst", "w", "t"):
        np.testing.assert_array_equal(getattr(trace, col),
                                      getattr(loaded, col))
    assert loaded.n_queries == trace.n_queries
    # the recorded per-source queries survive alongside the stream's own
    # untargeted (-1) markers
    qsrc = set(loaded.query_sources().tolist())
    assert set(SOURCES) <= qsrc <= set(SOURCES) | {-1}
    assert np.all(np.diff(loaded.t) >= 0), "timestamps must be monotone"

    def run():
        eng = SSSPDelEngine(EngineConfig(n, m + 64, SOURCES[0],
                                         sources=SOURCES))
        rep = replay_trace(eng, loaded)
        return eng.query(), rep

    q1, rep1 = run()
    q2, rep2 = run()
    np.testing.assert_array_equal(q1.dist, q2.dist)
    np.testing.assert_array_equal(q1.parent, q2.parent)
    assert rep1.queries == rep2.queries == loaded.n_queries
    assert rep1.topology_events == loaded.n_topology
    for key in ("p50", "p95", "p99"):
        assert rep1.latency_s[key] > 0
    assert 0.0 <= rep1.churn_mean["any"] <= 1.0
    assert rep1.churn_mean == rep2.churn_mean, "churn must be deterministic"
    assert rep1.events_per_s > 0
    rec = rep1.to_record()
    for key in ("events_per_s", "latency_p50_ms", "latency_p95_ms",
                "latency_p99_ms", "churn_mean", "stability_parent"):
        assert key in rec


def test_report_per_source_latency_and_cold_warm_split():
    """§10.6 serving attribution: the report breaks latency down per query
    source (log2-histogram p50/p95/p99 estimates + each tenant's exact
    cold first-query latency) and splits cold vs warm exactly — every
    query lands in one side, one cold per distinct scope."""
    n, m, log = _dynamic_stream(seed=19)
    trace = _multi_source_trace(log, SOURCES)
    eng = SSSPDelEngine(EngineConfig(n, m + 64, SOURCES[0],
                                     sources=SOURCES))
    rep = replay_trace(eng, trace)

    ps = rep.per_source
    assert ps is not None
    # the trace carries routed queries for every source (plus the
    # stream's own -1 markers answered as the full-stack "*" scope)
    assert set(SOURCES) <= set(k for k in ps if k != "*")
    assert sum(e["queries"] for e in ps.values()) == rep.queries
    for entry in ps.values():
        assert entry["queries"] >= 1 and entry["cold_ms"] > 0
        assert entry["p50_ms"] <= entry["p95_ms"] <= entry["p99_ms"]

    cw = rep.cold_warm
    assert cw is not None
    assert cw["cold_queries"] == len(ps)          # one cold per scope
    assert cw["cold_queries"] + cw["warm_queries"] == rep.queries
    assert cw["cold_p50_ms"] > 0 and cw["warm_p50_ms"] > 0

    rec = rep.to_record()
    for key in ("cold_queries", "warm_queries", "latency_cold_p50_ms",
                "latency_warm_p50_ms", "latency_warm_p99_ms"):
        assert key in rec
    assert "cold" in rep.summary() and "warm" in rep.summary()


def test_trace_replay_drives_sharded_engine(tmp_path):
    """The replayer is engine-agnostic: the same trace through the sharded
    batched engine matches the single-device batched engine."""
    n, m, log = _dynamic_stream(seed=29)
    trace = _multi_source_trace(log, SOURCES, n_points=3)
    single = SSSPDelEngine(EngineConfig(n, m + 64, SOURCES[0],
                                        sources=SOURCES))
    sharded = ShardedSSSPDelEngine(ShardedEngineConfig(
        n, m + 64, SOURCES[0], sources=SOURCES))
    rep_a = replay_trace(single, trace)
    rep_b = replay_trace(sharded, trace)
    qa, qb = single.query(), sharded.query()
    np.testing.assert_array_equal(qa.dist, qb.dist)
    np.testing.assert_array_equal(qa.parent, qb.parent)
    assert rep_a.churn_mean == rep_b.churn_mean
    assert rep_a.queries == rep_b.queries


def test_trace_load_error_contract(tmp_path):
    with pytest.raises(FileNotFoundError):
        ServingTrace.load(str(tmp_path / "missing.trace"))
    bad = tmp_path / "bad.trace"
    bad.write_bytes(b"not a trace at all")
    with pytest.raises(TraceFormatError):
        ServingTrace.load(str(bad))
    foreign = tmp_path / "foreign.npz"
    np.savez(foreign, a=np.arange(3))
    with pytest.raises(TraceFormatError):
        ServingTrace.load(str(foreign))


def test_example_exits_2_on_unknown_trace_path(tmp_path):
    """CLI contract (same as unknown --only sections): a missing or
    incompatible --replay-trace path exits with code 2."""
    import os

    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(root / "src")
                         + (":" + env["PYTHONPATH"]
                            if env.get("PYTHONPATH") else ""))
    for path in (str(tmp_path / "missing.trace"),):
        proc = subprocess.run(
            [sys.executable, str(root / "examples" / "streaming_sssp.py"),
             "--replay-trace", path],
            capture_output=True, text=True, env=env, timeout=120)
        assert proc.returncode == 2, proc.stderr
        assert "error:" in proc.stderr


# ---------------------------------------------------------------- metrics --
def test_churn_and_percentile_helpers():
    prev_d = np.array([1.0, np.inf, 3.0, 4.0], np.float32)
    prev_p = np.array([0, -1, 1, 2], np.int32)
    d = np.array([1.0, np.inf, 2.5, 4.0], np.float32)
    p = np.array([0, -1, 0, 2], np.int32)
    c = churn(prev_d, prev_p, d, p)
    assert c["dist"] == pytest.approx(0.25)     # inf==inf is stable
    assert c["parent"] == pytest.approx(0.25)
    assert c["any"] == pytest.approx(0.25)
    assert pctile([], 50) != pctile([], 50)     # NaN convention
    assert pctile([1.0, 2.0, 3.0], 50) == 2.0


def test_percentile_edge_cases_never_raise():
    """Satellite (DESIGN.md §10 ride-along): the percentile helpers must
    hold their conventions on degenerate inputs — empty -> NaN (never an
    exception), one sample is every percentile of itself, scalars wrap,
    generators materialize, [S, N] stacks flatten."""
    import math

    from repro.serving.metrics import percentiles
    for empty in ([], np.array([]), np.zeros((0, 4)), iter(())):
        assert math.isnan(pctile(empty, 50))
    assert all(math.isnan(v) for v in percentiles([]).values())
    for q in (0, 50, 99, 100):
        assert pctile([7.5], q) == 7.5          # single sample
        assert pctile(7.5, q) == 7.5            # bare scalar wraps
        assert pctile(np.float32(7.5), q) == 7.5
    assert pctile((x for x in (1.0, 2.0, 3.0)), 50) == 2.0   # generator
    stacked = np.array([[1.0, 2.0], [3.0, 4.0]])             # [S, N] flattens
    assert pctile(stacked, 50) == 2.5
    assert percentiles([5.0]) == {"p50": 5.0, "p95": 5.0, "p99": 5.0}


def test_percentile_helper_is_shared_with_benchmarks():
    """benchmarks/common.py must re-export THE serving implementation so
    bench sections and the harness can never disagree."""
    from benchmarks import common as C
    from repro.serving import metrics as M
    assert C.pctile is M.pctile
    assert C.percentiles is M.percentiles
