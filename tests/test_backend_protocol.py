"""Protocol seams of the backend layer (DESIGN.md §7): registry lookup,
construction-time config validation, and the per-window builders the
sharded coordinators build their shard-local layouts with.
"""
import numpy as np
import pytest

from repro.core import backends as bk
from repro.core.engine import EngineConfig
from repro.graphs import csr


# ---------------------------------------------------------------- registry --
def test_registry_has_all_stock_backends():
    assert set(bk.RELAX_BACKENDS) == {"segment", "ellpack", "sliced"}
    assert set(bk.BACKENDS) == set(bk.SHARDED_BACKENDS)
    for name, cls in bk.BACKENDS.items():
        assert cls.name == name
        assert issubclass(cls, bk.RelaxBackend)
    for name, cls in bk.SHARDED_BACKENDS.items():
        assert issubclass(cls, bk.ShardedBackend)


def test_registry_lookup_builds_matching_backend():
    cfg = EngineConfig(16, 64, 0, relax_backend="ellpack", ell_init_k=2)
    b = bk.make_backend("ellpack", cfg)
    assert isinstance(b, bk.EllpackBackend)
    assert b.planner.k == 2 and b.n == 16
    with pytest.raises(ValueError, match=r"ellpack.*segment.*sliced"):
        bk.make_backend("csr", cfg)


# -------------------------------------------------------------- validation --
def test_unknown_backend_raises_with_valid_set():
    with pytest.raises(ValueError) as ei:
        EngineConfig(16, 64, 0, relax_backend="elpack")
    msg = str(ei.value)
    assert "elpack" in msg
    for name in ("segment", "ellpack", "sliced"):
        assert name in msg, f"valid set missing {name}: {msg}"


def test_sliced_knobs_on_non_sliced_backend_raise():
    with pytest.raises(ValueError, match="sliced_hub_k"):
        EngineConfig(16, 64, 0, relax_backend="ellpack", sliced_hub_k=8)
    with pytest.raises(ValueError, match="sliced_init_k"):
        EngineConfig(16, 64, 0, relax_backend="segment", sliced_init_k=4)
    # the matching backend accepts them
    EngineConfig(16, 64, 0, relax_backend="sliced", sliced_hub_k=8,
                 sliced_init_k=4)


def test_ell_knobs_on_segment_backend_raise():
    with pytest.raises(ValueError, match="ell_init_k"):
        EngineConfig(16, 64, 0, ell_init_k=2)   # default backend = segment
    # dense-ELL geometry knobs apply ONLY to the ellpack backend (the
    # sliced layout never reads them — silently ignoring them would let
    # users believe they tuned something)
    EngineConfig(16, 64, 0, relax_backend="ellpack", ell_init_k=2)
    with pytest.raises(ValueError, match="ell_init_k"):
        EngineConfig(16, 64, 0, relax_backend="sliced", ell_init_k=2)
    with pytest.raises(ValueError, match="ell_block_rows"):
        EngineConfig(16, 64, 0, relax_backend="sliced", ell_block_rows=64)
    # ...but ell_use_kernel is genuinely shared by both ELL-layout backends
    EngineConfig(16, 64, 0, relax_backend="ellpack", ell_use_kernel=False)
    EngineConfig(16, 64, 0, relax_backend="sliced", ell_use_kernel=False)


def test_sharded_config_validates_identically():
    from repro.core.dist_engine import ShardedEngineConfig
    with pytest.raises(ValueError, match="valid backends"):
        ShardedEngineConfig(16, 64, 0, relax_backend="nope")
    with pytest.raises(ValueError, match="sliced_hub_k"):
        ShardedEngineConfig(16, 64, 0, sliced_hub_k=8)
    with pytest.raises(ValueError, match="exchange"):
        ShardedEngineConfig(16, 64, 0, exchange="gossip")


# ------------------------------------------------------ per-window builders --
def _window_graph(seed=3, n=90, m=520):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m).astype(np.int32)
    dst = rng.integers(0, n, m).astype(np.int32)
    w = rng.random(m).astype(np.float32)
    # dedup (u,v) like the slot allocator would
    key = src.astype(np.int64) * n + dst
    _, first = np.unique(key, return_index=True)
    return n, src[first], dst[first], w[first]


def test_ell_from_coo_window_matches_whole_graph():
    """Per-shard builder windows vs the whole-graph builder: building each
    vertex window with ``row0`` from globally-addressed edges must equal the
    corresponding row block of the whole-graph build — including the RAGGED
    last partition (n=90 over P=8 windows of npp=12 covers rows 90..95 that
    exist only as padding)."""
    n, src, dst, w = _window_graph()
    P, npp = 8, 12
    assert P * npp > n                       # ragged: last window is partial
    deg = np.bincount(dst, minlength=P * npp)
    k = csr.next_pow2(int(deg.max()))
    full_idx, full_w, full_fill = csr.ell_from_coo(
        P * npp, src, np.asarray(dst, np.int64), w, k=k, n_rows=P * npp)
    for p in range(P):
        lo, hi = p * npp, (p + 1) * npp
        sel = (dst >= lo) & (dst < hi)
        widx, ww, wfill = csr.ell_from_coo(
            npp, src[sel], dst[sel], w[sel], k=k, n_rows=npp, row0=lo)
        np.testing.assert_array_equal(widx, full_idx[lo:hi])
        np.testing.assert_array_equal(ww, full_w[lo:hi])
        np.testing.assert_array_equal(wfill, full_fill[lo:hi])


def test_ell_from_coo_window_rejects_out_of_window_dst():
    with pytest.raises(AssertionError, match="window"):
        csr.ell_from_coo(4, np.array([0]), np.array([9]),
                         np.array([1.0], np.float32), k=2, row0=4)


def test_sliced_ell_from_coo_window_matches_whole_graph():
    """Same contract for the hybrid builder: per-window flat buffers and
    overflow segments must match the whole-graph build sliced into windows
    (forcing identical widths, as the sharded coordinator's geometry sync
    does), again with a ragged last partition."""
    n, src, dst, w = _window_graph(seed=5)
    P, npp, sr, hub_k = 8, 12, 4, 4
    R = P * npp
    full = csr.sliced_ell_from_coo(R, src, np.asarray(dst, np.int64), w,
                                   slice_rows=sr, hub_k=hub_k)
    flat_idx, flat_w, fill, widths, osrc, odst, ow, n_over = full
    slices_pp = npp // sr
    _, _, base, _ = csr.sliced_geometry(widths, sr)
    for p in range(P):
        lo, hi = p * npp, (p + 1) * npp
        sel = (dst >= lo) & (dst < hi)
        wwidths = widths[p * slices_pp:(p + 1) * slices_pp]
        out = csr.sliced_ell_from_coo(
            npp, src[sel], dst[sel], w[sel], slice_rows=sr, hub_k=hub_k,
            widths=list(wwidths), row0=lo)
        w_flat_idx, w_flat_w, w_fill, _, w_osrc, w_odst, w_ow, w_nov = out
        a, b = int(base[lo]), int(base[lo] + len(w_flat_idx))
        np.testing.assert_array_equal(w_flat_idx, flat_idx[a:b])
        np.testing.assert_array_equal(w_flat_w, flat_w[a:b])
        np.testing.assert_array_equal(w_fill, fill[lo:hi])
        # the window's overflow entries are the whole-graph overflow entries
        # whose dst falls in the window (localized), same CSR order
        in_win = (odst[:n_over] >= lo) & (odst[:n_over] < hi)
        np.testing.assert_array_equal(w_osrc[:w_nov], osrc[:n_over][in_win])
        np.testing.assert_array_equal(w_odst[:w_nov],
                                      odst[:n_over][in_win] - lo)
        np.testing.assert_array_equal(w_ow[:w_nov], ow[:n_over][in_win])
