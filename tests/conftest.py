"""Shared test fixtures.

The full tier-1 run compiles thousands of distinct XLA programs in one
process (every engine x backend x schedule cell re-jits its epochs).  On
XLA:CPU each compiled executable pins LLVM JIT code memory for the life
of the process; past a few hundred test functions the accumulated
executables can crash the *next* compilation outright (segfault inside
``backend_compile``), taking the whole session down even though every
module passes in isolation.  Dropping jax's compilation caches between
modules releases the executables and keeps the per-process footprint
bounded; the price is a per-module recompile of the handful of shared
programs, which is noise next to the suite's own compile load.
"""
from __future__ import annotations

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()
