"""Subprocess worker for the sharded-engine equivalence tests (P=8).

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 (set by the
parent test process).  Replays the same mixed ADD/DEL/QUERY stream through
the single-device ``SSSPDelEngine`` and the 8-partition
``ShardedSSSPDelEngine`` on a (2,2,2) mesh — the production axis layout —
with the SAME relaxation backend on both sides, and asserts bit-identical
(dist, parent) at every query point, plus matching round/message stats for
the allgather exchange.

``--ckpt`` additionally exercises the crash-restart path: after half the
stream the sharded engine is checkpointed, a FRESH engine (fresh planners,
fresh backend layout) restores the snapshot and ingests the rest — the
restored run must stay on the reference trajectory query for query.

``--buckets`` runs the sharded engine under the bucketed delta-stepping
schedule (wave_schedule="buckets", DESIGN.md §9) against the single-device
ROUNDS reference — queries drain implicitly, so the per-query results must
still be bit-identical (stats differ by design: lazy epochs defer waves).

``--sparse`` runs the sharded engine with frontier_mode="sparse" and a
deliberately small frontier_cap, so each partition's in-wave edge
compaction AND its in-cond dense fallback both fire under P=8
(DESIGN.md §12.4) — results must stay on the dense trajectory exactly.

Usage: _dist_engine_worker.py <exchange> [batch_deletions] [use_doubling]
                              [backend] [--ckpt] [--buckets] [--sparse]
Prints "OK <queries> <rounds>" on success.
"""
import os
import sys

# must precede any jax import in this process
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.dist_engine import (ShardedEngineConfig,  # noqa: E402
                                    ShardedSSSPDelEngine)
from repro.core.engine import EngineConfig, SSSPDelEngine  # noqa: E402
from repro.graphs import generators, window  # noqa: E402
from repro.launch.mesh import _mk  # noqa: E402

# tiny layout knobs so rebuild/spill paths run under sharding too
BACKEND_KW = {
    "segment": {},
    "ellpack": dict(ell_init_k=2),
    "sliced": dict(sliced_slice_rows=8, sliced_hub_k=4, sliced_init_k=1),
}


def main(exchange: str, batch_deletions: bool, use_doubling: bool,
         backend: str = "segment", ckpt: bool = False,
         buckets: bool = False, sparse: bool = False) -> None:
    assert len(jax.devices()) == 8, f"expected 8 devices, got {len(jax.devices())}"
    mesh = _mk((2, 2, 2), ("pod", "data", "model"))
    n, src, dst, w = generators.erdos_renyi(120, 700, seed=23)
    source = int(generators.top_in_degree_sources(n, dst, 1)[0])
    log = window.sliding_window_stream(src, dst, w, window=len(src) // 3,
                                       delta=0.6, seed=23,
                                       query_every=len(src) // 4)
    kw = BACKEND_KW[backend]

    ref = SSSPDelEngine(EngineConfig(
        n, len(src) + 64, source, batch_deletions=batch_deletions,
        use_doubling=use_doubling, relax_backend=backend, **kw))

    sched = (dict(wave_schedule="buckets", bucket_width=1.0)
             if buckets else {})
    if sparse:
        # cap=32 over ~87 edge slots/partition: small batches compact,
        # recompute pulls overflow into the in-cond dense branch
        sched = dict(sched, frontier_mode="sparse", frontier_cap=32)

    def mk_sharded():
        # tiny delta_cap so the delta exchange exercises its overflow fallback
        return ShardedSSSPDelEngine(
            ShardedEngineConfig(n, len(src) + 64, source, exchange=exchange,
                                delta_cap=16, batch_deletions=batch_deletions,
                                use_doubling=use_doubling,
                                relax_backend=backend, **sched, **kw),
            mesh=mesh)

    res_ref = ref.ingest_log(log) + [ref.query()]
    if ckpt:
        half = len(log) // 2
        eng0 = mk_sharded()
        res_eng = eng0.ingest_log(log[:half])
        snapshot = eng0.checkpoint()
        del eng0                      # crash: the engine is gone
        eng = mk_sharded()            # restart: fresh planners + layout
        eng.restore(snapshot)
        res_eng += eng.ingest_log(log[half:]) + [eng.query()]
    else:
        eng = mk_sharded()
        res_eng = eng.ingest_log(log) + [eng.query()]
    assert len(res_ref) == len(res_eng) and len(res_ref) > 2
    for i, (a, b) in enumerate(zip(res_ref, res_eng)):
        np.testing.assert_array_equal(a.dist, b.dist,
                                      err_msg=f"dist mismatch at query {i}")
        np.testing.assert_array_equal(a.parent, b.parent,
                                      err_msg=f"parent mismatch at query {i}")
    if exchange == "allgather" and not ckpt and not buckets:
        assert ref.n_rounds == eng.n_rounds, (ref.n_rounds, eng.n_rounds)
        assert ref.n_messages == eng.n_messages, (
            ref.n_messages, eng.n_messages)
    assert eng.partition_fill().sum() == int(np.asarray(
        ref.state.edges.active).sum()), "pool mirror divergence"
    print(f"OK {len(res_eng)} {eng.n_rounds}")


if __name__ == "__main__":
    args = [a for a in sys.argv[1:]
            if a not in ("--ckpt", "--buckets", "--sparse")]
    exchange = args[0] if len(args) > 0 else "allgather"
    bd = bool(int(args[1])) if len(args) > 1 else False
    ud = bool(int(args[2])) if len(args) > 2 else True
    backend = args[3] if len(args) > 3 else "segment"
    main(exchange, bd, ud, backend, ckpt="--ckpt" in sys.argv[1:],
         buckets="--buckets" in sys.argv[1:],
         sparse="--sparse" in sys.argv[1:])
