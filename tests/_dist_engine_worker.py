"""Subprocess worker for the sharded-engine equivalence tests (P=8).

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 (set by the
parent test process).  Replays the same mixed ADD/DEL/QUERY stream through
the single-device ``SSSPDelEngine`` and the 8-partition
``ShardedSSSPDelEngine`` on a (2,2,2) mesh — the production axis layout —
and asserts bit-identical (dist, parent) at every query point, plus
matching round/message stats for the allgather exchange.

Usage: _dist_engine_worker.py <exchange> [batch_deletions] [use_doubling]
Prints "OK <queries> <rounds>" on success.
"""
import os
import sys

# must precede any jax import in this process
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.dist_engine import (ShardedEngineConfig,  # noqa: E402
                                    ShardedSSSPDelEngine)
from repro.core.engine import EngineConfig, SSSPDelEngine  # noqa: E402
from repro.graphs import generators, window  # noqa: E402
from repro.launch.mesh import _mk  # noqa: E402


def main(exchange: str, batch_deletions: bool, use_doubling: bool) -> None:
    assert len(jax.devices()) == 8, f"expected 8 devices, got {len(jax.devices())}"
    mesh = _mk((2, 2, 2), ("pod", "data", "model"))
    n, src, dst, w = generators.erdos_renyi(120, 700, seed=23)
    source = int(generators.top_in_degree_sources(n, dst, 1)[0])
    log = window.sliding_window_stream(src, dst, w, window=len(src) // 3,
                                       delta=0.6, seed=23,
                                       query_every=len(src) // 4)

    ref = SSSPDelEngine(EngineConfig(
        n, len(src) + 64, source, batch_deletions=batch_deletions,
        use_doubling=use_doubling))
    # tiny delta_cap so the delta exchange exercises its overflow fallback
    eng = ShardedSSSPDelEngine(
        ShardedEngineConfig(n, len(src) + 64, source, exchange=exchange,
                            delta_cap=16, batch_deletions=batch_deletions,
                            use_doubling=use_doubling),
        mesh=mesh)

    res_ref = ref.ingest_log(log) + [ref.query()]
    res_eng = eng.ingest_log(log) + [eng.query()]
    assert len(res_ref) == len(res_eng) and len(res_ref) > 2
    for i, (a, b) in enumerate(zip(res_ref, res_eng)):
        np.testing.assert_array_equal(a.dist, b.dist,
                                      err_msg=f"dist mismatch at query {i}")
        np.testing.assert_array_equal(a.parent, b.parent,
                                      err_msg=f"parent mismatch at query {i}")
    if exchange == "allgather":
        assert ref.n_rounds == eng.n_rounds, (ref.n_rounds, eng.n_rounds)
        assert ref.n_messages == eng.n_messages, (
            ref.n_messages, eng.n_messages)
    assert eng.partition_fill().sum() == int(np.asarray(
        ref.state.edges.active).sum()), "pool mirror divergence"
    print(f"OK {len(res_eng)} {eng.n_rounds}")


if __name__ == "__main__":
    exchange = sys.argv[1] if len(sys.argv) > 1 else "allgather"
    bd = bool(int(sys.argv[2])) if len(sys.argv) > 2 else False
    ud = bool(int(sys.argv[3])) if len(sys.argv) > 3 else True
    main(exchange, bd, ud)
