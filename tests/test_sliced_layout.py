"""Host-side sliced-ELL layout builders (graphs/csr.py): edge cases and
round-trips.

``csr_to_sliced_ell`` (list-of-blocks form, per-slice K) and
``sliced_ell_from_coo`` (flat hybrid form with hub overflow — the device
layout of the "sliced" relaxation backend, DESIGN.md §6) must both encode
exactly the input edge set: every (src, dst, w) present once, every other
cell inert (+inf).
"""
import numpy as np
import pytest

from repro.graphs import csr, generators


def _edge_set(src, dst, w):
    return {(int(s), int(d), float(np.float32(x)))
            for s, d, x in zip(src, dst, w)}


def _decode_sliced_blocks(blocks):
    """Edges encoded by csr_to_sliced_ell's (row_offset, idx, w) blocks."""
    out = set()
    for r0, idx, ww in blocks:
        rows, kpos = np.nonzero(np.isfinite(ww))
        for r, k in zip(rows, kpos):
            out.add((int(idx[r, k]), int(r0 + r), float(ww[r, k])))
    return out


def _decode_flat(flat_idx, flat_w, widths, slice_rows, osrc, odst, ow):
    """Edges encoded by sliced_ell_from_coo's flat + overflow arrays."""
    out = set()
    off = 0
    for s, k in enumerate(widths):
        idx = flat_idx[off:off + slice_rows * k].reshape(slice_rows, k)
        ww = flat_w[off:off + slice_rows * k].reshape(slice_rows, k)
        rows, kpos = np.nonzero(np.isfinite(ww))
        for r, c in zip(rows, kpos):
            out.add((int(idx[r, c]), int(s * slice_rows + r),
                     float(ww[r, c])))
        off += slice_rows * k
    live = np.isfinite(ow)
    for s, d, x in zip(osrc[live], odst[live], ow[live]):
        out.add((int(s), int(d), float(x)))
    return out


def _random_coo(n, m, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, 3 * m)
    dst = rng.integers(0, n, 3 * m)
    keep = src != dst
    key = src[keep] * n + dst[keep]
    _, idx = np.unique(key, return_index=True)
    src, dst = src[keep][idx][:m], dst[keep][idx][:m]
    w = rng.random(len(src)).astype(np.float32) + 0.1
    return src, dst, w


# ------------------------------------------------------- csr_to_sliced_ell --
def test_sliced_ell_empty_rows():
    # rows 0, 2, 4 have in-edges; 1, 3, 5..7 are empty
    n = 8
    src = np.array([1, 3, 5], np.int64)
    dst = np.array([0, 2, 4], np.int64)
    w = np.array([1.0, 2.0, 3.0], np.float32)
    indptr, cols, ws, _ = csr.coo_to_csr(n, src, dst, w)
    blocks = csr.csr_to_sliced_ell(n, indptr, cols, ws, slice_rows=4)
    assert len(blocks) == 2
    assert _decode_sliced_blocks(blocks) == _edge_set(src, dst, w)
    # per-slice K adapts to the slice's own max degree (here 1 everywhere)
    assert all(blk[1].shape[1] == 1 for blk in blocks)


def test_sliced_ell_totally_empty_graph():
    n = 5
    indptr = np.zeros(n + 1, np.int64)
    blocks = csr.csr_to_sliced_ell(n, indptr, np.empty(0, np.int64),
                                   np.empty(0, np.float32), slice_rows=4)
    assert _decode_sliced_blocks(blocks) == set()
    assert all(np.isinf(blk[2]).all() for blk in blocks)


def test_sliced_ell_single_slice():
    n, m = 10, 30
    src, dst, w = _random_coo(n, m, seed=3)
    indptr, cols, ws, _ = csr.coo_to_csr(n, src, dst, w)
    blocks = csr.csr_to_sliced_ell(n, indptr, cols, ws, slice_rows=256)
    assert len(blocks) == 1 and blocks[0][0] == 0
    assert _decode_sliced_blocks(blocks) == _edge_set(src, dst, w)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("slice_rows", [4, 32])
def test_sliced_ell_roundtrip_vs_ell_from_coo(seed, slice_rows):
    """Both layouts must encode the identical edge set on random COO."""
    n, m = 40, 150
    src, dst, w = _random_coo(n, m, seed=seed)
    indptr, cols, ws, _ = csr.coo_to_csr(n, src, dst, w)
    blocks = csr.csr_to_sliced_ell(n, indptr, cols, ws,
                                   slice_rows=slice_rows)

    deg = np.diff(indptr)
    idx, ww, fill = csr.ell_from_coo(n, src, dst, w, k=int(deg.max()))
    dense = set()
    rows, kpos = np.nonzero(np.isfinite(ww))
    for r, k in zip(rows, kpos):
        dense.add((int(idx[r, k]), int(r), float(ww[r, k])))

    assert _decode_sliced_blocks(blocks) == dense == _edge_set(src, dst, w)
    np.testing.assert_array_equal(fill[:n], deg)
    # sliced padding never exceeds dense padding
    sliced_cells = sum(blk[1].size for blk in blocks)
    assert sliced_cells <= idx.size


# ------------------------------------------------------ sliced_ell_from_coo --
def test_flat_hybrid_roundtrip_and_hub_split():
    n, m = 64, 400
    src, dst, w = _random_coo(n, m, seed=7)
    out = csr.sliced_ell_from_coo(n, src, dst, w, slice_rows=16, hub_k=4)
    flat_idx, flat_w, fill, widths, osrc, odst, ow, n_over = out
    assert _decode_flat(flat_idx, flat_w, widths, 16, osrc, odst, ow) \
        == _edge_set(src, dst, w)
    deg = np.bincount(dst, minlength=n)
    # fill is the capped in-degree; surplus lives in overflow
    np.testing.assert_array_equal(fill[:n], np.minimum(deg, 4))
    assert n_over == int(np.maximum(deg - 4, 0).sum())
    assert all(k <= 4 for k in widths)


def test_flat_hybrid_all_vertices_hubs():
    """Every vertex past the hub threshold: ELL holds exactly hub_k edges
    per row, everything else spills to overflow."""
    n, hub_k = 6, 2
    # complete digraph minus self-loops: in-degree 5 > hub_k everywhere
    src, dst = np.nonzero(~np.eye(n, dtype=bool))
    w = (1.0 + np.arange(len(src))).astype(np.float32)
    out = csr.sliced_ell_from_coo(n, src, dst, w, slice_rows=4,
                                  hub_k=hub_k)
    flat_idx, flat_w, fill, widths, osrc, odst, ow, n_over = out
    assert _decode_flat(flat_idx, flat_w, widths, 4, osrc, odst, ow) \
        == _edge_set(src, dst, w)
    np.testing.assert_array_equal(fill[:n], hub_k)
    assert n_over == n * (n - 1) - n * hub_k
    assert widths == [hub_k, hub_k]


def test_flat_hybrid_empty_and_width_overrides():
    n = 10
    z = np.empty(0, np.int64)
    out = csr.sliced_ell_from_coo(n, z, z, np.empty(0, np.float32),
                                  slice_rows=8, hub_k=8,
                                  widths=[4, 2], overflow_capacity=16)
    flat_idx, flat_w, fill, widths, osrc, odst, ow, n_over = out
    assert widths == [4, 2] and n_over == 0
    assert len(flat_w) == 8 * 4 + 8 * 2 and np.isinf(flat_w).all()
    assert len(ow) == 16 and np.isinf(ow).all()
    assert fill.sum() == 0


def test_flat_hybrid_power_law_padding_win():
    """The reason the layout exists: on in-degree power-law graphs the flat
    hybrid stores far fewer cells than dense ELL."""
    n, m = 256, 2560
    nv, src, dst, w = generators.power_law_hubs(n, m, n_hubs=3, seed=5,
                                                orientation="in")
    deg = np.bincount(dst, minlength=nv)
    out = csr.sliced_ell_from_coo(nv, src, dst, w, slice_rows=32, hub_k=16)
    flat_idx, flat_w, fill, widths, osrc, odst, ow, n_over = out
    assert _decode_flat(flat_idx, flat_w, widths, 32, osrc, odst, ow) \
        == _edge_set(src, dst, w)
    dense_cells = -(-nv // 32) * 32 * int(deg.max())
    hybrid_cells = len(flat_idx) + len(ow)
    assert hybrid_cells < dense_cells / 4, (hybrid_cells, dense_cells)


def test_sliced_kernel_path_tiles_merged_runs():
    """9 equal-width 32-row slices merge into a 288-row wave block, which
    the Pallas kernel path must split to satisfy its 256-row tiling
    (regression: AssertionError (288, 256) inside ellpack_relax)."""
    import numpy as np
    from repro.core import events as ev
    from repro.core.engine import EngineConfig, SSSPDelEngine
    from repro.core.oracle import check_tree, edges_of_pool

    n = 288
    eng = SSSPDelEngine(EngineConfig(n, 1024, 0, relax_backend="sliced",
                                     sliced_slice_rows=32, sliced_hub_k=4,
                                     sliced_init_k=1, ell_use_kernel=True))
    src = np.arange(n - 1)
    dst = np.arange(1, n)
    eng.ingest_log(ev.adds(src, dst, np.ones(n - 1, np.float32)))
    eng.ingest_log(ev.dels([10], [11]))   # cuts the path: 11.. unreachable
    q = eng.query()
    e = eng.state.edges
    es, ed, ew = edges_of_pool(e.src, e.dst, e.w, e.active)
    check_tree(n, es, ed, ew, 0, q.dist, q.parent)
    assert q.dist[10] == 10.0 and np.isinf(q.dist[11])


def test_power_law_hubs_orientation():
    n, m = 128, 1280
    _, so, do, wo = generators.power_law_hubs(n, m, seed=4)  # default "out"
    _, si, di, wi = generators.power_law_hubs(n, m, seed=4, orientation="in")
    # identical draws, swapped roles: the "in" stream is the transpose
    np.testing.assert_array_equal(so, di)
    np.testing.assert_array_equal(do, si)
    np.testing.assert_array_equal(wo, wi)
    assert np.bincount(di, minlength=n).max() \
        > 4 * np.bincount(do, minlength=n).max()
