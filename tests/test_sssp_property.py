"""Property-based tests (hypothesis): for *any* valid event stream, after any
prefix ending at an epoch boundary the engine's distances equal Dijkstra on
the snapshot and the parent pointers form a tight shortest-path tree.

This is the strongest form of the paper's Appendix A claim we can check
mechanically.
"""
import numpy as np
from repro.testing import given, settings, st  # hypothesis or fallback sampler

from repro.core import events as ev
from repro.core.engine import EngineConfig, SSSPDelEngine
from repro.core.oracle import check_tree, edges_of_pool

N = 24  # small vertex universe keeps shrinking effective


@st.composite
def event_streams(draw):
    n_ev = draw(st.integers(min_value=1, max_value=60))
    kinds, srcs, dsts, ws = [], [], [], []
    live: set[tuple[int, int]] = set()
    for _ in range(n_ev):
        u = draw(st.integers(0, N - 1))
        v = draw(st.integers(0, N - 1))
        if u == v:
            continue
        if (u, v) in live and draw(st.booleans()):
            kinds.append(ev.DEL); srcs.append(u); dsts.append(v); ws.append(0.0)
            live.discard((u, v))
        else:
            w = draw(st.floats(min_value=0.1, max_value=8.0,
                               allow_nan=False, allow_infinity=False))
            kinds.append(ev.ADD); srcs.append(u); dsts.append(v); ws.append(w)
            live.add((u, v))
    if not kinds:
        kinds, srcs, dsts, ws = [ev.ADD], [0], [1], [1.0]
    return ev.EventLog(np.asarray(kinds, np.uint8), np.asarray(srcs, np.int64),
                       np.asarray(dsts, np.int64), np.asarray(ws, np.float32))


@settings(max_examples=25, deadline=None)
@given(log=event_streams(), source=st.integers(0, N - 1),
       batch_dels=st.booleans(), doubling=st.booleans())
def test_engine_matches_oracle_on_any_stream(log, source, batch_dels, doubling):
    eng = SSSPDelEngine(EngineConfig(
        num_vertices=N, edge_capacity=4 * len(log) + 8, source=source,
        batch_deletions=batch_dels, use_doubling=doubling))
    eng.ingest_log(log)
    res = eng.query()
    e = eng.state.edges
    es, ed, ew = edges_of_pool(e.src, e.dst, e.w, e.active)
    check_tree(N, es, ed, ew, source, res.dist, res.parent)


@settings(max_examples=15, deadline=None)
@given(log=event_streams(), source=st.integers(0, N - 1),
       cut=st.integers(1, 50))
def test_oracle_holds_at_every_prefix(log, source, cut):
    prefix = log[:min(cut, len(log))]
    eng = SSSPDelEngine(EngineConfig(N, 4 * len(log) + 8, source))
    eng.ingest_log(prefix)
    res = eng.query()
    e = eng.state.edges
    es, ed, ew = edges_of_pool(e.src, e.dst, e.w, e.active)
    check_tree(N, es, ed, ew, source, res.dist, res.parent)


@settings(max_examples=10, deadline=None)
@given(log=event_streams(), source=st.integers(0, N - 1))
def test_dist_never_negative_and_source_zero(log, source):
    eng = SSSPDelEngine(EngineConfig(N, 4 * len(log) + 8, source))
    eng.ingest_log(log)
    res = eng.query()
    assert res.dist[source] == 0.0
    finite = res.dist[np.isfinite(res.dist)]
    assert (finite >= 0).all()
