"""Bucketed delta-stepping schedule (DESIGN.md §9): the ``buckets`` wave
schedule must land on the SAME fixpoint as the ``rounds`` schedule —
bit-identical final (dist, parent) at every drain point — across the bucket
width axis, the backend axis, the batched [S, N] serving axis and the
partition-count axis, while spending no more total rounds than the eager
schedule at delta >= 1 (the rounds *budget* gate; sub-unit widths may
over-serialize, which is delta-stepping working as specified, so the budget
is asserted only for widths >= 1).

Also here: the dense-ELL hub-blowup warning and the ``relax_backend="auto"``
fallback it motivates (DESIGN.md §6) — a rebuild whose K*N cell allocation
exceeds ELL_BLOWUP_RATIO x live edges warns once naming the sliced layout,
and "auto" swaps the engine onto it mid-stream without leaving the
equivalence contract.
"""
import warnings

import numpy as np
import pytest

import jax

from repro.core import events as ev
from repro.core.backends import SlicedBackend
from repro.core.dist_engine import ShardedEngineConfig, ShardedSSSPDelEngine
from repro.core.engine import EngineConfig, SSSPDelEngine
from repro.core.oracle import check_tree, edges_of_pool
from repro.graphs import generators, window
from repro.launch.mesh import _mk

WIDTHS = [0.25, 1.0, 4.0, float("inf")]
BACKEND_KW = {
    "segment": {},
    "ellpack": dict(ell_init_k=2),
    "sliced": dict(sliced_slice_rows=32, sliced_hub_k=4, sliced_init_k=1),
}


def _stream(seed, *, n=90, m=520, delta=0.6, query_every=None):
    n, src, dst, w = generators.erdos_renyi(n, m, seed=seed)
    log = window.sliding_window_stream(
        src, dst, w, window=m // 3, delta=delta, seed=seed,
        query_every=m // 2 if query_every is None else query_every)
    return n, len(src), log


def _run(cfg, log):
    eng = SSSPDelEngine(cfg)
    outs = eng.ingest_log(log)
    eng.drain()
    return eng, outs


def _assert_equal(res_a, res_b, tag=""):
    assert len(res_a) == len(res_b)
    for i, (a, b) in enumerate(zip(res_a, res_b)):
        np.testing.assert_array_equal(
            a.dist, b.dist, err_msg=f"{tag} dist mismatch at query {i}")
        np.testing.assert_array_equal(
            a.parent, b.parent, err_msg=f"{tag} parent mismatch at query {i}")


# ------------------------------------------------------- single-device axis --
@pytest.mark.parametrize("backend", sorted(BACKEND_KW))
@pytest.mark.parametrize("width", WIDTHS)
def test_bucketed_bit_identical_to_rounds(backend, width):
    """Final-state identity (DESIGN.md §9.2): every drain — the stream has
    ADDs, tree-edge DELETEs (recompute pulls) and interleaved queries —
    lands on the rounds schedule's exact (dist, parent) bits."""
    n, m, log = _stream(seed=41, delta=0.6)
    kw = BACKEND_KW[backend]
    ref, ref_outs = _run(EngineConfig(
        n, m + 64, 3, relax_backend=backend, **kw), log)
    eng, outs = _run(EngineConfig(
        n, m + 64, 3, relax_backend=backend, wave_schedule="buckets",
        bucket_width=width, **kw), log)
    _assert_equal(ref_outs + [ref.query()], outs + [eng.query()],
                  tag=f"{backend} w={width}")
    assert eng.n_dels > 0 and len(outs) >= 2  # deletes + drains exercised
    if width >= 1.0:
        # rounds budget: lazy epochs + bucketed drains must not spend more
        # waves than eager per-epoch convergence (sub-1 widths may)
        assert int(eng.n_rounds) <= int(ref.n_rounds), (
            f"buckets w={width} spent {int(eng.n_rounds)} rounds vs "
            f"rounds-schedule {int(ref.n_rounds)}")


def test_bucket_width_auto_bit_identical():
    """``bucket_width="auto"`` (DESIGN.md §9.5) resolves a pow2-quantized
    live-weight median host-side at drain time.  Whatever width it picks,
    the fixpoint contract is unchanged: every drain point must match the
    rounds schedule's exact bits, single-device and sharded (P=1), and the
    two engines must resolve the SAME width on the same stream."""
    n, m, log = _stream(seed=53, delta=0.6)
    ref, ref_outs = _run(EngineConfig(n, m + 64, 3), log)
    eng, outs = _run(EngineConfig(
        n, m + 64, 3, wave_schedule="buckets", bucket_width="auto"), log)
    _assert_equal(ref_outs + [ref.query()], outs + [eng.query()],
                  tag="bw-auto")
    # the resolved width is a positive pow2 multiple (quantization bounds
    # the distinct static widths the jitted drains ever see)
    w = eng._bucket_width()
    assert w > 0 and float(np.log2(w)) == int(np.log2(w))
    shd = ShardedSSSPDelEngine(ShardedEngineConfig(
        n, m + 64, 3, wave_schedule="buckets", bucket_width="auto"))
    shd_outs = shd.ingest_log(log)
    _assert_equal(ref_outs + [ref.query()], shd_outs + [shd.query()],
                  tag="bw-auto-sharded")
    assert shd._bucket_width() == w   # same policy, same stream, same width


def test_bucketed_rounds_identical_across_backends():
    """The drained wave SEQUENCE (not just the fixpoint) is backend-
    independent: per-width round/message counters agree across all three."""
    n, m, log = _stream(seed=43)
    for width in (0.5, 2.0):
        stats = []
        for backend, kw in sorted(BACKEND_KW.items()):
            eng, _ = _run(EngineConfig(
                n, m + 64, 3, relax_backend=backend,
                wave_schedule="buckets", bucket_width=width, **kw), log)
            stats.append((backend, int(eng.n_rounds), int(eng.n_messages)))
        assert len({s[1:] for s in stats}) == 1, stats


def test_bucketed_oracle_at_drain_points():
    """Every drained tree satisfies the Dijkstra oracle on the live edges."""
    n, m, log = _stream(seed=47, query_every=130)
    eng, outs = _run(EngineConfig(
        n, m + 64, 3, wave_schedule="buckets", bucket_width=1.0), log)
    assert len(outs) >= 3
    q = eng.query()
    e = eng.state.edges
    es, ed, ew = edges_of_pool(e.src, e.dst, e.w, e.active)
    check_tree(n, es, ed, ew, 3, np.asarray(q.dist), np.asarray(q.parent))


def test_bucketed_batched_lanes_match_rounds():
    """[S, N] serving lanes under the bucketed schedule: per-lane drains are
    bit-identical to the rounds schedule's stacked trees, per-lane stats
    frozen independently."""
    n, m, log = _stream(seed=53)
    sources = (0, 3, 11)
    for backend in ("segment", "sliced"):
        kw = BACKEND_KW[backend]
        ref, ref_outs = _run(EngineConfig(
            n, m + 64, 3, sources=sources, relax_backend=backend, **kw), log)
        for width in (1.0, float("inf")):
            eng, outs = _run(EngineConfig(
                n, m + 64, 3, sources=sources, relax_backend=backend,
                wave_schedule="buckets", bucket_width=width, **kw), log)
            _assert_equal(ref_outs + [ref.query()], outs + [eng.query()],
                          tag=f"batched {backend} w={width}")
            if width >= 1.0:
                assert int(np.asarray(eng.n_rounds).sum()) <= \
                    int(np.asarray(ref.n_rounds).sum())


def test_bucketed_checkpoint_restore_drains_first():
    """A checkpoint must capture a converged tree: pending work is drained
    before snapshotting, and a restored engine resumes with empty pending
    state on the reference trajectory."""
    n, m, log = _stream(seed=59)
    cfg = lambda: EngineConfig(n, m + 64, 3, wave_schedule="buckets",  # noqa
                               bucket_width=1.0)
    ref, _ = _run(EngineConfig(n, m + 64, 3), log)
    half = len(log) // 2
    eng0 = SSSPDelEngine(cfg())
    eng0.ingest_log(log[:half])
    snap = eng0.checkpoint()
    eng = SSSPDelEngine(cfg())
    eng.restore(snap)
    eng.ingest_log(log[half:])
    eng.drain()
    np.testing.assert_array_equal(ref.query().dist, eng.query().dist)
    np.testing.assert_array_equal(ref.query().parent, eng.query().parent)


def test_bucket_width_validation():
    with pytest.raises(ValueError, match="bucket_width"):
        EngineConfig(8, 16, 0, wave_schedule="buckets", bucket_width=0.0)
    with pytest.raises(ValueError, match="wave_schedule"):
        EngineConfig(8, 16, 0, wave_schedule="eager")
    with pytest.raises(ValueError, match="bucket_width"):
        # width configured while the schedule stays "rounds" = config bug
        EngineConfig(8, 16, 0, bucket_width=2.0)


# ------------------------------------------------------------ sharded axis --
@pytest.mark.parametrize("exchange", ["allgather", "delta"])
@pytest.mark.parametrize("width", [0.25, 1.0, float("inf")])
def test_sharded_bucketed_matches_single_device(exchange, width):
    """P=1 mesh, both exchanges: the sharded bucketed engine (broadcast
    bucket threshold, gated lazy epochs, collective-uniform drain) is
    bit-identical to the single-device ROUNDS engine at every query."""
    n, m, log = _stream(seed=61)
    ref, ref_outs = _run(EngineConfig(n, m + 64, 3), log)
    eng = ShardedSSSPDelEngine(ShardedEngineConfig(
        n, m + 64, 3, exchange=exchange, delta_cap=16,
        wave_schedule="buckets", bucket_width=width))
    outs = eng.ingest_log(log)
    eng.drain()
    _assert_equal(ref_outs + [ref.query()], outs + [eng.query()],
                  tag=f"sharded {exchange} w={width}")


def test_sharded_bucketed_stats_match_single_bucketed():
    """Same width => same wave sequence: the sharded bucketed engine's
    round/message counters equal the single-device bucketed engine's."""
    n, m, log = _stream(seed=67)
    sd, _ = _run(EngineConfig(n, m + 64, 3, wave_schedule="buckets",
                              bucket_width=1.0), log)
    sh = ShardedSSSPDelEngine(ShardedEngineConfig(
        n, m + 64, 3, wave_schedule="buckets", bucket_width=1.0))
    sh.ingest_log(log)
    sh.drain()
    assert int(sd.n_rounds) == int(sh.n_rounds)
    assert int(sd.n_messages) == int(sh.n_messages)


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 devices (CI runs this module with "
                           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
@pytest.mark.parametrize("exchange,sources", [
    ("allgather", None), ("delta", None), ("allgather", (0, 5, 9))])
def test_sharded_bucketed_p8(exchange, sources):
    """P=8 forced host devices: bucket threshold broadcast + drain across a
    real 8-way partition, single-source and batched lanes."""
    mesh = _mk((8,), ("graph",))
    n, m, log = _stream(seed=71, n=120, m=700)
    ref, ref_outs = _run(EngineConfig(n, m + 64, 5, sources=sources), log)
    eng = ShardedSSSPDelEngine(ShardedEngineConfig(
        n, m + 64, 5, exchange=exchange, delta_cap=16, sources=sources,
        wave_schedule="buckets", bucket_width=1.0), mesh=mesh)
    assert eng.P == 8
    outs = eng.ingest_log(log)
    eng.drain()
    _assert_equal(ref_outs + [ref.query()], outs + [eng.query()],
                  tag=f"p8 {exchange} sources={sources}")


# ----------------------------------------- hub blowup warning + auto fallback --
def _hub_stream(n=512, m=220, hub_deg=80, seed=7):
    """A few hub destinations dominate: dense ELL must pad every row to the
    hub in-degree -> K*N cells >> live edges."""
    rng = np.random.default_rng(seed)
    hub = rng.integers(1, n, size=hub_deg)
    src = np.r_[hub, rng.integers(0, n, size=m - hub_deg)]
    dst = np.r_[np.zeros(hub_deg, np.int64),
                rng.integers(0, n, size=m - hub_deg)]
    keep = src != dst
    src, dst = src[keep], dst[keep]
    w = rng.uniform(0.1, 1.0, size=len(src)).astype(np.float32)
    return src.astype(np.int64), dst.astype(np.int64), w


def test_dense_ell_blowup_warns_naming_sliced():
    src, dst, w = _hub_stream()
    n = 512
    log = ev.adds(src, dst, w)
    eng = SSSPDelEngine(EngineConfig(
        n, len(src) + 64, 0, relax_backend="ellpack", ell_init_k=1))
    with pytest.warns(RuntimeWarning, match="sliced"):
        eng.ingest_log(log)
    # warned once, not per rebuild
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        eng.ingest_log(ev.query_marker())


def test_auto_backend_falls_back_to_sliced():
    """relax_backend="auto": starts dense-ELL, swaps to the hybrid layout at
    the blowup rebuild, and stays bit-identical to the segment engine."""
    src, dst, w = _hub_stream()
    n = 512
    log = ev.interleave_queries(ev.adds(src, dst, w),
                                max(len(src) // 4, 1))
    ref = SSSPDelEngine(EngineConfig(n, len(src) + 64, 0))
    eng = SSSPDelEngine(EngineConfig(
        n, len(src) + 64, 0, relax_backend="auto", ell_init_k=1))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        _assert_equal(ref.ingest_log(log) + [ref.query()],
                      eng.ingest_log(log) + [eng.query()], tag="auto")
    assert isinstance(eng.backend, SlicedBackend)
    assert eng.backend_name == "sliced"
    # the hybrid layout caps hub rows at hub_k and spills the surplus, so
    # its allocation is far below the dense block the warning fired on
    # (K_dense = next_pow2(2 * hub in-degree) padded across ALL rows)
    pl = eng.backend.planner
    dense_cells = eng.cfg.num_vertices * 256   # what dense ELL allocated
    assert pl.cells + pl.ocap < dense_cells / 8, (
        pl.cells, pl.ocap, dense_cells)


def test_auto_backend_composes_with_buckets():
    src, dst, w = _hub_stream(seed=13)
    n = 512
    log = ev.adds(src, dst, w)
    ref = SSSPDelEngine(EngineConfig(n, len(src) + 64, 0))
    ref.ingest_log(log)
    eng = SSSPDelEngine(EngineConfig(
        n, len(src) + 64, 0, relax_backend="auto", ell_init_k=1,
        wave_schedule="buckets", bucket_width=1.0))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        eng.ingest_log(log)
        eng.drain()
    np.testing.assert_array_equal(ref.query().dist, eng.query().dist)
    np.testing.assert_array_equal(ref.query().parent, eng.query().parent)
    assert eng.backend_name == "sliced"
