"""Graph substrate tests: neighbor sampler, triplet builder, partitioner,
window streams — plus hypothesis property tests on their invariants."""
import numpy as np
import pytest
from repro.testing import given, settings, st  # hypothesis or fallback sampler

from repro.core import events as ev
from repro.graphs import generators as gen
from repro.graphs import partition as part
from repro.graphs import sampler as smp
from repro.graphs import triplets as tri
from repro.graphs import window as win


# ---------------------------------------------------------------- sampler ----

def test_sampler_shapes_and_validity():
    n, src, dst, w = gen.erdos_renyi(200, 2000, seed=0)
    s = smp.NeighborSampler(n, src, dst)
    seeds = np.array([3, 7, 11, 19])
    sub = s.sample(seeds, fanout=(5, 3), seed=1)
    n_cap, e_cap = smp.subgraph_capacity(4, (5, 3))
    assert sub.node_ids.shape == (n_cap,)
    assert sub.src.shape == (e_cap,)
    # every real edge connects valid local slots
    assert (sub.src[sub.edge_mask] < n_cap).all()
    assert (sub.dst[sub.edge_mask] < n_cap).all()
    # seeds are the first B slots
    np.testing.assert_array_equal(sub.node_ids[:4], seeds)
    # sampled edges are real in-edges of the parent graph
    gsrc = sub.node_ids[sub.src[sub.edge_mask]]
    gdst = sub.node_ids[sub.dst[sub.edge_mask]]
    edge_set = set(zip(src.tolist(), dst.tolist()))
    assert all((u, v) in edge_set for u, v in zip(gsrc, gdst))


def test_sampler_zero_degree_nodes():
    src = np.array([0, 1]); dst = np.array([1, 2])
    s = smp.NeighborSampler(4, src, dst)
    sub = s.sample(np.array([0, 3]), fanout=(2,), seed=0)  # 0,3 have no in-nbrs
    assert not sub.edge_mask.any()


def test_build_batch_masks_labels_to_seeds():
    n, src, dst, w = gen.erdos_renyi(50, 300, seed=2)
    s = smp.NeighborSampler(n, src, dst)
    sub = s.sample(np.array([1, 2]), fanout=(3,), seed=0)
    feats = np.random.default_rng(0).normal(size=(n, 4)).astype(np.float32)
    labels = np.arange(n, dtype=np.int64) % 5
    batch = smp.build_batch(sub, feats, labels)
    assert batch["label_mask"].sum() == 2
    assert batch["feats"].shape[1] == 4


# --------------------------------------------------------------- triplets ----

def test_triplets_semantics():
    # path graph 0->1->2->3: triplets (0->1,1->2), (1->2,2->3)
    src = np.array([0, 1, 2]); dst = np.array([1, 2, 3])
    t_kj, t_ji, mask = tri.build_triplets(4, src, dst, budget=8,
                                          per_edge_cap=4)
    real = list(zip(t_kj[mask].tolist(), t_ji[mask].tolist()))
    assert sorted(real) == [(0, 1), (1, 2)]
    # no backtracking: k == i excluded (0->1 then 1->0 would backtrack)
    src2 = np.array([0, 1]); dst2 = np.array([1, 0])
    _, _, m2 = tri.build_triplets(2, src2, dst2, budget=8, per_edge_cap=4)
    assert not m2.any()


def test_triplets_budget_cap():
    n, src, dst, w = gen.erdos_renyi(30, 300, seed=1)
    t_kj, t_ji, mask = tri.build_triplets(n, src, dst, budget=64,
                                          per_edge_cap=4, seed=0)
    assert len(t_kj) == 64
    assert mask.sum() <= 64


# ------------------------------------------------------------- partitioner ----

@given(st.integers(2, 6), st.integers(10, 200))
@settings(max_examples=20, deadline=None)
def test_edge_balanced_partition_covers_everything(parts, m):
    n, src, dst, w = gen.erdos_renyi(37, m, seed=0)
    bounds = part.edge_balanced_ranges(n, dst, parts)
    assert bounds[0] == 0 and bounds[-1] == n
    assert (np.diff(bounds) >= 0).all()
    owner = part.owner_of(np.arange(n), bounds)
    assert (owner >= 0).all() and (owner < parts).all()


# ----------------------------------------------------------------- window ----

@given(st.floats(0.0, 1.0), st.integers(1, 50))
@settings(max_examples=15, deadline=None)
def test_window_stream_invariants(delta, window):
    n, src, dst, w = gen.erdos_renyi(40, 120, seed=3)
    log = win.sliding_window_stream(src, dst, w, window=window, delta=delta,
                                    seed=0)
    # every deletion deletes a previously-added edge, at most once
    seen, deleted = set(), set()
    for k, u, v in zip(log.kind.tolist(), log.src.tolist(), log.dst.tolist()):
        if k == ev.ADD:
            seen.add((u, v))
        elif k == ev.DEL:
            assert (u, v) in seen
            assert (u, v) not in deleted
            deleted.add((u, v))
    if delta == 0.0:
        assert not deleted
