"""Per-architecture smoke tests: REDUCED config, one real train step (+
decode / retrieval where the family has one) on CPU; assert output shapes
and finiteness.  The FULL configs are exercised only via the dry-run."""
import numpy as np
import pytest

from repro.configs import registry as reg
from repro.configs import smoke as smoke_mod

ARCHS = [a for a, m in reg.ARCHES.items() if m.FAMILY != "sssp"]


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke(arch):
    metrics = smoke_mod.smoke(arch, seed=0)
    for k, v in metrics.items():
        arr = np.asarray(v)
        assert np.all(np.isfinite(arr.astype(np.float64))), f"{arch}:{k} = {v}"
    assert "loss" in metrics
    assert float(np.asarray(metrics["loss"])) > 0.0


def test_cells_enumeration():
    cells = reg.all_cells()
    # 5 LM archs x 4 shapes + 4 GNN x 4 + 1 recsys x 4 + sssp x 4
    assert len(cells) == 5 * 4 + 4 * 4 + 4 + 4
    skipped = [c for c in cells if c.skip]
    assert all(c.shape == "long_500k" for c in skipped)
    assert len(skipped) == 5  # every pure full-attention LM arch


def test_param_counts_sane():
    import repro.configs.mistral_large_123b as m
    import repro.configs.olmoe_1b_7b as o
    import repro.configs.qwen3_14b as q
    assert 110e9 < m.CONFIG.param_count() < 135e9
    assert 12e9 < q.CONFIG.param_count() < 16.5e9
    assert 6e9 < o.CONFIG.param_count() < 8e9       # OLMoE total ~6.9B
    assert 0.9e9 < o.CONFIG.active_param_count() < 1.6e9  # ~1.3B active
