"""Per-kernel allclose tests: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property sweeps and custom-VJP
gradient checks.
"""
import numpy as np
import pytest
from repro.testing import given, settings, st  # hypothesis or fallback sampler

import jax
import jax.numpy as jnp

from repro.kernels.embed_bag.embed_bag import embedding_bag
from repro.kernels.embed_bag.ops import bag_lookup
from repro.kernels.embed_bag.ref import embedding_bag_ref
from repro.kernels.relax.ops import relax_wave
from repro.kernels.relax.ref import ellpack_relax_ref
from repro.kernels.relax.relax import ellpack_relax
from repro.kernels.spmm.ops import neighbor_reduce
from repro.kernels.spmm.ref import spmm_ell_ref
from repro.kernels.spmm.spmm import spmm_ell


def _ell_case(n, r, k, seed, frac_pad=0.3):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, n, (r, k)).astype(np.int32)
    w = rng.uniform(0.1, 4.0, (r, k)).astype(np.float32)
    pad = rng.random((r, k)) < frac_pad
    w[pad] = np.inf
    idx[pad] = 0
    dist = rng.uniform(0, 10, n).astype(np.float32)
    dist[rng.random(n) < 0.2] = np.inf
    return jnp.asarray(dist), jnp.asarray(idx), jnp.asarray(w)


# ----------------------------------------------------------------- relax ----
@pytest.mark.parametrize("n,r,k,bm", [
    (64, 64, 8, 32), (256, 256, 16, 64), (128, 512, 4, 128), (512, 256, 128, 256),
])
def test_ellpack_relax_matches_ref(n, r, k, bm):
    dist, idx, w = _ell_case(n, r, k, seed=n + r + k)
    best_k, arg_k = ellpack_relax(dist, idx, w, block_rows=min(bm, r),
                                  interpret=True)
    best_r, arg_r = ellpack_relax_ref(dist, idx, w)
    np.testing.assert_allclose(np.nan_to_num(best_k, posinf=1e30),
                               np.nan_to_num(best_r, posinf=1e30), rtol=1e-6)
    # argmin must agree where finite (ref ties go to smallest k; kernel too)
    fin = np.isfinite(np.asarray(best_r))
    np.testing.assert_array_equal(np.asarray(arg_k)[fin], np.asarray(arg_r)[fin])
    assert (np.asarray(arg_k)[~fin] == -1).all()


@settings(max_examples=20, deadline=None)
@given(n=st.integers(8, 200), r=st.sampled_from([16, 32, 64]),
       k=st.integers(1, 24), seed=st.integers(0, 10_000))
def test_ellpack_relax_property(n, r, k, seed):
    dist, idx, w = _ell_case(n, r, k, seed)
    best_k, arg_k = ellpack_relax(dist, idx, w, block_rows=16, interpret=True)
    best_r, arg_r = ellpack_relax_ref(dist, idx, w)
    np.testing.assert_allclose(np.nan_to_num(best_k, posinf=1e30),
                               np.nan_to_num(best_r, posinf=1e30), rtol=1e-6)


def test_relax_wave_improves_monotonically():
    dist, idx, w = _ell_case(128, 128, 8, seed=7)
    parent = jnp.full((128,), -1, jnp.int32)
    d1, p1, imp1 = relax_wave(dist, parent, idx, w, use_kernel=True)
    assert bool(jnp.all(d1 <= dist))
    d2, p2, imp2 = relax_wave(d1, p1, idx, w, use_kernel=True)
    assert bool(jnp.all(d2 <= d1))


# ------------------------------------------------------------------ spmm ----
@pytest.mark.parametrize("agg", ["sum", "mean", "max"])
@pytest.mark.parametrize("s,r,k,f,dtype", [
    (64, 64, 8, 128, jnp.float32),
    (128, 256, 16, 256, jnp.float32),
    (64, 128, 4, 128, jnp.bfloat16),
])
def test_spmm_ell_matches_ref(agg, s, r, k, f, dtype):
    rng = np.random.default_rng(r + k)
    feats = jnp.asarray(rng.standard_normal((s, f)), dtype)
    idx = jnp.asarray(rng.integers(0, s, (r, k)).astype(np.int32))
    mask = jnp.asarray(rng.random((r, k)) < 0.7)
    out_k = spmm_ell(feats, idx, mask, agg=agg, block_rows=64, block_feat=128,
                     interpret=True)
    out_r = spmm_ell_ref(feats, idx, mask, agg=agg)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)


def test_neighbor_reduce_grad_matches_ref_grad():
    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 32, (48, 6)).astype(np.int32))
    mask = jnp.asarray(rng.random((48, 6)) < 0.8)

    def loss_via(fn):
        return jax.grad(lambda f: jnp.sum(fn(f) ** 2))(feats)

    g_wrapped = loss_via(lambda f: neighbor_reduce(f, idx, mask, "mean", False, True))
    g_ref = loss_via(lambda f: spmm_ell_ref(f, idx, mask, agg="mean"))
    np.testing.assert_allclose(np.asarray(g_wrapped), np.asarray(g_ref), rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(s=st.integers(4, 64), k=st.integers(1, 12), seed=st.integers(0, 9999),
       agg=st.sampled_from(["sum", "mean", "max"]))
def test_spmm_property(s, k, seed, agg):
    rng = np.random.default_rng(seed)
    feats = jnp.asarray(rng.standard_normal((s, 8)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, s, (16, k)).astype(np.int32))
    mask = jnp.asarray(rng.random((16, k)) < 0.5)
    out_k = spmm_ell(feats, idx, mask, agg=agg, block_rows=16, block_feat=8,
                     interpret=True)
    out_r = spmm_ell_ref(feats, idx, mask, agg=agg)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- embed_bag ----
@pytest.mark.parametrize("agg", ["sum", "mean"])
@pytest.mark.parametrize("v,b,l,d,dtype", [
    (128, 16, 8, 128, jnp.float32),
    (1024, 32, 20, 128, jnp.float32),
    (256, 8, 4, 256, jnp.bfloat16),
])
def test_embedding_bag_matches_ref(agg, v, b, l, d, dtype):
    rng = np.random.default_rng(v + b)
    table = jnp.asarray(rng.standard_normal((v, d)), dtype)
    idx = rng.integers(0, v, (b, l)).astype(np.int32)
    idx[rng.random((b, l)) < 0.25] = -1  # padding
    idx = jnp.asarray(idx)
    out_k = embedding_bag(table, idx, agg=agg, block_bags=8, interpret=True)
    out_r = embedding_bag_ref(table, idx, agg=agg)
    tol = 1e-6 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), rtol=tol, atol=tol)


def test_bag_lookup_grad():
    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.standard_normal((64, 16)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 64, (8, 5)).astype(np.int32))

    g1 = jax.grad(lambda t: jnp.sum(bag_lookup(t, idx, "sum", False, True) ** 2))(table)
    g2 = jax.grad(lambda t: jnp.sum(embedding_bag_ref(t, idx, agg="sum") ** 2))(table)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5)


def test_embedding_bag_all_padded_bag_is_zero():
    table = jnp.ones((16, 128), jnp.float32)
    idx = jnp.full((8, 4), -1, jnp.int32)
    out = embedding_bag(table, idx, agg="mean", block_bags=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out), 0.0)
