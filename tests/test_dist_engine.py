"""Sharded dynamic engine: equivalence with the single-device engine across
the partition-count axis AND the relaxation-backend axis (DESIGN.md §5,
§7.2).

P=1 runs inline on the default device (the trivial mesh still goes through
every shard_map code path).  P=8 runs in a subprocess with forced host
devices — and also inline when the test process itself was started with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI step does).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.core import events as ev
from repro.core.dist_engine import ShardedEngineConfig, ShardedSSSPDelEngine
from repro.core.engine import EngineConfig, SSSPDelEngine
from repro.core.oracle import check_tree, edges_of_pool
from repro.graphs import generators, window
from repro.graphs import partition as part_mod
from repro.launch.mesh import _mk

HERE = os.path.dirname(__file__)

# tiny layout knobs so rebuild/spill paths run under sharding too
BACKEND_KW = {
    "segment": {},
    "ellpack": dict(ell_init_k=2),
    "sliced": dict(sliced_slice_rows=8, sliced_hub_k=4, sliced_init_k=1),
}


def _dynamic_stream(seed, *, n=90, m=520, delta=0.6):
    n, src, dst, w = generators.erdos_renyi(n, m, seed=seed)
    log = window.sliding_window_stream(src, dst, w, window=m // 3,
                                       delta=delta, seed=seed,
                                       query_every=m // 2)
    return n, len(src), log, dst


def _assert_results_equal(res_a, res_b):
    assert len(res_a) == len(res_b)
    for i, (a, b) in enumerate(zip(res_a, res_b)):
        np.testing.assert_array_equal(a.dist, b.dist,
                                      err_msg=f"dist mismatch at query {i}")
        np.testing.assert_array_equal(a.parent, b.parent,
                                      err_msg=f"parent mismatch at query {i}")


@pytest.mark.parametrize("use_doubling", [False, True])
@pytest.mark.parametrize("batch_deletions", [False, True])
def test_sharded_matches_single_device(use_doubling, batch_deletions):
    """P=1 mesh: bit-identical (dist, parent) at every query point, and the
    device round/message counters agree (same waves, same improvements)."""
    n, m, log, _ = _dynamic_stream(seed=31 + 2 * use_doubling + batch_deletions)
    source = 3
    ref = SSSPDelEngine(EngineConfig(
        n, m + 64, source, use_doubling=use_doubling,
        batch_deletions=batch_deletions))
    eng = ShardedSSSPDelEngine(ShardedEngineConfig(
        n, m + 64, source, use_doubling=use_doubling,
        batch_deletions=batch_deletions))
    _assert_results_equal(ref.ingest_log(log) + [ref.query()],
                          eng.ingest_log(log) + [eng.query()])
    assert ref.n_rounds == eng.n_rounds
    assert ref.n_messages == eng.n_messages
    assert ref.n_epochs == eng.n_epochs
    assert ref.n_adds == eng.n_adds and ref.n_dels == eng.n_dels


@pytest.mark.parametrize("backend", ["ellpack", "sliced"])
def test_sharded_backend_matches_single_device_backend(backend):
    """Backend axis at P=1: the sharded engine with a layout backend is
    bit-identical — results AND stats — to the single-device engine running
    the same backend (and transitively to every other backend)."""
    n, m, log, _ = _dynamic_stream(seed=37)
    source = 3
    kw = BACKEND_KW[backend]
    ref = SSSPDelEngine(EngineConfig(
        n, m + 64, source, relax_backend=backend, **kw))
    eng = ShardedSSSPDelEngine(ShardedEngineConfig(
        n, m + 64, source, relax_backend=backend, **kw))
    _assert_results_equal(ref.ingest_log(log) + [ref.query()],
                          eng.ingest_log(log) + [eng.query()])
    assert ref.n_rounds == eng.n_rounds
    assert ref.n_messages == eng.n_messages
    # the coupled rebuild path must actually run under sharding
    assert sum(pl.rebuilds for pl in eng.bk.planners) >= 1


def test_sharded_delta_exchange_matches_single_device():
    """The delta exchange (tiny cap -> overflow fallbacks exercised) reaches
    the same (dist, parent) as the single-device engine on a mixed stream."""
    n, m, log, _ = _dynamic_stream(seed=7)
    ref = SSSPDelEngine(EngineConfig(n, m + 64, 3))
    eng = ShardedSSSPDelEngine(ShardedEngineConfig(
        n, m + 64, 3, exchange="delta", delta_cap=8))
    _assert_results_equal(ref.ingest_log(log) + [ref.query()],
                          eng.ingest_log(log) + [eng.query()])


def test_sharded_delta_exchange_with_sliced_backend():
    """Exchange strategy and relaxation backend compose: the delta exchange
    assembles the offers, the sliced wave reduces them — same fixpoint."""
    n, m, log, _ = _dynamic_stream(seed=7)
    ref = SSSPDelEngine(EngineConfig(n, m + 64, 3))
    eng = ShardedSSSPDelEngine(ShardedEngineConfig(
        n, m + 64, 3, exchange="delta", delta_cap=8,
        relax_backend="sliced", **BACKEND_KW["sliced"]))
    _assert_results_equal(ref.ingest_log(log) + [ref.query()],
                          eng.ingest_log(log) + [eng.query()])


def test_sharded_min_duplicate_policy():
    n = 8
    res = {}
    for name, cls, cfg in (
            ("single", SSSPDelEngine, EngineConfig(n, 32, 0, on_duplicate="min")),
            ("sharded", ShardedSSSPDelEngine,
             ShardedEngineConfig(n, 32, 0, on_duplicate="min")),
            ("sharded-ell", ShardedSSSPDelEngine,
             ShardedEngineConfig(n, 32, 0, on_duplicate="min",
                                 relax_backend="ellpack", ell_init_k=2))):
        eng = cls(cfg)
        eng.ingest_log(ev.adds([0, 1, 0, 0], [1, 2, 2, 1],
                               [4.0, 1.0, 9.0, 2.0]))
        eng.ingest_log(ev.adds([0], [1], [1.0]))   # decrease 0->1 to 1.0
        eng.ingest_log(ev.adds([0], [2], [20.0]))  # increase is dropped
        res[name] = eng.query()
    _assert_results_equal([res["single"]], [res["sharded"]])
    _assert_results_equal([res["single"]], [res["sharded-ell"]])
    assert res["single"].dist[2] == pytest.approx(2.0)


@pytest.mark.parametrize("backend", ["segment", "ellpack", "sliced"])
def test_sharded_ingest_never_reads_device_values(backend, monkeypatch):
    """DESIGN.md §2.4 for the sharded loop, per backend: no device->host
    readback between QUERY markers — layout patches, coupled rebuilds and
    epochs all run on host mirrors + device scalars until query()."""
    n, m, log, _ = _dynamic_stream(seed=13)
    eng = ShardedSSSPDelEngine(ShardedEngineConfig(
        n, m + 64, 0, relax_backend=backend, **BACKEND_KW[backend]))
    topo = log[np.asarray(log.kind) != ev.QUERY]

    def trap(*a, **k):
        raise AssertionError("device_get during ingest (host sync)")

    monkeypatch.setattr(jax, "device_get", trap)
    eng.ingest_log(topo)  # only ADD/DEL runs: must not sync
    monkeypatch.undo()
    q = eng.query()
    e_src, e_dst, e_w = [], [], []
    for p, a in enumerate(eng.allocs):
        s, d, w_ = a.active_coo()
        e_src.append(s); e_dst.append(d); e_w.append(w_)
    check_tree(n, np.concatenate(e_src), np.concatenate(e_dst),
               np.concatenate(e_w), 0, q.dist, q.parent)


@pytest.mark.parametrize("backend", ["segment", "ellpack", "sliced"])
def test_sharded_checkpoint_restore_roundtrip(backend):
    """Crash-restart at P=1: checkpoint mid-stream, restore into a FRESH
    engine (fresh per-partition planners; backend layout rebuilt from the
    pool mirrors, not serialized), continue — bit-identical to the
    uninterrupted run.  The P=8 variant runs in the subprocess worker."""
    n, m, log, _ = _dynamic_stream(seed=19)
    kw = BACKEND_KW[backend]

    def mk():
        return ShardedSSSPDelEngine(ShardedEngineConfig(
            n, m + 64, 3, relax_backend=backend, **kw))

    eng = mk()
    half = len(log) // 2
    eng.ingest_log(log[:half])
    ckpt = eng.checkpoint()
    eng.ingest_log(log[half:])
    want = eng.query()

    eng2 = mk()
    eng2.restore(ckpt)
    eng2.ingest_log(log[half:])
    got = eng2.query()
    np.testing.assert_array_equal(want.dist, got.dist)
    np.testing.assert_array_equal(want.parent, got.parent)
    assert eng.partition_fill().tolist() == eng2.partition_fill().tolist()


def test_sharded_edge_balanced_relabeling():
    """Edge-balanced placement via the relabeling permutation: identical
    distances (same paths, same float sums), valid tree, and the planner
    pools actually carry the relabeled in-edge mass."""
    n, m, log, dst_ref = _dynamic_stream(seed=17)
    source = 3
    # the relabeling must target the engine's partition count (default mesh
    # flattens every local device)
    relabel = part_mod.edge_balanced_relabeling(n, dst_ref, len(jax.devices()))
    ref = SSSPDelEngine(EngineConfig(n, m + 64, source))
    eng = ShardedSSSPDelEngine(ShardedEngineConfig(n, m + 64, source),
                               relabel=relabel)
    # a relabeling built for the wrong partition count must be rejected
    wrong = part_mod.edge_balanced_relabeling(n, dst_ref,
                                              2 * len(jax.devices()))
    with pytest.raises(AssertionError, match="partitions"):
        ShardedSSSPDelEngine(ShardedEngineConfig(n, m + 64, source),
                             relabel=wrong)
    res_ref = ref.ingest_log(log) + [ref.query()]
    res_eng = eng.ingest_log(log) + [eng.query()]
    for a, b in zip(res_ref, res_eng):
        np.testing.assert_array_equal(a.dist, b.dist)
    e = ref.state.edges
    es, ed, ew = edges_of_pool(e.src, e.dst, e.w, e.active)
    check_tree(n, es, ed, ew, source, res_eng[-1].dist, res_eng[-1].parent)
    assert eng.partition_fill().sum() == len(es)


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 devices (CI runs this module with "
                           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
@pytest.mark.parametrize("exchange,backend", [
    ("allgather", "segment"), ("allgather", "ellpack"),
    ("allgather", "sliced"), ("delta", "segment"), ("delta", "sliced")])
def test_sharded_p8_inprocess(exchange, backend):
    """P=8 on a (2,2,2) mesh, in-process (active under the CI 8-device
    step), across the backend axis."""
    mesh = _mk((2, 2, 2), ("pod", "data", "model"))
    n, m, log, _ = _dynamic_stream(seed=29, n=120, m=700)
    kw = BACKEND_KW[backend]
    ref = SSSPDelEngine(EngineConfig(n, m + 64, 5, relax_backend=backend,
                                     **kw))
    eng = ShardedSSSPDelEngine(
        ShardedEngineConfig(n, m + 64, 5, exchange=exchange, delta_cap=16,
                            relax_backend=backend, **kw),
        mesh=mesh)
    assert eng.P == 8
    _assert_results_equal(ref.ingest_log(log) + [ref.query()],
                          eng.ingest_log(log) + [eng.query()])
    if exchange == "allgather":
        assert ref.n_rounds == eng.n_rounds
        assert ref.n_messages == eng.n_messages


@pytest.mark.parametrize("exchange,batched,doubling,backend,extra", [
    ("allgather", 0, 1, "segment", []),
    ("allgather", 1, 0, "segment", []),
    ("delta", 0, 1, "segment", []),
    ("allgather", 0, 1, "ellpack", []),
    ("allgather", 0, 1, "sliced", []),
    ("allgather", 0, 1, "sliced", ["--ckpt"]),
    ("allgather", 0, 1, "segment", ["--buckets"]),
    ("delta", 0, 1, "sliced", ["--buckets"]),
    ("allgather", 0, 1, "segment", ["--sparse"]),
    ("delta", 0, 1, "sliced", ["--sparse"]),
])
def test_sharded_p8_subprocess(exchange, batched, doubling, backend, extra):
    """Full equivalence contract at P=8 forced host devices (subprocess —
    XLA device count must be set before jax initialises), across the
    backend axis, including the crash-restart checkpoint roundtrip."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    out = subprocess.run(
        [sys.executable, os.path.join(HERE, "_dist_engine_worker.py"),
         exchange, str(batched), str(doubling), backend] + extra,
        env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert out.stdout.strip().startswith("OK"), out.stdout
