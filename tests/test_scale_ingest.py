"""Paper-scale ingest stack (DESIGN.md §11): columnar control plane at the
engine level, chunked trace replay, the real-dataset loader, the
``make_engine`` factory, and the stable ``repro`` public surface.

Allocator-level bit-identity is pinned in tests/test_ingest.py; here the
pin is end-to-end: a full dynamic stream through engines that differ ONLY
in ``alloc_impl`` must produce identical (dist, parent) at every query and
identical device counters — across relaxation backends and under sharding.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

import repro
from repro.core import events as ev
from repro.graphs import datasets as ds
from repro.graphs import generators, window
from repro.launch.mesh import _mk
from repro.serving.replay import replay_trace
from repro.serving.trace import ServingTrace, TraceFormatError

HERE = os.path.dirname(__file__)

BACKEND_KW = {
    "segment": {},
    "ellpack": dict(ell_init_k=2),
    "sliced": dict(sliced_slice_rows=8, sliced_hub_k=4, sliced_init_k=1),
}


def _dynamic_stream(seed, *, n=90, m=520, delta=0.6):
    n, src, dst, w = generators.erdos_renyi(n, m, seed=seed)
    log = window.sliding_window_stream(src, dst, w, window=m // 3,
                                       delta=delta, seed=seed,
                                       query_every=m // 2)
    return n, len(src), log


def _assert_results_equal(res_a, res_b):
    assert len(res_a) == len(res_b)
    for i, (a, b) in enumerate(zip(res_a, res_b)):
        np.testing.assert_array_equal(a.dist, b.dist,
                                      err_msg=f"dist mismatch at query {i}")
        np.testing.assert_array_equal(a.parent, b.parent,
                                      err_msg=f"parent mismatch at query {i}")


# ----------------------------- engine-level columnar == dict bit-identity --
@pytest.mark.parametrize("backend", ["segment", "ellpack", "sliced"])
def test_engine_columnar_matches_dict_single(backend):
    n, m, log = _dynamic_stream(seed=41)
    kw = BACKEND_KW[backend]
    res = {}
    for impl in ("dict", "columnar"):
        eng = repro.make_engine(num_vertices=n, edge_capacity=m + 64,
                                source=3, relax_backend=backend,
                                alloc_impl=impl, **kw)
        res[impl] = eng.ingest_log(log) + [eng.query()]
        res[impl + "_stats"] = (eng.n_rounds, eng.n_messages, eng.n_epochs,
                                eng.n_adds, eng.n_dels)
    _assert_results_equal(res["dict"], res["columnar"])
    assert res["dict_stats"] == res["columnar_stats"]


@pytest.mark.parametrize("backend", ["segment", "ellpack", "sliced"])
def test_engine_columnar_matches_dict_sharded_p1(backend):
    n, m, log = _dynamic_stream(seed=43)
    kw = BACKEND_KW[backend]
    res = {}
    for impl in ("dict", "columnar"):
        eng = repro.make_engine(num_vertices=n, edge_capacity=m + 64,
                                source=3, partitions=1,
                                relax_backend=backend, alloc_impl=impl, **kw)
        res[impl] = eng.ingest_log(log) + [eng.query()]
        res[impl + "_stats"] = (eng.n_rounds, eng.n_messages, eng.n_epochs)
    _assert_results_equal(res["dict"], res["columnar"])
    assert res["dict_stats"] == res["columnar_stats"]


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 devices (CI runs this module with "
                           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
@pytest.mark.parametrize("backend", ["segment", "ellpack", "sliced"])
def test_engine_columnar_matches_dict_sharded_p8(backend):
    n, m, log = _dynamic_stream(seed=47, n=120, m=700)
    kw = BACKEND_KW[backend]
    res = {}
    for impl in ("dict", "columnar"):
        eng = repro.make_engine(num_vertices=n, edge_capacity=m + 64,
                                source=5, partitions=8,
                                relax_backend=backend, alloc_impl=impl, **kw)
        assert eng.P == 8
        res[impl] = eng.ingest_log(log) + [eng.query()]
    _assert_results_equal(res["dict"], res["columnar"])


def test_engine_checkpoint_restore_preserves_alloc_impl():
    """restore() must rebuild the SAME control plane the config names —
    and the restored columnar engine stays bit-identical to dict."""
    n, m, log = _dynamic_stream(seed=53)
    half = len(log) // 2
    res = {}
    for impl in ("dict", "columnar"):
        eng = repro.make_engine(num_vertices=n, edge_capacity=m + 64,
                                source=3, alloc_impl=impl)
        eng.ingest_log(log[:half])
        ckpt = eng.checkpoint()
        eng2 = repro.make_engine(num_vertices=n, edge_capacity=m + 64,
                                 source=3, alloc_impl=impl)
        eng2.restore(ckpt)
        assert type(eng2.alloc).__name__ == type(eng.alloc).__name__
        res[impl] = eng2.ingest_log(log[half:]) + [eng2.query()]
    _assert_results_equal(res["dict"], res["columnar"])


# --------------------------------------------------- chunked trace + replay --
def _small_trace(seed=11):
    n, m, log = _dynamic_stream(seed=seed)
    return n, m, ServingTrace.from_log(log, events_per_s=1e5)


def test_chunked_save_load_equals_monolithic(tmp_path):
    n, m, trace = _small_trace()
    p1 = str(tmp_path / "v1.npz")
    p2 = str(tmp_path / "v2.npz")
    trace.save(p1)                      # version-1 monolithic
    trace.save(p2, chunk_events=64)     # version-2 chunked
    t1 = ServingTrace.load(p1)
    t2 = ServingTrace.load(p2)
    for col in ("kind", "src", "dst", "w", "t"):
        np.testing.assert_array_equal(getattr(t1, col), getattr(t2, col))


def test_trace_reader_chunks_are_bounded(tmp_path):
    n, m, trace = _small_trace()
    p = str(tmp_path / "t.npz")
    trace.save(p, chunk_events=100)
    with repro.open_trace(p) as r:
        assert r.n_chunks == -(-len(trace.kind) // 100)
        sizes = [len(c.kind) for c in r.chunks()]
    assert all(s <= 100 for s in sizes)
    assert sum(sizes) == len(trace.kind)


def test_trace_reader_on_v1_yields_single_chunk(tmp_path):
    n, m, trace = _small_trace()
    p = str(tmp_path / "t.npz")
    trace.save(p)
    with repro.open_trace(p) as r:
        assert r.n_chunks == 1
        (chunk,) = list(r.chunks())
    np.testing.assert_array_equal(chunk.kind, trace.kind)


def test_chunked_replay_matches_monolithic(tmp_path):
    """Streaming the trace chunk-by-chunk through replay_trace converges to
    the same tree as one monolithic pass (final dist/parent bit-identical;
    event counts equal)."""
    n, m, trace = _small_trace(seed=23)
    p = str(tmp_path / "t.npz")
    trace.save(p, chunk_events=77)

    def run(source_trace):
        eng = repro.make_engine(num_vertices=n, edge_capacity=m + 64,
                                source=3)
        rep = replay_trace(eng, source_trace)
        return eng.query(), rep

    q_mono, rep_mono = run(trace)
    with repro.open_trace(p) as r:
        q_chunk, rep_chunk = run(r)
    np.testing.assert_array_equal(q_mono.dist, q_chunk.dist)
    np.testing.assert_array_equal(q_mono.parent, q_chunk.parent)
    assert rep_mono.events == rep_chunk.events
    assert rep_mono.topology_events == rep_chunk.topology_events


def test_ingest_log_accepts_chunk_iterable():
    n, m, log = _dynamic_stream(seed=29)
    mono = repro.make_engine(num_vertices=n, edge_capacity=m + 64, source=3)
    chunked = repro.make_engine(num_vertices=n, edge_capacity=m + 64,
                                source=3)
    res_mono = mono.ingest_log(log) + [mono.query()]

    def gen():
        step = 97
        for i in range(0, len(log), step):
            yield log[i:i + step]

    res_chunk = chunked.ingest_log(gen()) + [chunked.query()]
    _assert_results_equal(res_mono, res_chunk)


def test_iter_chunks_validates_chunk_size():
    _, _, trace = _small_trace()
    with pytest.raises(ValueError):
        list(trace.iter_chunks(0))


def test_open_trace_rejects_garbage(tmp_path):
    p = tmp_path / "bad.npz"
    np.savez(p, foo=np.arange(3))
    with pytest.raises(TraceFormatError):
        repro.open_trace(str(p))


# ------------------------------------------------------------ dataset loader --
SNAP = """\
# Directed graph (each unordered pair of nodes is saved once)
# FromNodeId\tToNodeId
0\t1
0\t2
17\t0
2\t17
"""

KONECT = """\
% sym positive
% 4 3 3
1 2 0.5
2 3 1.25
3 1 2.0
"""


def test_parse_snap_unweighted_synthesizes_weights(tmp_path):
    p = tmp_path / "snap.txt"
    p.write_text(SNAP)
    src, dst, w = ds.parse_edge_list(str(p), weight_seed=7)
    assert src.tolist() == [0, 0, 17, 2]
    assert dst.tolist() == [1, 2, 0, 17]
    assert (w >= 0.5).all() and (w < 1.5).all()
    # deterministic synthesis: same seed, same weights
    _, _, w2 = ds.parse_edge_list(str(p), weight_seed=7)
    np.testing.assert_array_equal(w, w2)


def test_parse_konect_weighted(tmp_path):
    p = tmp_path / "konect.tsv"
    p.write_text(KONECT)
    src, dst, w = ds.parse_edge_list(str(p))
    assert src.tolist() == [1, 2, 3]
    np.testing.assert_allclose(w, [0.5, 1.25, 2.0])


def test_compact_ids_is_dense_and_deterministic(tmp_path):
    p = tmp_path / "snap.txt"
    p.write_text(SNAP)
    src, dst, _ = ds.parse_edge_list(str(p))
    n, cs, cd = ds.compact_ids(src, dst)
    assert n == 4
    assert set(np.concatenate([cs, cd]).tolist()) == {0, 1, 2, 3}
    # sorted-unique relabel: original order preserved
    assert cs.tolist() == [0, 0, 3, 2]  # {0,1,2,17} -> {0,1,2,3} sorted


def test_malformed_rows_raise_format_error(tmp_path):
    p = tmp_path / "bad.txt"
    p.write_text("0 1\njunk\n")
    with pytest.raises(ds.DatasetFormatError):
        ds.parse_edge_list(str(p))
    p2 = tmp_path / "neg.txt"
    p2.write_text("0 -4\n")
    with pytest.raises(ds.DatasetFormatError):
        ds.parse_edge_list(str(p2))


def test_loader_cli_writes_chunked_trace(tmp_path, capsys):
    src_p = tmp_path / "snap.txt"
    src_p.write_text(SNAP)
    out_p = tmp_path / "out.npz"
    rc = ds.main([str(src_p), str(out_p), "--chunk-events", "2",
                  "--query-every", "2"])
    assert rc == 0
    with repro.open_trace(str(out_p)) as r:
        assert r.n_chunks >= 2
        total = sum(len(c.kind) for c in r.chunks())
    assert total > 0
    assert "n=4" in capsys.readouterr().out


def test_loader_exits_2_on_missing_and_malformed(tmp_path):
    with pytest.raises(SystemExit) as e:
        ds.load_dataset_or_exit(str(tmp_path / "nope.txt"))
    assert e.value.code == 2
    p = tmp_path / "bad.txt"
    p.write_text("not numbers at all\n")
    with pytest.raises(SystemExit) as e:
        ds.load_dataset_or_exit(str(p))
    assert e.value.code == 2


def test_dataset_to_trace_replays_to_oracle(tmp_path):
    p = tmp_path / "snap.txt"
    p.write_text(SNAP)
    n, trace = ds.dataset_to_trace(str(p), window_frac=1.0, delta=0.0,
                                   query_every=2)
    eng = repro.make_engine(num_vertices=n, edge_capacity=32, source=0)
    replay_trace(eng, trace)
    q = eng.query()
    from repro.core.oracle import check_tree
    s, d, w = eng.alloc.active_coo()
    check_tree(n, s, d, w, 0, q.dist, q.parent)


# ------------------------------------------------- factory + public surface --
def test_make_engine_selects_single_vs_sharded():
    single = repro.make_engine(num_vertices=8, edge_capacity=32, source=0)
    assert type(single).__name__ == "SSSPDelEngine"
    sharded = repro.make_engine(num_vertices=8, edge_capacity=32, source=0,
                                partitions=1)
    assert type(sharded).__name__ == "ShardedSSSPDelEngine"
    assert sharded.cfg.edges_per_part == 32  # total budget / P


def test_make_engine_splits_edge_budget_across_partitions():
    if len(jax.devices()) < 2:
        pytest.skip("needs >=2 devices")
    eng = repro.make_engine(num_vertices=8, edge_capacity=33, source=0,
                            partitions=2)
    assert eng.cfg.edges_per_part == 17  # ceil(33 / 2)


def test_make_engine_unknown_knob_lists_valid_ones():
    with pytest.raises(ValueError) as e:
        repro.make_engine(num_vertices=8, edge_capacity=32, source=0,
                          wave_schdule="buckets")  # typo on purpose
    msg = str(e.value)
    assert "wave_schdule" in msg and "wave_schedule" in msg


def test_make_engine_sharded_knob_validation():
    with pytest.raises(ValueError) as e:
        repro.make_engine(num_vertices=8, edge_capacity=32, source=0,
                          partitions=1, no_such_knob=1)
    assert "no_such_knob" in str(e.value) and "exchange" in str(e.value)


def test_make_engine_relabel_requires_sharding():
    with pytest.raises(ValueError, match="relabel"):
        repro.make_engine(num_vertices=8, edge_capacity=32, source=0,
                          relabel=np.arange(8))


def test_make_engine_rejects_too_many_partitions():
    with pytest.raises(ValueError, match="partitions"):
        repro.make_engine(num_vertices=8, edge_capacity=32, source=0,
                          partitions=len(jax.devices()) + 1)


def test_make_engine_mesh_partitions_must_agree():
    mesh = _mk((1,), ("graph",))
    with pytest.raises(ValueError):
        repro.make_engine(num_vertices=8, edge_capacity=32, source=0,
                          mesh=mesh, partitions=2)


def test_public_surface_import_smoke():
    """Every name in repro.__all__ resolves, and dir() advertises it.
    (PEP 562: resolution is lazy, so this is the import-cycle canary.)"""
    for name in repro.__all__:
        assert getattr(repro, name) is not None, name
    assert set(repro.__all__) <= set(dir(repro))
    with pytest.raises(AttributeError):
        repro.no_such_symbol


# ------------------------------------------------------------- slow RSS smoke --
@pytest.mark.skipif(not os.environ.get("RUN_SLOW"),
                    reason="1M-edge RSS smoke (~2 min); set RUN_SLOW=1")
def test_scale_worker_1m_rss_budget():
    """Marked-slow paper-scale smoke: 1M-vertex / 10M-event ingest in a
    fresh process stays under the documented RSS budget
    (benchmarks/scale_worker.py module docstring)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.scale_worker",
         "--n", str(1 << 20), "--e", str(10 * (1 << 20))],
        env=env, capture_output=True, text=True, timeout=900,
        cwd=os.path.join(HERE, ".."))
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["rss_ok"], rec
    assert rec["peak_rss_mb"] <= rec["rss_budget_mb"], rec
