"""Fused sliced-ELL + overflow wave kernel (kernels/relax/fused.py,
DESIGN.md §9.4), interpret mode: the single fused pallas_call must be
bit-identical to the unfused three-dispatch composition
``combine_lanes(sliced_gather_min, overflow_min)`` on any layout — ragged
last run groups, empty or zero-capacity overflow lanes, pervasive weight
ties (the smallest-src-id rule across BOTH lanes), and arbitrary
bucket/frontier row masks — plus the roofline sanity check of the kernel's
flop/byte model against the compiled HLO (roofline/hlo_analysis.py).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.backends.sliced import (combine_lanes, overflow_min,
                                        sliced_gather_min)
from repro.kernels.relax.config import default_interpret, resolve_interpret
from repro.kernels.relax.fused import (fused_cost, fused_sliced_relax,
                                       slice_run_groups)
from repro.roofline import hlo_analysis as H

INF = np.float32(np.inf)


def _random_layout(widths, slice_rows, n, ocap, seed, *, tie_weights=False,
                   fill_frac=0.6, overflow_frac=0.7):
    """Random flat sliced-ELL buffer + overflow segment over n vertices.
    Empty cells/entries carry w=+inf (never win); live entries point at
    random in-neighbors."""
    rng = np.random.default_rng(seed)
    L = slice_rows * int(np.dot(widths, np.ones_like(widths)))
    L = slice_rows * sum(widths)
    flat_idx = rng.integers(0, n, size=L).astype(np.int32)
    wpool = ([0.5, 1.0] if tie_weights
             else rng.uniform(0.1, 2.0, size=8).tolist())
    flat_w = rng.choice(np.asarray(wpool, np.float32), size=L)
    flat_w = np.where(rng.random(L) < fill_frac, flat_w, INF).astype(
        np.float32)
    osrc = rng.integers(0, n, size=ocap).astype(np.int32)
    odst = rng.integers(0, n, size=ocap).astype(np.int32)
    ow = rng.choice(np.asarray(wpool, np.float32), size=ocap)
    ow = np.where(rng.random(ocap) < overflow_frac, ow, INF).astype(
        np.float32)
    dist = np.where(rng.random(n) < 0.8,
                    rng.uniform(0.0, 4.0, size=n), INF).astype(np.float32)
    return (jnp.asarray(flat_idx), jnp.asarray(flat_w), jnp.asarray(osrc),
            jnp.asarray(odst), jnp.asarray(ow), jnp.asarray(dist))


def _ref(offers, flat_idx, flat_w, osrc, odst, ow, widths, slice_rows, n):
    best, arg = sliced_gather_min(offers, flat_idx, flat_w,
                                  widths=widths, slice_rows=slice_rows)
    R = len(widths) * slice_rows
    obest, oarg = overflow_min(offers, osrc, odst, ow, R)
    return combine_lanes(best, arg, obest, oarg)


CASES = [
    # uniform small run (single remainder group)
    ((2, 2, 2), 8, 20, 8, False),
    # ragged: 40 equal-width slices at slice_rows=8 split into a 256-row
    # main block plus a 64-row remainder
    ((2,) * 40, 8, 300, 16, False),
    # mixed widths: several runs, each its own tile shape
    ((1, 1, 4, 4, 4, 2, 8), 16, 100, 8, False),
    # pervasive ties across both lanes
    ((2, 2, 4, 4), 16, 60, 32, True),
]


@pytest.mark.parametrize("widths,slice_rows,n,ocap,ties", CASES)
def test_fused_matches_unfused_composition(widths, slice_rows, n, ocap, ties):
    flat_idx, flat_w, osrc, odst, ow, dist = _random_layout(
        widths, slice_rows, n, ocap, seed=hash((widths, ocap)) % 1000,
        tie_weights=ties)
    act = jnp.ones(n, jnp.bool_)
    want_b, want_a = _ref(dist, flat_idx, flat_w, osrc, odst, ow,
                          widths, slice_rows, n)
    got_b, got_a = fused_sliced_relax(
        dist, act, flat_idx, flat_w, osrc, odst, ow,
        widths=widths, slice_rows=slice_rows, interpret=True)
    np.testing.assert_array_equal(np.asarray(want_b), np.asarray(got_b))
    np.testing.assert_array_equal(np.asarray(want_a), np.asarray(got_a))


@pytest.mark.parametrize("widths,slice_rows,n,ocap,ties", CASES[:2])
def test_fused_bucket_mask_fuses_offer_masking(widths, slice_rows, n, ocap,
                                               ties):
    """The in-kernel ``where(active, dist, inf)`` must equal pre-masked
    offers fed to the unfused path — random masks, including all-False."""
    flat_idx, flat_w, osrc, odst, ow, dist = _random_layout(
        widths, slice_rows, n, ocap, seed=7, tie_weights=ties)
    rng = np.random.default_rng(11)
    for mask in (rng.random(n) < 0.5, np.zeros(n, bool), np.ones(n, bool)):
        act = jnp.asarray(mask)
        offers = jnp.where(act, dist, jnp.float32(np.inf))
        want_b, want_a = _ref(offers, flat_idx, flat_w, osrc, odst, ow,
                              widths, slice_rows, n)
        got_b, got_a = fused_sliced_relax(
            dist, act, flat_idx, flat_w, osrc, odst, ow,
            widths=widths, slice_rows=slice_rows, interpret=True)
        np.testing.assert_array_equal(np.asarray(want_b), np.asarray(got_b))
        np.testing.assert_array_equal(np.asarray(want_a), np.asarray(got_a))


def test_fused_empty_and_zero_capacity_overflow():
    """An all-tombstoned overflow lane contributes nothing; a ZERO-capacity
    lane (static shape 0) must not break the kernel's uniform signature."""
    widths, slice_rows, n = (2, 4), 8, 14
    flat_idx, flat_w, osrc, odst, ow, dist = _random_layout(
        widths, slice_rows, n, 8, seed=3)
    act = jnp.ones(n, jnp.bool_)
    dead = jnp.full_like(ow, np.inf)
    want_b, want_a = _ref(dist, flat_idx, flat_w, osrc, odst, dead,
                          widths, slice_rows, n)
    got_b, got_a = fused_sliced_relax(
        dist, act, flat_idx, flat_w, osrc, odst, dead,
        widths=widths, slice_rows=slice_rows, interpret=True)
    np.testing.assert_array_equal(np.asarray(want_b), np.asarray(got_b))
    np.testing.assert_array_equal(np.asarray(want_a), np.asarray(got_a))
    z_b, z_a = fused_sliced_relax(
        dist, act, flat_idx, flat_w,
        jnp.zeros(0, jnp.int32), jnp.zeros(0, jnp.int32),
        jnp.zeros(0, jnp.float32),
        widths=widths, slice_rows=slice_rows, interpret=True)
    np.testing.assert_array_equal(np.asarray(want_b), np.asarray(z_b))
    np.testing.assert_array_equal(np.asarray(want_a), np.asarray(z_a))


def test_fused_overflow_lane_wins_and_ties_against_ell():
    """Hand-built case: the overflow lane holds the min for one row, ties
    the ELL lane on another — the tie must break to the smaller src id
    ACROSS lanes, exactly like combine_lanes."""
    widths, slice_rows, n = (2,), 8, 8
    dist = jnp.asarray(np.zeros(n, np.float32))
    flat_idx = np.zeros(16, np.int32)
    flat_w = np.full(16, INF, np.float32)
    # row 1 via ELL: offer from src 5, w=1.0
    flat_idx[2], flat_w[2] = 5, 1.0
    # row 2 via ELL: offer from src 6, w=2.0
    flat_idx[4], flat_w[4] = 6, 2.0
    osrc = np.asarray([7, 3], np.int32)
    odst = np.asarray([1, 2], np.int32)
    ow = np.asarray([0.5, 2.0], np.float32)   # row1: coo wins; row2: tie
    act = jnp.ones(n, jnp.bool_)
    b, a = fused_sliced_relax(
        dist, act, jnp.asarray(flat_idx), jnp.asarray(flat_w),
        jnp.asarray(osrc), jnp.asarray(odst), jnp.asarray(ow),
        widths=widths, slice_rows=slice_rows, interpret=True)
    b, a = np.asarray(b), np.asarray(a)
    assert b[1] == np.float32(0.5) and a[1] == 7     # overflow strictly wins
    assert b[2] == np.float32(2.0) and a[2] == 3     # tie -> smaller src id

    want_b, want_a = _ref(dist, jnp.asarray(flat_idx), jnp.asarray(flat_w),
                          jnp.asarray(osrc), jnp.asarray(odst),
                          jnp.asarray(ow), widths, slice_rows, n)
    np.testing.assert_array_equal(np.asarray(want_b), b)
    np.testing.assert_array_equal(np.asarray(want_a), a)


def test_slice_run_groups_tiling_rules():
    """Run grouping: equal-width runs merge, split at multiples of 256 rows,
    and every group's row count divides by min(256, rows) (the pallas grid
    divisibility requirement)."""
    for widths, sr in [((2,) * 40, 8), ((1, 1, 4, 4, 4, 2, 8), 16),
                       ((4,), 512), ((2, 2), 256)]:
        groups = slice_run_groups(widths, sr)
        assert sum(c for _, c in groups) == len(widths)
        ks = [k for k, _ in groups]
        for (k1, c1), (k2, c2) in zip(groups, groups[1:]):
            if k1 == k2:   # a split run: first part must be the main block
                assert (sr * c1) % 256 == 0
        for k, cnt in groups:
            rows_g = sr * cnt
            assert rows_g % min(256, rows_g) == 0
        assert ks == [k for k, _ in groups]
    # all-settled-on-one-width, run length a multiple of the 256-row block:
    # ONE dense group, no remainder
    groups = slice_run_groups((4,) * 64, 8)
    assert groups == [(4, 64)]
    # ...and with a ragged tail: main block + sub-256-row remainder
    groups = slice_run_groups((4,) * 40, 8)
    assert groups == [(4, 32), (4, 8)]


def test_interpret_default_is_unified():
    """Satellite fix: both kernel entry points resolve the SAME platform
    default — interpret everywhere except TPU (kernels/relax/config.py)."""
    on_tpu = jax.default_backend() == "tpu"
    assert default_interpret() == (not on_tpu)
    assert resolve_interpret(None) == (not on_tpu)
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False


def test_fused_roofline_model_matches_compiled_hlo():
    """Flop/byte validation (ISSUE acceptance): the analytic model the
    pallas_call's CostEstimate claims must agree with the compiled
    interpret-mode HLO within an order of magnitude, and the kernel must
    sit in the memory-bound regime (low arithmetic intensity)."""
    widths, slice_rows, n, ocap = (2,) * 40, 8, 300, 16
    flat_idx, flat_w, osrc, odst, ow, dist = _random_layout(
        widths, slice_rows, n, ocap, seed=5)
    act = jnp.ones(n, jnp.bool_)

    @jax.jit
    def wave(dist, act, flat_idx, flat_w, osrc, odst, ow):
        return fused_sliced_relax(
            dist, act, flat_idx, flat_w, osrc, odst, ow,
            widths=widths, slice_rows=slice_rows, interpret=True)

    comp = wave.lower(dist, act, flat_idx, flat_w, osrc, odst, ow).compile()
    cost = H.analyze_text(comp.as_text())
    model = fused_cost(widths, slice_rows, n, ocap)
    assert cost.flops > 0 and cost.hbm_bytes > 0
    # interpret mode emulates the kernel with real jax ops, so the walker
    # sees the true arithmetic; band is loose (gathers don't count flops,
    # XLA fuses the byte traffic)
    assert model["flops"] / 20 <= cost.flops <= model["flops"] * 20, (
        cost.flops, model)
    assert model["intensity"] < 8.0    # memory-bound, far below any ridge
