"""Subprocess worker for multi-device distributed tests.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 (set by the
parent test process, NOT globally — see dry-run rules).  Exercises the full
dynamic cycle (relax -> delete -> relax) on a (2,2,2) mesh, checks against
the Dijkstra oracle, prints "OK <rounds>" on success.
"""
import os
import sys

# must precede any jax import in this process
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.distributed import DistConfig, DistributedSSSP  # noqa: E402
from repro.core.oracle import dijkstra  # noqa: E402
from repro.graphs import generators  # noqa: E402
from repro.launch.mesh import _mk  # noqa: E402


def main(exchange: str) -> None:
    assert len(jax.devices()) == 8, f"expected 8 devices, got {len(jax.devices())}"
    mesh = _mk((2, 2, 2), ("pod", "data", "model"))
    n_raw, src, dst, w = generators.power_law_hubs(400, 3000, seed=1)
    source = int(generators.top_in_degree_sources(n_raw, dst, 1)[0])
    P = 8
    npp = -(-n_raw // P)
    N = P * npp
    cfg = DistConfig(num_vertices=N, edges_per_part=2048,
                     mesh_axes=("pod", "data", "model"),
                     exchange=exchange, delta_cap=64)
    ds = DistributedSSSP(mesh, cfg)

    es, ed, ew, ea = ds.place_edges(src, dst, w)
    eput = ds.put_edges(es, ed, ew, ea)
    dist, parent = ds.init_vertex_arrays(source)
    front = ds.frontier_of(np.array([source]))
    epoch = ds.make_relax_epoch()
    dist, parent, r1 = epoch(dist, parent, front, *eput)

    ref, _ = dijkstra(n_raw, src, dst, w, source)
    got = np.asarray(dist)[:n_raw]
    assert np.allclose(np.nan_to_num(ref, posinf=1e30),
                       np.nan_to_num(got, posinf=1e30), rtol=1e-5), "relax mismatch"

    # delete 3 tree edges at once (batched deletion epoch)
    par = np.asarray(parent)
    cand = np.nonzero((par[:n_raw] >= 0))[0]
    heads = cand[:3]
    tails = par[heads]
    mask = np.ones(len(src), np.bool_)
    for u, v in zip(tails, heads):
        mask &= ~((src == u) & (dst == v))
    src2, dst2, w2 = src[mask], dst[mask], w[mask]
    e2 = ds.put_edges(*ds.place_edges(src2, dst2, w2))
    seed_fn = ds.make_seed_from_deletions()
    pad = lambda a: jnp.asarray(np.pad(a.astype(np.int32), (0, 5 - len(a)),
                                       constant_values=-1))
    seed = seed_fn(parent, pad(tails), pad(heads))
    del_epoch = ds.make_delete_epoch()
    dist, parent, r2 = del_epoch(dist, parent, seed, *e2)

    ref2, _ = dijkstra(n_raw, src2, dst2, w2, source)
    got2 = np.asarray(dist)[:n_raw]
    assert np.allclose(np.nan_to_num(ref2, posinf=1e30),
                       np.nan_to_num(got2, posinf=1e30), rtol=1e-5), "delete mismatch"
    print(f"OK {int(r1)} {int(r2)}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "allgather")
