"""Numerical equivalence of the §Perf variants: the optimizations must not
change the math — loss and grads identical (to dtype tolerance) across
attn_impl / remat_policy / act sharding variants."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import qwen3_14b
from repro.models import transformer as tfm


def _setup(**kw):
    cfg = dataclasses.replace(
        qwen3_14b.REDUCED, n_layers=4, **kw)
    params = tfm.init_lm(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                              jnp.int32),
    }
    return cfg, params, batch


def _loss_and_grads(cfg, params, batch):
    def f(p):
        total, m = tfm.lm_loss(p, batch, cfg)
        return total
    loss, grads = jax.value_and_grad(f)(params)
    return float(loss), grads


def test_sqrt_remat_matches_layer_remat():
    cfg1, params, batch = _setup(remat_policy="layer")
    cfg2 = dataclasses.replace(cfg1, remat_policy="sqrt", remat_group=2)
    l1, g1 = _loss_and_grads(cfg1, params, batch)
    l2, g2 = _loss_and_grads(cfg2, params, batch)
    assert abs(l1 - l2) < 1e-5
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)


def test_flash_matches_scan_attention():
    cfg1, params, batch = _setup(attn_impl="scan")
    cfg2 = dataclasses.replace(cfg1, attn_impl="flash_vjp")
    l1, g1 = _loss_and_grads(cfg1, params, batch)
    l2, g2 = _loss_and_grads(cfg2, params, batch)
    assert abs(l1 - l2) < 2e-4
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-4)


def test_act_sharding_context_is_noop_on_single_device():
    cfg, params, batch = _setup()
    from repro.launch.mesh import _mk  # AxisType compat across jax versions
    mesh = _mk((1,), ("data",))
    l1, _ = _loss_and_grads(cfg, params, batch)
    with tfm.activation_sharding(mesh, ("data",)):
        l2, _ = _loss_and_grads(cfg, params, batch)
    assert abs(l1 - l2) < 1e-6
