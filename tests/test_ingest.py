"""Ingestion-layer unit tests: pad_pow2 contract, vectorized slot planning,
and the host COO mirror the ELL rebuild path depends on.

The allocator tests run against BOTH control planes (DESIGN.md §11): the
dict reference and the columnar open-addressing implementation, which is
pinned bit-identical to the reference (same slot order, same free-stack
order) by the property test at the bottom."""
import numpy as np
import pytest

from repro.core import ingest
from repro.testing import given, settings, st


# ---------------------------------------------------------------- pad_pow2 --
def test_pad_pow2_empty_batch_is_identity():
    a = np.empty(0, np.int32)
    b = np.empty(0, np.float32)
    out = ingest.pad_pow2(a, b)
    assert isinstance(out, tuple) and len(out) == 2
    assert out[0] is a and out[1] is b  # no copy on the no-op path
    assert len(out[0]) == 0


@pytest.mark.parametrize("n", [1, 2, 4, 8, 64])
def test_pad_pow2_already_pow2_is_identity(n):
    a = np.arange(n, dtype=np.int32)
    out = ingest.pad_pow2(a)
    assert isinstance(out, tuple)
    assert out[0] is a


@pytest.mark.parametrize("n,m", [(3, 4), (5, 8), (9, 16), (1023, 1024)])
def test_pad_pow2_pads_by_repeating_tail(n, m):
    a = np.arange(n, dtype=np.int32)
    b = np.arange(n, dtype=np.float32) * 0.5
    pa, pb = ingest.pad_pow2(a, b)
    assert len(pa) == len(pb) == m
    np.testing.assert_array_equal(pa[:n], a)
    assert (pa[n:] == a[-1]).all()
    assert (pb[n:] == b[-1]).all()


def test_pad_pow2_rejects_mismatched_lengths():
    with pytest.raises(AssertionError):
        ingest.pad_pow2(np.arange(3), np.arange(4))


# ----------------------------------------------------------- SlotAllocator --
@pytest.fixture(params=ingest.ALLOC_IMPLS)
def impl(request):
    return request.param


def _alloc(cap=32, dup="ignore", impl="dict"):
    return ingest.make_allocator(cap, dup, impl=impl)


def test_plan_adds_assigns_distinct_slots_and_mirror(impl):
    a = _alloc(impl=impl)
    plan = a.plan_adds(np.array([0, 1, 2]), np.array([1, 2, 3]),
                       np.array([1.0, 2.0, 3.0]))
    assert len(np.unique(plan.slots)) == 3
    assert plan.fresh.all()
    ms, md, mw = a.active_coo()
    assert sorted(zip(ms.tolist(), md.tolist())) == [(0, 1), (1, 2), (2, 3)]
    np.testing.assert_allclose(np.sort(mw), [1.0, 2.0, 3.0])


def test_plan_adds_ignore_drops_duplicates_within_and_across_batches(impl):
    a = _alloc(impl=impl)
    p1 = a.plan_adds(np.array([0, 0, 0]), np.array([1, 1, 2]),
                     np.array([1.0, 9.0, 2.0]))
    assert len(p1.slots) == 2  # in-batch dup of (0,1) collapsed to first
    p2 = a.plan_adds(np.array([0]), np.array([1]), np.array([5.0]))
    assert len(p2.slots) == 0  # cross-batch duplicate dropped


def test_plan_adds_min_keeps_decreases_drops_increases(impl):
    a = _alloc(dup="min", impl=impl)
    a.plan_adds(np.array([0]), np.array([1]), np.array([4.0]))
    p = a.plan_adds(np.array([0, 0]), np.array([1, 1]), np.array([9.0, 3.0]))
    # in-batch min is 3.0 < 4.0 -> one non-fresh decrease emitted
    assert len(p.slots) == 1 and not p.fresh[0]
    assert p.w[0] == pytest.approx(3.0)
    p2 = a.plan_adds(np.array([0]), np.array([1]), np.array([7.0]))
    assert len(p2.slots) == 0  # increase dropped
    _, _, mw = a.active_coo()
    assert mw[0] == pytest.approx(3.0)


def test_plan_dels_pops_and_frees(impl):
    a = _alloc(cap=4, impl=impl)
    p = a.plan_adds(np.array([0, 1]), np.array([1, 2]), np.array([1.0, 1.0]))
    slots, ps, pd = a.plan_dels(np.array([0, 0, 5]), np.array([1, 1, 6]))
    assert slots.tolist() == [p.slots[0]]  # dup del + missing edge are no-ops
    assert (ps[0], pd[0]) == (0, 1)
    assert not a.mactive[slots[0]]
    # freed slot is reusable
    p2 = a.plan_adds(np.array([7, 8]), np.array([8, 9]), np.array([1.0, 1.0]))
    assert len(p2.slots) == 2


def test_capacity_exhaustion_raises(impl):
    a = _alloc(cap=2, impl=impl)
    a.plan_adds(np.array([0, 1]), np.array([1, 2]), np.array([1.0, 1.0]))
    with pytest.raises(RuntimeError):
        a.plan_adds(np.array([2]), np.array([3]), np.array([1.0]))


def test_from_pool_roundtrip(impl):
    a = _alloc(cap=8, impl=impl)
    a.plan_adds(np.array([0, 1, 2]), np.array([1, 2, 3]),
                np.array([1.0, 2.0, 3.0]))
    a.plan_dels(np.array([1]), np.array([2]))
    b = ingest.allocator_cls(impl).from_pool(8, "ignore", a.msrc, a.mdst,
                                             a.mw, a.mactive)
    assert b.slot_of == a.slot_of
    assert sorted(b.free) == sorted(a.free)
    np.testing.assert_array_equal(b.mactive, a.mactive)

# ------------------------------------------------- vertex-id validation ----
@pytest.mark.parametrize("bad", [-1, 1 << 31, (1 << 31) + 7])
def test_plan_adds_rejects_out_of_range_ids(impl, bad):
    """Regression: ids outside [0, 2**31) would silently alias another edge
    in the packed (src << 32) | dst int64 key — must raise instead."""
    a = _alloc(impl=impl)
    with pytest.raises(ValueError, match=r"outside \[0, 2\*\*31\)"):
        a.plan_adds(np.array([0, bad]), np.array([1, 2]),
                    np.array([1.0, 1.0]))
    with pytest.raises(ValueError, match=r"outside \[0, 2\*\*31\)"):
        a.plan_adds(np.array([0]), np.array([bad]), np.array([1.0]))


@pytest.mark.parametrize("bad", [-1, 1 << 31])
def test_plan_dels_rejects_out_of_range_ids(impl, bad):
    a = _alloc(impl=impl)
    a.plan_adds(np.array([0]), np.array([1]), np.array([1.0]))
    with pytest.raises(ValueError, match=r"outside \[0, 2\*\*31\)"):
        a.plan_dels(np.array([bad]), np.array([1]))


def test_max_valid_id_is_accepted(impl):
    top = (1 << 31) - 1
    a = _alloc(impl=impl)
    p = a.plan_adds(np.array([top]), np.array([top - 1]), np.array([1.0]))
    assert len(p.slots) == 1
    slots, _, _ = a.plan_dels(np.array([top]), np.array([top - 1]))
    assert slots.tolist() == p.slots.tolist()


def test_make_allocator_unknown_impl_raises():
    with pytest.raises(ValueError, match="valid values"):
        ingest.make_allocator(8, impl="btree")


# --------------------------------- columnar == dict reference (property) ---
def _assert_same_state(cols, ref):
    assert cols.slot_of == ref.slot_of
    assert cols.free == ref.free  # ORDER matters: same future slot choices
    np.testing.assert_array_equal(cols.mactive, ref.mactive)
    np.testing.assert_array_equal(cols.msrc, ref.msrc)
    np.testing.assert_array_equal(cols.mdst, ref.mdst)
    np.testing.assert_array_equal(cols.mw, ref.mw)


def _assert_same_plan(pc, pr):
    np.testing.assert_array_equal(pc.slots, pr.slots)
    np.testing.assert_array_equal(pc.src, pr.src)
    np.testing.assert_array_equal(pc.dst, pr.dst)
    np.testing.assert_array_equal(pc.w, pr.w)
    np.testing.assert_array_equal(pc.fresh, pr.fresh)


@settings(max_examples=6)
@given(seed=st.integers(min_value=0, max_value=1 << 20),
       dup=st.sampled_from(["ignore", "min"]))
def test_columnar_matches_dict_reference(seed, dup):
    """Bit-identity pin (DESIGN.md §11): over randomized add / del /
    duplicate / checkpoint-restore sequences, the columnar allocator makes
    the same slot choices in the same order as the dict reference — plans,
    slot_of, free-stack ORDER and mirrors all equal at every step."""
    rng = np.random.default_rng(seed)
    # a few huge ids keep the packed-key/hash path honest
    ids = np.array([0, 1, 2, 3, 5, 8, 13, 100, 10**6, (1 << 31) - 1],
                   dtype=np.int64)
    cap = len(ids) * len(ids) + 16
    ref = ingest.make_allocator(cap, dup, impl="dict")
    col = ingest.make_allocator(cap, dup, impl="columnar")
    for _ in range(50):
        op = rng.random()
        k = int(rng.integers(1, 9))
        src = ids[rng.integers(0, len(ids), k)]
        dst = ids[rng.integers(0, len(ids), k)]
        if op < 0.55:
            w = rng.uniform(0.1, 4.0, k).astype(np.float32)
            _assert_same_plan(col.plan_adds(src, dst, w),
                              ref.plan_adds(src, dst, w))
        elif op < 0.9:
            sc, psc, pdc = col.plan_dels(src, dst)
            sr, psr, pdr = ref.plan_dels(src, dst)
            np.testing.assert_array_equal(sc, sr)
            np.testing.assert_array_equal(psc, psr)
            np.testing.assert_array_equal(pdc, pdr)
        else:  # checkpoint-restore: both sides rebuilt from pool mirrors
            ref = ingest.SlotAllocator.from_pool(
                cap, dup, ref.msrc, ref.mdst, ref.mw, ref.mactive)
            col = ingest.ColumnarSlotAllocator.from_pool(
                cap, dup, col.msrc, col.mdst, col.mw, col.mactive)
        _assert_same_state(col, ref)


def test_columnar_table_growth_matches_dict():
    """Churn past several index doublings/compactions: the capacity-growing
    open-addressing table never changes slot-assignment order."""
    cap = 5000
    ref = ingest.make_allocator(cap, impl="dict")
    col = ingest.make_allocator(cap, impl="columnar")
    rng = np.random.default_rng(0)
    for step in range(8):
        m = 600
        src = rng.integers(0, 3000, m)
        dst = rng.integers(0, 3000, m)
        w = rng.uniform(0.1, 1.0, m).astype(np.float32)
        _assert_same_plan(col.plan_adds(src, dst, w),
                          ref.plan_adds(src, dst, w))
        ds = rng.integers(0, 3000, m // 2)
        dd = rng.integers(0, 3000, m // 2)
        np.testing.assert_array_equal(col.plan_dels(ds, dd)[0],
                                      ref.plan_dels(ds, dd)[0])
    assert col._tsize > 1024  # the index actually grew
    _assert_same_state(col, ref)
