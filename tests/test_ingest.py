"""Ingestion-layer unit tests: pad_pow2 contract, vectorized slot planning,
and the host COO mirror the ELL rebuild path depends on."""
import numpy as np
import pytest

from repro.core import ingest


# ---------------------------------------------------------------- pad_pow2 --
def test_pad_pow2_empty_batch_is_identity():
    a = np.empty(0, np.int32)
    b = np.empty(0, np.float32)
    out = ingest.pad_pow2(a, b)
    assert isinstance(out, tuple) and len(out) == 2
    assert out[0] is a and out[1] is b  # no copy on the no-op path
    assert len(out[0]) == 0


@pytest.mark.parametrize("n", [1, 2, 4, 8, 64])
def test_pad_pow2_already_pow2_is_identity(n):
    a = np.arange(n, dtype=np.int32)
    out = ingest.pad_pow2(a)
    assert isinstance(out, tuple)
    assert out[0] is a


@pytest.mark.parametrize("n,m", [(3, 4), (5, 8), (9, 16), (1023, 1024)])
def test_pad_pow2_pads_by_repeating_tail(n, m):
    a = np.arange(n, dtype=np.int32)
    b = np.arange(n, dtype=np.float32) * 0.5
    pa, pb = ingest.pad_pow2(a, b)
    assert len(pa) == len(pb) == m
    np.testing.assert_array_equal(pa[:n], a)
    assert (pa[n:] == a[-1]).all()
    assert (pb[n:] == b[-1]).all()


def test_pad_pow2_rejects_mismatched_lengths():
    with pytest.raises(AssertionError):
        ingest.pad_pow2(np.arange(3), np.arange(4))


# ----------------------------------------------------------- SlotAllocator --
def _alloc(cap=32, dup="ignore"):
    return ingest.SlotAllocator(cap, dup)


def test_plan_adds_assigns_distinct_slots_and_mirror():
    a = _alloc()
    plan = a.plan_adds(np.array([0, 1, 2]), np.array([1, 2, 3]),
                       np.array([1.0, 2.0, 3.0]))
    assert len(np.unique(plan.slots)) == 3
    assert plan.fresh.all()
    ms, md, mw = a.active_coo()
    assert sorted(zip(ms.tolist(), md.tolist())) == [(0, 1), (1, 2), (2, 3)]
    np.testing.assert_allclose(np.sort(mw), [1.0, 2.0, 3.0])


def test_plan_adds_ignore_drops_duplicates_within_and_across_batches():
    a = _alloc()
    p1 = a.plan_adds(np.array([0, 0, 0]), np.array([1, 1, 2]),
                     np.array([1.0, 9.0, 2.0]))
    assert len(p1.slots) == 2  # in-batch dup of (0,1) collapsed to first
    p2 = a.plan_adds(np.array([0]), np.array([1]), np.array([5.0]))
    assert len(p2.slots) == 0  # cross-batch duplicate dropped


def test_plan_adds_min_keeps_decreases_drops_increases():
    a = _alloc(dup="min")
    a.plan_adds(np.array([0]), np.array([1]), np.array([4.0]))
    p = a.plan_adds(np.array([0, 0]), np.array([1, 1]), np.array([9.0, 3.0]))
    # in-batch min is 3.0 < 4.0 -> one non-fresh decrease emitted
    assert len(p.slots) == 1 and not p.fresh[0]
    assert p.w[0] == pytest.approx(3.0)
    p2 = a.plan_adds(np.array([0]), np.array([1]), np.array([7.0]))
    assert len(p2.slots) == 0  # increase dropped
    _, _, mw = a.active_coo()
    assert mw[0] == pytest.approx(3.0)


def test_plan_dels_pops_and_frees():
    a = _alloc(cap=4)
    p = a.plan_adds(np.array([0, 1]), np.array([1, 2]), np.array([1.0, 1.0]))
    slots, ps, pd = a.plan_dels(np.array([0, 0, 5]), np.array([1, 1, 6]))
    assert slots.tolist() == [p.slots[0]]  # dup del + missing edge are no-ops
    assert (ps[0], pd[0]) == (0, 1)
    assert not a.mactive[slots[0]]
    # freed slot is reusable
    p2 = a.plan_adds(np.array([7, 8]), np.array([8, 9]), np.array([1.0, 1.0]))
    assert len(p2.slots) == 2


def test_capacity_exhaustion_raises():
    a = _alloc(cap=2)
    a.plan_adds(np.array([0, 1]), np.array([1, 2]), np.array([1.0, 1.0]))
    with pytest.raises(RuntimeError):
        a.plan_adds(np.array([2]), np.array([3]), np.array([1.0]))


def test_from_pool_roundtrip():
    a = _alloc(cap=8)
    a.plan_adds(np.array([0, 1, 2]), np.array([1, 2, 3]),
                np.array([1.0, 2.0, 3.0]))
    a.plan_dels(np.array([1]), np.array([2]))
    b = ingest.SlotAllocator.from_pool(8, "ignore", a.msrc, a.mdst, a.mw,
                                       a.mactive)
    assert b.slot_of == a.slot_of
    assert sorted(b.free) == sorted(a.free)
    np.testing.assert_array_equal(b.mactive, a.mactive)
