"""Training-substrate tests: optimizer, checkpoint/restart (incl. crash
mid-write + elastic reshard), gradient compression with error feedback,
deterministic data pipelines."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.train import checkpoint as ckpt
from repro.train import compression as comp
from repro.train import data as data_mod
from repro.train import optimizer as opt
from repro.train import steps as steps_mod


# ------------------------------------------------------------- optimizer ----

def _quadratic_loss(params, batch):
    err = params["w"] - batch["target"]
    loss = jnp.sum(err * err)
    return loss, {"loss": loss}


def test_adamw_descends():
    params = {"w": jnp.ones((8,), jnp.float32) * 5.0}
    batch = {"target": jnp.zeros((8,), jnp.float32)}
    cfg = opt.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=1000)
    step = jax.jit(steps_mod.make_train_step(_quadratic_loss, cfg, 1))
    state = opt.adamw_init(params)
    losses = []
    for _ in range(50):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.1
    assert int(state["step"]) == 50


def test_grad_accum_matches_full_batch():
    key = jax.random.key(0)
    w = jax.random.normal(key, (4, 4))
    params = {"w": w}
    x = jax.random.normal(jax.random.key(1), (8, 4))

    def loss(params, batch):
        y = batch["x"] @ params["w"]
        l = jnp.mean(y * y)
        return l, {"loss": l}

    cfg = opt.AdamWConfig(lr=1e-2, warmup_steps=0)
    s1 = jax.jit(steps_mod.make_train_step(loss, cfg, 1))
    s4 = jax.jit(steps_mod.make_train_step(loss, cfg, 4))
    p1, _, _ = s1(params, opt.adamw_init(params), {"x": x})
    p4, _, _ = s4(params, opt.adamw_init(params),
                  {"x": x.reshape(4, 2, 4)})
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p4["w"]),
                               rtol=2e-5, atol=2e-6)


# ------------------------------------------------------------ checkpoint ----

def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (64, 8)),
            "nested": {"b": jnp.arange(13, dtype=jnp.int32),
                       "c": jnp.float32(3.5)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    path = ckpt.save(t, str(tmp_path), step=7, chunk_bytes=256)  # force chunking
    assert path.endswith("step_000000007")
    assert ckpt.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    out = ckpt.restore(like, str(tmp_path))
    for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_crash_leaves_no_partial(tmp_path):
    t = _tree()
    ckpt.save(t, str(tmp_path), step=1)
    # simulate a crash: a stale .tmp dir from a dead writer
    os.makedirs(tmp_path / "step_000000002.tmp")
    assert ckpt.latest_step(str(tmp_path)) == 1  # tmp is invisible
    # and a fresh save of the same step succeeds over the stale tmp
    ckpt.save(t, str(tmp_path), step=2)
    assert ckpt.latest_step(str(tmp_path)) == 2


def test_checkpoint_retention(tmp_path):
    t = _tree()
    for s in range(5):
        ckpt.save(t, str(tmp_path), step=s)
    ckpt.cleanup(str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    assert sorted(os.listdir(tmp_path)) == ["step_000000003", "step_000000004"]


def test_async_save(tmp_path):
    t = _tree()
    saver = ckpt.AsyncSaver()
    saver.save(t, str(tmp_path), step=3)
    saver.wait()
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_elastic_reshard(tmp_path):
    """Checkpoint written under one sharding loads under another (the
    single-device equivalent of mesh A -> mesh B; multi-device resharding is
    exercised in tests/test_sssp_distributed.py's forced-device worker)."""
    t = {"w": jnp.arange(64, dtype=jnp.float32).reshape(16, 4)}
    ckpt.save(t, str(tmp_path), step=0)
    like = {"w": jax.ShapeDtypeStruct((16, 4), jnp.float32)}
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    out = ckpt.restore(like, str(tmp_path), sharding_tree={"w": sh})
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(t["w"]))


def test_restore_shape_mismatch_raises(tmp_path):
    ckpt.save({"w": jnp.zeros((4,))}, str(tmp_path), step=0)
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore({"w": jax.ShapeDtypeStruct((5,), jnp.float32)},
                     str(tmp_path))


# ------------------------------------------------------------ compression ----

def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.key(0), (1000,)) * 3.0
    q, s = comp.quantize_int8(x)
    back = comp.dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_time():
    """With EF, the *accumulated* applied gradient tracks the accumulated
    true gradient (residual stays bounded)."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros(256, np.float32)
    applied_sum = np.zeros(256, np.float32)
    e = jnp.zeros(256, jnp.float32)
    for t in range(50):
        g = jnp.asarray(rng.normal(size=256).astype(np.float32)) * 0.01
        corrected = g + e
        q, s = comp.quantize_int8(corrected)
        sent = comp.dequantize_int8(q, s)
        e = corrected - sent
        true_sum += np.asarray(g)
        applied_sum += np.asarray(sent)
    # residual is one quantization step, not 50 accumulated steps
    resid = np.abs(true_sum - applied_sum).max()
    assert resid <= float(s) + 1e-5


def test_compression_ratio():
    tree = {"a": jnp.zeros((1024,)), "b": jnp.zeros((128, 16))}
    r = comp.compression_ratio(tree)
    assert 0.24 < r < 0.27   # ~4x


# ------------------------------------------------------------------ data ----

def test_token_stream_deterministic_and_restartable():
    s1 = data_mod.TokenStream(vocab_size=97, batch=4, seq_len=32, seed=1)
    b1 = [s1.next_batch() for _ in range(3)]
    s2 = data_mod.TokenStream(vocab_size=97, batch=4, seq_len=32, seed=1)
    s2.next_batch()
    state = s2.state()
    s3 = data_mod.TokenStream(vocab_size=97, batch=4, seq_len=32, seed=1)
    s3.restore(state)
    np.testing.assert_array_equal(s3.next_batch()["tokens"],
                                  b1[1]["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1[0]["labels"][:, :-1],
                                  b1[0]["tokens"][:, 1:])


def test_click_stream_labels_balanced():
    s = data_mod.ClickStream(n_items=1000, n_cates=16, batch=512, seed=0)
    b = s.next_batch()
    assert 0.3 < b["labels"].mean() < 0.7
    assert b["hist_mask"].any(axis=1).all()
