"""Engine telemetry layer (DESIGN.md §10): counter registry, span tracer,
flight recorder, and their wiring through both engines.

The load-bearing contracts:

  * telemetry never changes the computation — an observability-enabled
    engine is bit-identical (dist, parent, rounds, messages) to its
    uninstrumented twin on any stream, for every backend and schedule;
  * span counts, engine counters and the exported Chrome trace are three
    views of the same events and must always agree;
  * instrumented ingest obeys the §2.4 no-host-sync rule — the device
    counters accumulate lazily and drain only at ``snapshot()`` /
    ``metrics_snapshot()`` (the device_get trap test, across the backend
    x engine grid);
  * the flight recorder is a bounded ring and dumps once on a dispatch
    exception.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax

from repro.core import events as ev
from repro.core.dist_engine import ShardedEngineConfig, ShardedSSSPDelEngine
from repro.core.engine import EngineConfig, SSSPDelEngine
from repro.graphs import generators, window
from repro.obs import (CounterRegistry, EngineObs, FlightRecorder,
                       SpanTracer, load_chrome_trace, out_path_or_exit,
                       span_counts_of, write_log_jsonl)
from repro.serving import TraceRecorder, replay_trace

HERE = os.path.dirname(__file__)
# tiny layout knobs so rebuild/spill paths run under instrumentation too
BACKEND_KW = {
    "segment": {},
    "ellpack": dict(ell_init_k=2),
    "sliced": dict(sliced_slice_rows=32, sliced_hub_k=4, sliced_init_k=1),
}


def _dynamic_stream(seed: int, *, n=72, m=320, delta=0.5):
    n, src, dst, w = generators.erdos_renyi(n, m, seed=seed)
    log = window.sliding_window_stream(src, dst, w, window=m // 3,
                                       delta=delta, seed=seed,
                                       query_every=m // 2)
    return n, len(src), log


def _mk(engine: str, backend: str, n: int, cap: int, source: int, **kw):
    if engine == "single":
        return SSSPDelEngine(EngineConfig(
            n, cap, source, relax_backend=backend,
            **BACKEND_KW[backend], **kw))
    return ShardedSSSPDelEngine(ShardedEngineConfig(
        n, cap, source, relax_backend=backend, **BACKEND_KW[backend], **kw))


# --------------------------------------------------------- counter registry --
def test_counter_registry_device_and_host():
    import jax.numpy as jnp
    reg = CounterRegistry(enabled=True)
    reg.add("frontier", jnp.int32(3))          # device scalar, lazy
    reg.add("frontier", jnp.int32(4))
    reg.add("waves", jnp.asarray([1, 2, 3]))   # [S] vector, lazy
    reg.add("waves", jnp.asarray([1, 0, 1]))
    reg.peak("hw", jnp.int32(5))
    reg.peak("hw", jnp.int32(2))
    reg.inc("epochs")                          # host int
    reg.inc("epochs", 4)
    reg.inc("per_part", np.array([1, 0]))      # host [P] tally
    reg.inc("per_part", np.array([0, 2]))
    snap = reg.snapshot()
    assert snap["frontier"] == 7 and isinstance(snap["frontier"], int)
    np.testing.assert_array_equal(snap["waves"], [2, 2, 4])
    assert snap["hw"] == 5
    assert snap["epochs"] == 5
    np.testing.assert_array_equal(snap["per_part"], [1, 2])
    assert reg.names() == sorted(["frontier", "waves", "hw", "epochs",
                                  "per_part"])


def test_counter_registry_merges_host_and_device_same_name():
    import jax.numpy as jnp
    reg = CounterRegistry(enabled=True)
    reg.inc("rebuilds", 2)
    reg.add("rebuilds", jnp.int32(3))
    assert reg.snapshot()["rebuilds"] == 5


def test_counter_registry_disabled_noops():
    reg = CounterRegistry(enabled=False)
    reg.add("a", 1)
    reg.inc("b")
    reg.peak("c", 9)
    assert reg.snapshot() == {} and reg.names() == []


# --------------------------------------------------------------- span tracer --
def test_span_nesting_roundtrips_through_chrome_trace(tmp_path):
    tr = SpanTracer(enabled=True)
    with tr.span("outer", events=2):
        with tr.span("inner"):
            pass
        tr.instant("rebuild")
        with tr.span("inner"):
            pass
    path = str(tmp_path / "trace.json")
    tr.save_chrome(path)
    events = load_chrome_trace(path)
    assert span_counts_of(events) == tr.span_counts() == \
        {"outer": 1, "inner": 2, "rebuild": 1}
    by_name = {}
    for e in events:
        by_name.setdefault(e["name"], []).append(e)
    outer, = by_name["outer"]
    assert outer["ph"] == "X" and outer["args"]["depth"] == 0
    assert outer["args"]["events"] == 2
    for inner in by_name["inner"]:
        assert inner["args"]["depth"] == 1
        # nesting: every inner interval sits inside the outer interval
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    reb, = by_name["rebuild"]
    assert reb["ph"] == "i" and reb["s"] == "t" and "dur" not in reb
    assert outer["ts"] <= reb["ts"] <= outer["ts"] + outer["dur"]


def test_span_jsonl_and_load_errors(tmp_path):
    tr = SpanTracer(enabled=True)
    with tr.span("epoch", kindof="add"):
        tr.instant("mark")
    path = str(tmp_path / "spans.jsonl")
    tr.save_jsonl(path)
    lines = [json.loads(line) for line in
             Path(path).read_text().splitlines()]
    assert [ln["name"] for ln in lines] == ["mark", "epoch"]
    assert lines[1]["args"] == {"kindof": "add"}
    assert all(ln["dur_us"] >= 0 and ln["ts_us"] >= 0 for ln in lines)
    bad = tmp_path / "not_chrome.json"
    bad.write_text(json.dumps([1, 2, 3]))
    with pytest.raises(ValueError, match="traceEvents"):
        load_chrome_trace(str(bad))


def test_disabled_tracer_records_nothing():
    tr = SpanTracer(enabled=False)
    with tr.span("epoch"):
        tr.instant("mark")
    assert tr.spans == [] and tr.span_counts() == {}


# ----------------------------------------------------------- flight recorder --
def test_flight_recorder_ring_wraps_at_capacity():
    fr = FlightRecorder(capacity=8)
    for i in range(20):
        fr.record("add_epoch", events=i)
    assert fr.total == 20 and fr.capacity == 8
    recs = fr.records()
    assert len(recs) == 8
    assert [r["seq"] for r in recs] == list(range(12, 20))
    assert recs[-1]["events"] == 19
    with pytest.raises(ValueError, match="capacity"):
        FlightRecorder(capacity=0)


def test_flight_recorder_dump_format(capsys):
    fr = FlightRecorder(capacity=4)
    fr.record("drain", wall_ms=1.25)
    text = fr.dump(header="postmortem")
    err = capsys.readouterr().err
    assert text in err and err.startswith("# postmortem")
    assert json.loads(text.splitlines()[1])["kind"] == "drain"


# ----------------------------------------------------------------- EngineObs --
def test_engine_obs_epoch_dumps_flight_recorder_once(capsys):
    obs = EngineObs(enabled=True, flight_capacity=4)
    with obs.epoch("add_epoch", events=3):
        pass
    with pytest.raises(RuntimeError, match="boom"):
        with obs.epoch("del_epoch", events=1):
            raise RuntimeError("boom")
    err = capsys.readouterr().err
    assert "flight recorder" in err and "boom" in err
    snap = obs.counters.snapshot()
    assert snap["add_epochs"] == 1                       # failure not counted
    # the successful epoch also folded one wall-time histogram sample;
    # the failed one folded none
    assert int(np.sum(snap["hist_add_epoch_wall_us"])) == 1
    assert "hist_del_epoch_wall_us" not in snap
    assert set(snap) == {"add_epochs", "hist_add_epoch_wall_us"}
    assert obs.tracer.span_counts() == {"add_epoch": 1, "del_epoch": 1}
    assert [r["kind"] for r in obs.recorder.records()] == \
        ["add_epoch", "del_epoch"]
    assert obs.recorder.records()[-1]["error"].startswith("RuntimeError")
    # one-shot: a second failure must not dump again
    with pytest.raises(RuntimeError):
        with obs.epoch("drain"):
            raise RuntimeError("again")
    assert "flight recorder" not in capsys.readouterr().err


def test_engine_obs_disabled_is_inert():
    obs = EngineObs(enabled=False)
    with obs.epoch("add_epoch"):
        pass
    obs.note_layout({"rebuilds": 3})
    assert obs.counters.snapshot() == {}
    assert obs.tracer.span_counts() == {}
    assert obs.recorder.total == 0


def test_note_layout_deltas_and_rebuild_instants():
    obs = EngineObs(enabled=True)
    obs.note_layout({"rebuilds": 2, "overflow_hits": 5})
    obs.note_layout({"rebuilds": 2, "overflow_hits": 9})
    obs.note_layout({"rebuilds": 3, "overflow_hits": 0})  # reset clamps to 0
    snap = obs.counters.snapshot()
    assert snap == {"rebuilds": 3, "overflow_hits": 9}
    # one instant per rebuild delta — spans and counters can never disagree
    assert obs.tracer.span_counts() == {"rebuild": 3}


# --------------------------------------------------------- engine integration --
@pytest.mark.parametrize("backend", ["segment", "ellpack", "sliced"])
@pytest.mark.parametrize("schedule", ["rounds", "buckets"])
def test_single_engine_obs_bit_identical_and_consistent(backend, schedule):
    """Instrumentation is algorithmically free: the obs-enabled engine
    matches its uninstrumented twin bit for bit, and every telemetry view
    (spans, counters, metrics_snapshot) agrees with the engine's own
    stats."""
    n, m, log = _dynamic_stream(seed=11)
    kw = dict(wave_schedule=schedule)
    plain = _mk("single", backend, n, m + 64, 3, **kw)
    inst = _mk("single", backend, n, m + 64, 3, observability=True, **kw)
    res_p = plain.ingest_log(log) + [plain.query()]
    res_i = inst.ingest_log(log) + [inst.query()]
    for a, b in zip(res_p, res_i):
        np.testing.assert_array_equal(a.dist, b.dist)
        np.testing.assert_array_equal(a.parent, b.parent)
    assert plain.n_rounds == inst.n_rounds
    assert plain.n_messages == inst.n_messages

    snap = inst.metrics_snapshot()
    assert snap["rounds"] == inst.n_rounds
    assert snap["messages"] == inst.n_messages
    sp, ct = snap["spans"], snap["counters"]
    assert sp["add_epoch"] == ct["add_epochs"]
    assert sp["del_epoch"] == ct["del_epochs"]
    assert sp["add_epoch"] + sp["del_epoch"] == inst.n_epochs
    assert sp["query"] == ct["queries"] == len(res_i)
    assert sp.get("rebuild", 0) == ct.get("rebuilds", 0)
    if backend == "ellpack":
        assert ct["rebuilds"] == inst.backend.planner.rebuilds >= 1
    if backend == "sliced":
        assert ct["overflow_hits"] == inst.backend.planner.spills >= 1
    assert ct["frontier"] > 0            # lazy device counter drained here
    if schedule == "buckets":
        assert sp.get("drain", 0) == ct.get("drains", 0) > 0
        assert ct["drain_waves"] > 0
    # the plain twin carries no telemetry state at all
    assert plain.metrics_snapshot()["counters"] == {}
    assert plain.metrics_snapshot()["spans"] == {}


@pytest.mark.parametrize("backend", ["segment", "sliced"])
def test_sharded_engine_obs_bit_identical_and_consistent(backend):
    n, m, log = _dynamic_stream(seed=17)
    plain = _mk("sharded", backend, n, m + 64, 3)
    inst = _mk("sharded", backend, n, m + 64, 3, observability=True)
    res_p = plain.ingest_log(log) + [plain.query()]
    res_i = inst.ingest_log(log) + [inst.query()]
    for a, b in zip(res_p, res_i):
        np.testing.assert_array_equal(a.dist, b.dist)
        np.testing.assert_array_equal(a.parent, b.parent)
    assert plain.n_rounds == inst.n_rounds
    assert plain.n_messages == inst.n_messages
    snap = inst.metrics_snapshot()
    assert snap["rounds"] == inst.n_rounds
    sp, ct = snap["spans"], snap["counters"]
    assert sp["add_epoch"] == ct["add_epochs"]
    assert sp["del_epoch"] == ct["del_epochs"]
    assert sp["add_epoch"] + sp["del_epoch"] == inst.n_epochs
    assert sp.get("rebuild", 0) == ct.get("rebuilds", 0)
    # per-partition tallies come back as [P] vectors summing to the totals
    P = inst.P
    assert np.asarray(ct["adds_per_part"]).shape == (P,)
    assert int(np.sum(ct["adds_per_part"])) == inst.n_adds
    assert int(np.sum(ct["dels_per_part"])) == inst.n_dels


def test_batched_sources_snapshot_is_per_lane():
    n, m, log = _dynamic_stream(seed=23)
    srcs = (3, 17, 40)
    eng = SSSPDelEngine(EngineConfig(n, m + 64, srcs[0], sources=srcs,
                                     observability=True))
    eng.ingest_log(log)
    snap = eng.metrics_snapshot()
    np.testing.assert_array_equal(snap["rounds"], eng.n_rounds)
    np.testing.assert_array_equal(snap["messages"], eng.n_messages)
    assert np.asarray(snap["rounds"]).shape == (len(srcs),)
    ck = eng.checkpoint()
    assert ck is not None
    snap = eng.metrics_snapshot()
    assert snap["spans"]["checkpoint"] == snap["counters"]["checkpoints"] == 1


def test_replay_report_carries_engine_metrics():
    n, m, log = _dynamic_stream(seed=29)
    rec = TraceRecorder()
    rec.extend_from_log(log)
    eng = SSSPDelEngine(EngineConfig(n, m + 64, 3, observability=True))
    rep = replay_trace(eng, rec.trace())
    assert rep.engine_metrics["rounds"] == eng.n_rounds
    assert rep.engine_metrics["messages"] == eng.n_messages
    r = rep.to_record()
    assert r["rounds"] == eng.n_rounds and r["messages"] == eng.n_messages


def test_dump_flight_recorder_postmortem():
    n, m, log = _dynamic_stream(seed=31)
    eng = SSSPDelEngine(EngineConfig(n, m + 64, 3, observability=True,
                                     obs_flight_capacity=6))
    eng.ingest_log(log)
    text = eng.dump_flight_recorder()
    recs = [json.loads(line) for line in text.splitlines()
            if not line.startswith("#")]
    assert 0 < len(recs) <= 6
    assert {r["kind"] for r in recs} <= \
        {"add_epoch", "del_epoch", "drain", "query", "checkpoint"}
    with pytest.raises(ValueError, match="obs_flight_capacity"):
        EngineConfig(n, m + 64, 3, obs_flight_capacity=0)


# -------------------------------------------------- §2.4 no-host-sync rule --
@pytest.mark.parametrize("engine", ["single", "sharded"])
@pytest.mark.parametrize("backend", ["segment", "ellpack", "sliced"])
def test_instrumented_ingest_never_reads_device_values(engine, backend,
                                                       monkeypatch):
    """Satellite: the device_get trap holds WITH observability enabled —
    the counter registry accumulates lazily, spans are pure host
    bookkeeping, so ADD/DEL ingest still never syncs."""
    n, m, log = _dynamic_stream(seed=13)
    eng = _mk(engine, backend, n, m + 64, 0, observability=True)
    topo = log[np.asarray(log.kind) != ev.QUERY]

    def trap(*a, **k):
        raise AssertionError("device_get during instrumented ingest")

    monkeypatch.setattr(jax, "device_get", trap)
    eng.ingest_log(topo)  # only ADD/DEL runs: must not sync
    monkeypatch.undo()
    q = eng.query()
    assert np.isfinite(np.asarray(q.dist)).any()
    snap = eng.metrics_snapshot()   # the sanctioned read-back point
    assert snap["counters"]["add_epochs"] > 0


# ----------------------------------------------------------- CLI / examples --
def _example_env():
    root = Path(HERE).resolve().parent
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(root / "src")
                         + (":" + env["PYTHONPATH"]
                            if env.get("PYTHONPATH") else ""))
    return root, env


def test_out_path_or_exit_contract(tmp_path, capsys):
    ok = str(tmp_path / "trace.json")
    assert out_path_or_exit(ok) == ok
    with pytest.raises(SystemExit) as ei:
        out_path_or_exit(str(tmp_path / "no_such_dir" / "trace.json"))
    assert ei.value.code == 2
    assert "error:" in capsys.readouterr().err


@pytest.mark.parametrize("flag", ["--trace-out", "--metrics-out"])
@pytest.mark.parametrize("example", ["streaming_sssp.py",
                                     "sharded_streaming_sssp.py"])
def test_examples_exit_2_on_bad_obs_out_dir(example, flag, tmp_path):
    root, env = _example_env()
    proc = subprocess.run(
        [sys.executable, str(root / "examples" / example),
         flag, str(tmp_path / "missing_dir" / "out.json")],
        capture_output=True, text=True, env=env, timeout=120)
    assert proc.returncode == 2, proc.stderr
    assert "error:" in proc.stderr


def test_example_replay_writes_trace_and_jsonl(tmp_path):
    """End-to-end CLI pass: replay a tiny recorded trace with --trace-out
    and --log-json; both artifacts must exist and parse, and the JSONL's
    final metrics_snapshot line must agree with the Chrome trace's span
    counts."""
    n, m, log = _dynamic_stream(seed=37)
    rec = TraceRecorder()
    rec.extend_from_log(log)
    trace_path = str(tmp_path / "stream.trace")
    rec.trace().save(trace_path)
    out_json = str(tmp_path / "spans.chrome.json")
    out_jsonl = str(tmp_path / "spans.jsonl")
    out_prom = str(tmp_path / "metrics.prom")
    root, env = _example_env()
    proc = subprocess.run(
        [sys.executable, str(root / "examples" / "streaming_sssp.py"),
         "--replay-trace", trace_path, "--trace-out", out_json,
         "--log-json", out_jsonl, "--metrics-out", out_prom],
        capture_output=True, text=True, env=env, timeout=300)
    assert proc.returncode == 0, proc.stderr[-3000:]
    events = load_chrome_trace(out_json)
    counts = span_counts_of(events)
    assert counts.get("add_epoch", 0) > 0
    lines = Path(out_jsonl).read_text().splitlines()
    final = json.loads(lines[-1])
    assert final["kind"] == "metrics_snapshot"
    assert final["spans"] == counts
    assert final["counters"]["add_epochs"] == counts["add_epoch"]
    # the Prometheus artifact agrees with both other views (§10.7)
    from repro.obs.export import parse_prometheus_text
    parsed = parse_prometheus_text(Path(out_prom).read_text())
    assert parsed["repro_add_epochs"][()] == counts["add_epoch"]
    assert parsed["repro_hist_latency_us_count"][()] == \
        final["counters"]["queries"]


# ------------------------------------------------------- P=8 acceptance run --
def test_obs_p8_acceptance_subprocess(tmp_path):
    """The ISSUE's acceptance scenario: a sharded (P=8 forced devices)
    bucketed replay of the power-law trace with a Chrome trace out; the
    worker asserts span counts == engine counters and metrics_snapshot
    bit-identity, the parent re-validates the exported artifact."""
    out = str(tmp_path / "p8.chrome.json")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "_obs_worker.py"), out],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-3000:]}"
    assert proc.stdout.strip().startswith("OK"), proc.stdout
    events = load_chrome_trace(out)
    counts = span_counts_of(events)
    assert counts.get("add_epoch", 0) > 0 and counts.get("drain", 0) > 0
    assert counts.get("rebuild", 0) > 0


def test_obs_p8_crash_dumps_flight_recorder_subprocess():
    """Satellite scenario: a failing epoch on the SHARDED (P=8) path must
    dump the flight recorder postmortem to stderr exactly once, carrying
    the injected error and the healthy epochs recorded before it."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "_obs_crash_worker.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-3000:]}"
    assert proc.stdout.strip().startswith("OK"), proc.stdout
    err = proc.stderr
    assert err.count("flight recorder postmortem") == 1, err[-2000:]
    assert "RuntimeError('injected epoch failure')" in err, err[-2000:]
    # the dump carries the healthy epochs recorded BEFORE the failure
    lines = [ln for ln in err.splitlines() if ln.startswith("{")]
    assert any('"error"' in ln for ln in lines), err[-2000:]
    assert any('"wall_ms"' in ln and "add_epoch" in ln
               for ln in lines), err[-2000:]
