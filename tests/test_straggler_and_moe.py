"""Straggler mitigation + chunked-MoE equivalence.

Straggler contract (DESIGN.md §7): a bounded-round epoch
(`max_rounds=k`) can be re-issued until convergence — monotone relaxation
is idempotent, so splitting one epoch into many bounded ones reaches the
same fixpoint (this is what the launcher does when a round times out)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import oracle, relax
from repro.core.state import EdgePool, SSSPState
from repro.graphs import generators as gen
from repro.models import moe


def test_bounded_round_epochs_reach_fixpoint():
    n, src, dst, w = gen.erdos_renyi(300, 2500, seed=4)
    edges = EdgePool(src=jnp.asarray(src.astype(np.int32)),
                     dst=jnp.asarray(dst.astype(np.int32)),
                     w=jnp.asarray(w), active=jnp.ones(len(src), jnp.bool_))
    source = int(gen.top_in_degree_sources(n, dst, 1)[0])
    sssp = SSSPState.init(n, source)
    frontier = relax.frontier_from_vertices(jnp.asarray([source]), n)

    # unbounded reference
    ref_state, ref_stats = relax.relax_until_converged(
        sssp, edges, frontier, num_vertices=n)

    # straggler mode: max 2 rounds per epoch, re-issue with the improved
    # frontier until no progress
    state = sssp
    fr = frontier
    issued = 0
    for _ in range(200):
        new_state, stats = relax.relax_until_converged(
            state, edges, fr, num_vertices=n, max_rounds=2)
        issued += 1
        improved = new_state.dist < state.dist
        state = new_state
        if not bool(jnp.any(improved)):
            break
        fr = improved
    assert issued > 1                      # the bound actually bit
    np.testing.assert_allclose(np.asarray(state.dist),
                               np.asarray(ref_state.dist), rtol=1e-6)
    dist_ref, _ = oracle.dijkstra(n, src, dst, w, source)
    got = np.asarray(state.dist)
    assert np.allclose(np.nan_to_num(dist_ref, posinf=1e30),
                       np.nan_to_num(got, posinf=1e30), rtol=1e-5)


def test_chunked_moe_dispatch_matches_oneshot():
    cfg = moe.MoEConfig(n_experts=8, top_k=2, d_ff=32)
    params = moe.init_moe(jax.random.key(0), 64, cfg)
    x = jax.random.normal(jax.random.key(1), (16, 64, 64))  # T*K = 2048
    old = moe.DISPATCH_CHUNK
    try:
        moe.DISPATCH_CHUNK = 0
        y0, _ = jax.jit(lambda p, x: moe.moe_forward(p, x, cfg))(params, x)
        g0 = jax.grad(lambda p: jnp.sum(
            moe.moe_forward(p, x, cfg)[0] ** 2))(params)
        moe.DISPATCH_CHUNK = 256
        y1, _ = jax.jit(lambda p, x: moe.moe_forward(p, x, cfg))(params, x)
        g1 = jax.grad(lambda p: jnp.sum(
            moe.moe_forward(p, x, cfg)[0] ** 2))(params)
    finally:
        moe.DISPATCH_CHUNK = old
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_chunked_moe_disabled_when_indivisible():
    cfg = moe.MoEConfig(n_experts=4, top_k=2, d_ff=16)
    params = moe.init_moe(jax.random.key(0), 32, cfg)
    x = jax.random.normal(jax.random.key(1), (3, 7, 32))  # T*K=42, prime-ish
    old = moe.DISPATCH_CHUNK
    try:
        moe.DISPATCH_CHUNK = 16   # does not divide 42 -> one-shot path
        y, _ = moe.moe_forward(params, x, cfg)
    finally:
        moe.DISPATCH_CHUNK = old
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))
