"""Flash-attention custom-VJP vs the dense oracle: values AND gradients,
swept over GQA group sizes, block sizes, ragged T, and dtypes."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models import flash, layers


def _mk(B, S, T, nq, nkv, D, Dv, dtype, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (B, S, nq, D), dtype)
    k = jax.random.normal(ks[1], (B, T, nkv, D), dtype)
    v = jax.random.normal(ks[2], (B, T, nkv, Dv), dtype)
    return q, k, v


@pytest.mark.parametrize("nq,nkv", [(4, 4), (8, 2), (6, 1)])
@pytest.mark.parametrize("block_k", [16, 64, 100])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_forward_matches_oracle(nq, nkv, block_k, causal):
    q, k, v = _mk(2, 24, 48, nq, nkv, 16, 16, jnp.float32)
    out = flash.flash_attention(q, k, v, causal, block_k)
    ref = layers.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("nq,nkv", [(4, 4), (8, 2)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_grads_match_oracle(nq, nkv, causal):
    q, k, v = _mk(2, 16, 32, nq, nkv, 8, 8, jnp.float32, seed=3)

    def f_flash(q, k, v):
        return jnp.sum(flash.flash_attention(q, k, v, causal, 16) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(layers.attention_ref(q, k, v, causal=causal) ** 2)

    gf = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gf, gr, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-5,
                                   err_msg=f"d{name} mismatch")


def test_flash_grads_match_naive_scan_bf16():
    """bf16 inputs: flash vjp ~= autodiff-through-scan (the baseline path)."""
    q, k, v = _mk(1, 8, 24, 4, 2, 8, 8, jnp.bfloat16, seed=5)

    def f_flash(q, k, v):
        return jnp.sum(flash.flash_attention(q, k, v, True, 8)
                       .astype(jnp.float32) ** 2)

    def f_scan(q, k, v):
        return jnp.sum(layers.blockwise_attention(q, k, v, causal=True,
                                                  block_k=8)
                       .astype(jnp.float32) ** 2)

    gf = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    gs = jax.grad(f_scan, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gs):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0.1, atol=0.05)


def test_flash_different_value_dim():
    q, k, v = _mk(2, 12, 12, 4, 2, 16, 8, jnp.float32)  # Dv != D (MLA-style)
    out = flash.flash_attention(q, k, v, True, 8)
    ref = layers.attention_ref(q, k, v, causal=True)
    assert out.shape == (2, 12, 4, 8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_row_with_no_valid_keys():
    """causal + T < S offsets never happen in our usage, but all-masked rows
    must still produce zeros, not NaN (first row with causal over empty)."""
    q, k, v = _mk(1, 4, 4, 2, 2, 8, 8, jnp.float32)
    out = flash.flash_attention(q, k, v, True, 2)
    assert bool(jnp.all(jnp.isfinite(out)))
