"""End-to-end behaviour tests for the SSSP-Del engine (the paper's system).

Every test validates the engine's (dist, parent) against the independent
numpy Dijkstra oracle on the *current* snapshot — i.e. exactly the paper's
correctness claim (Appendix A) at every epoch boundary we probe.
"""
import numpy as np
import pytest

from repro.core import events as ev
from repro.core.baseline import ReMoBaseline
from repro.core.engine import EngineConfig, SSSPDelEngine
from repro.core.oracle import check_tree, dijkstra, edges_of_pool
from repro.core.state import validate_state
from repro.graphs import generators, window


def _validate(eng: SSSPDelEngine, n: int, source: int):
    res = eng.query()
    e = eng.state.edges
    es, ed, ew = edges_of_pool(e.src, e.dst, e.w, e.active)
    check_tree(n, es, ed, ew, source, res.dist, res.parent)
    inv = validate_state(eng.state, n)
    for k, v in inv.items():
        assert bool(v), f"invariant {k} violated"
    return res


def test_additions_only_matches_dijkstra():
    n, src, dst, w = generators.erdos_renyi(120, 700, seed=0)
    eng = SSSPDelEngine(EngineConfig(n, 1024, source=3))
    eng.ingest_log(ev.adds(src, dst, w))
    _validate(eng, n, 3)


def test_single_tree_edge_deletion():
    # path 0->1->2->3 plus detour 0->9->3 (longer); delete 1->2, detour wins.
    n = 10
    eng = SSSPDelEngine(EngineConfig(n, 64, source=0))
    eng.ingest_log(ev.adds([0, 1, 2, 0, 9], [1, 2, 3, 9, 3],
                           [1.0, 1.0, 1.0, 5.0, 5.0]))
    r0 = _validate(eng, n, 0)
    assert r0.dist[3] == pytest.approx(3.0)
    eng.ingest_log(ev.dels([1], [2]))
    r1 = _validate(eng, n, 0)
    assert r1.dist[2] == np.inf
    assert r1.dist[3] == pytest.approx(10.0)
    assert r1.parent[3] == 9


def test_non_tree_deletion_is_free():
    n = 6
    eng = SSSPDelEngine(EngineConfig(n, 64, source=0))
    eng.ingest_log(ev.adds([0, 0, 1], [1, 2, 2], [1.0, 1.0, 5.0]))
    rounds_before = eng.n_rounds
    eng.ingest_log(ev.dels([1], [2]))  # not a tree edge (0->2 is shorter)
    assert eng.n_rounds == rounds_before  # no algorithmic work
    _validate(eng, n, 0)


def test_disconnection_goes_to_infinity():
    n = 5
    eng = SSSPDelEngine(EngineConfig(n, 32, source=0))
    eng.ingest_log(ev.adds([0, 1], [1, 2], [1.0, 1.0]))
    eng.ingest_log(ev.dels([0], [1]))
    res = _validate(eng, n, 0)
    assert np.isinf(res.dist[1]) and np.isinf(res.dist[2])
    assert res.parent[1] == -1 and res.parent[2] == -1


def test_reinsertion_after_deletion():
    n = 4
    eng = SSSPDelEngine(EngineConfig(n, 32, source=0))
    eng.ingest_log(ev.adds([0, 1], [1, 2], [1.0, 1.0]))
    eng.ingest_log(ev.dels([0], [1]))
    eng.ingest_log(ev.adds([0], [1], [2.0]))
    res = _validate(eng, n, 0)
    assert res.dist[2] == pytest.approx(3.0)


def test_weight_tie_breaking_deterministic():
    # two equal shortest paths; engine must pick the smaller src id twice
    n = 4
    for _ in range(2):
        eng = SSSPDelEngine(EngineConfig(n, 32, source=0))
        eng.ingest_log(ev.adds([0, 0, 1, 2], [1, 2, 3, 3],
                               [1.0, 1.0, 1.0, 1.0]))
        res = eng.query()
        assert res.parent[3] == 1  # deterministic tie-break


def test_sliding_window_stream_full_replay():
    n, src, dst, w = generators.power_law_hubs(300, 2500, seed=5)
    source = int(generators.top_in_degree_sources(n, dst, 1)[0])
    log = window.sliding_window_stream(src, dst, w, window=600, delta=0.5,
                                       seed=7, query_every=500)
    eng = SSSPDelEngine(EngineConfig(n, len(src) + 8, source=source))
    for batch in log.runs():
        if batch.kind == ev.ADD:
            eng._ingest_adds(batch)
        elif batch.kind == ev.DEL:
            eng._ingest_dels(batch)
        else:
            _validate(eng, n, source)
    _validate(eng, n, source)


def test_batched_deletions_match_sequential():
    n, src, dst, w = generators.erdos_renyi(80, 500, seed=3)
    source = 0
    log = window.sliding_window_stream(src, dst, w, window=120, delta=0.8, seed=4)
    engs = {
        "seq": SSSPDelEngine(EngineConfig(n, 600, source, batch_deletions=False)),
        "bat": SSSPDelEngine(EngineConfig(n, 600, source, batch_deletions=True)),
    }
    for e in engs.values():
        e.ingest_log(log)
    d0 = engs["seq"].query().dist
    d1 = engs["bat"].query().dist
    np.testing.assert_allclose(np.nan_to_num(d0, posinf=1e30),
                               np.nan_to_num(d1, posinf=1e30), rtol=1e-6)


def test_flood_and_doubling_invalidation_agree():
    n, src, dst, w = generators.erdos_renyi(100, 600, seed=9)
    log = window.sliding_window_stream(src, dst, w, window=150, delta=0.7, seed=9)
    res = {}
    for name, doubling in (("flood", False), ("double", True)):
        eng = SSSPDelEngine(EngineConfig(n, 700, 0, use_doubling=doubling))
        eng.ingest_log(log)
        res[name] = eng.query().dist
    np.testing.assert_allclose(np.nan_to_num(res["flood"], posinf=1e30),
                               np.nan_to_num(res["double"], posinf=1e30), rtol=1e-6)


def test_remo_baseline_agrees_with_engine():
    n, src, dst, w = generators.erdos_renyi(150, 900, seed=11)
    log = window.sliding_window_stream(src, dst, w, window=200, delta=0.4, seed=11)
    eng = SSSPDelEngine(EngineConfig(n, 1000, 1))
    eng.ingest_log(log)
    base = ReMoBaseline(n, 1000, 1)
    base.ingest_log(log)
    d_eng = eng.query().dist
    d_base = base.query().dist
    np.testing.assert_allclose(np.nan_to_num(d_eng, posinf=1e30),
                               np.nan_to_num(d_base, posinf=1e30), rtol=1e-6)


def test_engine_checkpoint_restore_roundtrip():
    n, src, dst, w = generators.erdos_renyi(60, 300, seed=2)
    log = window.sliding_window_stream(src, dst, w, window=100, delta=0.5, seed=2)
    eng = SSSPDelEngine(EngineConfig(n, 400, 0))
    half = len(log) // 2
    eng.ingest_log(log[:half])
    ckpt = eng.checkpoint()

    # continue original
    eng.ingest_log(log[half:])
    want = eng.query().dist

    # restore into a fresh engine (simulated node failure + restart)
    eng2 = SSSPDelEngine(EngineConfig(n, 400, 0))
    eng2.restore(ckpt)
    eng2.ingest_log(log[half:])
    got = eng2.query().dist
    np.testing.assert_allclose(np.nan_to_num(want, posinf=1e30),
                               np.nan_to_num(got, posinf=1e30), rtol=1e-6)


def test_stability_metric_bounds():
    n, src, dst, w = generators.erdos_renyi(100, 800, seed=6)
    log = window.sliding_window_stream(src, dst, w, window=200, delta=0.3,
                                       seed=6, query_every=300)
    eng = SSSPDelEngine(EngineConfig(n, 900, 0))
    stabilities = []
    for batch in log.runs():
        if batch.kind == ev.ADD:
            eng._ingest_adds(batch)
        elif batch.kind == ev.DEL:
            eng._ingest_dels(batch)
        else:
            r = eng.query()
            stabilities.append(eng.stability_vs_prev(r.parent))
    assert all(0.0 <= s <= 1.0 for s in stabilities)
