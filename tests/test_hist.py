"""Device-side log2 histograms (DESIGN.md §10.6).

Contracts under test:

  * bucket geometry — bucket 0 catches everything below 1 (and NaN on
    the host path), bucket ``i`` spans ``[2^(i-1), 2^i)``, the last
    bucket is open-ended;
  * the device ``one_hot`` and host ``one_hot_np`` bucket every value
    identically (the host/device twins must merge under one name);
  * percentile estimation — exact inside a bucket under linear
    interpolation, NaN on empty, lower bound for the open last bucket;
  * end-to-end totals — every histogram an instrumented engine exports
    counts exactly as many samples as the flat counter it shadows,
    across the backend x engine x schedule grid;
  * a batched (multi-source) engine reports per-lane [S, B] latency
    rows whose pooled total equals the flat query counter.
"""
import math

import numpy as np
import pytest

from repro.core.dist_engine import ShardedEngineConfig, ShardedSSSPDelEngine
from repro.core.engine import EngineConfig, SSSPDelEngine
from repro.graphs import generators, window
from repro.obs import hist

BACKEND_KW = {
    "segment": {},
    "ellpack": dict(ell_init_k=2),
    "sliced": dict(sliced_slice_rows=32, sliced_hub_k=4, sliced_init_k=1),
}


# ----------------------------------------------------------- bucket geometry
def test_bucket_edges_are_log2():
    assert hist.bucket_lo(0) == 0.0 and hist.bucket_hi(0) == 1.0
    assert hist.bucket_lo(1) == 1.0 and hist.bucket_hi(1) == 2.0
    assert hist.bucket_lo(5) == 16.0 and hist.bucket_hi(5) == 32.0
    assert math.isinf(hist.bucket_hi(hist.NUM_BUCKETS - 1))
    es = hist.edges()
    assert len(es) == hist.NUM_BUCKETS and es[-1] == math.inf
    assert es[:-1] == sorted(es[:-1])


@pytest.mark.parametrize("value,idx", [
    (0.0, 0), (0.5, 0), (0.999, 0),
    (1.0, 1), (1.5, 1), (2.0, 2), (3.99, 2), (4.0, 3),
    (2.0 ** 21, 22), (2.0 ** 22, 23), (1e30, hist.NUM_BUCKETS - 1),
])
def test_host_bucket_index(value, idx):
    assert hist.bucket_index_np(value) == idx


def test_host_bucket_index_nan_and_negative_go_to_bucket_zero():
    assert hist.bucket_index_np(float("nan")) == 0
    assert hist.bucket_index_np(-7.0) == 0


def test_device_and_host_bucketing_agree():
    import jax.numpy as jnp
    vals = [0.0, 0.3, 1.0, 1.9, 2.0, 7.0, 8.0, 1000.0, 2.0 ** 23, 1e30]
    dev = np.asarray(hist.bucket_index(jnp.asarray(vals, jnp.float32)))
    host = np.array([hist.bucket_index_np(v) for v in vals])
    np.testing.assert_array_equal(dev, host)


def test_one_hot_scalar_and_vector():
    oh = np.asarray(hist.one_hot(5.0))
    assert oh.sum() == 1 and oh[hist.bucket_index_np(5.0)] == 1
    # an [S] vector folds S samples into one count vector
    ohv = np.asarray(hist.one_hot(np.array([1.0, 1.5, 900.0])))
    assert ohv.sum() == 3
    assert ohv[1] == 2 and ohv[hist.bucket_index_np(900.0)] == 1


def test_fold_np_matches_one_hot_np():
    counts = hist.zeros_np()
    for v in (0.2, 1.0, 6.0, 6.5, 1e9):
        hist.fold_np(counts, v)
    ref = sum((hist.one_hot_np(v) for v in (0.2, 1.0, 6.0, 6.5, 1e9)),
              hist.zeros_np())
    np.testing.assert_array_equal(counts, ref)
    assert hist.total(counts) == 5


# ------------------------------------------------------------- percentiles --
def test_percentile_empty_is_nan():
    assert math.isnan(hist.percentile(hist.zeros_np(), 50.0))


def test_percentile_interpolates_within_bucket():
    counts = hist.zeros_np()
    counts[3] = 10                       # bucket [4, 8)
    assert hist.percentile(counts, 50.0) == pytest.approx(6.0)
    assert hist.percentile(counts, 100.0) == pytest.approx(8.0)


def test_percentile_open_last_bucket_reports_lower_bound():
    counts = hist.zeros_np()
    counts[-1] = 4
    assert hist.percentile(counts, 99.0) == hist.bucket_lo(
        hist.NUM_BUCKETS - 1)


def test_percentile_ranks_across_buckets():
    counts = hist.zeros_np()
    counts[1] = 90                       # [1, 2)
    counts[10] = 10                      # [512, 1024)
    assert hist.percentile(counts, 50.0) < 2.0
    assert hist.percentile(counts, 95.0) >= 512.0


def test_merge_and_summary():
    a, b = hist.one_hot_np(1.5), hist.one_hot_np(600.0)
    m = hist.merge(a, b)
    assert hist.total(m) == 2
    s = hist.summary(np.stack([a, b]))   # [S, B] per-lane
    assert s["count"] == 2
    assert len(s["per_row_p50"]) == 2
    assert s["per_row_p50"][0] < 2.0 <= s["per_row_p50"][1]


def test_summarize_extracts_hist_prefixed_counters():
    snap = {"hist_latency_us": hist.one_hot_np(3.0), "queries": 1,
            "hist_scalar_is_ignored": np.int64(7)}
    out = hist.summarize(snap)
    assert set(out) == {"latency_us"}
    assert out["latency_us"]["count"] == 1


# ------------------------------------------- engine totals == flat counters --
def _stream(seed=3, n=72, m=320):
    n, src, dst, w = generators.erdos_renyi(n, m, seed=seed)
    return n, m, window.sliding_window_stream(
        src, dst, w, window=m // 3, delta=0.5, seed=seed, query_every=m // 2)


def _check_totals(eng):
    eng.query()
    snap = eng.metrics_snapshot()
    ct, h = snap["counters"], snap["histograms"]
    assert h["latency_us"]["count"] == ct["queries"]
    assert h["frontier_occupancy"]["count"] == ct["add_epochs"]
    # rounds schedule samples waves/messages at every add+del epoch;
    # bucketed adds defer relaxation, so the drain's sample stands in
    expected = (ct["del_epochs"] + ct["drains"] if "drains" in ct
                else ct["add_epochs"] + ct["del_epochs"])
    assert h["waves_per_epoch"]["count"] == expected, (h, ct)
    assert h["messages_per_epoch"]["count"] == expected, (h, ct)
    for kind, plural in (("add_epoch", "add_epochs"),
                         ("del_epoch", "del_epochs"), ("query", "queries")):
        key = f"{kind}_wall_us"
        if key in h:
            assert h[key]["count"] == ct[plural], (key, h[key], ct)
    # a second snapshot re-reads the same cumulative counts — the lazy
    # flush must not double-fold pending samples
    again = eng.metrics_snapshot()["histograms"]
    assert again["waves_per_epoch"]["count"] == expected
    return snap


@pytest.mark.parametrize("backend", sorted(BACKEND_KW))
@pytest.mark.parametrize("schedule", ["rounds", "buckets"])
def test_single_engine_histogram_totals(backend, schedule):
    n, m, log = _stream()
    eng = SSSPDelEngine(EngineConfig(
        n, 2 * m, 0, relax_backend=backend, wave_schedule=schedule,
        observability=True, **BACKEND_KW[backend]))
    eng.ingest_log(log)
    _check_totals(eng)


@pytest.mark.parametrize("backend", sorted(BACKEND_KW))
def test_sharded_engine_histogram_totals_and_attribution(backend):
    n, m, log = _stream()
    eng = ShardedSSSPDelEngine(ShardedEngineConfig(
        n, 2 * m, 0, relax_backend=backend, observability=True,
        **BACKEND_KW[backend]))
    eng.ingest_log(log)
    snap = _check_totals(eng)
    att = snap["attribution"]["partition"]
    assert int(np.sum(att["adds_per_part"])) == eng.n_adds
    assert int(np.sum(att["dels_per_part"])) == eng.n_dels
    assert int(np.sum(att["frontier_per_part"])) == \
        int(snap["counters"]["frontier"])
    assert "updates_per_part" in att


def test_batched_engine_reports_per_lane_latency_rows():
    n, m, log = _stream()
    eng = ShardedSSSPDelEngine(ShardedEngineConfig(
        n, 2 * m, 0, sources=(0, 1, 2), observability=True))
    eng.ingest_log(log)
    for lane in (0, 2, 2):
        eng.query(source=lane)
    snap = eng.metrics_snapshot()
    rows = np.asarray(
        snap["counters"]["hist_latency_us_per_lane"])
    assert rows.shape == (3, hist.NUM_BUCKETS)
    lane_counts = rows.sum(axis=1)
    assert lane_counts[0] >= 1 and lane_counts[2] >= 2
    att = snap["attribution"]["lane"]
    assert int(np.sum(att["queries_per_lane"])) == int(rows.sum())
    assert "updates_per_lane" in att
