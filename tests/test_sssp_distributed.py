"""Distributed engine tests.

Single-device: the shard_map code paths must produce oracle-exact results on
a trivial mesh (P=1).  Multi-device: a subprocess with 8 forced host devices
runs the full dynamic cycle on a (2,2,2) ("pod","data","model") mesh — the
same axis layout as the production mesh — for both exchange strategies.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.distributed import DistConfig, DistributedSSSP
from repro.core.oracle import dijkstra
from repro.graphs import generators
from repro.launch.mesh import _mk

HERE = os.path.dirname(__file__)


def _single_device_run(exchange: str, delta_cap: int = 32):
    mesh = _mk((1,), ("graph",))
    n_raw, src, dst, w = generators.erdos_renyi(150, 900, seed=4)
    cfg = DistConfig(num_vertices=n_raw, edges_per_part=2048,
                     mesh_axes=("graph",), exchange=exchange,
                     delta_cap=delta_cap)
    ds = DistributedSSSP(mesh, cfg)
    eput = ds.put_edges(*ds.place_edges(src, dst, w))
    dist, parent = ds.init_vertex_arrays(source=0)
    front = ds.frontier_of(np.array([0]))
    epoch = ds.make_relax_epoch()
    dist, parent, rounds = epoch(dist, parent, front, *eput)
    ref, _ = dijkstra(n_raw, src, dst, w, 0)
    np.testing.assert_allclose(np.nan_to_num(ref, posinf=1e30),
                               np.nan_to_num(np.asarray(dist), posinf=1e30),
                               rtol=1e-5)
    return int(rounds)


def test_single_device_allgather_matches_oracle():
    assert _single_device_run("allgather") > 0


def test_single_device_delta_matches_oracle():
    # tiny delta_cap forces both the sparse path and the overflow fallback
    assert _single_device_run("delta", delta_cap=8) > 0


def test_partition_overflow_raises():
    mesh = _mk((1,), ("graph",))
    cfg = DistConfig(num_vertices=16, edges_per_part=2, mesh_axes=("graph",))
    ds = DistributedSSSP(mesh, cfg)
    src = np.zeros(8, np.int64); dst = np.arange(8) % 4; w = np.ones(8, np.float32)
    with pytest.raises(ValueError, match="overflow"):
        ds.place_edges(src, dst, w)


def test_edge_placement_layout():
    mesh = _mk((1,), ("graph",))
    cfg = DistConfig(num_vertices=8, edges_per_part=4, mesh_axes=("graph",))
    ds = DistributedSSSP(mesh, cfg)
    src = np.array([0, 1, 2], np.int64)
    dst = np.array([7, 0, 3], np.int64)
    w = np.ones(3, np.float32)
    es, ed, ew, ea = ds.place_edges(src, dst, w)
    assert ea.sum() == 3
    assert es.shape == (4,)  # P=1, Epp=4


@pytest.mark.parametrize("exchange", ["allgather", "delta"])
def test_multidevice_subprocess(exchange):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    out = subprocess.run(
        [sys.executable, os.path.join(HERE, "_dist_worker.py"), exchange],
        env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert out.stdout.strip().startswith("OK"), out.stdout
