"""Distributed engine tests.

Single-device: the shard_map code paths must produce oracle-exact results on
a trivial mesh (P=1).  Multi-device: a subprocess with 8 forced host devices
runs the full dynamic cycle on a (2,2,2) ("pod","data","model") mesh — the
same axis layout as the production mesh — for both exchange strategies.
"""
import os
import subprocess
import sys

import types

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.distributed import DistConfig, DistributedSSSP
from repro.core.oracle import dijkstra
from repro.graphs import generators
from repro.graphs import partition as part_mod
from repro.launch.mesh import _mk

HERE = os.path.dirname(__file__)


def _single_device_run(exchange: str, delta_cap: int = 32):
    mesh = _mk((1,), ("graph",))
    n_raw, src, dst, w = generators.erdos_renyi(150, 900, seed=4)
    cfg = DistConfig(num_vertices=n_raw, edges_per_part=2048,
                     mesh_axes=("graph",), exchange=exchange,
                     delta_cap=delta_cap)
    ds = DistributedSSSP(mesh, cfg)
    eput = ds.put_edges(*ds.place_edges(src, dst, w))
    dist, parent = ds.init_vertex_arrays(source=0)
    front = ds.frontier_of(np.array([0]))
    epoch = ds.make_relax_epoch()
    dist, parent, rounds = epoch(dist, parent, front, *eput)
    ref, _ = dijkstra(n_raw, src, dst, w, 0)
    np.testing.assert_allclose(np.nan_to_num(ref, posinf=1e30),
                               np.nan_to_num(np.asarray(dist), posinf=1e30),
                               rtol=1e-5)
    return int(rounds)


def test_single_device_allgather_matches_oracle():
    assert _single_device_run("allgather") > 0


def test_single_device_delta_matches_oracle():
    # tiny delta_cap forces both the sparse path and the overflow fallback
    assert _single_device_run("delta", delta_cap=8) > 0


def test_partition_overflow_raises():
    mesh = _mk((1,), ("graph",))
    cfg = DistConfig(num_vertices=16, edges_per_part=2, mesh_axes=("graph",))
    ds = DistributedSSSP(mesh, cfg)
    src = np.zeros(8, np.int64); dst = np.arange(8) % 4; w = np.ones(8, np.float32)
    with pytest.raises(ValueError, match="overflow"):
        ds.place_edges(src, dst, w)


def test_edge_placement_layout():
    mesh = _mk((1,), ("graph",))
    cfg = DistConfig(num_vertices=8, edges_per_part=4, mesh_axes=("graph",))
    ds = DistributedSSSP(mesh, cfg)
    src = np.array([0, 1, 2], np.int64)
    dst = np.array([7, 0, 3], np.int64)
    w = np.ones(3, np.float32)
    es, ed, ew, ea = ds.place_edges(src, dst, w)
    assert ea.sum() == 3
    assert es.shape == (4,)  # P=1, Epp=4


def _fake_ds(P, npp, epp):
    """Host-only stand-in exposing the attributes place_edges reads — lets
    the layout tests cover P>1 bucketing without an 8-device mesh."""
    return types.SimpleNamespace(
        P=P, npp=npp,
        cfg=types.SimpleNamespace(edges_per_part=epp, num_vertices=P * npp))


def test_place_edges_vectorized_matches_loop_reference():
    """The numpy-bucketing placement must reproduce the per-partition copy
    loop it replaced: same slots, same padding rows (DESIGN.md §2.5)."""
    rng = np.random.default_rng(3)
    P, npp, epp, m = 8, 16, 48, 250
    src = rng.integers(0, P * npp, m).astype(np.int64)
    dst = rng.integers(0, P * npp, m).astype(np.int64)
    w = rng.random(m).astype(np.float32)
    got = DistributedSSSP.place_edges(_fake_ds(P, npp, epp), src, dst, w)

    # reference: the original per-partition copy loop
    owner = np.minimum(dst // npp, P - 1)
    order = np.argsort(owner, kind="stable")
    src_s, dst_s, w_s, owner_s = src[order], dst[order], w[order], owner[order]
    ref_src = np.zeros(P * epp, np.int32)
    ref_dst = np.zeros(P * epp, np.int32)
    ref_w = np.zeros(P * epp, np.float32)
    ref_act = np.zeros(P * epp, np.bool_)
    counts = np.bincount(owner_s, minlength=P)
    starts = np.concatenate([[0], np.cumsum(counts)])
    for p in range(P):
        a, b = starts[p], starts[p + 1]
        o = p * epp
        ref_src[o:o + b - a] = src_s[a:b]
        ref_dst[o:o + b - a] = dst_s[a:b]
        ref_w[o:o + b - a] = w_s[a:b]
        ref_act[o:o + b - a] = True
        ref_dst[o + b - a:o + epp] = p * npp
    for g, r in zip(got, (ref_src, ref_dst, ref_w, ref_act)):
        np.testing.assert_array_equal(g, r)

    # empty input: all-padding layout, no crash
    es, ed, ew, ea = DistributedSSSP.place_edges(
        _fake_ds(P, npp, epp), src[:0], dst[:0], w[:0])
    assert not ea.any()
    np.testing.assert_array_equal(
        ed, np.repeat(np.arange(P) * npp, epp))

    # overflow still raises
    with pytest.raises(ValueError, match="overflow"):
        DistributedSSSP.place_edges(
            _fake_ds(P, npp, 2), np.zeros(24, np.int64),
            np.zeros(24, np.int64), np.ones(24, np.float32))


def test_edge_balanced_relabel_roundtrip():
    """Owner/relabel round trip: perm packs each edge-balanced range at its
    partition base, inv inverts it exactly, padding ids are inert (-1)."""
    rng = np.random.default_rng(11)
    n, parts = 113, 8
    # skewed in-degrees so uniform ranges would be badly unbalanced
    dst = (rng.pareto(1.0, 4000) * 7).astype(np.int64) % n
    bounds = part_mod.edge_balanced_ranges(n, dst, parts)
    perm, inv, npp = part_mod.edge_balanced_relabeling(n, dst, parts)
    v = np.arange(n)
    np.testing.assert_array_equal(inv[perm], v)           # exact inverse
    np.testing.assert_array_equal(perm // npp,
                                  part_mod.owner_of(v, bounds))
    assert len(inv) == parts * npp
    assert (inv >= 0).sum() == n                          # padding marked -1
    assert npp == part_mod.pad_ranges_to_equal(bounds)
    # balance: no partition carries more than target + one vertex's degree
    deg = np.bincount(dst, minlength=n)
    mass = np.bincount(perm[dst] // npp, minlength=parts)
    assert mass.max() <= -(-len(dst) // parts) + deg.max()


def test_edge_balanced_relabel_wires_into_placement():
    """Relabeled placement: every edge lands in the partition that owns its
    relabeled dst, and a relaxation epoch on the relabeled graph matches the
    oracle on the original ids."""
    rng = np.random.default_rng(5)
    n_raw, src, dst, w = generators.power_law_hubs(150, 900, seed=5)
    parts = 8
    perm, inv, npp = part_mod.edge_balanced_relabeling(n_raw, dst, parts)
    es, ed, ew, ea = DistributedSSSP.place_edges(
        _fake_ds(parts, npp, 400), perm[src], perm[dst], w)
    live = np.nonzero(ea)[0]
    np.testing.assert_array_equal(live // 400, ed[live] // npp)

    # end-to-end on the (trivial) mesh: relabel, solve, un-relabel, check
    mesh = _mk((1,), ("graph",))
    cfg = DistConfig(num_vertices=len(inv), edges_per_part=4096,
                     mesh_axes=("graph",))
    ds = DistributedSSSP(mesh, cfg)
    eput = ds.put_edges(*ds.place_edges(perm[src], perm[dst], w))
    d, p = ds.init_vertex_arrays(source=int(perm[0]))
    front = ds.frontier_of(np.array([int(perm[0])]))
    d, p, _ = ds.make_relax_epoch()(d, p, front, *eput)
    ref, _ = dijkstra(n_raw, src, dst, w, 0)
    np.testing.assert_allclose(np.nan_to_num(ref, posinf=1e30),
                               np.nan_to_num(np.asarray(d)[perm], posinf=1e30),
                               rtol=1e-5)


@pytest.mark.parametrize("delta_cap", [2, 4096])
def test_delta_overflow_fallback_matches_allgather(delta_cap):
    """Satellite contract: delta_cap exceeded -> dense all_gather fallback
    round.  Either way the delta exchange must equal the allgather strategy
    *exactly* — dist bitwise and parent tie-breaks included.  cap=2 forces
    the overflow fallback nearly every round; cap=4096 stays sparse."""
    mesh = _mk((1,), ("graph",))
    n_raw, src, dst, w = generators.erdos_renyi(150, 900, seed=4)
    out = {}
    for exchange in ("allgather", "delta"):
        cfg = DistConfig(num_vertices=n_raw, edges_per_part=2048,
                         mesh_axes=("graph",), exchange=exchange,
                         delta_cap=delta_cap)
        ds = DistributedSSSP(mesh, cfg)
        eput = ds.put_edges(*ds.place_edges(src, dst, w))
        dist, parent = ds.init_vertex_arrays(source=0)
        front = ds.frontier_of(np.array([0]))
        dist, parent, _ = ds.make_relax_epoch()(dist, parent, front, *eput)

        # deletion epoch on top: drop 3 tree edges, recompute
        par = np.asarray(parent)
        heads = np.nonzero(par >= 0)[0][:3]
        tails = par[heads]
        mask = np.ones(len(src), np.bool_)
        for u, v in zip(tails, heads):
            mask &= ~((src == u) & (dst == v))
        e2 = ds.put_edges(*ds.place_edges(src[mask], dst[mask], w[mask]))
        pad = lambda a: jnp.asarray(np.pad(  # noqa: E731
            a.astype(np.int32), (0, 4 - len(a)), constant_values=-1))
        seed = ds.make_seed_from_deletions()(parent, pad(tails), pad(heads))
        dist, parent, _ = ds.make_delete_epoch()(dist, parent, seed, *e2)
        out[exchange] = (np.asarray(dist), np.asarray(parent))
    np.testing.assert_array_equal(out["allgather"][0], out["delta"][0])
    np.testing.assert_array_equal(out["allgather"][1], out["delta"][1])


@pytest.mark.parametrize("exchange", ["allgather", "delta"])
def test_multidevice_subprocess(exchange):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    out = subprocess.run(
        [sys.executable, os.path.join(HERE, "_dist_worker.py"), exchange],
        env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert out.stdout.strip().startswith("OK"), out.stdout
