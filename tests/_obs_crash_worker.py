"""Subprocess worker: flight-recorder postmortem on the SHARDED path.

Forces an 8-device host mesh, ingests a few healthy epochs through an
instrumented ``ShardedSSSPDelEngine``, then injects a failure into the
backend's add staging so the NEXT ``obs.epoch("add_epoch")`` region sees
an escaping exception.  Asserts the §10.3 contract from inside the dying
process:

  * the exception propagates (telemetry never swallows engine errors);
  * ``dump_on_error`` ran exactly once (``obs._dumped``);
  * the stderr dump carries the injected error AND the healthy epochs
    recorded before it (the parent test re-asserts this on captured
    stderr).

Prints "OK <epochs>" on success.
"""
import os
import sys

# must precede any jax import in this process
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import events as ev  # noqa: E402
from repro.core.dist_engine import (ShardedEngineConfig,  # noqa: E402
                                    ShardedSSSPDelEngine)
from repro.graphs import generators, window  # noqa: E402


def main() -> None:
    assert len(jax.devices()) == 8, \
        f"expected 8 devices, got {len(jax.devices())}"
    n, src, dst, w = generators.erdos_renyi(64, 256, seed=11)
    log = window.sliding_window_stream(src, dst, w, window=len(src) // 2,
                                       delta=0.5, seed=11)
    eng = ShardedSSSPDelEngine(
        ShardedEngineConfig(n, len(src) + 64, 0, observability=True))

    batches = list(log.runs())
    healthy = 0
    for b in batches:
        if b.kind == ev.ADD:
            eng._ingest_adds(b)
            healthy += 1
        elif b.kind == ev.DEL:
            eng._ingest_dels(b)
        if healthy >= 2 and b.kind == ev.ADD:
            break
    assert healthy >= 2, "stream produced too few add batches"

    def boom(*a, **kw):
        raise RuntimeError("injected epoch failure")

    eng.bk.stage_adds = boom
    nxt = next(b for b in batches if b.kind == ev.ADD)
    try:
        eng._ingest_adds(nxt)
    except RuntimeError as exc:
        assert "injected epoch failure" in str(exc), exc
    else:
        raise AssertionError("injected failure did not propagate")

    assert eng.obs._dumped, "dump_on_error did not run"
    # a second failure must not dump again (one-shot)
    try:
        eng._ingest_adds(nxt)
    except RuntimeError:
        pass
    print(f"OK {healthy}")


if __name__ == "__main__":
    main()
