"""Subprocess worker for the telemetry-layer acceptance scenario (P=8).

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 (set by the
parent test process).  Records a power-law hub stream as a serving trace,
replays it through the 8-partition ``ShardedSSSPDelEngine`` under the
bucketed delta-stepping schedule with observability enabled, writes the
span trace as Chrome trace-event JSON to argv[1], reloads it, and asserts
the DESIGN.md §10 contract:

  * the exported trace's span counts equal the live tracer's AND the
    engine's own epoch/drain/rebuild counters (nothing dropped or
    double-counted on the export path);
  * ``metrics_snapshot()`` / ``ServingReport.engine_metrics`` report
    rounds/messages bit-identical to the engine's ``n_rounds`` /
    ``n_messages`` (the §2.4 lazy device scalars are the single source of
    truth — instrumentation reads them, never re-derives them).

Usage: _obs_worker.py <chrome-trace-out.json>
Prints "OK <events> <spans> <rounds>" on success.
"""
import os
import sys

# must precede any jax import in this process
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.dist_engine import (ShardedEngineConfig,  # noqa: E402
                                    ShardedSSSPDelEngine)
from repro.graphs import generators, window  # noqa: E402
from repro.launch.mesh import _mk  # noqa: E402
from repro.obs import load_chrome_trace, span_counts_of  # noqa: E402
from repro.serving import TraceRecorder, replay_trace  # noqa: E402


def main(trace_out: str) -> None:
    assert len(jax.devices()) == 8, \
        f"expected 8 devices, got {len(jax.devices())}"
    mesh = _mk((2, 2, 2), ("pod", "data", "model"))
    n, src, dst, w = generators.power_law_hubs(120, 700, n_hubs=4, seed=23,
                                               orientation="in")
    source = int(generators.top_in_degree_sources(n, dst, 1)[0])
    log = window.sliding_window_stream(src, dst, w, window=len(src) // 3,
                                       delta=0.6, seed=23,
                                       query_every=len(src) // 4)
    rec = TraceRecorder()
    rec.extend_from_log(log)
    trace = rec.trace()

    eng = ShardedSSSPDelEngine(
        ShardedEngineConfig(n, len(src) + 64, source,
                            wave_schedule="buckets", bucket_width=1.0,
                            relax_backend="sliced", sliced_slice_rows=8,
                            sliced_hub_k=4, sliced_init_k=1,
                            observability=True),
        mesh=mesh)
    report = replay_trace(eng, trace)

    # export -> reload roundtrip: the Chrome trace must carry exactly the
    # spans the live tracer recorded
    eng.obs.tracer.save_chrome(trace_out)
    events = load_chrome_trace(trace_out)
    sp = eng.obs.tracer.span_counts()
    assert span_counts_of(events) == sp, (span_counts_of(events), sp)

    # span counts == the engine's own epoch/drain/rebuild counters
    ct = eng.metrics_snapshot()["counters"]
    assert sp["add_epoch"] == ct["add_epochs"], (sp, ct)
    assert sp["del_epoch"] == ct["del_epochs"], (sp, ct)
    assert sp["add_epoch"] + sp["del_epoch"] == eng.n_epochs
    assert sp.get("drain", 0) == ct.get("drains", 0), (sp, ct)
    assert sp.get("query", 0) == ct.get("queries", 0) == report.queries
    assert sp.get("rebuild", 0) == ct.get("rebuilds", 0), (sp, ct)
    assert ct.get("rebuilds", 0) > 0, "tiny sliced knobs must rebuild"

    # metrics_snapshot / engine_metrics rounds+messages == the §2.4 lazy
    # device stats, bit for bit
    em = report.engine_metrics
    assert int(em["rounds"]) == int(eng.n_rounds), (em, eng.n_rounds)
    assert int(em["messages"]) == int(eng.n_messages)
    snap = eng.metrics_snapshot()
    assert int(snap["rounds"]) == int(eng.n_rounds)
    assert int(snap["messages"]) == int(eng.n_messages)

    # §10.6 histogram totals == the flat counters they shadow (bucketed
    # schedule: waves/messages sample at dels + drains, adds defer)
    h = snap["histograms"]
    assert h["latency_us"]["count"] == ct["queries"], (h, ct)
    assert h["frontier_occupancy"]["count"] == ct["add_epochs"], (h, ct)
    exp = ct["del_epochs"] + ct["drains"]
    assert h["waves_per_epoch"]["count"] == exp, (h, ct)
    assert h["messages_per_epoch"]["count"] == exp, (h, ct)

    # §10.5 per-partition attribution sums == engine totals
    import numpy as np
    att = snap["attribution"]["partition"]
    assert int(np.sum(att["adds_per_part"])) == eng.n_adds, att
    assert int(np.sum(att["dels_per_part"])) == eng.n_dels, att
    assert "updates_per_part" in att and "frontier_per_part" in att, att

    # serving report per-source split (§10.6): one source here, so the
    # cold/warm split must account for every query
    cw = report.cold_warm
    assert cw is not None and cw["cold_queries"] >= 1, cw
    assert cw["cold_queries"] + cw["warm_queries"] == report.queries, cw

    print(f"OK {len(events)} {sum(sp.values())} {eng.n_rounds}")


if __name__ == "__main__":
    main(sys.argv[1])
