"""Roofline HLO analyzer unit tests: trip-count multiplication, dot flops,
collective wire models, dynamic-slice byte accounting — validated against a
live compiled module (8 forced devices would pollute this process's device
count, so the live check uses the single real device; the collective parsing
is tested on a synthetic HLO snippet)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline import hlo_analysis as H
from repro.roofline import report as R


def test_scan_trip_count_multiplication():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    x = jnp.ones((32, 64)); w = jnp.ones((64, 64))
    comp = jax.jit(f).lower(x, w).compile()
    cost = H.analyze_text(comp.as_text())
    expected_dots = 7 * 2 * 32 * 64 * 64
    assert cost.flops >= expected_dots
    assert cost.flops < expected_dots * 1.5  # elementwise tanh etc. only
    # XLA's own analysis counts the body once — ours must exceed it
    ca = comp.cost_analysis()  # older jax returns a 1-element list
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    xla_flops = ca.get("flops", 0)
    assert cost.flops > xla_flops * 3


def test_dynamic_slice_reads_slice_not_buffer():
    def f(big, i):
        def body(c, idx):
            return c + jax.lax.dynamic_slice(big, (idx, 0), (1, 64))[0], None
        y, _ = jax.lax.scan(body, jnp.zeros(64), jnp.arange(16))
        return y

    big = jnp.ones((1024, 64))
    comp = jax.jit(f).lower(big, 0).compile()
    cost = H.analyze_text(comp.as_text())
    # 16 iterations x O(slice) bytes, NOT 16 x 256KB buffer
    assert cost.hbm_bytes < 16 * big.nbytes / 4


_SYNTH = """
HloModule synth, entry_computation_layout={()->f32[]}, num_partitions=8

ENTRY %main_spmd (p: f32[128,256]) -> f32[128,256] {
  %p = f32[128,256]{1,0} parameter(0)
  %ag = f32[128,2048]{1,0} all-gather(%p), channel_id=1, replica_groups=[1,8]<=[8], dimensions={1}
  %ar = f32[128,256]{1,0} all-reduce(%p), channel_id=2, replica_groups=[2,4]<=[8], to_apply=%add
  %cp = f32[128,256]{1,0} collective-permute(%p), channel_id=3, source_target_pairs={{0,1}}
  ROOT %out = f32[128,256]{1,0} add(%ar, %cp)
}
"""


def test_collective_wire_models():
    cost = H.analyze_text(_SYNTH, num_partitions=8)
    b = 128 * 256 * 4
    # all-gather: result bytes x (g-1)/g with g=8
    ag = 128 * 2048 * 4 * (7 / 8)
    # all-reduce: 2 x operand x (g-1)/g with g=4
    ar = 2 * b * (3 / 4)
    cp = b
    assert abs(cost.coll_wire_bytes - (ag + ar + cp)) < 1.0
    assert cost.coll_by_type["all-gather"] == b  # operand bytes
    assert cost.coll_operand_bytes == 3 * b


def test_roofline_terms_and_dominant():
    rf = R.roofline_from_text(_SYNTH, num_partitions=8)
    assert rf.collective_s > 0
    assert rf.dominant in ("compute", "memory", "collective")
    assert rf.bound_s == max(rf.compute_s, rf.memory_s, rf.collective_s)
    frac = rf.roofline_fraction(1e12, 8)
    assert 0 <= frac


def test_shape_parsing():
    assert H._shape_bytes("bf16[16,128]{1,0}") == 16 * 128 * 2
    assert H._shape_bytes("(f32[4]{0}, s32[])") == 20
    assert H._shape_elems("pred[3,3]") == 9
    assert H._first_shape_dims("f32[7,9]{1,0}") == [7, 9]
