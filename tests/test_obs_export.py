"""Metrics export surface (DESIGN.md §10.7): Prometheus text, JSONL,
and the stdlib HTTP endpoint.

The round-trip contract: everything the renderer emits parses back
bit-equal through ``parse_prometheus_text`` — scalar counters, the
dimension-labeled attribution vectors, native histogram ``_bucket``
series (cumulative, ending in ``+Inf``) whose final count equals the
engine's flat counter, and the p50/p95/p99 gauges.  The HTTP server is
exercised over a real socket with stdlib urllib only.
"""
import json
import math
import urllib.request

import numpy as np
import pytest

from repro.core.engine import EngineConfig, SSSPDelEngine
from repro.core.dist_engine import ShardedEngineConfig, ShardedSSSPDelEngine
from repro.graphs import generators, window
from repro.obs import hist
from repro.obs.export import (JsonlMetricsWriter, MetricsServer,
                              parse_prometheus_text, prometheus_text,
                              write_prometheus)


def _engine(sharded=False):
    n, src, dst, w = generators.erdos_renyi(64, 256, seed=9)
    log = window.sliding_window_stream(src, dst, w, window=128, delta=0.5,
                                       seed=9, query_every=128)
    cls, cfg = ((ShardedSSSPDelEngine, ShardedEngineConfig) if sharded
                else (SSSPDelEngine, EngineConfig))
    eng = cls(cfg(n, len(src) + 64, 0, observability=True))
    eng.ingest_log(log)
    eng.query()
    return eng


# ----------------------------------------------------------- text renderer --
def test_prometheus_text_round_trips_scalars_and_histograms():
    eng = _engine()
    snap = eng.metrics_snapshot()
    parsed = parse_prometheus_text(prometheus_text(snap))

    for key in ("epochs", "rounds", "messages"):
        assert parsed[f"repro_{key}"][()] == float(snap[key])
    for name, value in snap["counters"].items():
        if np.ndim(value) == 0:
            assert parsed[f"repro_{name}"][()] == float(value)

    # histogram: cumulative buckets end at +Inf and _count == the total
    ct = snap["counters"]
    buckets = parsed["repro_hist_latency_us_bucket"]
    les = sorted(float(k[0][1]) if k[0][1] != "+Inf" else math.inf
                 for k in buckets)
    assert len(les) == hist.NUM_BUCKETS and les[-1] == math.inf
    cums = [v for _, v in sorted(
        buckets.items(),
        key=lambda kv: float(kv[0][0][1]) if kv[0][0][1] != "+Inf"
        else math.inf)]
    assert cums == sorted(cums)          # cumulative: monotone
    assert parsed["repro_hist_latency_us_count"][()] == float(ct["queries"])

    # percentile gauges ride along
    assert "repro_latency_us_p50" in parsed


def test_prometheus_labels_carry_attribution_dims():
    eng = _engine(sharded=True)
    snap = eng.metrics_snapshot()
    parsed = parse_prometheus_text(prometheus_text(snap))
    series = parsed["repro_adds_per_part"]
    P = len(snap["attribution"]["partition"]["adds_per_part"])
    assert set(series) == {(("partition", str(i)),) for i in range(P)}
    assert sum(series.values()) == float(eng.n_adds)


def test_prometheus_inf_nan_formatting():
    from repro.obs.export import _fmt
    assert _fmt(math.inf) == "+Inf" and _fmt(-math.inf) == "-Inf"
    assert _fmt(float("nan")) == "NaN"
    assert _fmt(3.0) == "3" and _fmt(2.5) == "2.5"
    t = parse_prometheus_text('m_bucket{le="+Inf"} 4\nm2 NaN\n')
    assert t["m_bucket"][(("le", "+Inf"),)] == 4.0
    assert math.isnan(t["m2"][()])


def test_write_prometheus_file(tmp_path):
    eng = _engine()
    path = str(tmp_path / "metrics.prom")
    write_prometheus(path, eng.metrics_snapshot())
    parsed = parse_prometheus_text(open(path).read())
    assert parsed["repro_epochs"][()] == float(eng.n_epochs)


# ------------------------------------------------------------------- JSONL --
def test_jsonl_writer_appends_sequenced_snapshots(tmp_path):
    eng = _engine()
    path = str(tmp_path / "metrics.jsonl")
    wr = JsonlMetricsWriter(path, eng.metrics_snapshot)
    wr.dump()
    eng.query()
    wr.dump()
    lines = [json.loads(ln) for ln in open(path)]
    assert [ln["seq"] for ln in lines] == [0, 1]
    q0 = lines[0]["metrics"]["counters"]["queries"]
    q1 = lines[1]["metrics"]["counters"]["queries"]
    assert q1 == q0 + 1
    # everything is plain JSON — histograms included
    assert lines[1]["metrics"]["histograms"]["latency_us"]["count"] == q1


# -------------------------------------------------------------------- HTTP --
def test_metrics_server_serves_text_and_json():
    eng = _engine()
    srv = MetricsServer(eng.metrics_snapshot, port=0)
    try:
        body = urllib.request.urlopen(srv.url, timeout=10).read().decode()
        parsed = parse_prometheus_text(body)
        assert parsed["repro_epochs"][()] == float(eng.n_epochs)
        jurl = srv.url.rsplit("/", 1)[0] + "/metrics.json"
        js = json.loads(
            urllib.request.urlopen(jurl, timeout=10).read().decode())
        assert js["counters"]["queries"] == \
            parsed["repro_queries"][()]
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                srv.url.rsplit("/", 1)[0] + "/nope", timeout=10)
    finally:
        srv.close()
