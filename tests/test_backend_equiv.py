"""Relaxation-backend equivalence: every registered RelaxBackend must be a
drop-in for the segment backend — bit-identical (dist, parent) on any
dynamic stream, and all must satisfy the Dijkstra oracle at every query
point (DESIGN.md §2.2, §6, §7).

The sweep crosses backend-relevant switches (doubling vs flood invalidation,
batched vs per-event deletions) and runs with deliberately tiny initial ELL
widths / hub thresholds so the capacity-doubling rebuild path (dense), the
per-slice doubling rebuilds AND the hub overflow-spill path (sliced) are all
exercised repeatedly.

The same contract extends across the *partition-count* axis: the sharded
engine (core/dist_engine.py, DESIGN.md §5/§7.2) must be bit-identical to
every single-device backend on the same streams — P=1 here, P=8 forced host
devices in tests/test_dist_engine.py.
"""
import numpy as np
import pytest

from repro.core import events as ev
from repro.core.backends import EllpackBackend, SlicedBackend
from repro.core.dist_engine import ShardedEngineConfig, ShardedSSSPDelEngine
from repro.core.engine import EngineConfig, SSSPDelEngine
from repro.core.oracle import check_tree, edges_of_pool
from repro.graphs import generators, window


# tiny hub threshold + slice rows: many slices, frequent spills & rebuilds
SLICED_KW = dict(sliced_slice_rows=32, sliced_hub_k=4, sliced_init_k=1)
# per-backend construction kwargs (backend knobs only apply to their
# backend — EngineConfig validation enforces it)
BACKEND_KW = {
    "segment": {},
    # ell_init_k=2 forces the capacity-doubling rebuild path several times
    "ellpack": dict(ell_init_k=2),
    "sliced": SLICED_KW,
}


def _dynamic_stream(seed: int, *, n=90, m=520, delta=0.6):
    n, src, dst, w = generators.erdos_renyi(n, m, seed=seed)
    log = window.sliding_window_stream(src, dst, w, window=m // 3,
                                       delta=delta, seed=seed,
                                       query_every=m // 2)
    return n, len(src), log


def _run(backend: str, n: int, cap: int, log, source: int, *,
         use_doubling: bool, batch_deletions: bool, **kw) -> SSSPDelEngine:
    eng = SSSPDelEngine(EngineConfig(
        n, cap + 64, source, relax_backend=backend,
        use_doubling=use_doubling, batch_deletions=batch_deletions, **kw))
    eng.ingest_log(log)
    return eng


def _oracle_check(eng: SSSPDelEngine, n: int, source: int):
    q = eng.query()
    e = eng.state.edges
    es, ed, ew = edges_of_pool(e.src, e.dst, e.w, e.active)
    check_tree(n, es, ed, ew, source, q.dist, q.parent)
    bk = eng.backend
    for k, ok in bk.invariants().items():
        assert bool(ok), f"{bk.name} invariant violated: {k}"
    if isinstance(bk, (EllpackBackend, SlicedBackend)):
        # the device fill marks must track the host planner's exactly
        np.testing.assert_array_equal(np.asarray(bk.state.fill),
                                      bk.planner.fill)
    return q


@pytest.mark.parametrize("use_doubling", [False, True])
@pytest.mark.parametrize("batch_deletions", [False, True])
def test_backends_bit_identical_on_dynamic_stream(use_doubling, batch_deletions):
    n, m, log = _dynamic_stream(seed=11 + 2 * use_doubling + batch_deletions)
    source = 3
    ell = _run("ellpack", n, m, log, source, use_doubling=use_doubling,
               batch_deletions=batch_deletions, **BACKEND_KW["ellpack"])
    seg = _run("segment", n, m, log, source, use_doubling=use_doubling,
               batch_deletions=batch_deletions)
    sld = _run("sliced", n, m, log, source, use_doubling=use_doubling,
               batch_deletions=batch_deletions, **BACKEND_KW["sliced"])
    q_ell = _oracle_check(ell, n, source)
    q_seg = _oracle_check(seg, n, source)
    q_sld = _oracle_check(sld, n, source)
    np.testing.assert_array_equal(q_seg.dist, q_ell.dist)
    np.testing.assert_array_equal(q_seg.parent, q_ell.parent)
    np.testing.assert_array_equal(q_seg.dist, q_sld.dist)
    np.testing.assert_array_equal(q_seg.parent, q_sld.parent)
    # same waves, same improvements — the stats must agree too
    assert seg.n_rounds == ell.n_rounds == sld.n_rounds
    assert seg.n_messages == ell.n_messages == sld.n_messages
    assert ell.backend.planner.rebuilds >= 1, "rebuild path not exercised"
    assert sld.backend.planner.rebuilds >= 1, \
        "sliced rebuild path not exercised"
    assert sld.backend.planner.spills >= 1, \
        "hub overflow-spill path not exercised"


@pytest.mark.parametrize("backend", ["segment", "ellpack", "sliced"])
def test_sharded_engine_joins_the_equivalence_contract(backend):
    """Partition axis: every backend, sharded (P=1) vs single-device — same
    dist, parent, and wave stats on the same dynamic stream (DESIGN.md
    §5.4/§7.2); and all sharded backends equal the single-device segment
    engine transitively."""
    n, m, log = _dynamic_stream(seed=11)
    source = 3
    kw = BACKEND_KW[backend]
    seg = _run("segment", n, m, log, source,
               use_doubling=True, batch_deletions=False)
    shd = ShardedSSSPDelEngine(ShardedEngineConfig(
        n, m + 64, source, relax_backend=backend, **kw))
    shd.ingest_log(log)
    q_seg, q_shd = seg.query(), shd.query()
    np.testing.assert_array_equal(q_seg.dist, q_shd.dist)
    np.testing.assert_array_equal(q_seg.parent, q_shd.parent)
    assert seg.n_rounds == shd.n_rounds
    assert seg.n_messages == shd.n_messages


@pytest.mark.parametrize("backend", ["segment", "ellpack", "sliced"])
@pytest.mark.parametrize("mode", ["sparse", "auto"])
@pytest.mark.parametrize("schedule", ["rounds", "buckets"])
def test_frontier_modes_join_the_equivalence_contract(backend, mode,
                                                      schedule):
    """Frontier axis (DESIGN.md §12): the compacted sparse path is one
    shared backend-independent implementation, so it must keep every
    backend inside the bit-identity contract — same (dist, parent) and
    wave stats as that backend's dense run, under both wave schedules.
    ``frontier_cap=16`` keeps both ladder rungs AND the in-cond dense
    fallback exercised on these streams."""
    n, m, log = _dynamic_stream(seed=41)
    source = 3
    kw = dict(BACKEND_KW[backend], wave_schedule=schedule)
    dense = _run(backend, n, m, log, source, use_doubling=True,
                 batch_deletions=False, **kw)
    sparse = _run(backend, n, m, log, source, use_doubling=True,
                  batch_deletions=False, frontier_mode=mode,
                  frontier_cap=16, **kw)
    q_d = _oracle_check(dense, n, source)
    q_s = _oracle_check(sparse, n, source)
    np.testing.assert_array_equal(q_d.dist, q_s.dist)
    np.testing.assert_array_equal(q_d.parent, q_s.parent)
    assert dense.n_rounds == sparse.n_rounds
    assert dense.n_messages == sparse.n_messages


def test_backends_identical_parents_under_pervasive_ties():
    """Unit weights make equal-cost predecessors pervasive (paper §5.4); the
    smallest-src-id rule must make all backends pick the same parent."""
    n, src, dst, w = generators.erdos_renyi(100, 900, seed=21)
    w = np.ones_like(w)
    log = window.sliding_window_stream(src, dst, w, window=300, delta=0.5,
                                       seed=21, query_every=400)
    res = {}
    for backend in ("segment", "ellpack", "sliced"):
        eng = SSSPDelEngine(EngineConfig(n, len(src) + 64, 2,
                                         relax_backend=backend,
                                         **BACKEND_KW[backend]))
        eng.ingest_log(log)
        res[backend] = _oracle_check(eng, n, 2)
    for backend in ("ellpack", "sliced"):
        np.testing.assert_array_equal(res["segment"].dist, res[backend].dist)
        np.testing.assert_array_equal(res["segment"].parent,
                                      res[backend].parent)


def test_capacity_doubling_under_degree_growth():
    """A hub whose in-degree doubles batch over batch must force repeated
    capacity-doubling rebuilds, each preserving oracle-exactness."""
    n, hub = 130, 0
    eng = SSSPDelEngine(EngineConfig(n, 512, 1, relax_backend="ellpack",
                                     ell_init_k=2))
    eng.ingest_log(ev.adds([1], [hub], [10.0]))
    k_seen = {eng.backend.planner.k}
    nxt = 2
    for size in (4, 8, 16, 32, 64):
        tails = np.arange(nxt, nxt + size)
        nxt += size
        eng.ingest_log(ev.adds([1] * size, tails, [1.0] * size))  # reach tails
        eng.ingest_log(ev.adds(tails, [hub] * size,
                               np.linspace(2.0, 3.0, size)))
        k_seen.add(eng.backend.planner.k)
        _oracle_check(eng, n, 1)
    assert eng.backend.planner.rebuilds >= 3
    assert len(k_seen) >= 3, f"ELL width never doubled: {sorted(k_seen)}"


def test_ellpack_oracle_at_every_query_point():
    n, m, log = _dynamic_stream(seed=5, delta=0.8)
    eng = SSSPDelEngine(EngineConfig(n, m + 64, 0, relax_backend="ellpack",
                                     ell_init_k=2))
    for batch in log.runs():
        if batch.kind == ev.ADD:
            eng._ingest_adds(batch)
        elif batch.kind == ev.DEL:
            eng._ingest_dels(batch)
        else:
            _oracle_check(eng, n, 0)
    _oracle_check(eng, n, 0)


def test_ellpack_min_duplicate_policy_matches_segment():
    # repeated adds of the same edge with shrinking weights must propagate
    # as weight-decreases under on_duplicate="min" in all backends
    n = 8
    tiny = {"segment": {},
            "ellpack": dict(ell_init_k=2),
            "sliced": dict(sliced_slice_rows=4, sliced_hub_k=2,
                           sliced_init_k=1)}
    res = {}
    for backend in ("segment", "ellpack", "sliced"):
        eng = SSSPDelEngine(EngineConfig(
            n, 32, 0, relax_backend=backend, on_duplicate="min",
            **tiny[backend]))
        eng.ingest_log(ev.adds([0, 1, 0, 0], [1, 2, 2, 1],
                               [4.0, 1.0, 9.0, 2.0]))
        eng.ingest_log(ev.adds([0], [1], [1.0]))   # decrease 0->1 to 1.0
        eng.ingest_log(ev.adds([0], [2], [20.0]))  # increase is dropped
        res[backend] = _oracle_check(eng, n, 0)
    for backend in ("ellpack", "sliced"):
        np.testing.assert_array_equal(res["segment"].dist, res[backend].dist)
        np.testing.assert_array_equal(res["segment"].parent,
                                      res[backend].parent)
    assert res["segment"].dist[2] == pytest.approx(2.0)


@pytest.mark.parametrize("backend", ["ellpack", "sliced"])
def test_ell_backends_checkpoint_restore_roundtrip(backend):
    n, m, log = _dynamic_stream(seed=9)
    kw = BACKEND_KW[backend]
    eng = SSSPDelEngine(EngineConfig(n, m + 64, 0, relax_backend=backend,
                                     **kw))
    half = len(log) // 2
    eng.ingest_log(log[:half])
    ckpt = eng.checkpoint()
    eng.ingest_log(log[half:])
    want = eng.query()

    eng2 = SSSPDelEngine(EngineConfig(n, m + 64, 0, relax_backend=backend,
                                      **{k: v for k, v in kw.items()
                                         if not k.startswith("ell_")}))
    eng2.restore(ckpt)
    eng2.ingest_log(log[half:])
    got = eng2.query()
    np.testing.assert_array_equal(want.dist, got.dist)
    np.testing.assert_array_equal(want.parent, got.parent)
    _oracle_check(eng2, n, 0)


def test_arch_config_bridges_backend_selection():
    import dataclasses
    from repro.configs import sssp_del as c_sssp
    arch = dataclasses.replace(c_sssp.REDUCED, relax_backend="ellpack",
                               num_vertices=64, ell_init_k=2)
    eng = arch.make_engine(edge_capacity=256, source=0)
    assert isinstance(eng, SSSPDelEngine)
    assert isinstance(eng.backend, EllpackBackend)
    eng.ingest_log(ev.adds([0, 1, 2], [1, 2, 3], [1.0, 1.0, 1.0]))
    _oracle_check(eng, 64, 0)
    sh = dataclasses.replace(arch, edges_per_part=256) \
        .make_engine(partitions=1, source=0)
    assert sh.cfg.relax_backend == "ellpack" and sh.cfg.ell_init_k == 2
    assert sh.cfg.edges_per_part == 256 and sh.P == 1


def test_arch_config_deprecated_bridges_warn_but_work():
    """engine_config / sharded_engine_config stay as thin shims that point
    at make_engine (DESIGN.md §11.5)."""
    import dataclasses
    import warnings
    from repro.configs import sssp_del as c_sssp
    arch = dataclasses.replace(c_sssp.REDUCED, num_vertices=64,
                               edges_per_part=256)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        cfg = arch.engine_config(edge_capacity=256, source=0)
        sh_cfg = arch.sharded_engine_config(source=0)
    assert [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert cfg.num_vertices == 64 and sh_cfg.edges_per_part == 256


@pytest.mark.parametrize("backend", ["ellpack", "sliced"])
def test_ell_backends_non_tree_deletion_is_free(backend):
    n = 6
    eng = SSSPDelEngine(EngineConfig(n, 64, 0, relax_backend=backend,
                                     **BACKEND_KW[backend]))
    eng.ingest_log(ev.adds([0, 0, 1], [1, 2, 2], [1.0, 1.0, 5.0]))
    rounds_before = eng.n_rounds
    eng.ingest_log(ev.dels([1], [2]))  # not a tree edge (0->2 is shorter)
    assert eng.n_rounds == rounds_before  # stats stay zero without a host sync
    _oracle_check(eng, n, 0)


def test_backends_bit_identical_on_power_law_hub_stream():
    """The sliced backend's home turf (DESIGN.md §6): a mixed ADD/DEL/QUERY
    stream over in-degree power-law hubs, where dense ELL's global K blows
    up and hub rows run through BOTH lanes (slice cells + overflow).  All
    three backends must stay bit-identical in (dist, parent) and stats, and
    the unit weights make equal-cost predecessors pervasive."""
    n, m = 128, 1100
    nv, src, dst, w = generators.power_law_hubs(n, m, n_hubs=3, seed=31,
                                                orientation="in")
    source = int(np.bincount(dst, minlength=nv).argmax())  # a hub
    log = window.sliding_window_stream(src, dst, w, window=len(src) // 3,
                                       delta=0.5, seed=31,
                                       query_every=len(src) // 2)
    hub_kw = {"segment": {},
              "ellpack": dict(ell_init_k=2),
              "sliced": dict(sliced_slice_rows=32, sliced_hub_k=8,
                             sliced_init_k=1)}
    res = {}
    for backend in ("segment", "ellpack", "sliced"):
        eng = SSSPDelEngine(EngineConfig(
            nv, len(src) + 64, source, relax_backend=backend,
            **hub_kw[backend]))
        eng.ingest_log(log)
        res[backend] = (_oracle_check(eng, nv, source), eng)
    q_seg, seg = res["segment"]
    for backend in ("ellpack", "sliced"):
        q, eng = res[backend]
        np.testing.assert_array_equal(q_seg.dist, q.dist)
        np.testing.assert_array_equal(q_seg.parent, q.parent)
        assert seg.n_rounds == eng.n_rounds
        assert seg.n_messages == eng.n_messages
    sld = res["sliced"][1].backend
    assert sld.planner.spills >= 1 or sld.planner.ofill > 0, \
        "hub stream never touched the overflow lane"
    # the hybrid stores far fewer device values than the dense block it
    # replaces (ELL cell = idx+w, overflow entry = src+dst+w)
    dense_vals = 2 * res["ellpack"][1].backend.state.nbr_w.size
    hybrid_vals = 2 * sld.state.flat_w.size + 3 * sld.state.ow.size
    assert hybrid_vals < dense_vals, (hybrid_vals, dense_vals)
