"""repro — dynamic distributed SSSP (the paper's SSSP-Del) in JAX.

Stable public surface (DESIGN.md §11.5).  Downstream code should import
from here instead of reaching into ``repro.core.*`` module paths:

    import repro
    eng = repro.make_engine(num_vertices=n, edge_capacity=m, source=0)
    report = repro.replay_trace(eng, repro.open_trace("trace.npz"))

Attributes resolve lazily (PEP 562) so ``import repro`` stays cheap and
never initializes jax device state by itself.
"""
from __future__ import annotations

__all__ = [
    "EngineConfig",
    "ServingTrace",
    "ShardedEngineConfig",
    "ShardedSSSPDelEngine",
    "SSSPDelEngine",
    "TraceReader",
    "TraceRecorder",
    "dataset_to_trace",
    "load_dataset_or_exit",
    "make_engine",
    "open_trace",
    "replay_trace",
]

_EXPORTS = {
    "EngineConfig": ("repro.core.engine", "EngineConfig"),
    "SSSPDelEngine": ("repro.core.engine", "SSSPDelEngine"),
    "ShardedEngineConfig": ("repro.core.dist_engine", "ShardedEngineConfig"),
    "ShardedSSSPDelEngine": ("repro.core.dist_engine",
                             "ShardedSSSPDelEngine"),
    "make_engine": ("repro.core.factory", "make_engine"),
    "ServingTrace": ("repro.serving.trace", "ServingTrace"),
    "TraceReader": ("repro.serving.trace", "TraceReader"),
    "TraceRecorder": ("repro.serving.trace", "TraceRecorder"),
    "open_trace": ("repro.serving.trace", "open_trace"),
    "replay_trace": ("repro.serving.replay", "replay_trace"),
    "dataset_to_trace": ("repro.graphs.datasets", "dataset_to_trace"),
    "load_dataset_or_exit": ("repro.graphs.datasets", "load_dataset_or_exit"),
}


def __getattr__(name: str):
    try:
        mod_name, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None
    import importlib
    value = getattr(importlib.import_module(mod_name), attr)
    globals()[name] = value  # cache: next access skips __getattr__
    return value


def __dir__() -> list[str]:
    return sorted(set(globals()) | set(__all__))
