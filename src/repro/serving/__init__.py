"""Serving layer (DESIGN.md §8): batched multi-source SSSP serving over the
dynamic engines, workload-trace record/replay, and the paper's serving
metrics (result latency, solution stability, event throughput).

The batched multi-source *state* itself lives in the engines
(``EngineConfig(sources=...)`` / ``ShardedEngineConfig(sources=...)``,
core/engine.py, core/dist_engine.py); this package provides the workload
side: the on-disk trace format, the deterministic replayer, and the
``ServingReport`` metrics harness every scaling PR (query routing, caching,
admission control) plugs into.
"""
from repro.serving.metrics import (ServingReport, churn, pctile,
                                   percentiles)
from repro.serving.replay import replay_trace
from repro.serving.trace import (TRACE_MAGIC, TRACE_VERSION, ServingTrace,
                                 TraceFormatError, TraceRecorder,
                                 load_trace_or_exit)

__all__ = [
    "ServingReport", "ServingTrace", "TraceFormatError", "TraceRecorder",
    "TRACE_MAGIC", "TRACE_VERSION", "churn", "load_trace_or_exit",
    "pctile", "percentiles", "replay_trace",
]
