"""Trace replayer: drive any dynamic engine from a recorded trace and
measure the paper's serving metrics along the way (DESIGN.md §8).

Deterministic by construction — the trace fixes the event order, the
engines' epochs are deterministic, so two replays of the same trace on
equivalently configured engines produce bit-identical results
(tests/test_serving.py round-trip test).

Query routing: a QUERY row carrying source ``s`` is answered from lane
``s`` of a batched multi-source engine (only that lane's [N] snapshot is
read back).  On a single-source engine the trace's query sources select
nothing — the engine serves its one tree — which is exactly what the
sequential-baseline comparison in the ``serving`` bench section needs.

``pace=True`` honors the trace's inter-event gaps (sleeping until each
batch's first timestamp) to model offered load instead of max-speed
replay; throughput then reflects the trace's rate, not the engine's.
"""
from __future__ import annotations

import time
from typing import Callable

from repro.core import events as ev
from repro.core.stream import QueryResult, StreamEngineBase
from repro.obs import hist as hist_mod
from repro.serving.metrics import (ServingReport, churn, hist_merge,
                                   hist_percentile, percentiles)
from repro.serving.trace import ServingTrace, TraceReader


def _engine_label(engine: StreamEngineBase) -> str:
    kind = ("sharded" if type(engine).__name__.startswith("Sharded")
            else "single")
    return f"{kind}/{getattr(engine.cfg, 'relax_backend', '?')}"


def replay_trace(engine: StreamEngineBase,
                 trace: ServingTrace | TraceReader, *,
                 pace: bool = False,
                 on_query: Callable[[QueryResult], None] | None = None
                 ) -> ServingReport:
    """Replay ``trace`` through ``engine``; returns the ``ServingReport``.

    ``trace`` may be an in-memory ``ServingTrace`` or a streaming
    ``TraceReader`` (serving/trace.py): the replay loop consumes one chunk
    at a time, so peak host memory is O(chunk) + the engine's own state,
    never O(stream).  A run of consecutive ADDs (or DELs) that straddles a
    chunk boundary ingests as two batches — the converged (dist, parent)
    is identical (insertion is order-free, deletions are per-event unless
    ``batch_deletions``), only epoch counters may differ from a monolithic
    replay.

    Latency comes from each ``QueryResult.latency_s`` (the snapshot
    readback timed in ``StreamEngineBase.query``).  Churn compares each
    query's (dist, parent) against the PREVIOUS snapshot of the same scope
    — per lane for routed queries, the full stack otherwise — so the first
    observation of a scope contributes no churn sample.  Throughput is
    topology events over the whole replay wall-clock.
    """
    chunks = (trace.chunks() if isinstance(trace, TraceReader)
              else iter((trace,)))
    latencies: list[float] = []
    churns: list[dict[str, float]] = []
    prev: dict[object, tuple] = {}
    # per-tenant latency histograms (§10.6 log2 buckets, microseconds) +
    # each scope's exact first-query (cold) latency — the cold/warm split
    lat_hists: dict[object, "hist_mod.np.ndarray"] = {}
    cold_s: dict[object, float] = {}
    n_queries = 0
    n_events = 0
    n_topo = 0
    t_first: float | None = None
    t0 = time.perf_counter()
    for piece in chunks:
        if len(piece) == 0:
            continue
        if t_first is None:
            t_first = float(piece.t[0])
        n_events += len(piece)
        n_topo += piece.n_topology
        log = piece.to_log()
        cursor = 0
        for batch in log.runs():
            if pace:
                lag = float(piece.t[cursor] - t_first) \
                    - (time.perf_counter() - t0)
                if lag > 0:
                    time.sleep(lag)
            if batch.kind == ev.ADD:
                engine._ingest_adds(batch)
                cursor += len(batch)
            elif batch.kind == ev.DEL:
                engine._ingest_dels(batch)
                cursor += len(batch)
            else:
                res = engine.query(
                    source=engine.route_of(batch.query_source))
                n_queries += 1
                cursor += 1
                latencies.append(res.latency_s)
                key = res.source if res.source is not None else "*"
                if key not in lat_hists:
                    lat_hists[key] = hist_mod.zeros_np()
                    cold_s[key] = res.latency_s
                hist_mod.fold_np(lat_hists[key], res.latency_s * 1e6)
                if key in prev:
                    pd, pp = prev[key]
                    churns.append(churn(pd, pp, res.dist, res.parent))
                prev[key] = (res.dist, res.parent)
                if on_query is not None:
                    on_query(res)
    wall = time.perf_counter() - t0
    mean = (lambda k: (sum(c[k] for c in churns) / len(churns))
            if churns else 0.0)
    # per-tenant p50/p95/p99 from the per-source histograms (estimates in
    # ms), plus each tenant's exact cold (first-query) latency
    per_source = {
        key: {
            "queries": int(h.sum()),
            "cold_ms": cold_s[key] * 1e3,
            "p50_ms": hist_percentile(h, 50) / 1e3,
            "p95_ms": hist_percentile(h, 95) / 1e3,
            "p99_ms": hist_percentile(h, 99) / 1e3,
        }
        for key, h in lat_hists.items()}
    # cold/warm split: the warm histogram is the merged per-tenant pool
    # minus each tenant's cold sample (histograms are additive, so the
    # subtraction is exact at bucket granularity); cold percentiles come
    # from the exact first-query latencies
    cold_warm = None
    if lat_hists:
        pooled = hist_merge(*lat_hists.values())
        cold_hist = hist_merge(*(hist_mod.one_hot_np(v * 1e6)
                                 for v in cold_s.values()))
        warm_hist = pooled - cold_hist
        cold_vals = list(cold_s.values())
        cold_warm = {
            "cold_queries": float(cold_hist.sum()),
            "warm_queries": float(warm_hist.sum()),
            "cold_p50_ms": percentiles(cold_vals)["p50"] * 1e3,
            "cold_p99_ms": percentiles(cold_vals)["p99"] * 1e3,
            "warm_p50_ms": hist_percentile(warm_hist, 50) / 1e3,
            "warm_p99_ms": hist_percentile(warm_hist, 99) / 1e3,
        }
    return ServingReport(
        engine=_engine_label(engine),
        n_sources=len(engine.sources) if engine.sources else 1,
        events=n_events,
        topology_events=n_topo,
        queries=n_queries,
        wall_s=wall,
        events_per_s=n_topo / max(wall, 1e-9),
        latency_s=percentiles(latencies),
        churn_mean={"dist": mean("dist"), "parent": mean("parent"),
                    "any": mean("any")},
        latencies=latencies,
        churns=churns,
        # the engine's own telemetry (DESIGN.md §10) — rounds/messages plus
        # the obs counter/span snapshot when observability is enabled
        engine_metrics=engine.metrics_snapshot(),
        per_source=per_source or None,
        cold_warm=cold_warm,
    )
