"""Workload traces: a compact on-disk event stream with per-event
timestamps and per-query sources (DESIGN.md §8.2).

This is the replay-an-update-trace methodology of Hanauer et al.'s fully
dynamic experimental studies (PAPERS.md): record a mixed ADD/DEL/QUERY
stream once, then replay it deterministically against any engine
configuration so latency/stability/throughput comparisons share the exact
same workload.

Format (version 1) — a compressed ``.npz`` container written through an
explicit file handle (so the path is stored verbatim, no ``.npz`` suffix
magic) with struct-of-arrays columns:

    magic    "sssp-del-trace"         (format tag)
    version  1
    kind     u8[n]   events.ADD / DEL / QUERY
    src      i64[n]  ADD/DEL tail; QUERY rows carry the query source
                     (-1 = default / every maintained source)
    dst      i64[n]  ADD/DEL head (-1 on QUERY rows)
    w        f32[n]  ADD weight (0 on DEL/QUERY rows)
    t        f64[n]  nondecreasing seconds since trace start

``ServingTrace.to_log()`` lowers a trace to the engines' ``EventLog`` (the
query-source column rides along — events.py QUERY markers carry it);
``from_log`` lifts a generated log into a trace with synthetic timestamps.
``TraceRecorder`` stamps live events with a monotonic clock.

Format (version 2) — the chunked container for paper-scale streams
(DESIGN.md §11): the same five columns, split into fixed-size chunks stored
as separate npz members (``kind_00000000``, ``src_00000000``, ...) plus a
``chunk_sizes`` index.  npz members decompress lazily, so ``open_trace`` /
``TraceReader.chunks()`` stream the file with O(chunk) peak host memory —
replaying a 10M-event trace never materializes 10M-row columns.  Version-1
files still load (and read as a single chunk).
"""
from __future__ import annotations

import dataclasses
import time
import zipfile

import numpy as np

from repro.core import events as ev

TRACE_MAGIC = "sssp-del-trace"
TRACE_VERSION = 2
_COLUMNS = ("kind", "src", "dst", "w", "t")
_DTYPES = (np.uint8, np.int64, np.int64, np.float32, np.float64)


class TraceFormatError(ValueError):
    """The file exists but is not a (compatible) serving trace."""


@dataclasses.dataclass(frozen=True)
class ServingTrace:
    """In-memory trace: an EventLog plus timestamps (struct of arrays)."""

    kind: np.ndarray  # u8[n]
    src: np.ndarray   # i64[n]
    dst: np.ndarray   # i64[n]
    w: np.ndarray     # f32[n]
    t: np.ndarray     # f64[n], nondecreasing, seconds from trace start

    def __post_init__(self):
        n = len(self.kind)
        for c in _COLUMNS[1:]:
            if len(getattr(self, c)) != n:
                raise TraceFormatError(
                    f"column {c!r} has {len(getattr(self, c))} rows, "
                    f"kind has {n}")

    def __len__(self) -> int:
        return len(self.kind)

    @property
    def n_topology(self) -> int:
        return int(np.sum(self.kind != ev.QUERY))

    @property
    def n_queries(self) -> int:
        return int(np.sum(self.kind == ev.QUERY))

    def query_sources(self) -> np.ndarray:
        """The query-source column of the QUERY rows (-1 = default)."""
        return self.src[self.kind == ev.QUERY]

    def duration_s(self) -> float:
        return float(self.t[-1] - self.t[0]) if len(self) else 0.0

    # ------------------------------------------------------------ conversion
    def to_log(self) -> ev.EventLog:
        return ev.EventLog(self.kind.astype(np.uint8),
                           self.src.astype(np.int64),
                           self.dst.astype(np.int64),
                           self.w.astype(np.float32))

    @staticmethod
    def from_log(log: ev.EventLog, *, t: np.ndarray | None = None,
                 events_per_s: float = 1e6) -> "ServingTrace":
        """Lift an EventLog into a trace.  Without explicit timestamps a
        synthetic uniform ramp at ``events_per_s`` is used — monotone and
        deterministic, so record->replay round-trips are reproducible."""
        if t is None:
            t = np.arange(len(log), dtype=np.float64) / float(events_per_s)
        t = np.asarray(t, np.float64)
        return ServingTrace(np.asarray(log.kind, np.uint8),
                            np.asarray(log.src, np.int64),
                            np.asarray(log.dst, np.int64),
                            np.asarray(log.w, np.float32), t)

    # ----------------------------------------------------------------- chunks
    def iter_chunks(self, events_per_chunk: int):
        """Yield this trace as consecutive slices of ≤ ``events_per_chunk``
        rows (views, no copies) — the in-memory side of the chunked path."""
        if events_per_chunk < 1:
            raise ValueError(f"events_per_chunk must be >= 1; got "
                             f"{events_per_chunk}")
        for lo in range(0, len(self), events_per_chunk):
            hi = lo + events_per_chunk
            yield ServingTrace(self.kind[lo:hi], self.src[lo:hi],
                               self.dst[lo:hi], self.w[lo:hi], self.t[lo:hi])

    # ------------------------------------------------------------------ disk
    def save(self, path: str, *, chunk_events: int | None = None) -> None:
        """Write version 1 (monolithic columns) by default; passing
        ``chunk_events`` writes the version-2 chunked container, which
        ``open_trace`` can later replay with O(chunk) peak memory."""
        if chunk_events is not None:
            with ChunkedTraceWriter(path) as wr:
                for piece in self.iter_chunks(chunk_events):
                    wr.append(piece)
            return
        with open(path, "wb") as f:
            np.savez_compressed(
                f, magic=np.asarray(TRACE_MAGIC),
                version=np.asarray(1),
                kind=self.kind.astype(np.uint8),
                src=self.src.astype(np.int64),
                dst=self.dst.astype(np.int64),
                w=self.w.astype(np.float32),
                t=self.t.astype(np.float64))

    @staticmethod
    def load(path: str) -> "ServingTrace":
        """Load and validate a trace (either version, fully materialized).
        Raises ``FileNotFoundError`` for a missing path and
        ``TraceFormatError`` for anything that is not a compatible trace
        (CLI entry points map both to exit code 2).  For O(chunk)-memory
        streaming of version-2 files use ``open_trace`` instead."""
        with open_trace(path) as r:
            pieces = list(r.chunks())
        if not pieces:
            z8, z64 = np.empty(0, np.uint8), np.empty(0, np.int64)
            return ServingTrace(z8, z64, z64.copy(),
                                np.empty(0, np.float32),
                                np.empty(0, np.float64))
        if len(pieces) == 1:
            return pieces[0]
        return ServingTrace(*(np.concatenate([getattr(p, c) for p in pieces])
                              for c in _COLUMNS))


class ChunkedTraceWriter:
    """Incremental version-2 trace writer: append ``ServingTrace`` pieces
    one at a time; nothing but the current piece is ever resident, so a
    stream synthesizer can emit a 10M-event trace in O(chunk) memory.

    Members are standard ``.npy`` entries in a deflated zip — byte-level
    compatible with ``np.savez_compressed`` / ``np.load``.
    """

    def __init__(self, path: str):
        self._zf = zipfile.ZipFile(path, "w", zipfile.ZIP_DEFLATED)
        self._sizes: list[int] = []
        self._closed = False

    def _member(self, name: str, arr: np.ndarray) -> None:
        import io

        from numpy.lib import format as npf
        buf = io.BytesIO()
        # note: np.ascontiguousarray would promote the 0-d magic/version
        # members to 1-d, which np.savez does not do
        npf.write_array(buf, np.asarray(arr), allow_pickle=False)
        self._zf.writestr(name + ".npy", buf.getvalue())

    def append(self, piece: ServingTrace) -> None:
        assert not self._closed, "writer already closed"
        i = len(self._sizes)
        for col, dt in zip(_COLUMNS, _DTYPES):
            self._member(f"{col}_{i:08d}", getattr(piece, col).astype(dt))
        self._sizes.append(len(piece))

    def close(self) -> None:
        if self._closed:
            return
        self._member("magic", np.asarray(TRACE_MAGIC))
        self._member("version", np.asarray(TRACE_VERSION))
        self._member("chunk_sizes", np.asarray(self._sizes, np.int64))
        self._zf.close()
        self._closed = True

    def __enter__(self) -> "ChunkedTraceWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class TraceReader:
    """Streaming handle over an on-disk trace: ``chunks()`` yields
    ``ServingTrace`` pieces, decompressing one chunk's members at a time
    (npz entries load lazily), so replay memory is O(chunk) not O(stream).

    Version-1 files read as a single chunk — correct, but without the
    memory bound; write with ``save(chunk_events=...)`` to get it.
    """

    def __init__(self, path: str):
        self.path = path
        try:
            self._z = np.load(path, allow_pickle=False)
        except (zipfile.BadZipFile, ValueError, OSError) as e:
            # np.load raises plain ValueError for non-npz bytes
            if isinstance(e, FileNotFoundError):
                raise
            raise TraceFormatError(f"{path}: not a readable trace "
                                   f"({e})") from e
        try:
            files = set(self._z.files)
            if "magic" not in files or str(self._z["magic"]) != TRACE_MAGIC:
                raise TraceFormatError(f"{path}: not a {TRACE_MAGIC} file")
            self.version = int(self._z["version"])
            if self.version > TRACE_VERSION:
                raise TraceFormatError(
                    f"{path}: trace version {self.version} is newer than "
                    f"supported {TRACE_VERSION}")
            if self.version == 1:
                missing = [c for c in _COLUMNS if c not in files]
                if missing:
                    raise TraceFormatError(
                        f"{path}: missing column(s) {missing}")
                self.chunk_sizes = None  # length known only after reading
            else:
                if "chunk_sizes" not in files:
                    raise TraceFormatError(f"{path}: missing chunk_sizes")
                self.chunk_sizes = self._z["chunk_sizes"].astype(np.int64)
                missing = [f"{c}_{i:08d}"
                           for i in range(len(self.chunk_sizes))
                           for c in _COLUMNS
                           if f"{c}_{i:08d}" not in files]
                if missing:
                    raise TraceFormatError(
                        f"{path}: missing chunk member(s) {missing[:4]}")
        except Exception:
            self._z.close()
            raise

    @property
    def n_chunks(self) -> int:
        return 1 if self.chunk_sizes is None else len(self.chunk_sizes)

    def chunks(self):
        """Yield the trace as ``ServingTrace`` pieces, in stream order."""
        if self.chunk_sizes is None:
            yield ServingTrace(*(self._z[c] for c in _COLUMNS))
            return
        for i in range(len(self.chunk_sizes)):
            yield ServingTrace(*(self._z[f"{c}_{i:08d}"] for c in _COLUMNS))

    def close(self) -> None:
        self._z.close()

    def __enter__(self) -> "TraceReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def open_trace(path: str) -> TraceReader:
    """Open a trace for chunked streaming (see ``TraceReader``)."""
    return TraceReader(path)


def load_trace_or_exit(path: str) -> ServingTrace:
    """CLI loader shared by the examples: exit code 2 on unknown or
    incompatible trace paths — the same contract as benchmarks/run.py's
    unknown ``--only`` sections."""
    import sys

    try:
        return ServingTrace.load(path)
    except (FileNotFoundError, TraceFormatError) as e:
        print(f"error: {e}", file=sys.stderr)
        sys.exit(2)


class TraceRecorder:
    """Accumulates a timestamped event stream (DESIGN.md §8.2).

    Live events are stamped with a monotonic clock relative to the first
    recorded event; ``extend_from_log`` bulk-appends a pre-built EventLog
    with synthetic (or caller-supplied) timestamps.
    """

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._t0: float | None = None
        self._kind: list[int] = []
        self._src: list[int] = []
        self._dst: list[int] = []
        self._w: list[float] = []
        self._t: list[float] = []

    def __len__(self) -> int:
        return len(self._kind)

    def _stamp(self) -> float:
        now = self._clock()
        if self._t0 is None:
            self._t0 = now
        # never step backwards: mixing live stamps with a synthetic
        # ``extend_from_log`` ramp must keep the trace monotone
        return max(now - self._t0, self._t[-1] if self._t else 0.0)

    def _push(self, kind: int, src: int, dst: int, w: float) -> None:
        self._kind.append(kind)
        self._src.append(int(src))
        self._dst.append(int(dst))
        self._w.append(float(w))
        self._t.append(self._stamp())

    def add(self, u: int, v: int, w: float) -> None:
        self._push(ev.ADD, u, v, w)

    def delete(self, u: int, v: int) -> None:
        self._push(ev.DEL, u, v, 0.0)

    def query(self, source: int = -1) -> None:
        self._push(ev.QUERY, source, -1, 0.0)

    def extend_from_log(self, log: ev.EventLog,
                        t: np.ndarray | None = None,
                        events_per_s: float = 1e6) -> None:
        """Append a whole EventLog; timestamps default to a uniform ramp
        continuing from the last recorded stamp."""
        base = self._t[-1] if self._t else 0.0
        if t is None:
            t = base + (np.arange(1, len(log) + 1, dtype=np.float64)
                        / float(events_per_s))
        if self._t0 is None:
            self._t0 = self._clock()
        self._kind.extend(int(k) for k in log.kind)
        self._src.extend(int(s) for s in log.src)
        self._dst.extend(int(d) for d in log.dst)
        self._w.extend(float(x) for x in log.w)
        self._t.extend(float(x) for x in t)

    def trace(self) -> ServingTrace:
        return ServingTrace(np.asarray(self._kind, np.uint8),
                            np.asarray(self._src, np.int64),
                            np.asarray(self._dst, np.int64),
                            np.asarray(self._w, np.float32),
                            np.asarray(self._t, np.float64))
