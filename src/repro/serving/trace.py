"""Workload traces: a compact on-disk event stream with per-event
timestamps and per-query sources (DESIGN.md §8.2).

This is the replay-an-update-trace methodology of Hanauer et al.'s fully
dynamic experimental studies (PAPERS.md): record a mixed ADD/DEL/QUERY
stream once, then replay it deterministically against any engine
configuration so latency/stability/throughput comparisons share the exact
same workload.

Format (version 1) — a compressed ``.npz`` container written through an
explicit file handle (so the path is stored verbatim, no ``.npz`` suffix
magic) with struct-of-arrays columns:

    magic    "sssp-del-trace"         (format tag)
    version  1
    kind     u8[n]   events.ADD / DEL / QUERY
    src      i64[n]  ADD/DEL tail; QUERY rows carry the query source
                     (-1 = default / every maintained source)
    dst      i64[n]  ADD/DEL head (-1 on QUERY rows)
    w        f32[n]  ADD weight (0 on DEL/QUERY rows)
    t        f64[n]  nondecreasing seconds since trace start

``ServingTrace.to_log()`` lowers a trace to the engines' ``EventLog`` (the
query-source column rides along — events.py QUERY markers carry it);
``from_log`` lifts a generated log into a trace with synthetic timestamps.
``TraceRecorder`` stamps live events with a monotonic clock.
"""
from __future__ import annotations

import dataclasses
import time
import zipfile

import numpy as np

from repro.core import events as ev

TRACE_MAGIC = "sssp-del-trace"
TRACE_VERSION = 1
_COLUMNS = ("kind", "src", "dst", "w", "t")


class TraceFormatError(ValueError):
    """The file exists but is not a (compatible) serving trace."""


@dataclasses.dataclass(frozen=True)
class ServingTrace:
    """In-memory trace: an EventLog plus timestamps (struct of arrays)."""

    kind: np.ndarray  # u8[n]
    src: np.ndarray   # i64[n]
    dst: np.ndarray   # i64[n]
    w: np.ndarray     # f32[n]
    t: np.ndarray     # f64[n], nondecreasing, seconds from trace start

    def __post_init__(self):
        n = len(self.kind)
        for c in _COLUMNS[1:]:
            if len(getattr(self, c)) != n:
                raise TraceFormatError(
                    f"column {c!r} has {len(getattr(self, c))} rows, "
                    f"kind has {n}")

    def __len__(self) -> int:
        return len(self.kind)

    @property
    def n_topology(self) -> int:
        return int(np.sum(self.kind != ev.QUERY))

    @property
    def n_queries(self) -> int:
        return int(np.sum(self.kind == ev.QUERY))

    def query_sources(self) -> np.ndarray:
        """The query-source column of the QUERY rows (-1 = default)."""
        return self.src[self.kind == ev.QUERY]

    def duration_s(self) -> float:
        return float(self.t[-1] - self.t[0]) if len(self) else 0.0

    # ------------------------------------------------------------ conversion
    def to_log(self) -> ev.EventLog:
        return ev.EventLog(self.kind.astype(np.uint8),
                           self.src.astype(np.int64),
                           self.dst.astype(np.int64),
                           self.w.astype(np.float32))

    @staticmethod
    def from_log(log: ev.EventLog, *, t: np.ndarray | None = None,
                 events_per_s: float = 1e6) -> "ServingTrace":
        """Lift an EventLog into a trace.  Without explicit timestamps a
        synthetic uniform ramp at ``events_per_s`` is used — monotone and
        deterministic, so record->replay round-trips are reproducible."""
        if t is None:
            t = np.arange(len(log), dtype=np.float64) / float(events_per_s)
        t = np.asarray(t, np.float64)
        return ServingTrace(np.asarray(log.kind, np.uint8),
                            np.asarray(log.src, np.int64),
                            np.asarray(log.dst, np.int64),
                            np.asarray(log.w, np.float32), t)

    # ------------------------------------------------------------------ disk
    def save(self, path: str) -> None:
        with open(path, "wb") as f:
            np.savez_compressed(
                f, magic=np.asarray(TRACE_MAGIC),
                version=np.asarray(TRACE_VERSION),
                kind=self.kind.astype(np.uint8),
                src=self.src.astype(np.int64),
                dst=self.dst.astype(np.int64),
                w=self.w.astype(np.float32),
                t=self.t.astype(np.float64))

    @staticmethod
    def load(path: str) -> "ServingTrace":
        """Load and validate a trace.  Raises ``FileNotFoundError`` for a
        missing path and ``TraceFormatError`` for anything that is not a
        compatible trace (CLI entry points map both to exit code 2)."""
        try:
            z = np.load(path, allow_pickle=False)
        except (zipfile.BadZipFile, ValueError, OSError) as e:
            # np.load raises plain ValueError for non-npz bytes
            if isinstance(e, FileNotFoundError):
                raise
            raise TraceFormatError(f"{path}: not a readable trace "
                                   f"({e})") from e
        with z:
                files = set(z.files)
                if "magic" not in files or str(z["magic"]) != TRACE_MAGIC:
                    raise TraceFormatError(
                        f"{path}: not a {TRACE_MAGIC} file")
                version = int(z["version"])
                if version > TRACE_VERSION:
                    raise TraceFormatError(
                        f"{path}: trace version {version} is newer than "
                        f"supported {TRACE_VERSION}")
                missing = [c for c in _COLUMNS if c not in files]
                if missing:
                    raise TraceFormatError(
                        f"{path}: missing column(s) {missing}")
                return ServingTrace(*(z[c] for c in _COLUMNS))


def load_trace_or_exit(path: str) -> ServingTrace:
    """CLI loader shared by the examples: exit code 2 on unknown or
    incompatible trace paths — the same contract as benchmarks/run.py's
    unknown ``--only`` sections."""
    import sys

    try:
        return ServingTrace.load(path)
    except (FileNotFoundError, TraceFormatError) as e:
        print(f"error: {e}", file=sys.stderr)
        sys.exit(2)


class TraceRecorder:
    """Accumulates a timestamped event stream (DESIGN.md §8.2).

    Live events are stamped with a monotonic clock relative to the first
    recorded event; ``extend_from_log`` bulk-appends a pre-built EventLog
    with synthetic (or caller-supplied) timestamps.
    """

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._t0: float | None = None
        self._kind: list[int] = []
        self._src: list[int] = []
        self._dst: list[int] = []
        self._w: list[float] = []
        self._t: list[float] = []

    def __len__(self) -> int:
        return len(self._kind)

    def _stamp(self) -> float:
        now = self._clock()
        if self._t0 is None:
            self._t0 = now
        # never step backwards: mixing live stamps with a synthetic
        # ``extend_from_log`` ramp must keep the trace monotone
        return max(now - self._t0, self._t[-1] if self._t else 0.0)

    def _push(self, kind: int, src: int, dst: int, w: float) -> None:
        self._kind.append(kind)
        self._src.append(int(src))
        self._dst.append(int(dst))
        self._w.append(float(w))
        self._t.append(self._stamp())

    def add(self, u: int, v: int, w: float) -> None:
        self._push(ev.ADD, u, v, w)

    def delete(self, u: int, v: int) -> None:
        self._push(ev.DEL, u, v, 0.0)

    def query(self, source: int = -1) -> None:
        self._push(ev.QUERY, source, -1, 0.0)

    def extend_from_log(self, log: ev.EventLog,
                        t: np.ndarray | None = None,
                        events_per_s: float = 1e6) -> None:
        """Append a whole EventLog; timestamps default to a uniform ramp
        continuing from the last recorded stamp."""
        base = self._t[-1] if self._t else 0.0
        if t is None:
            t = base + (np.arange(1, len(log) + 1, dtype=np.float64)
                        / float(events_per_s))
        if self._t0 is None:
            self._t0 = self._clock()
        self._kind.extend(int(k) for k in log.kind)
        self._src.extend(int(s) for s in log.src)
        self._dst.extend(int(d) for d in log.dst)
        self._w.extend(float(x) for x in log.w)
        self._t.extend(float(x) for x in t)

    def trace(self) -> ServingTrace:
        return ServingTrace(np.asarray(self._kind, np.uint8),
                            np.asarray(self._src, np.int64),
                            np.asarray(self._dst, np.int64),
                            np.asarray(self._w, np.float32),
                            np.asarray(self._t, np.float64))
