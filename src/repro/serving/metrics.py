"""Serving metrics (DESIGN.md §8): the paper's three serving qualities —
result latency, solution stability, event throughput — as one
machine-readable ``ServingReport`` computed during trace replay.

Definitions (matching the paper's evaluation; see DESIGN.md §8.3):

  * **result latency** — the wall-clock cost of answering one QUERY: the
    device->host snapshot readback timed inside ``StreamEngineBase.query``
    (epochs are enforced per batch, so no residual convergence is ever
    folded in).  Reported as p50/p95/p99 over the replay's queries.
  * **solution stability** — per-epoch churn between consecutive results
    *of the same source*: the fraction of vertices whose dist changed
    (``churn_dist``), whose parent changed (``churn_parent``), or either
    (``churn``).  Low churn = stable trees, the paper's §5.4 quality
    (``1 - churn_parent`` is the predecessor-overlap stability figure).
  * **throughput** — sustained topology events (ADD+DEL) per second over
    the whole replay wall-clock.

The percentile helpers here are THE shared implementation: benchmarks/
common.py re-exports ``pctile``/``percentiles`` so the bench sections and
this harness can never disagree on how a percentile is computed.  The
histogram merge/estimate helpers (``hist_merge``/``hist_percentile``) are
likewise re-exported from the telemetry layer's ``obs/hist.py``
(DESIGN.md §10.6) — the replayer's per-tenant and cold/warm figures are
computed from the same log2 buckets the engines accumulate on device.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.obs.hist import merge as hist_merge
from repro.obs.hist import percentile as hist_percentile


def pctile(xs, q) -> float:
    """Percentile with the edge-case conventions every caller shares:
    empty input -> NaN (never raises), a single sample is every percentile
    of itself, and any input shape is accepted — generators and other
    len()-less iterables are materialized, scalars wrap, [S, N] stacks
    flatten."""
    if not hasattr(xs, "__len__") and not isinstance(xs, np.ndarray):
        xs = list(xs) if np.iterable(xs) else [xs]
    arr = np.asarray(xs, np.float64).reshape(-1)
    return float(np.percentile(arr, q)) if arr.size else float("nan")


def percentiles(xs, qs=(50, 95, 99)) -> dict[str, float]:
    """``{"p50": ..., "p95": ..., "p99": ...}`` over ``xs``."""
    return {f"p{q:g}": pctile(xs, q) for q in qs}


def churn(prev_dist: np.ndarray, prev_parent: np.ndarray,
          dist: np.ndarray, parent: np.ndarray) -> dict[str, float]:
    """Fraction of vertices whose dist / parent / either changed between
    two snapshots of the same source's tree (shape-agnostic: a stacked
    [S, N] pair scores all lanes at once).  ``inf == inf`` counts as
    unchanged (numpy equality), so unreached-and-still-unreached vertices
    are stable."""
    d_ch = dist != prev_dist
    p_ch = parent != prev_parent
    return {
        "dist": float(np.mean(d_ch)),
        "parent": float(np.mean(p_ch)),
        "any": float(np.mean(d_ch | p_ch)),
    }


@dataclasses.dataclass
class ServingReport:
    """Aggregate serving metrics for one trace replay (DESIGN.md §8.3).

    ``latencies`` / ``churns`` keep the per-query series for callers that
    want distributions; ``to_record()`` flattens the aggregates into the
    BENCH_sssp.json record shape."""

    engine: str               # e.g. "single/segment" or "sharded/sliced"
    n_sources: int
    events: int               # total trace events (topology + queries)
    topology_events: int
    queries: int
    wall_s: float
    events_per_s: float       # sustained topology-event throughput
    latency_s: dict[str, float]          # p50/p95/p99 (seconds)
    churn_mean: dict[str, float]         # dist/parent/any means
    latencies: list[float] = dataclasses.field(default_factory=list,
                                               repr=False)
    churns: list[dict[str, float]] = dataclasses.field(default_factory=list,
                                                       repr=False)
    # the engine's metrics_snapshot() at replay end (DESIGN.md §10):
    # epochs/rounds/messages plus the obs counter registry and span counts
    engine_metrics: dict[str, Any] | None = dataclasses.field(default=None,
                                                              repr=False)
    # per-source (per-tenant) latency: {source_key: {"queries", "cold_ms",
    # "p50_ms", "p95_ms", "p99_ms"}} — percentile estimates from the §10.6
    # log2 histogram each tenant's queries fold into during replay; the
    # key "*" covers unrouted full-state queries
    per_source: dict[Any, dict[str, float]] | None = dataclasses.field(
        default=None, repr=False)
    # cold-vs-warm admission split: each scope's FIRST query is cold (the
    # tree has never been read back for that tenant), the rest are warm —
    # the ROADMAP's cold-vs-warm admission latency figure
    cold_warm: dict[str, float] | None = None

    @property
    def stability_parent(self) -> float:
        """Paper §5.4 figure: mean predecessor overlap between consecutive
        results (1 - mean parent churn)."""
        return 1.0 - self.churn_mean["parent"]

    def summary(self) -> str:
        """Human-readable report (the examples' replay output)."""
        return "\n".join([
            f"replayed {self.events} events ({self.topology_events} "
            f"topology, {self.queries} queries) as {self.engine} "
            f"x{self.n_sources} source(s)",
            f"latency p50/p95/p99: "
            f"{self.latency_s['p50'] * 1e3:.3f}/"
            f"{self.latency_s['p95'] * 1e3:.3f}/"
            f"{self.latency_s['p99'] * 1e3:.3f} ms",
            f"stability (1 - parent churn): {self.stability_parent:.4f}",
            f"throughput: {self.events_per_s:.0f} events/s",
        ] + ([
            f"cold/warm queries: {int(self.cold_warm['cold_queries'])}/"
            f"{int(self.cold_warm['warm_queries'])}, warm p50/p99 ~ "
            f"{self.cold_warm['warm_p50_ms']:.3f}/"
            f"{self.cold_warm['warm_p99_ms']:.3f} ms"
        ] if self.cold_warm else []))

    def to_record(self) -> dict[str, Any]:
        rec = {
            "engine": self.engine,
            "n_sources": self.n_sources,
            "events": self.events,
            "topology_events": self.topology_events,
            "queries": self.queries,
            "wall_s": round(self.wall_s, 4),
            "events_per_s": round(self.events_per_s, 1),
            "latency_p50_ms": round(self.latency_s["p50"] * 1e3, 4),
            "latency_p95_ms": round(self.latency_s["p95"] * 1e3, 4),
            "latency_p99_ms": round(self.latency_s["p99"] * 1e3, 4),
            "churn_dist_mean": round(self.churn_mean["dist"], 6),
            "churn_parent_mean": round(self.churn_mean["parent"], 6),
            "churn_mean": round(self.churn_mean["any"], 6),
            "stability_parent": round(self.stability_parent, 6),
        }
        if self.engine_metrics is not None:
            # flatten the two algorithmic figures the bench records track;
            # [S] per-lane vectors stringify via the sink's default=str
            rec["rounds"] = self.engine_metrics.get("rounds")
            rec["messages"] = self.engine_metrics.get("messages")
        if self.cold_warm is not None:
            rec["cold_queries"] = int(self.cold_warm["cold_queries"])
            rec["warm_queries"] = int(self.cold_warm["warm_queries"])
            rec["latency_cold_p50_ms"] = round(
                self.cold_warm["cold_p50_ms"], 4)
            rec["latency_warm_p50_ms"] = round(
                self.cold_warm["warm_p50_ms"], 4)
            rec["latency_warm_p99_ms"] = round(
                self.cold_warm["warm_p99_ms"], 4)
        return rec
