"""Host-side graph sampling substrate (numpy, CSR-based).

``minibatch_lg`` requires a real neighbor sampler: given seed nodes and a
fanout schedule (GraphSAGE's 25-10 / the shape's 15-10), sample a k-hop
neighborhood and emit a *padded COO subgraph* with relabelled node ids.
Every GNN arch consumes this one format (models/gnn/common.py), so the
sampler is shared substrate, not per-arch code.

Static shapes: the subgraph is padded to its worst case
  n_sub = B * (1 + f1 + f1*f2 ...),  e_sub = B * (f1 + f1*f2 ...)
with ``edge_mask`` marking real edges — required for JIT cache stability.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SampledSubgraph:
    node_ids: np.ndarray   # i32[n_sub] — global ids (padded with 0)
    src: np.ndarray        # i32[e_sub] — local (relabelled) ids
    dst: np.ndarray        # i32[e_sub]
    edge_mask: np.ndarray  # bool[e_sub]
    node_mask: np.ndarray  # bool[n_sub]
    seed_slots: np.ndarray # i32[B] — local ids of the seed nodes


def subgraph_capacity(batch: int, fanout: tuple[int, ...]) -> tuple[int, int]:
    n, e, layer = 1, 0, 1
    for f in fanout:
        layer *= f
        n += layer
        e += layer
    return batch * n, batch * e


class NeighborSampler:
    """Uniform fanout sampler over a CSR adjacency (in-neighbors: the
    aggregation direction, matching dst-owned edges everywhere else)."""

    def __init__(self, num_nodes: int, src: np.ndarray, dst: np.ndarray):
        order = np.argsort(dst, kind="stable")
        self.cols = np.ascontiguousarray(src[order]).astype(np.int64)
        self.indptr = np.zeros(num_nodes + 1, np.int64)
        np.add.at(self.indptr, dst + 1, 1)
        np.cumsum(self.indptr, out=self.indptr)
        self.num_nodes = num_nodes

    def _sample_nbrs(self, nodes: np.ndarray, k: int,
                     rng: np.random.Generator) -> np.ndarray:
        """(M,) -> (M, k) sampled in-neighbors, -1 where degree == 0."""
        lo, hi = self.indptr[nodes], self.indptr[nodes + 1]
        deg = hi - lo
        out = np.full((len(nodes), k), -1, np.int64)
        has = deg > 0
        if has.any():
            r = rng.integers(0, np.maximum(deg[has], 1)[:, None],
                             size=(int(has.sum()), k))
            out[has] = self.cols[lo[has, None] + r]
        return out

    def sample(self, seeds: np.ndarray, fanout: tuple[int, ...],
               seed: int = 0) -> SampledSubgraph:
        rng = np.random.default_rng(seed)
        B = len(seeds)
        n_cap, e_cap = subgraph_capacity(B, fanout)

        # frontier-by-frontier expansion; relabel greedily (no dedup across
        # branches — tree-structured subgraph, the GraphSAGE semantics)
        node_ids = np.zeros(n_cap, np.int64)
        node_mask = np.zeros(n_cap, bool)
        src = np.zeros(e_cap, np.int64)
        dst = np.zeros(e_cap, np.int64)
        emask = np.zeros(e_cap, bool)

        node_ids[:B] = seeds
        node_mask[:B] = True
        frontier_slots = np.arange(B)
        n_ptr, e_ptr = B, 0
        for f in fanout:
            fr_nodes = node_ids[frontier_slots]
            fr_valid = node_mask[frontier_slots]
            nbrs = self._sample_nbrs(fr_nodes, f, rng)           # (M, f)
            M = len(frontier_slots)
            new_slots = n_ptr + np.arange(M * f)
            valid = fr_valid[:, None] & (nbrs >= 0)
            node_ids[new_slots] = np.maximum(nbrs, 0).reshape(-1)
            node_mask[new_slots] = valid.reshape(-1)
            # edges: sampled neighbor (src) -> frontier node (dst)
            src[e_ptr:e_ptr + M * f] = new_slots
            dst[e_ptr:e_ptr + M * f] = np.repeat(frontier_slots, f)
            emask[e_ptr:e_ptr + M * f] = valid.reshape(-1)
            frontier_slots = new_slots
            n_ptr += M * f
            e_ptr += M * f

        return SampledSubgraph(
            node_ids=node_ids.astype(np.int32),
            src=src.astype(np.int32), dst=dst.astype(np.int32),
            edge_mask=emask, node_mask=node_mask,
            seed_slots=np.arange(B, dtype=np.int32))


def build_batch(sub: SampledSubgraph, feats: np.ndarray, labels: np.ndarray,
                pos: np.ndarray | None = None) -> dict:
    """Materialize the padded-subgraph training batch dict consumed by the
    GNN loss functions (gathers features host-side; at scale this gather is
    the input pipeline's job, overlapped with the previous step)."""
    n = len(sub.node_ids)
    batch = {
        "feats": feats[sub.node_ids].astype(np.float32),
        "src": sub.src, "dst": sub.dst, "edge_mask": sub.edge_mask,
        "labels": np.where(sub.node_mask, labels[sub.node_ids], -1
                           ).astype(np.int32),
        "label_mask": np.zeros(n, bool),
    }
    batch["label_mask"][sub.seed_slots] = True   # loss only on seeds
    if pos is not None:
        batch["pos"] = pos[sub.node_ids].astype(np.float32)
    return batch
