"""CSR / sliced-ELLPACK builders (host-side numpy; device consumers in
kernels/ and core/).

The TPU-native relaxation kernel consumes a *by-destination* sliced-ELLPACK
view: for every dst row, a padded list of (in-neighbor id, weight).  Padding
entries point at row 0 with +inf weight so they never win a min.

All builders are fancy-indexed scatters — no per-row Python loops — so the
dynamic engine can afford full rebuilds on ELL capacity overflow (DESIGN.md
§2.3): a rebuild is O(E) numpy work plus one host->device transfer.

Per-window building (DESIGN.md §7.2): ``ell_from_coo`` and
``sliced_ell_from_coo`` take ``row0`` so a caller can build the layout of
one vertex window ``[row0, row0 + n)`` directly from globally-addressed
edges — the sharded engine's per-partition planners build exactly their
owned window this way (dst-owner placement guarantees every edge's dst
falls inside it).  ``row0=0`` is the whole-graph build and the two must
agree block-for-block (test_sliced_layout.py window round-trips).
"""
from __future__ import annotations

import numpy as np

PAD_W = np.float32(np.inf)


def coo_to_csr(n: int, src: np.ndarray, dst: np.ndarray, w: np.ndarray,
               *, by: str = "dst"):
    """Sort COO by row (dst or src); returns (indptr, cols, w_sorted, perm)."""
    rows = dst if by == "dst" else src
    cols = src if by == "dst" else dst
    perm = np.argsort(rows, kind="stable")
    rows_s, cols_s, w_s = rows[perm], cols[perm], w[perm]
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, rows_s + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, cols_s, w_s, perm


def _csr_positions(indptr: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(row, column-within-row) for every CSR entry, vectorized."""
    deg = np.diff(indptr)
    rows = np.repeat(np.arange(len(deg)), deg)
    kpos = np.arange(indptr[-1]) - np.repeat(indptr[:-1], deg)
    return rows, kpos


def csr_to_ell(n: int, indptr: np.ndarray, cols: np.ndarray, w: np.ndarray,
               *, k: int | None = None, pad_col: int = 0, n_rows: int | None = None):
    """Dense ELLPACK (n_rows, K) from CSR; K defaults to max row degree.

    Returns (nbr_idx i32[n_rows,K], nbr_w f32[n_rows,K]); pad weight +inf.
    Rows longer than K are truncated (callers pick K >= max degree unless
    deliberately sketching).  ``n_rows >= n`` pads extra all-inf rows at the
    bottom — the engine uses this to round the row count up to the relax
    kernel's block size.
    """
    deg = np.diff(indptr)
    kmax = int(deg.max()) if n and len(cols) else 0
    K = kmax if k is None else k
    K = max(K, 1)
    R = n if n_rows is None else n_rows
    assert R >= n, (R, n)
    idx = np.full((R, K), pad_col, np.int32)
    ww = np.full((R, K), PAD_W, np.float32)
    rows, kpos = _csr_positions(indptr)
    keep = kpos < K
    idx[rows[keep], kpos[keep]] = cols[keep]
    ww[rows[keep], kpos[keep]] = w[keep]
    return idx, ww


def csr_to_sliced_ell(n: int, indptr: np.ndarray, cols: np.ndarray,
                      w: np.ndarray, *, slice_rows: int = 256):
    """Sliced ELLPACK: rows grouped into slices of ``slice_rows``; each slice
    padded to its own max degree.  Returns a list of
    (row_offset, nbr_idx [s,Ks], nbr_w [s,Ks]) — VMEM-friendly blocks with far
    less padding than global ELL on power-law graphs."""
    rows, kpos = _csr_positions(indptr)
    out = []
    for r0 in range(0, n, slice_rows):
        r1 = min(r0 + slice_rows, n)
        deg = np.diff(indptr[r0:r1 + 1])
        Ks = max(1, int(deg.max()) if len(deg) else 1)
        idx = np.zeros((r1 - r0, Ks), np.int32)
        ww = np.full((r1 - r0, Ks), PAD_W, np.float32)
        a, b = indptr[r0], indptr[r1]
        idx[rows[a:b] - r0, kpos[a:b]] = cols[a:b]
        ww[rows[a:b] - r0, kpos[a:b]] = w[a:b]
        out.append((r0, idx, ww))
    return out


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (shared by the layout builders here and
    the engine planners in core/backends/)."""
    m = 1
    while m < x:
        m <<= 1
    return m


def sliced_geometry(widths: list[int], slice_rows: int):
    """Cell addressing of the flat sliced-ELL layout: returns
    ``(offsets i64[S+1], rowk i32[R], base i64[R], total_cells)`` where row
    r's cells occupy ``[base[r], base[r] + rowk[r])``.

    This is THE addressing rule — shared by ``sliced_ell_from_coo`` (rebuild
    placement) and the engine planner (incremental append positions); the
    two must agree bit-for-bit or the device state silently corrupts.
    """
    wid = np.asarray(widths, np.int64)
    offsets = slice_rows * np.r_[0, np.cumsum(wid)]
    rowk = np.repeat(wid, slice_rows).astype(np.int32)
    R = len(widths) * slice_rows
    base = (np.repeat(offsets[:-1], slice_rows)
            + (np.arange(R) % slice_rows) * rowk).astype(np.int64)
    return offsets, rowk, base, int(offsets[-1])


def sliced_ell_from_coo(
    n: int, src: np.ndarray, dst: np.ndarray, w: np.ndarray, *,
    slice_rows: int = 256, hub_k: int = 32, n_rows: int | None = None,
    widths: list[int] | None = None, overflow_capacity: int | None = None,
    row0: int = 0,
):
    """Hub-aware hybrid layout: flat sliced-ELL + COO overflow (by dst).

    Rows are grouped into slices of ``slice_rows`` consecutive ids; each
    slice is padded to its own pow2 width ``K_s`` (the slice's max in-degree
    capped at ``hub_k``).  Rows with in-degree > hub_k are *hubs*: their
    first ``hub_k`` in-neighbors (CSR order) stay in the slice, the surplus
    spills into the COO overflow segment.  The ELL cells are flattened into
    one 1-D buffer (slice s at offset ``slice_rows * sum(widths[:s])``, row-
    major within the slice) so incremental patch ops are single scatters at
    planner-computed flat positions regardless of which slice they hit.

    Returns ``(flat_idx i32[L], flat_w f32[L], fill i32[R], widths,
    osrc i32[C], odst i32[C], ow f32[C], n_overflow)`` with
    ``L = slice_rows * sum(widths)``, ``R = n_rows`` (ceil of n to a slice
    multiple), ``C = overflow_capacity`` (pow2, >= surplus edge count).
    Empty/padding cells carry idx 0 / w +inf; padded overflow entries carry
    src=dst=0 / w=+inf — neither can win a min.

    ``widths`` (one pow2 per slice, each >= the slice's capped max degree)
    and ``overflow_capacity`` override the tight defaults — the engine's
    planner passes its monotone-grown values so rebuilds amortize.

    ``row0`` builds the vertex window ``[row0, row0 + n)``: ``dst`` stays
    globally addressed (every value must fall in the window; the returned
    rows and overflow ``odst`` are window-local), ``src`` ids pass through
    untouched — cells always store global in-neighbor ids.
    """
    assert slice_rows >= 1 and slice_rows == next_pow2(slice_rows), slice_rows
    hub_k = next_pow2(max(hub_k, 1))
    dst = np.asarray(dst, np.int64) - row0
    assert not len(dst) or (dst.min() >= 0 and dst.max() < n), \
        f"dst outside window [row0={row0}, row0+{n})"
    indptr, cols, ws, _ = coo_to_csr(n, np.asarray(src), dst,
                                     np.asarray(w), by="dst")
    R = -(-max(n, 1) // slice_rows) * slice_rows if n_rows is None else n_rows
    assert R >= n and R % slice_rows == 0, (R, n, slice_rows)
    n_slices = R // slice_rows
    deg = np.zeros(R, np.int64)
    deg[:n] = np.diff(indptr)
    capped = np.minimum(deg, hub_k)
    slice_max = capped.reshape(n_slices, slice_rows).max(axis=1)
    if widths is None:
        widths = [next_pow2(int(max(k, 1))) for k in slice_max]
    widths = [int(k) for k in widths]
    assert len(widths) == n_slices, (len(widths), n_slices)
    assert all(k == next_pow2(k) and k <= hub_k for k in widths), widths
    assert all(int(m) <= k for m, k in zip(slice_max, widths)), \
        (slice_max.tolist(), widths)

    _, _, base, L = sliced_geometry(widths, slice_rows)
    flat_idx = np.zeros(L, np.int32)
    flat_w = np.full(L, PAD_W, np.float32)
    rows, kpos = _csr_positions(indptr)
    keep = kpos < hub_k
    pos = base[rows[keep]] + kpos[keep]
    flat_idx[pos] = cols[keep]
    flat_w[pos] = ws[keep]

    o_src, o_dst, o_w = cols[~keep], rows[~keep], ws[~keep]
    n_over = len(o_src)
    C = (next_pow2(max(2 * n_over, 8)) if overflow_capacity is None
         else overflow_capacity)
    assert C >= n_over, (C, n_over)
    osrc = np.zeros(C, np.int32)
    odst = np.zeros(C, np.int32)
    ow = np.full(C, PAD_W, np.float32)
    osrc[:n_over] = o_src
    odst[:n_over] = o_dst
    ow[:n_over] = o_w

    fill = capped.astype(np.int32)
    return flat_idx, flat_w, fill, widths, osrc, odst, ow, n_over


def ell_from_coo(n: int, src: np.ndarray, dst: np.ndarray, w: np.ndarray,
                 *, k: int, n_rows: int | None = None, row0: int = 0):
    """By-destination ELL directly from COO: (nbr_idx, nbr_w, fill).

    ``fill`` is the per-row occupancy (== in-degree; the incremental
    maintenance path treats it as a high-water mark).  Requires
    ``k >= max in-degree`` — the engine's rebuild policy guarantees it.
    ``row0`` builds the vertex window ``[row0, row0 + n)`` from globally
    addressed ``dst`` (src ids pass through untouched).
    """
    dst = np.asarray(dst, np.int64) - row0
    assert not len(dst) or (dst.min() >= 0 and dst.max() < n), \
        f"dst outside window [row0={row0}, row0+{n})"
    indptr, cols, ws, _ = coo_to_csr(n, np.asarray(src), dst,
                                     np.asarray(w), by="dst")
    deg = np.diff(indptr)
    assert int(deg.max(initial=0)) <= k, (int(deg.max(initial=0)), k)
    idx, ww = csr_to_ell(n, indptr, cols, ws, k=k, n_rows=n_rows)
    R = n if n_rows is None else n_rows
    fill = np.zeros(R, np.int32)
    fill[:n] = deg
    return idx, ww, fill
