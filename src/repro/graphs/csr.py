"""CSR / sliced-ELLPACK builders (host-side numpy; device consumers in
kernels/ and core/).

The TPU-native relaxation kernel consumes a *by-destination* sliced-ELLPACK
view: for every dst row, a padded list of (in-neighbor id, weight).  Padding
entries point at row 0 with +inf weight so they never win a min.

All builders are fancy-indexed scatters — no per-row Python loops — so the
dynamic engine can afford full rebuilds on ELL capacity overflow (DESIGN.md
§2.3): a rebuild is O(E) numpy work plus one host->device transfer.
"""
from __future__ import annotations

import numpy as np

PAD_W = np.float32(np.inf)


def coo_to_csr(n: int, src: np.ndarray, dst: np.ndarray, w: np.ndarray,
               *, by: str = "dst"):
    """Sort COO by row (dst or src); returns (indptr, cols, w_sorted, perm)."""
    rows = dst if by == "dst" else src
    cols = src if by == "dst" else dst
    perm = np.argsort(rows, kind="stable")
    rows_s, cols_s, w_s = rows[perm], cols[perm], w[perm]
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, rows_s + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, cols_s, w_s, perm


def _csr_positions(indptr: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(row, column-within-row) for every CSR entry, vectorized."""
    deg = np.diff(indptr)
    rows = np.repeat(np.arange(len(deg)), deg)
    kpos = np.arange(indptr[-1]) - np.repeat(indptr[:-1], deg)
    return rows, kpos


def csr_to_ell(n: int, indptr: np.ndarray, cols: np.ndarray, w: np.ndarray,
               *, k: int | None = None, pad_col: int = 0, n_rows: int | None = None):
    """Dense ELLPACK (n_rows, K) from CSR; K defaults to max row degree.

    Returns (nbr_idx i32[n_rows,K], nbr_w f32[n_rows,K]); pad weight +inf.
    Rows longer than K are truncated (callers pick K >= max degree unless
    deliberately sketching).  ``n_rows >= n`` pads extra all-inf rows at the
    bottom — the engine uses this to round the row count up to the relax
    kernel's block size.
    """
    deg = np.diff(indptr)
    kmax = int(deg.max()) if n and len(cols) else 0
    K = kmax if k is None else k
    K = max(K, 1)
    R = n if n_rows is None else n_rows
    assert R >= n, (R, n)
    idx = np.full((R, K), pad_col, np.int32)
    ww = np.full((R, K), PAD_W, np.float32)
    rows, kpos = _csr_positions(indptr)
    keep = kpos < K
    idx[rows[keep], kpos[keep]] = cols[keep]
    ww[rows[keep], kpos[keep]] = w[keep]
    return idx, ww


def csr_to_sliced_ell(n: int, indptr: np.ndarray, cols: np.ndarray,
                      w: np.ndarray, *, slice_rows: int = 256):
    """Sliced ELLPACK: rows grouped into slices of ``slice_rows``; each slice
    padded to its own max degree.  Returns a list of
    (row_offset, nbr_idx [s,Ks], nbr_w [s,Ks]) — VMEM-friendly blocks with far
    less padding than global ELL on power-law graphs."""
    rows, kpos = _csr_positions(indptr)
    out = []
    for r0 in range(0, n, slice_rows):
        r1 = min(r0 + slice_rows, n)
        deg = np.diff(indptr[r0:r1 + 1])
        Ks = max(1, int(deg.max()) if len(deg) else 1)
        idx = np.zeros((r1 - r0, Ks), np.int32)
        ww = np.full((r1 - r0, Ks), PAD_W, np.float32)
        a, b = indptr[r0], indptr[r1]
        idx[rows[a:b] - r0, kpos[a:b]] = cols[a:b]
        ww[rows[a:b] - r0, kpos[a:b]] = w[a:b]
        out.append((r0, idx, ww))
    return out


def ell_from_coo(n: int, src: np.ndarray, dst: np.ndarray, w: np.ndarray,
                 *, k: int, n_rows: int | None = None):
    """By-destination ELL directly from COO: (nbr_idx, nbr_w, fill).

    ``fill`` is the per-row occupancy (== in-degree; the incremental
    maintenance path treats it as a high-water mark).  Requires
    ``k >= max in-degree`` — the engine's rebuild policy guarantees it.
    """
    indptr, cols, ws, _ = coo_to_csr(n, np.asarray(src), np.asarray(dst),
                                     np.asarray(w), by="dst")
    deg = np.diff(indptr)
    assert int(deg.max(initial=0)) <= k, (int(deg.max(initial=0)), k)
    idx, ww = csr_to_ell(n, indptr, cols, ws, k=k, n_rows=n_rows)
    R = n if n_rows is None else n_rows
    fill = np.zeros(R, np.int32)
    fill[:n] = deg
    return idx, ww, fill
