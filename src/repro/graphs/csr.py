"""CSR / sliced-ELLPACK builders (host-side numpy; device consumers in
kernels/ and core/).

The TPU-native relaxation kernel consumes a *by-destination* sliced-ELLPACK
view: for every dst row, a padded list of (in-neighbor id, weight).  Padding
entries point at row 0 with +inf weight so they never win a min.
"""
from __future__ import annotations

import numpy as np

PAD_W = np.float32(np.inf)


def coo_to_csr(n: int, src: np.ndarray, dst: np.ndarray, w: np.ndarray,
               *, by: str = "dst"):
    """Sort COO by row (dst or src); returns (indptr, cols, w_sorted, perm)."""
    rows = dst if by == "dst" else src
    cols = src if by == "dst" else dst
    perm = np.argsort(rows, kind="stable")
    rows_s, cols_s, w_s = rows[perm], cols[perm], w[perm]
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, rows_s + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, cols_s, w_s, perm


def csr_to_ell(n: int, indptr: np.ndarray, cols: np.ndarray, w: np.ndarray,
               *, k: int | None = None, pad_col: int = 0):
    """Dense ELLPACK (n, K) from CSR; K defaults to max row degree.

    Returns (nbr_idx i32[n,K], nbr_w f32[n,K]); pad weight +inf.
    Rows longer than K are truncated (callers pick K >= max degree unless
    deliberately sketching).
    """
    deg = np.diff(indptr)
    kmax = int(deg.max()) if n and len(cols) else 0
    K = kmax if k is None else k
    K = max(K, 1)
    idx = np.full((n, K), pad_col, np.int32)
    ww = np.full((n, K), PAD_W, np.float32)
    for r in range(n):
        a, b = indptr[r], indptr[r + 1]
        take = min(K, b - a)
        idx[r, :take] = cols[a:a + take]
        ww[r, :take] = w[a:a + take]
    return idx, ww


def csr_to_sliced_ell(n: int, indptr: np.ndarray, cols: np.ndarray,
                      w: np.ndarray, *, slice_rows: int = 256):
    """Sliced ELLPACK: rows grouped into slices of ``slice_rows``; each slice
    padded to its own max degree.  Returns a list of
    (row_offset, nbr_idx [s,Ks], nbr_w [s,Ks]) — VMEM-friendly blocks with far
    less padding than global ELL on power-law graphs."""
    out = []
    for r0 in range(0, n, slice_rows):
        r1 = min(r0 + slice_rows, n)
        deg = np.diff(indptr[r0:r1 + 1])
        Ks = max(1, int(deg.max()) if len(deg) else 1)
        idx = np.zeros((r1 - r0, Ks), np.int32)
        ww = np.full((r1 - r0, Ks), PAD_W, np.float32)
        for i, r in enumerate(range(r0, r1)):
            a, b = indptr[r], indptr[r + 1]
            idx[i, : b - a] = cols[a:b]
            ww[i, : b - a] = w[a:b]
        out.append((r0, idx, ww))
    return out
