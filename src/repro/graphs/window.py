"""Sliding-window event stream generation (paper §5.1.3).

Given an ordered edge list (timestamps == arrival indices for non-temporal
datasets, as in the paper), window size ``W`` and deletion probability
``delta``: upon emitting the ADD with index T, edges with index < T - W are
deleted with probability ``delta`` (each considered once, when they first
fall out of the window).  ``delta=0`` -> addition-only; ``delta=1`` ->
delete-heavy (everything outside the window removed).
"""
from __future__ import annotations

import numpy as np

from repro.core import events as ev


def sliding_window_stream(
    src: np.ndarray,
    dst: np.ndarray,
    w: np.ndarray,
    *,
    window: int,
    delta: float,
    seed: int = 0,
    query_every: int = 0,
) -> ev.EventLog:
    """Build the interleaved ADD/DEL (and optional QUERY) log."""
    rng = np.random.default_rng(seed)
    n = len(src)
    kinds: list[np.ndarray] = []
    srcs: list[np.ndarray] = []
    dsts: list[np.ndarray] = []
    ws: list[np.ndarray] = []

    # decide once, per edge, whether it dies when it exits the window
    dies = rng.random(n) < delta

    # Emit in chunks so DELs interleave at the right positions but the log
    # stays vectorized: process in blocks of `window // 8` (>=1) adds.
    block = max(1, window // 8)
    next_del = 0  # first edge index not yet considered for deletion
    emitted_q = 0
    for a in range(0, n, block):
        b = min(a + block, n)
        kinds.append(np.full(b - a, ev.ADD, np.uint8))
        srcs.append(src[a:b]); dsts.append(dst[a:b]); ws.append(w[a:b].astype(np.float32))
        # edges now outside the window: indices < b - window
        out_hi = max(0, b - window)
        if out_hi > next_del:
            sel = np.arange(next_del, out_hi)
            sel = sel[dies[sel]]
            if len(sel):
                kinds.append(np.full(len(sel), ev.DEL, np.uint8))
                srcs.append(src[sel]); dsts.append(dst[sel])
                ws.append(np.zeros(len(sel), np.float32))
            next_del = out_hi
        if query_every:
            done = b
            while (done - emitted_q * query_every) >= query_every:
                kinds.append(np.array([ev.QUERY], np.uint8))
                srcs.append(np.array([-1], np.int64))
                dsts.append(np.array([-1], np.int64))
                ws.append(np.array([0.0], np.float32))
                emitted_q += 1
    return ev.EventLog(
        np.concatenate(kinds), np.concatenate(srcs).astype(np.int64),
        np.concatenate(dsts).astype(np.int64), np.concatenate(ws))


def stream_stats(log: ev.EventLog) -> dict[str, int]:
    k = log.kind
    return {
        "adds": int((k == ev.ADD).sum()),
        "dels": int((k == ev.DEL).sum()),
        "queries": int((k == ev.QUERY).sum()),
        "events": len(k),
    }
