"""Graph generators (host-side, numpy).

* ``rmat`` — R-MAT (Chakrabarti et al., 2004) with Graph500 parameters
  (a,b,c,d) = (0.57, 0.19, 0.19, 0.05), the paper's RMAT(20) source; weights
  U(0,4) as in the paper's footnote 2.
* ``erdos_renyi`` — uniform random digraphs (small tests).
* ``grid2d`` — deterministic mesh graphs (MeshGraphNet shapes, oracle tests).
* ``power_law_hubs`` — a small web-Google-like graph: a few high in-degree
  hubs (the paper picks top-PageRank sources precisely because they create
  large shortest-path trees).
"""
from __future__ import annotations

import numpy as np


def rmat(scale: int, edge_factor: int = 16, *, a: float = 0.57, b: float = 0.19,
         c: float = 0.19, seed: int = 0, weights: tuple[float, float] = (0.0, 4.0),
         dedup: bool = True) -> tuple[int, np.ndarray, np.ndarray, np.ndarray]:
    """Graph500-style R-MAT. Returns (n, src, dst, w); weights in (lo, hi]."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r = rng.random(m)
        # quadrant choice per Chakrabarti et al.
        go_b = (r >= a) & (r < ab)
        go_c = (r >= ab) & (r < abc)
        go_d = r >= abc
        src += ((go_c | go_d).astype(np.int64)) << bit
        dst += ((go_b | go_d).astype(np.int64)) << bit
    keep = src != dst  # drop self-loops (paper: simple graphs)
    src, dst = src[keep], dst[keep]
    if dedup:
        key = src * n + dst
        _, idx = np.unique(key, return_index=True)
        idx.sort()
        src, dst = src[idx], dst[idx]
    lo, hi = weights
    w = lo + (hi - lo) * rng.random(len(src)).astype(np.float32)
    w = np.maximum(w, 1e-3).astype(np.float32)  # strictly positive (termination)
    return n, src, dst, w


def erdos_renyi(n: int, m: int, *, seed: int = 0,
                weights: tuple[float, float] = (0.5, 2.0)
                ) -> tuple[int, np.ndarray, np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, 4 * m)
    dst = rng.integers(0, n, 4 * m)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    key = src * n + dst
    _, idx = np.unique(key, return_index=True)
    idx.sort()
    src, dst = src[idx][:m], dst[idx][:m]
    lo, hi = weights
    w = (lo + (hi - lo) * rng.random(len(src))).astype(np.float32)
    return n, src, dst, w


def grid2d(rows: int, cols: int, *, bidirectional: bool = True,
           weight: float = 1.0) -> tuple[int, np.ndarray, np.ndarray, np.ndarray]:
    """rows x cols lattice; vertex id = r*cols + c."""
    n = rows * cols
    srcs, dsts = [], []
    for r in range(rows):
        for c in range(cols):
            v = r * cols + c
            if c + 1 < cols:
                srcs.append(v); dsts.append(v + 1)
            if r + 1 < rows:
                srcs.append(v); dsts.append(v + cols)
    src = np.asarray(srcs, np.int64)
    dst = np.asarray(dsts, np.int64)
    if bidirectional:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    w = np.full(len(src), weight, np.float32)
    return n, src, dst, w


def power_law_hubs(n: int, m: int, n_hubs: int = 3, *, seed: int = 0,
                   orientation: str = "out"
                   ) -> tuple[int, np.ndarray, np.ndarray, np.ndarray]:
    """Hub-heavy digraph: ~30% of edges touch a hub endpoint, rest uniform.

    ``orientation="out"`` concentrates the hub mass on the *source* side
    (high out-degree hubs — large reachable sets, the source-selection
    regime).  ``"in"`` concentrates it on the *destination* side (high
    in-degree hubs — the regime that stresses by-destination edge layouts:
    dense ELL pads every row to the hub degree, the sliced/hybrid backend
    exists for exactly this shape — DESIGN.md §6).  Both orientations draw
    identical random streams, so "out" output is unchanged from before the
    parameter existed.
    """
    assert orientation in ("out", "in"), orientation
    rng = np.random.default_rng(seed)
    hubs = rng.choice(n, n_hubs, replace=False)
    m_hub = m // 3
    hub_end = np.concatenate([
        rng.choice(hubs, m_hub),
        rng.integers(0, n, m - m_hub),
    ])
    uni_end = rng.integers(0, n, m)
    src, dst = ((hub_end, uni_end) if orientation == "out"
                else (uni_end, hub_end))
    keep = src != dst
    src, dst = src[keep], dst[keep]
    key = src * n + dst
    _, idx = np.unique(key, return_index=True)
    idx.sort()
    src, dst = src[idx], dst[idx]
    w = np.ones(len(src), np.float32)  # paper: unit weights for real graphs
    return n, src, dst, w


def top_in_degree_sources(n: int, dst: np.ndarray, k: int = 3) -> np.ndarray:
    """Stand-in for the paper's PageRank-on-transpose source selection: the
    top in-degree vertices (PageRank on the transpose is dominated by
    in-degree for these graphs; avoids an extra dependency)."""
    deg = np.bincount(dst, minlength=n)
    return np.argsort(-deg)[:k]
