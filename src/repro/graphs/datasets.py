"""Real-dataset loader: SNAP / Konect edge lists -> the serving trace
format (DESIGN.md §11.3).

The paper evaluates on real-world graphs; Hanauer et al.'s dynamic studies
(PAPERS.md) build update streams from exactly these repositories.  This
module reads the common interchange format — whitespace/tab-separated
``u v [w ...]`` rows with ``#`` (SNAP) or ``%`` (Konect) comment lines,
optionally gzipped — and lowers it to our chunked npz trace:

  1. parse the static edge list (ids may be arbitrary non-negative int64);
  2. compact ids to ``[0, n)`` deterministically (sorted unique order);
  3. synthesize the dynamic portion with the paper's sliding-window model
     (graphs/window.py): edge arrival order is the temporal order, a
     seeded rng decides which edges die when they exit the window — fully
     deterministic for a given (file, window, delta, seed);
  4. write a version-2 chunked trace replayable at O(chunk) host memory.

Rows with fewer than two columns are malformed (``DatasetFormatError``);
a third numeric column is the weight (Konect weighted/TSV), further
columns (e.g. Konect timestamps) are ignored.  Unweighted rows get
deterministic synthetic weights in [0.5, 1.5).

CLI (bad paths exit 2, matching the examples' convention):

    PYTHONPATH=src python -m repro.graphs.datasets IN OUT.npz \
        [--window-frac 0.25] [--delta 0.3] [--seed 0] \
        [--query-every 0] [--chunk-events 65536]
"""
from __future__ import annotations

import gzip
import hashlib
import os
import sys
import urllib.error
import urllib.request

import numpy as np

from repro.graphs import window as window_mod
from repro.serving.trace import ServingTrace

_COMMENT = ("#", "%")
_PARSE_BLOCK = 1 << 20  # lines per parse block (bounds Python-object churn)

# Known dataset registry: name -> (url, sha256-or-None).  A None digest is
# trust-on-first-use: the first fetch records the digest in a ``.sha256``
# sidecar next to the cached file and every later use verifies against it
# (the paper-scale bench runs repeatedly against the same cache, so a
# silent mid-flight corruption or upstream content swap fails loudly).
DATASETS: dict[str, tuple[str, str | None]] = {
    # paper-scale instance for the sparse-frontier bench (DESIGN.md §12.5);
    # CI stays on synthetic RMAT — fetching is opt-in via REPRO_SCALE_DATASET
    "soc-livejournal1": (
        "https://snap.stanford.edu/data/soc-LiveJournal1.txt.gz", None),
    "roadnet-ca": (
        "https://snap.stanford.edu/data/roadNet-CA.txt.gz", None),
}

_CHUNK = 1 << 20


class DatasetFormatError(ValueError):
    """The file exists but is not a parseable edge list."""


class ChecksumError(ValueError):
    """A cached or downloaded dataset failed sha256 verification."""


def dataset_cache_dir() -> str:
    """The on-disk download cache root; ``REPRO_DATASET_CACHE`` overrides
    the default ``~/.cache/repro/datasets`` (CI points it at a tmpdir)."""
    return os.environ.get(
        "REPRO_DATASET_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro", "datasets"))


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(_CHUNK), b""):
            h.update(block)
    return h.hexdigest()


def fetch_dataset(name_or_url: str, *, sha256: str | None = None,
                  cache_dir: str | None = None) -> str:
    """Return a local path to the (cached) dataset, downloading on miss.

    ``name_or_url`` is either a ``DATASETS`` registry key (its url + pinned
    digest are used) or a raw url (``file://`` works — the tests exercise
    the full cache path without network).  Verification order: an explicit
    ``sha256`` argument beats the registry pin beats the sidecar digest
    recorded at first fetch.  A mismatch raises ``ChecksumError`` and
    leaves the offending file in place for inspection; downloads land via
    a temp file + atomic rename so a crashed fetch never poisons the
    cache."""
    url, expected = name_or_url, sha256
    if name_or_url in DATASETS:
        url, pinned = DATASETS[name_or_url]
        expected = sha256 if sha256 is not None else pinned
    cache = cache_dir or dataset_cache_dir()
    os.makedirs(cache, exist_ok=True)
    fname = os.path.basename(url.rstrip("/")) or "dataset"
    path = os.path.join(cache, fname)
    sidecar = path + ".sha256"
    if not os.path.exists(path):
        tmp = path + ".part"
        with urllib.request.urlopen(url) as r, open(tmp, "wb") as out:
            for block in iter(lambda: r.read(_CHUNK), b""):
                out.write(block)
        os.replace(tmp, path)
    digest = _sha256_file(path)
    if expected is None and os.path.exists(sidecar):
        with open(sidecar) as f:
            expected = f.read().strip() or None
    if expected is not None and digest != expected:
        raise ChecksumError(
            f"{path}: sha256 mismatch — expected {expected}, got {digest} "
            f"(delete the cached file to re-fetch)")
    if not os.path.exists(sidecar):
        with open(sidecar, "w") as f:
            f.write(digest + "\n")
    return path


def _open_text(path: str):
    if str(path).endswith(".gz"):
        return gzip.open(path, "rt")
    return open(path, "r")


def parse_edge_list(path: str, *, weight_seed: int = 0
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Parse a SNAP/Konect edge list into (src i64, dst i64, w f32) with
    the file's raw vertex ids.  Raises ``FileNotFoundError`` for a missing
    path and ``DatasetFormatError`` for malformed content."""
    srcs: list[np.ndarray] = []
    dsts: list[np.ndarray] = []
    ws: list[np.ndarray] = []
    n_unweighted = 0
    with _open_text(path) as f:
        block_u: list[int] = []
        block_v: list[int] = []
        block_w: list[float] = []

        def flush():
            nonlocal block_u, block_v, block_w
            if block_u:
                srcs.append(np.asarray(block_u, np.int64))
                dsts.append(np.asarray(block_v, np.int64))
                ws.append(np.asarray(block_w, np.float32))
                block_u, block_v, block_w = [], [], []

        for lineno, line in enumerate(f, 1):
            s = line.strip()
            if not s or s.startswith(_COMMENT):
                continue
            cols = s.split()
            if len(cols) < 2:
                raise DatasetFormatError(
                    f"{path}:{lineno}: expected 'u v [w]' columns, got "
                    f"{s!r}")
            try:
                u, v = int(cols[0]), int(cols[1])
                w = float(cols[2]) if len(cols) > 2 else -1.0
            except ValueError as e:
                raise DatasetFormatError(
                    f"{path}:{lineno}: non-numeric edge row {s!r}") from e
            if w < 0:
                # missing or non-positive weight -> synthesize below
                w = -1.0
                n_unweighted += 1
            block_u.append(u)
            block_v.append(v)
            block_w.append(w)
            if len(block_u) >= _PARSE_BLOCK:
                flush()
        flush()
    if not srcs:
        raise DatasetFormatError(f"{path}: no edge rows found")
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    w = np.concatenate(ws)
    if n_unweighted:
        # deterministic synthetic weights (seeded, index-addressed) for
        # unweighted datasets — the paper's instances are weighted
        rng = np.random.default_rng(weight_seed)
        synth = rng.uniform(0.5, 1.5, len(w)).astype(np.float32)
        w = np.where(w < 0, synth, w)
    if src.min() < 0 or dst.min() < 0:
        raise DatasetFormatError(f"{path}: negative vertex ids")
    return src, dst, w.astype(np.float32)


def compact_ids(src: np.ndarray, dst: np.ndarray
                ) -> tuple[int, np.ndarray, np.ndarray]:
    """Relabel raw ids to [0, n) in sorted-unique order (deterministic for
    a given edge set, independent of row order)."""
    ids = np.unique(np.concatenate([src, dst]))
    return (len(ids), np.searchsorted(ids, src).astype(np.int64),
            np.searchsorted(ids, dst).astype(np.int64))


def dataset_to_trace(path: str, *, window_frac: float = 0.25,
                     delta: float = 0.3, seed: int = 0,
                     query_every: int = 0, events_per_s: float = 1e6
                     ) -> tuple[int, ServingTrace]:
    """Load an edge list and synthesize the dynamic trace; returns
    ``(num_vertices, trace)``.  ``window_frac`` is the sliding-window size
    as a fraction of the edge count; ``delta`` the deletion probability
    for edges falling out of the window (paper §5.1.3)."""
    if not 0.0 < window_frac <= 1.0:
        raise ValueError(f"window_frac must be in (0, 1]; got {window_frac}")
    src, dst, w = parse_edge_list(path, weight_seed=seed)
    n, src, dst = compact_ids(src, dst)
    log = window_mod.sliding_window_stream(
        src, dst, w, window=max(1, int(len(src) * window_frac)),
        delta=delta, seed=seed, query_every=query_every)
    return n, ServingTrace.from_log(log, events_per_s=events_per_s)


def load_named_dataset(name_or_url: str, *, sha256: str | None = None,
                       cache_dir: str | None = None, **kw
                       ) -> tuple[int, ServingTrace]:
    """``fetch_dataset`` + ``dataset_to_trace`` in one call — the entry the
    paper-scale bench uses (``REPRO_SCALE_DATASET=soc-livejournal1``)."""
    path = fetch_dataset(name_or_url, sha256=sha256, cache_dir=cache_dir)
    return dataset_to_trace(path, **kw)


def load_dataset_or_exit(path: str, **kw) -> tuple[int, ServingTrace]:
    """CLI wrapper: exit code 2 on missing or malformed dataset paths —
    the same contract as serving.trace.load_trace_or_exit.  Registry names
    and raw urls fetch through the verified cache first."""
    try:
        if path in DATASETS or "://" in path:
            return load_named_dataset(path, **kw)
        return dataset_to_trace(path, **kw)
    except (FileNotFoundError, DatasetFormatError, ChecksumError,
            urllib.error.URLError) as e:
        print(f"error: {e}", file=sys.stderr)
        sys.exit(2)


def main(argv: list[str] | None = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.graphs.datasets",
        description="SNAP/Konect edge list -> chunked serving trace")
    ap.add_argument("edge_list", help="input edge list (.gz ok)")
    ap.add_argument("out", help="output trace path (npz container)")
    ap.add_argument("--window-frac", type=float, default=0.25)
    ap.add_argument("--delta", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--query-every", type=int, default=0)
    ap.add_argument("--chunk-events", type=int, default=65536,
                    help="events per chunk in the version-2 container")
    args = ap.parse_args(argv)
    n, trace = load_dataset_or_exit(
        args.edge_list, window_frac=args.window_frac, delta=args.delta,
        seed=args.seed, query_every=args.query_every)
    trace.save(args.out, chunk_events=args.chunk_events)
    stats = window_mod.stream_stats(trace.to_log())
    print(f"{args.edge_list}: n={n} -> {args.out} "
          f"(adds={stats['adds']} dels={stats['dels']} "
          f"queries={stats['queries']}, chunks of {args.chunk_events})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
