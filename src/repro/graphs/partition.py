"""Vertex partitioning for the shared-nothing distributed engine.

The paper's model: "each node owns a disjoint subset of vertices and their
edges".  We partition vertices into P contiguous ranges *balanced by
in-degree* (edge-balanced), because the per-partition relaxation cost is
proportional to owned in-edges, not owned vertices — this is the static
equivalent of straggler avoidance for BSP rounds.

Edges are owned by the partition of their **dst** so the scatter-min in each
relaxation round is partition-local; only ``dist[src]`` crosses partitions.
"""
from __future__ import annotations

import numpy as np


def edge_balanced_ranges(n: int, dst: np.ndarray, parts: int) -> np.ndarray:
    """Returns boundaries b[0..parts] with b[0]=0, b[parts]=n such that each
    vertex range [b[i], b[i+1]) owns ~equal numbers of in-edges."""
    deg = np.bincount(dst, minlength=n).astype(np.int64)
    csum = np.concatenate([[0], np.cumsum(deg)])
    total = csum[-1]
    targets = (np.arange(1, parts) * total) // parts
    cuts = np.searchsorted(csum, targets, side="left")
    b = np.concatenate([[0], cuts, [n]])
    return np.maximum.accumulate(b)  # enforce monotonicity for empty parts


def uniform_ranges(n: int, parts: int) -> np.ndarray:
    b = (np.arange(parts + 1) * n) // parts
    return b


def owner_of(vertices: np.ndarray, bounds: np.ndarray) -> np.ndarray:
    """Partition id owning each vertex (bounds as from *_ranges)."""
    return np.clip(np.searchsorted(bounds, vertices, side="right") - 1,
                   0, len(bounds) - 2)


def pad_ranges_to_equal(bounds: np.ndarray) -> int:
    """Static per-partition capacity = max range width (device arrays must be
    equal-shaped across shards)."""
    return int(np.max(np.diff(bounds)))


def relabel_to_uniform(bounds: np.ndarray
                       ) -> tuple[np.ndarray, np.ndarray, int]:
    """Vertex relabeling that turns variable-width ranges into the uniform
    layout the device mesh wants.

    Device shards must be equal-shaped, but ``edge_balanced_ranges`` produces
    variable-width ranges.  The bridge is a permutation into a *padded* id
    space: partition ``p``'s vertices are packed at ``[p*npp, p*npp+width_p)``
    where ``npp = max range width``; the tail of each padded range is unused
    (no edges ever reference it, so it is inert in every epoch).

    Returns ``(perm, inv, npp)``: ``perm`` (i32[n]) maps original -> padded
    id, ``inv`` (i32[parts*npp]) maps padded -> original with -1 on padding.
    """
    widths = np.diff(bounds)
    parts = len(widths)
    npp = int(widths.max()) if parts else 0
    n = int(bounds[-1])
    v = np.arange(n)
    own = owner_of(v, bounds)
    perm = (own * npp + (v - bounds[own])).astype(np.int32)
    inv = np.full(parts * npp, -1, np.int32)
    inv[perm] = v
    return perm, inv, npp


def edge_balanced_relabeling(n: int, dst: np.ndarray, parts: int
                             ) -> tuple[np.ndarray, np.ndarray, int]:
    """Edge-balanced placement as a relabeling: cut ``n`` vertices into
    ``parts`` ranges of ~equal in-degree mass (from a reference ``dst``
    sample, e.g. the expected stream), then relabel to the uniform padded
    layout.  Feed ``perm``/``inv`` to the sharded engine (or apply ``perm``
    to src/dst before ``DistributedSSSP.place_edges``) so each shard owns
    ~equal relaxation work instead of ~equal vertex counts."""
    return relabel_to_uniform(edge_balanced_ranges(n, dst, parts))
