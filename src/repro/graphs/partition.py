"""Vertex partitioning for the shared-nothing distributed engine.

The paper's model: "each node owns a disjoint subset of vertices and their
edges".  We partition vertices into P contiguous ranges *balanced by
in-degree* (edge-balanced), because the per-partition relaxation cost is
proportional to owned in-edges, not owned vertices — this is the static
equivalent of straggler avoidance for BSP rounds.

Edges are owned by the partition of their **dst** so the scatter-min in each
relaxation round is partition-local; only ``dist[src]`` crosses partitions.
"""
from __future__ import annotations

import numpy as np


def edge_balanced_ranges(n: int, dst: np.ndarray, parts: int) -> np.ndarray:
    """Returns boundaries b[0..parts] with b[0]=0, b[parts]=n such that each
    vertex range [b[i], b[i+1]) owns ~equal numbers of in-edges."""
    deg = np.bincount(dst, minlength=n).astype(np.int64)
    csum = np.concatenate([[0], np.cumsum(deg)])
    total = csum[-1]
    targets = (np.arange(1, parts) * total) // parts
    cuts = np.searchsorted(csum, targets, side="left")
    b = np.concatenate([[0], cuts, [n]])
    return np.maximum.accumulate(b)  # enforce monotonicity for empty parts


def uniform_ranges(n: int, parts: int) -> np.ndarray:
    b = (np.arange(parts + 1) * n) // parts
    return b


def owner_of(vertices: np.ndarray, bounds: np.ndarray) -> np.ndarray:
    """Partition id owning each vertex (bounds as from *_ranges)."""
    return np.clip(np.searchsorted(bounds, vertices, side="right") - 1,
                   0, len(bounds) - 2)


def pad_ranges_to_equal(bounds: np.ndarray) -> int:
    """Static per-partition capacity = max range width (device arrays must be
    equal-shaped across shards)."""
    return int(np.max(np.diff(bounds)))
