from repro.graphs import generators, window, partition, csr  # noqa: F401
