"""Host-side triplet index construction for DimeNet-style directional MP.

For each directed edge e_ji = (j -> i), its triplets are the edges
e_kj = (k -> j) with k != i: message m_kj feeds m_ji through the angular
basis.  We emit flat (t_kj, t_ji) edge-index arrays, padded/capped to a
static budget (mega-graphs: uniform per-edge cap, recorded in DESIGN.md §9).
"""
from __future__ import annotations

import numpy as np


def build_triplets(num_nodes: int, src: np.ndarray, dst: np.ndarray,
                   *, budget: int | None = None, per_edge_cap: int = 8,
                   seed: int = 0) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (t_kj, t_ji, mask), each of length ``budget`` (or exact count
    when budget is None).

    t_kj[t] / t_ji[t] index into the edge arrays; mask marks real triplets.
    """
    E = len(src)
    rng = np.random.default_rng(seed)
    # in-edges of each node: CSR over dst
    order = np.argsort(dst, kind="stable")
    eid_by_dst = order
    indptr = np.zeros(num_nodes + 1, np.int64)
    np.add.at(indptr, dst + 1, 1)
    np.cumsum(indptr, out=indptr)

    t_kj, t_ji = [], []
    for e in range(E):
        j, i = src[e], dst[e]
        lo, hi = indptr[j], indptr[j + 1]
        cand = eid_by_dst[lo:hi]                 # edges (k -> j)
        cand = cand[src[cand] != i]              # exclude backtracking k == i
        if per_edge_cap and len(cand) > per_edge_cap:
            cand = rng.choice(cand, per_edge_cap, replace=False)
        t_kj.extend(cand.tolist())
        t_ji.extend([e] * len(cand))

    t_kj = np.asarray(t_kj, np.int32)
    t_ji = np.asarray(t_ji, np.int32)
    n = len(t_kj)
    if budget is None:
        return t_kj, t_ji, np.ones(n, bool)
    out_kj = np.zeros(budget, np.int32)
    out_ji = np.zeros(budget, np.int32)
    mask = np.zeros(budget, bool)
    m = min(n, budget)
    if n > budget:   # uniform downsample (documented cap)
        take = rng.choice(n, budget, replace=False)
        out_kj[:], out_ji[:], mask[:] = t_kj[take], t_ji[take], True
    else:
        out_kj[:m], out_ji[:m], mask[:m] = t_kj[:m], t_ji[:m], True
    return out_kj, out_ji, mask


def triplet_budget(num_edges: int, factor: float = 2.0,
                   cap: int = 134_217_728) -> int:
    """Static triplet budget for dry-run input specs: factor·E, capped."""
    return int(min(num_edges * factor, cap))
