"""Sharded, chunked, atomic checkpointing with elastic resharding.

Layout (one directory per step)::

    <dir>/step_000123.tmp/            # written first
        leaf_00000.npy ...            # one file per pytree leaf (chunked
        leaf_00001.npy                #   along dim0 above chunk_bytes)
        MANIFEST.json                 # tree structure, shapes, chunking
    <dir>/step_000123/                # atomic rename when complete

Fault-tolerance contract:
  * a crash mid-write leaves only ``*.tmp`` — ``latest_step`` never sees it;
  * ``save`` is synchronous by default; ``async_save`` runs in a worker
    thread and overlaps the next training step (device->host copy happens
    first, so the arrays snapshot is consistent);
  * ``restore(..., sharding_tree=...)`` re-shards on load: a checkpoint
    written on mesh A loads onto mesh B (elastic scaling) because leaves are
    stored as full logical arrays (gathered chunks), not per-device shards.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

_FLAG = "__ckpt_leaf__"


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _chunks(arr: np.ndarray, chunk_bytes: int):
    if arr.nbytes <= chunk_bytes or arr.ndim == 0 or arr.shape[0] <= 1:
        return [arr]
    rows = max(1, int(chunk_bytes // max(arr.nbytes // arr.shape[0], 1)))
    return [arr[i:i + rows] for i in range(0, arr.shape[0], rows)]


def save(tree: Any, directory: str, step: int, *,
         chunk_bytes: int = 256 * 1024 * 1024) -> str:
    """Write checkpoint; returns the final path."""
    leaves, treedef = _flatten(tree)
    host = [np.asarray(jax.device_get(l)) for l in leaves]

    tmp = os.path.join(directory, f"step_{step:09d}.tmp")
    final = os.path.join(directory, f"step_{step:09d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, arr in enumerate(host):
        parts = _chunks(arr, chunk_bytes)
        names = []
        for j, part in enumerate(parts):
            name = f"leaf_{i:05d}_{j:04d}.npy"
            np.save(os.path.join(tmp, name), part)
            names.append(name)
        manifest["leaves"].append({
            "files": names, "shape": list(arr.shape),
            "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # atomic publish
    return final


class AsyncSaver:
    """One-in-flight async checkpointing (device->host copy is synchronous;
    disk I/O overlaps the next step)."""

    def __init__(self):
        self._thread: threading.Thread | None = None

    def save(self, tree: Any, directory: str, step: int, **kw) -> None:
        self.wait()
        leaves, treedef = _flatten(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        snapshot = jax.tree_util.tree_unflatten(treedef, host)
        self._thread = threading.Thread(
            target=save, args=(snapshot, directory, step), kwargs=kw)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(directory, d, "MANIFEST.json"))]
    return max(steps) if steps else None


def restore(tree_like: Any, directory: str, step: int | None = None,
            *, sharding_tree: Any = None) -> Any:
    """Load into the structure of ``tree_like`` (shapes validated).

    ``sharding_tree``: optional pytree of shardings (same structure) —
    leaves are device_put with them (elastic reshard on a new mesh).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)

    leaves_like, treedef = _flatten(tree_like)
    if len(leaves_like) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"expected {len(leaves_like)}")
    sh_leaves = (None,) * len(leaves_like)
    if sharding_tree is not None:
        sh_leaves = jax.tree_util.tree_flatten(
            sharding_tree, is_leaf=lambda x: hasattr(x, "device_set") or x is None
        )[0]

    out = []
    for like, meta, sh in zip(leaves_like, manifest["leaves"], sh_leaves):
        parts = [np.load(os.path.join(path, n)) for n in meta["files"]]
        arr = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"shape mismatch: ckpt {arr.shape} vs "
                             f"expected {tuple(like.shape)}")
        arr = arr.astype(like.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def cleanup(directory: str, keep: int = 3) -> None:
    """Retention: keep the newest ``keep`` complete checkpoints."""
    if not os.path.isdir(directory):
        return
    steps = sorted(s for s in (
        int(d.split("_")[1]) for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)
