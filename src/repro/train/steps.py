"""Generic train-step builders: loss -> grads (with microbatch accumulation)
-> AdamW update.

Gradient accumulation is a ``lax.scan`` over microbatches with an f32
accumulator pytree — the standard memory lever for the big train cells
(mistral-large train_4k runs accum=16).  The scan also gives XLA a natural
compute/communication overlap point: the gradient all-reduce of microbatch i
overlaps the forward of i+1 (no barrier between them in the HLO).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.train import optimizer as opt_mod


def make_train_step(loss_fn: Callable, opt_cfg: opt_mod.AdamWConfig,
                    grad_accum: int = 1):
    """loss_fn(params, batch) -> (loss, metrics).

    Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  With grad_accum > 1, every batch leaf must arrive PRE-SPLIT
    as (grad_accum, micro_batch, ...) — splitting host-side keeps each
    microbatch sharded over the data axes (an in-jit reshape of a
    batch-sharded dim would put microbatch i entirely on device i, turning
    the scan into a serial device walk).
    """
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            (_, metrics), grads = grad_fn(params, batch)
        else:
            micro = batch

            def acc_body(carry, mb):
                g_acc, m_acc = carry
                (_, metrics), grads = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                m_acc = jax.tree.map(lambda a, m: a + m, m_acc, metrics)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = jax.eval_shape(lambda: grad_fn(params, jax.tree.map(
                lambda x: x[0], micro))[0][1])
            m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m0)
            (grads, metrics), _ = jax.lax.scan(
                acc_body, (g0, m0), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            metrics = jax.tree.map(lambda m: m / grad_accum, metrics)

        params, opt_state, om = opt_mod.adamw_update(
            grads, opt_state, params, opt_cfg)
        return params, opt_state, {**metrics, **om}

    return train_step


def make_eval_step(loss_fn: Callable):
    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics
    return eval_step
