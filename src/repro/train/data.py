"""Data pipelines (host-side, deterministic, restart-safe).

* ``TokenStream`` — synthetic-but-structured LM corpus: a Zipf unigram
  stream with Markov bigram mixing so the loss has real signal (the 100M
  end-to-end example trains to visibly decreasing loss).  Sharded by
  (host, step) so every restart resumes exactly (state = step counter only).
* ``ClickStream`` — DIN training batches: user behaviour sequences with a
  planted preference structure (clicked items share categories with the
  history) so AUC is learnable.
* GNN datasets come from graphs/generators.py + graphs/sampler.py.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    step: int = 0          # restart-safe position

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng((self.seed, step))

    def next_batch(self) -> dict:
        rng = self._rng(self.step)
        self.step += 1
        B, S, V = self.batch, self.seq_len, self.vocab_size
        # Zipf marginals + deterministic bigram successor (i -> 7i+3 mod V)
        # mixed 50/50: predictable structure a model can learn.
        zipf = rng.zipf(1.3, size=(B, S)).astype(np.int64) % V
        toks = np.empty((B, S), np.int64)
        toks[:, 0] = zipf[:, 0]
        follow = rng.random((B, S)) < 0.5
        for t in range(1, S):
            succ = (7 * toks[:, t - 1] + 3) % V
            toks[:, t] = np.where(follow[:, t], succ, zipf[:, t])
        tokens = toks.astype(np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], np.full((B, 1), -1, np.int32)], axis=1)
        return {"tokens": tokens, "labels": labels}

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])


@dataclasses.dataclass
class ClickStream:
    n_items: int
    n_cates: int
    batch: int
    seq_len: int = 100
    seed: int = 0
    step: int = 0

    def next_batch(self) -> dict:
        rng = np.random.default_rng((self.seed, self.step))
        self.step += 1
        B, S = self.batch, self.seq_len
        cate_of = lambda item: item % self.n_cates
        # histories cluster in a per-user band of categories so that
        # category-presence carries signal even with few categories
        band = rng.integers(0, self.n_cates, B)
        width = max(self.n_cates // 8, 1)
        hist_c = (band[:, None] + rng.integers(0, width, (B, S))) % self.n_cates
        hist = hist_c + self.n_cates * rng.integers(
            0, max(self.n_items // self.n_cates, 1), (B, S))
        hist_len = rng.integers(S // 4, S + 1, B)
        mask = np.arange(S)[None, :] < hist_len[:, None]
        # positives share the user's category band; negatives are drawn
        # from outside it (hard label structure the model can learn)
        pos = rng.random(B) < 0.5
        pos_c = (band + rng.integers(0, width, B)) % self.n_cates
        neg_c = (band + width + rng.integers(
            0, max(self.n_cates - width, 1), B)) % self.n_cates
        tc = np.where(pos, pos_c, neg_c)
        target = tc + self.n_cates * rng.integers(
            0, max(self.n_items // self.n_cates, 1), B)
        return {
            "target_item": target.astype(np.int32),
            "target_cate": cate_of(target).astype(np.int32),
            "hist_items": hist.astype(np.int32),
            "hist_cates": cate_of(hist).astype(np.int32),
            "hist_mask": mask,
            "labels": pos.astype(np.float32),
        }

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])
