"""AdamW in pure JAX (no optax dependency), with global-norm clipping and a
linear-warmup + cosine schedule.

State is a pytree mirroring params (m, v) + a scalar step — it inherits the
params' sharding (FSDP-sharded optimizer state == ZeRO), which is what makes
the 123B config fit: 16 bytes/param spread over every chip.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def adamw_init(params: Any) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params),
            "step": jnp.int32(0)}


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads: Any, state: dict, params: Any, cfg: AdamWConfig
                 ) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = schedule(step, cfg)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh, vh = m / b1c, v / b2c
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
