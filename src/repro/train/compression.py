"""Gradient compression: int8 quantization with error feedback (EF-SGD
family), for the cross-pod (DCN) gradient all-reduce.

Within a pod the ICI is fast enough that gradients stay bf16/f32; across
pods the DCN link is the bottleneck, so the pod-axis all-reduce is the one
worth compressing (4x over f32).  Error feedback keeps the quantization
noise from accumulating: the residual e_t is added back before the next
quantization, making the scheme unbiased in the long run (Karimireddy et
al., 2019).

``compressed_psum`` is the collective building block (used inside
shard_map over the pod axis); ``ef_state`` / ``apply_ef`` wrap it with the
error-feedback memory.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8: returns (q int8, scale f32)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Quantize -> all-reduce int8 (as int32 accumulate) -> dequantize.

    The wire format is int8 (4x smaller than f32); accumulation happens in
    int32 with per-participant scales reconciled by taking the max scale
    (each participant re-quantizes to the shared scale first so the sum is
    exact in the shared grid).
    """
    q, scale = quantize_int8(x)
    smax = jax.lax.pmax(scale, axis_name)
    # requantize into the shared grid (cheap: scale ratio multiply)
    q_shared = jnp.clip(jnp.round(q.astype(jnp.float32) * (scale / smax)),
                        -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q_shared.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * smax


def ef_init(grads_like: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def ef_compress_tree(grads: Any, ef: Any, axis_name: str) -> tuple[Any, Any]:
    """Error-feedback compressed all-reduce over a gradient pytree.

    Returns (reduced_grads, new_ef).  Usage (inside shard_map over the pod
    axis): g_hat, ef = ef_compress_tree(local_grads, ef, "pod").
    """

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_int8(corrected)
        sent = dequantize_int8(q, scale)
        new_e = corrected - sent
        reduced = compressed_psum(corrected, axis_name)
        return reduced, new_e

    out = jax.tree.map(one, grads, ef)
    red = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return red, new_ef


def compression_ratio(tree: Any) -> float:
    """Wire bytes int8 / f32 (plus one f32 scale per tensor)."""
    f32 = sum(x.size * 4 for x in jax.tree.leaves(tree))
    i8 = sum(x.size * 1 + 4 for x in jax.tree.leaves(tree))
    return i8 / f32
