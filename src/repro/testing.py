"""Hypothesis compatibility layer for the test suite.

The seed image does not ship ``hypothesis`` (and CI images may not either),
which used to make three test modules fail at *collection* — taking every
non-property test in them down too.  Tests import ``given``/``settings``/``st``
from here instead:

  * when hypothesis is installed, this module re-exports the real thing
    (full shrinking, database, health checks);
  * otherwise a minimal deterministic random-sampling fallback runs each
    property test ``max_examples`` times with values drawn from a seeded PRNG.
    No shrinking, but the properties are still exercised — strictly better
    than ``pytest.importorskip`` which would skip whole modules.

Only the strategy surface the suite actually uses is implemented:
``integers``, ``floats``, ``booleans``, ``sampled_from``, ``composite``.
Adding a new strategy to a test?  Extend the fallback below (or just install
hypothesis — see requirements.txt).
"""
from __future__ import annotations

HAVE_HYPOTHESIS = True
try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random
    import zlib

    class _Strategy:
        """A strategy is just ``example(rng) -> value`` here."""

        def __init__(self, draw_fn):
            self._draw = draw_fn

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _St:
        """Fallback for ``hypothesis.strategies`` (the used subset)."""

        @staticmethod
        def integers(min_value=None, max_value=None):
            lo = -(2**31) if min_value is None else min_value
            hi = 2**31 - 1 if max_value is None else max_value
            return _Strategy(lambda rng: rng.randint(lo, hi))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_ignored):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(lambda rng: items[rng.randrange(len(items))])

        @staticmethod
        def composite(fn):
            def build(*args, **kwargs):
                def draw_value(rng):
                    return fn(lambda strat: strat.example(rng), *args, **kwargs)
                return _Strategy(draw_value)
            return build

    st = _St()

    def settings(max_examples: int = 10, **_ignored):
        """Records ``max_examples`` on the function; order-independent with
        ``given`` (functools.wraps copies the attribute through)."""

        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(*gargs, **gkwargs):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_compat_max_examples", 10)
                base = zlib.crc32(fn.__qualname__.encode())
                for i in range(n):
                    rng = random.Random(base * 1_000_003 + i)
                    drawn = [s.example(rng) for s in gargs]
                    kdrawn = {k: s.example(rng) for k, s in gkwargs.items()}
                    try:
                        fn(*args, *drawn, **kwargs, **kdrawn)
                    except Exception as e:  # no shrinking: report the draw
                        raise AssertionError(
                            f"property falsified on example {i}: "
                            f"args={drawn} kwargs={kdrawn}") from e
            # hide the drawn parameters from pytest's fixture resolution
            # (functools.wraps leaks the inner signature via __wrapped__)
            wrapper.__signature__ = inspect.Signature()
            del wrapper.__wrapped__
            return wrapper

        return deco
