"""Three-term roofline report from a compiled dry-run artifact.

Hardware model (TPU v5e, per chip):
  peak bf16 compute  197 TFLOP/s
  HBM bandwidth      819 GB/s
  ICI link bandwidth ~50 GB/s per link share

Terms (seconds, per step, per device — the SPMD module is per-device):
  compute    = HLO_FLOPs / 197e12
  memory     = HLO_bytes / 819e9
  collective = wire_bytes / 50e9     (ring-model wire bytes; the raw
               operand-byte sum per the assignment definition is also
               reported)
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.roofline import hlo_analysis as H

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    coll_operand_bytes: float
    coll_wire_bytes: float
    coll_by_type: dict
    dynamic_whiles: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Lower-bound step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self, model_flops_global: float,
                          chips: int) -> float:
        """'How close to roofline': useful-FLOPs time at peak vs the bound."""
        useful_s = model_flops_global / (chips * PEAK_FLOPS)
        return useful_s / max(self.bound_s, 1e-30)

    def mfu_ratio(self, model_flops_global: float, chips: int) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global) — remat/redundancy waste probe."""
        return model_flops_global / max(self.flops * chips, 1e-30)


def roofline_from_text(hlo_text: str, *, default_trip: float = 1.0,
                       num_partitions: int = 1) -> Roofline:
    cost = H.analyze_text(hlo_text, default_trip=default_trip,
                          num_partitions=num_partitions)
    return Roofline(
        compute_s=cost.flops / PEAK_FLOPS,
        memory_s=cost.hbm_bytes / HBM_BW,
        collective_s=cost.coll_wire_bytes / ICI_BW,
        flops=cost.flops,
        hbm_bytes=cost.hbm_bytes,
        coll_operand_bytes=cost.coll_operand_bytes,
        coll_wire_bytes=cost.coll_wire_bytes,
        coll_by_type=dict(cost.coll_by_type),
        dynamic_whiles=cost.dynamic_whiles,
    )


def report_dict(rf: Roofline, meta: dict, chips: int) -> dict[str, Any]:
    mf = float(meta.get("model_flops", 0.0))
    return {
        "compute_s": rf.compute_s,
        "memory_s": rf.memory_s,
        "collective_s": rf.collective_s,
        "dominant": rf.dominant,
        "bound_s": rf.bound_s,
        "flops_per_device": rf.flops,
        "hbm_bytes_per_device": rf.hbm_bytes,
        "coll_operand_bytes": rf.coll_operand_bytes,
        "coll_wire_bytes": rf.coll_wire_bytes,
        "coll_by_type": rf.coll_by_type,
        "dynamic_whiles": rf.dynamic_whiles,
        "model_flops": mf,
        "model_flops_ratio": rf.mfu_ratio(mf, chips) if mf else None,
        "roofline_fraction": rf.roofline_fraction(mf, chips) if mf else None,
        "chips": chips,
    }
