"""Post-optimization HLO cost walker with while-loop trip multiplication.

``compiled.cost_analysis()`` counts each while body ONCE; all our big
programs are scans (layers x microbatches x kv-blocks), so we do our own
accounting over ``compiled.as_text()``:

  * FLOPs        — dots (2 * result_elems * contracted), elementwise/reduce
                   (1/elem), in fusion bodies too;
  * HBM bytes    — operand + result bytes at fusion boundaries (internals of
                   a fusion stay in registers/VMEM);
  * collectives  — per op: operand/result bytes, group size (from
                   replica_groups), and an estimated per-device WIRE byte
                   count (ring terms: all-reduce 2x(g-1)/g, all-gather /
                   reduce-scatter / all-to-all (g-1)/g, permute 1x);
  * while loops  — costs multiplied by ``known_trip_count`` from
                   backend_config (exact for lax.scan-derived loops);
                   data-dependent loops (the SSSP fixpoints) have none and
                   use ``default_trip`` (report per-round costs with
                   default_trip=1).

All numbers are PER DEVICE (the compiled module is the SPMD per-device
program; shapes in it are already sharded).
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Any

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1, "token": 0, "opaque": 0,
}

_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "ragged-all-to-all",
}
_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "partition-id", "replica-id", "after-all", "all-reduce-done",
    "all-gather-done", "collective-permute-done", "copy-start", "copy-done",
    "opt-barrier", "domain", "rng-get-and-update-state",
}
_ELEMENTWISE_FLOPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "rsqrt", "sqrt", "tanh", "negate", "abs", "sign",
    "cosine", "sine", "atan2", "logistic", "expm1", "log1p", "remainder",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "compare",
    "select", "and", "or", "xor", "not", "clamp", "convert", "erf",
    "cbrt", "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "reduce", "reduce-window", "map", "exponential-minus-one",
    "stochastic-convert", "clz", "popcnt",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(shape_str: str) -> float:
    total = 0
    for _, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _first_shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    opcode: str
    rest: str           # everything after the open paren (operands + attrs)
    operands: list[str]


@dataclasses.dataclass
class CollectiveRecord:
    opcode: str
    operand_bytes: float
    result_bytes: float
    group_size: int
    wire_bytes: float
    count: float        # trip-multiplied occurrence count


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_operand_bytes: float = 0.0
    coll_wire_bytes: float = 0.0
    coll_by_type: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collectives: list = dataclasses.field(default_factory=list)
    dynamic_whiles: int = 0

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.coll_operand_bytes += other.coll_operand_bytes * mult
        self.coll_wire_bytes += other.coll_wire_bytes * mult
        for k, v in other.coll_by_type.items():
            self.coll_by_type[k] += v * mult
        for c in other.collectives:
            self.collectives.append(dataclasses.replace(
                c, count=c.count * mult))
        self.dynamic_whiles += other.dynamic_whiles


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[Op]] = {}
        self.entry: str | None = None
        self._parse(text)
        self._sym: dict[str, dict[str, str]] = {}

    def _parse(self, text: str) -> None:
        cur: list[Op] | None = None
        cur_name = None
        for line in text.splitlines():
            if cur is None:
                m = _COMP_RE.match(line)
                if m and line.rstrip().endswith("{"):
                    cur_name = m.group(2)
                    cur = []
                    if m.group(1):
                        self.entry = cur_name
                continue
            if line.startswith("}"):
                self.computations[cur_name] = cur
                cur = None
                continue
            m = _OP_RE.match(line)
            if not m:
                continue
            name, shape, opcode, rest = m.groups()
            operands = re.findall(r"%([\w.\-]+)", rest.split(", metadata=")[0]
                                  .split(" calls=")[0])
            cur.append(Op(name=name, shape=shape, opcode=opcode, rest=rest,
                          operands=operands))

    def symtab(self, comp: str) -> dict[str, str]:
        if comp not in self._sym:
            self._sym[comp] = {op.name: op.shape
                               for op in self.computations[comp]}
        return self._sym[comp]


def _attr(rest: str, key: str) -> str | None:
    m = re.search(key + r"=%([\w.\-]+)", rest)
    return m.group(1) if m else None


def _trip_count(rest: str) -> int | None:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', rest)
    return int(m.group(1)) if m else None


def _group_size(rest: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", rest)
    if m:
        return len(m.group(1).split(","))
    return default


def _wire_bytes(opcode: str, operand_b: float, result_b: float,
                g: int) -> float:
    frac = (g - 1) / max(g, 1)
    base = opcode.replace("-start", "")
    if base == "all-reduce":
        return 2.0 * operand_b * frac
    if base == "all-gather":
        return result_b * frac
    if base == "reduce-scatter":
        return operand_b * frac
    if base in ("all-to-all", "ragged-all-to-all"):
        return operand_b * frac
    return operand_b  # collective-permute


class Analyzer:
    def __init__(self, module: HloModule, *, default_trip: float = 1.0,
                 num_partitions: int = 1):
        self.m = module
        self.default_trip = default_trip
        self.np_ = num_partitions
        self._memo: dict[tuple[str, bool], Cost] = {}

    def analyze(self) -> Cost:
        return self._comp_cost(self.m.entry, fused=False)

    # ------------------------------------------------------------------
    def _operand_bytes(self, op: Op, sym: dict[str, str]) -> float:
        return sum(_shape_bytes(sym.get(o, "")) for o in op.operands)

    def _fusion_io_bytes(self, op: Op, sym: dict[str, str]) -> float:
        """HBM bytes of a fusion node: parameters that are only
        dynamic-sliced inside are charged the SLICE bytes (a scan body
        addressing one layer of a stacked weight reads one layer, not the
        stack); a root dynamic-update-slice writes the update region, not
        the whole (aliased, in-place) buffer."""
        callee = _attr(op.rest, "calls")
        comp = self.m.computations.get(callee, [])
        inner_sym = self.m.symtab(callee) if callee in self.m.computations \
            else {}
        # map inner parameter name -> index
        param_order: list[str] = []
        for iop in comp:
            if iop.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", iop.rest)
                idx = int(m.group(1)) if m else len(param_order)
                while len(param_order) <= idx:
                    param_order.append("")
                param_order[idx] = iop.name
        # consumers of each parameter
        read_bytes: dict[str, float] = {}
        for pname in param_order:
            if not pname:
                continue
            slice_bytes, full = 0.0, False
            for iop in comp:
                if pname in iop.operands:
                    if iop.opcode == "dynamic-slice" \
                            and iop.operands[0] == pname:
                        slice_bytes += _shape_bytes(iop.shape)
                    elif iop.opcode == "dynamic-update-slice" \
                            and iop.operands[0] == pname:
                        # pass-through buffer being updated in place:
                        # reads nothing beyond the update region
                        continue
                    else:
                        full = True
                        break
            read_bytes[pname] = (_shape_bytes(inner_sym.get(pname, ""))
                                 if full or slice_bytes == 0.0
                                 else slice_bytes)
        reads = 0.0
        for i, o in enumerate(op.operands):
            pname = param_order[i] if i < len(param_order) else ""
            if pname and pname in read_bytes:
                reads += read_bytes[pname]
            else:
                reads += _shape_bytes(sym.get(o, ""))
        # writes: root DUS -> update region only
        root = comp[-1] if comp else None
        writes = _shape_bytes(op.shape)
        if root is not None and root.opcode == "dynamic-update-slice" \
                and len(root.operands) > 1:
            upd = _shape_bytes(inner_sym.get(root.operands[1], ""))
            if upd:
                writes = upd
        return reads + writes

    def _comp_cost(self, comp: str, fused: bool) -> Cost:
        key = (comp, fused)
        if key in self._memo:
            return self._memo[key]
        cost = Cost()
        sym = self.m.symtab(comp)
        for op in self.m.computations.get(comp, []):
            oc = op.opcode
            if oc in _SKIP_OPS:
                continue
            res_b = _shape_bytes(op.shape)
            opnd_b = self._operand_bytes(op, sym)
            if oc == "fusion":
                callee = _attr(op.rest, "calls")
                if callee:
                    inner = self._comp_cost(callee, fused=True)
                    cost.flops += inner.flops
                if not fused:
                    cost.hbm_bytes += self._fusion_io_bytes(op, sym)
            elif oc == "while":
                body = _attr(op.rest, "body")
                cond = _attr(op.rest, "condition")
                trip = _trip_count(op.rest)
                if trip is None:
                    trip = self.default_trip
                    cost.dynamic_whiles += 1
                inner = Cost()
                if body:
                    inner.add(self._comp_cost(body, fused=False))
                if cond:
                    inner.add(self._comp_cost(cond, fused=False))
                cost.add(inner, mult=float(trip))
            elif oc in ("call", "conditional", "async-start"):
                for callee_key in ("to_apply", "called_computations",
                                   "true_computation", "false_computation",
                                   "calls"):
                    callee = _attr(op.rest, callee_key)
                    if callee and callee in self.m.computations:
                        cost.add(self._comp_cost(callee, fused=fused))
                if not fused:
                    cost.hbm_bytes += opnd_b + res_b
            elif oc in _COLLECTIVES:
                g = _group_size(op.rest, self.np_)
                wire = _wire_bytes(oc, opnd_b, res_b, g)
                cost.coll_operand_bytes += opnd_b
                cost.coll_wire_bytes += wire
                cost.coll_by_type[oc.replace("-start", "")] += opnd_b
                cost.collectives.append(CollectiveRecord(
                    opcode=oc.replace("-start", ""), operand_bytes=opnd_b,
                    result_bytes=res_b, group_size=g, wire_bytes=wire,
                    count=1.0))
                if not fused:
                    cost.hbm_bytes += opnd_b + res_b
            elif oc == "dot":
                dims = _first_shape_dims(sym.get(op.operands[0], "")) \
                    if op.operands else []
                m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
                contracted = 1
                if m and m.group(1) and dims:
                    for d in m.group(1).split(","):
                        i = int(d)
                        if i < len(dims):
                            contracted *= dims[i]
                cost.flops += 2.0 * _shape_elems(op.shape) * contracted
                if not fused:
                    cost.hbm_bytes += opnd_b + res_b
            elif oc == "convolution":
                # not used by our models; approximate as dot on result
                cost.flops += 2.0 * _shape_elems(op.shape) * max(
                    1, int(opnd_b / max(res_b, 1)))
                if not fused:
                    cost.hbm_bytes += opnd_b + res_b
            elif oc == "dynamic-slice":
                # reads + writes the slice, not the source buffer
                if not fused:
                    cost.hbm_bytes += 2.0 * res_b
            elif oc == "dynamic-update-slice":
                upd_b = (_shape_bytes(sym.get(op.operands[1], ""))
                         if len(op.operands) > 1 else res_b)
                if not fused:
                    cost.hbm_bytes += 2.0 * upd_b
            else:
                if oc in _ELEMENTWISE_FLOPS:
                    cost.flops += _shape_elems(op.shape)
                if not fused:
                    cost.hbm_bytes += opnd_b + res_b
        self._memo[key] = cost
        return cost


def analyze_text(text: str, *, default_trip: float = 1.0,
                 num_partitions: int = 1) -> Cost:
    return Analyzer(HloModule(text), default_trip=default_trip,
                    num_partitions=num_partitions).analyze()


def summarize(cost: Cost) -> dict[str, Any]:
    by_type = dict(cost.coll_by_type)
    return {
        "flops_per_device": cost.flops,
        "hbm_bytes_per_device": cost.hbm_bytes,
        "collective_operand_bytes": cost.coll_operand_bytes,
        "collective_wire_bytes": cost.coll_wire_bytes,
        "collective_by_type": by_type,
        "n_collectives": len(cost.collectives),
        "dynamic_whiles": cost.dynamic_whiles,
    }
