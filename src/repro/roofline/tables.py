"""Render EXPERIMENTS.md roofline tables from the dry-run report JSONs.

Usage: PYTHONPATH=src python -m repro.roofline.tables [reports/dryrun ...]
"""
from __future__ import annotations

import json
import os
import sys


def fmt(x, digits=3):
    if x is None:
        return "—"
    return f"{x:.{digits}e}"


def load_dir(base: str) -> dict:
    out = {}
    for mesh in ("single", "multi"):
        d = os.path.join(base, mesh)
        if not os.path.isdir(d):
            continue
        for f in sorted(os.listdir(d)):
            if f.endswith(".json"):
                rec = json.load(open(os.path.join(d, f)))
                if rec.get("ok"):
                    out[(mesh, rec["arch"], rec["shape"],
                         f[:-5].split(".")[-1] if "." in f[:-5] else "")] = rec
    return out


def table(base: str, mesh: str) -> str:
    recs = load_dir(base)
    rows = ["| cell | c (s) | m (s) | x (s) | dominant | peak GB | "
            "MODEL_FLOPS ratio | roofline frac |",
            "|---|---|---|---|---|---|---|---|"]
    for (m, arch, shape, variant), rec in sorted(recs.items()):
        if m != mesh:
            continue
        r = rec["roofline"]
        name = f"{arch} × {shape}" + (f" [{variant}]" if variant else "")
        rows.append(
            f"| {name} | {fmt(r['compute_s'])} | {fmt(r['memory_s'])} | "
            f"{fmt(r['collective_s'])} | {r['dominant']} | "
            f"{rec['memory']['peak_per_device_gb']:.2f} | "
            f"{fmt(r.get('model_flops_ratio'), 2)} | "
            f"{fmt(r.get('roofline_fraction'), 2)} |")
    return "\n".join(rows)


def main():
    bases = sys.argv[1:] or ["reports/dryrun"]
    for base in bases:
        for mesh in ("single", "multi"):
            print(f"\n### {base} — {mesh} mesh\n")
            print(table(base, mesh))


if __name__ == "__main__":
    main()
