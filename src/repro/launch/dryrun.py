import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x input-shape) cell on the
production meshes and record memory/cost/roofline evidence.

The two lines above MUST stay first: jax locks the device count on first
initialization, and the dry-run needs 512 placeholder host devices so
``jax.make_mesh((2,16,16))`` can build the production mesh.  Tests and
benchmarks must NOT import this module (they want the single real device).

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch olmoe-1b-7b \
        --shape train_4k --mesh single --out reports/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]

Per cell it writes ``<out>/<mesh>/<arch>__<shape>.json`` with:
  * compile status + lower/compile wall time,
  * ``compiled.memory_analysis()``  (proves the cell fits per-chip HBM),
  * ``compiled.cost_analysis()``    (XLA's own flops/bytes, loop bodies
    counted once — kept for cross-checking),
  * our roofline terms (trip-count-multiplied; see roofline/hlo_analysis.py).
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro.configs import registry as reg
from repro.launch.mesh import make_production_mesh
from repro.roofline import report as rf_report

MESHES = {"single": False, "multi": True}


def run_cell(arch: str, shape: str, mesh_name: str, *,
             default_trip: float = 1.0, save_hlo: str | None = None,
             overrides: dict | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=MESHES[mesh_name])
    chips = mesh.size
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_name,
                 "chips": chips, "ok": False, "overrides": overrides}
    try:
        prog = reg.build_program(arch, shape, mesh, overrides=overrides)
    except ValueError as e:   # skipped cell
        rec["skipped"] = str(e)
        return rec
    jfn = jax.jit(prog.fn, in_shardings=prog.in_shardings,
                  out_shardings=prog.out_shardings,
                  donate_argnums=prog.donate_argnums)
    t0 = time.perf_counter()
    lowered = jfn.lower(*prog.args)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    rf = rf_report.roofline_from_text(txt, default_trip=default_trip,
                                      num_partitions=chips)
    rec.update({
        "ok": True,
        "lower_s": t1 - t0,
        "compile_s": t2 - t1,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_per_device_gb": (ma.argument_size_in_bytes
                                   + ma.output_size_in_bytes
                                   + ma.temp_size_in_bytes
                                   - ma.alias_size_in_bytes) / 2**30,
        },
        "xla_cost_analysis": {k: ca.get(k) for k in ("flops", "bytes accessed")
                              if k in ca},
        "roofline": rf_report.report_dict(rf, prog.meta, chips),
        "meta": {k: v for k, v in prog.meta.items()
                 if isinstance(v, (int, float, str))},
        "hlo_bytes": len(txt),
    })
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(txt)
    return rec


def cell_list(args) -> list[tuple[str, str]]:
    if args.arch:
        return [(args.arch, args.shape)]
    return [(c.arch, c.shape) for c in reg.all_cells() if not c.skip]


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch")
    p.add_argument("--shape")
    p.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default="reports/dryrun")
    p.add_argument("--default-trip", type=float, default=1.0,
                   help="trip count assumed for data-dependent while loops "
                        "(SSSP fixpoints); 1.0 = per-round terms")
    p.add_argument("--save-hlo", action="store_true")
    p.add_argument("--attn-impl", choices=["flash_vjp", "scan"],
                   help="override LM attention implementation "
                        "(scan = paper-era baseline, flash_vjp = optimized)")
    args = p.parse_args()
    if not args.all and not (args.arch and args.shape):
        p.error("give --arch/--shape or --all")

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = cell_list(args)
    failures = 0
    for mesh_name in meshes:
        outdir = os.path.join(args.out, mesh_name)
        os.makedirs(outdir, exist_ok=True)
        for arch, shape in cells:
            tag = f"{arch}__{shape}"
            path = os.path.join(outdir, tag + ".json")
            hlo_path = (os.path.join(outdir, tag + ".hlo.txt")
                        if args.save_hlo else None)
            overrides = None
            if args.attn_impl and reg.ARCHES[arch].FAMILY == "lm":
                overrides = {"attn_impl": args.attn_impl}
                if args.attn_impl == "scan":   # true paper-era baseline
                    overrides["act_batch_sharding"] = False
            try:
                rec = run_cell(arch, shape, mesh_name,
                               default_trip=args.default_trip,
                               save_hlo=hlo_path, overrides=overrides)
            except Exception:
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "ok": False, "error": traceback.format_exc()}
                failures += 1
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            status = ("SKIP" if rec.get("skipped")
                      else "ok" if rec["ok"] else "FAIL")
            extra = ""
            if rec.get("ok"):
                r = rec["roofline"]
                extra = (f" dom={r['dominant']}"
                         f" c={r['compute_s']:.3e} m={r['memory_s']:.3e}"
                         f" x={r['collective_s']:.3e}"
                         f" peakGB={rec['memory']['peak_per_device_gb']:.2f}"
                         f" compile={rec['compile_s']:.0f}s")
            print(f"[{mesh_name}] {tag}: {status}{extra}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
