"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
forces 512 host devices while tests/benches run on the single real device.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5 names explicit/auto axis kinds
    from jax.sharding import AxisType
except ImportError:  # older jaxlib: every axis is Auto already
    AxisType = None


def _mk(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_test_mesh(shape: tuple[int, ...] = None, axes: tuple[str, ...] = None) -> Mesh:
    """Mesh over however many devices exist (tests / local runs)."""
    n = len(jax.devices())
    if shape is None:
        shape, axes = (1, n) if n > 1 else (1, 1), ("data", "model")
    return _mk(shape, axes)


def graph_axes(mesh: Mesh) -> tuple[str, ...]:
    """The SSSP engine flattens every mesh axis into one vertex partition."""
    return tuple(mesh.axis_names)
