"""Production training driver: config -> mesh -> sharded train loop with
checkpoint/restart, failure injection (for FT testing) and async saves.

On the real cluster this binary runs under the pod launcher with
``jax.distributed.initialize`` (multi-host); on this container it runs the
same code on the single CPU device (mesh (1,1)).  The *same* train_step is
what launch/dryrun.py lowers for the 256/512-chip meshes.

Usage (see examples/train_lm.py for a wrapped demo)::

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b \
        --preset smoke --steps 50 --ckpt-dir /tmp/ckpt --ckpt-every 20
    ...                                  --resume   # restart after a crash
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import registry as reg
from repro.models import transformer as tfm
from repro.train import checkpoint as ckpt_mod
from repro.train import data as data_mod
from repro.train import optimizer as opt_mod
from repro.train import steps as steps_mod


def preset_config(arch: str, preset: str) -> tfm.LMConfig:
    mod = reg.ARCHES[arch]
    if preset == "full":
        return mod.CONFIG
    if preset == "smoke":
        return mod.REDUCED
    if preset == "100m":   # ~110M-param end-to-end trainable-on-CPU config
        return dataclasses.replace(
            mod.REDUCED, n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
            d_ff=2304, vocab_size=16384, vocab_pad_to=256, moe=None,
            mla=None, attn="gqa", d_head=64, name=arch + "-100m")
    raise ValueError(preset)


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-14b")
    p.add_argument("--preset", default="smoke",
                   choices=["smoke", "100m", "full"])
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--fail-at-step", type=int, default=0,
                   help="fault-tolerance test: hard-exit at this step")
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = preset_config(args.arch, args.preset)
    n_params_fn = lambda tree: sum(int(np.prod(x.shape))
                                   for x in jax.tree.leaves(tree))

    params = tfm.init_lm(jax.random.key(args.seed), cfg)
    opt_cfg = opt_mod.AdamWConfig(lr=args.lr, warmup_steps=20,
                                  total_steps=args.steps)
    opt_state = opt_mod.adamw_init(params)
    stream = data_mod.TokenStream(vocab_size=cfg.vocab_size,
                                  batch=args.batch, seq_len=args.seq,
                                  seed=args.seed)
    start_step = 0
    if args.resume and args.ckpt_dir:
        latest = ckpt_mod.latest_step(args.ckpt_dir)
        if latest is not None:
            tree = {"params": params, "opt": opt_state,
                    "data": {"step": jnp.int32(0)}}
            restored = ckpt_mod.restore(
                jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                             tree), args.ckpt_dir)
            params, opt_state = restored["params"], restored["opt"]
            stream.restore({"step": int(restored["data"]["step"])})
            start_step = latest
            print(f"[train] resumed from step {latest}", flush=True)

    loss_fn = lambda p_, b_: tfm.lm_loss(p_, b_, cfg)
    step_fn = jax.jit(steps_mod.make_train_step(loss_fn, opt_cfg, 1),
                      donate_argnums=(0, 1))
    saver = ckpt_mod.AsyncSaver()
    print(f"[train] arch={cfg.name} params={n_params_fn(params):,} "
          f"steps {start_step}..{args.steps}", flush=True)

    t_start = time.perf_counter()
    losses = []
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if args.fail_at_step and step + 1 == args.fail_at_step:
            print(f"[train] INJECTED FAILURE at step {step + 1}", flush=True)
            sys.stdout.flush()
            import os
            os._exit(17)       # hard crash: no cleanup, tests restart cycle
        if (step + 1) % args.log_every == 0:
            dt = time.perf_counter() - t_start
            print(f"[train] step {step+1} loss {metrics['loss']:.4f} "
                  f"lr {metrics['lr']:.2e} gnorm {metrics['grad_norm']:.3f} "
                  f"({dt/ (step - start_step + 1):.2f}s/step)", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            saver.save({"params": params, "opt": opt_state,
                        "data": {"step": jnp.int32(stream.step)}},
                       args.ckpt_dir, step + 1)
    saver.wait()
    if args.ckpt_dir:
        ckpt_mod.save({"params": params, "opt": opt_state,
                       "data": {"step": jnp.int32(stream.step)}},
                      args.ckpt_dir, args.steps)
        ckpt_mod.cleanup(args.ckpt_dir, keep=2)
    if len(losses) >= 20:
        first = float(np.mean(losses[:10]))
        last = float(np.mean(losses[-10:]))
        print(f"[train] loss first10={first:.4f} last10={last:.4f} "
              f"improved={last < first}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
