"""Public wrapper for the ELLPACK relaxation kernel.

``relax_wave`` composes the kernel (or the jnp ref) with the engine-level
update rule: take the elementwise min against current distances, emit the
improved mask (next frontier) and updated parents.  The host-side ELL builder
lives in repro.graphs.csr.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.relax.ref import ellpack_relax_ref
from repro.kernels.relax.relax import ellpack_relax


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def relax_wave(dist: jax.Array, parent: jax.Array, nbr_idx: jax.Array,
               nbr_w: jax.Array, *, use_kernel: bool = True,
               interpret: bool = True):
    """One full (non-frontier-masked) relaxation wave in ELL layout.

    Returns (dist', parent', improved).  CPU container: interpret=True.
    """
    if use_kernel:
        best, arg = ellpack_relax(dist, nbr_idx, nbr_w, interpret=interpret)
    else:
        best, arg = ellpack_relax_ref(dist, nbr_idx, nbr_w)
    improved = best < dist
    return (jnp.where(improved, best, dist),
            jnp.where(improved, arg, parent),
            improved)
