"""Public wrapper for the ELLPACK relaxation kernel.

``relax_wave`` composes the kernel (or the jnp ref) with the engine-level
update rule: take the elementwise min against current distances, emit the
improved mask (next frontier) and updated parents.  The host-side ELL builder
lives in repro.graphs.csr; the dynamic engines' incremental ELL maintenance
lives in repro.core.backends.ellpack.

Frontier masking (work-efficiency, DESIGN.md §2.2): sources outside the
frontier are masked to +inf *before* the gather, so a wave only delivers
offers from vertices that improved last round — the ELL rendering of the
segment path's ``active & frontier[src]`` edge mask.  The mask costs one O(N)
``where``; the kernel itself stays a dense gather + row-min.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.relax.config import resolve_interpret
from repro.kernels.relax.ref import ellpack_relax_ref
from repro.kernels.relax.relax import ellpack_relax

_INF = jnp.float32(jnp.inf)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def relax_wave(dist: jax.Array, parent: jax.Array, nbr_idx: jax.Array,
               nbr_w: jax.Array, *, frontier: jax.Array | None = None,
               use_kernel: bool = True, interpret: bool | None = None):
    """One relaxation wave in ELL layout (frontier-masked when given).

    ``nbr_idx``/``nbr_w`` may have more rows than ``dist`` (kernel block
    padding); the extra rows are all-+inf and are sliced off the outputs.
    Returns (dist', parent', improved).  ``interpret=None`` resolves to the
    platform default (interpret everywhere except TPU) — the same default
    ``ellpack_relax`` uses, so the two entry points can no longer disagree.
    """
    interpret = resolve_interpret(interpret)
    n = dist.shape[0]
    offers = dist if frontier is None else jnp.where(frontier, dist, _INF)
    if use_kernel:
        best, arg = ellpack_relax(offers, nbr_idx, nbr_w, interpret=interpret)
    else:
        best, arg = ellpack_relax_ref(offers, nbr_idx, nbr_w)
    best, arg = best[:n], arg[:n]
    improved = best < dist
    return (jnp.where(improved, best, dist),
            jnp.where(improved, arg, parent),
            improved)
