"""Pure-jnp oracle for the ELLPACK min-plus relaxation kernel.

Semantics (one bulk "DistanceUpdate" wave in ELL layout):

    cand[i, k] = dist[nbr_idx[i, k]] + nbr_w[i, k]
    best[i]    = min_k cand[i, k]                (+inf padded entries lose)
    arg[i]     = nbr_idx[i, argmin_k cand[i,k]]  (-1 if best == +inf)

Ties break toward the smallest k (jnp.argmin convention) — the host ELL
builder sorts each row's neighbors by id, so this matches the engine's
smallest-src-id rule.
"""
from __future__ import annotations

import jax.numpy as jnp


def ellpack_relax_ref(dist: jnp.ndarray, nbr_idx: jnp.ndarray,
                      nbr_w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    cand = dist[nbr_idx] + nbr_w                       # (N, K)
    best = jnp.min(cand, axis=1)
    kstar = jnp.argmin(cand, axis=1)
    arg = jnp.take_along_axis(nbr_idx, kstar[:, None], axis=1)[:, 0]
    arg = jnp.where(jnp.isfinite(best), arg, -1)
    return best, arg.astype(jnp.int32)
