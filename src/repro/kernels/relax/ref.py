"""Pure-jnp oracle for the ELLPACK min-plus relaxation kernel.

Semantics (one bulk "DistanceUpdate" wave in ELL layout):

    cand[i, k] = dist[nbr_idx[i, k]] + nbr_w[i, k]
    best[i]    = min_k cand[i, k]                      (+inf padded entries lose)
    arg[i]     = min {nbr_idx[i,k] : cand[i,k] == best[i]}   (-1 if best == +inf)

Ties break toward the smallest *neighbor id* — identical to the engine's
segment_min path (smallest-src-id rule), so the ELL relaxation backend
produces bit-identical parent trees (DESIGN.md §2.2).
"""
from __future__ import annotations

import jax.numpy as jnp


def ellpack_relax_ref(dist: jnp.ndarray, nbr_idx: jnp.ndarray,
                      nbr_w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    cand = dist[nbr_idx] + nbr_w                       # (R, K)
    best = jnp.min(cand, axis=1)
    is_min = cand == best[:, None]
    arg = jnp.min(jnp.where(is_min, nbr_idx, jnp.int32(2**31 - 1)), axis=1)
    arg = jnp.where(jnp.isfinite(best), arg, -1)
    return best, arg.astype(jnp.int32)
