"""ELLPACK min-plus relaxation — the Pallas TPU kernel for SSSP-Del's hot loop.

TPU adaptation (see DESIGN.md §2): GPU implementations scatter-min with
atomics over CSR; TPUs have no atomics and hate irregular scatters, so we
re-block the graph into sliced-ELLPACK — per destination row, a padded dense
list of (in-neighbor, weight).  One wave is then:

    gather (VMEM-resident dist tile) -> add -> row-min / row-argmin

entirely dense, VPU-friendly work.  Grid tiles rows in ``bm`` blocks; the
dist vector is kept whole in VMEM (per-shard vertex counts at production
scale are <= ~64k, i.e. <= 256 KiB f32 — trivially VMEM resident; the
BlockSpec pins it once and Mosaic hoists the load out of the grid loop).

Layout notes
------------
* ``nbr_idx``/``nbr_w`` tiles are (bm, K): K is the slice's padded degree,
  rounded to a multiple of 128 (lane width) by the host builder.
* padded entries carry w=+inf, idx=0 — they can never win the min.
* argmin is computed in-kernel with broadcasted_iota (TPU needs 2D iota).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.relax.config import resolve_interpret


def _relax_kernel(dist_ref, idx_ref, w_ref, best_ref, arg_ref):
    dist = dist_ref[...]                       # (N,) VMEM-resident tile
    idx = idx_ref[...]                         # (bm, K)
    w = w_ref[...]                             # (bm, K)
    cand = jnp.take(dist, idx, axis=0) + w     # dense gather + add
    best = jnp.min(cand, axis=1)               # (bm,)
    # row-argmin with ties broken toward the SMALLEST NEIGHBOR ID — the same
    # rule the segment_min engine path uses, so both relaxation backends pick
    # bit-identical parents.  (min over masked ids; no iota/argmin needed.)
    is_min = cand == best[:, None]
    arg = jnp.min(jnp.where(is_min, idx, jnp.int32(2**31 - 1)), axis=1)
    best_ref[...] = best
    arg_ref[...] = jnp.where(jnp.isfinite(best), arg, -1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def ellpack_relax(dist: jax.Array, nbr_idx: jax.Array, nbr_w: jax.Array,
                  *, block_rows: int = 256, interpret: bool | None = None
                  ) -> tuple[jax.Array, jax.Array]:
    """best[i], arg[i] = min-plus reduction of row i's in-neighbors.

    Shapes: dist (N,) f32; nbr_idx (R, K) i32 (entries in [0, N)); nbr_w
    (R, K) f32 (+inf padding).  R % block_rows == 0 (host builder pads).
    ``interpret=None`` resolves to the platform default (interpret
    everywhere except TPU — kernels/relax/config.py).
    """
    interpret = resolve_interpret(interpret)
    R, K = nbr_idx.shape
    N = dist.shape[0]
    bm = min(block_rows, R)
    assert R % bm == 0, (R, bm)
    grid = (R // bm,)
    return pl.pallas_call(
        _relax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((N,), lambda i: (0,)),              # dist: whole vector
            pl.BlockSpec((bm, K), lambda i: (i, 0)),          # idx tile
            pl.BlockSpec((bm, K), lambda i: (i, 0)),          # w tile
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R,), jnp.float32),
            jax.ShapeDtypeStruct((R,), jnp.int32),
        ],
        interpret=interpret,
    )(dist, nbr_idx, nbr_w)
