"""Gathered-edges relaxation wave for the frontier-compacted sparse path
(DESIGN.md §12).

The sparse epochs compact the frontier twice on device — vertices into a
bounded [F] worklist, then that worklist's OUT-adjacency cells (plus the
frontier-live hub-overflow entries) into a bounded 1-D edge list — so the
scatter volume of a wave is proportional to the edges actually touched,
never to F x max-slice-width padding.  This module evaluates the relax
min/tie-break over such a compacted edge list: candidates
``src_dist + w`` scattered-min into the [N] row space plus the
smallest-source-id parent keys — the same computation as every dense
wave, restricted to the affected region.

Two renderings, bit-identical by construction:

* ``gathered_rows_relax_ref`` — plain jnp scatter-min composition (the
  default execution path everywhere; scatters via ``.at[].min``);
* ``gathered_rows_relax`` — a single-block Pallas kernel fusing the
  candidate generation, scatter-min and key scatter in one dispatch
  (``frontier_kernel=True``); interpret-mode is resolved by
  ``kernels.relax.config`` (interpret everywhere except TPU), and masked
  slots are remapped to the out-of-range row ``num_rows`` before the
  scatter because Pallas scatters *wrap* rather than drop negative
  indices (same trick as the fused kernel's overflow lane).

Contract (shared with the jnp reference): all inputs are 1-D edge-aligned
arrays; ``mask`` selects real slots; masked-out slots never contribute
(their candidate is +inf and their scatter target is dropped).
Tombstoned cells arrive with ``w=+inf`` and lose every min on their own.
Returns per-row ``(best f32[num_rows], arg i32[num_rows])`` where ``arg``
is the smallest source vertex id achieving ``best`` (INT_MAX where no
finite candidate hit).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.relax.config import resolve_interpret

_INT_MAX = jnp.int32(2**31 - 1)
_INF = jnp.float32(jnp.inf)


def gathered_rows_relax_ref(src_dist: jax.Array, src_ids: jax.Array,
                            nbr: jax.Array, w: jax.Array, mask: jax.Array,
                            *, num_rows: int
                            ) -> tuple[jax.Array, jax.Array]:
    """jnp reference: candidates ``src_dist + w`` scattered-min into
    ``nbr`` rows, parent key = smallest ``src_ids`` among slots achieving
    the row min (the repo-wide tie rule)."""
    cand = jnp.where(mask, src_dist + w, _INF)
    tgt = jnp.where(mask, nbr, num_rows)          # masked slots -> dropped
    best = jnp.full((num_rows,), _INF, jnp.float32).at[tgt].min(
        cand, mode="drop")
    row_min = best[jnp.clip(tgt, 0, num_rows - 1)]
    hit = (cand == row_min) & (cand < _INF)
    key = jnp.where(hit, src_ids, _INT_MAX)
    arg = jnp.full((num_rows,), _INT_MAX, jnp.int32).at[tgt].min(
        key, mode="drop")
    return best, arg


def _gather_kernel(num_rows: int, wd_ref, src_ref, nbr_ref, w_ref, mask_ref,
                   best_ref, arg_ref):
    wd = wd_ref[...]
    src = src_ref[...]
    nbr = nbr_ref[...]
    w = w_ref[...]
    mask = mask_ref[...]
    # literals (not module globals) so the kernel body closes over nothing
    inf = jnp.float32(jnp.inf)
    int_max = jnp.int32(2**31 - 1)
    cand = jnp.where(mask, wd + w, inf)
    # Pallas scatters WRAP out-of-range/negative indices; route masked
    # slots to the explicit out-of-range row and drop it.
    tgt = jnp.where(mask, nbr, num_rows)
    best = jnp.full((num_rows,), inf, jnp.float32).at[tgt].min(
        cand, mode="drop")
    row_min = jnp.take(best, tgt, mode="clip")
    hit = (cand == row_min) & (cand < inf)
    key = jnp.where(hit, src, int_max)
    arg = jnp.full((num_rows,), int_max, jnp.int32).at[tgt].min(
        key, mode="drop")
    best_ref[...] = best
    arg_ref[...] = arg


def gathered_rows_relax(src_dist: jax.Array, src_ids: jax.Array,
                        nbr: jax.Array, w: jax.Array, mask: jax.Array,
                        *, num_rows: int, interpret: bool | None = None
                        ) -> tuple[jax.Array, jax.Array]:
    """Single-block Pallas rendering of ``gathered_rows_relax_ref`` — one
    dispatch for the whole compacted edge list (its length is already
    bounded by the capacity ladder's edge budget, so no tiling is
    needed)."""
    kernel = functools.partial(_gather_kernel, num_rows)
    best, arg = pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((num_rows,), jnp.float32),
            jax.ShapeDtypeStruct((num_rows,), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(src_dist, src_ids, nbr, w, mask)
    return best, arg
