"""One shared ``interpret`` default for every relax kernel entry point.

The Pallas kernels compile through Mosaic only on TPU; everywhere else they
must run in interpret mode (the kernel body executed as traced jax ops).
Historically ``relax.ellpack_relax`` defaulted ``interpret=False`` while
``ops.relax_wave`` hardcoded ``interpret=True`` — correct on exactly one
platform each.  Both entry points (and the fused sliced kernel) now take
``interpret=None`` and resolve it here: detect the platform once, interpret
everywhere except TPU.  Callers that pass an explicit bool keep full control
(tests force interpret=True regardless of platform).
"""
from __future__ import annotations

import jax

_DEFAULT_INTERPRET: bool | None = None


def default_interpret() -> bool:
    """True unless the Mosaic TPU compiler is available (platform probed
    once per process; ``jax.default_backend()`` initializes the backend)."""
    global _DEFAULT_INTERPRET
    if _DEFAULT_INTERPRET is None:
        _DEFAULT_INTERPRET = jax.default_backend() != "tpu"
    return _DEFAULT_INTERPRET


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` -> the platform default; an explicit bool wins."""
    return default_interpret() if interpret is None else bool(interpret)
