"""Fused Pallas kernel for one hybrid sliced-ELL + overflow-COO wave.

The unfused hybrid wave (core/backends/sliced.py) is three dispatches per
equal-width run group plus two combine passes: per-group ELL gather+row-min,
a segment-min over the hub overflow COO lane, and the scalar min-combine
with the smallest-src-id tie rule — with the frontier/bucket mask
materialized as a full masked ``offers`` vector up front.  This module fuses
all of it into ONE kernel per run group (DESIGN.md §9.4):

  * the bucket/frontier row mask is applied in-kernel (``offers =
    where(active, dist, inf)`` never hits HBM);
  * each grid block row-mins its ``(bm, k)`` ELL tile as before;
  * the SAME kernel scans the entire overflow COO segment and folds the
    entries whose destination row lands in the block via a scatter-min
    into the block's rows (out-of-block entries drop) — an O(C)-per-block
    segment-min, exact for any odst distribution.  A dense ``(bm, C)``
    row-match mask would be branch-free but costs O(rows x C) total, which
    loses to the unfused scatter path as soon as the overflow lane grows
    past a few hundred entries;
  * both lanes min-combine in registers under the shared smallest-id tie
    rule, so the kernel's ``(best, arg)`` output is bit-identical to
    ``combine_lanes(sliced_gather_min(...), overflow_min(...))``.

Tiling follows the run-group rules: runs of equal-width slices merge into
contiguous row-major ``(rows_g, k)`` blocks (``slice_run_groups`` below,
shared with the unfused path, whose 256-row main/remainder split the fused
path RE-COALESCES: one pallas_call per distinct-width run, block =
``(rows_g, k)`` with k the run's slice width, grid=1).  One block per run
is what keeps the overflow lane at one COO scan per run — a 256-row grid
would rescan the whole segment once per block and lose to the unfused
path as soon as the lane grows.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.relax.config import resolve_interpret

_INF = jnp.float32(jnp.inf)
_INT_MAX = jnp.int32(2**31 - 1)


def slice_run_groups(widths: tuple[int, ...] | list[int],
                     slice_rows: int) -> list[tuple[int, int]]:
    """Merge runs of equal-width slices and split each into a
    multiple-of-256-rows main block plus a remainder: list of
    ``(k, n_slices)`` groups, in row order.  Shared by the fused kernel and
    the unfused ``sliced_gather_min`` so both tile identically."""
    per_blk = max(1, 256 // slice_rows)
    runs: list[list[int]] = []
    for k in widths:
        if runs and runs[-1][0] == k:
            runs[-1][1] += 1
        else:
            runs.append([k, 1])
    groups: list[tuple[int, int]] = []
    for k, cnt in runs:
        main = (cnt // per_blk) * per_blk
        if main:
            groups.append((k, main))
        if cnt - main:
            groups.append((k, cnt - main))
    return groups


def _mk_kernel(row0: int, bm: int):
    """Kernel body for one run group: ELL tile row-min + full-overflow-lane
    fold + in-register lane combine.  ``row0`` is the group's first global
    row; the block's rows are ``[row0 + i*bm, row0 + (i+1)*bm)``."""

    def kernel(dist_ref, act_ref, idx_ref, w_ref, osrc_ref, odst_ref, ow_ref,
               best_ref, arg_ref):
        # literals must be built inside the kernel (Pallas rejects captured
        # device constants)
        _INF = jnp.float32(jnp.inf)
        _INT_MAX = jnp.int32(2**31 - 1)
        # bucket/frontier mask fused into the offer read — inactive rows
        # offer +inf and can never win a min
        offers = jnp.where(act_ref[...], dist_ref[...], _INF)

        # ELL lane: gather + row-min over this block's (bm, k) tile
        idx = idx_ref[...]
        cand = jnp.take(offers, idx, axis=0) + w_ref[...]
        best = jnp.min(cand, axis=1)
        is_min = (cand == best[:, None]) & (cand < _INF)
        arg = jnp.min(jnp.where(is_min, idx, _INT_MAX), axis=1)

        # overflow lane: scan the WHOLE COO segment, segment-min into this
        # block's rows via scatter-min — entries whose destination falls
        # outside the block drop; empty/tombstoned entries carry w=+inf and
        # never win.  Two passes give the smallest-src-id argmin: the value
        # scatter, then a key scatter gated on matching the row minimum
        # (the clip-gathered minimum of an out-of-block entry may spuriously
        # compare equal, but its key scatter drops too, so it cannot leak).
        blk0 = row0 + pl.program_id(0) * bm
        osrc = osrc_ref[...]
        lrow = odst_ref[...] - blk0
        # scatter mode="drop" only drops indices >= bm — NEGATIVE indices
        # wrap (NumPy semantics), so remap rows before the block to bm
        lrow = jnp.where(lrow >= 0, lrow, bm)
        ocand = jnp.take(offers, osrc, axis=0) + ow_ref[...]
        obest = jnp.full((bm,), _INF).at[lrow].min(ocand, mode="drop")
        row_min = jnp.take(obest, lrow, mode="clip")
        okey = jnp.where((ocand == row_min) & (ocand < _INF), osrc, _INT_MAX)
        oarg = jnp.full((bm,), _INT_MAX).at[lrow].min(okey, mode="drop")

        # lane combine, smallest minimizing src id across both lanes —
        # exactly combine_lanes(), evaluated in registers
        comb = jnp.minimum(best, obest)
        ell_key = jnp.where((best == comb) & (best < _INF), arg, _INT_MAX)
        coo_key = jnp.where((obest == comb) & (obest < _INF), oarg, _INT_MAX)
        best_ref[...] = comb
        arg_ref[...] = jnp.minimum(ell_key, coo_key)

    return kernel


def fused_sliced_relax(dist: jax.Array, active: jax.Array,
                       flat_idx: jax.Array, flat_w: jax.Array,
                       osrc: jax.Array, odst: jax.Array, ow: jax.Array, *,
                       widths: tuple[int, ...], slice_rows: int,
                       interpret: bool | None = None):
    """One fused hybrid wave over the flat sliced-ELL buffer plus the
    overflow COO segment: returns ``(best f32[R], arg i32[R])`` for
    ``R = len(widths) * slice_rows`` rows, already lane-combined —
    bit-identical to the unfused three-dispatch composition.

    ``active`` is the bucket/frontier row mask over offer SOURCES (vertex
    space); pass all-True for an unmasked pull wave.  ``odst`` must be in
    the same row space the groups cover (vertex ids single-device).
    """
    interpret = resolve_interpret(interpret)
    C = ow.shape[0]
    if C == 0:          # static degenerate shape: keep the kernel uniform
        osrc = jnp.zeros(1, jnp.int32)
        odst = jnp.full(1, -1, jnp.int32)
        ow = jnp.full(1, _INF, jnp.float32)
        C = 1
    n = dist.shape[0]
    # re-coalesce the unfused path's 256-row main/remainder split: ONE
    # pallas_call (grid=1, block = the whole run) per distinct-width run,
    # so the overflow COO segment is scanned once per run, not per block
    groups: list[list[int]] = []
    for k, cnt in slice_run_groups(widths, slice_rows):
        if groups and groups[-1][0] == k:
            groups[-1][1] += cnt
        else:
            groups.append([k, cnt])
    bests, args_ = [], []
    off_cells = 0
    off_rows = 0
    for k, cnt in groups:
        rows_g = slice_rows * cnt
        bm = rows_g
        blk = slice(off_cells, off_cells + rows_g * k)
        blk_idx = flat_idx[blk].reshape(rows_g, k)
        blk_w = flat_w[blk].reshape(rows_g, k)
        cost = pl.CostEstimate(
            flops=3.0 * rows_g * k + 4.0 * C,
            bytes_accessed=float(5 * n + 8 * rows_g * k + 12 * C
                                 + 8 * rows_g),
            transcendentals=0)
        b, a = pl.pallas_call(
            _mk_kernel(off_rows, bm),
            grid=(rows_g // bm,),
            in_specs=[
                pl.BlockSpec((n,), lambda i: (0,)),       # dist (whole)
                pl.BlockSpec((n,), lambda i: (0,)),       # active (whole)
                pl.BlockSpec((bm, k), lambda i: (i, 0)),  # ELL idx tile
                pl.BlockSpec((bm, k), lambda i: (i, 0)),  # ELL w tile
                pl.BlockSpec((C,), lambda i: (0,)),       # overflow src
                pl.BlockSpec((C,), lambda i: (0,)),       # overflow dst
                pl.BlockSpec((C,), lambda i: (0,)),       # overflow w
            ],
            out_specs=[
                pl.BlockSpec((bm,), lambda i: (i,)),
                pl.BlockSpec((bm,), lambda i: (i,)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((rows_g,), jnp.float32),
                jax.ShapeDtypeStruct((rows_g,), jnp.int32),
            ],
            cost_estimate=cost,
            interpret=interpret,
        )(dist, active, blk_idx, blk_w, osrc, odst, ow)
        bests.append(b)
        args_.append(a)
        off_cells += rows_g * k
        off_rows += rows_g
    return jnp.concatenate(bests), jnp.concatenate(args_)


@partial(jax.jit, static_argnames=("widths", "slice_rows", "interpret"))
def _fused_wave_jit(dist, active, flat_idx, flat_w, osrc, odst, ow, *,
                    widths, slice_rows, interpret=True):
    return fused_sliced_relax(
        dist, active, flat_idx, flat_w, osrc, odst, ow,
        widths=widths, slice_rows=slice_rows, interpret=interpret)


def fused_cost(widths: tuple[int, ...] | list[int], slice_rows: int,
               num_vertices: int, overflow_cap: int) -> dict[str, float]:
    """Analytic flop/byte model of one fused wave — what the pallas_call
    cost_estimate claims, summed over run groups.  ``roofline`` validation
    (tests/test_fused_relax.py) checks the compiled interpret-mode HLO
    against this model via ``roofline/hlo_analysis.py``."""
    C = max(overflow_cap, 1)
    flops = 0.0
    bytes_ = 0.0
    runs: list[list[int]] = []
    for k, cnt in slice_run_groups(tuple(widths), slice_rows):
        if runs and runs[-1][0] == k:
            runs[-1][1] += cnt
        else:
            runs.append([k, cnt])
    for k, cnt in runs:
        rows_g = slice_rows * cnt
        # ELL lane: add + min-reduce + argmin select per cell; overflow
        # lane: one gather+add+scatter-min chain per entry per RUN (one
        # block per run — the whole COO segment is scanned once per run)
        flops += 3.0 * rows_g * k + 4.0 * C
        bytes_ += (5.0 * num_vertices       # dist f32 + active bool
                   + 8.0 * rows_g * k       # idx i32 + w f32 tiles
                   + 12.0 * C               # overflow triplet, per run
                   + 8.0 * rows_g)          # best f32 + arg i32 out
    return {"flops": flops, "bytes": bytes_,
            "intensity": flops / max(bytes_, 1.0)}
