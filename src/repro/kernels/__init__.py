"""Pallas TPU kernels for the perf-critical hot spots.

Every kernel ships three artifacts:
  * ``<name>/<name>.py`` — the pl.pallas_call + BlockSpec kernel (TPU target);
  * ``<name>/ops.py``    — the jitted public wrapper (+ shape plumbing);
  * ``<name>/ref.py``    — a pure-jnp oracle, used by tests (interpret mode)
    and by the engine as the fallback when kernels are disabled.

Kernels here are the TPU adaptation of the paper's hot loop (edge relaxation)
plus the two gather-reduce primitives the assigned GNN/recsys architectures
hinge on.  CPU container note: kernels are *validated* with interpret=True
(Python execution of the kernel body); the BlockSpec tiling targets TPU v5e
VMEM.
"""
