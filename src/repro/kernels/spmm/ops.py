"""Public wrapper for the ELL SpMM kernel with a custom VJP.

Backward pass: d(feats) = scatter of d(out) back through the gather — which
is itself a segment-sum, expressed with the jnp ref's transpose (JAX's AD of
the ref is used; the kernel is forward-only and wrapped in custom_vjp so the
GNN training path stays differentiable whether or not the kernel is on).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.spmm.ref import spmm_ell_ref
from repro.kernels.spmm.spmm import spmm_ell


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def neighbor_reduce(feats, nbr_idx, nbr_mask, agg: str = "sum",
                    use_kernel: bool = False, interpret: bool = True):
    """Differentiable neighbor aggregation (GraphSAGE/MeshGraphNet hot path)."""
    if use_kernel:
        return spmm_ell(feats, nbr_idx, nbr_mask, agg=agg, interpret=interpret)
    return spmm_ell_ref(feats, nbr_idx, nbr_mask, agg=agg)


def _fwd(feats, nbr_idx, nbr_mask, agg, use_kernel, interpret):
    out = neighbor_reduce(feats, nbr_idx, nbr_mask, agg, use_kernel, interpret)
    return out, (feats, nbr_idx, nbr_mask, out)


def _bwd(agg, use_kernel, interpret, res, g):
    feats, nbr_idx, nbr_mask, out = res
    # AD through the pure-jnp oracle gives the correct scatter for all aggs.
    _, vjp = jax.vjp(lambda f: spmm_ell_ref(f, nbr_idx, nbr_mask, agg=agg), feats)
    (dfeats,) = vjp(g)
    return (dfeats, None, None)


neighbor_reduce.defvjp(_fwd, _bwd)
