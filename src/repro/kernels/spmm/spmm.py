"""ELL gather-reduce SpMM — Pallas TPU kernel for GNN neighbor aggregation.

The message-passing primitive (`segment_sum` over edge lists on GPU) becomes,
on TPU, a dense gather + masked reduce over the sliced-ELLPACK layout:

    out[i, f] = agg_k feats[nbr_idx[i, k], f]        (masked over pads)

Grid: (row blocks, feature blocks).  Per step the kernel holds
  * the feature column-panel (S, bf) in VMEM — S is the *source window*:
    at production scale each shard aggregates from its own vertex range
    (+halo), so S <= ~64k rows and the panel is <= 64k*128*4B = 32 MiB at
    bf=128; the host picks bf so the panel fits VMEM alongside the tiles;
  * the (bm, K) index/mask tiles and the (bm, bf) output tile.

The gather runs once per (i, j) block on the VMEM-resident panel; reduction
is a VPU masked sum/max over K.  dtype: f32 or bf16 feats (accumulate f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(agg: str, out_dtype):
    def kernel(feats_ref, idx_ref, mask_ref, out_ref):
        feats = feats_ref[...]                      # (S, bf) VMEM panel
        idx = idx_ref[...]                          # (bm, K)
        mask = mask_ref[...]                        # (bm, K) bool
        g = jnp.take(feats, idx, axis=0)            # (bm, K, bf)
        g = g.astype(jnp.float32)
        m = mask[..., None]
        if agg == "sum":
            r = jnp.sum(jnp.where(m, g, 0.0), axis=1)
        elif agg == "mean":
            s = jnp.sum(jnp.where(m, g, 0.0), axis=1)
            cnt = jnp.maximum(jnp.sum(mask.astype(jnp.float32), axis=1,
                                      keepdims=True), 1.0)
            r = s / cnt
        elif agg == "max":
            neg = jnp.float32(jnp.finfo(jnp.float32).min)
            mx = jnp.max(jnp.where(m, g, neg), axis=1)
            has = jnp.any(mask, axis=1, keepdims=True)
            r = jnp.where(has, mx, 0.0)
        else:
            raise ValueError(agg)
        out_ref[...] = r.astype(out_dtype)
    return kernel


@functools.partial(jax.jit,
                   static_argnames=("agg", "block_rows", "block_feat", "interpret"))
def spmm_ell(feats: jax.Array, nbr_idx: jax.Array, nbr_mask: jax.Array, *,
             agg: str = "sum", block_rows: int = 128, block_feat: int = 128,
             interpret: bool = False) -> jax.Array:
    """feats (S, F); nbr_idx (R, K) in [0, S); nbr_mask (R, K) bool -> (R, F)."""
    S, F = feats.shape
    R, K = nbr_idx.shape
    bm = min(block_rows, R)
    bf = min(block_feat, F)
    assert R % bm == 0 and F % bf == 0, (R, F, bm, bf)
    grid = (R // bm, F // bf)
    return pl.pallas_call(
        _make_kernel(agg, feats.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((S, bf), lambda i, j: (0, j)),      # feature panel
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, F), feats.dtype),
        interpret=interpret,
    )(feats, nbr_idx, nbr_mask)
