"""Pure-jnp oracle for the ELL gather-reduce SpMM (GNN aggregation).

    out[i, :] = agg_{k : mask[i,k]} feats[nbr_idx[i, k], :]

agg in {sum, mean, max}; mean divides by the row's valid count (0 -> 0);
max over an empty row is 0 (GraphSAGE convention for isolated nodes).
"""
from __future__ import annotations

import jax.numpy as jnp


def spmm_ell_ref(feats: jnp.ndarray, nbr_idx: jnp.ndarray,
                 nbr_mask: jnp.ndarray, agg: str = "sum") -> jnp.ndarray:
    gathered = feats[nbr_idx]                       # (R, K, F)
    m = nbr_mask[..., None]                         # (R, K, 1)
    if agg == "sum":
        return jnp.sum(jnp.where(m, gathered, 0.0), axis=1)
    if agg == "mean":
        s = jnp.sum(jnp.where(m, gathered, 0.0), axis=1)
        cnt = jnp.maximum(jnp.sum(nbr_mask, axis=1, keepdims=True), 1)
        return s / cnt.astype(feats.dtype)
    if agg == "max":
        neg = jnp.finfo(feats.dtype).min
        mx = jnp.max(jnp.where(m, gathered, neg), axis=1)
        has = jnp.any(nbr_mask, axis=1, keepdims=True)
        return jnp.where(has, mx, 0.0)
    raise ValueError(f"unknown agg {agg!r}")
