"""Pure-jnp oracle for the embedding-bag kernel (recsys hot path).

    out[b, :] = agg_{l : idx[b, l] >= 0} table[idx[b, l], :]  (* wt[b, l])

JAX has no native EmbeddingBag — this gather + masked reduce IS the
implementation (see kernel taxonomy §RecSys); the Pallas kernel tiles the
same dataflow for TPU.
"""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(table: jnp.ndarray, idx: jnp.ndarray,
                      weights: jnp.ndarray | None = None,
                      agg: str = "sum") -> jnp.ndarray:
    valid = idx >= 0
    safe = jnp.clip(idx, 0)
    g = table[safe]                                   # (B, L, D)
    if weights is not None:
        g = g * weights[..., None]
    g = jnp.where(valid[..., None], g, 0.0)
    s = jnp.sum(g, axis=1)
    if agg == "sum":
        return s
    if agg == "mean":
        cnt = jnp.maximum(jnp.sum(valid, axis=1, keepdims=True), 1)
        return s / cnt.astype(table.dtype)
    raise ValueError(f"unknown agg {agg!r}")
