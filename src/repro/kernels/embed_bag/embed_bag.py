"""Embedding-bag — Pallas TPU kernel for the sparse-table lookup hot path.

RecSys tables are huge (1e6–1e9 rows) and live in HBM; the bag indices are
small.  TPU-native plan (vs. GPU's warp-per-bag gather):

  * the table stays in HBM (``memory_space=pl.ANY``) — rows are DMA'd on
    demand with dynamic slices;
  * the grid tiles bags in ``bb`` blocks; each block's (bb, L) indices sit in
    VMEM and a fori_loop walks bag slots, issuing a (bb?, D)-row dynamic load
    per (bag, slot) and accumulating in a VMEM f32 scratch;
  * D is padded to lane width (128) by the caller (ops.py).

This mirrors the classic TPU embedding pattern (scalar-prefetched row DMA +
vector accumulate).  On-CPU validation uses interpret=True.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(bb: int, L: int, agg: str, out_dtype):
    def kernel(idx_ref, table_ref, out_ref):
        def bag_body(b, acc):
            def slot_body(l, ac):
                i = idx_ref[b, l]
                valid = i >= 0
                safe = jnp.maximum(i, 0)
                row = pl.load(table_ref, (pl.dslice(safe, 1), slice(None)))
                row = row.astype(jnp.float32)
                return ac.at[b].add(jnp.where(valid, row[0], 0.0))
            return jax.lax.fori_loop(0, L, slot_body, acc)

        acc0 = jnp.zeros(out_ref.shape, jnp.float32)
        acc = jax.lax.fori_loop(0, bb, bag_body, acc0)
        if agg == "mean":
            cnt = jnp.maximum(
                jnp.sum((idx_ref[...] >= 0).astype(jnp.float32), axis=1,
                        keepdims=True), 1.0)
            acc = acc / cnt
        out_ref[...] = acc.astype(out_dtype)
    return kernel


@functools.partial(jax.jit, static_argnames=("agg", "block_bags", "interpret"))
def embedding_bag(table: jax.Array, idx: jax.Array, *, agg: str = "sum",
                  block_bags: int = 8, interpret: bool = False) -> jax.Array:
    """table (V, D) f32/bf16; idx (B, L) i32 (-1 = pad) -> (B, D)."""
    V, D = table.shape
    B, L = idx.shape
    bb = min(block_bags, B)
    assert B % bb == 0, (B, bb)
    grid = (B // bb,)
    return pl.pallas_call(
        _make_kernel(bb, L, agg, table.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, L), lambda i: (i, 0)),          # indices (VMEM)
            pl.BlockSpec(memory_space=pl.ANY),                # table in HBM
        ],
        out_specs=pl.BlockSpec((bb, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(idx, table)
