"""Public embedding-bag wrapper with custom VJP (recsys training path).

Backward is the transposed scatter-add into the table — expressed through AD
of the jnp oracle so training works with or without the kernel enabled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.embed_bag.embed_bag import embedding_bag
from repro.kernels.embed_bag.ref import embedding_bag_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def bag_lookup(table, idx, agg: str = "sum", use_kernel: bool = False,
               interpret: bool = True):
    if use_kernel:
        return embedding_bag(table, idx, agg=agg, interpret=interpret)
    return embedding_bag_ref(table, idx, agg=agg)


def _fwd(table, idx, agg, use_kernel, interpret):
    return bag_lookup(table, idx, agg, use_kernel, interpret), (table, idx)


def _bwd(agg, use_kernel, interpret, res, g):
    table, idx = res
    _, vjp = jax.vjp(lambda t: embedding_bag_ref(t, idx, agg=agg), table)
    (dtable,) = vjp(g)
    return (dtable, None)


bag_lookup.defvjp(_fwd, _bwd)
