"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

Dispatch strategy (TPU/JAX-native, MegaBlocks-flavoured but dense):

  1. router logits -> top_k experts per token, softmax-renormalized gates;
  2. flatten (token, slot) assignments, sort by expert id;
  3. position-within-expert via cumsum over the sorted one-hot;
  4. tokens beyond capacity C are *dropped* (GShard semantics,
     capacity_factor configurable);
  5. gather into an (E, C, d) buffer -> batched expert SwiGLU
     (einsum over the expert dim; experts sharded over the "model" axis =
     expert parallelism) -> scatter-combine weighted by gates.

No (T, E, C) one-hot is ever materialized — the dispatch is O(T*k) gathers
plus one sort, which is what makes the 1M-token train cells compilable.

Aux losses: standard load-balancing loss (Switch) + router z-loss, returned
for logging and added to the LM loss by the caller.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import layers


BUFFER_CONSTRAINT = True  # §Perf D1 toggle (see EXPERIMENTS.md)
# Chunk size (in (token, slot) assignments) for the dispatch/combine
# gathers; 0 disables.  Bounds the (T*K, d) transients at the 1M-token
# prefill cells (§Perf F3).  Must divide T*K to engage.
DISPATCH_CHUNK = 524_288


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                  # per-expert hidden dim
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    balance_coef: float = 1e-2


def init_moe(key, d_model: int, cfg: MoEConfig) -> dict:
    kr, ke = jax.random.split(key)
    E, F = cfg.n_experts, cfg.d_ff
    s_in = 1.0 / jnp.sqrt(d_model)
    s_ff = 1.0 / jnp.sqrt(F)
    k1, k2, k3 = jax.random.split(ke, 3)
    return {
        "router": jax.random.normal(kr, (d_model, E), jnp.float32) * s_in,
        "w_gate": jax.random.normal(k1, (E, d_model, F), jnp.float32) * s_in,
        "w_up": jax.random.normal(k2, (E, d_model, F), jnp.float32) * s_in,
        "w_down": jax.random.normal(k3, (E, F, d_model), jnp.float32) * s_ff,
    }


def moe_capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU sublane alignment


def moe_forward(params: dict, x: jax.Array, cfg: MoEConfig
                ) -> tuple[jax.Array, dict]:
    """x (..., d) -> (..., d); aux dict carries router losses."""
    orig_shape = x.shape
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    T = xt.shape[0]
    E, K = cfg.n_experts, cfg.top_k
    C = moe_capacity(T, cfg)

    logits = (xt.astype(jnp.float32) @ params["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                  # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- aux losses
    me = jnp.mean(probs, axis=0)                                   # (E,)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], E), axis=0)
    balance = cfg.balance_coef * E * jnp.sum(me * ce)
    z = cfg.router_z_coef * jnp.mean(jax.nn.logsumexp(logits, -1) ** 2)

    # ---- sort-based dispatch
    flat_e = gate_idx.reshape(-1)                                  # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_g = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st_, sg = flat_e[order], flat_t[order], flat_g[order]
    # position of each sorted slot within its expert
    pos_all = jnp.arange(T * K)
    first_of_e = jnp.searchsorted(se, jnp.arange(E), side="left")  # (E,)
    pos_in_e = pos_all - first_of_e[se]
    keep = pos_in_e < C
    slot = se * C + jnp.where(keep, pos_in_e, 0)

    safe_slot = jnp.where(keep, slot, E * C - 1)
    n_slots = T * K
    if DISPATCH_CHUNK and n_slots > DISPATCH_CHUNK \
            and n_slots % DISPATCH_CHUNK == 0:
        # Chunked dispatch (§Perf F3): the one-shot gather xt[st_]
        # materializes a (T*K, d) tensor — 34 GB at the 1M-token prefill
        # cells.  Scanning over slot chunks bounds the transient to
        # (chunk, d) while keeping routing/drops bit-identical (positions
        # were computed globally above).
        nchunk = n_slots // DISPATCH_CHUNK
        st_c = st_.reshape(nchunk, DISPATCH_CHUNK)
        sl_c = safe_slot.reshape(nchunk, DISPATCH_CHUNK)
        kp_c = keep.reshape(nchunk, DISPATCH_CHUNK)

        def disp(buf, ch):
            st_i, sl_i, kp_i = ch
            upd = jnp.where(kp_i[:, None], xt[st_i], 0.0)
            return buf.at[sl_i].add(upd), None

        buf, _ = jax.lax.scan(
            disp, jnp.zeros((E * C, d), xt.dtype), (st_c, sl_c, kp_c))
    else:
        buf = jnp.zeros((E * C, d), xt.dtype)
        buf = buf.at[safe_slot].add(jnp.where(keep[:, None], xt[st_], 0.0))
    buf = buf.reshape(E, C, d)
    # Constrain the dispatch buffer to (E over model [EP], d over the batch
    # axes): the scatter's cross-shard reduction then moves (E/tp, C, d/dp)
    # slices instead of the full (E, C, d) buffer (EXPERIMENTS.md §Perf D1).
    # No-op outside the activation context or with BUFFER_CONSTRAINT off.
    if BUFFER_CONSTRAINT:
        from repro.models import sharding as shd_mod
        buf = shd_mod.wsc(buf, "model", None, "batch")

    # ---- expert SwiGLU (batched einsum over E; E sharded -> EP)
    wg = params["w_gate"].astype(xt.dtype)
    wu = params["w_up"].astype(xt.dtype)
    wd = params["w_down"].astype(xt.dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
    h = h * jnp.einsum("ecd,edf->ecf", buf, wu)
    y = jnp.einsum("ecf,efd->ecd", h, wd)                          # (E, C, d)
    if BUFFER_CONSTRAINT:
        from repro.models import sharding as shd_mod
        y = shd_mod.wsc(y, "model", None, "batch")

    # ---- combine: gather each kept slot's output back to its token
    y_flat = y.reshape(E * C, d)
    if DISPATCH_CHUNK and n_slots > DISPATCH_CHUNK \
            and n_slots % DISPATCH_CHUNK == 0:
        sg_c = sg.reshape(nchunk, DISPATCH_CHUNK)

        def comb(out, ch):
            st_i, sl_i, kp_i, sg_i = ch
            contrib = jnp.where(kp_i[:, None],
                                y_flat[sl_i] * sg_i[:, None].astype(xt.dtype),
                                0.0)
            return out.at[st_i].add(contrib), None

        out, _ = jax.lax.scan(comb, jnp.zeros_like(xt),
                              (st_c, sl_c, kp_c, sg_c))
    else:
        contrib = jnp.where(keep[:, None],
                            y_flat[slot] * sg[:, None].astype(xt.dtype), 0.0)
        out = jnp.zeros_like(xt).at[st_].add(contrib)

    frac_dropped = 1.0 - jnp.sum(keep.astype(jnp.float32)) / (T * K)
    aux = {"moe_balance": balance, "moe_z": z, "moe_dropped": frac_dropped}
    return out.reshape(orig_shape), aux
