"""Shared transformer layers: norms, RoPE, blockwise (flash-style) attention
with GQA + optional qk-norm, and gated MLPs.

All functions are pure; parameters are plain dict pytrees created by the
``init_*`` helpers (shape-only via jax.eval_shape for the dry-run).  Compute
dtype is bf16 with f32 accumulation/normalization (TPU convention); params
are kept f32 (master copy) and cast at use.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------- norms ----

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(dt)


def init_rms_norm(d: int) -> jax.Array:
    return jnp.ones((d,), jnp.float32)


# ----------------------------------------------------------------- rope ----

def rope_angles(positions: jax.Array, d_head: int, theta: float = 1e4
                ) -> tuple[jax.Array, jax.Array]:
    """positions (..., S) int -> (cos, sin) of shape (..., S, d_head//2)."""
    half = d_head // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, D); cos/sin (..., S, D//2) broadcast over heads."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.astype(dt)


# ------------------------------------------------- blockwise attention ----

def _gqa_scores(q, k):
    """q (B, S, nq, D), k (B, T, nkv, D) -> scores (B, nkv, G, S, T)."""
    B, S, nq, D = q.shape
    nkv = k.shape[2]
    G = nq // nkv
    qg = q.reshape(B, S, nkv, G, D)
    return jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                      k.astype(jnp.float32))


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool, block_k: int = 512,
                        q_offset: jax.Array | int = 0,
                        kv_len: jax.Array | None = None) -> jax.Array:
    """Flash-style attention: scan over KV blocks with running softmax stats.

    Memory is O(B * heads * S * block_k) instead of O(S * T): required for
    the 32k prefill cells and the standard TPU approach (the Pallas flash
    kernel on real hardware has this exact dataflow; on this CPU container
    the scan itself is the validated implementation).

    q (B, S, nq, D); k/v (B, T, nkv, D), nq % nkv == 0.
    ``q_offset``: global position of q[0] (decode: T_cur; train/prefill: 0).
    ``kv_len``: number of valid kv positions (decode with a partially filled
    cache); None means all T are valid.
    Returns (B, S, nq, D) in q.dtype.
    """
    B, S, nq, D = q.shape
    T, nkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = nq // nkv
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    nblk = -(-T // block_k)
    Tp = nblk * block_k
    if Tp != T:
        pad = Tp - T
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblk, block_k, nkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, block_k, nkv, Dv).transpose(1, 0, 2, 3, 4)

    qg = q.reshape(B, S, nkv, G, D)
    q_pos = (jnp.arange(S) + q_offset)[None, None, None, :, None]  # (1,1,1,S,1)

    def step(carry, blk):
        m, l, acc, t0 = carry
        kblk, vblk = blk  # (B, bk, nkv, D)
        s = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                       kblk.astype(jnp.float32)) * scale  # (B,nkv,G,S,bk)
        kv_pos = (t0 + jnp.arange(block_k))[None, None, None, None, :]
        mask = kv_pos < (Tp if kv_len is None else kv_len)
        if causal:
            mask = mask & (kv_pos <= q_pos)
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard: rows with no valid key yet keep m=-inf; exp(-inf - -inf) nan
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkd->bkgsd", p, vblk.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new, t0 + block_k), None

    m0 = jnp.full((B, nkv, G, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, nkv, G, S), jnp.float32)
    a0 = jnp.zeros((B, nkv, G, S, Dv), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, jnp.int32(0)), (kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, nq, Dv)
    return out.astype(q.dtype)


def attention_ref(q, k, v, *, causal: bool, q_offset=0, kv_len=None):
    """Dense O(S*T) oracle for blockwise_attention (tests only)."""
    B, S, nq, D = q.shape
    T, nkv = k.shape[1], k.shape[2]
    G = nq // nkv
    s = _gqa_scores(q, k) / jnp.sqrt(D)  # (B,nkv,G,S,T)
    q_pos = (jnp.arange(S) + q_offset)[None, None, None, :, None]
    kv_pos = jnp.arange(T)[None, None, None, None, :]
    mask = jnp.ones((1, 1, 1, S, T), bool)
    if kv_len is not None:
        mask = mask & (kv_pos < kv_len)
    if causal:
        mask = mask & (kv_pos <= q_pos)
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask, -1, keepdims=True), p, 0.0)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, nq, v.shape[-1]).astype(q.dtype)


# ------------------------------------------------------------------ mlp ----

def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    dt = x.dtype
    h = jax.nn.silu(x @ w_gate.astype(dt)) * (x @ w_up.astype(dt))
    return h @ w_down.astype(dt)


def init_swiglu(key, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / jnp.sqrt(d_model)
    s_ff = 1.0 / jnp.sqrt(d_ff)
    return {
        "w_gate": jax.random.normal(k1, (d_model, d_ff), jnp.float32) * s_in,
        "w_up": jax.random.normal(k2, (d_model, d_ff), jnp.float32) * s_in,
        "w_down": jax.random.normal(k3, (d_ff, d_model), jnp.float32) * s_ff,
    }


def linear(x: jax.Array, w: jax.Array) -> jax.Array:
    return x @ w.astype(x.dtype)


def init_linear(key, d_in: int, d_out: int) -> jax.Array:
    return jax.random.normal(key, (d_in, d_out), jnp.float32) / jnp.sqrt(d_in)
