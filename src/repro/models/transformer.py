"""Decoder-only LM family covering the five assigned transformer archs.

One config dataclass + one parameter pytree layout covers:

  * olmoe-1b-7b          — GQA(16/16) + MoE 64e top-8
  * moonshot-v1-16b-a3b  — GQA(16/16) + MoE 64e top-6
  * minicpm3-4b          — MLA (DeepSeek-V2 style latent attention), dense
  * mistral-large-123b   — GQA(96/8), dense
  * qwen3-14b            — GQA(40/8) + qk-norm, dense

Layer parameters are *stacked* on a leading ``L`` axis and the forward pass
is a ``jax.lax.scan`` over layers (remat-wrapped) so the lowered HLO contains
one layer body regardless of depth — this is what keeps the 88-layer
mistral-large dry-run compile tractable and is also the standard production
trick (MaxText does the same).

Three entry points match the assigned input shapes:

  * ``lm_loss``      — training forward+loss (train_4k), grad-accum handled
                       by the caller (train/steps.py);
  * ``lm_forward``   — full-sequence logits (prefill_32k uses the blockwise
                       attention path; activations stay O(S·block_k));
  * ``decode_step``  — one token with a KV cache (decode_32k).  GQA caches
                       (k, v); MLA caches the latent (c_kv, k_rope) pair and
                       uses the absorbed-matmul form (the memory-roofline
                       point of MLA).

``long_500k`` is *skipped* for all five archs: they are pure full-attention
models (see DESIGN.md §5 / EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import flash as flash_mod
from repro.models import layers, mla as mla_mod, moe as moe_mod

# --- activation-sharding context (set by the launcher/dry-run) -------------
# When set, the residual stream is constrained to BATCH-ONLY sharding at
# every layer boundary.  Without it GSPMD is free to shard x over the model
# axis and then all-gathers activations around every matmul (measured:
# 12.1 GB wire per layer on mistral-large train_4k — EXPERIMENTS.md §Perf
# iteration A2); with it, the per-layer collectives collapse to the
# Megatron pattern (weights gathered once, two x-sized all-reduces).
# The machinery lives in models/sharding.py (shared with the MoE layer).
from repro.models.sharding import activation_context as activation_sharding  # noqa: E402
from repro.models.sharding import wsc_batch as _wsc_batch  # noqa: E402


def attention(q, k, v, *, causal: bool, block_k: int, impl: str):
    """Training/prefill attention dispatch (decode has its own dense path)."""
    if impl == "flash_vjp":
        return flash_mod.flash_attention(q, k, v, causal, block_k)
    return layers.blockwise_attention(q, k, v, causal=causal, block_k=block_k)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int | None = None          # default d_model // n_heads
    attn: str = "gqa"                  # "gqa" | "mla"
    qk_norm: bool = False
    rope_theta: float = 1e4
    moe: moe_mod.MoEConfig | None = None
    mla: mla_mod.MLAConfig | None = None
    tie_embeddings: bool = False
    vocab_pad_to: int = 256
    # performance knobs (hillclimb targets; see EXPERIMENTS.md §Perf)
    remat: bool = True
    block_k: int = 512
    grad_accum: int = 1                # microbatches per train step
    compute_dtype: Any = jnp.bfloat16
    # "flash_vjp": custom-VJP flash attention (O(S*d) residuals) — the
    # optimized default.  "scan": plain lax.scan + autodiff (baseline; its
    # backward saves O(S*T) softmax numerators — see EXPERIMENTS.md §Perf).
    attn_impl: str = "flash_vjp"
    # "layer": stash one residual per layer (default).  "sqrt": two-level
    # scan stashing one residual per remat_group layers (peak-memory lever
    # for the 88-layer mistral cell — EXPERIMENTS.md §Perf iteration A3).
    remat_policy: str = "layer"
    remat_group: int = 1
    # constrain the residual stream to batch-only sharding (§Perf A2);
    # the launcher activates it via the activation_sharding context
    act_batch_sharding: bool = True

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_to)

    # ------------------------------------------------- analytic param counts
    def params_per_layer(self) -> int:
        d, dh = self.d_model, self.head_dim
        if self.attn == "mla":
            m = self.mla
            attn = (d * m.q_lora_rank
                    + m.q_lora_rank * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                    + d * (m.kv_lora_rank + m.qk_rope_dim)
                    + m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
        else:
            attn = (d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh
                    + self.n_heads * dh * d)
        if self.moe is not None:
            mlp = self.moe.n_experts * 3 * d * self.moe.d_ff + d * self.moe.n_experts
        else:
            mlp = 3 * d * self.d_ff
        return attn + mlp + 2 * d  # + norms

    def param_count(self) -> int:
        emb = self.padded_vocab * self.d_model
        head = 0 if self.tie_embeddings else self.d_model * self.padded_vocab
        return emb + head + self.n_layers * self.params_per_layer() + self.d_model

    def active_params_per_layer(self) -> int:
        """MoE: only top_k experts touch each token (for MODEL_FLOPS=6·N_act·D)."""
        per = self.params_per_layer()
        if self.moe is not None:
            dense_all = self.moe.n_experts * 3 * self.d_model * self.moe.d_ff
            dense_act = self.moe.top_k * 3 * self.d_model * self.moe.d_ff
            per = per - dense_all + dense_act
        return per

    def active_param_count(self) -> int:
        emb = self.padded_vocab * self.d_model
        head = 0 if self.tie_embeddings else self.d_model * self.padded_vocab
        return emb + head + self.n_layers * self.active_params_per_layer() + self.d_model

    def model_flops(self, n_tokens: int, *, train: bool = True) -> float:
        """6·N_active·D (train fwd+bwd) or 2·N_active·D (inference fwd)."""
        n = self.active_param_count() - self.padded_vocab * self.d_model  # non-embed
        if not self.tie_embeddings:
            n -= 0  # lm_head matmul is real compute; keep it
        return (6.0 if train else 2.0) * n * n_tokens


# ------------------------------------------------------------------ init ----

def _init_attn(key, cfg: LMConfig) -> dict:
    d, dh = cfg.d_model, cfg.head_dim
    if cfg.attn == "mla":
        return mla_mod.init_mla(key, d, cfg.n_heads, cfg.mla)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / jnp.sqrt(d)
    p = {
        "wq": jax.random.normal(k1, (d, cfg.n_heads * dh), jnp.float32) * s,
        "wk": jax.random.normal(k2, (d, cfg.n_kv_heads * dh), jnp.float32) * s,
        "wv": jax.random.normal(k3, (d, cfg.n_kv_heads * dh), jnp.float32) * s,
        "wo": jax.random.normal(k4, (cfg.n_heads * dh, d), jnp.float32)
              / jnp.sqrt(cfg.n_heads * dh),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.init_rms_norm(dh)
        p["k_norm"] = layers.init_rms_norm(dh)
    return p


def init_block(key, cfg: LMConfig) -> dict:
    ka, km = jax.random.split(key)
    blk = {
        "attn_norm": layers.init_rms_norm(cfg.d_model),
        "mlp_norm": layers.init_rms_norm(cfg.d_model),
        "attn": _init_attn(ka, cfg),
    }
    if cfg.moe is not None:
        blk["moe"] = moe_mod.init_moe(km, cfg.d_model, cfg.moe)
    else:
        blk["mlp"] = layers.init_swiglu(km, cfg.d_model, cfg.d_ff)
    return blk


def init_lm(key, cfg: LMConfig) -> dict:
    ke, kb, kh = jax.random.split(key, 3)
    block_keys = jax.random.split(kb, cfg.n_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(block_keys)
    params = {
        "embed": jax.random.normal(ke, (cfg.padded_vocab, cfg.d_model),
                                   jnp.float32) * 0.02,
        "blocks": blocks,
        "final_norm": layers.init_rms_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(kh, (cfg.d_model, cfg.padded_vocab),
                                               jnp.float32)
                             / jnp.sqrt(cfg.d_model))
    return params


def lm_param_shapes(cfg: LMConfig):
    """ShapeDtypeStruct tree (no allocation) — dry-run stand-in."""
    return jax.eval_shape(lambda: init_lm(jax.random.key(0), cfg))


# --------------------------------------------------------------- forward ----

def _gqa_attention(p, x, cfg: LMConfig, positions, *, causal=True):
    B, S, _ = x.shape
    dh = cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, cfg.n_heads, dh)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, cfg.n_kv_heads, dh)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"])
        k = layers.rms_norm(k, p["k_norm"])
    cos, sin = layers.rope_angles(positions, dh, cfg.rope_theta)
    q = layers.apply_rope(q, cos, sin)
    k = layers.apply_rope(k, cos, sin)
    out = attention(q, k, v, causal=causal, block_k=cfg.block_k,
                    impl=cfg.attn_impl)
    return out.reshape(B, S, cfg.n_heads * dh) @ p["wo"].astype(x.dtype)


def block_forward(blk, x, cfg: LMConfig, positions):
    """One pre-norm transformer block; returns (x, aux)."""
    if cfg.act_batch_sharding:
        x = _wsc_batch(x)
    h = layers.rms_norm(x, blk["attn_norm"])
    if cfg.attn == "mla":
        a = mla_mod.mla_attention_full(blk["attn"], h, cfg.n_heads, cfg.mla,
                                       positions, cfg.rope_theta, cfg.block_k)
    else:
        a = _gqa_attention(blk["attn"], h, cfg, positions)
    x = x + a
    h = layers.rms_norm(x, blk["mlp_norm"])
    if cfg.moe is not None:
        m, aux = moe_mod.moe_forward(blk["moe"], h, cfg.moe)
    else:
        m, aux = layers.swiglu(h, **blk["mlp"]), {}
    return x + m, aux


def lm_forward(params, tokens, cfg: LMConfig):
    """tokens (B, S) int32 -> (logits (B, S, V) in compute dtype, aux dict)."""
    B, S = tokens.shape
    x = _wsc_batch(params["embed"].astype(cfg.compute_dtype)[tokens])
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    def body(carry, blk):
        y, aux = block_forward(blk, carry, cfg, positions)
        return y, aux

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)

    if cfg.remat_policy == "sqrt" and cfg.n_layers % cfg.remat_group > 0:
        raise ValueError("n_layers must divide remat_group for sqrt remat")
    if cfg.remat_policy == "sqrt" and cfg.remat_group > 1:
        # Two-level remat: the outer scan stashes only L/G residuals; the
        # inner G layers are recomputed from the group input in backward.
        # Cuts the layer-input stash by G at the price of one extra forward
        # of the inner layers (EXPERIMENTS.md §Perf iteration A3).
        G = cfg.remat_group
        grouped = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers // G, G) + a.shape[1:]),
            params["blocks"])

        def group_body(carry, grp):
            y, aux = jax.lax.scan(body, carry, grp)
            return y, jax.tree.map(jnp.sum, aux)

        x, aux_stacked = jax.lax.scan(
            jax.checkpoint(group_body, prevent_cse=False), x, grouped)
    else:
        x, aux_stacked = jax.lax.scan(body, x, params["blocks"])
    aux = {k: jnp.sum(v) for k, v in aux_stacked.items()}

    x = layers.rms_norm(x, params["final_norm"])
    w_head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ w_head.astype(x.dtype)
    return logits, aux


def lm_loss(params, batch: dict, cfg: LMConfig):
    """batch: tokens (B,S) i32, labels (B,S) i32 (-1 = masked).

    Returns (loss, metrics).  Softmax cross-entropy in f32; MoE aux losses
    (balance + z) are added with their configured coefficients.
    """
    logits, aux = lm_forward(params, batch["tokens"], cfg)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    ntok = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll) / ntok
    total = loss + aux.get("moe_balance", 0.0) + aux.get("moe_z", 0.0)
    metrics = {"loss": loss, "ntok": ntok, **aux}
    return total, metrics


# ---------------------------------------------------------------- decode ----

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KVCache:
    """Decode cache.  GQA: k/v (L, B, T, n_kv, dh).  MLA: k holds the latent
    c_kv (L, B, T, r_kv) and v holds k_rope (L, B, T, dr)."""
    k: jax.Array
    v: jax.Array
    length: jax.Array  # i32[] — number of valid positions

    @property
    def capacity(self) -> int:
        return self.k.shape[2]


def init_cache(cfg: LMConfig, batch: int, capacity: int,
               dtype=jnp.bfloat16) -> KVCache:
    L = cfg.n_layers
    if cfg.attn == "mla":
        k = jnp.zeros((L, batch, capacity, cfg.mla.kv_lora_rank), dtype)
        v = jnp.zeros((L, batch, capacity, cfg.mla.qk_rope_dim), dtype)
    else:
        k = jnp.zeros((L, batch, capacity, cfg.n_kv_heads, cfg.head_dim), dtype)
        v = jnp.zeros_like(k)
    return KVCache(k=k, v=v, length=jnp.int32(0))


def cache_shapes(cfg: LMConfig, batch: int, capacity: int, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_cache(cfg, batch, capacity, dtype))


def _decode_attn_gqa(p, x, cfg: LMConfig, ck, cv, length):
    """x (B,1,d); ck/cv (B,T,nkv,dh) with the new token NOT yet appended.
    Returns (attn_out (B,1,d), new_ck, new_cv)."""
    B = x.shape[0]
    dh = cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, 1, cfg.n_heads, dh)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, 1, cfg.n_kv_heads, dh)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, 1, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"])
        k = layers.rms_norm(k, p["k_norm"])
    pos = jnp.reshape(length, (1, 1))
    cos, sin = layers.rope_angles(pos, dh, cfg.rope_theta)
    q = layers.apply_rope(q, cos, sin)
    k = layers.apply_rope(k, cos, sin)
    ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, length, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, length, 0, 0))
    T = ck.shape[1]
    # dense single-token attention: scores (B, nkv, G, 1, T) in f32.  The T
    # dim is what the mesh "model" axis shards at 32k (context parallelism by
    # GSPMD propagation); softmax/psum combine is compiler-inserted.
    nkv, G = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, 1, nkv, G, dh)
    s = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                   ck.astype(jnp.float32)) / jnp.sqrt(jnp.float32(dh))
    mask = jnp.arange(T)[None, None, None, None, :] <= length
    s = jnp.where(mask, s, -jnp.inf)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", pr, cv.astype(jnp.float32))
    o = o.reshape(B, 1, cfg.n_heads * dh).astype(x.dtype)
    return o @ p["wo"].astype(x.dtype), ck, cv


def _decode_attn_mla(p, x, cfg: LMConfig, cc, cr, length):
    """MLA absorbed decode; cc (B,T,rkv), cr (B,T,dr)."""
    c_kv, k_rope = mla_mod.mla_latent_for_token(
        p, x, cfg.mla, length, cfg.rope_theta)
    cc = jax.lax.dynamic_update_slice(cc, c_kv.astype(cc.dtype), (0, length, 0))
    cr = jax.lax.dynamic_update_slice(cr, k_rope[:, None, :].astype(cr.dtype)
                                      if k_rope.ndim == 2 else k_rope.astype(cr.dtype),
                                      (0, length, 0))
    out = mla_mod.mla_decode_absorbed(p, x, cfg.n_heads, cfg.mla,
                                      cc, cr, length + 1, cfg.rope_theta)
    return out, cc, cr


def decode_step(params, cache: KVCache, tokens, cfg: LMConfig):
    """tokens (B,) i32 (the newest token) -> (logits (B, V), new cache)."""
    x = params["embed"].astype(cfg.compute_dtype)[tokens][:, None, :]  # (B,1,d)
    length = cache.length

    attn_fn = _decode_attn_mla if cfg.attn == "mla" else _decode_attn_gqa

    def body(carry, xs):
        h = carry
        blk, ck, cv = xs
        a_in = layers.rms_norm(h, blk["attn_norm"])
        a, ck, cv = attn_fn(blk["attn"], a_in, cfg, ck, cv, length)
        h = h + a
        m_in = layers.rms_norm(h, blk["mlp_norm"])
        if cfg.moe is not None:
            m, _ = moe_mod.moe_forward(blk["moe"], m_in, cfg.moe)
        else:
            m = layers.swiglu(m_in, **blk["mlp"])
        return h + m, (ck, cv)

    x, (new_k, new_v) = jax.lax.scan(body, x, (params["blocks"], cache.k, cache.v))
    x = layers.rms_norm(x, params["final_norm"])
    w_head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (x @ w_head.astype(x.dtype))[:, 0, :]
    return logits, KVCache(k=new_k, v=new_v, length=length + 1)


def prefill(params, tokens, cfg: LMConfig, capacity: int):
    """Full-sequence prefill that also fills a decode cache (serving path)."""
    B, S = tokens.shape
    logits, _ = lm_forward(params, tokens, cfg)
    # Re-run the cheap per-layer cache projections to fill the cache.  (One
    # fused pass would save ~1 projection; kept simple — prefill attention
    # dominates.)
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    cache = init_cache(cfg, B, capacity)

    from repro.models import sharding as shd_mod

    def _cache_wsc(c):
        # Always constrain per-layer cache slices to (batch, seq@model):
        # without this the scan's stacked (L, B, T, ...) cache buffer is
        # replicated per device (measured 70-130 GB peak on the 32k prefill
        # cells — EXPERIMENTS.md §Perf B1).
        return shd_mod.wsc(c, "batch", "model", *([None] * (c.ndim - 2)))

    def body(x, blk):
        if cfg.act_batch_sharding:
            x = _wsc_batch(x)
        h = layers.rms_norm(x, blk["attn_norm"])
        if cfg.attn == "mla":
            q, k, v, c_kv, k_rope = mla_mod.mla_qkv_full(
                blk["attn"], h, cfg.n_heads, cfg.mla, positions, cfg.rope_theta)
            out = attention(q, k, v, causal=True, block_k=cfg.block_k,
                            impl=cfg.attn_impl)
            B_, S_ = x.shape[:2]
            a = out.reshape(B_, S_, -1) @ blk["attn"]["w_o"].astype(x.dtype)
            ck = jnp.zeros((B, capacity, cfg.mla.kv_lora_rank), jnp.bfloat16)
            cv = jnp.zeros((B, capacity, cfg.mla.qk_rope_dim), jnp.bfloat16)
            ck = jax.lax.dynamic_update_slice(ck, c_kv.astype(ck.dtype), (0, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, k_rope.astype(cv.dtype), (0, 0, 0))
        else:
            dh = cfg.head_dim
            p = blk["attn"]
            q = (h @ p["wq"].astype(h.dtype)).reshape(B, S, cfg.n_heads, dh)
            k = (h @ p["wk"].astype(h.dtype)).reshape(B, S, cfg.n_kv_heads, dh)
            v = (h @ p["wv"].astype(h.dtype)).reshape(B, S, cfg.n_kv_heads, dh)
            if cfg.qk_norm:
                q = layers.rms_norm(q, p["q_norm"])
                k = layers.rms_norm(k, p["k_norm"])
            cos, sin = layers.rope_angles(positions, dh, cfg.rope_theta)
            q = layers.apply_rope(q, cos, sin)
            k = layers.apply_rope(k, cos, sin)
            out = attention(q, k, v, causal=True, block_k=cfg.block_k,
                            impl=cfg.attn_impl)
            a = out.reshape(B, S, -1) @ p["wo"].astype(h.dtype)
            ck = jnp.zeros((B, capacity, cfg.n_kv_heads, dh), jnp.bfloat16)
            cv = jnp.zeros_like(ck)
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, 0, 0, 0))
        x = x + a
        m_in = layers.rms_norm(x, blk["mlp_norm"])
        if cfg.moe is not None:
            m, _ = moe_mod.moe_forward(blk["moe"], m_in, cfg.moe)
        else:
            m = layers.swiglu(m_in, **blk["mlp"])
        return x + m, (_cache_wsc(ck), _cache_wsc(cv))

    _, (cks, cvs) = jax.lax.scan(body, x, params["blocks"])
    return logits, KVCache(k=cks, v=cvs, length=jnp.int32(S))
