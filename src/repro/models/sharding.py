"""Parameter/activation sharding rules (logical axes -> mesh axes).

Production mesh axes (launch/mesh.py):

  * ``data``  (16) — batch parallelism + FSDP (ZeRO-3-style parameter
    sharding; GSPMD inserts the per-use all-gathers);
  * ``model`` (16) — tensor parallelism (heads / d_ff / experts / vocab);
  * ``pod``   (2, multi-pod only) — pure data parallelism across pods:
    params replicated pod-wise (gradient all-reduce crosses the DCN once per
    step), batch sharded over (pod, data).

Divisibility is checked per-dimension: a rule that does not divide evenly is
dropped to ``None`` for that dim (e.g. minicpm3's 40 heads on a 16-way model
axis — the flattened head*dim projections still shard; the per-head score
layout is left to GSPMD).
"""
from __future__ import annotations

import re
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    names = (axis,) if isinstance(axis, str) else tuple(axis)
    size = 1
    for n in names:
        if n not in mesh.shape:
            return False
        size *= mesh.shape[n]
    return dim % size == 0


def spec_for(shape: Sequence[int], wanted: Sequence, mesh: Mesh) -> P:
    """Clamp a wanted spec to the dims that actually divide."""
    out = []
    for dim, ax in zip(shape, wanted):
        out.append(ax if _fits(dim, mesh, ax) else None)
    return P(*out)


# Param-path rules: (regex over "/".join(path), wanted logical spec where
# "fsdp" -> data axis, "tp" -> model axis; matched against the *trailing*
# dims — stacked-layer leading L dims get None automatically).
_LM_RULES: list[tuple[str, tuple]] = [
    (r"embed$",                ("tp", "fsdp")),        # (V, d)
    (r"lm_head$",              ("fsdp", "tp")),        # (d, V)
    (r"final_norm$|.*_norm$|.*norm$", (None,)),        # (d,) and friends
    # GQA attention
    (r"attn/wq$|attn/wk$|attn/wv$", ("fsdp", "tp")),   # (d, h*dh)
    (r"attn/wo$",              ("tp", "fsdp")),        # (h*dh, d)
    # MLA
    (r"attn/w_dq$",            ("fsdp", "tp")),        # (d, rq)
    (r"attn/w_uq$",            ("fsdp", "tp")),        # (rq, h*(dn+dr))
    (r"attn/w_dkv$",           ("fsdp", None)),        # (d, rkv+dr)
    (r"attn/w_uk$|attn/w_uv$", (None, "tp")),          # (rkv, h*dn)
    (r"attn/w_o$",             ("tp", "fsdp")),        # (h*dv, d)
    # dense MLP
    (r"mlp/w_gate$|mlp/w_up$", ("fsdp", "tp")),        # (d, F)
    (r"mlp/w_down$",           ("tp", "fsdp")),        # (F, d)
    # MoE: experts over model axis (expert parallelism)
    (r"moe/router$",           ("fsdp", None)),        # (d, E)
    (r"moe/w_gate$|moe/w_up$", ("tp", "fsdp", None)),  # (E, d, F)
    (r"moe/w_down$",           ("tp", None, "fsdp")),  # (E, F, d)
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(k.name)
        else:
            parts.append(str(k))
    return "/".join(parts)


def _resolve(ax, fsdp_axis, tp_axis):
    if ax == "fsdp":
        return fsdp_axis
    if ax == "tp":
        return tp_axis
    return ax


def lm_param_specs(params_shape, mesh: Mesh, *, fsdp_axis="data",
                   tp_axis="model") -> "jax.tree_util.PyTreeDef":
    """PartitionSpec tree for an LM param pytree (works on shapes or arrays).

    Stacked-layer leaves (under ``blocks``) get a leading None for the L dim.
    """

    def one(path, leaf):
        pstr = _path_str(path)
        shape = leaf.shape
        stacked = pstr.startswith("blocks/")
        trail = shape[1:] if stacked else shape
        for pat, wanted in _LM_RULES:
            if re.search(pat, pstr):
                w = tuple(_resolve(a, fsdp_axis, tp_axis) for a in wanted)
                if len(w) != len(trail):   # e.g. stacked norms (L, d)
                    w = (None,) * (len(trail) - 1) + (w[-1],) if len(trail) else ()
                sp = spec_for(trail, w, mesh)
                return P(*((None,) + tuple(sp))) if stacked else sp
        return P(*((None,) * len(shape)))  # default: replicated

    return jax.tree_util.tree_map_with_path(one, params_shape)


def lm_shardings(params_shape, mesh: Mesh, **kw):
    specs = lm_param_specs(params_shape, mesh, **kw)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Axes the global batch is sharded over: (pod, data) when multi-pod."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def lm_batch_spec(mesh: Mesh) -> P:
    return P(batch_axes(mesh))


def cache_spec(cache_shape, mesh: Mesh) -> P:
    """KV cache sharding: batch over (pod,data); cache-length dim over model
    (context parallelism for 32k decode — the memory-roofline winner; see
    EXPERIMENTS.md §Perf)."""
    b_ax = batch_axes(mesh)

    def one(path, leaf):
        name = _path_str(path)
        if name == "length":
            return P()
        # (L, B, T, ...) — shard B over data axes, T over model.
        shape = leaf.shape
        want = [None, b_ax, "model"] + [None] * (len(shape) - 3)
        return spec_for(shape, want, mesh)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def tree_specs_to_shardings(specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ----------------------------------------------------- graph-model specs ----

def graph_axes(mesh: Mesh) -> tuple[str, ...]:
    """GNN / recsys / SSSP models flatten every mesh axis into one big
    vertex/row partition (shared-nothing, paper §3)."""
    return tuple(mesh.axis_names)


def row_sharded(mesh: Mesh, ndim: int) -> NamedSharding:
    """Shard dim0 over all mesh axes, replicate the rest (node/edge/row
    tables)."""
    return NamedSharding(mesh, P(graph_axes(mesh), *([None] * (ndim - 1))))


# ------------------------------------------------ activation-sharding ctx ----
# Set by the launcher/dry-run around tracing; model code consults it to
# constrain activation layouts (see EXPERIMENTS.md §Perf iterations A2/D1).
ACT_CTX: list = []


class activation_context:
    def __init__(self, mesh: Mesh, batch_axes_):
        self.proto = (mesh, tuple(batch_axes_))

    def __enter__(self):
        ACT_CTX.append(self.proto)
        return self

    def __exit__(self, *exc):
        ACT_CTX.pop()
        return False


def wsc(x, *wanted):
    """with_sharding_constraint against the active context; ``wanted`` uses
    "batch" for the batch axes, a mesh-axis name, or None per dim.  No-op
    when no context is active or a dim does not divide."""
    if not ACT_CTX:
        return x
    mesh, bx = ACT_CTX[-1]
    resolved = tuple(bx if a == "batch" else a for a in wanted)
    spec = spec_for(x.shape, resolved, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def wsc_batch(x):
    return wsc(x, "batch", *([None] * (x.ndim - 1)))
