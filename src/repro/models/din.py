"""DIN — Deep Interest Network (Zhou et al., arXiv:1706.06978).

Assigned config: embed_dim=18, behaviour seq_len=100, attention MLP 80-40,
prediction MLP 200-80, interaction = target attention.

System shape (kernel-taxonomy §RecSys): huge sparse embedding tables ->
feature interaction -> small MLP.  The tables are the hot path:

  * item table   (n_items x 18)   — row-sharded over the full mesh;
  * cate table   (n_cates x 18);
  * lookups are ``jnp.take`` (GSPMD turns cross-shard rows into collective
    gathers); sum-bags where needed use the embedding-bag kernel substrate
    (kernels/embed_bag) — JAX has no native EmbeddingBag, we built one.

Four serving/training entry points match the assigned shapes:

  * ``din_loss``        — train_batch (65,536): BCE on click labels;
  * ``din_score``       — serve_p99 (512) / serve_bulk (262,144): forward;
  * ``din_retrieval``   — retrieval_cand: ONE user history scored against
    1M candidates.  Implemented as a batched-dot: the user's behaviour
    embeddings are computed once, the per-candidate target-attention is a
    single (candidates x seq) einsum — not a loop.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DINConfig:
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: tuple[int, ...] = (80, 40)
    mlp: tuple[int, ...] = (200, 80)
    n_items: int = 10_000_000
    n_cates: int = 1_000
    # Dice/PReLU simplified to silu (activation choice is not the paper's
    # contribution; noted in DESIGN.md)

    @property
    def d_item(self) -> int:
        return 2 * self.embed_dim  # item ++ cate embedding


def _mlp_init(key, dims):
    ks = jax.random.split(key, len(dims) - 1)
    return {
        "w": [jax.random.normal(k, (dims[i], dims[i + 1]), jnp.float32)
              / jnp.sqrt(dims[i]) for i, k in enumerate(ks)],
        "b": [jnp.zeros((dims[i + 1],), jnp.float32)
              for i in range(len(dims) - 1)],
    }


def _mlp(p, x, final=None):
    n = len(p["w"])
    for i, (w, b) in enumerate(zip(p["w"], p["b"])):
        x = x @ w.astype(x.dtype) + b.astype(x.dtype)
        if i < n - 1:
            x = jax.nn.silu(x)
    return x if final is None else final(x)


def init_din(key, cfg: DINConfig) -> dict:
    ki, kc, ka, km = jax.random.split(key, 4)
    d = cfg.embed_dim
    di = cfg.d_item
    # attention MLP input: [target, behav, target-behav, target*behav]
    attn_dims = (4 * di,) + tuple(cfg.attn_mlp) + (1,)
    # prediction MLP input: [user_interest (di), target (di), sum_pool (di)]
    mlp_dims = (3 * di,) + tuple(cfg.mlp) + (1,)
    return {
        "item_emb": jax.random.normal(ki, (cfg.n_items, d), jnp.float32) * 0.01,
        "cate_emb": jax.random.normal(kc, (cfg.n_cates, d), jnp.float32) * 0.01,
        "attn": _mlp_init(ka, attn_dims),
        "mlp": _mlp_init(km, mlp_dims),
    }


def din_param_shapes(cfg: DINConfig):
    return jax.eval_shape(lambda: init_din(jax.random.key(0), cfg))


def _embed_items(params, item_ids, cate_ids):
    """(..., ) int32 ids -> (..., 2*d) [item ++ cate] embeddings."""
    e_i = jnp.take(params["item_emb"], item_ids, axis=0)
    e_c = jnp.take(params["cate_emb"], cate_ids, axis=0)
    return jnp.concatenate([e_i, e_c], axis=-1)


def _target_attention(params, target, behav, behav_mask):
    """DIN's local activation unit.

    target (B, di); behav (B, S, di); mask (B, S) -> interest (B, di).
    Attention weights are NOT softmax-normalized (paper §4.3 keeps the
    un-normalized sum to preserve interest intensity).
    """
    B, S, di = behav.shape
    t = jnp.broadcast_to(target[:, None, :], (B, S, di))
    feat = jnp.concatenate([t, behav, t - behav, t * behav], axis=-1)
    w = _mlp(params["attn"], feat)[..., 0]                    # (B, S)
    w = jnp.where(behav_mask, w, 0.0)
    return jnp.einsum("bs,bsd->bd", w, behav)


def din_forward(params, batch, cfg: DINConfig) -> jax.Array:
    """batch: target_item/target_cate (B,), hist_items/hist_cates (B, S),
    hist_mask (B, S) bool.  Returns click logits (B,)."""
    target = _embed_items(params, batch["target_item"], batch["target_cate"])
    behav = _embed_items(params, batch["hist_items"], batch["hist_cates"])
    mask = batch["hist_mask"]
    interest = _target_attention(params, target, behav, mask)
    # sum-pool of the behaviour sequence (embedding-bag; masked)
    pool = jnp.einsum("bs,bsd->bd", mask.astype(behav.dtype), behav)
    x = jnp.concatenate([interest, target, pool], axis=-1)
    return _mlp(params["mlp"], x)[..., 0]


def din_loss(params, batch, cfg: DINConfig):
    logits = din_forward(params, batch, cfg).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    acc = jnp.mean(((logits > 0) == (y > 0.5)).astype(jnp.float32))
    return loss, {"loss": loss, "acc": acc}


def din_score(params, batch, cfg: DINConfig) -> jax.Array:
    """Online/offline scoring: sigmoid click probability (B,)."""
    return jax.nn.sigmoid(din_forward(params, batch, cfg))


def din_retrieval(params, batch, cfg: DINConfig) -> jax.Array:
    """One user, n_candidates targets (retrieval_cand shape).

    batch: hist_items/hist_cates (S,), hist_mask (S,),
           cand_items/cand_cates (C,).  Returns scores (C,).

    The user's behaviour embedding (S, di) is computed ONCE; the local
    activation unit is evaluated as one (C, S) batched interaction — the
    candidate axis is just a batch axis, so this is a single fused einsum
    chain, not a per-candidate loop.
    """
    behav = _embed_items(params, batch["hist_items"], batch["hist_cates"])
    mask = batch["hist_mask"]                                  # (S,)
    cand = _embed_items(params, batch["cand_items"], batch["cand_cates"])
    Cn, di = cand.shape
    S = behav.shape[0]
    t = jnp.broadcast_to(cand[:, None, :], (Cn, S, di))
    b = jnp.broadcast_to(behav[None], (Cn, S, di))
    feat = jnp.concatenate([t, b, t - b, t * b], axis=-1)
    w = _mlp(params["attn"], feat)[..., 0]                     # (C, S)
    w = jnp.where(mask[None, :], w, 0.0)
    interest = jnp.einsum("cs,sd->cd", w, behav)
    pool = jnp.einsum("s,sd->d", mask.astype(behav.dtype), behav)
    x = jnp.concatenate(
        [interest, cand, jnp.broadcast_to(pool[None], (Cn, di))], axis=-1)
    return jax.nn.sigmoid(_mlp(params["mlp"], x)[..., 0])
