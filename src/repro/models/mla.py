"""Multi-head Latent Attention (MLA, DeepSeek-V2 / MiniCPM3 style).

Projections:
  q:  x -> q_lora (rank r_q, RMS-normed) -> per-head [nope dn | rope dr]
  kv: x -> [c_kv (rank r_kv, RMS-normed) | shared k_rope (dr)]
  k_h = [W_uk c_kv | k_rope (broadcast over heads)],  v_h = W_uv c_kv

Train/prefill reconstruct full k/v and run blockwise attention (activation
cost dominated by S anyway).  Decode uses the **absorbed** form: q_nope is
folded through W_uk so scores are taken directly against the latent cache
(c_kv, k_rope) — the cache holds only (r_kv + dr) per token, which is the
whole point of MLA (memory term in the roofline).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64


def init_mla(key, d_model: int, n_heads: int, cfg: MLAConfig) -> dict:
    ks = jax.random.split(key, 6)
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    s = lambda d: 1.0 / jnp.sqrt(d)
    return {
        "w_dq": jax.random.normal(ks[0], (d_model, rq), jnp.float32) * s(d_model),
        "q_norm": layers.init_rms_norm(rq),
        "w_uq": jax.random.normal(ks[1], (rq, n_heads * (dn + dr)), jnp.float32) * s(rq),
        "w_dkv": jax.random.normal(ks[2], (d_model, rkv + dr), jnp.float32) * s(d_model),
        "kv_norm": layers.init_rms_norm(rkv),
        "w_uk": jax.random.normal(ks[3], (rkv, n_heads * dn), jnp.float32) * s(rkv),
        "w_uv": jax.random.normal(ks[4], (rkv, n_heads * dv), jnp.float32) * s(rkv),
        "w_o": jax.random.normal(ks[5], (n_heads * dv, d_model), jnp.float32) * s(n_heads * dv),
    }


def mla_qkv_full(p: dict, x: jax.Array, n_heads: int, cfg: MLAConfig,
                 positions: jax.Array, rope_theta: float):
    """Train/prefill path: returns q, k, v as (B, S, H, *) full tensors plus
    the latent (c_kv, k_rope) pair for cache seeding."""
    B, S, _ = x.shape
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ql = layers.rms_norm(x @ p["w_dq"].astype(x.dtype), p["q_norm"])
    q = (ql @ p["w_uq"].astype(x.dtype)).reshape(B, S, n_heads, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    ckv_full = x @ p["w_dkv"].astype(x.dtype)
    c_kv = layers.rms_norm(ckv_full[..., : cfg.kv_lora_rank], p["kv_norm"])
    k_rope = ckv_full[..., cfg.kv_lora_rank:]                    # (B, S, dr)

    cos, sin = layers.rope_angles(positions, dr, rope_theta)
    q_rope = layers.apply_rope(q_rope, cos, sin)
    k_rope = layers.apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]

    k_nope = (c_kv @ p["w_uk"].astype(x.dtype)).reshape(B, S, n_heads, dn)
    v = (c_kv @ p["w_uv"].astype(x.dtype)).reshape(B, S, n_heads, dv)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, n_heads, dr))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    return q, k, v, c_kv, k_rope


def mla_attention_full(p: dict, x: jax.Array, n_heads: int, cfg: MLAConfig,
                       positions: jax.Array, rope_theta: float,
                       block_k: int = 512, attn_impl: str = "flash_vjp"
                       ) -> jax.Array:
    from repro.models import flash as flash_mod
    q, k, v, _, _ = mla_qkv_full(p, x, n_heads, cfg, positions, rope_theta)
    # v's value dim (dv) differs from k's (dn+dr); both paths support that.
    if attn_impl == "flash_vjp":
        out = flash_mod.flash_attention(q, k, v, True, block_k)
    else:
        out = layers.blockwise_attention(q, k, v, causal=True, block_k=block_k)
    B, S = x.shape[:2]
    return out.reshape(B, S, -1) @ p["w_o"].astype(x.dtype)


def mla_decode_absorbed(p: dict, x: jax.Array, n_heads: int, cfg: MLAConfig,
                        c_kv_cache: jax.Array, k_rope_cache: jax.Array,
                        kv_len: jax.Array, rope_theta: float) -> jax.Array:
    """Absorbed single-token decode.

    x (B, 1, d); c_kv_cache (B, T, r_kv) — includes the current token already
    appended by the caller; k_rope_cache (B, T, dr); kv_len: valid length.
    """
    B = x.shape[0]
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    rkv = cfg.kv_lora_rank
    T = c_kv_cache.shape[1]

    ql = layers.rms_norm(x @ p["w_dq"].astype(x.dtype), p["q_norm"])
    q = (ql @ p["w_uq"].astype(x.dtype)).reshape(B, 1, n_heads, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    pos = (kv_len - 1)[None] if jnp.ndim(kv_len) == 0 else (kv_len - 1)
    cos, sin = layers.rope_angles(jnp.reshape(pos, (1, 1)), dr, rope_theta)
    q_rope = layers.apply_rope(q_rope, cos, sin)

    # absorb q_nope through W_uk:  (B,1,H,dn) x (H,rkv,dn) -> (B,1,H,rkv)
    w_uk = p["w_uk"].reshape(rkv, n_heads, dn).transpose(1, 0, 2)  # (H,rkv,dn)
    q_lat = jnp.einsum("bshd,hrd->bshr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))

    scores = (jnp.einsum("bshr,btr->bhst", q_lat,
                         c_kv_cache.astype(jnp.float32))
              + jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                           k_rope_cache.astype(jnp.float32)))
    scores = scores / jnp.sqrt(jnp.float32(dn + dr))
    mask = jnp.arange(T)[None, None, None, :] < kv_len
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)

    ctx_lat = jnp.einsum("bhst,btr->bshr", probs,
                         c_kv_cache.astype(jnp.float32))       # (B,1,H,rkv)
    w_uv = p["w_uv"].reshape(rkv, n_heads, dv).transpose(1, 0, 2)  # (H,rkv,dv)
    out = jnp.einsum("bshr,hrd->bshd", ctx_lat, w_uv.astype(jnp.float32))
    out = out.reshape(B, 1, n_heads * dv).astype(x.dtype)
    return out @ p["w_o"].astype(x.dtype)


def mla_latent_for_token(p: dict, x: jax.Array, cfg: MLAConfig,
                         pos: jax.Array, rope_theta: float):
    """(c_kv, k_rope) of a single new token (decode cache append)."""
    ckv_full = x @ p["w_dkv"].astype(x.dtype)
    c_kv = layers.rms_norm(ckv_full[..., : cfg.kv_lora_rank], p["kv_norm"])
    k_rope = ckv_full[..., cfg.kv_lora_rank:]
    dr = cfg.qk_rope_dim
    cos, sin = layers.rope_angles(jnp.reshape(pos, (1, 1)), dr, rope_theta)
    k_rope = layers.apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return c_kv, k_rope
