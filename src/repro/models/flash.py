"""Flash attention with a custom VJP (TPU-style block recomputation).

The naive ``lax.scan`` attention (layers.blockwise_attention) is memory-
light in FORWARD only: its autodiff backward saves the per-block softmax
numerators — an O(S*T) f32 tensor per layer that blows the per-chip HBM on
the 32k cells (dry-run baseline: 36-99 GB peak).  This module implements the
flash-attention gradient identity instead:

  D_i     = rowsum(dOut_i * Out_i)
  P_ij    = exp(q_i k_j - m_i) / l_i
  dV_j    = sum_i P_ij dOut_i
  dP_ij   = dOut_i . V_j
  dS_ij   = P_ij * (dP_ij - D_i) * scale
  dQ_i    = sum_j dS_ij K_j ;  dK_j = sum_i dS_ij Q_i

so the backward recomputes P block-by-block and saves only (out, m, l) —
O(S*d) residuals.  Combined with the per-layer remat of the scan-over-
layers, peak activation memory drops from O(L*S*T) to O(S*block_k).

Layout matches layers.blockwise_attention: q (B,S,nq,D), k/v (B,T,nkv,Dv),
GQA via nq = G*nkv.  Forward math is IDENTICAL to the naive path (same
scan), asserted by tests/test_flash.py against the dense oracle for both
values and grads.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def _blocks(x, nblk, bk):
    """(B, T, h, d) -> (nblk, B, bk, h, d)."""
    B, T, h, d = x.shape
    return x.reshape(B, nblk, bk, h, d).transpose(1, 0, 2, 3, 4)


def _fwd_scan(q, k, v, causal, block_k):
    B, S, nq, D = q.shape
    T, nkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = nq // nkv
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    nblk = -(-T // block_k)
    Tp = nblk * block_k
    if Tp != T:
        k = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    kb = _blocks(k, nblk, block_k)
    vb = _blocks(v, nblk, block_k)
    qg = q.reshape(B, S, nkv, G, D)
    q_pos = jnp.arange(S)[None, None, None, :, None]

    def step(carry, blk):
        m, l, acc, t0 = carry
        kblk, vblk = blk
        s = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                       kblk.astype(jnp.float32)) * scale
        kv_pos = (t0 + jnp.arange(block_k))[None, None, None, None, :]
        mask = kv_pos < T
        if causal:
            mask = mask & (kv_pos <= q_pos)
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(mask, jnp.exp(s - m_safe[..., None]), 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkd->bkgsd", p, vblk.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new, t0 + block_k), None

    m0 = jnp.full((B, nkv, G, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, nkv, G, S), jnp.float32)
    a0 = jnp.zeros((B, nkv, G, S, Dv), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, jnp.int32(0)),
                                     (kb, vb))
    l_safe = jnp.maximum(l, 1e-30)
    out = acc / l_safe[..., None]                     # (B,nkv,G,S,Dv)
    out_q = out.transpose(0, 3, 1, 2, 4).reshape(B, S, nq, Dv)
    return out_q.astype(q.dtype), (m, l_safe, out)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, causal: bool = True, block_k: int = 512):
    out, _ = _fwd_scan(q, k, v, causal, block_k)
    return out


def _flash_fwd(q, k, v, causal, block_k):
    out, (m, l, o5) = _fwd_scan(q, k, v, causal, block_k)
    return out, (q, k, v, o5, m, l)


def _flash_bwd(causal, block_k, res, dout):
    q, k, v, out5, m, l = res            # out5: (B,nkv,G,S,Dv) f32
    B, S, nq, D = q.shape
    T, nkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = nq // nkv
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    nblk = -(-T // block_k)
    Tp = nblk * block_k
    kp = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0))) if Tp != T else k
    vp = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0))) if Tp != T else v
    kb = _blocks(kp, nblk, block_k)
    vb = _blocks(vp, nblk, block_k)

    qg = q.reshape(B, S, nkv, G, D).astype(jnp.float32)
    do = dout.reshape(B, S, nkv, G, Dv).transpose(0, 2, 3, 1, 4) \
        .astype(jnp.float32)                       # (B,nkv,G,S,Dv)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    Dvec = jnp.sum(do * out5, axis=-1)             # (B,nkv,G,S)
    q_pos = jnp.arange(S)[None, None, None, :, None]

    def step(dq_acc, blk):
        kblk, vblk, t0 = blk                       # (B,bk,nkv,*), scalar
        s = jnp.einsum("bskgd,btkd->bkgst", qg,
                       kblk.astype(jnp.float32)) * scale
        kv_pos = (t0 + jnp.arange(block_k))[None, None, None, None, :]
        mask = kv_pos < T
        if causal:
            mask = mask & (kv_pos <= q_pos)
        p = jnp.where(mask, jnp.exp(s - m_safe[..., None]), 0.0)
        P = p / l[..., None]                       # true softmax probs
        dv_b = jnp.einsum("bkgst,bkgsd->btkd", P, do)
        dp = jnp.einsum("bkgsd,btkd->bkgst", do, vblk.astype(jnp.float32))
        ds = P * (dp - Dvec[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum("bkgst,btkd->bskgd", ds,
                                     kblk.astype(jnp.float32))
        dk_b = jnp.einsum("bkgst,bskgd->btkd", ds, qg)
        return dq_acc, (dk_b, dv_b)

    t0s = jnp.arange(nblk, dtype=jnp.int32) * block_k
    dq0 = jnp.zeros((B, S, nkv, G, D), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(step, dq0, (kb, vb, t0s))
    dq = dq.reshape(B, S, nq, D).astype(q.dtype)
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, Tp, nkv, D)[:, :T] \
        .astype(k.dtype)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, Tp, nkv, Dv)[:, :T] \
        .astype(v.dtype)
    return dq, dk, dv


flash_attention.defvjp(_flash_fwd, _flash_bwd)
