from repro.models.gnn import common, dimenet, equiformer, graphsage, meshgraphnet  # noqa: F401
