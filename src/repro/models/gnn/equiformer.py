"""Equiformer-V2 (Liao et al., arXiv:2306.12059) — eSCN-style equivariant
graph attention, SO(2)-restricted.

Assigned config: 12 layers, d_hidden=128, l_max=6, m_max=2, 8 heads.

Representation: each node carries real spherical-tensor features
``x (N, C, d)`` where C enumerates (l, m) with l <= l_max and |m| <=
min(l, m_max) — the eSCN m-restriction that cuts the O(L^6) tensor product
to O(L^3).  For l_max=6, m_max=2: C = 1+3+5+5+5+5+5 = 29.

Per-edge message (the eSCN convolution, z-alignment simplified to azimuthal
phase factorization — DESIGN.md §9):

  1. gather source features, rotate each (+m, -m) pair by -m*phi_e
     (phi = edge azimuth) — the SO(2) frame alignment;
  2. per-(l,m) SO(2) linear maps (complex pair mixing for m>0);
  3. radial-angular gains: MLP([bessel(d), cos^k(theta)]) -> per-l scale
     (this is where the polar dependence enters in lieu of full Wigner-D);
  4. 8-head graph attention: logits from the invariant (m=0) channels,
     scatter-softmax over incoming edges;
  5. rotate back (+m*phi), segment-sum into destination nodes.

Node update: per-l channel mixing + equivariant RMS norm (norm taken over
the m multiplet per (l, channel)) + gated FFN (invariant gate from l=0).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.gnn import common as C


@dataclasses.dataclass(frozen=True)
class EqV2Config:
    n_layers: int = 12
    d_hidden: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_radial: int = 8
    n_theta: int = 4
    d_in: int = 16
    n_out: int = 8
    cutoff: float = 5.0

    # ---- static coefficient bookkeeping (numpy; baked into the jaxpr)
    def coef_table(self):
        """Returns (l_of, m_of) int arrays over the C coefficients; order:
        for each l: m=0, then (+1,-1), (+2,-2) up to min(l, m_max)."""
        ls, ms = [], []
        for l in range(self.l_max + 1):
            ls.append(l); ms.append(0)
            for m in range(1, min(l, self.m_max) + 1):
                ls.extend([l, l]); ms.extend([m, -m])
        return np.array(ls), np.array(ms)

    @property
    def n_coef(self) -> int:
        return len(self.coef_table()[0])

    @property
    def n_l(self) -> int:
        return self.l_max + 1

    def pair_index(self):
        """Indices of (+m, -m) coefficient pairs: (plus, minus, m, l)."""
        ls, ms = self.coef_table()
        plus, minus, mm, ll = [], [], [], []
        for i in range(len(ls)):
            if ms[i] > 0:
                j = np.nonzero((ls == ls[i]) & (ms == -ms[i]))[0][0]
                plus.append(i); minus.append(j)
                mm.append(ms[i]); ll.append(ls[i])
        return (np.array(plus), np.array(minus), np.array(mm), np.array(ll))

    def m0_index(self):
        ls, ms = self.coef_table()
        idx = np.nonzero(ms == 0)[0]
        return idx, ls[idx]


def init_eqv2(key, cfg: EqV2Config) -> dict:
    d, nl = cfg.d_hidden, cfg.n_l
    n_pair = len(cfg.pair_index()[0])
    ks = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)

    def one_layer(k):
        kk = jax.random.split(k, 8)
        return {
            "so2_w0": jax.random.normal(kk[0], (nl, d, d), jnp.float32) * s,
            "so2_wr": jax.random.normal(kk[1], (n_pair, d, d), jnp.float32) * s,
            "so2_wi": jax.random.normal(kk[2], (n_pair, d, d), jnp.float32) * s,
            "radial": C.init_mlp(kk[3], [cfg.n_radial + cfg.n_theta, d, nl]),
            "attn": C.init_mlp(kk[4], [nl * d, d, cfg.n_heads]),
            "node_mix": jax.random.normal(kk[5], (nl, d, d), jnp.float32) * s,
            "ln_scale": jnp.ones((nl, d), jnp.float32),
            "ffn_gate": C.init_mlp(kk[6], [d, d, d]),
            "ffn_mix": jax.random.normal(kk[7], (nl, d, d), jnp.float32) * s,
            "ffn_ln": jnp.ones((nl, d), jnp.float32),
        }

    blocks = jax.vmap(one_layer)(jax.random.split(ks[0], cfg.n_layers))
    return {
        "embed": C.init_mlp(ks[1], [cfg.d_in, d, d]),
        "blocks": blocks,
        "head": C.init_mlp(ks[2], [d, d, cfg.n_out]),
    }


def _equiv_norm(x, l_of, scale, eps=1e-6):
    """Equivariant RMS norm: normalize per (node, l, channel) by the RMS over
    the m multiplet.  x (N, C, d); l_of (C,) static."""
    nl = int(l_of.max()) + 1
    sq = x.astype(jnp.float32) ** 2
    per_l = jax.ops.segment_sum(sq.swapaxes(0, 1), jnp.asarray(l_of),
                                num_segments=nl)            # (nl, N, d)
    cnt = np.bincount(l_of, minlength=nl).astype(np.float32)
    rms = jnp.sqrt(per_l / cnt[:, None, None] + eps)        # (nl, N, d)
    denom = rms[jnp.asarray(l_of)].swapaxes(0, 1)           # (N, C, d)
    return (x / denom * scale[jnp.asarray(l_of)][None]).astype(x.dtype)


def eqv2_forward(params, feats, pos, src, dst, cfg: EqV2Config,
                 edge_mask=None) -> jax.Array:
    n = feats.shape[0]
    l_of, _ = cfg.coef_table()
    plus, minus, pm, pl = cfg.pair_index()
    m0_idx, m0_l = cfg.m0_index()
    nc, nl, d, H = cfg.n_coef, cfg.n_l, cfg.d_hidden, cfg.n_heads

    vec, dist = C.edge_vectors(pos, src, dst)
    # edge angles: theta (polar, vs z), phi (azimuth)
    cos_t = vec[:, 2] / jnp.maximum(dist, 1e-9)
    phi = jnp.arctan2(vec[:, 1], vec[:, 0] + 1e-12)
    rbf = C.radial_bessel(dist, cfg.n_radial, cfg.cutoff) \
        * C.envelope(dist, cfg.cutoff)[:, None]
    tbf = cos_t[:, None] ** jnp.arange(cfg.n_theta, dtype=jnp.float32)
    rad_in = jnp.concatenate([rbf, tbf], axis=-1)           # (E, n_rad+n_th)

    cph = jnp.cos(pm[None, :] * phi[:, None])               # (E, n_pair)
    sph = jnp.sin(pm[None, :] * phi[:, None])

    # initial embedding: invariant features in the l=0 slot
    x = jnp.zeros((n, nc, d), feats.dtype)
    x = x.at[:, 0, :].set(C.mlp(params["embed"], feats))

    def layer(x, blk):
        msg = x[src]                                        # (E, C, d)
        # --- SO(2) frame alignment (rotate pairs by -m phi)
        xp, xm = msg[:, plus], msg[:, minus]                # (E, P, d)
        rp = cph[..., None] * xp + sph[..., None] * xm
        rm = -sph[..., None] * xp + cph[..., None] * xm
        x0 = msg[:, m0_idx]                                 # (E, nl, d)
        # --- per-(l,m) SO(2) linear
        y0 = jnp.einsum("eld,ldf->elf", x0, blk["so2_w0"].astype(x.dtype))
        yp = (jnp.einsum("epd,pdf->epf", rp, blk["so2_wr"].astype(x.dtype))
              - jnp.einsum("epd,pdf->epf", rm, blk["so2_wi"].astype(x.dtype)))
        ym = (jnp.einsum("epd,pdf->epf", rp, blk["so2_wi"].astype(x.dtype))
              + jnp.einsum("epd,pdf->epf", rm, blk["so2_wr"].astype(x.dtype)))
        # --- radial-angular gains per l
        g = C.mlp(blk["radial"], rad_in)                    # (E, nl)
        y0 = y0 * g[..., None]
        yp = yp * g[:, pl][..., None]
        ym = ym * g[:, pl][..., None]
        # --- attention from invariants
        logits = C.mlp(blk["attn"], y0.reshape(-1, nl * d)) \
            / np.sqrt(d / H)                                # (E, H)
        alpha = jax.vmap(lambda lg: C.segment_softmax(lg, dst, n, edge_mask),
                         in_axes=1, out_axes=1)(logits)     # (E, H)

        def weight_heads(y):                                # (E, K, d)
            yh = y.reshape(y.shape[0], y.shape[1], H, d // H)
            return (yh * alpha[:, None, :, None]).reshape(y.shape)

        y0, yp, ym = weight_heads(y0), weight_heads(yp), weight_heads(ym)
        # --- rotate back (+m phi)
        bp = cph[..., None] * yp - sph[..., None] * ym
        bm = sph[..., None] * yp + cph[..., None] * ym
        out = jnp.zeros((msg.shape[0], nc, d), x.dtype)
        out = out.at[:, m0_idx].set(y0)
        out = out.at[:, plus].set(bp)
        out = out.at[:, minus].set(bm)
        if edge_mask is not None:
            out = jnp.where(edge_mask[:, None, None], out, 0)
        agg = jax.ops.segment_sum(out, dst, num_segments=n)  # (N, C, d)
        # --- node update: per-l mixing (weight gathered per coefficient so
        # each x[:, c] is multiplied once, not nl times) + equivariant norm
        w_mix = blk["node_mix"].astype(x.dtype)[jnp.asarray(l_of)]  # (C, d, d)
        mixed = jnp.einsum("ncd,cdf->ncf", agg, w_mix)
        x = x + _equiv_norm(mixed, l_of, blk["ln_scale"])
        # --- gated FFN: invariant gate from l=0 broadcast over coefficients
        gate = jax.nn.silu(C.mlp(blk["ffn_gate"], x[:, 0, :]))  # (N, d)
        w_ffn = blk["ffn_mix"].astype(x.dtype)[jnp.asarray(l_of)]
        val = jnp.einsum("ncd,cdf->ncf", x, w_ffn)
        x = x + _equiv_norm(val * gate[:, None, :], l_of, blk["ffn_ln"])
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(layer, prevent_cse=False),
                        x, params["blocks"])
    return C.mlp(params["head"], x[:, 0, :])                # invariant readout


def eqv2_node_loss(params, batch, cfg: EqV2Config):
    out = eqv2_forward(params, batch["feats"], batch["pos"], batch["src"],
                       batch["dst"], cfg, batch.get("edge_mask"))
    return C.node_classification_loss(out, batch["labels"],
                                      batch["label_mask"])


def eqv2_graph_loss(params, batch, cfg: EqV2Config):
    def one(feats, pos, src, dst, em):
        out = eqv2_forward(params, feats, pos, src, dst, cfg, em)
        return jnp.sum(C.masked_node_mean(out, None))

    pred = jax.vmap(one)(batch["feats"], batch["pos"], batch["src"],
                         batch["dst"], batch["edge_mask"])
    return C.graph_regression_loss(pred, batch["target"])
