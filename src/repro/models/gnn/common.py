"""Shared GNN substrate: MLPs, segment aggregators, bases, graph containers.

JAX has no native sparse message-passing (BCOO only) — per the assignment,
message passing here is built from ``jnp.take`` (gather) over an edge index
plus ``jax.ops.segment_sum`` / ``segment_max`` scatters.  This is the same
gather/scatter substrate the SSSP-Del engine uses (core/relax.py), which is
exactly why these four archs share the paper's infrastructure.

Uniform graph form (all four archs, all four shapes):

  * flat COO: feats (N,F) [+ pos (N,3)], src/dst (E,) int32, edge_mask (E,)
    — covers full_graph_sm, ogb_products and minibatch_lg (the host-side
    neighbor sampler in graphs/sampler.py emits a padded subgraph in this
    exact form);
  * batched molecules: the same per graph, vmapped over a leading B dim.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp


# ------------------------------------------------------------------ MLPs ----

def init_mlp(key, dims: Sequence[int], *, final_bias: bool = True) -> dict:
    ks = jax.random.split(key, len(dims) - 1)
    ws, bs = [], []
    for i, k in enumerate(ks):
        ws.append(jax.random.normal(k, (dims[i], dims[i + 1]), jnp.float32)
                  / jnp.sqrt(dims[i]))
        bs.append(jnp.zeros((dims[i + 1],), jnp.float32))
    return {"w": ws, "b": bs}


def mlp(params: dict, x: jax.Array, *, act=jax.nn.silu,
        final_act: bool = False) -> jax.Array:
    n = len(params["w"])
    for i, (w, b) in enumerate(zip(params["w"], params["b"])):
        x = x @ w.astype(x.dtype) + b.astype(x.dtype)
        if i < n - 1 or final_act:
            x = act(x)
    return x


def init_layernorm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]).astype(dt)


# ----------------------------------------------------------- aggregators ----

def segment_sum(vals, dst, n, mask=None):
    if mask is not None:
        vals = jnp.where(mask.reshape(mask.shape + (1,) * (vals.ndim - 1)),
                         vals, 0)
    return jax.ops.segment_sum(vals, dst, num_segments=n)


def segment_mean(vals, dst, n, mask=None):
    s = segment_sum(vals, dst, n, mask)
    ones = jnp.ones(vals.shape[0], vals.dtype) if mask is None \
        else mask.astype(vals.dtype)
    cnt = jax.ops.segment_sum(ones, dst, num_segments=n)
    return s / jnp.maximum(cnt, 1.0).reshape((n,) + (1,) * (vals.ndim - 1))


def segment_max(vals, dst, n, mask=None):
    neg = jnp.finfo(vals.dtype).min
    if mask is not None:
        vals = jnp.where(mask.reshape(mask.shape + (1,) * (vals.ndim - 1)),
                         vals, neg)
    out = jax.ops.segment_max(vals, dst, num_segments=n)
    return jnp.maximum(out, 0.0)  # empty segments -> 0, and clamp -inf


def segment_softmax(logits, dst, n, mask=None):
    """Numerically-stable scatter softmax (graph attention)."""
    neg = jnp.float32(-1e30)
    lg = logits.astype(jnp.float32)
    if mask is not None:
        lg = jnp.where(mask, lg, neg)
    mx = jax.ops.segment_max(lg, dst, num_segments=n)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    ex = jnp.exp(lg - mx[dst])
    if mask is not None:
        ex = jnp.where(mask, ex, 0.0)
    den = jax.ops.segment_sum(ex, dst, num_segments=n)
    return (ex / jnp.maximum(den[dst], 1e-30)).astype(logits.dtype)


# ------------------------------------------------------------------ bases ----

def radial_bessel(d: jax.Array, n_radial: int, cutoff: float) -> jax.Array:
    """DimeNet's radial Bessel basis: sqrt(2/c)·sin(nπd/c)/d (d>0)."""
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    d = jnp.maximum(d.astype(jnp.float32), 1e-9)[..., None]
    return (jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d / cutoff) / d)


def envelope(d: jax.Array, cutoff: float, p: int = 6) -> jax.Array:
    """Smooth polynomial cutoff envelope u(d) (DimeNet eq. 8 family)."""
    x = jnp.clip(d.astype(jnp.float32) / cutoff, 0.0, 1.0)
    a = -(p + 1) * (p + 2) / 2.0
    b = p * (p + 2.0)
    c = -p * (p + 1) / 2.0
    return 1.0 + a * x ** p + b * x ** (p + 1) + c * x ** (p + 2)


def angular_fourier(cos_angle: jax.Array, n_spherical: int) -> jax.Array:
    """Angular basis cos(l·α), l = 0..n_spherical-1 — the Chebyshev form of
    DimeNet's spherical harmonics Y_l0(α) (published functional family with
    fixed frequencies; see DESIGN.md §9)."""
    ang = jnp.arccos(jnp.clip(cos_angle.astype(jnp.float32), -1.0, 1.0))
    l = jnp.arange(n_spherical, dtype=jnp.float32)
    return jnp.cos(ang[..., None] * l)


# ------------------------------------------------------- geometry helpers ----

def edge_vectors(pos: jax.Array, src: jax.Array, dst: jax.Array):
    """Returns (vec (E,3), dist (E,)) for edges src->dst."""
    v = pos[dst] - pos[src]
    d = jnp.sqrt(jnp.maximum(jnp.sum(v * v, axis=-1), 1e-12))
    return v, d


def masked_node_mean(x: jax.Array, node_mask: jax.Array | None) -> jax.Array:
    """Graph readout: mean over valid nodes. x (N, d) -> (d,)."""
    if node_mask is None:
        return jnp.mean(x, axis=0)
    m = node_mask.astype(x.dtype)[:, None]
    return jnp.sum(x * m, axis=0) / jnp.maximum(jnp.sum(m), 1.0)


# ------------------------------------------------------------- loss heads ----

def node_classification_loss(logits: jax.Array, labels: jax.Array,
                             mask: jax.Array) -> tuple[jax.Array, dict]:
    """Masked softmax CE over nodes; labels int32, mask bool."""
    lg = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    safe = jnp.maximum(labels, 0)
    gold = jnp.take_along_axis(lg, safe[:, None], axis=-1)[:, 0]
    m = (mask & (labels >= 0)).astype(jnp.float32)
    n = jnp.maximum(jnp.sum(m), 1.0)
    loss = jnp.sum((logz - gold) * m) / n
    acc = jnp.sum((jnp.argmax(lg, -1) == labels) * m) / n
    return loss, {"loss": loss, "acc": acc}


def graph_regression_loss(pred: jax.Array, target: jax.Array
                          ) -> tuple[jax.Array, dict]:
    err = (pred.astype(jnp.float32) - target.astype(jnp.float32))
    loss = jnp.mean(err * err)
    return loss, {"loss": loss, "mae": jnp.mean(jnp.abs(err))}
