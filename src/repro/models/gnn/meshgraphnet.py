"""MeshGraphNet (Pfaff et al., arXiv:2010.03409): Encode-Process-Decode.

Assigned config: 15 message-passing layers, d_hidden=128, sum aggregation,
2-layer MLPs (+LayerNorm after every MLP, residual node/edge updates).

Edge features are geometric: [pos_dst - pos_src, |pos_dst - pos_src|] (4
features) — for non-mesh shapes (cora / ogbn-products / sampled reddit) the
data layer supplies synthetic coordinates; see DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import common as C


@dataclasses.dataclass(frozen=True)
class MGNConfig:
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2      # hidden layers per MLP
    d_in: int = 16           # node input features
    n_out: int = 8           # node output dim (e.g. classes or dynamics dim)
    aggregator: str = "sum"


def _mlp_dims(d_in: int, d_h: int, d_out: int, n_hidden: int) -> list[int]:
    return [d_in] + [d_h] * n_hidden + [d_out]


def init_mgn(key, cfg: MGNConfig) -> dict:
    ks = jax.random.split(key, 5)
    d = cfg.d_hidden
    enc_n = C.init_mlp(ks[0], _mlp_dims(cfg.d_in, d, d, cfg.mlp_layers))
    enc_e = C.init_mlp(ks[1], _mlp_dims(4, d, d, cfg.mlp_layers))
    dec = C.init_mlp(ks[2], _mlp_dims(d, d, cfg.n_out, cfg.mlp_layers))

    def one_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "edge_mlp": C.init_mlp(k1, _mlp_dims(3 * d, d, d, cfg.mlp_layers)),
            "edge_ln": C.init_layernorm(d),
            "node_mlp": C.init_mlp(k2, _mlp_dims(2 * d, d, d, cfg.mlp_layers)),
            "node_ln": C.init_layernorm(d),
        }

    layer_keys = jax.random.split(ks[3], cfg.n_layers)
    blocks = jax.vmap(one_layer)(layer_keys)
    return {"enc_n": enc_n, "enc_e": enc_e, "enc_n_ln": C.init_layernorm(d),
            "enc_e_ln": C.init_layernorm(d), "blocks": blocks, "dec": dec}


def mgn_forward(params, feats, pos, src, dst, cfg: MGNConfig,
                edge_mask=None) -> jax.Array:
    """feats (N, d_in); pos (N, 3); src/dst (E,) -> node outputs (N, n_out)."""
    n = feats.shape[0]
    vec, dist = C.edge_vectors(pos, src, dst)
    e_in = jnp.concatenate([vec, dist[:, None]], axis=-1).astype(feats.dtype)

    h = C.layernorm(params["enc_n_ln"], C.mlp(params["enc_n"], feats))
    e = C.layernorm(params["enc_e_ln"], C.mlp(params["enc_e"], e_in))

    agg = {"sum": C.segment_sum, "mean": C.segment_mean,
           "max": C.segment_max}[cfg.aggregator]

    def body(carry, blk):
        h, e = carry
        # edge update: e' = e + LN(MLP([e, h_src, h_dst]))
        msg_in = jnp.concatenate([e, h[src], h[dst]], axis=-1)
        e = e + C.layernorm(blk["edge_ln"], C.mlp(blk["edge_mlp"], msg_in))
        # node update: h' = h + LN(MLP([h, sum_in e']))
        inc = agg(e, dst, n, edge_mask)
        h = h + C.layernorm(blk["node_ln"],
                            C.mlp(blk["node_mlp"],
                                  jnp.concatenate([h, inc], axis=-1)))
        return (h, e), None

    (h, _), _ = jax.lax.scan(jax.checkpoint(body, prevent_cse=False),
                             (h, e), params["blocks"])
    return C.mlp(params["dec"], h)


def mgn_node_loss(params, batch, cfg: MGNConfig):
    out = mgn_forward(params, batch["feats"], batch["pos"], batch["src"],
                      batch["dst"], cfg, batch.get("edge_mask"))
    return C.node_classification_loss(out, batch["labels"], batch["label_mask"])


def mgn_graph_loss(params, batch, cfg: MGNConfig):
    """Batched molecules: vmap the flat forward; sum-pool -> scalar."""

    def one(feats, pos, src, dst, emask):
        out = mgn_forward(params, feats, pos, src, dst, cfg, emask)
        return jnp.sum(C.masked_node_mean(out, None))

    pred = jax.vmap(one)(batch["feats"], batch["pos"], batch["src"],
                         batch["dst"], batch["edge_mask"])
    return C.graph_regression_loss(pred, batch["target"])
