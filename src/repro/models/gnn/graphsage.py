"""GraphSAGE (Hamilton et al., arXiv:1706.02216), mean aggregator.

Assigned config: 2 layers, d_hidden=128, sample sizes 25-10 (training-time
neighbor fanout — realized by the host-side sampler in graphs/sampler.py,
which emits a padded COO subgraph consumed by the same forward as the
full-graph shapes).

Layer: h'_v = ReLU(W_self h_v + W_nbr mean_{u in N(v)} h_u), L2-normalized
(as in the paper).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import common as C


@dataclasses.dataclass(frozen=True)
class SAGEConfig:
    n_layers: int = 2
    d_hidden: int = 128
    d_in: int = 602
    n_out: int = 41
    sample_sizes: tuple[int, ...] = (25, 10)
    normalize: bool = True


def init_sage(key, cfg: SAGEConfig) -> dict:
    layers = []
    d_prev = cfg.d_in
    ks = jax.random.split(key, cfg.n_layers + 1)
    for i in range(cfg.n_layers):
        d_out = cfg.d_hidden
        k1, k2 = jax.random.split(ks[i])
        layers.append({
            "w_self": jax.random.normal(k1, (d_prev, d_out), jnp.float32)
                      / jnp.sqrt(d_prev),
            "w_nbr": jax.random.normal(k2, (d_prev, d_out), jnp.float32)
                     / jnp.sqrt(d_prev),
            "b": jnp.zeros((d_out,), jnp.float32),
        })
        d_prev = d_out
    head = jax.random.normal(ks[-1], (d_prev, cfg.n_out), jnp.float32) \
        / jnp.sqrt(d_prev)
    return {"layers": layers, "head": head}


def sage_forward(params, feats, src, dst, cfg: SAGEConfig,
                 edge_mask=None) -> jax.Array:
    """Full-graph/subgraph forward over COO edges src->dst."""
    n = feats.shape[0]
    h = feats
    for lyr in params["layers"]:
        nbr = C.segment_mean(h[src], dst, n, edge_mask)
        h = jax.nn.relu(h @ lyr["w_self"].astype(h.dtype)
                        + nbr @ lyr["w_nbr"].astype(h.dtype)
                        + lyr["b"].astype(h.dtype))
        if cfg.normalize:
            h = h / jnp.maximum(
                jnp.linalg.norm(h.astype(jnp.float32), axis=-1,
                                keepdims=True), 1e-6).astype(h.dtype)
    return h @ params["head"].astype(h.dtype)


def sage_node_loss(params, batch, cfg: SAGEConfig):
    out = sage_forward(params, batch["feats"], batch["src"], batch["dst"],
                       cfg, batch.get("edge_mask"))
    return C.node_classification_loss(out, batch["labels"],
                                      batch["label_mask"])


def sage_graph_loss(params, batch, cfg: SAGEConfig):
    def one(feats, src, dst, emask):
        out = sage_forward(params, feats, src, dst, cfg, emask)
        return jnp.sum(C.masked_node_mean(out, None))

    pred = jax.vmap(one)(batch["feats"], batch["src"], batch["dst"],
                         batch["edge_mask"])
    return C.graph_regression_loss(pred, batch["target"])
