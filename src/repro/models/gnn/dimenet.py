"""DimeNet (Klicpera et al., arXiv:2003.03123): directional message passing.

Assigned config: 6 interaction blocks, d_hidden=128, n_bilinear=8,
n_spherical=7, n_radial=6.

Messages live on *edges* m_ji; the interaction block refines them with
two-hop (triplet) terms k->j->i weighted by a joint radial x angular basis
through a bilinear tensor (the kernel-taxonomy "triplet gather" regime).
Triplet index lists (t_kj, t_ji) are precomputed host-side
(graphs/triplets.py) with a static padded budget — on mega-graphs
(ogb_products) the budget caps/samples triplets per edge (DESIGN.md §9).

Generic-graph adaptation: node "atom types" are replaced by an MLP over the
node features; positions come from the data layer (synthetic for citation
graphs).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import common as C


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    d_in: int = 16
    n_out: int = 8
    cutoff: float = 5.0
    n_res_pre: int = 1          # residual MLPs before the skip
    n_res_post: int = 2         # after


def _res_block(key, d):
    return C.init_mlp(key, [d, d, d])


def init_dimenet(key, cfg: DimeNetConfig) -> dict:
    d = cfg.d_hidden
    ks = jax.random.split(key, 8)
    emb = {
        "node": C.init_mlp(ks[0], [cfg.d_in, d]),
        "rbf": C.init_mlp(ks[1], [cfg.n_radial, d], final_bias=False),
        "edge": C.init_mlp(ks[2], [3 * d, d]),
    }

    def one_block(k):
        kk = jax.random.split(k, 8)
        return {
            "w_rbf": C.init_mlp(kk[0], [cfg.n_radial, d], final_bias=False),
            "w_sbf": C.init_mlp(kk[1], [cfg.n_spherical * cfg.n_radial,
                                        cfg.n_bilinear], final_bias=False),
            "w_kj": C.init_mlp(kk[2], [d, d]),
            "w_ji": C.init_mlp(kk[3], [d, d]),
            "bilinear": jax.random.normal(
                kk[4], (cfg.n_bilinear, d, d), jnp.float32) / jnp.sqrt(d),
            "res_pre": jax.vmap(lambda q: _res_block(q, d))(
                jax.random.split(kk[5], cfg.n_res_pre)),
            "w_skip": C.init_mlp(kk[6], [d, d]),
            "res_post": jax.vmap(lambda q: _res_block(q, d))(
                jax.random.split(kk[7], cfg.n_res_post)),
        }

    blocks = jax.vmap(one_block)(jax.random.split(ks[3], cfg.n_blocks))

    def one_out(k):
        k1, k2 = jax.random.split(k)
        return {
            "w_rbf": C.init_mlp(k1, [cfg.n_radial, d], final_bias=False),
            "mlp": C.init_mlp(k2, [d, d, cfg.n_out]),
        }

    outs = jax.vmap(one_out)(jax.random.split(ks[4], cfg.n_blocks + 1))
    return {"emb": emb, "blocks": blocks, "outs": outs}


def _res(stack, x):
    """Apply a stacked set of residual MLPs (leading dim = count)."""
    n = jax.tree.leaves(stack)[0].shape[0]
    for i in range(n):
        p = jax.tree.map(lambda a: a[i], stack)
        x = x + C.mlp(p, x, final_act=False)
    return x


def dimenet_forward(params, feats, pos, src, dst, t_kj, t_ji, cfg: DimeNetConfig,
                    edge_mask=None, triplet_mask=None) -> jax.Array:
    """Returns per-node outputs (N, n_out).

    src/dst (E,): directed edges j->i (src=j, dst=i); messages m indexed by
    edge.  t_kj/t_ji (T,): triplet edge indices — edge (k->j) feeding edge
    (j->i).
    """
    n = feats.shape[0]
    vec, dist = C.edge_vectors(pos, src, dst)
    u = C.envelope(dist, cfg.cutoff)
    rbf = C.radial_bessel(dist, cfg.n_radial, cfg.cutoff) * u[:, None]

    # triplet angle at j between edges (k->j) and (j->i):
    #   a = vec(j->i), b = -vec(k->j)
    a = vec[t_ji]
    b = -vec[t_kj]
    cos_ang = jnp.sum(a * b, -1) / jnp.maximum(
        jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(b, axis=-1), 1e-9)
    ang = C.angular_fourier(cos_ang, cfg.n_spherical)          # (T, n_sph)
    sbf = (ang[:, :, None] * rbf[t_kj][:, None, :]).reshape(
        -1, cfg.n_spherical * cfg.n_radial)                    # (T, n_sph*n_rad)

    h = C.mlp(params["emb"]["node"], feats)                    # (N, d)
    rbf_e = C.mlp(params["emb"]["rbf"], rbf)
    m = C.mlp(params["emb"]["edge"],
              jnp.concatenate([h[src], h[dst], rbf_e], axis=-1))
    m = jax.nn.silu(m)
    if edge_mask is not None:
        m = jnp.where(edge_mask[:, None], m, 0.0)

    def out_block(p, m_edges, rbf_, i_dst):
        g = C.mlp(p["w_rbf"], rbf_) * m_edges
        node = C.segment_sum(g, i_dst, n, edge_mask)
        return C.mlp(p["mlp"], node)

    out = out_block(jax.tree.map(lambda a: a[0], params["outs"]), m, rbf, dst)

    def body(m, xs):
        blk, out_p = xs
        rbf_g = C.mlp(blk["w_rbf"], rbf)                       # (E, d)
        sbf_g = C.mlp(blk["w_sbf"], sbf)                       # (T, n_bil)
        x_ji = jax.nn.silu(C.mlp(blk["w_ji"], m))
        x_kj = jax.nn.silu(C.mlp(blk["w_kj"], m)) * rbf_g      # (E, d)
        xk = x_kj[t_kj]                                        # (T, d)
        tri = jnp.einsum("tb,tf,bfh->th", sbf_g, xk, blk["bilinear"])
        if triplet_mask is not None:
            tri = jnp.where(triplet_mask[:, None], tri, 0.0)
        agg = C.segment_sum(tri, t_ji, m.shape[0])             # (E, d)
        mm = x_ji + agg
        mm = _res(blk["res_pre"], mm)
        mm = m + C.mlp(blk["w_skip"], jax.nn.silu(mm))
        mm = _res(blk["res_post"], mm)
        if edge_mask is not None:
            mm = jnp.where(edge_mask[:, None], mm, 0.0)
        o = out_block(out_p, mm, rbf, dst)
        return mm, o

    outs_rest = jax.tree.map(lambda a: a[1:], params["outs"])
    m, os_ = jax.lax.scan(jax.checkpoint(body, prevent_cse=False),
                          m, (params["blocks"], outs_rest))
    return out + jnp.sum(os_, axis=0)


def dimenet_node_loss(params, batch, cfg: DimeNetConfig):
    out = dimenet_forward(params, batch["feats"], batch["pos"], batch["src"],
                          batch["dst"], batch["t_kj"], batch["t_ji"], cfg,
                          batch.get("edge_mask"), batch.get("triplet_mask"))
    return C.node_classification_loss(out, batch["labels"],
                                      batch["label_mask"])


def dimenet_graph_loss(params, batch, cfg: DimeNetConfig):
    def one(feats, pos, src, dst, tkj, tji, em, tm):
        out = dimenet_forward(params, feats, pos, src, dst, tkj, tji, cfg,
                              em, tm)
        return jnp.sum(jnp.sum(out, axis=0))

    pred = jax.vmap(one)(batch["feats"], batch["pos"], batch["src"],
                         batch["dst"], batch["t_kj"], batch["t_ji"],
                         batch["edge_mask"], batch["triplet_mask"])
    return C.graph_regression_loss(pred, batch["target"])
