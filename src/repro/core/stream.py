"""Shared event-stream plumbing for the dynamic engines.

Both the single-device ``SSSPDelEngine`` (core/engine.py) and the sharded
``ShardedSSSPDelEngine`` (core/dist_engine.py) are host orchestrators over
jitted device epochs that consume the same ``EventLog`` stream.  Everything
that is *stream* logic rather than *epoch* logic lives here:

  * the driver loop (``ingest_log``) that coalesces the log into runs and
    dispatches ADD/DEL batches and QUERY markers;
  * the ``QueryResult`` record returned at every QUERY marker;
  * lazy device-scalar stats counters (DESIGN.md §2.4: the ingest loop never
    blocks on a device value — rounds/messages accumulate on device and are
    only read back inside ``query()``);
  * the paper's §5.4 predecessor-stability metric;
  * the device-scalar stat accumulators the epoch results fold into.

Subclasses implement ``_ingest_adds`` / ``_ingest_dels`` / ``query`` and keep
``_dev_rounds`` / ``_dev_messages`` as device scalars.  Layout-specific work
lives one layer down, behind the ``RelaxBackend`` protocol
(core/backends/, DESIGN.md §7): the single-device engine folds its
backend's epoch stats through ``_accumulate_relax`` /
``_accumulate_delete``; the sharded engine threads the same counters
through its shard_map epochs as replicated device scalars.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev


@dataclasses.dataclass
class QueryResult:
    dist: np.ndarray
    parent: np.ndarray
    latency_s: float
    epoch_stats: dict[str, Any]


class StreamEngineBase:
    """Host-side driver over jitted device epochs; subclasses own the state."""

    def __init__(self) -> None:
        # batch counters (host-side; no device source)
        self.n_epochs = 0
        self.n_adds = 0
        self.n_dels = 0
        # round/message counters live ON DEVICE; read back lazily at query()
        self._dev_rounds = jnp.int32(0)
        self._dev_messages = jnp.int32(0)
        self._last_parent: np.ndarray | None = None

    # --------------------------------------------------------- lazy counters
    @property
    def n_rounds(self) -> int:
        return int(jax.device_get(self._dev_rounds))

    @property
    def n_messages(self) -> int:
        return int(jax.device_get(self._dev_messages))

    def _stream_stats(self) -> dict[str, Any]:
        return {
            "epochs": self.n_epochs, "rounds": self.n_rounds,
            "messages": self.n_messages, "adds": self.n_adds,
            "dels": self.n_dels,
        }

    def _accumulate_relax(self, stats) -> None:
        """Fold one relaxation epoch's ``RelaxStats`` into the device
        scalars (lazy add — no host sync)."""
        self._dev_rounds = self._dev_rounds + stats.rounds
        self._dev_messages = self._dev_messages + stats.messages

    def _accumulate_delete(self, dstats) -> None:
        """Fold one deletion epoch's ``DeleteStats`` into the device
        scalars; ``affected`` counts as messages (the SetToInfinity
        deliveries), matching the sharded epochs' accounting."""
        self._dev_rounds = (self._dev_rounds + dstats.invalidation_rounds
                            + dstats.recompute_rounds)
        self._dev_messages = (self._dev_messages + dstats.recompute_messages
                              + dstats.affected)

    # ------------------------------------------------------------- interface
    def _deletion_groups(self, batch: ev.EventBatch
                         ) -> list[tuple[np.ndarray, np.ndarray]]:
        """Paper-faithful: one stop-the-world epoch PER deletion;
        ``batch_deletions=True`` coalesces the whole run into one epoch
        (union of affected subtrees — DESIGN.md §3).  Both engines must
        group identically or the equivalence contract breaks."""
        if self.cfg.batch_deletions:
            return [(batch.src, batch.dst)]
        return [(batch.src[i:i + 1], batch.dst[i:i + 1])
                for i in range(len(batch.src))]

    def _ingest_adds(self, batch: ev.EventBatch) -> None:
        raise NotImplementedError

    def _ingest_dels(self, batch: ev.EventBatch) -> None:
        raise NotImplementedError

    def query(self) -> QueryResult:
        raise NotImplementedError

    # ---------------------------------------------------------------- stream
    def ingest_log(self, log: ev.EventLog,
                   on_query: Callable[[QueryResult], None] | None = None
                   ) -> list[QueryResult]:
        """Drive the engine over an event log; returns query results."""
        results: list[QueryResult] = []
        for batch in log.runs():
            if batch.kind == ev.ADD:
                self._ingest_adds(batch)
            elif batch.kind == ev.DEL:
                self._ingest_dels(batch)
            else:
                res = self.query()
                results.append(res)
                if on_query is not None:
                    on_query(res)
        return results

    # ------------------------------------------------------------- stability
    def stability_vs_prev(self, parent: np.ndarray) -> float:
        """Paper §5.4: fraction of vertices whose predecessor is unchanged
        (over vertices present in both results)."""
        if self._last_parent is None:
            self._last_parent = parent.copy()
            return 1.0
        prev = self._last_parent
        both = (prev >= 0) & (parent >= 0)
        frac = float(np.mean(prev[both] == parent[both])) if both.any() else 1.0
        self._last_parent = parent.copy()
        return frac
