"""Shared event-stream plumbing for the dynamic engines.

Both the single-device ``SSSPDelEngine`` (core/engine.py) and the sharded
``ShardedSSSPDelEngine`` (core/dist_engine.py) are host orchestrators over
jitted device epochs that consume the same ``EventLog`` stream.  Everything
that is *stream* logic rather than *epoch* logic lives here:

  * the driver loop (``ingest_log``) that coalesces the log into runs and
    dispatches ADD/DEL batches and QUERY markers;
  * the ``QueryResult`` record returned at every QUERY marker, with its
    wall-clock ``latency_s`` timed HERE (the template ``query()`` wraps the
    engine's ``_snapshot`` readback) so both engines measure result latency
    identically — the serving harness's latency metric (DESIGN.md §8);
  * multi-source lane routing (DESIGN.md §8): engines constructed with
    ``sources=(s0, s1, ...)`` maintain stacked ``[S, N]`` dist/parent state;
    ``query(source=s)`` reads back ONE lane, ``query()`` the full stack,
    and QUERY stream markers carry their requested source;
  * lazy device-scalar stats counters (DESIGN.md §2.4: the ingest loop never
    blocks on a device value — rounds/messages accumulate on device and are
    only read back inside ``query()``; in batched mode they are ``[S]``
    device vectors, one independent counter per source);
  * the paper's §5.4 predecessor-stability metric;
  * the device-scalar stat accumulators the epoch results fold into.

Subclasses implement ``_ingest_adds`` / ``_ingest_dels`` / ``_snapshot`` and
keep ``_dev_rounds`` / ``_dev_messages`` as device scalars (or ``[S]``
vectors).  Layout-specific work lives one layer down, behind the
``RelaxBackend`` protocol (core/backends/, DESIGN.md §7): the single-device
engine folds its backend's epoch stats through ``_accumulate_relax`` /
``_accumulate_delete``; the sharded engine threads the same counters
through its shard_map epochs as replicated device scalars.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev
from repro.obs import EngineObs, WatchdogConfig
from repro.obs import hist as hist_mod


@dataclasses.dataclass
class QueryResult:
    dist: np.ndarray      # f32[N] (lane or single-source) or f32[S, N]
    parent: np.ndarray    # i32 of the same shape
    latency_s: float      # wall-clock snapshot latency (timed in query())
    epoch_stats: dict[str, Any]
    source: int | None = None   # the lane's source for a routed query


class StreamEngineBase:
    """Host-side driver over jitted device epochs; subclasses own the state."""

    def __init__(self, sources: tuple[int, ...] | None = None, *,
                 observability: bool = False,
                 flight_capacity: int = 128,
                 watchdog: "WatchdogConfig | None" = None) -> None:
        # observability layer (DESIGN.md §10): counter registry + span
        # tracer + flight recorder + optional stall watchdog; every hook
        # no-ops when disabled
        self.obs = EngineObs(enabled=observability,
                             flight_capacity=flight_capacity,
                             watchdog=watchdog)
        # Batched multi-source serving mode (DESIGN.md §8): ``sources`` is
        # the static tuple of maintained sources; None = classic
        # single-source engine.  ``_lane_of`` routes query sources to rows
        # of the stacked [S, N] state.
        self.sources = tuple(int(s) for s in sources) if sources else None
        if self.sources is not None:
            if len(set(self.sources)) != len(self.sources):
                raise ValueError(f"duplicate sources: {self.sources}")
            self._lane_of = {s: i for i, s in enumerate(self.sources)}
        else:
            self._lane_of = {}
        # batch counters (host-side; no device source)
        self.n_epochs = 0
        self.n_adds = 0
        self.n_dels = 0
        # round/message counters live ON DEVICE; read back lazily at query()
        # (batched engines keep one independent [S] counter per source)
        if self.sources is not None:
            self._dev_rounds = jnp.zeros((len(self.sources),), jnp.int32)
            self._dev_messages = jnp.zeros((len(self.sources),), jnp.int32)
        else:
            self._dev_rounds = jnp.int32(0)
            self._dev_messages = jnp.int32(0)
        # previous parent snapshot per stability scope (None = full state,
        # a source id = that routed lane) — two routed [N] snapshots from
        # DIFFERENT lanes must never be compared against each other
        self._last_parent: dict[int | None, np.ndarray] = {}

    # --------------------------------------------------------- lazy counters
    @staticmethod
    def _counter(x) -> int | np.ndarray:
        got = jax.device_get(x)
        return int(got) if np.ndim(got) == 0 else np.asarray(got)

    @property
    def n_rounds(self) -> int | np.ndarray:
        """BSP rounds so far — an int, or i32[S] per source when batched."""
        return self._counter(self._dev_rounds)

    @property
    def n_messages(self) -> int | np.ndarray:
        return self._counter(self._dev_messages)

    def _stream_stats(self) -> dict[str, Any]:
        return {
            "epochs": self.n_epochs, "rounds": self.n_rounds,
            "messages": self.n_messages, "adds": self.n_adds,
            "dels": self.n_dels,
        }

    def _accumulate_relax(self, stats) -> None:
        """Fold one relaxation epoch's ``RelaxStats`` into the device
        scalars (lazy add — no host sync).  Batched epochs carry ``[S]``
        stat vectors; the add broadcasts the initial scalar up.  With obs
        on, the same stats also record one sample each for the
        waves/messages-per-epoch histograms (§10.6) — a host list append,
        materialized at snapshot flush; still no host sync and no extra
        dispatch on the hot path."""
        self._dev_rounds = self._dev_rounds + stats.rounds
        self._dev_messages = self._dev_messages + stats.messages
        if self.obs.enabled:
            self.obs.hist_device("hist_waves_per_epoch", stats.rounds)
            self.obs.hist_device("hist_messages_per_epoch", stats.messages)

    def _accumulate_delete(self, dstats) -> None:
        """Fold one deletion epoch's ``DeleteStats`` into the device
        scalars; ``affected`` counts as messages (the SetToInfinity
        deliveries), matching the sharded epochs' accounting."""
        rounds = dstats.invalidation_rounds + dstats.recompute_rounds
        messages = dstats.recompute_messages + dstats.affected
        self._dev_rounds = self._dev_rounds + rounds
        self._dev_messages = self._dev_messages + messages
        if self.obs.enabled:
            self.obs.hist_device("hist_waves_per_epoch", rounds)
            self.obs.hist_device("hist_messages_per_epoch", messages)

    # ------------------------------------------------------------- interface
    def _deletion_groups(self, batch: ev.EventBatch
                         ) -> list[tuple[np.ndarray, np.ndarray]]:
        """Paper-faithful: one stop-the-world epoch PER deletion;
        ``batch_deletions=True`` coalesces the whole run into one epoch
        (union of affected subtrees — DESIGN.md §3).  Both engines must
        group identically or the equivalence contract breaks."""
        if self.cfg.batch_deletions:
            return [(batch.src, batch.dst)]
        return [(batch.src[i:i + 1], batch.dst[i:i + 1])
                for i in range(len(batch.src))]

    def _ingest_adds(self, batch: ev.EventBatch) -> None:
        raise NotImplementedError

    def _ingest_dels(self, batch: ev.EventBatch) -> None:
        raise NotImplementedError

    def _snapshot(self, lane: int | None) -> tuple[np.ndarray, np.ndarray]:
        """Device->host readback of (dist, parent) — one lane of the
        stacked state when ``lane`` is given, everything otherwise."""
        raise NotImplementedError

    def _obs_pre_snapshot(self) -> None:
        """Engine-specific lazy folds right before the registry snapshot
        (metrics_snapshot only) — e.g. the sharded engine's per-partition
        touched-vertex attribution: per-READOUT device work, never
        per-epoch (§10.4)."""

    # ----------------------------------------------------------------- query
    def serves(self, source: int) -> bool:
        """Whether a routed ``query(source=...)`` would be answered from a
        dedicated lane/tree of this engine."""
        if self.sources is not None:
            return source in self._lane_of
        return int(source) == int(self.cfg.source)

    def route_of(self, query_source: int) -> int | None:
        """THE stream-marker routing policy, shared by ``ingest_log`` and
        the trace replayer (repro/serving/replay.py) so the two can never
        drift: a marker's source routes to its lane on a batched engine
        that serves it; everything else (``-1``, unserved sources,
        single-source engines) reads the full state."""
        if (query_source >= 0 and self.sources is not None
                and self.serves(query_source)):
            return query_source
        return None

    def lane_of(self, source: int) -> int:
        """Row of the stacked [S, N] state serving ``source``."""
        if self.sources is None:
            raise ValueError("lane_of() on a single-source engine; construct "
                             "with sources=(...) for batched serving")
        if source not in self._lane_of:
            raise ValueError(f"source {source} is not served by this engine "
                             f"(sources={self.sources})")
        return self._lane_of[source]

    def query(self, source: int | None = None) -> QueryResult:
        """State collection (paper §3): epochs are already enforced (every
        batch runs to convergence), so the query cost is the device->host
        readback — timed here as the result latency (DESIGN.md §8).

        ``source`` routes the query to one maintained tree of a batched
        engine (only that lane is read back); a single-source engine
        accepts its own source or None.
        """
        lane: int | None = None
        if source is not None:
            if self.sources is not None:
                lane = self.lane_of(int(source))
            elif int(source) != int(self.cfg.source):
                raise ValueError(
                    f"source {source} is not served by this engine "
                    f"(source={self.cfg.source})")
        t0 = time.perf_counter()
        # the query span NESTS any drain span _snapshot dispatches — the
        # bucketed engines settle pending work inside the query (§10.2)
        with self.obs.epoch("query", lane=lane):
            dist, parent = self._snapshot(lane)
        dt = time.perf_counter() - t0
        if self.obs.enabled:
            # result-latency histogram in microseconds (§10.6): total
            # sample count == the ``queries`` counter by construction
            us = dt * 1e6
            self.obs.hist_host("hist_latency_us", us)
            if lane is not None:
                # per-lane attribution (§10.5): routed queries tally the
                # lane and fold the sample into an [S, B] per-lane row
                S = len(self.sources)
                one = np.zeros(S, np.int64)
                one[lane] = 1
                self.obs.counters.inc("queries_per_lane", one, dim="lane")
                row = np.zeros((S, hist_mod.NUM_BUCKETS), np.int64)
                row[lane, hist_mod.bucket_index_np(us)] = 1
                self.obs.counters.inc("hist_latency_us_per_lane", row,
                                      dim="lane")
        return QueryResult(dist=dist, parent=parent, latency_s=dt,
                           epoch_stats=self._stream_stats(),
                           source=None if source is None else int(source))

    # ---------------------------------------------------------------- stream
    def ingest_log(self, log: "ev.EventLog | Iterable[ev.EventLog]",
                   on_query: Callable[[QueryResult], None] | None = None
                   ) -> list[QueryResult]:
        """Drive the engine over an event log; returns query results.

        ``log`` may be a single ``EventLog`` or any iterable of them (e.g.
        a generator lowering ``TraceReader.chunks()``): chunks are ingested
        in order with only the current chunk resident, so paper-scale
        streams cost O(chunk) host memory here (DESIGN.md §11).  A run
        split across a chunk boundary ingests as two batches — converged
        results are identical, epoch counters may differ.

        QUERY markers carrying a source (events.query_marker(source=s)) are
        routed to that lane on a batched engine; markers with ``-1`` (and
        every marker on a single-source engine) read the full state.
        """
        chunks = [log] if isinstance(log, ev.EventLog) else log
        results: list[QueryResult] = []
        for chunk in chunks:
            for batch in chunk.runs():
                if batch.kind == ev.ADD:
                    self._ingest_adds(batch)
                elif batch.kind == ev.DEL:
                    self._ingest_dels(batch)
                else:
                    res = self.query(source=self.route_of(batch.query_source))
                    results.append(res)
                    if on_query is not None:
                        on_query(res)
        return results

    # ---------------------------------------------------------- observability
    def metrics_snapshot(self) -> dict[str, Any]:
        """One-stop observable state (DESIGN.md §10): the stream counters,
        rounds/messages drained from the SAME ``_dev_rounds`` /
        ``_dev_messages`` device scalars as ``n_rounds`` / ``n_messages``
        (bit-identical by construction), the counter registry's snapshot
        (its only device_get), histogram summaries + dimension attribution
        derived from that SAME snapshot (§10.5/§10.6 — no second
        device_get), span counts, and flight-recorder occupancy.  Consumed
        by ``ServingReport``, both examples, the exporters (§10.7) and the
        benches.  An armed watchdog reviews the snapshot for divergence;
        its findings land in the *next* snapshot's counters (§10.8)."""
        if self.obs.enabled:
            self._obs_pre_snapshot()
            self.obs.flush_histograms()
        counters = self.obs.counters.snapshot()
        snap = {
            "epochs": self.n_epochs, "adds": self.n_adds,
            "dels": self.n_dels, "rounds": self.n_rounds,
            "messages": self.n_messages,
            "counters": counters,
            "histograms": hist_mod.summarize(counters),
            "attribution": self.obs.counters.attribution(counters),
            "spans": self.obs.tracer.span_counts(),
            "flight": {"records": self.obs.recorder.total,
                       "capacity": self.obs.recorder.capacity},
        }
        if self.obs.watchdog is not None:
            self.obs.watchdog.review(counters)
        return snap

    def dump_flight_recorder(self, file=None) -> str:
        """Postmortem: write the flight-recorder ring (most recent epoch
        records) as JSONL to ``file`` (default stderr) and return it."""
        return self.obs.recorder.dump(
            file=file, header=f"flight recorder "
            f"({self.obs.recorder.total} records total)")

    # ------------------------------------------------------------- stability
    def stability_vs_prev(self, parent: np.ndarray,
                          source: int | None = None) -> float:
        """Paper §5.4: fraction of vertices whose predecessor is unchanged
        (over vertices present in both results).  Shape-agnostic: a batched
        [S, N] parent stack scores all lanes at once.  ``source`` scopes
        the comparison: pass ``QueryResult.source`` so a routed lane's
        snapshot is only ever compared against the SAME lane's previous
        snapshot (the first observation of each scope scores 1.0)."""
        key = None if source is None else int(source)
        prev = self._last_parent.get(key)
        self._last_parent[key] = parent.copy()
        if prev is None or prev.shape != parent.shape:
            return 1.0
        both = (prev >= 0) & (parent >= 0)
        return float(np.mean(prev[both] == parent[both])) if both.any() else 1.0
