"""Bucketed (delta-stepping) wave schedule — DESIGN.md §9.

The rounds schedule (core/relax.py) settles EVERY epoch to fixpoint with one
global wave per round; at high delete probability the per-epoch converge
loops dominate ingest wall-clock (ROADMAP open item #2).  The bucketed
schedule exploits the same property that makes the paper's asynchronous
runtime correct — insertion-mode relaxation is monotone, so ANY delivery
order reaches the same fixpoint — to defer convergence work and batch it
into distance-class buckets:

  * ingest epochs do only the work the paper's correctness argument needs
    *immediately*: deletions run invalidation (seed -> mark -> SetToInfinity)
    right away, but the recomputation pull and all push waves are deferred;
    insertions just enqueue the tails as push obligations;
  * the deferred work lives in a ``PendingState``: ``push`` marks vertices
    whose current distance has not been offered to their out-neighbours yet,
    ``pull`` marks invalidated vertices awaiting their bulk DistanceQuery;
  * a *drain* (run at query / checkpoint / whenever a converged tree is
    needed) settles the pending set one bucket at a time: each wave only
    activates pending vertices whose tentative distance falls in the lowest
    nonempty bucket ``[q*w, (q+1)*w)`` — the delta-stepping discipline —
    so every vertex pushes a settled value exactly once per improvement
    chain instead of re-cascading per epoch.

Why the final state is bit-identical to the rounds schedule: the fixpoint of
the monotone Bellman operator over the live edge set is unique, and every
candidate is a single binary ``dist[src] + w`` float add, so deferred and
eager settling compute the same distances bit-for-bit.  Parents follow
because at the last improving wave of any vertex every candidate equal to
its final distance comes from a genuinely minimizing in-edge (a stale source
distance would contradict fixpointness), and all schedules break ties among
those by the same smallest-src-id rule.  See DESIGN.md §9 for the invariant
("every finite distance is witnessed by its parent chain over live edges")
that makes interleaved deletions safe under deferral.

Round accounting: waves executed, same as the rounds schedule — but the
totals are *not* comparable wave-for-wave, so tests gate a rounds *budget*
(bucketed total <= rounds-schedule total) instead of exact equality.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import delete as del_mod
from repro.core import relax
from repro.core.relax import RelaxStats
from repro.core.state import INF, NO_PARENT, EdgePool, SSSPState

WAVE_SCHEDULES = ("rounds", "buckets")


class PendingState(NamedTuple):
    """Deferred-work masks carried across bucketed epochs (bool[N] each, or
    [S, N] on a batched multi-source engine)."""
    push: jax.Array   # settled-but-unoffered vertices (push obligations)
    pull: jax.Array   # invalidated vertices awaiting the bulk DistanceQuery


def empty_pending(num_vertices: int,
                  num_sources: int | None = None) -> PendingState:
    shape = ((num_vertices,) if num_sources is None
             else (num_sources, num_vertices))
    return PendingState(push=jnp.zeros(shape, jnp.bool_),
                        pull=jnp.zeros(shape, jnp.bool_))


def pending_occupancy(pend: PendingState) -> tuple[jax.Array, jax.Array]:
    """Lazy device occupancy of the pending masks — (push, pull) counts as
    i32 device scalars, or [S] per-lane vectors on a batched engine.  Fed
    to the obs counter registry at drain entry (DESIGN.md §10.1): no host
    sync, just one cheap eager reduction the registry accumulates."""
    return (jnp.sum(pend.push.astype(jnp.int32), axis=-1),
            jnp.sum(pend.pull.astype(jnp.int32), axis=-1))


def bucket_limit(cur: jax.Array, bucket_width: float) -> jax.Array:
    """Exclusive upper bound of the lowest nonempty bucket given the minimum
    pending distance ``cur``.  ``bucket_width=inf`` degenerates to one
    all-encompassing bucket (== the plain converge drain)."""
    width = jnp.float32(bucket_width)
    return (jnp.floor(cur / width) + 1.0) * width


def bucket_active(dist: jax.Array, push: jax.Array,
                  bucket_width: float) -> jax.Array:
    """Active mask for one drain wave: pending vertices inside the lowest
    nonempty bucket.  The strict-progress guard ``dist == cur`` keeps the
    minimum pending vertex active even if float rounding ever lands the
    bucket limit at or below ``cur``."""
    cur = jnp.min(jnp.where(push, dist, INF))
    limit = bucket_limit(cur, bucket_width)
    return push & ((dist < limit) | (dist == cur))


@jax.jit
def enqueue_push(pend: PendingState, frontier: jax.Array,
                 dist: jax.Array) -> PendingState:
    """Fold an ADD epoch's frontier (inserted-edge tails) into the pending
    push set — the bucketed rendering of 'relax from the tails', deferred.
    Currently-unreachable tails (dist=inf) are pruned: their offers are
    worthless now, and if a later wave ever improves them the improved mask
    re-enqueues them with all their out-edges.  ``frontier`` is the shared
    [N] tail mask; ``dist`` may be [N] or batched [S, N] (broadcasts)."""
    return PendingState(push=pend.push | (frontier & jnp.isfinite(dist)),
                        pull=pend.pull)


# ------------------------------------------------------------ lazy deletion --
def _lazy_invalidate_one(sssp: SSSPState, pend: PendingState,
                         del_src: jax.Array, del_dst: jax.Array,
                         *, num_vertices: int, use_doubling: bool
                         ) -> tuple[SSSPState, PendingState,
                                    "del_mod.DeleteStats"]:
    """Invalidation-only deletion epoch on one tree: seed from the CURRENT
    witness forest, mark the dependent subtree, SetToInfinity — and defer
    the recomputation into the pending state.  Correct on a partially
    settled tree because ``parent`` always witnesses ``dist`` over live
    edges: exactly the bounds that depended on the deleted edge are the
    marked subtree."""
    is_tree = sssp.parent[del_dst] == del_src
    safe = jnp.clip(del_dst, 0, num_vertices - 1)
    seed = jnp.zeros((num_vertices,), jnp.bool_).at[safe].max(
        is_tree & (del_dst >= 0))
    any_seed = jnp.any(seed)
    mark = (del_mod.mark_subtree_doubling if use_doubling
            else del_mod.mark_subtree_flood)
    aff, inv_rounds = mark(sssp.parent, seed, gate=any_seed)
    aff = aff.at[sssp.source].set(False)

    dist = jnp.where(aff, INF, sssp.dist)
    parent = jnp.where(aff, NO_PARENT, sssp.parent)
    # invalidated vertices stop offering; they re-enter via the drain pull
    pend = PendingState(push=pend.push & jnp.isfinite(dist),
                        pull=pend.pull | aff)
    zero = jnp.int32(0)
    stats = del_mod.DeleteStats(
        invalidation_rounds=jnp.where(any_seed, inv_rounds, zero),
        affected=jnp.sum(aff.astype(jnp.int32)),
        recompute_rounds=zero, recompute_messages=zero)
    return SSSPState(dist=dist, parent=parent, source=sssp.source), pend, stats


@partial(jax.jit, static_argnames=("num_vertices", "use_doubling"))
def lazy_delete(sssp: SSSPState, edges: EdgePool, pend: PendingState,
                del_src: jax.Array, del_dst: jax.Array, slots: jax.Array,
                *, num_vertices: int, use_doubling: bool = True):
    """ONE fused device dispatch per deletion event: deactivate the slots,
    seed + mark + invalidate, update the pending masks.  Everything the
    rounds schedule spreads over three dispatches plus a converge loop."""
    edges = EdgePool(src=edges.src, dst=edges.dst, w=edges.w,
                     active=edges.active.at[slots].set(False))
    sssp, pend, stats = _lazy_invalidate_one(
        sssp, pend, del_src, del_dst, num_vertices=num_vertices,
        use_doubling=use_doubling)
    return sssp, edges, pend, stats


@partial(jax.jit, static_argnames=("num_vertices", "use_doubling"))
def lazy_delete_batched(sssp: SSSPState, edges: EdgePool, pend: PendingState,
                        del_src: jax.Array, del_dst: jax.Array,
                        slots: jax.Array, *, num_vertices: int,
                        use_doubling: bool = True):
    """Batched [S, N] lanes: the edge pool is shared (deactivated once), the
    seeds/marks are per-lane — whether a deleted edge is a tree edge depends
    on each lane's witness forest."""
    edges = EdgePool(src=edges.src, dst=edges.dst, w=edges.w,
                     active=edges.active.at[slots].set(False))
    sssp, pend, stats = jax.vmap(
        lambda s, pd: _lazy_invalidate_one(
            s, pd, del_src, del_dst, num_vertices=num_vertices,
            use_doubling=use_doubling))(sssp, pend)
    return sssp, edges, pend, stats


# ------------------------------------------------------------------- drains --
def run_drain(dist: jax.Array, parent: jax.Array, pend: PendingState,
              *, bucket_width: float,
              wave: Callable[[jax.Array, jax.Array, jax.Array], tuple],
              pull_wave: Callable[[jax.Array, jax.Array, jax.Array], tuple],
              track_occupancy: bool = False):
    """Generic drain driver, shared by all backends' jitted entry points.

    ``wave(dist, parent, active) -> (dist', parent', improved)`` is one
    frontier-masked relaxation wave; ``pull_wave(dist, parent, aff)`` is the
    backend's bulk DistanceQuery into the accumulated invalidated set.  Both
    must evaluate the same candidate sets with the same smallest-src-id tie
    rule as the rounds schedule, so the drain's wave sequence — and hence
    (dist, parent) AND the round/message counters — is bit-identical across
    backends.

    Phase structure: one cond-gated pull (counted as a round when it runs),
    then threshold-paced waves.  The bucket limit is recomputed from the
    minimum pending distance every wave, so settling the lowest bucket to
    fixpoint and advancing to the next is emergent — no inner loop, and the
    limit is one broadcast scalar (the sharded drain computes it from the
    already-allgathered offers: no new collectives).

    ``track_occupancy=True`` (the frontier-compacted sparse drain,
    DESIGN.md §12) additionally folds each wave's active-vertex count into a
    fourth returned i32 device scalar — the ``frontier_occupancy`` obs
    signal per §2.4; the extra carry slot rides at 0 otherwise and the
    3-tuple return shape is preserved for existing callers.
    """
    any_pull = jnp.any(pend.pull)

    def do_pull(args):
        d, p = args
        return pull_wave(d, p, pend.pull)

    def no_pull(args):
        d, p = args
        return d, p, jnp.zeros_like(pend.pull)

    dist, parent, imp = jax.lax.cond(any_pull, do_pull, no_pull,
                                     (dist, parent))
    push = pend.push | imp
    rounds0 = jnp.where(any_pull, jnp.int32(1), jnp.int32(0))
    msgs0 = jnp.sum(imp.astype(jnp.int32))

    def cond(carry):
        _, _, push, _, _, _ = carry
        return jnp.any(push)

    def body(carry):
        dist, parent, push, rounds, msgs, occ = carry
        active = bucket_active(dist, push, bucket_width)
        if track_occupancy:
            occ = occ + jnp.sum(active.astype(jnp.int32))
        dist, parent, improved = wave(dist, parent, active)
        push = (push & ~active) | improved
        return (dist, parent, push, rounds + 1,
                msgs + jnp.sum(improved.astype(jnp.int32)), occ)

    dist, parent, _, rounds, msgs, occ = jax.lax.while_loop(
        cond, body, (dist, parent, push, rounds0, msgs0, jnp.int32(0)))
    stats = RelaxStats(rounds=rounds, messages=msgs)
    if track_occupancy:
        return dist, parent, stats, occ
    return dist, parent, stats


@partial(jax.jit, static_argnames=("num_vertices", "bucket_width"))
def segment_drain(sssp: SSSPState, edges: EdgePool, pend: PendingState,
                  *, num_vertices: int, bucket_width: float
                  ) -> tuple[SSSPState, PendingState, RelaxStats]:
    """COO scatter-min drain (the segment backend's bucketed settle)."""

    def wave(dist, parent, active):
        dist, parent, improved, _ = relax.relax_round(
            dist, parent, edges, active, num_vertices=num_vertices)
        return dist, parent, improved

    def pull_wave(dist, parent, aff):
        return del_mod.pull_once(dist, parent, edges, aff, num_vertices)

    dist, parent, stats = run_drain(
        sssp.dist, sssp.parent, pend, bucket_width=bucket_width,
        wave=wave, pull_wave=pull_wave)
    return (SSSPState(dist=dist, parent=parent, source=sssp.source),
            empty_pending(num_vertices), stats)


@partial(jax.jit, static_argnames=("num_vertices", "bucket_width"))
def segment_drain_batched(sssp: SSSPState, edges: EdgePool,
                          pend: PendingState, *, num_vertices: int,
                          bucket_width: float):
    """[S, N] lanes: vmapped drain — jax's while_loop batching rule freezes
    each lane's carry once its own pending set empties, so per-lane stats
    stay bit-identical to unbatched runs (see base.RelaxBackend notes)."""
    return jax.vmap(
        lambda s, pd: segment_drain(s, edges, pd, num_vertices=num_vertices,
                                    bucket_width=bucket_width))(sssp, pend)
