"""``make_engine`` — the one front door to both dynamic engines.

Callers used to pick between ``configs/sssp_del.engine_config`` (single
host) and ``sharded_engine_config`` (mesh) and then construct the engine
themselves; the factory collapses that into one call that returns a READY
engine (DESIGN.md §11.5):

    eng = make_engine(num_vertices=n, edge_capacity=m, source=0)          # single
    eng = make_engine(num_vertices=n, edge_capacity=m, source=0,
                      partitions=8)                                       # sharded
    eng = make_engine(num_vertices=n, edge_capacity=m, source=0,
                      mesh=my_mesh, relax_backend="sliced")               # sharded

Selection rule: passing ``mesh=`` or ``partitions=`` builds the sharded
engine (``partitions=P`` makes a 1-axis mesh over the first P local
devices; ``mesh`` wins when both are given and P must then match its
size).  ``edge_capacity`` is always the TOTAL edge budget — the sharded
path divides it into ``ceil(edge_capacity / P)`` slots per partition, so
switching a workload between the two engines never changes its pool math.

Every remaining keyword must be a field of the selected config dataclass
(``EngineConfig`` / ``ShardedEngineConfig``); anything else raises a
ValueError listing the valid knobs, mirroring the configs' own
``__post_init__`` style.
"""
from __future__ import annotations

import dataclasses
from typing import Any


def _valid_knobs(cfg_cls, exclude: tuple[str, ...]) -> tuple[str, ...]:
    return tuple(f.name for f in dataclasses.fields(cfg_cls)
                 if f.name not in exclude)


def make_engine(*, num_vertices: int, edge_capacity: int, source: int = 0,
                sources: tuple[int, ...] | None = None,
                partitions: int | None = None, mesh: Any | None = None,
                relabel: Any | None = None, **knobs):
    """Build a ready single-host or sharded engine (see module docstring).

    ``relabel`` (sharded only) forwards the edge-balanced relabeling
    triple to ``ShardedSSSPDelEngine``.
    """
    fixed = ("num_vertices", "edge_capacity", "edges_per_part", "source",
             "sources")
    if mesh is None and partitions is None:
        if relabel is not None:
            raise ValueError(
                "relabel= requires the sharded engine; pass mesh= or "
                "partitions= to select it")
        from repro.core.engine import EngineConfig, SSSPDelEngine
        valid = _valid_knobs(EngineConfig, fixed)
        bad = sorted(set(knobs) - set(valid))
        if bad:
            raise ValueError(
                f"unknown engine knob(s) {bad} for the single-host "
                f"engine; valid knobs: {valid}")
        return SSSPDelEngine(EngineConfig(
            num_vertices=num_vertices, edge_capacity=edge_capacity,
            source=source, sources=sources, **knobs))

    import jax

    from repro.core.dist_engine import (ShardedEngineConfig,
                                        ShardedSSSPDelEngine)
    from repro.launch import mesh as mesh_mod
    if mesh is None:
        avail = len(jax.devices())
        if not 1 <= partitions <= avail:
            raise ValueError(
                f"partitions={partitions} but only {avail} device(s) are "
                f"visible; pass mesh= for an explicit layout")
        mesh = mesh_mod._mk((partitions,), ("graph",))
    P = 1
    for a in mesh.axis_names:
        P *= mesh.shape[a]
    if partitions is not None and partitions != P:
        raise ValueError(
            f"partitions={partitions} does not match mesh size {P}; pass "
            "only one of mesh= / partitions=")
    valid = _valid_knobs(ShardedEngineConfig, fixed)
    bad = sorted(set(knobs) - set(valid))
    if bad:
        raise ValueError(
            f"unknown engine knob(s) {bad} for the sharded engine; "
            f"valid knobs: {valid}")
    cfg = ShardedEngineConfig(
        num_vertices=num_vertices,
        edges_per_part=-(-edge_capacity // P),  # total budget / P, ceil
        source=source, sources=sources, **knobs)
    return ShardedSSSPDelEngine(cfg, mesh=mesh, relabel=relabel)
