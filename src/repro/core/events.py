"""Topology event stream types (paper §3, "Topology Event Ingestion").

Events are produced host-side (file replay, generators, sliding-window
deletion model) as numpy struct-of-arrays batches and consumed by the engine.

Event kinds::

    ADD    — edge insertion (u, v, w)
    DEL    — edge deletion  (u, v)
    QUERY  — state-collection marker (paper: on-demand query in the stream)

The stream has no lookahead; the engine is free to coalesce *consecutive*
events of the same kind into one device batch (the paper's runtime similarly
drains its topology buffer before algorithmic messages).

QUERY events carry the *query source* in their ``src`` column (``-1`` = the
engine's default / every maintained source) — the serving layer's
multi-source streams (repro/serving/, DESIGN.md §8) route each query to one
of the batched trees this way.  Single-source streams leave it at ``-1`` and
nothing changes.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

ADD = 0
DEL = 1
QUERY = 2


@dataclasses.dataclass(frozen=True)
class EventBatch:
    """A run of same-kind events (host-side, numpy)."""

    kind: int
    src: np.ndarray  # i64[n]  (QUERY: singleton query source; -1 = default)
    dst: np.ndarray  # i64[n]
    w: np.ndarray    # f32[n]  (DEL/QUERY: ignored)

    def __len__(self) -> int:
        return 0 if self.kind == QUERY else len(self.src)

    @property
    def query_source(self) -> int:
        """The QUERY marker's requested source (``-1`` = default)."""
        assert self.kind == QUERY
        return int(self.src[0]) if len(self.src) else -1


@dataclasses.dataclass(frozen=True)
class EventLog:
    """Flat event log: kind[i] in {ADD, DEL, QUERY}."""

    kind: np.ndarray  # u8[n]
    src: np.ndarray   # i64[n]
    dst: np.ndarray   # i64[n]
    w: np.ndarray     # f32[n]

    def __len__(self) -> int:
        return len(self.kind)

    def __getitem__(self, sl) -> "EventLog":
        return EventLog(self.kind[sl], self.src[sl], self.dst[sl], self.w[sl])

    def runs(self) -> Iterator[EventBatch]:
        """Coalesce consecutive same-kind events into batches.

        QUERY markers are always emitted as singleton batches (each is a
        distinct state-collection point) carrying their query-source row.
        """
        n = len(self)
        if n == 0:
            return
        kinds = self.kind
        # boundaries where the kind changes, plus around every QUERY
        change = np.nonzero(np.diff(kinds) != 0)[0] + 1
        bounds = np.concatenate([[0], change, [n]])
        for a, b in zip(bounds[:-1], bounds[1:]):
            k = int(kinds[a])
            if k == QUERY:
                for i in range(a, b):
                    yield EventBatch(QUERY, self.src[i:i + 1],
                                     self.dst[i:i + 1], self.w[i:i + 1])
            else:
                yield EventBatch(k, self.src[a:b], self.dst[a:b], self.w[a:b])

    @staticmethod
    def concatenate(logs: list["EventLog"]) -> "EventLog":
        return EventLog(
            np.concatenate([l.kind for l in logs]),
            np.concatenate([l.src for l in logs]),
            np.concatenate([l.dst for l in logs]),
            np.concatenate([l.w for l in logs]),
        )


def adds(src, dst, w) -> EventLog:
    src = np.asarray(src, np.int64)
    return EventLog(np.full(len(src), ADD, np.uint8), src,
                    np.asarray(dst, np.int64), np.asarray(w, np.float32))


def dels(src, dst) -> EventLog:
    src = np.asarray(src, np.int64)
    return EventLog(np.full(len(src), DEL, np.uint8), src,
                    np.asarray(dst, np.int64), np.zeros(len(src), np.float32))


def query_marker(source: int = -1) -> EventLog:
    """QUERY marker; ``source`` routes the query to one maintained tree of a
    batched multi-source engine (``-1`` = default/every source)."""
    return EventLog(np.array([QUERY], np.uint8),
                    np.array([source], np.int64),
                    np.array([-1], np.int64), np.array([0.0], np.float32))


def interleave_queries(log: EventLog, every: int) -> EventLog:
    """Insert a QUERY marker after every ``every`` topology events
    (paper §5.3: query interval as a fraction of the window size)."""
    out: list[EventLog] = []
    n = len(log)
    for a in range(0, n, every):
        out.append(log[a:min(a + every, n)])
        out.append(query_marker())
    return EventLog.concatenate(out) if out else log
