"""Reference oracle: textbook Dijkstra on the *current* graph snapshot.

Used by unit/property tests to validate the dynamic engine after every epoch,
and by the stability benchmark as the "ground truth distance" check.  Pure
numpy + heapq — deliberately independent of all JAX code paths.
"""
from __future__ import annotations

import heapq

import numpy as np


def dijkstra(
    num_vertices: int,
    src: np.ndarray,
    dst: np.ndarray,
    w: np.ndarray,
    source: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (dist f64[N] with inf, parent i64[N] with -1)."""
    heads: list[list[tuple[int, float]]] = [[] for _ in range(num_vertices)]
    for u, v, wt in zip(src.tolist(), dst.tolist(), w.tolist()):
        heads[u].append((v, float(wt)))
    dist = np.full(num_vertices, np.inf)
    parent = np.full(num_vertices, -1, np.int64)
    dist[source] = 0.0
    pq: list[tuple[float, int]] = [(0.0, source)]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist[u]:
            continue
        for v, wt in heads[u]:
            nd = d + wt
            if nd < dist[v]:
                dist[v] = nd
                parent[v] = u
                heapq.heappush(pq, (nd, v))
    return dist, parent


def edges_of_pool(pool_src, pool_dst, pool_w, pool_active):
    """Extract the active COO triple from (host copies of) an EdgePool."""
    m = np.asarray(pool_active)
    return (np.asarray(pool_src)[m], np.asarray(pool_dst)[m], np.asarray(pool_w)[m])


def check_tree(
    num_vertices: int,
    src: np.ndarray,
    dst: np.ndarray,
    w: np.ndarray,
    source: int,
    dist: np.ndarray,
    parent: np.ndarray,
    atol: float = 1e-4,
) -> None:
    """Assert (dist, parent) is a valid SSSP solution for the snapshot.

    Distances must match Dijkstra exactly (within fp tolerance); the parent
    pointers must form a *valid* shortest-path tree — the specific tree may
    legitimately differ from Dijkstra's (multiple optima), so we check the
    tree property (dist[v] == dist[parent[v]] + w(parent[v], v), edge exists)
    rather than parent equality.
    """
    ref_dist, _ = dijkstra(num_vertices, src, dst, w, source)
    got = np.asarray(dist, np.float64)
    if not np.allclose(np.where(np.isinf(ref_dist), 1e30, ref_dist),
                       np.where(np.isinf(got), 1e30, got), atol=atol, rtol=1e-5):
        bad = np.nonzero(~np.isclose(
            np.where(np.isinf(ref_dist), 1e30, ref_dist),
            np.where(np.isinf(got), 1e30, got), atol=atol, rtol=1e-5))[0]
        raise AssertionError(
            f"dist mismatch at {bad[:10]}: ref={ref_dist[bad[:10]]} got={got[bad[:10]]}")

    wmap = {}
    for u, v, wt in zip(src.tolist(), dst.tolist(), w.tolist()):
        key = (u, v)
        wmap[key] = min(wmap.get(key, np.inf), float(wt))
    par = np.asarray(parent)
    for v in range(num_vertices):
        p = int(par[v])
        if v == source:
            continue
        if np.isinf(ref_dist[v]):
            assert p == -1, f"unreached vertex {v} has parent {p}"
            continue
        assert p >= 0, f"reached vertex {v} lacks a parent"
        assert (p, v) in wmap, f"parent edge ({p},{v}) not in graph"
        assert abs((got[p] + wmap[(p, v)]) - got[v]) < max(atol, 1e-5 * max(1.0, abs(got[v]))), (
            f"tree edge ({p},{v}) not tight: {got[p]} + {wmap[(p, v)]} != {got[v]}")
