"""Graph + SSSP-tree state for the SSSP-Del engine.

JAX needs static shapes, so the dynamic graph lives in fixed-capacity pools:

  * an edge pool in COO form (``src``, ``dst``, ``w``, ``active``) that the
    ingestion layer mutates functionally (``.at[slot].set``), and
  * per-vertex SSSP state: ``dist`` (+inf == unreached) and ``parent``
    (-1 == no predecessor).

The paper keeps explicit ``SuccessorVertices`` sets per vertex (Listing 1);
here successor sets are *implicit* — the children of ``v`` are exactly
``{u : parent[u] == v}`` — which removes all successor-set bookkeeping
messages (AddToSuccessor / RemoveFromSuccessor become no-ops by construction)
while preserving the invariant they maintain.  This is recorded in DESIGN.md
as part of the async->bulk adaptation.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

INF = jnp.float32(jnp.inf)
NO_PARENT = jnp.int32(-1)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EdgePool:
    """Fixed-capacity COO edge pool.

    Inactive slots have ``active == False`` and are ignored by every kernel.
    ``src``/``dst`` of inactive slots are kept in-range (0) so gathers stay safe.
    """

    src: jax.Array  # i32[E]
    dst: jax.Array  # i32[E]
    w: jax.Array    # f32[E]
    active: jax.Array  # bool[E]

    @property
    def capacity(self) -> int:
        return self.src.shape[0]

    @staticmethod
    def empty(capacity: int) -> "EdgePool":
        return EdgePool(
            src=jnp.zeros((capacity,), jnp.int32),
            dst=jnp.zeros((capacity,), jnp.int32),
            w=jnp.zeros((capacity,), jnp.float32),
            active=jnp.zeros((capacity,), jnp.bool_),
        )

    def num_active(self) -> jax.Array:
        return jnp.sum(self.active.astype(jnp.int32))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SSSPState:
    """Per-vertex SSSP tree state."""

    dist: jax.Array    # f32[N]; +inf == unreached
    parent: jax.Array  # i32[N]; -1 == none (source or unreached)
    source: jax.Array  # i32[] scalar

    @property
    def num_vertices(self) -> int:
        return self.dist.shape[0]

    @staticmethod
    def init(num_vertices: int, source: int | jax.Array) -> "SSSPState":
        source = jnp.asarray(source, jnp.int32)
        dist = jnp.full((num_vertices,), INF, jnp.float32).at[source].set(0.0)
        parent = jnp.full((num_vertices,), NO_PARENT, jnp.int32)
        return SSSPState(dist=dist, parent=parent, source=source)

    @staticmethod
    def init_batched(num_vertices: int,
                     sources: tuple[int, ...]) -> "SSSPState":
        """Stacked multi-source state (serving layer, DESIGN.md §8): one
        [S, N] dist/parent pair per maintained source, sharing the graph.
        Row ``i`` is exactly ``init(num_vertices, sources[i])``."""
        srcs = jnp.asarray(sources, jnp.int32)
        s = len(sources)
        dist = jnp.full((s, num_vertices), INF, jnp.float32).at[
            jnp.arange(s), srcs].set(0.0)
        parent = jnp.full((s, num_vertices), NO_PARENT, jnp.int32)
        return SSSPState(dist=dist, parent=parent, source=srcs)

    def reached(self) -> jax.Array:
        return jnp.isfinite(self.dist)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GraphState:
    """Full engine state: topology pool + SSSP tree."""

    edges: EdgePool
    sssp: SSSPState
    # Next free slot pointer for ring-buffer style slot allocation.  Slot reuse
    # of deleted edges is handled by the host-side ingestion planner; on device
    # we only need the cursor for append-style allocation.
    cursor: jax.Array  # i32[]

    @property
    def num_vertices(self) -> int:
        return self.sssp.num_vertices

    @staticmethod
    def init(num_vertices: int, edge_capacity: int, source: int) -> "GraphState":
        return GraphState(
            edges=EdgePool.empty(edge_capacity),
            sssp=SSSPState.init(num_vertices, source),
            cursor=jnp.int32(0),
        )


def degree_histogram(edges: EdgePool, num_vertices: int) -> jax.Array:
    """In-degree of every vertex over active edges (diagnostics/partitioning)."""
    ones = edges.active.astype(jnp.int32)
    return jax.ops.segment_sum(ones, edges.dst, num_segments=num_vertices)


@partial(jax.jit, static_argnames=("num_vertices",))
def validate_state(state: GraphState, num_vertices: int) -> dict[str, Any]:
    """Cheap invariant probes used by property tests and the engine's
    self-check mode (all computed on device, returned as scalars)."""
    e, s = state.edges, state.sssp
    in_range = jnp.all((e.src >= 0) & (e.src < num_vertices) &
                       (e.dst >= 0) & (e.dst < num_vertices))
    pos_w = jnp.all(jnp.where(e.active, e.w > 0, True))
    src_ok = s.dist[s.source] == 0.0
    parent_range = jnp.all((s.parent >= -1) & (s.parent < num_vertices))
    # every reached non-source vertex has a parent; unreached have none
    reached = jnp.isfinite(s.dist)
    non_src = jnp.arange(num_vertices) != s.source
    has_parent_ok = jnp.all(jnp.where(reached & non_src, s.parent >= 0, True))
    no_parent_ok = jnp.all(jnp.where(~reached, s.parent == NO_PARENT, True))
    return {
        "edges_in_range": in_range,
        "weights_positive": pos_w,
        "source_dist_zero": src_ok,
        "parent_in_range": parent_range,
        "reached_have_parent": has_parent_ok,
        "unreached_have_no_parent": no_parent_ok,
    }
