"""Monotone (insertion-mode) relaxation — the bulk equivalent of the paper's
``DistanceUpdate`` flood (Listing 3/5).

One *round* delivers every in-flight ``DistanceUpdate`` simultaneously:

    cand_e  = dist[src_e] + w_e                (for active, frontier-masked e)
    best_v  = min over {e : dst_e == v} cand_e (segment_min)
    improved_v = best_v < dist_v
    parent_v  := src of an edge attaining best_v (ties -> smallest src id)

and the engine loops rounds until no vertex improves.  Monotonicity of the
paper's insertion mode (Appendix A) makes this reordering exact: the fixpoint
is the same as under any asynchronous delivery order.

Frontier masking reproduces the paper's work-efficiency: only edges whose
source improved in the previous round can deliver a better distance, so all
other edges are masked out of the segment reduction.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.state import INF, NO_PARENT, EdgePool, SSSPState


class RelaxStats(NamedTuple):
    rounds: jax.Array          # i32[] — BSP rounds until convergence
    messages: jax.Array        # i32[] — total "DistanceUpdate deliveries" (improvements)


def relax_round(
    dist: jax.Array,
    parent: jax.Array,
    edges: EdgePool,
    frontier: jax.Array,
    *,
    num_vertices: int,
    tie_perm: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One bulk message wave. Returns (dist, parent, new_frontier, n_improved)."""
    live = edges.active & frontier[edges.src]
    cand = jnp.where(live, dist[edges.src] + edges.w, INF)
    best = jax.ops.segment_min(cand, edges.dst, num_segments=num_vertices)
    best = jnp.minimum(best, INF)  # segment_min fills empty segments with +inf already
    improved = best < dist

    # argmin edge per dst, tie-break by smallest src id so the result is
    # deterministic (the paper's async runtime is nondeterministic here; a
    # deterministic rule keeps tests and stability metrics reproducible).
    # ``tie_perm`` (i32[N] permutation) overrides the tie order — the
    # ReMo-from-scratch baseline draws a fresh permutation per query to
    # model the async runtime's run-to-run arbitrariness among equally
    # valid shortest-path trees (paper §5.4).
    hit = live & (cand == best[edges.dst]) & improved[edges.dst]
    key = edges.src if tie_perm is None else tie_perm[edges.src]
    cand_key = jnp.where(hit, key, jnp.int32(2**31 - 1))
    best_key = jax.ops.segment_min(cand_key, edges.dst,
                                   num_segments=num_vertices)
    if tie_perm is None:
        new_parent = best_key
    else:
        win = hit & (cand_key == best_key[edges.dst])
        cand_src = jnp.where(win, edges.src, jnp.int32(2**31 - 1))
        new_parent = jax.ops.segment_min(cand_src, edges.dst,
                                         num_segments=num_vertices)

    dist = jnp.where(improved, best, dist)
    parent = jnp.where(improved, new_parent, parent)
    return dist, parent, improved, jnp.sum(improved.astype(jnp.int32))


def converged_loop(dist: jax.Array, parent: jax.Array, frontier: jax.Array,
                   wave, *, max_rounds: int = 0,
                   track_occupancy: bool = False
                   ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                              jax.Array]:
    """The shared wave-to-fixpoint driver: loop ``wave(dist, parent,
    frontier) -> (dist, parent, improved)`` while the frontier is non-empty,
    counting rounds and improvement messages exactly as the original dense
    loop did.  Both the dense epochs here and the frontier-compacted sparse
    epochs (core/frontier.py, DESIGN.md §12) run through this driver, so
    their (rounds, messages) accounting matches by construction.

    ``track_occupancy=True`` additionally folds ``sum(frontier)`` per wave
    into the returned occupancy scalar (device-side, no host sync — the
    ``frontier_occupancy`` obs counter per §2.4); otherwise the occupancy
    slot rides along at 0.  Returns (dist, parent, rounds, messages, occ).
    """

    def cond(carry):
        _, _, frontier, rounds, _, _ = carry
        go = jnp.any(frontier)
        if max_rounds:
            go = go & (rounds < max_rounds)
        return go

    def body(carry):
        dist, parent, frontier, rounds, msgs, occ = carry
        if track_occupancy:
            occ = occ + jnp.sum(frontier.astype(jnp.int32))
        dist, parent, improved = wave(dist, parent, frontier)
        return (dist, parent, improved, rounds + 1,
                msgs + jnp.sum(improved.astype(jnp.int32)), occ)

    dist, parent, _, rounds, msgs, occ = jax.lax.while_loop(
        cond,
        body,
        (dist, parent, frontier, jnp.int32(0), jnp.int32(0), jnp.int32(0)),
    )
    return dist, parent, rounds, msgs, occ


@partial(jax.jit, static_argnames=("num_vertices", "max_rounds"))
def relax_until_converged(
    sssp: SSSPState,
    edges: EdgePool,
    frontier: jax.Array,
    *,
    num_vertices: int,
    max_rounds: int = 0,
    tie_perm: jax.Array | None = None,
) -> tuple[SSSPState, RelaxStats]:
    """Run rounds until fixpoint (== the paper's epoch drain).

    ``max_rounds=0`` means unbounded (guaranteed to terminate: distances are
    strictly decreasing and bounded below — Appendix A.1).  A positive bound
    is used by the straggler-mitigation path of the distributed engine.
    """

    def wave(dist, parent, frontier):
        dist, parent, improved, _ = relax_round(
            dist, parent, edges, frontier, num_vertices=num_vertices,
            tie_perm=tie_perm)
        return dist, parent, improved

    dist, parent, rounds, msgs, _ = converged_loop(
        sssp.dist, sssp.parent, frontier, wave, max_rounds=max_rounds)
    return (
        SSSPState(dist=dist, parent=parent, source=sssp.source),
        RelaxStats(rounds=rounds, messages=msgs),
    )


def full_frontier(num_vertices: int) -> jax.Array:
    return jnp.ones((num_vertices,), jnp.bool_)


def frontier_from_vertices(vertices: jax.Array, num_vertices: int) -> jax.Array:
    """Boolean frontier from a (possibly padded with -1) vertex id list."""
    f = jnp.zeros((num_vertices,), jnp.bool_)
    safe = jnp.clip(vertices, 0, num_vertices - 1)
    upd = vertices >= 0
    return f.at[safe].max(upd)
