"""Distributed SSSP-Del: shard_map over a vertex-partitioned device mesh.

Shared-nothing mapping (paper §3 -> TPU):

  * vertices are range-partitioned over the *flattened* mesh axes (every chip
    owns ``Npp = N/P`` contiguous vertices and their SSSP state);
  * edges live with the partition of their **dst** (each chip owns up to
    ``Epp`` in-edges of its vertices) so the per-round scatter-min is local;
  * the shard-local candidate evaluation is a pluggable *wave*
    (``wave(offers) -> (best, arg)``, DESIGN.md §7.2): the exchange
    strategies below assemble the global ``offers`` vector (dist masked to
    the offering set) and the wave — segment-min over the pool slice by
    default, an ELL/sliced gather-min when the sharded dynamic engine plugs
    a relaxation backend in — reduces it per owned row with the shared
    smallest-src-id tie-break;
  * the only cross-partition traffic is the paper's "messages": ``dist[src]``
    offers.  Two exchange strategies:
      - ``"allgather"`` (paper-faithful bulk): all_gather the dist (+frontier)
        vectors each round — the BSP rendering of "send DistanceUpdate to all
        out-neighbours";
      - ``"delta"`` (beyond-paper): each round all_gathers only a fixed-size
        buffer of (index, value) pairs for vertices that *improved* last round
        — message-compression; falls back to dense gather on overflow.
  * convergence is detected with a ``psum`` over per-partition improvement
    counts (the paper's distributed epoch/termination detection).

Everything below is pure shard_map + lax collectives; the same code lowers on
1 CPU device (P=1), 8 forced host devices (tests) and the 256/512-chip
production meshes (launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.6 exposes shard_map at top level (kwarg: check_vma)
    _shard_map = jax.shard_map
    _SHARD_MAP_KW = {"check_vma": False}
except AttributeError:  # older jax: experimental module (kwarg: check_rep)
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_KW = {"check_rep": False}

from repro.core.backends.segment import shard_segment_wave
from repro.core.state import INF, NO_PARENT
from repro.graphs import csr as csr_mod
from repro.graphs import partition as part_mod

BIG = jnp.int32(2**31 - 1)


def inactive_dst_layout(P: int, npp: int, epp: int) -> np.ndarray:
    """dst ids for an all-inactive (or padding) pool slot range: every slot
    points at its owner partition's first row, keeping the shard-local
    segment ids ``dst - row0`` inside [0, npp).  The single source of truth
    for the padding-row invariant (place_edges, the sharded engine's empty
    pools)."""
    return np.repeat(np.arange(P, dtype=np.int64) * npp, epp).astype(np.int32)


def per_partition_occupancy(mask: jax.Array, P: int, npp: int) -> jax.Array:
    """Live counts of a sharded bool vertex mask for the obs counter
    registry (DESIGN.md §10.1): an [N] mask reshapes to (P, npp) and sums
    shard-local rows — each partition reduces only the window it owns, no
    collective, no host sync — yielding a [P] per-partition vector the
    registry accumulates lazily.  A batched [S, N] mask reduces over the
    vertex axis instead ([S] per-lane totals, folded through the existing
    sharded-sum machinery — still no new collective pattern)."""
    if mask.ndim == 2:
        return jnp.sum(mask.astype(jnp.int32), axis=-1)
    return jnp.sum(mask.astype(jnp.int32).reshape(P, npp), axis=-1)


@dataclasses.dataclass(frozen=True)
class DistConfig:
    num_vertices: int        # padded: divisible by P
    edges_per_part: int      # static per-partition edge capacity
    mesh_axes: tuple[str, ...]  # axes to flatten into the vertex partition
    exchange: str = "allgather"  # or "delta"
    delta_cap: int = 4096    # per-part (idx,val) slots for "delta" exchange
    max_rounds: int = 0      # 0 = run to fixpoint; >0 = straggler bound


def _flat_axis_size(mesh: Mesh, names: Sequence[str]) -> int:
    s = 1
    for n in names:
        s *= mesh.shape[n]
    return s


class DistributedSSSP:
    """Builds the jitted shard_map epoch functions for a given mesh."""

    def __init__(self, mesh: Mesh, cfg: DistConfig):
        self.mesh = mesh
        self.cfg = cfg
        self.P = _flat_axis_size(mesh, cfg.mesh_axes)
        assert cfg.num_vertices % self.P == 0, (
            f"num_vertices {cfg.num_vertices} must divide P={self.P}")
        self.npp = cfg.num_vertices // self.P
        ax = cfg.mesh_axes
        self.vspec = P(ax)          # vertex arrays: sharded dim 0
        self.espec = P(ax)          # edge arrays: sharded dim 0 (dst-owner order)
        self.rspec = P()            # replicated scalars
        # batched multi-source vertex arrays [S, N]: source axis replicated,
        # vertex axis sharded (serving layer, DESIGN.md §8)
        self.vspec_ms = P(None, ax)

    # -------------------------------------------------------------- sharding
    def vertex_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, self.vspec)

    def vertex_sharding_ms(self) -> NamedSharding:
        """Sharding for stacked [S, N] multi-source vertex arrays."""
        return NamedSharding(self.mesh, self.vspec_ms)

    def edge_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, self.espec)

    # ------------------------------------------------------------ partition
    def place_edges(self, src: np.ndarray, dst: np.ndarray, w: np.ndarray
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Host-side: bucket edges by dst partition, pad each bucket to Epp.

        Returns (src, dst, w, active) of shape (P*Epp,) in partition-major
        order — the layout the edge sharding expects.  Fully numpy-vectorized
        (DESIGN.md §2.5): a stable owner sort plus a per-owner rank gives each
        edge its flat output position — no per-partition Python copy loop.
        """
        P_, npp, epp = self.P, self.npp, self.cfg.edges_per_part
        owner = np.minimum(np.asarray(dst, np.int64) // npp, P_ - 1)
        counts = np.bincount(owner, minlength=P_)
        if len(owner) and counts.max() > epp:
            raise ValueError(f"partition overflow: max {counts.max()} > Epp {epp}"
                             " — raise edges_per_part or rebalance")
        order = np.argsort(owner, kind="stable")
        owner_s = owner[order]
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        rank = np.arange(len(order)) - starts[owner_s]
        pos = owner_s * epp + rank
        out_src = np.zeros(P_ * epp, np.int32)
        out_dst = inactive_dst_layout(P_, npp, epp)
        out_w = np.zeros(P_ * epp, np.float32)
        out_act = np.zeros(P_ * epp, np.bool_)
        out_src[pos] = src[order]
        out_dst[pos] = dst[order]
        out_w[pos] = w[order]
        out_act[pos] = True
        return out_src, out_dst, out_w, out_act

    # --------------------------------------------------------------- epochs
    def _apply_wave(self, dist_sh, parent_sh, wave, offers):
        """Shared tail of every round: evaluate the local wave on the
        assembled offers and fold improvements into (dist, parent)."""
        best, arg = wave(offers)
        improved = best < dist_sh
        dist_sh = jnp.where(improved, best, dist_sh)
        parent_sh = jnp.where(improved, arg, parent_sh)
        return dist_sh, parent_sh, improved

    def _round_allgather(self, dist_sh, parent_sh, frontier_sh, wave):
        """One BSP message wave with dense dist/frontier exchange.  Sources
        outside the frontier offer +inf — the offers-vector rendering of the
        old per-edge ``active & frontier[src]`` mask (bit-identical)."""
        ax = self.cfg.mesh_axes
        dist_full = jax.lax.all_gather(dist_sh, ax, tiled=True)
        front_full = jax.lax.all_gather(frontier_sh, ax, tiled=True)
        offers = jnp.where(front_full, dist_full, INF)
        return self._apply_wave(dist_sh, parent_sh, wave, offers)

    def _round_delta(self, dist_sh, parent_sh, frontier_sh, wave, row0):
        """Delta-compressed wave: exchange only (idx,val) of improved vertices.

        Each partition packs the indices of its frontier vertices into a
        fixed ``delta_cap`` buffer (global ids; slot 0-padded with id=-1),
        all_gathers the small buffers, scatters them into a local copy of the
        *stale* dist vector, and proceeds as usual.  Overflow falls back to a
        dense all_gather for that round (flagged via psum).
        """
        ax = self.cfg.mesh_axes
        cap = self.cfg.delta_cap
        n_front = jnp.sum(frontier_sh.astype(jnp.int32))
        overflow = n_front > cap
        any_overflow = jax.lax.psum(overflow.astype(jnp.int32), ax) > 0

        # pack local frontier (idx, dist) — global ids
        local_ids = row0 + jnp.arange(self.npp, dtype=jnp.int32)
        order = jnp.argsort(~frontier_sh)  # frontier first (stable)
        take = order[:cap]
        sel = frontier_sh[take]
        pack_idx = jnp.where(sel, local_ids[take], -1)
        pack_val = jnp.where(sel, dist_sh[take], INF)

        all_idx = jax.lax.all_gather(pack_idx, ax, tiled=True)   # (P*cap,)
        all_val = jax.lax.all_gather(pack_val, ax, tiled=True)

        def sparse_dist():
            base = jnp.full((self.cfg.num_vertices,), INF, dist_sh.dtype)
            safe = jnp.clip(all_idx, 0, self.cfg.num_vertices - 1)
            return base.at[safe].min(jnp.where(all_idx >= 0, all_val, INF))

        def dense_dist():
            return jax.lax.all_gather(dist_sh, ax, tiled=True)

        # No separate frontier gather: in the sparse case the offers are
        # +inf for every non-frontier src, which masks those candidates; in
        # the dense-fallback round all sources offer (a superset — safe,
        # costs one extra wave's work only on overflow rounds).
        offers = jax.lax.cond(any_overflow, dense_dist, sparse_dist)
        return self._apply_wave(dist_sh, parent_sh, wave, offers)

    def _relax_body(self, dist_sh, parent_sh, frontier_sh, wave):
        """Relaxation rounds to fixpoint with the given local wave.  Returns
        (dist, parent, rounds, messages); ``messages`` counts DistanceUpdate
        deliveries (improvements summed over partitions) — same semantics as
        core/relax.RelaxStats, for any backend's wave."""
        ax = self.cfg.mesh_axes
        row0 = (jnp.int32(self._flat_index()) * self.npp)

        def rnd(dist, parent, frontier):
            if self.cfg.exchange == "delta":
                return self._round_delta(dist, parent, frontier, wave, row0)
            return self._round_allgather(dist, parent, frontier, wave)

        def cond(carry):
            _, _, _, go, rounds, _ = carry
            keep = go
            if self.cfg.max_rounds:
                keep = keep & (rounds < self.cfg.max_rounds)
            return keep

        def body(carry):
            dist, parent, frontier, _, rounds, msgs = carry
            dist, parent, improved = rnd(dist, parent, frontier)
            n_imp = jax.lax.psum(jnp.sum(improved.astype(jnp.int32)), ax)
            return dist, parent, improved, n_imp > 0, rounds + 1, msgs + n_imp

        init_go = jax.lax.psum(
            jnp.sum(frontier_sh.astype(jnp.int32)), ax) > 0
        dist_sh, parent_sh, _, _, rounds, msgs = jax.lax.while_loop(
            cond, body, (dist_sh, parent_sh, frontier_sh, init_go,
                         jnp.int32(0), jnp.int32(0)))
        return dist_sh, parent_sh, rounds, msgs

    def _flat_index(self):
        """Flattened partition index from the (possibly multiple) mesh axes."""
        idx = jnp.int32(0)
        for name in self.cfg.mesh_axes:
            idx = idx * self.mesh.shape[name] + jax.lax.axis_index(name)
        return idx

    # ---- public jitted entry points ----------------------------------------
    def make_relax_epoch(self):
        """epoch(dist, parent, frontier, esrc, edst, ew, eact) -> (dist, parent, rounds)"""
        cfg = self.cfg

        @jax.jit
        @partial(_shard_map, mesh=self.mesh,
                 in_specs=(self.vspec, self.vspec, self.vspec,
                           self.espec, self.espec, self.espec, self.espec),
                 out_specs=(self.vspec, self.vspec, self.rspec),
                 **_SHARD_MAP_KW)
        def epoch(dist, parent, frontier, esrc, edst, ew, eact):
            row0 = jnp.int32(self._flat_index()) * self.npp
            wave = shard_segment_wave(esrc, edst, ew, eact, row0, self.npp)
            d, p, r, _ = self._relax_body(dist, parent, frontier, wave)
            return d, p, r

        return epoch

    def make_delete_epoch(self):
        """delete(dist, parent, seed, esrc, edst, ew, eact) -> (dist, parent, rounds)

        seed: bool[N] (sharded) marking invalidation roots (heads of deleted
        tree edges; computed host-side or by ``seed_from_deletions`` below).
        Performs: pointer-doubling subtree marking -> invalidate -> pull ->
        push-relax to fixpoint.  eact must already exclude the deleted edges.
        """
        ax = self.cfg.mesh_axes

        @jax.jit
        @partial(_shard_map, mesh=self.mesh,
                 in_specs=(self.vspec, self.vspec, self.vspec,
                           self.espec, self.espec, self.espec, self.espec),
                 out_specs=(self.vspec, self.vspec, self.rspec),
                 **_SHARD_MAP_KW)
        def delete_epoch(dist, parent, seed, esrc, edst, ew, eact):
            row0 = jnp.int32(self._flat_index()) * self.npp
            wave = shard_segment_wave(esrc, edst, ew, eact, row0, self.npp)

            if self.cfg.exchange == "delta":
                aff, inv_rounds = self._invalidate_delta(parent, seed, row0)
            else:
                aff, inv_rounds = self._invalidate_doubling(parent, seed)

            dist = jnp.where(aff, INF, dist)
            parent = jnp.where(aff, NO_PARENT, parent)

            if self.cfg.exchange == "delta":
                dist, parent, rounds, _ = self._recompute_delta(
                    dist, parent, aff, esrc, edst, eact, wave, row0)
            else:
                dist, parent, rounds, _ = self._recompute_pull_push(
                    dist, parent, aff, wave)
            return dist, parent, rounds + inv_rounds

        return delete_epoch

    # -------------------------------------------------- recomputation impls
    # Shared by the static delete epoch above and the sharded dynamic
    # engine's deletion epochs (core/dist_engine.py) — one implementation so
    # the bit-identical equivalence contract has a single source of truth,
    # for ANY backend's wave.  Both return (dist, parent, rounds, messages)
    # with the same semantics as core/delete.DeleteStats'
    # recompute_{rounds,messages}.

    def _recompute_pull_push(self, dist, parent, aff, wave):
        """Dense pull wave (bulk DistanceQuery: one unmasked wave, counted
        as one round, improvements folded into affected rows only —
        unaffected rows cannot improve, the pre-deletion state was
        converged) + push to fixpoint."""
        ax = self.cfg.mesh_axes
        offers = jax.lax.all_gather(dist, ax, tiled=True)
        best, arg = wave(offers)
        improved = (best < dist) & aff
        dist = jnp.where(improved, best, dist)
        parent = jnp.where(improved, arg, parent)
        n_pull = jax.lax.psum(jnp.sum(improved.astype(jnp.int32)), ax)
        dist, parent, rounds, msgs = self._relax_body(
            dist, parent, improved, wave)
        return dist, parent, rounds + 1, msgs + n_pull

    def _recompute_delta(self, dist, parent, aff, esrc, edst, eact, wave,
                         row0):
        """Bulk DistanceQuery, message form (paper Listing 9): each partition
        broadcasts the ids of the srcs its affected vertices need offers from
        (packed, delta_cap); owners of queried valid vertices become the PUSH
        frontier and normal delta relaxation delivers the offers.  Same
        fixpoint as the dense pull (Appendix A); O(P*cap) bytes instead of
        O(N).  Overflow falls back to every valid vertex pushing once.

        The request set is packed from the COO pool slice (maintained for
        every backend); the offer delivery itself runs through the wave.
        """
        ax = self.cfg.mesh_axes
        dl = edst - row0
        req = eact & aff[dl]
        cap = self.cfg.delta_cap
        order = jnp.argsort(~req)
        take = order[:cap]
        sel = req[take]
        pack = jnp.where(sel, esrc[take], -1)
        overflow = jax.lax.psum(
            (jnp.sum(req.astype(jnp.int32)) > cap).astype(jnp.int32),
            ax) > 0
        all_q = jax.lax.all_gather(pack, ax, tiled=True)

        def sparse_front():
            base = jnp.zeros((self.cfg.num_vertices,), jnp.bool_)
            safe = jnp.clip(all_q, 0, self.cfg.num_vertices - 1)
            base = base.at[safe].max(all_q >= 0)
            local_ids = row0 + jnp.arange(self.npp, dtype=jnp.int32)
            return base[local_ids]

        def dense_front():
            return jnp.ones((self.npp,), jnp.bool_)

        queried = jax.lax.cond(overflow, dense_front, sparse_front)
        frontier0 = queried & jnp.isfinite(dist)
        return self._relax_body(dist, parent, frontier0, wave)

    # ------------------------------------------------- bucketed drain impls
    # The sharded rendering of core/buckets.run_drain (DESIGN.md §9): one
    # pull wave into the accumulated invalidated set, then bucket-threshold-
    # paced push waves.  The bucket limit is a replicated scalar computed
    # from the SAME gathered data a normal round exchanges (dist plus one
    # bool mask) — every partition derives identical (cur, limit), so the
    # schedule needs NO new collective primitives, and the wave sequence —
    # hence final (dist, parent) AND the round/message counters — is
    # bit-identical to the single-device drain.

    def _bucket_offers_allgather(self, dist, push, bucket_width):
        from repro.core.buckets import bucket_limit
        ax = self.cfg.mesh_axes
        dist_full = jax.lax.all_gather(dist, ax, tiled=True)
        push_full = jax.lax.all_gather(push, ax, tiled=True)
        cur = jnp.min(jnp.where(push_full, dist_full, INF))
        limit = bucket_limit(cur, bucket_width)
        act_full = push_full & ((dist_full < limit) | (dist_full == cur))
        offers = jnp.where(act_full, dist_full, INF)
        active = push & ((dist < limit) | (dist == cur))
        return offers, active

    def _bucket_offers_delta(self, dist, push, row0, bucket_width):
        """Delta-compressed drain wave: pack the WHOLE pending set (ids +
        dists); ``cur`` from the packed values is exact because every pending
        vertex is packed when no partition overflows.  Overflow falls back to
        the dense gathers — the offers stay bucket-gated there too, so the
        wave sequence is unchanged (unlike ``_round_delta``'s superset
        fallback, a superset here would break the pacing parity)."""
        from repro.core.buckets import bucket_limit
        ax = self.cfg.mesh_axes
        cap = self.cfg.delta_cap
        n = self.cfg.num_vertices
        overflow = jax.lax.psum(
            (jnp.sum(push.astype(jnp.int32)) > cap).astype(jnp.int32),
            ax) > 0
        local_ids = row0 + jnp.arange(self.npp, dtype=jnp.int32)
        order = jnp.argsort(~push)
        take = order[:cap]
        sel = push[take]
        pack_idx = jnp.where(sel, local_ids[take], -1)
        pack_val = jnp.where(sel, dist[take], INF)
        all_idx = jax.lax.all_gather(pack_idx, ax, tiled=True)
        all_val = jax.lax.all_gather(pack_val, ax, tiled=True)

        def sparse():
            cur = jnp.min(all_val)
            limit = bucket_limit(cur, bucket_width)
            act = (all_val < limit) | (all_val == cur)
            base = jnp.full((n,), INF, dist.dtype)
            safe = jnp.clip(all_idx, 0, n - 1)
            offers = base.at[safe].min(
                jnp.where((all_idx >= 0) & act, all_val, INF))
            return offers, cur

        def dense():
            dist_full = jax.lax.all_gather(dist, ax, tiled=True)
            push_full = jax.lax.all_gather(push, ax, tiled=True)
            cur = jnp.min(jnp.where(push_full, dist_full, INF))
            limit = bucket_limit(cur, bucket_width)
            act_full = push_full & ((dist_full < limit) | (dist_full == cur))
            return jnp.where(act_full, dist_full, INF), cur

        offers, cur = jax.lax.cond(overflow, dense, sparse)
        limit = bucket_limit(cur, bucket_width)
        active = push & ((dist < limit) | (dist == cur))
        return offers, active

    def _drain_body(self, dist, parent, push, pull, wave, row0, bucket_width):
        """Sharded drain: (dist, parent, rounds, messages), counters equal to
        ``run_drain``'s.  Pull phase runs unconditionally (collectives are
        uniform across partitions) but improvements fold into ``pull`` rows
        only and the round is counted iff any lane pulled — state-identical
        to the single-device ``lax.cond`` gating."""
        ax = self.cfg.mesh_axes
        any_pull = jax.lax.psum(jnp.sum(pull.astype(jnp.int32)), ax) > 0
        offers = jax.lax.all_gather(dist, ax, tiled=True)
        best, arg = wave(offers)
        improved = (best < dist) & pull
        dist = jnp.where(improved, best, dist)
        parent = jnp.where(improved, arg, parent)
        push = push | improved
        msgs0 = jax.lax.psum(jnp.sum(improved.astype(jnp.int32)), ax)
        rounds0 = jnp.where(any_pull, jnp.int32(1), jnp.int32(0))

        def cond(carry):
            return carry[3]

        def body(carry):
            dist, parent, push, _, rounds, msgs = carry
            if self.cfg.exchange == "delta":
                offers, active = self._bucket_offers_delta(
                    dist, push, row0, bucket_width)
            else:
                offers, active = self._bucket_offers_allgather(
                    dist, push, bucket_width)
            dist, parent, improved = self._apply_wave(
                dist, parent, wave, offers)
            push = (push & ~active) | improved
            tot = jax.lax.psum(
                jnp.stack([jnp.sum(improved.astype(jnp.int32)),
                           jnp.sum(push.astype(jnp.int32))]), ax)
            return dist, parent, push, tot[1] > 0, rounds + 1, msgs + tot[0]

        init_go = jax.lax.psum(jnp.sum(push.astype(jnp.int32)), ax) > 0
        dist, parent, _, _, rounds, msgs = jax.lax.while_loop(
            cond, body, (dist, parent, push, init_go, rounds0, msgs0))
        return dist, parent, rounds, msgs

    def _bucket_offers_allgather_ms(self, dist, push, bucket_width):
        from repro.core.buckets import bucket_limit
        ax = self.cfg.mesh_axes
        dist_full = jax.lax.all_gather(dist, ax, tiled=True, axis=1)
        push_full = jax.lax.all_gather(push, ax, tiled=True, axis=1)
        cur = jnp.min(jnp.where(push_full, dist_full, INF),
                      axis=1, keepdims=True)                       # [S, 1]
        limit = bucket_limit(cur, bucket_width)
        act_full = push_full & ((dist_full < limit) | (dist_full == cur))
        offers = jnp.where(act_full, dist_full, INF)
        active = push & ((dist < limit) | (dist == cur))
        return offers, active

    def _bucket_offers_delta_ms(self, dist, push, row0, bucket_width):
        """Per-lane packing with a per-lane dense-fallback select (both
        operands computed — the batched rendering of the unbatched
        ``lax.cond``, same wave sequence per lane)."""
        from repro.core.buckets import bucket_limit
        ax = self.cfg.mesh_axes
        cap = self.cfg.delta_cap
        n = self.cfg.num_vertices
        overflow = jax.lax.psum(
            (jnp.sum(push.astype(jnp.int32), axis=1)
             > cap).astype(jnp.int32), ax) > 0                     # [S]
        local_ids = row0 + jnp.arange(self.npp, dtype=jnp.int32)
        order = jnp.argsort(~push, axis=1)
        take = order[:, :cap]
        sel = jnp.take_along_axis(push, take, axis=1)
        pack_idx = jnp.where(sel, local_ids[take], -1)
        pack_val = jnp.where(sel, jnp.take_along_axis(dist, take, axis=1),
                             INF)
        all_idx = jax.lax.all_gather(pack_idx, ax, tiled=True, axis=1)
        all_val = jax.lax.all_gather(pack_val, ax, tiled=True, axis=1)
        dist_full = jax.lax.all_gather(dist, ax, tiled=True, axis=1)
        push_full = jax.lax.all_gather(push, ax, tiled=True, axis=1)
        cur_sparse = jnp.min(all_val, axis=1, keepdims=True)
        cur_dense = jnp.min(jnp.where(push_full, dist_full, INF),
                            axis=1, keepdims=True)
        cur = jnp.where(overflow[:, None], cur_dense, cur_sparse)   # [S, 1]
        limit = bucket_limit(cur, bucket_width)
        act_pack = (all_val < limit) | (all_val == cur)
        safe = jnp.clip(all_idx, 0, n - 1)
        sparse = jax.vmap(lambda s_, v: jnp.full((n,), INF, dist.dtype)
                          .at[s_].min(v))(
            safe, jnp.where((all_idx >= 0) & act_pack, all_val, INF))
        act_full = push_full & ((dist_full < limit) | (dist_full == cur))
        dense = jnp.where(act_full, dist_full, INF)
        offers = jnp.where(overflow[:, None], dense, sparse)
        active = push & ((dist < limit) | (dist == cur))
        return offers, active

    def _drain_body_ms(self, dist, parent, push, pull, wave_b, row0,
                       bucket_width):
        """Batched drain over [S, npp] lanes; per-lane ``go`` gates freeze a
        drained lane's round counter exactly where its unbatched drain would
        exit (same trick as ``_relax_body_ms``)."""
        ax = self.cfg.mesh_axes
        S = dist.shape[0]
        any_pull = jax.lax.psum(
            jnp.sum(pull.astype(jnp.int32), axis=1), ax) > 0        # [S]
        offers = jax.lax.all_gather(dist, ax, tiled=True, axis=1)
        best, arg = wave_b(offers)
        improved = (best < dist) & pull
        dist = jnp.where(improved, best, dist)
        parent = jnp.where(improved, arg, parent)
        push = push | improved
        msgs0 = jax.lax.psum(jnp.sum(improved.astype(jnp.int32), axis=1), ax)
        rounds0 = any_pull.astype(jnp.int32)

        def cond(carry):
            return jnp.any(carry[3])

        def body(carry):
            dist, parent, push, go, rounds, msgs = carry
            if self.cfg.exchange == "delta":
                offers, active = self._bucket_offers_delta_ms(
                    dist, push, row0, bucket_width)
            else:
                offers, active = self._bucket_offers_allgather_ms(
                    dist, push, bucket_width)
            dist, parent, improved = self._apply_wave(
                dist, parent, wave_b, offers)
            push = (push & ~active) | improved
            n_imp = jax.lax.psum(
                jnp.sum(improved.astype(jnp.int32), axis=1), ax)
            n_push = jax.lax.psum(
                jnp.sum(push.astype(jnp.int32), axis=1), ax)
            return (dist, parent, push, n_push > 0,
                    rounds + go.astype(jnp.int32), msgs + n_imp)

        init_go = jax.lax.psum(
            jnp.sum(push.astype(jnp.int32), axis=1), ax) > 0
        dist, parent, _, _, rounds, msgs = jax.lax.while_loop(
            cond, body, (dist, parent, push, init_go, rounds0, msgs0))
        return dist, parent, rounds, msgs

    # --------------------------------------------------- invalidation impls
    # ``gate`` (optional replicated bool, or [S] per-lane bool on the _ms
    # variants) short-circuits the marking loop when no partition seeded —
    # the bucketed schedule's lazy deletion epoch passes ``any_seed`` so
    # non-tree deletions cost zero marking rounds, matching the gated
    # single-device ``mark_subtree_*``.  Stats stay identical either way:
    # callers already mask inv_rounds with the same any_seed.

    def _invalidate_doubling(self, parent, seed, gate=None):
        """Pointer-doubling subtree marking with dense all_gathers of the
        (aff, ptr) vectors — O(log depth) rounds x O(N) bytes/round."""
        ax = self.cfg.mesh_axes

        def dcond(carry):
            _, _, grew, _ = carry
            return grew if gate is None else grew & gate

        def dbody(carry):
            aff, ptr, _, rounds = carry
            aff_full = jax.lax.all_gather(aff, ax, tiled=True)
            par_full = jax.lax.all_gather(ptr, ax, tiled=True)
            valid = ptr >= 0
            safe = jnp.clip(ptr, 0)
            hop = jnp.where(valid, aff_full[safe], False)
            new_aff = aff | hop
            nxt = jnp.where(valid, par_full[safe], NO_PARENT)
            grew_local = jnp.any(new_aff != aff) | jnp.any(nxt != ptr)
            grew = jax.lax.psum(grew_local.astype(jnp.int32), ax) > 0
            return new_aff, nxt, grew, rounds + 1

        aff, _, _, inv_rounds = jax.lax.while_loop(
            dcond, dbody, (seed, parent, jnp.bool_(True), jnp.int32(0)))
        return aff, inv_rounds

    def _invalidate_flood_dense(self, parent, seed, gate=None):
        """Paper-faithful level-by-level SetToInfinity flood with dense aff
        gathers — one round per tree level.  The distributed rendering of
        ``delete.mark_subtree_flood`` (identical wave/round structure, so the
        sharded engine's stats match the single-device flood path exactly)."""
        ax = self.cfg.mesh_axes

        def dcond(carry):
            _, grew, _ = carry
            return grew if gate is None else grew & gate

        def dbody(carry):
            aff, _, rounds = carry
            aff_full = jax.lax.all_gather(aff, ax, tiled=True)
            join = jnp.where(parent >= 0, aff_full[jnp.clip(parent, 0)], False)
            new = aff | join
            grew = jax.lax.psum(
                jnp.sum((new != aff).astype(jnp.int32)), ax) > 0
            return new, grew, rounds + 1

        aff, _, inv_rounds = jax.lax.while_loop(
            dcond, dbody, (seed, jnp.bool_(True), jnp.int32(0)))
        return aff, inv_rounds

    def _invalidate_delta(self, parent, seed, row0, gate=None):
        """Paper-faithful SetToInfinity flood with delta-compressed frontier
        exchange: each wave broadcasts only the NEWLY affected vertex ids
        (packed (idx) buffers, delta_cap per partition) — O(depth) rounds x
        O(P*cap) bytes.  Overflow rounds fall back to a dense aff gather.
        Beyond-paper vs the doubling variant: 10-40x fewer wire bytes on
        shallow subtrees (EXPERIMENTS.md §Perf C3)."""
        ax = self.cfg.mesh_axes
        cap = self.cfg.delta_cap
        n = self.cfg.num_vertices
        local_ids = row0 + jnp.arange(self.npp, dtype=jnp.int32)

        def dcond(carry):
            _, _, grew, _ = carry
            return grew if gate is None else grew & gate

        def dbody(carry):
            aff, frontier, _, rounds = carry
            n_front = jnp.sum(frontier.astype(jnp.int32))
            overflow = jax.lax.psum(
                (n_front > cap).astype(jnp.int32), ax) > 0

            order = jnp.argsort(~frontier)
            take = order[:cap]
            sel = frontier[take]
            pack = jnp.where(sel, local_ids[take], -1)
            all_ids = jax.lax.all_gather(pack, ax, tiled=True)   # (P*cap,)

            def sparse_base():
                base = jnp.zeros((n,), jnp.bool_)
                safe = jnp.clip(all_ids, 0, n - 1)
                return base.at[safe].max(all_ids >= 0)

            def dense_base():
                return jax.lax.all_gather(aff, ax, tiled=True)

            base = jax.lax.cond(overflow, dense_base, sparse_base)
            valid = parent >= 0
            join = jnp.where(valid, base[jnp.clip(parent, 0)], False)
            new = join & ~aff
            aff2 = aff | new
            grew = jax.lax.psum(jnp.sum(new.astype(jnp.int32)), ax) > 0
            return aff2, new, grew, rounds + 1

        aff, _, _, inv_rounds = jax.lax.while_loop(
            dcond, dbody, (seed, seed, jnp.bool_(True), jnp.int32(0)))
        return aff, inv_rounds

    # ------------------------------------------- batched multi-source impls
    # The serving layer's [S, npp] renderings of the bodies above
    # (DESIGN.md §8): S stacked trees advance through ONE shared loop over
    # the shared graph.  Written with an explicit leading source dimension
    # (not vmap) so no collective ever needs a batching rule — all_gather
    # takes ``axis=1``, psum reduces [S] vectors elementwise; only the pure
    # shard-local ``wave`` is vmapped by the caller.
    #
    # Per-lane bit-identity argument: a lane whose frontier has drained
    # offers +inf everywhere, so its (dist, parent, frontier) are natural
    # fixpoints of every further round — no select-masking needed — and the
    # per-lane ``go`` gate stops its round counter exactly where the
    # unbatched while_loop would have exited.  Messages need no gate: a
    # drained lane improves nothing, so its per-round count is already 0.

    def _relax_body_ms(self, dist, parent, frontier, wave_b):
        """Batched ``_relax_body``: dist/parent/frontier are [S, npp];
        returns (dist, parent, rounds[S], messages[S]) — each lane equal to
        what the unbatched body returns for its source."""
        ax = self.cfg.mesh_axes
        row0 = jnp.int32(self._flat_index()) * self.npp
        S = dist.shape[0]

        def rnd(dist, parent, frontier):
            if self.cfg.exchange == "delta":
                return self._round_delta_ms(dist, parent, frontier, wave_b,
                                            row0)
            return self._round_allgather_ms(dist, parent, frontier, wave_b)

        def cond(carry):
            return jnp.any(carry[3])

        def body(carry):
            dist, parent, frontier, go, rounds, msgs = carry
            dist, parent, improved = rnd(dist, parent, frontier)
            n_imp = jax.lax.psum(
                jnp.sum(improved.astype(jnp.int32), axis=1), ax)
            return (dist, parent, improved, n_imp > 0,
                    rounds + go.astype(jnp.int32), msgs + n_imp)

        init_go = jax.lax.psum(
            jnp.sum(frontier.astype(jnp.int32), axis=1), ax) > 0
        dist, parent, _, _, rounds, msgs = jax.lax.while_loop(
            cond, body, (dist, parent, frontier, init_go,
                         jnp.zeros((S,), jnp.int32),
                         jnp.zeros((S,), jnp.int32)))
        return dist, parent, rounds, msgs

    def _round_allgather_ms(self, dist, parent, frontier, wave_b):
        ax = self.cfg.mesh_axes
        dist_full = jax.lax.all_gather(dist, ax, tiled=True, axis=1)
        front_full = jax.lax.all_gather(frontier, ax, tiled=True, axis=1)
        offers = jnp.where(front_full, dist_full, INF)
        return self._apply_wave(dist, parent, wave_b, offers)

    def _round_delta_ms(self, dist, parent, frontier, wave_b, row0):
        """Per-lane delta packing; overflow lanes fall back to the dense
        gather via a per-lane select (both operands are computed — the
        batched rendering of the unbatched ``lax.cond``, same fixpoint)."""
        ax = self.cfg.mesh_axes
        cap = self.cfg.delta_cap
        n = self.cfg.num_vertices
        overflow = jax.lax.psum(
            (jnp.sum(frontier.astype(jnp.int32), axis=1)
             > cap).astype(jnp.int32), ax) > 0                     # [S]
        local_ids = row0 + jnp.arange(self.npp, dtype=jnp.int32)
        order = jnp.argsort(~frontier, axis=1)   # frontier first (stable)
        take = order[:, :cap]
        sel = jnp.take_along_axis(frontier, take, axis=1)
        pack_idx = jnp.where(sel, local_ids[take], -1)
        pack_val = jnp.where(sel, jnp.take_along_axis(dist, take, axis=1),
                             INF)
        all_idx = jax.lax.all_gather(pack_idx, ax, tiled=True, axis=1)
        all_val = jax.lax.all_gather(pack_val, ax, tiled=True, axis=1)
        safe = jnp.clip(all_idx, 0, n - 1)
        sparse = jax.vmap(lambda s_, v: jnp.full((n,), INF, dist.dtype)
                          .at[s_].min(v))(
            safe, jnp.where(all_idx >= 0, all_val, INF))
        dense = jax.lax.all_gather(dist, ax, tiled=True, axis=1)
        offers = jnp.where(overflow[:, None], dense, sparse)
        return self._apply_wave(dist, parent, wave_b, offers)

    def _recompute_pull_push_ms(self, dist, parent, aff, wave_b):
        """Batched ``_recompute_pull_push``: one unmasked pull wave per
        lane, improvements folded into affected rows only, then the batched
        push body to fixpoint."""
        ax = self.cfg.mesh_axes
        offers = jax.lax.all_gather(dist, ax, tiled=True, axis=1)
        best, arg = wave_b(offers)
        improved = (best < dist) & aff
        dist = jnp.where(improved, best, dist)
        parent = jnp.where(improved, arg, parent)
        n_pull = jax.lax.psum(jnp.sum(improved.astype(jnp.int32), axis=1), ax)
        dist, parent, rounds, msgs = self._relax_body_ms(
            dist, parent, improved, wave_b)
        return dist, parent, rounds + 1, msgs + n_pull

    def _recompute_delta_ms(self, dist, parent, aff, esrc, edst, eact,
                            wave_b, row0):
        """Batched ``_recompute_delta``: the request set is packed per lane
        from the shared pool slice (each lane's affected rows differ)."""
        ax = self.cfg.mesh_axes
        cap = self.cfg.delta_cap
        n = self.cfg.num_vertices
        S = dist.shape[0]
        dl = edst - row0
        req = eact[None, :] & aff[:, dl]                          # [S, epp]
        order = jnp.argsort(~req, axis=1)
        take = order[:, :cap]
        sel = jnp.take_along_axis(req, take, axis=1)
        pack = jnp.where(sel, esrc[take], -1)
        overflow = jax.lax.psum(
            (jnp.sum(req.astype(jnp.int32), axis=1)
             > cap).astype(jnp.int32), ax) > 0
        all_q = jax.lax.all_gather(pack, ax, tiled=True, axis=1)
        safe = jnp.clip(all_q, 0, n - 1)
        base = jax.vmap(lambda s_, m: jnp.zeros((n,), jnp.bool_)
                        .at[s_].max(m))(safe, all_q >= 0)
        local_ids = row0 + jnp.arange(self.npp, dtype=jnp.int32)
        sparse_front = jnp.take(base, local_ids, axis=1)
        queried = jnp.where(overflow[:, None],
                            jnp.ones((S, self.npp), jnp.bool_), sparse_front)
        frontier0 = queried & jnp.isfinite(dist)
        return self._relax_body_ms(dist, parent, frontier0, wave_b)

    def _invalidate_doubling_ms(self, parent, seed, gate=None):
        """Batched pointer-doubling marking over [S, npp] per-lane forests."""
        ax = self.cfg.mesh_axes
        S = parent.shape[0]

        def dcond(carry):
            return jnp.any(carry[2])

        def dbody(carry):
            aff, ptr, go, rounds = carry
            aff_full = jax.lax.all_gather(aff, ax, tiled=True, axis=1)
            par_full = jax.lax.all_gather(ptr, ax, tiled=True, axis=1)
            valid = ptr >= 0
            safe = jnp.clip(ptr, 0)
            hop = jnp.where(valid,
                            jnp.take_along_axis(aff_full, safe, axis=1),
                            False)
            new_aff = aff | hop
            nxt = jnp.where(valid,
                            jnp.take_along_axis(par_full, safe, axis=1),
                            NO_PARENT)
            grew_local = (jnp.any(new_aff != aff, axis=1)
                          | jnp.any(nxt != ptr, axis=1))
            grew = jax.lax.psum(grew_local.astype(jnp.int32), ax) > 0
            if gate is not None:
                grew = grew & gate
            return new_aff, nxt, grew, rounds + go.astype(jnp.int32)

        go0 = jnp.ones((S,), jnp.bool_) if gate is None else gate
        aff, _, _, inv_rounds = jax.lax.while_loop(
            dcond, dbody, (seed, parent, go0, jnp.zeros((S,), jnp.int32)))
        return aff, inv_rounds

    def _invalidate_flood_dense_ms(self, parent, seed, gate=None):
        """Batched level-by-level SetToInfinity flood over per-lane forests."""
        ax = self.cfg.mesh_axes
        S = parent.shape[0]

        def dcond(carry):
            return jnp.any(carry[1])

        def dbody(carry):
            aff, go, rounds = carry
            aff_full = jax.lax.all_gather(aff, ax, tiled=True, axis=1)
            join = jnp.where(
                parent >= 0,
                jnp.take_along_axis(aff_full, jnp.clip(parent, 0), axis=1),
                False)
            new = aff | join
            grew = jax.lax.psum(
                jnp.sum((new != aff).astype(jnp.int32), axis=1), ax) > 0
            if gate is not None:
                grew = grew & gate
            return new, grew, rounds + go.astype(jnp.int32)

        go0 = jnp.ones((S,), jnp.bool_) if gate is None else gate
        aff, _, inv_rounds = jax.lax.while_loop(
            dcond, dbody, (seed, go0, jnp.zeros((S,), jnp.int32)))
        return aff, inv_rounds

    def _invalidate_delta_ms(self, parent, seed, row0, gate=None):
        """Batched delta-compressed flood; per-lane packing, per-lane dense
        fallback select (same structure as ``_round_delta_ms``)."""
        ax = self.cfg.mesh_axes
        cap = self.cfg.delta_cap
        n = self.cfg.num_vertices
        S = parent.shape[0]
        local_ids = row0 + jnp.arange(self.npp, dtype=jnp.int32)

        def dcond(carry):
            return jnp.any(carry[2])

        def dbody(carry):
            aff, frontier, go, rounds = carry
            overflow = jax.lax.psum(
                (jnp.sum(frontier.astype(jnp.int32), axis=1)
                 > cap).astype(jnp.int32), ax) > 0
            order = jnp.argsort(~frontier, axis=1)
            take = order[:, :cap]
            sel = jnp.take_along_axis(frontier, take, axis=1)
            pack = jnp.where(sel, local_ids[take], -1)
            all_ids = jax.lax.all_gather(pack, ax, tiled=True, axis=1)
            safe = jnp.clip(all_ids, 0, n - 1)
            sparse = jax.vmap(lambda s_, m: jnp.zeros((n,), jnp.bool_)
                              .at[s_].max(m))(safe, all_ids >= 0)
            dense = jax.lax.all_gather(aff, ax, tiled=True, axis=1)
            base = jnp.where(overflow[:, None], dense, sparse)
            valid = parent >= 0
            join = jnp.where(
                valid, jnp.take_along_axis(base, jnp.clip(parent, 0), axis=1),
                False)
            new = join & ~aff
            aff2 = aff | new
            grew = jax.lax.psum(
                jnp.sum(new.astype(jnp.int32), axis=1), ax) > 0
            if gate is not None:
                grew = grew & gate
            return aff2, new, grew, rounds + go.astype(jnp.int32)

        go0 = jnp.ones((S,), jnp.bool_) if gate is None else gate
        aff, _, _, inv_rounds = jax.lax.while_loop(
            dcond, dbody, (seed, seed, go0, jnp.zeros((S,), jnp.int32)))
        return aff, inv_rounds

    def make_seed_from_deletions(self):
        """seed(parent, del_src, del_dst) -> bool[N] invalidation seeds.

        del_src/del_dst: replicated i32[K] (pad with -1).  A deletion seeds
        iff it was a tree edge (Listing 4)."""

        @jax.jit
        @partial(_shard_map, mesh=self.mesh,
                 in_specs=(self.vspec, self.rspec, self.rspec),
                 out_specs=self.vspec,
                 **_SHARD_MAP_KW)
        def seed_fn(parent, del_src, del_dst):
            row0 = jnp.int32(self._flat_index()) * self.npp
            local = (del_dst >= row0) & (del_dst < row0 + self.npp) & (del_dst >= 0)
            safe = jnp.clip(del_dst - row0, 0, self.npp - 1)
            is_tree = parent[safe] == del_src
            f = jnp.zeros((self.npp,), jnp.bool_)
            return f.at[safe].max(local & is_tree)

        return seed_fn

    # ------------------------------------------------------------- host init
    def init_vertex_arrays(self, source: int):
        n = self.cfg.num_vertices
        dist = np.full(n, np.inf, np.float32); dist[source] = 0.0
        parent = np.full(n, -1, np.int32)
        sh = self.vertex_sharding()
        return (jax.device_put(dist, sh), jax.device_put(parent, sh))

    def init_vertex_arrays_ms(self, sources):
        """Stacked [S, N] multi-source vertex state, sharded along the
        vertex axis (row ``i`` == ``init_vertex_arrays(sources[i])``)."""
        n = self.cfg.num_vertices
        s = len(sources)
        dist = np.full((s, n), np.inf, np.float32)
        dist[np.arange(s), np.asarray(sources)] = 0.0
        parent = np.full((s, n), -1, np.int32)
        sh = self.vertex_sharding_ms()
        return (jax.device_put(dist, sh), jax.device_put(parent, sh))

    def put_edges(self, src, dst, w, active):
        sh = self.edge_sharding()
        return (jax.device_put(src.astype(np.int32), sh),
                jax.device_put(dst.astype(np.int32), sh),
                jax.device_put(w.astype(np.float32), sh),
                jax.device_put(active, sh))

    def frontier_of(self, vertices: np.ndarray):
        f = np.zeros(self.cfg.num_vertices, np.bool_)
        f[vertices[vertices >= 0]] = True
        return jax.device_put(f, self.vertex_sharding())
