"""Deletion mode: invalidation + recomputation (paper §4.1, Listings 4/8/9).

Invalidation
------------
The paper floods ``SetToInfinity`` down the successor sets — O(depth) message
waves.  With the implicit-successor representation (children of v are the
vertices whose ``parent`` is v), marking the affected subtree T(v) is
*descendant marking over the parent forest*.  We provide two implementations:

* ``mark_subtree_flood`` — the paper-faithful wave-by-wave flood
  (one round per tree level), and
* ``mark_subtree_doubling`` — beyond-paper pointer doubling: O(log depth)
  rounds.  Each round jumps ``ptr := parent[ptr]`` after folding in
  ``aff |= aff[ptr]``; this is the classic parallel tree-contraction trick and
  is exact because the parent forest is static during invalidation
  (SetToInfinity is the only in-flight message type — paper Appendix A.1).

Recomputation
-------------
Affected vertices get ``dist=inf, parent=-1`` and then *pull* once from all
valid in-neighbours (bulk ``DistanceQuery``), after which ordinary monotone
push relaxation re-converges (bulk ``DistanceUpdate`` responses).  The pull is
a single masked segment-min over edges whose dst is affected; this realizes
"each invalidated vertex queries its incoming neighbours" in one wave.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.state import INF, NO_PARENT, EdgePool, SSSPState
from repro.core import relax


class DeleteStats(NamedTuple):
    invalidation_rounds: jax.Array
    affected: jax.Array          # i32[] — |T|, size of invalidated subtree
    recompute_rounds: jax.Array
    recompute_messages: jax.Array


def mark_subtree_flood(parent: jax.Array, seed: jax.Array,
                       gate: jax.Array | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Paper-faithful successor flood. ``seed``: bool[N]. Returns (aff, rounds).

    ``gate`` (device bool) short-circuits the loop when False — the bucketed
    lazy-deletion path passes ``any(seed)`` so the frequent non-tree deletion
    costs zero flood iterations instead of a full no-op sweep.  ``None``
    preserves the original loop byte-for-byte for the eager epochs."""

    def cond(carry):
        aff, grew, _ = carry
        return grew if gate is None else grew & gate

    def body(carry):
        aff, _, rounds = carry
        # a vertex joins T if its parent is already in T
        child_join = jnp.where(parent >= 0, aff[jnp.clip(parent, 0)], False)
        new = aff | child_join
        return new, jnp.any(new != aff), rounds + 1

    aff, _, rounds = jax.lax.while_loop(cond, body, (seed, jnp.bool_(True), jnp.int32(0)))
    return aff, rounds


def mark_subtree_doubling(parent: jax.Array, seed: jax.Array,
                          gate: jax.Array | None = None
                          ) -> tuple[jax.Array, jax.Array]:
    """Pointer-doubling descendant marking: O(log depth) rounds (beyond-paper).

    ``gate`` as in ``mark_subtree_flood``: an early-exit predicate for the
    lazy path.  Note the loop must otherwise run until the pointers are fully
    collapsed even when ``aff`` stops growing mid-way (gap distributions can
    stall a round and resume), so the gate is the only extra exit."""
    n = parent.shape[0]

    def cond(carry):
        _, _, grew, _ = carry
        return grew if gate is None else grew & gate

    def body(carry):
        aff, ptr, _, rounds = carry
        valid = ptr >= 0
        hop = jnp.where(valid, aff[jnp.clip(ptr, 0)], False)
        new_aff = aff | hop
        # double: ptr := ptr[ptr] (stays -1 once off-tree)
        nxt = jnp.where(valid, ptr[jnp.clip(ptr, 0)], NO_PARENT)
        grew = jnp.any(new_aff != aff) | jnp.any(nxt != ptr)
        return new_aff, nxt, grew, rounds + 1

    aff, _, _, rounds = jax.lax.while_loop(
        cond, body, (seed, parent, jnp.bool_(True), jnp.int32(0))
    )
    return aff, rounds


def pull_once(dist: jax.Array, parent: jax.Array, edges: EdgePool,
              aff: jax.Array, num_vertices: int
              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One bulk DistanceQuery wave (Listing 9): affected vertices pull their
    best offer from valid (finite-dist) in-neighbours.  Returns
    (dist', parent', improved) — the improved mask is the push frontier the
    recomputation (or the bucketed drain) continues from."""
    live = edges.active & aff[edges.dst] & jnp.isfinite(dist[edges.src])
    cand = jnp.where(live, dist[edges.src] + edges.w, INF)
    best = jax.ops.segment_min(cand, edges.dst, num_segments=num_vertices)
    improved = best < dist
    hit = live & (cand == best[edges.dst]) & improved[edges.dst]
    cand_src = jnp.where(hit, edges.src, jnp.int32(2**31 - 1))
    new_parent = jax.ops.segment_min(cand_src, edges.dst,
                                     num_segments=num_vertices)
    return (jnp.where(improved, best, dist),
            jnp.where(improved, new_parent, parent), improved)


@partial(jax.jit, static_argnames=("num_vertices", "use_doubling"))
def invalidate_and_recompute(
    sssp: SSSPState,
    edges: EdgePool,
    seed: jax.Array,
    *,
    num_vertices: int,
    use_doubling: bool = True,
) -> tuple[SSSPState, DeleteStats]:
    """Full deletion epoch given invalidation seeds (bool[N]).

    ``seed`` marks heads of deleted tree edges (possibly several — consecutive
    deletions may be batched; Appendix A's argument covers the union of
    subtrees since invalidation completes before any recomputation starts).

    An all-false seed (non-tree deletion) is safe and cheap: the state comes
    back unchanged and every stat is 0 — so callers need no blocking
    ``bool(jnp.any(seed))`` check before dispatching (DESIGN.md §2.4).
    """
    any_seed = jnp.any(seed)
    mark = mark_subtree_doubling if use_doubling else mark_subtree_flood
    aff, inv_rounds = mark(sssp.parent, seed)
    # Never invalidate the source itself (its dist is 0 by definition; a
    # deleted edge cannot be on the source's path to itself).
    aff = aff.at[sssp.source].set(False)

    dist = jnp.where(aff, INF, sssp.dist)
    parent = jnp.where(aff, NO_PARENT, sssp.parent)

    # --- Recomputation phase -------------------------------------------------
    # Bulk DistanceQuery: pull from *valid* (finite-dist) in-neighbours into
    # affected vertices only.  Edges out of affected vertices are excluded for
    # this wave (their dist is inf -> they offer nothing), matching Listing 9's
    # "if connected, reply with best offer".
    dist, parent, improved = pull_once(dist, parent, edges, aff, num_vertices)

    # Then ordinary monotone relaxation from the re-seeded vertices drains the
    # epoch (responses propagate down the rebuilt subtree).
    state1 = SSSPState(dist=dist, parent=parent, source=sssp.source)
    state2, stats = relax.relax_until_converged(
        state1, edges, improved, num_vertices=num_vertices
    )
    zero = jnp.int32(0)
    return state2, DeleteStats(
        invalidation_rounds=jnp.where(any_seed, inv_rounds, zero),
        affected=jnp.sum(aff.astype(jnp.int32)),
        recompute_rounds=jnp.where(any_seed, stats.rounds + 1, zero),
        recompute_messages=jnp.where(
            any_seed,
            stats.messages + jnp.sum(improved.astype(jnp.int32)), zero),
    )


def deletion_seed_for_edges(
    sssp: SSSPState,
    del_src: jax.Array,
    del_dst: jax.Array,
    num_vertices: int,
) -> jax.Array:
    """Listing 4: only deletions of *tree* edges (parent[head]==tail) seed
    invalidation; non-tree deletions need no algorithmic work."""
    is_tree = sssp.parent[del_dst] == del_src
    f = jnp.zeros((num_vertices,), jnp.bool_)
    safe = jnp.clip(del_dst, 0, num_vertices - 1)
    return f.at[safe].max(is_tree & (del_dst >= 0))


@partial(jax.jit, static_argnames=("num_vertices",))
def deletion_seed_for_edges_batched(
    sssp: SSSPState,
    del_src: jax.Array,
    del_dst: jax.Array,
    num_vertices: int,
) -> jax.Array:
    """Per-lane [S, N] seeds for a batched multi-source engine (DESIGN.md
    §8): whether a deleted edge is a tree edge depends on each lane's
    parent forest.  Jitted so the per-deletion hot path stays on the pjit
    fast path."""
    return jax.vmap(
        lambda s: deletion_seed_for_edges(s, del_src, del_dst,
                                          num_vertices))(sssp)
