"""Event ingestion: host-side slot planning + device-side batched applies.

A real deployment splits responsibilities exactly like this: a light control
plane (here: ``SlotAllocator``, a host hash map from (u,v) to pool slot and a
free-list) plans where each topology event lands, and the data plane applies
whole batches functionally on device.  The device never sees hash maps —
only dense ``(slots, src, dst, w)`` arrays.

The planner is numpy-vectorized (DESIGN.md §2.5): per-batch work is a handful
of array ops plus O(batch) dict membership probes — the dict is consulted only
for *collisions* (duplicate adds, deletions of known edges), never iterated.
The allocator also keeps a host **mirror** of the device pool (src/dst/w/
active as numpy arrays); the ELL maintenance path rebuilds its device layout
from the mirror without ever reading device memory back.

Duplicate policy: the paper preprocesses inputs to simple graphs; adds of an
already-present edge are dropped by default (``on_duplicate="ignore"``) or
treated as weight-*decrease* updates (``"min"`` — still monotone, still safe
for insertion mode; increases are dropped).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.state import EdgePool, GraphState


class PlannedAdds(NamedTuple):
    slots: np.ndarray  # i32[m] pool slots to write
    src: np.ndarray    # i32[m]
    dst: np.ndarray    # i32[m]
    w: np.ndarray      # f32[m]
    fresh: np.ndarray  # bool[m]; False = weight-decrease of an existing edge


class SlotAllocator:
    """Host-side (u,v) -> slot map + free list over the fixed edge pool.

    Also maintains the host mirror of the pool (``m*`` arrays) so layout
    rebuilds (CSR/ELL) never require a device readback.
    """

    def __init__(self, capacity: int, on_duplicate: str = "ignore"):
        assert on_duplicate in ("ignore", "min")
        self.capacity = capacity
        self.slot_of: dict[tuple[int, int], int] = {}
        self.free: list[int] = list(range(capacity - 1, -1, -1))
        self.on_duplicate = on_duplicate
        self.msrc = np.zeros(capacity, np.int32)
        self.mdst = np.zeros(capacity, np.int32)
        self.mw = np.zeros(capacity, np.float32)
        self.mactive = np.zeros(capacity, np.bool_)

    @classmethod
    def from_pool(cls, capacity: int, on_duplicate: str, src: np.ndarray,
                  dst: np.ndarray, w: np.ndarray, active: np.ndarray
                  ) -> "SlotAllocator":
        """Rebuild planner state from a checkpointed pool snapshot."""
        a = cls(capacity, on_duplicate)
        act = np.asarray(active, bool)
        a.msrc[:] = src; a.mdst[:] = dst; a.mw[:] = w; a.mactive[:] = act
        live = np.nonzero(act)[0]
        a.slot_of = {(int(src[i]), int(dst[i])): int(i) for i in live}
        a.free = [i for i in range(capacity - 1, -1, -1) if not act[i]]
        return a

    def active_coo(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(src, dst, w) of the live edges, from the host mirror."""
        act = self.mactive
        return self.msrc[act], self.mdst[act], self.mw[act]

    # ------------------------------------------------------------------ adds
    def plan_adds(self, src: np.ndarray, dst: np.ndarray, w: np.ndarray
                  ) -> PlannedAdds:
        """Plan a batch of insertions; returns the accepted writes."""
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        w = np.asarray(w, np.float32)
        m = len(src)
        if m == 0:
            return self._empty_adds()
        # Collapse within-batch duplicates: one row per (u,v), first-occurrence
        # order; "min" keeps the smallest weight among the duplicates.
        key = (src << 32) | dst
        uniq, first, inv = np.unique(key, return_index=True,
                                     return_inverse=True)
        if len(uniq) != m and self.on_duplicate == "min":
            wmin = np.full(len(uniq), np.inf, np.float32)
            np.minimum.at(wmin, inv, w)
        else:
            wmin = w[first]
        order = np.argsort(first, kind="stable")
        uu = (uniq >> 32).astype(np.int32)[order]
        vv = (uniq & 0xFFFFFFFF).astype(np.int32)[order]
        ww = wmin[order]

        # Collision probe against the live-edge map (the only dict use).
        slot_of = self.slot_of
        hit = np.fromiter(
            ((int(u), int(v)) in slot_of for u, v in zip(uu, vv)),
            np.bool_, count=len(uu))

        out: list[tuple[np.ndarray, ...]] = []
        new_u, new_v, new_w = uu[~hit], vv[~hit], ww[~hit]
        k = len(new_u)
        if k:
            if k > len(self.free):
                raise RuntimeError("edge pool capacity exhausted")
            new_slots = np.asarray(self.free[-k:][::-1], np.int32)
            del self.free[-k:]
            slot_of.update(zip(zip(new_u.tolist(), new_v.tolist()),
                               new_slots.tolist()))
            self.msrc[new_slots] = new_u
            self.mdst[new_slots] = new_v
            self.mw[new_slots] = new_w
            self.mactive[new_slots] = True
            out.append((new_slots, new_u, new_v, new_w,
                        np.ones(k, np.bool_)))

        if hit.any() and self.on_duplicate == "min":
            du, dv, dw = uu[hit], vv[hit], ww[hit]
            dslots = np.fromiter(
                (slot_of[(int(u), int(v))] for u, v in zip(du, dv)),
                np.int32, count=len(du))
            better = dw < self.mw[dslots]  # weight increases are dropped
            if better.any():
                dslots, du, dv, dw = (dslots[better], du[better],
                                      dv[better], dw[better])
                self.mw[dslots] = dw
                out.append((dslots, du, dv, dw,
                            np.zeros(len(dslots), np.bool_)))

        if not out:
            return self._empty_adds()
        return PlannedAdds(*(np.concatenate(parts) for parts in zip(*out)))

    @staticmethod
    def _empty_adds() -> PlannedAdds:
        z32 = np.empty(0, np.int32)
        return PlannedAdds(z32, z32, z32, np.empty(0, np.float32),
                           np.empty(0, np.bool_))

    # ------------------------------------------------------------------ dels
    def plan_dels(self, src: np.ndarray, dst: np.ndarray):
        """Returns (slots, src, dst) for deletions of edges that exist.
        Deleting a non-existent edge (or the same edge twice in one batch)
        is a no-op."""
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        pop = self.slot_of.pop
        found = [(s, int(u), int(v))
                 for u, v in zip(src.tolist(), dst.tolist())
                 if (s := pop((u, v), None)) is not None]
        if not found:
            z32 = np.empty(0, np.int32)
            return z32, z32.copy(), z32.copy()
        slots = np.asarray([f[0] for f in found], np.int32)
        ps = np.asarray([f[1] for f in found], np.int32)
        pd = np.asarray([f[2] for f in found], np.int32)
        self.free.extend(slots.tolist())
        self.mactive[slots] = False
        return slots, ps, pd


def pad_pow2(*arrays: np.ndarray) -> tuple[np.ndarray, ...]:
    """Pad batch arrays to the next power of two by REPEATING the last
    element (idempotent for slot writes: re-setting the same slot to the
    same value is a no-op).  Keeps the number of distinct jitted shapes —
    and therefore compilations — at O(log max_batch) instead of O(#sizes),
    which is what keeps the ingestion throughput benchmarks honest.

    Contract (uniform across all input lengths): returns a fresh tuple of
    arrays, all of length ``next_pow2(n)``; a zero-length or already-pow2
    batch passes through with the *same* array objects (no copy).  All
    inputs must share the same leading length.
    """
    n = len(arrays[0])
    assert all(len(a) == n for a in arrays), [len(a) for a in arrays]
    if n == 0:
        return tuple(arrays)
    m = 1
    while m < n:
        m <<= 1
    if m == n:
        return tuple(arrays)
    return tuple(np.concatenate([a, np.repeat(a[-1:], m - n, axis=0)])
                 for a in arrays)


@jax.jit
def apply_adds(edges: EdgePool, slots: jax.Array, src: jax.Array,
               dst: jax.Array, w: jax.Array) -> EdgePool:
    """Write a batch of insertions into their slots (functional)."""
    return EdgePool(
        src=edges.src.at[slots].set(src),
        dst=edges.dst.at[slots].set(dst),
        w=edges.w.at[slots].set(w),
        active=edges.active.at[slots].set(True),
    )


@jax.jit
def apply_dels(edges: EdgePool, slots: jax.Array) -> EdgePool:
    """Deactivate a batch of slots (functional). src/dst stay in-range."""
    return EdgePool(
        src=edges.src,
        dst=edges.dst,
        w=edges.w,
        active=edges.active.at[slots].set(False),
    )
