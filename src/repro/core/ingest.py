"""Event ingestion: host-side slot planning + device-side batched applies.

A real deployment splits responsibilities exactly like this: a light control
plane (here: ``SlotAllocator``, a host hash map from (u,v) to pool slot and a
free-list) plans where each topology event lands, and the data plane applies
whole batches functionally on device.  The device never sees hash maps —
only dense ``(slots, src, dst, w)`` arrays.

The planner is numpy-vectorized (DESIGN.md §2.5): per-batch work is a handful
of array ops plus O(batch) dict membership probes — the dict is consulted only
for *collisions* (duplicate adds, deletions of known edges), never iterated.
The allocator also keeps a host **mirror** of the device pool (src/dst/w/
active as numpy arrays); the ELL maintenance path rebuilds its device layout
from the mirror without ever reading device memory back.

Duplicate policy: the paper preprocesses inputs to simple graphs; adds of an
already-present edge are dropped by default (``on_duplicate="ignore"``) or
treated as weight-*decrease* updates (``"min"`` — still monotone, still safe
for insertion mode; increases are dropped).

Two control-plane implementations share the contract (DESIGN.md §11):

* ``SlotAllocator`` — the original ``dict[(u, v), int]`` reference.  Simple,
  but the per-row Python-object probes and ``.tolist()`` round-trips make it
  the host-RSS and latency ceiling at E ≥ 10M.
* ``ColumnarSlotAllocator`` — the default.  ``slot_of`` becomes an
  open-addressing numpy hash table over packed ``(u << 32) | v`` keys and the
  free list becomes an i32 stack, so a batch costs a handful of vectorized
  probe rounds and zero per-edge Python objects.  Bit-identical to the dict
  reference (pinned by tests/test_ingest.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.state import EdgePool, GraphState


class PlannedAdds(NamedTuple):
    slots: np.ndarray  # i32[m] pool slots to write
    src: np.ndarray    # i32[m]
    dst: np.ndarray    # i32[m]
    w: np.ndarray      # f32[m]
    fresh: np.ndarray  # bool[m]; False = weight-decrease of an existing edge


_MAX_ID = np.int64(1) << 31


def _check_ids(src: np.ndarray, dst: np.ndarray) -> None:
    """Both allocators pack (u, v) into one int64 key as (u << 32) | v; a
    negative or ≥ 2**31 id would silently alias another edge, so reject it
    loudly instead (ISSUE 8 regression)."""
    for name, a in (("src", src), ("dst", dst)):
        if len(a) == 0:
            continue
        lo, hi = a.min(), a.max()
        if lo < 0 or hi >= _MAX_ID:
            bad = int(lo) if lo < 0 else int(hi)
            raise ValueError(
                f"vertex id {bad} in {name} is outside [0, 2**31): packed "
                "(src << 32) | dst keys are int64, ids beyond 31 bits would "
                "silently alias another edge")


def _coalesce_adds(src: np.ndarray, dst: np.ndarray, w: np.ndarray,
                   on_duplicate: str):
    """Collapse within-batch duplicate (u, v) rows to one row each, in
    first-occurrence order; "min" keeps the smallest weight among the
    duplicates.  Returns (uu i32, vv i32, ww f32, keys i64)."""
    key = (src << 32) | dst
    uniq, first, inv = np.unique(key, return_index=True, return_inverse=True)
    if len(uniq) != len(src) and on_duplicate == "min":
        wmin = np.full(len(uniq), np.inf, np.float32)
        np.minimum.at(wmin, inv, w)
    else:
        wmin = w[first]
    order = np.argsort(first, kind="stable")
    uu = (uniq >> 32).astype(np.int32)[order]
    vv = (uniq & 0xFFFFFFFF).astype(np.int32)[order]
    return uu, vv, wmin[order], uniq[order]


class SlotAllocator:
    """Host-side (u,v) -> slot map + free list over the fixed edge pool.

    Also maintains the host mirror of the pool (``m*`` arrays) so layout
    rebuilds (CSR/ELL) never require a device readback.
    """

    def __init__(self, capacity: int, on_duplicate: str = "ignore"):
        assert on_duplicate in ("ignore", "min")
        self.capacity = capacity
        self.slot_of: dict[tuple[int, int], int] = {}
        self.free: list[int] = list(range(capacity - 1, -1, -1))
        self.on_duplicate = on_duplicate
        self.msrc = np.zeros(capacity, np.int32)
        self.mdst = np.zeros(capacity, np.int32)
        self.mw = np.zeros(capacity, np.float32)
        self.mactive = np.zeros(capacity, np.bool_)

    @classmethod
    def from_pool(cls, capacity: int, on_duplicate: str, src: np.ndarray,
                  dst: np.ndarray, w: np.ndarray, active: np.ndarray
                  ) -> "SlotAllocator":
        """Rebuild planner state from a checkpointed pool snapshot."""
        a = cls(capacity, on_duplicate)
        act = np.asarray(active, bool)
        a.msrc[:] = src; a.mdst[:] = dst; a.mw[:] = w; a.mactive[:] = act
        live = np.nonzero(act)[0]
        a.slot_of = {(int(src[i]), int(dst[i])): int(i) for i in live}
        a.free = [i for i in range(capacity - 1, -1, -1) if not act[i]]
        return a

    def active_coo(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(src, dst, w) of the live edges, from the host mirror."""
        act = self.mactive
        return self.msrc[act], self.mdst[act], self.mw[act]

    # ------------------------------------------------------------------ adds
    def plan_adds(self, src: np.ndarray, dst: np.ndarray, w: np.ndarray
                  ) -> PlannedAdds:
        """Plan a batch of insertions; returns the accepted writes."""
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        w = np.asarray(w, np.float32)
        m = len(src)
        if m == 0:
            return self._empty_adds()
        _check_ids(src, dst)
        uu, vv, ww, _ = _coalesce_adds(src, dst, w, self.on_duplicate)

        # Collision probe against the live-edge map (the only dict use).
        slot_of = self.slot_of
        hit = np.fromiter(
            ((int(u), int(v)) in slot_of for u, v in zip(uu, vv)),
            np.bool_, count=len(uu))

        out: list[tuple[np.ndarray, ...]] = []
        new_u, new_v, new_w = uu[~hit], vv[~hit], ww[~hit]
        k = len(new_u)
        if k:
            if k > len(self.free):
                raise RuntimeError("edge pool capacity exhausted")
            new_slots = np.asarray(self.free[-k:][::-1], np.int32)
            del self.free[-k:]
            slot_of.update(zip(zip(new_u.tolist(), new_v.tolist()),
                               new_slots.tolist()))
            self.msrc[new_slots] = new_u
            self.mdst[new_slots] = new_v
            self.mw[new_slots] = new_w
            self.mactive[new_slots] = True
            out.append((new_slots, new_u, new_v, new_w,
                        np.ones(k, np.bool_)))

        if hit.any() and self.on_duplicate == "min":
            du, dv, dw = uu[hit], vv[hit], ww[hit]
            dslots = np.fromiter(
                (slot_of[(int(u), int(v))] for u, v in zip(du, dv)),
                np.int32, count=len(du))
            better = dw < self.mw[dslots]  # weight increases are dropped
            if better.any():
                dslots, du, dv, dw = (dslots[better], du[better],
                                      dv[better], dw[better])
                self.mw[dslots] = dw
                out.append((dslots, du, dv, dw,
                            np.zeros(len(dslots), np.bool_)))

        if not out:
            return self._empty_adds()
        return PlannedAdds(*(np.concatenate(parts) for parts in zip(*out)))

    @staticmethod
    def _empty_adds() -> PlannedAdds:
        z32 = np.empty(0, np.int32)
        return PlannedAdds(z32, z32, z32, np.empty(0, np.float32),
                           np.empty(0, np.bool_))

    # ------------------------------------------------------------------ dels
    def plan_dels(self, src: np.ndarray, dst: np.ndarray):
        """Returns (slots, src, dst) for deletions of edges that exist.
        Deleting a non-existent edge (or the same edge twice in one batch)
        is a no-op."""
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        _check_ids(src, dst)
        pop = self.slot_of.pop
        found = [(s, int(u), int(v))
                 for u, v in zip(src.tolist(), dst.tolist())
                 if (s := pop((u, v), None)) is not None]
        if not found:
            z32 = np.empty(0, np.int32)
            return z32, z32.copy(), z32.copy()
        slots = np.asarray([f[0] for f in found], np.int32)
        ps = np.asarray([f[1] for f in found], np.int32)
        pd = np.asarray([f[2] for f in found], np.int32)
        self.free.extend(slots.tolist())
        self.mactive[slots] = False
        return slots, ps, pd


# open-addressing sentinels: packed keys are always ≥ 0 (ids < 2**31)
_EMPTY = np.int64(-1)
_DELETED = np.int64(-2)
# Fibonacci multiplicative hash constant (2**64 / φ)
_HASH_MULT = np.uint64(0x9E3779B97F4A7C15)


class ColumnarSlotAllocator:
    """Columnar control plane: the (u, v) -> slot map as an open-addressing
    numpy hash table, the free list as an i32 stack.  Same contract and
    bit-identical outputs to :class:`SlotAllocator` (same slot-assignment
    order, same duplicate/deletion semantics), but a batch of m events costs
    a few vectorized probe rounds instead of m Python dict operations —
    this is what keeps host RSS and ingest latency flat at E ≥ 10M.

    The index table stores only packed int64 keys + i32 slots; when it fills
    past ~3/4 (live keys + deletion tombstones) it doubles and rehashes the
    *live* keys straight out of the column mirror — the old table is dropped
    before the new one is populated, so growth never holds two copies of the
    mirror columns (they are fixed-capacity and never copied at all).
    """

    def __init__(self, capacity: int, on_duplicate: str = "ignore"):
        assert on_duplicate in ("ignore", "min")
        self.capacity = capacity
        self.on_duplicate = on_duplicate
        self.msrc = np.zeros(capacity, np.int32)
        self.mdst = np.zeros(capacity, np.int32)
        self.mw = np.zeros(capacity, np.float32)
        self.mactive = np.zeros(capacity, np.bool_)
        # free stack: same bottom-to-top order as the dict reference's list
        # (pops come off the top = high indices, batch-reversed)
        self._free = np.arange(capacity - 1, -1, -1, dtype=np.int32)
        self._ntop = capacity
        self._tsize = 0
        self._rebuild(0)

    @classmethod
    def from_pool(cls, capacity: int, on_duplicate: str, src: np.ndarray,
                  dst: np.ndarray, w: np.ndarray, active: np.ndarray
                  ) -> "ColumnarSlotAllocator":
        """Rebuild planner state from a checkpointed pool snapshot."""
        a = cls(capacity, on_duplicate)
        act = np.asarray(active, bool)
        a.msrc[:] = src; a.mdst[:] = dst; a.mw[:] = w; a.mactive[:] = act
        idx = np.arange(capacity - 1, -1, -1, dtype=np.int32)
        fr = idx[~act[idx]]
        a._free[:len(fr)] = fr
        a._ntop = len(fr)
        a._rebuild(int(act.sum()))
        return a

    def active_coo(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(src, dst, w) of the live edges, from the host mirror."""
        act = self.mactive
        return self.msrc[act], self.mdst[act], self.mw[act]

    # ------------------------------------------------------- debug/test views
    @property
    def slot_of(self) -> dict[tuple[int, int], int]:
        """Dict view of the live map (O(capacity); tests/debug only)."""
        live = np.nonzero(self.mactive)[0]
        return {(int(self.msrc[i]), int(self.mdst[i])): int(i) for i in live}

    @property
    def free(self) -> list[int]:
        """List view of the free stack, same order as the dict reference's
        ``free`` list (tests/debug only)."""
        return self._free[:self._ntop].tolist()

    # -------------------------------------------------------- open addressing
    def _probe0(self, keys: np.ndarray) -> np.ndarray:
        h = keys.astype(np.uint64) * _HASH_MULT
        return (h >> np.uint64(self._shift)).astype(np.int64)

    def _rebuild(self, min_live: int) -> None:
        """(Re)build the index table sized for ``min_live`` keys at ≤ 1/2
        load, rehashing live keys from the mirror and dropping tombstones."""
        size = max(16, self._tsize)
        while (min_live + 1) * 2 > size:
            size <<= 1
        self._tkeys = np.full(size, _EMPTY, np.int64)  # old table freed here
        self._tvals = np.zeros(size, np.int32)
        self._tsize = size
        self._shift = 65 - size.bit_length()  # 64 - log2(size)
        self._used = 0  # non-EMPTY cells (live + tombstones)
        live = np.nonzero(self.mactive)[0].astype(np.int32)
        self._live = len(live)
        if len(live):
            keys = ((self.msrc[live].astype(np.int64) << 32)
                    | self.mdst[live].astype(np.int64))
            self._insert(keys, live)

    def _lookup(self, keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Batched probe for distinct keys.  Returns (slots i32, cells i64)
        with -1 where absent.  Each probe round is pure array work; the loop
        runs for the longest collision chain only."""
        n = len(keys)
        slots = np.full(n, -1, np.int32)
        cells = np.full(n, -1, np.int64)
        if n == 0 or self._used == 0:
            return slots, cells
        mask = self._tsize - 1
        pos = self._probe0(keys)
        idx = np.arange(n)
        while len(idx):
            p = pos[idx]
            tk = self._tkeys[p]
            found = tk == keys[idx]
            if found.any():
                slots[idx[found]] = self._tvals[p[found]]
                cells[idx[found]] = p[found]
            idx = idx[~(found | (tk == _EMPTY))]  # EMPTY terminates: absent
            pos[idx] = (pos[idx] + 1) & mask
        return slots, cells

    def _insert(self, keys: np.ndarray, vals: np.ndarray) -> None:
        """Batched insert of distinct keys known to be absent.  First free
        cell (EMPTY or tombstone) on the chain wins; same-cell contention
        within the batch is resolved one probe round at a time."""
        mask = self._tsize - 1
        pos = self._probe0(keys)
        idx = np.arange(len(keys))
        while len(idx):
            p = pos[idx]
            tk = self._tkeys[p]
            freec = (tk == _EMPTY) | (tk == _DELETED)
            if freec.any():
                cand = idx[freec]
                pc = p[freec]
                # one winner per contended cell (first in batch order)
                _, firsts = np.unique(pc, return_index=True)
                win = cand[firsts]
                wp = pc[firsts]
                self._used += int((self._tkeys[wp] == _EMPTY).sum())
                self._tkeys[wp] = keys[win]
                self._tvals[wp] = vals[win]
                keep = np.ones(len(idx), bool)
                keep[np.searchsorted(idx, win)] = False
                idx = idx[keep]
            pos[idx] = (pos[idx] + 1) & mask

    def _ensure_headroom(self, k: int) -> None:
        """Grow/compact before inserting k keys: keep live load ≤ 1/2 and
        live+tombstone load ≤ 3/4 so every probe chain hits an EMPTY cell."""
        if ((self._live + k) * 2 > self._tsize
                or (self._used + k) * 4 > self._tsize * 3):
            self._rebuild(self._live + k)

    # ------------------------------------------------------------------ adds
    def plan_adds(self, src: np.ndarray, dst: np.ndarray, w: np.ndarray
                  ) -> PlannedAdds:
        """Plan a batch of insertions; returns the accepted writes."""
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        w = np.asarray(w, np.float32)
        if len(src) == 0:
            return SlotAllocator._empty_adds()
        _check_ids(src, dst)
        uu, vv, ww, keys = _coalesce_adds(src, dst, w, self.on_duplicate)

        slots, _ = self._lookup(keys)
        hit = slots >= 0

        out: list[tuple[np.ndarray, ...]] = []
        new_u, new_v, new_w = uu[~hit], vv[~hit], ww[~hit]
        k = len(new_u)
        if k:
            if k > self._ntop:
                raise RuntimeError("edge pool capacity exhausted")
            new_slots = self._free[self._ntop - k:self._ntop][::-1].copy()
            self._ntop -= k
            self._ensure_headroom(k)
            self._insert(keys[~hit], new_slots)
            self._live += k
            self.msrc[new_slots] = new_u
            self.mdst[new_slots] = new_v
            self.mw[new_slots] = new_w
            self.mactive[new_slots] = True
            out.append((new_slots, new_u, new_v, new_w,
                        np.ones(k, np.bool_)))

        if hit.any() and self.on_duplicate == "min":
            dslots, du, dv, dw = slots[hit], uu[hit], vv[hit], ww[hit]
            better = dw < self.mw[dslots]  # weight increases are dropped
            if better.any():
                dslots, du, dv, dw = (dslots[better], du[better],
                                      dv[better], dw[better])
                self.mw[dslots] = dw
                out.append((dslots, du, dv, dw,
                            np.zeros(len(dslots), np.bool_)))

        if not out:
            return SlotAllocator._empty_adds()
        return PlannedAdds(*(np.concatenate(parts) for parts in zip(*out)))

    # ------------------------------------------------------------------ dels
    def plan_dels(self, src: np.ndarray, dst: np.ndarray):
        """Returns (slots, src, dst) for deletions of edges that exist.
        Deleting a non-existent edge (or the same edge twice in one batch)
        is a no-op — identical semantics to the dict reference."""
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        _check_ids(src, dst)
        z32 = np.empty(0, np.int32)
        if len(src) == 0:
            return z32, z32.copy(), z32.copy()
        # in-batch duplicate dels collapse to the first occurrence
        key = (src << 32) | dst
        uniq, first = np.unique(key, return_index=True)
        keys = uniq[np.argsort(first, kind="stable")]
        slots, cells = self._lookup(keys)
        found = slots >= 0
        if not found.any():
            return z32, z32.copy(), z32.copy()
        fslots = slots[found]
        fkeys = keys[found]
        self._tkeys[cells[found]] = _DELETED  # tombstone; _used unchanged
        self._live -= len(fslots)
        self._free[self._ntop:self._ntop + len(fslots)] = fslots
        self._ntop += len(fslots)
        self.mactive[fslots] = False
        return (fslots, (fkeys >> 32).astype(np.int32),
                (fkeys & 0xFFFFFFFF).astype(np.int32))


ALLOC_IMPLS = ("columnar", "dict")


def allocator_cls(impl: str = "columnar"):
    """Resolve an ``alloc_impl`` config knob to an allocator class."""
    if impl not in ALLOC_IMPLS:
        raise ValueError(
            f"unknown alloc_impl {impl!r}; valid values: {ALLOC_IMPLS}")
    return ColumnarSlotAllocator if impl == "columnar" else SlotAllocator


def make_allocator(capacity: int, on_duplicate: str = "ignore",
                   impl: str = "columnar"):
    """Construct the configured control-plane implementation."""
    return allocator_cls(impl)(capacity, on_duplicate)


def pad_pow2(*arrays: np.ndarray) -> tuple[np.ndarray, ...]:
    """Pad batch arrays to the next power of two by REPEATING the last
    element (idempotent for slot writes: re-setting the same slot to the
    same value is a no-op).  Keeps the number of distinct jitted shapes —
    and therefore compilations — at O(log max_batch) instead of O(#sizes),
    which is what keeps the ingestion throughput benchmarks honest.

    Contract (uniform across all input lengths): returns a fresh tuple of
    arrays, all of length ``next_pow2(n)``; a zero-length or already-pow2
    batch passes through with the *same* array objects (no copy).  All
    inputs must share the same leading length.
    """
    n = len(arrays[0])
    assert all(len(a) == n for a in arrays), [len(a) for a in arrays]
    if n == 0:
        return tuple(arrays)
    m = 1
    while m < n:
        m <<= 1
    if m == n:
        return tuple(arrays)
    return tuple(np.concatenate([a, np.repeat(a[-1:], m - n, axis=0)])
                 for a in arrays)


@jax.jit
def apply_adds(edges: EdgePool, slots: jax.Array, src: jax.Array,
               dst: jax.Array, w: jax.Array) -> EdgePool:
    """Write a batch of insertions into their slots (functional)."""
    return EdgePool(
        src=edges.src.at[slots].set(src),
        dst=edges.dst.at[slots].set(dst),
        w=edges.w.at[slots].set(w),
        active=edges.active.at[slots].set(True),
    )


@jax.jit
def apply_dels(edges: EdgePool, slots: jax.Array) -> EdgePool:
    """Deactivate a batch of slots (functional). src/dst stay in-range."""
    return EdgePool(
        src=edges.src,
        dst=edges.dst,
        w=edges.w,
        active=edges.active.at[slots].set(False),
    )
