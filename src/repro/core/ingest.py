"""Event ingestion: host-side slot planning + device-side batched applies.

A real deployment splits responsibilities exactly like this: a light control
plane (here: ``SlotAllocator``, a host hash map from (u,v) to pool slot and a
free-list) plans where each topology event lands, and the data plane applies
whole batches functionally on device.  The device never sees hash maps —
only dense ``(slots, src, dst, w)`` arrays.

Duplicate policy: the paper preprocesses inputs to simple graphs; adds of an
already-present edge are dropped by default (``on_duplicate="ignore"``) or
treated as weight-decrease updates (``"min"`` — still monotone, still safe for
insertion mode).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.state import EdgePool, GraphState


class SlotAllocator:
    """Host-side (u,v) -> slot map + free list over the fixed edge pool."""

    def __init__(self, capacity: int, on_duplicate: str = "ignore"):
        assert on_duplicate in ("ignore", "min")
        self.capacity = capacity
        self.slot_of: dict[tuple[int, int], int] = {}
        self.free: list[int] = list(range(capacity - 1, -1, -1))
        self.on_duplicate = on_duplicate

    def plan_adds(self, src: np.ndarray, dst: np.ndarray, w: np.ndarray):
        """Returns (slots, src, dst, w) for the accepted adds."""
        slots, ps, pd, pw = [], [], [], []
        for u, v, wt in zip(src.tolist(), dst.tolist(), w.tolist()):
            key = (u, v)
            if key in self.slot_of:
                if self.on_duplicate == "ignore":
                    continue
                # "min": re-emit the slot with the smaller weight; device-side
                # apply takes elementwise min via overwrite (weight monotone).
                slots.append(self.slot_of[key]); ps.append(u); pd.append(v); pw.append(wt)
                continue
            if not self.free:
                raise RuntimeError("edge pool capacity exhausted")
            s = self.free.pop()
            self.slot_of[key] = s
            slots.append(s); ps.append(u); pd.append(v); pw.append(wt)
        return (np.asarray(slots, np.int32), np.asarray(ps, np.int32),
                np.asarray(pd, np.int32), np.asarray(pw, np.float32))

    def plan_dels(self, src: np.ndarray, dst: np.ndarray):
        """Returns (slots, src, dst) for deletions of edges that exist."""
        slots, ps, pd = [], [], []
        for u, v in zip(src.tolist(), dst.tolist()):
            s = self.slot_of.pop((u, v), None)
            if s is None:
                continue  # deleting a non-existent edge is a no-op
            self.free.append(s)
            slots.append(s); ps.append(u); pd.append(v)
        return (np.asarray(slots, np.int32), np.asarray(ps, np.int32),
                np.asarray(pd, np.int32))


def pad_pow2(*arrays: np.ndarray) -> tuple[np.ndarray, ...]:
    """Pad batch arrays to the next power of two by REPEATING the last
    element (idempotent for slot writes: re-setting the same slot to the
    same value is a no-op).  Keeps the number of distinct jitted shapes —
    and therefore compilations — at O(log max_batch) instead of O(#sizes),
    which is what keeps the ingestion throughput benchmarks honest."""
    n = len(arrays[0])
    if n == 0:
        return arrays
    m = 1
    while m < n:
        m <<= 1
    if m == n:
        return arrays
    return tuple(np.concatenate([a, np.repeat(a[-1:], m - n, axis=0)])
                 for a in arrays)


@jax.jit
def apply_adds(edges: EdgePool, slots: jax.Array, src: jax.Array,
               dst: jax.Array, w: jax.Array) -> EdgePool:
    """Write a batch of insertions into their slots (functional)."""
    return EdgePool(
        src=edges.src.at[slots].set(src),
        dst=edges.dst.at[slots].set(dst),
        w=edges.w.at[slots].set(w),
        active=edges.active.at[slots].set(True),
    )


@jax.jit
def apply_dels(edges: EdgePool, slots: jax.Array) -> EdgePool:
    """Deactivate a batch of slots (functional). src/dst stay in-range."""
    return EdgePool(
        src=edges.src,
        dst=edges.dst,
        w=edges.w,
        active=edges.active.at[slots].set(False),
    )
