"""Sliced hybrid backend (DESIGN.md §6): per-slice-K ELL + hub overflow COO,
behind the RelaxBackend protocol (§7).

Rows are bucketed into degree slices with per-slice pow2 K (capped at a hub
threshold), flattened into one 1-D cell buffer, plus a device COO *overflow*
segment holding hub rows' surplus in-edges, relaxed with the segment-min
kernel and min-combined with the per-slice ELL waves.  Maintenance mirrors
the dense ELL backend cell-for-cell (idempotent appends, device-side
match+tombstone DEL/min-update probing both lanes, per-slice width doubling
plus overflow doubling at mirror rebuilds).

Wave decomposition is shared between the single-device epochs and the
sharded per-partition wave (§7.2): ``sliced_gather_min`` (the per-slice ELL
lane), ``overflow_min`` (the hub-surplus COO lane) and ``combine_lanes``
(scalar min per row with the smallest-global-src-id tie rule across lanes).

Sharded participation: ``ShardedSliced`` keeps one shard-local planner per
partition; per-slice widths and the overflow capacity are synchronized
across shards at rebuild time (elementwise max of the per-shard doubling
policies) so the shard_map epochs see one static flat geometry.  Overflow
``odst`` entries are stored in *global ELL-row* space (``p*rows_pp + local
row``) — the same row space the flat cells use — so the single-device patch
ops work verbatim on the global arrays.

Batched multi-source serving (§8): both lanes and their combine are pure
jnp gathers/segment-mins over source-independent layout state, so the base
protocol's ``relax_batched``/``delete_batched`` vmap (and the sharded
engine's ``jax.vmap(wave)``) batch the stacked [S, N] trees directly.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import delete as del_mod
from repro.core import ingest
from repro.core.backends.base import (RelaxBackend, ShardedBackend, register,
                                      register_sharded, rank_within_rows)
from repro.core.relax import RelaxStats
from repro.core.state import INF, NO_PARENT, SSSPState
from repro.graphs import csr as csr_mod

_NEG_INF = jnp.float32(-jnp.inf)
_INT_MAX = jnp.int32(2**31 - 1)
_next_pow2 = csr_mod.next_pow2


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SlicedEllState:
    """Device-resident hybrid sliced-ELL + overflow-COO view of the edge set.

    The ELL cells of all slices live in ONE flat buffer (``flat_idx``,
    ``flat_w``): row r's cells occupy ``[base[r], base[r] + rowk[r])`` where
    ``rowk[r]`` is r's slice width.  ``fill`` is the per-row occupancy
    high-water mark, exactly as in ``EllState``.  Hub rows (in-degree above
    the planner's hub threshold) keep their surplus in-edges in the COO
    overflow segment ``(osrc, odst, ow)``; empty/tombstoned entries there
    carry w=+inf and never win a min.  ``odst`` is in row space — vertex ids
    single-device, global ELL-row ids when sharded.
    """

    flat_idx: jax.Array  # i32[L] in-neighbor ids (0 where empty/tombstone)
    flat_w: jax.Array    # f32[L] weights (+inf where empty/tombstone)
    fill: jax.Array      # i32[R]
    base: jax.Array      # i32[R] flat offset of each row's first cell
    rowk: jax.Array      # i32[R] each row's slice width
    osrc: jax.Array      # i32[C] overflow in-neighbor ids
    odst: jax.Array      # i32[C] overflow destination rows
    ow: jax.Array        # f32[C] overflow weights (+inf empty/tombstone)


# --------------------------------------------------------------- patch ops --
@jax.jit
def sliced_append(st: SlicedEllState, pos: jax.Array, rows: jax.Array,
                  kpos: jax.Array, src: jax.Array, w: jax.Array
                  ) -> SlicedEllState:
    """Write fresh edges into planner-assigned flat cells (idempotent scatter
    — pad_pow2 repeats are no-ops).  ``pos == base[rows] + kpos``; the
    planner passes both so the device fill marks stay in sync."""
    return dataclasses.replace(
        st,
        flat_idx=st.flat_idx.at[pos].set(src),
        flat_w=st.flat_w.at[pos].set(w),
        fill=st.fill.at[rows].max(kpos + 1),
    )


@jax.jit
def sliced_spill(st: SlicedEllState, opos: jax.Array, src: jax.Array,
                 rows: jax.Array, w: jax.Array) -> SlicedEllState:
    """Append hub-surplus edges into planner-assigned overflow entries
    (idempotent scatter, same pad_pow2 contract as ``sliced_append``)."""
    return dataclasses.replace(
        st,
        osrc=st.osrc.at[opos].set(src),
        odst=st.odst.at[opos].set(rows),
        ow=st.ow.at[opos].set(w),
    )


def _sliced_match(st: SlicedEllState, rows: jax.Array, src: jax.Array,
                  width: int):
    """Locate each (src -> rows) edge's live ELL cell: (flat_pos, found).

    Gathers a ``width``-wide window per row (``width`` = max slice width,
    static) masked to the row's actual slice width — the sliced rendering of
    the dense ELL cell match.  Live edges are unique per (row, src), so at
    most one finite-weight cell matches; edges living in the overflow
    segment simply don't match here."""
    m = rows.shape[0]
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (m, width), 1)
    pos = jnp.clip(st.base[rows][:, None] + k_iota, 0,
                   st.flat_w.shape[0] - 1)
    in_row = k_iota < st.rowk[rows][:, None]
    hit = (in_row & (st.flat_idx[pos] == src[:, None])
           & jnp.isfinite(st.flat_w[pos]))
    kbest = jnp.argmax(hit, axis=1)
    sel = jnp.take_along_axis(pos, kbest[:, None], axis=1)[:, 0]
    return sel, jnp.any(hit, axis=1)


def _overflow_match(st: SlicedEllState, rows: jax.Array, src: jax.Array):
    """Locate each (src -> rows) edge's live overflow entry: (opos, found)."""
    live = jnp.isfinite(st.ow)[None, :]
    hit = (live & (st.osrc[None, :] == src[:, None])
           & (st.odst[None, :] == rows[:, None]))
    return jnp.argmax(hit, axis=1), jnp.any(hit, axis=1)


@partial(jax.jit, static_argnames=("width",))
def sliced_delete(st: SlicedEllState, rows: jax.Array, src: jax.Array,
                  *, width: int) -> SlicedEllState:
    """Tombstone deleted edges (w := +inf) wherever they live — ELL cell or
    overflow entry — located on device by source-id match.  The max-combine
    (-inf = no-op) makes both scatters order-free under batch padding."""
    sel, found = _sliced_match(st, rows, src, width)
    opos, ofound = _overflow_match(st, rows, src)
    return dataclasses.replace(
        st,
        flat_w=st.flat_w.at[sel].max(jnp.where(found, INF, _NEG_INF)),
        ow=st.ow.at[opos].max(jnp.where(ofound, INF, _NEG_INF)),
    )


@partial(jax.jit, static_argnames=("width",))
def sliced_update_min(st: SlicedEllState, rows: jax.Array, src: jax.Array,
                      w: jax.Array, *, width: int) -> SlicedEllState:
    """Weight-decrease of existing edges (on_duplicate="min"): device-side
    match + min-scatter in both lanes (+inf = no-op when unmatched)."""
    sel, found = _sliced_match(st, rows, src, width)
    opos, ofound = _overflow_match(st, rows, src)
    return dataclasses.replace(
        st,
        flat_w=st.flat_w.at[sel].min(jnp.where(found, w, INF)),
        ow=st.ow.at[opos].min(jnp.where(ofound, w, INF)),
    )


@partial(jax.jit, static_argnames=("width",))
def sliced_invariants(st: SlicedEllState, *, width: int
                      ) -> dict[str, jax.Array]:
    """Occupancy invariants over the flat buffer (mirrors ``ell_invariants``):
    cells between a row's fill mark and its slice width must be empty."""
    R = st.fill.shape[0]
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (R, width), 1)
    pos = jnp.clip(st.base[:, None] + k_iota, 0, st.flat_w.shape[0] - 1)
    beyond = (k_iota < st.rowk[:, None]) & (k_iota >= st.fill[:, None])
    return {
        "beyond_fill_empty": jnp.all(
            jnp.where(beyond, jnp.isinf(st.flat_w[pos]), True)),
        "fill_in_range": jnp.all((st.fill >= 0) & (st.fill <= st.rowk)),
    }


# ------------------------------------------------------------------- waves --
def sliced_gather_min(offers: jax.Array, flat_idx: jax.Array,
                      flat_w: jax.Array, *, widths: tuple[int, ...],
                      slice_rows: int, use_kernel: bool = False,
                      interpret: bool = True):
    """The ELL lane of one hybrid wave: per-slice gather + row-min over the
    flat cell buffer.  Returns (best f32[R], arg i32[R]) for R =
    len(widths) * slice_rows rows; arg is the smallest minimizing neighbor
    id (the shared tie rule).

    Runs of equal-width slices are contiguous row-major (R_g, k) blocks in
    the flat buffer — merge them so the common all-settled-on-one-width
    case is a single dense wave, not one dispatch per slice.  The Pallas
    kernel tiles rows in 256-row blocks and requires R_g % min(256, R_g)
    == 0, so a merged run is split into a multiple-of-256-rows main block
    plus a sub-256-row remainder block.
    """
    from repro.kernels.relax.fused import slice_run_groups
    from repro.kernels.relax.ref import ellpack_relax_ref
    from repro.kernels.relax.relax import ellpack_relax

    groups = slice_run_groups(widths, slice_rows)
    bests, args_ = [], []
    off = 0
    for k, cnt in groups:                  # static unroll: one block per run
        rows_g = slice_rows * cnt
        blk = slice(off, off + rows_g * k)
        blk_idx = flat_idx[blk].reshape(rows_g, k)
        blk_w = flat_w[blk].reshape(rows_g, k)
        if use_kernel:
            b, a = ellpack_relax(offers, blk_idx, blk_w, interpret=interpret)
        else:
            b, a = ellpack_relax_ref(offers, blk_idx, blk_w)
        bests.append(b)
        args_.append(a)
        off += rows_g * k
    return jnp.concatenate(bests), jnp.concatenate(args_)


def overflow_min(offers: jax.Array, osrc: jax.Array, odst: jax.Array,
                 ow: jax.Array, nrows: int):
    """The overflow lane: the segment backend's scatter-min on the hub
    surplus.  ``odst`` must already be local row ids in [0, nrows)."""
    ocand = offers[osrc] + ow              # +inf entries can never win
    obest = jnp.minimum(
        jax.ops.segment_min(ocand, odst, num_segments=nrows), INF)
    ohit = (ocand == obest[odst]) & (ocand < INF)
    oarg = jax.ops.segment_min(jnp.where(ohit, osrc, _INT_MAX), odst,
                               num_segments=nrows)
    return obest, oarg


def combine_lanes(best: jax.Array, arg: jax.Array, obest: jax.Array,
                  oarg: jax.Array):
    """Min-combine the two lanes per row.  Parent ties break toward the
    smallest in-neighbor id ACROSS both lanes — each lane already reports
    its smallest minimizing id, so the combine is a scalar min per row —
    which keeps (dist, parent) bit-identical to the segment and dense-ELL
    backends."""
    comb = jnp.minimum(best, obest)
    ell_key = jnp.where((best == comb) & (best < INF), arg, _INT_MAX)
    coo_key = jnp.where((obest == comb) & (obest < INF), oarg, _INT_MAX)
    return comb, jnp.minimum(ell_key, coo_key)


@partial(jax.jit, static_argnames=("widths", "slice_rows", "num_vertices",
                                   "use_kernel", "interpret", "use_fused"))
def sliced_relax_wave(dist: jax.Array, parent: jax.Array,
                      st: SlicedEllState, *, widths: tuple[int, ...],
                      slice_rows: int, num_vertices: int,
                      frontier: jax.Array | None = None,
                      use_kernel: bool = False, interpret: bool = True,
                      use_fused: bool = False):
    """One hybrid relaxation wave: per-slice ELL gather+row-min min-combined
    with a segment-min over the overflow COO lane.

    ``use_fused`` routes the whole wave — frontier masking, ELL lane,
    overflow lane, lane combine — through the single fused Pallas kernel
    (kernels/relax/fused.py, DESIGN.md §9.4) instead of the three-dispatch
    composition below; both paths are bit-identical by construction."""
    n = dist.shape[0]
    if use_fused:
        from repro.kernels.relax.fused import fused_sliced_relax
        act = (jnp.ones(dist.shape, jnp.bool_) if frontier is None
               else frontier)
        comb, new_parent = fused_sliced_relax(
            dist, act, st.flat_idx, st.flat_w, st.osrc, st.odst, st.ow,
            widths=widths, slice_rows=slice_rows, interpret=interpret)
        comb, new_parent = comb[:n], new_parent[:n]
    else:
        offers = dist if frontier is None else jnp.where(frontier, dist, INF)
        best, arg = sliced_gather_min(
            offers, st.flat_idx, st.flat_w, widths=widths,
            slice_rows=slice_rows, use_kernel=use_kernel,
            interpret=interpret)
        best, arg = best[:n], arg[:n]
        obest, oarg = overflow_min(offers, st.osrc, st.odst, st.ow,
                                   num_vertices)
        comb, new_parent = combine_lanes(best, arg, obest, oarg)
    improved = comb < dist
    return (jnp.where(improved, comb, dist),
            jnp.where(improved, new_parent, parent),
            improved)


# ------------------------------------------------------------------ epochs --
@partial(jax.jit, static_argnames=("widths", "slice_rows", "num_vertices",
                                   "max_rounds", "use_kernel", "interpret",
                                   "use_fused"))
def sliced_relax_until_converged(
    sssp: SSSPState,
    st: SlicedEllState,
    frontier: jax.Array,
    *,
    widths: tuple[int, ...],
    slice_rows: int,
    num_vertices: int,
    max_rounds: int = 0,
    use_kernel: bool = False,
    interpret: bool = True,
    use_fused: bool = False,
) -> tuple[SSSPState, RelaxStats]:
    """Sliced rendering of relax.relax_until_converged: frontier-masked
    hybrid waves to fixpoint.  Same candidate sets, same tie-break =>
    bit-identical results and stats."""

    def cond(carry):
        _, _, frontier, rounds, _ = carry
        go = jnp.any(frontier)
        if max_rounds:
            go = go & (rounds < max_rounds)
        return go

    def body(carry):
        dist, parent, frontier, rounds, msgs = carry
        dist, parent, improved = sliced_relax_wave(
            dist, parent, st, widths=widths, slice_rows=slice_rows,
            num_vertices=num_vertices, frontier=frontier,
            use_kernel=use_kernel, interpret=interpret,
            use_fused=use_fused)
        return (dist, parent, improved, rounds + 1,
                msgs + jnp.sum(improved.astype(jnp.int32)))

    dist, parent, _, rounds, msgs = jax.lax.while_loop(
        cond, body,
        (sssp.dist, sssp.parent, frontier, jnp.int32(0), jnp.int32(0)),
    )
    return (
        SSSPState(dist=dist, parent=parent, source=sssp.source),
        RelaxStats(rounds=rounds, messages=msgs),
    )


@partial(jax.jit, static_argnames=("widths", "slice_rows", "num_vertices",
                                   "use_doubling", "use_kernel",
                                   "interpret", "use_fused"))
def sliced_invalidate_and_recompute(
    sssp: SSSPState,
    st: SlicedEllState,
    seed: jax.Array,
    *,
    widths: tuple[int, ...],
    slice_rows: int,
    num_vertices: int,
    use_doubling: bool = True,
    use_kernel: bool = False,
    interpret: bool = True,
    use_fused: bool = False,
) -> tuple[SSSPState, del_mod.DeleteStats]:
    """Deletion epoch on the hybrid layout — structurally identical to
    the dense-ELL deletion epoch (same marking, same bulk-pull-as-one-
    unmasked-wave, same stat gating on ``any(seed)``), with the hybrid wave
    so hub rows also pull offers through the overflow lane."""
    any_seed = jnp.any(seed)
    mark = (del_mod.mark_subtree_doubling if use_doubling
            else del_mod.mark_subtree_flood)
    aff, inv_rounds = mark(sssp.parent, seed)
    aff = aff.at[sssp.source].set(False)

    dist = jnp.where(aff, INF, sssp.dist)
    parent = jnp.where(aff, NO_PARENT, sssp.parent)

    dist_p, parent_p, improved = sliced_relax_wave(
        dist, parent, st, widths=widths, slice_rows=slice_rows,
        num_vertices=num_vertices, use_kernel=use_kernel,
        interpret=interpret, use_fused=use_fused)
    improved = improved & aff
    dist = jnp.where(improved, dist_p, dist)
    parent = jnp.where(improved, parent_p, parent)

    state1 = SSSPState(dist=dist, parent=parent, source=sssp.source)
    state2, stats = sliced_relax_until_converged(
        state1, st, improved, widths=widths, slice_rows=slice_rows,
        num_vertices=num_vertices, use_kernel=use_kernel,
        interpret=interpret, use_fused=use_fused)
    zero = jnp.int32(0)
    return state2, del_mod.DeleteStats(
        invalidation_rounds=jnp.where(any_seed, inv_rounds, zero),
        affected=jnp.sum(aff.astype(jnp.int32)),
        recompute_rounds=jnp.where(any_seed, stats.rounds + 1, zero),
        recompute_messages=jnp.where(
            any_seed,
            stats.messages + jnp.sum(improved.astype(jnp.int32)), zero),
    )


@partial(jax.jit, static_argnames=("widths", "slice_rows", "num_vertices",
                                   "use_kernel", "interpret",
                                   "use_fused"))
def sliced_relax_batched(sssp, st, frontier, *, widths, slice_rows,
                         num_vertices, use_kernel=False, interpret=True,
                         use_fused=False):
    """Batched multi-source rendering (DESIGN.md §8): jit(vmap(epoch)) over
    the [S, N] tree stack, the shared hybrid layout captured unbatched."""
    return jax.vmap(
        lambda s: sliced_relax_until_converged(
            s, st, frontier, widths=widths, slice_rows=slice_rows,
            num_vertices=num_vertices, use_kernel=use_kernel,
            interpret=interpret, use_fused=use_fused))(sssp)


@partial(jax.jit, static_argnames=("widths", "slice_rows", "num_vertices",
                                   "use_doubling", "use_kernel",
                                   "interpret", "use_fused"))
def sliced_delete_batched(sssp, st, seed, *, widths, slice_rows,
                          num_vertices, use_doubling=True, use_kernel=False,
                          interpret=True, use_fused=False):
    """Batched deletion epoch: per-lane [S, N] seeds over the shared layout."""
    return jax.vmap(
        lambda s, sd: sliced_invalidate_and_recompute(
            s, st, sd, widths=widths, slice_rows=slice_rows,
            num_vertices=num_vertices, use_doubling=use_doubling,
            use_kernel=use_kernel, interpret=interpret,
            use_fused=use_fused))(sssp, seed)


@partial(jax.jit, static_argnames=("widths", "slice_rows", "num_vertices",
                                   "bucket_width", "use_kernel",
                                   "interpret", "use_fused"))
def sliced_drain(sssp, st, pend, *, widths, slice_rows, num_vertices: int,
                 bucket_width: float, use_kernel: bool = False,
                 interpret: bool = True, use_fused: bool = False):
    """Bucketed drain on the hybrid layout (DESIGN.md §9) — same pull
    pattern as the deletion epoch (one unmasked hybrid wave, improvements
    applied to affected rows only), so the drain's wave sequence and stats
    stay bit-identical to the segment and dense-ELL drains."""
    from repro.core import buckets

    def wave(dist, parent, active):
        return sliced_relax_wave(
            dist, parent, st, widths=widths, slice_rows=slice_rows,
            num_vertices=num_vertices, frontier=active,
            use_kernel=use_kernel, interpret=interpret,
            use_fused=use_fused)

    def pull_wave(dist, parent, aff):
        dist_p, parent_p, improved = sliced_relax_wave(
            dist, parent, st, widths=widths, slice_rows=slice_rows,
            num_vertices=num_vertices, use_kernel=use_kernel,
            interpret=interpret, use_fused=use_fused)
        improved = improved & aff
        return (jnp.where(improved, dist_p, dist),
                jnp.where(improved, parent_p, parent), improved)

    dist, parent, stats = buckets.run_drain(
        sssp.dist, sssp.parent, pend, bucket_width=bucket_width,
        wave=wave, pull_wave=pull_wave)
    return (SSSPState(dist=dist, parent=parent, source=sssp.source),
            buckets.empty_pending(num_vertices), stats)


@partial(jax.jit, static_argnames=("widths", "slice_rows", "num_vertices",
                                   "bucket_width", "use_kernel",
                                   "interpret", "use_fused"))
def sliced_drain_batched(sssp, st, pend, *, widths, slice_rows,
                         num_vertices: int, bucket_width: float,
                         use_kernel: bool = False, interpret: bool = True,
                         use_fused: bool = False):
    return jax.vmap(
        lambda s, pd: sliced_drain(
            s, st, pd, widths=widths, slice_rows=slice_rows,
            num_vertices=num_vertices, bucket_width=bucket_width,
            use_kernel=use_kernel, interpret=interpret,
            use_fused=use_fused))(sssp, pend)


# ------------------------------------------------------------ host planner --
class SlicedPlan(NamedTuple):
    """One ADD batch's placement: ELL cells + overflow spills (all numpy,
    planner-local row/position space)."""

    pos: np.ndarray    # i32[e] flat ELL cell positions (base[row] + kpos)
    rows: np.ndarray   # i32[e]
    kpos: np.ndarray   # i32[e]
    src: np.ndarray    # i32[e]
    w: np.ndarray      # f32[e]
    opos: np.ndarray   # i32[s] overflow entry positions
    osrc: np.ndarray   # i32[s]
    orows: np.ndarray  # i32[s]
    ow: np.ndarray     # f32[s]


class SlicedEllPlanner:
    """Host control plane for the hybrid layout (DESIGN.md §6): assigns ELL
    cells and overflow entries, detects per-slice / overflow exhaustion, and
    rebuilds from the host COO mirror with monotone per-slice capacity
    doubling (each slice's width doubles independently, capped at ``hub_k``;
    the overflow capacity doubles when the live surplus outgrows it).

    Hub threshold policy: a row whose fill reaches ``hub_k`` is a hub — its
    further in-edges spill to the overflow segment instead of widening the
    whole slice.  Rows below the threshold that outgrow their slice width
    trigger a rebuild, which doubles that slice's width only.

    ``row0`` makes the planner window-local: it accepts *global* destination
    ids for the vertex window ``[row0, row0 + num_vertices)`` and emits
    positions/rows in its own local space (the sharded coordinator
    globalizes them).
    """

    def __init__(self, num_vertices: int, *, slice_rows: int = 256,
                 hub_k: int = 32, init_k: int = 2, row0: int = 0):
        self.n = num_vertices
        self.row0 = row0
        self.sr = min(_next_pow2(max(slice_rows, 1)),
                      _next_pow2(max(num_vertices, 1)))
        self.rows = -(-num_vertices // self.sr) * self.sr
        self.n_slices = self.rows // self.sr
        self.hub_k = _next_pow2(max(hub_k, 1))
        init_k = min(_next_pow2(max(init_k, 1)), self.hub_k)
        self.widths = [init_k] * self.n_slices
        self.fill = np.zeros(self.rows, np.int32)
        self.ocap = 8
        self.ofill = 0
        self.rebuilds = 0
        self.spills = 0
        self._recompute_geometry()

    def _recompute_geometry(self) -> None:
        _, self.rowk, self.base, self.cells = csr_mod.sliced_geometry(
            self.widths, self.sr)

    @property
    def max_width(self) -> int:
        return max(self.widths)

    def empty_state(self) -> SlicedEllState:
        fi, fw, fill, osrc, odst, ow = self.empty_host()
        return SlicedEllState(
            flat_idx=jnp.asarray(fi), flat_w=jnp.asarray(fw),
            fill=jnp.asarray(fill),
            base=jnp.asarray(self.base, jnp.int32),
            rowk=jnp.asarray(self.rowk, jnp.int32),
            osrc=jnp.asarray(osrc), odst=jnp.asarray(odst),
            ow=jnp.asarray(ow))

    def empty_host(self):
        return (np.zeros(self.cells, np.int32),
                np.full(self.cells, INF, np.float32),
                np.zeros(self.rows, np.int32),
                np.zeros(self.ocap, np.int32),
                np.zeros(self.ocap, np.int32),
                np.full(self.ocap, INF, np.float32))

    def plan_appends(self, rows: np.ndarray, src: np.ndarray,
                     w: np.ndarray) -> SlicedPlan | None:
        """Assign each fresh edge (global dst ids) an ELL cell past its
        row's fill mark, or an overflow entry once the row is at the hub
        threshold.  Returns None when a sub-threshold row outgrows its slice
        width or the overflow segment is full — the caller must rebuild
        instead."""
        m = len(rows)
        z32 = np.empty(0, np.int32)
        zf = np.empty(0, np.float32)
        if m == 0:
            return SlicedPlan(z32, z32, z32, z32, zf, z32, z32, z32, zf)
        rows = np.asarray(rows, np.int64) - self.row0
        kcand = self.fill[rows] + rank_within_rows(rows)
        to_ell = kcand < self.rowk[rows]
        over = ~to_ell
        # overflow is only legal past the hub threshold; a sub-threshold row
        # outgrowing its slice width means the slice must double -> rebuild
        if bool((over & (self.rowk[rows] < self.hub_k)).any()):
            return None
        n_spill = int(over.sum())
        if self.ofill + n_spill > self.ocap:
            return None
        # commit
        erows = rows[to_ell]
        ekpos = kcand[to_ell].astype(np.int32)
        np.maximum.at(self.fill, erows, ekpos + 1)
        sp_rank = np.cumsum(over) - 1
        opos = (self.ofill + sp_rank[over]).astype(np.int32)
        self.ofill += n_spill
        self.spills += n_spill
        return SlicedPlan(
            pos=(self.base[erows] + ekpos).astype(np.int32),
            rows=erows.astype(np.int32), kpos=ekpos,
            src=np.asarray(src)[to_ell], w=np.asarray(w)[to_ell],
            opos=opos, osrc=np.asarray(src)[over],
            orows=rows[over].astype(np.int32), ow=np.asarray(w)[over])

    def required_geometry(self, dst: np.ndarray
                          ) -> tuple[list[int], int]:
        """(widths, overflow capacity) this planner's doubling policy wants
        for a live edge set (global dst ids) — used by the sharded
        coordinator to synchronize geometry before a coupled rebuild."""
        deg = np.zeros(self.rows, np.int64)
        if len(dst):
            deg[:self.n] = np.bincount(
                np.asarray(dst, np.int64) - self.row0, minlength=self.n)
        capped = np.minimum(deg, self.hub_k)
        slice_max = capped.reshape(self.n_slices, self.sr).max(axis=1)
        widths = [
            max(cur, min(self.hub_k, _next_pow2(max(2 * int(mx), 1))))
            for cur, mx in zip(self.widths, slice_max)]
        surplus = int((deg - capped).sum())
        ocap = max(self.ocap, _next_pow2(max(2 * surplus, 8)))
        return widths, ocap

    def rebuild_host(self, src: np.ndarray, dst: np.ndarray, w: np.ndarray):
        """Numpy half of ``rebuild`` — the sharded coordinator concatenates
        these blocks partition-major before one sharded transfer.  Returns
        (flat_idx, flat_w, fill, osrc, odst, ow) with ``odst`` in the
        planner's local row space."""
        self.widths, self.ocap = self.required_geometry(dst)
        flat_idx, flat_w, fill, _, osrc, odst, ow, n_over = \
            csr_mod.sliced_ell_from_coo(
                self.n, src, dst, w, slice_rows=self.sr, hub_k=self.hub_k,
                n_rows=self.rows, widths=self.widths,
                overflow_capacity=self.ocap, row0=self.row0)
        self.fill = fill
        self.ofill = n_over
        self.rebuilds += 1
        self._recompute_geometry()
        return flat_idx, flat_w, fill, osrc, odst, ow

    def rebuild(self, src: np.ndarray, dst: np.ndarray, w: np.ndarray
                ) -> SlicedEllState:
        """Rebuild the device layout from the live COO edge set (host
        mirror): tombstones compact away, each slice's width grows to the
        next pow2 of 2x its capped max in-degree (monotone, <= hub_k), and
        the overflow capacity doubles past the live surplus."""
        flat_idx, flat_w, fill, osrc, odst, ow = self.rebuild_host(src, dst, w)
        return SlicedEllState(
            flat_idx=jnp.asarray(flat_idx), flat_w=jnp.asarray(flat_w),
            fill=jnp.asarray(fill), base=jnp.asarray(self.base, jnp.int32),
            rowk=jnp.asarray(self.rowk, jnp.int32),
            osrc=jnp.asarray(osrc), odst=jnp.asarray(odst),
            ow=jnp.asarray(ow))


# ----------------------------------------------------------------- backend --
@register
class SlicedBackend(RelaxBackend):
    """RelaxBackend over the hybrid layout: SlicedEllPlanner host control
    plane, dual-lane patch ops, hybrid epoch waves, coupled per-slice /
    overflow rebuilds from the mirror."""

    name = "sliced"

    def __init__(self, cfg, num_vertices, *, use_kernel=False, interpret=True):
        super().__init__(cfg, num_vertices, use_kernel=use_kernel,
                         interpret=interpret)
        self.use_fused = bool(getattr(cfg, "sliced_fused", False))
        self.planner = self._mk_planner()
        self.state = self.planner.empty_state()

    def _mk_planner(self) -> SlicedEllPlanner:
        return SlicedEllPlanner(
            self.n, slice_rows=self.cfg.sliced_slice_rows,
            hub_k=self.cfg.sliced_hub_k, init_k=self.cfg.sliced_init_k)

    def apply_adds(self, plan, alloc):
        """Incremental hybrid-layout maintenance for one ADD batch
        (DESIGN.md §6).  Fresh edges get planner-assigned ELL cells or — for
        rows at the hub threshold — overflow entries; weight-decreases
        resolve their cell/entry on device.  Slice-width or overflow
        exhaustion triggers a full rebuild from the host COO mirror (which
        already contains this batch, so no patch follows)."""
        fresh = plan.fresh
        sp = self.planner.plan_appends(
            plan.dst[fresh].astype(np.int64), plan.src[fresh], plan.w[fresh])
        if sp is None:
            self.state = self.planner.rebuild(*alloc.active_coo())
            return
        if len(sp.pos):
            pos_p, rows_p, kpos_p, src_p, w_p = ingest.pad_pow2(
                sp.pos, sp.rows, sp.kpos, sp.src, sp.w)
            self.state = sliced_append(
                self.state, jnp.asarray(pos_p), jnp.asarray(rows_p),
                jnp.asarray(kpos_p), jnp.asarray(src_p), jnp.asarray(w_p))
        if len(sp.opos):
            opos_p, osrc_p, orows_p, ow_p = ingest.pad_pow2(
                sp.opos, sp.osrc, sp.orows, sp.ow)
            self.state = sliced_spill(
                self.state, jnp.asarray(opos_p), jnp.asarray(osrc_p),
                jnp.asarray(orows_p), jnp.asarray(ow_p))
        if not fresh.all():
            upd = ~fresh
            rows_p, src_p, w_p = ingest.pad_pow2(
                plan.dst[upd], plan.src[upd], plan.w[upd])
            self.state = sliced_update_min(
                self.state, jnp.asarray(rows_p), jnp.asarray(src_p),
                jnp.asarray(w_p), width=self.planner.max_width)

    def apply_dels(self, rows, src):
        self.state = sliced_delete(
            self.state, jnp.asarray(rows), jnp.asarray(src),
            width=self.planner.max_width)

    def relax(self, sssp, edges, frontier):
        return sliced_relax_until_converged(
            sssp, self.state, frontier,
            widths=tuple(self.planner.widths), slice_rows=self.planner.sr,
            num_vertices=self.n, use_kernel=self.use_kernel,
            interpret=self.interpret, use_fused=self.use_fused)

    def delete(self, sssp, edges, seed):
        return sliced_invalidate_and_recompute(
            sssp, self.state, seed,
            widths=tuple(self.planner.widths), slice_rows=self.planner.sr,
            num_vertices=self.n, use_doubling=self.cfg.use_doubling,
            use_kernel=self.use_kernel, interpret=self.interpret, use_fused=self.use_fused)

    def relax_batched(self, sssp, edges, frontier):
        return sliced_relax_batched(
            sssp, self.state, frontier,
            widths=tuple(self.planner.widths), slice_rows=self.planner.sr,
            num_vertices=self.n, use_kernel=self.use_kernel,
            interpret=self.interpret, use_fused=self.use_fused)

    def delete_batched(self, sssp, edges, seed):
        return sliced_delete_batched(
            sssp, self.state, seed,
            widths=tuple(self.planner.widths), slice_rows=self.planner.sr,
            num_vertices=self.n, use_doubling=self.cfg.use_doubling,
            use_kernel=self.use_kernel, interpret=self.interpret, use_fused=self.use_fused)

    def drain(self, sssp, edges, pend, *, bucket_width):
        return sliced_drain(
            sssp, self.state, pend,
            widths=tuple(self.planner.widths), slice_rows=self.planner.sr,
            num_vertices=self.n, bucket_width=bucket_width,
            use_kernel=self.use_kernel, interpret=self.interpret, use_fused=self.use_fused)

    def drain_batched(self, sssp, edges, pend, *, bucket_width):
        return sliced_drain_batched(
            sssp, self.state, pend,
            widths=tuple(self.planner.widths), slice_rows=self.planner.sr,
            num_vertices=self.n, bucket_width=bucket_width,
            use_kernel=self.use_kernel, interpret=self.interpret, use_fused=self.use_fused)

    def restore(self, alloc):
        self.planner = self._mk_planner()
        self.state = self.planner.rebuild(*alloc.active_coo())

    def invariants(self):
        return sliced_invariants(self.state, width=self.planner.max_width)


# ----------------------------------------------------------- sharded side --
@register_sharded
class ShardedSliced(ShardedBackend):
    """One shard-local SlicedEllPlanner per partition + the per-shard flat
    buffers / overflow segments concatenated partition-major into globally
    sharded device arrays.

    Row space: vertex ``v`` (owner ``p``) lives in global ELL row
    ``p * rows_pp + (v % npp)``; flat cell positions globalize as
    ``p * L + local`` and overflow entries as ``p * ocap + local``.
    Per-slice widths and the overflow capacity are synchronized across
    shards at rebuild time (elementwise max of the per-shard policies) so
    every shard shares one static flat geometry; any shard's exhaustion
    triggers a coupled rebuild of all shards from the mirrors.
    """

    name = "sliced"
    n_extra = 5   # (flat_idx, flat_w, osrc, odst, ow) — what the wave reads

    def __init__(self, cfg, ds, allocs):
        super().__init__(cfg, ds, allocs)
        self.P, self.npp = ds.P, ds.npp
        on_tpu = jax.default_backend() == "tpu"
        self.use_kernel = (on_tpu if cfg.ell_use_kernel is None
                           else cfg.ell_use_kernel)
        self.interpret = not on_tpu
        self.planners = [
            SlicedEllPlanner(self.npp, slice_rows=cfg.sliced_slice_rows,
                             hub_k=cfg.sliced_hub_k,
                             init_k=cfg.sliced_init_k, row0=p * self.npp)
            for p in range(self.P)]
        p0 = self.planners[0]
        self.sr, self.rows_pp = p0.sr, p0.rows
        self._sh = ds.vertex_sharding()   # dim-0 sharding, any rank
        self._put_blocks([pl.empty_host() for pl in self.planners])

    # ---- geometry / assembly
    @property
    def widths(self) -> list[int]:
        return self.planners[0].widths    # synchronized across shards

    @property
    def max_width(self) -> int:
        return self.planners[0].max_width

    @property
    def L(self) -> int:
        return self.planners[0].cells

    @property
    def ocap(self) -> int:
        return self.planners[0].ocap

    def _put_blocks(self, blocks) -> None:
        p0, L, ocap = self.planners[0], self.L, self.ocap
        base_g = np.concatenate(
            [p * L + p0.base for p in range(self.P)]).astype(np.int32)
        rowk_g = np.tile(p0.rowk, self.P)
        # overflow odst globalizes into ELL-row space (padding entries sit
        # at each shard's row 0 with w=+inf — they never win a min)
        parts = []
        for p, b in enumerate(blocks):
            fi, fw, fill, osrc, odst, ow = b
            parts.append((fi, fw, fill, osrc,
                          (p * self.rows_pp + odst).astype(np.int32), ow))
        cat = [np.concatenate([b[i] for b in parts]) for i in range(6)]
        put = lambda a: jax.device_put(a, self._sh)  # noqa: E731
        self.state = SlicedEllState(
            flat_idx=put(cat[0]), flat_w=put(cat[1]), fill=put(cat[2]),
            base=put(base_g), rowk=put(rowk_g),
            osrc=put(cat[3]), odst=put(cat[4]), ow=put(cat[5]))

    def _pin(self) -> None:
        """Re-pin the patched arrays to the partition sharding (device-to-
        device, async — the ingest loop stays host-sync free).  On a P=1
        mesh any layout is trivially correctly sharded, so the per-batch
        device_put dispatches would be pure overhead — skip them."""
        if self.P == 1:
            return
        put = lambda a: jax.device_put(a, self._sh)  # noqa: E731
        st = self.state
        self.state = SlicedEllState(
            flat_idx=put(st.flat_idx), flat_w=put(st.flat_w),
            fill=put(st.fill), base=st.base, rowk=st.rowk,
            osrc=put(st.osrc), odst=put(st.odst), ow=put(st.ow))

    def _ellrows(self, p: int, rows_local: np.ndarray) -> np.ndarray:
        return (p * self.rows_pp
                + np.asarray(rows_local, np.int64)).astype(np.int32)

    def arrays(self):
        st = self.state
        return (st.flat_idx, st.flat_w, st.osrc, st.odst, st.ow)

    def static_key(self):
        return (self.name, tuple(self.widths), self.sr,
                self.use_kernel, self.interpret)

    # ---- patch staging
    def stage_adds(self, plans) -> None:
        app, spill, upd = [], [], []
        for p, plan in plans:
            fresh = plan.fresh
            sp = self.planners[p].plan_appends(
                plan.dst[fresh].astype(np.int64), plan.src[fresh],
                plan.w[fresh])
            if sp is None:
                self._rebuild_all()   # mirrors already contain this batch
                return
            if len(sp.pos):
                app.append(((p * self.L + sp.pos).astype(np.int32),
                            self._ellrows(p, sp.rows), sp.kpos, sp.src, sp.w))
            if len(sp.opos):
                spill.append(((p * self.ocap + sp.opos).astype(np.int32),
                              sp.osrc, self._ellrows(p, sp.orows), sp.ow))
            if not fresh.all():
                u = ~fresh
                lrows = plan.dst[u].astype(np.int64) - p * self.npp
                upd.append((self._ellrows(p, lrows), plan.src[u], plan.w[u]))
        if app:
            pos, rows, kpos, src, w = (np.concatenate(x) for x in zip(*app))
            pos, rows, kpos, src, w = ingest.pad_pow2(pos, rows, kpos, src, w)
            self.state = sliced_append(
                self.state, jnp.asarray(pos), jnp.asarray(rows),
                jnp.asarray(kpos), jnp.asarray(src), jnp.asarray(w))
        if spill:
            opos, osrc, orows, ow = (np.concatenate(x) for x in zip(*spill))
            opos, osrc, orows, ow = ingest.pad_pow2(opos, osrc, orows, ow)
            self.state = sliced_spill(
                self.state, jnp.asarray(opos), jnp.asarray(osrc),
                jnp.asarray(orows), jnp.asarray(ow))
        if upd:
            rows, src, w = (np.concatenate(x) for x in zip(*upd))
            rows, src, w = ingest.pad_pow2(rows, src, w)
            self.state = sliced_update_min(
                self.state, jnp.asarray(rows), jnp.asarray(src),
                jnp.asarray(w), width=self.max_width)
        if app or spill or upd:
            self._pin()

    def update_del_arrays(self, new_vals) -> None:
        flat_w, ow = new_vals
        self.state = dataclasses.replace(self.state, flat_w=flat_w, ow=ow)

    # ---- coupled rebuild / restore
    def _rebuild_all(self) -> None:
        want_w = list(self.widths)
        want_ocap = self.ocap
        for pl, alloc in zip(self.planners, self.allocs):
            w_p, ocap_p = pl.required_geometry(alloc.active_coo()[1])
            want_w = [max(a, b) for a, b in zip(want_w, w_p)]
            want_ocap = max(want_ocap, ocap_p)
        for pl in self.planners:
            pl.widths = list(want_w)
            pl.ocap = want_ocap
        self._put_blocks([pl.rebuild_host(*alloc.active_coo())
                          for pl, alloc in zip(self.planners, self.allocs)])

    def restore(self) -> None:
        self.planners = [
            SlicedEllPlanner(self.npp, slice_rows=self.cfg.sliced_slice_rows,
                             hub_k=self.cfg.sliced_hub_k,
                             init_k=self.cfg.sliced_init_k, row0=p * self.npp)
            for p in range(self.P)]
        self._rebuild_all()

    # ---- wave / in-epoch DEL patch
    @classmethod
    def shard_wave_factory(cls, static, npp):
        _, widths, sr, use_kernel, interpret = static
        rows_pp = len(widths) * sr

        def make_wave(esrc, edst, ew, eact, extras, my_p):
            flat_idx, flat_w, osrc, odst, ow = extras
            row0_ell = my_p * rows_pp

            def wave(offers):
                best, arg = sliced_gather_min(
                    offers, flat_idx, flat_w, widths=widths, slice_rows=sr,
                    use_kernel=use_kernel, interpret=interpret)
                best, arg = best[:npp], arg[:npp]
                dl = jnp.clip(odst - row0_ell, 0, npp - 1)
                obest, oarg = overflow_min(offers, osrc, dl, ow, npp)
                return combine_lanes(best, arg, obest, oarg)

            return wave

        return make_wave

    del_mutated = (1, 4)   # flat_w, ow

    @classmethod
    def shard_del_patch(cls, static, npp):
        _, widths, sr, _, _ = static
        rows_pp = len(widths) * sr
        _, rowk_np, base_np, _ = csr_mod.sliced_geometry(list(widths), sr)
        width = max(widths)

        def patch(extras, psrc, pdst, my_p):
            """Tombstone deleted edges in this shard's blocks, both lanes:
            the in-epoch rendering of ``sliced_delete`` against the shard's
            LOCAL geometry (static base/rowk from the synced widths).
            Foreign/unmatched entries no-op under the -inf/max combine."""
            flat_idx, flat_w, osrc, odst, ow = extras
            L = flat_w.shape[0]
            base_l = jnp.asarray(base_np, jnp.int32)
            rowk_l = jnp.asarray(rowk_np, jnp.int32)
            lrow = pdst - my_p * npp
            in_r = (lrow >= 0) & (lrow < npp)
            rows = jnp.clip(lrow, 0, rows_pp - 1)
            m = pdst.shape[0]
            k_iota = jax.lax.broadcasted_iota(jnp.int32, (m, width), 1)
            pos = jnp.clip(base_l[rows][:, None] + k_iota, 0, L - 1)
            in_row = k_iota < rowk_l[rows][:, None]
            hit = (in_r[:, None] & in_row
                   & (flat_idx[pos] == psrc[:, None])
                   & jnp.isfinite(flat_w[pos]))
            kbest = jnp.argmax(hit, axis=1)
            sel = jnp.take_along_axis(pos, kbest[:, None], axis=1)[:, 0]
            found = jnp.any(hit, axis=1)
            flat_w = flat_w.at[sel].max(jnp.where(found, INF, _NEG_INF))
            # overflow lane: this shard's odst block holds global ELL rows
            # of the form my_p*rows_pp + local_vertex_row
            odst_l = odst - my_p * rows_pp
            ohit = (jnp.isfinite(ow)[None, :] & in_r[:, None]
                    & (osrc[None, :] == psrc[:, None])
                    & (odst_l[None, :] == lrow[:, None]))
            opos = jnp.argmax(ohit, axis=1)
            ofound = jnp.any(ohit, axis=1)
            ow = ow.at[opos].max(jnp.where(ofound, INF, _NEG_INF))
            return flat_w, ow

        return patch
