"""Segment backend — the portable COO scatter-min relaxation (DESIGN.md §2.1).

The layout IS the edge pool: no derived device state, no planner, no patch
ops — ``apply_adds`` / ``apply_dels`` are no-ops and the epochs run straight
over ``core/relax.py`` / ``core/delete.py``.

The sharded wave (``shard_segment_wave``) is the shard-local rendering of
``relax.relax_round``'s candidate evaluation: a segment-min over the shard's
in-edge pool slice with the smallest-src-id tie-break.  It is the single
source of truth for the segment-min used by both ``DistributedSSSP``'s
static epochs and the sharded dynamic engine's backend'd epochs.

Batched multi-source serving (DESIGN.md §8) needs nothing special here:
the epochs and the wave are pure jnp scatter-mins, so the base protocol's
``relax_batched``/``delete_batched`` vmap and the sharded engine's
``jax.vmap(wave)`` over the source axis apply directly.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import delete as del_mod
from repro.core import relax
from repro.core.backends.base import (RelaxBackend, ShardedBackend, register,
                                      register_sharded)
from repro.core.state import INF

_BIG = jnp.int32(2**31 - 1)


# Batched multi-source epochs (DESIGN.md §8): module-level jit(vmap(epoch))
# so repeated batched ingest hits the pjit fast path instead of re-tracing
# a fresh vmap wrapper per event batch (see base.RelaxBackend notes).
@partial(jax.jit, static_argnames=("num_vertices",))
def segment_relax_batched(sssp, edges, frontier, *, num_vertices: int):
    return jax.vmap(
        lambda s: relax.relax_until_converged(
            s, edges, frontier, num_vertices=num_vertices))(sssp)


@partial(jax.jit, static_argnames=("num_vertices", "use_doubling"))
def segment_delete_batched(sssp, edges, seed, *, num_vertices: int,
                           use_doubling: bool):
    return jax.vmap(
        lambda s, sd: del_mod.invalidate_and_recompute(
            s, edges, sd, num_vertices=num_vertices,
            use_doubling=use_doubling))(sssp, seed)


def shard_segment_wave(esrc, edst, ew, eact, row0, npp: int):
    """Local segment-min wave over one shard's in-edge pool slice.

    ``wave(offers) -> (best, arg)``: per owned row, the min of
    ``offers[src] + w`` over live in-edges and the smallest minimizing
    global src id (``2**31-1`` when no live candidate).  Frontier masking is
    carried by ``offers`` (+inf for non-offering sources), which makes the
    same wave serve relaxation rounds, delta rounds and the deletion pull.
    """

    def wave(offers):
        cand = jnp.where(eact, offers[esrc] + ew, INF)
        dl = edst - row0
        best = jnp.minimum(
            jax.ops.segment_min(cand, dl, num_segments=npp), INF)
        hit = (cand == best[dl]) & (cand < INF)
        arg = jax.ops.segment_min(jnp.where(hit, esrc, _BIG), dl,
                                  num_segments=npp)
        return best, arg

    return wave


@register
class SegmentBackend(RelaxBackend):
    """No derived layout: epochs scatter-min over the flat COO pool."""

    name = "segment"

    def relax(self, sssp, edges, frontier):
        return relax.relax_until_converged(
            sssp, edges, frontier, num_vertices=self.n)

    def delete(self, sssp, edges, seed):
        return del_mod.invalidate_and_recompute(
            sssp, edges, seed, num_vertices=self.n,
            use_doubling=self.cfg.use_doubling)

    def relax_batched(self, sssp, edges, frontier):
        return segment_relax_batched(sssp, edges, frontier,
                                     num_vertices=self.n)

    def delete_batched(self, sssp, edges, seed):
        return segment_delete_batched(sssp, edges, seed, num_vertices=self.n,
                                      use_doubling=self.cfg.use_doubling)

    def drain(self, sssp, edges, pend, *, bucket_width):
        from repro.core import buckets
        return buckets.segment_drain(sssp, edges, pend, num_vertices=self.n,
                                     bucket_width=bucket_width)

    def drain_batched(self, sssp, edges, pend, *, bucket_width):
        from repro.core import buckets
        return buckets.segment_drain_batched(
            sssp, edges, pend, num_vertices=self.n, bucket_width=bucket_width)


@register_sharded
class ShardedSegment(ShardedBackend):
    """Sharded coordinator with nothing to coordinate: the pool patched by
    the epochs is the layout, so every hook is a no-op."""

    name = "segment"
    n_extra = 0

    @classmethod
    def shard_wave_factory(cls, static, npp):
        def make_wave(esrc, edst, ew, eact, extras, my_p):
            return shard_segment_wave(esrc, edst, ew, eact,
                                      my_p * npp, npp)
        return make_wave
