"""Dense ELLPACK backend: incrementally maintained by-destination ELL block
(DESIGN.md §2), behind the RelaxBackend protocol (§7).

The segment backend scatter-reduces over the flat COO edge pool; this module
keeps a second, TPU-native view of the same graph and maintains it
*incrementally* under ADD/DEL batches:

  * ADD  — the host planner assigns each new edge a (row, k) cell past the
    row's fill high-water mark; the device patch is one idempotent scatter.
  * DEL  — resolved entirely on device: each deleted edge's cell is found by
    matching the source id in its destination row and tombstoned (w := +inf).
    No host map of ELL positions exists at all.
  * weight-decrease (``on_duplicate="min"``) — device-side match + min-scatter.
  * overflow — when a row's fill would exceed K, the planner rebuilds the
    whole block from the host COO mirror with K doubled (next pow2 of twice
    the max in-degree) and tombstones compacted away.  O(E) numpy + one
    transfer, amortized over the doublings.

All patch ops are jitted, tolerate pad_pow2-repeated rows (their scatters are
idempotent or min/max-combined), and never read device memory back.

Epoch functions mirror core/relax.py and core/delete.py exactly — same
frontier evolution, same smallest-src-id tie-break — so (dist, parent) are
bit-identical between the backends (test_backend_equiv.py).

Sharded participation (§7.2): ``ShardedEllpack`` holds one shard-local
planner per partition (each planning rows for its owned vertex window via
the planner's ``row0``) and the per-shard ELL blocks concatenated
partition-major into globally sharded device arrays; K is synchronized
across shards at rebuild time so the shard_map epochs see one static block
shape.

Batched multi-source serving (§8): the ELL block is source-independent —
one layout serves every lane.  The epochs vmap over the stacked [S, N]
dist/parent (base protocol ``relax_batched``/``delete_batched``; the
sharded engine vmaps the wave), with the block arrays captured unbatched.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import buckets
from repro.core import delete as del_mod
from repro.core import ingest
from repro.core.backends.base import (ELL_BLOWUP_RATIO, RelaxBackend,
                                      ShardedBackend, register,
                                      register_sharded, rank_within_rows)
from repro.core.relax import RelaxStats
from repro.core.state import INF, NO_PARENT, SSSPState
from repro.graphs import csr as csr_mod
from repro.kernels.relax.ops import relax_wave

_NEG_INF = jnp.float32(-jnp.inf)
_next_pow2 = csr_mod.next_pow2


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EllState:
    """Device-resident dense-ELL view of the active edge set (one global K;
    the hub-aware sliced/hybrid variant lives in backends/sliced.py).

    ``fill`` is each row's occupancy high-water mark: cells at k >= fill[r]
    have never been written; cells below it are live edges or tombstones
    (w == +inf).  Rows n..R-1 are kernel block padding and stay empty.
    """

    nbr_idx: jax.Array  # i32[R, K] in-neighbor ids (0 where empty/tombstone)
    nbr_w: jax.Array    # f32[R, K] weights (+inf where empty/tombstone)
    fill: jax.Array     # i32[R]

    @property
    def k(self) -> int:
        return self.nbr_w.shape[1]

    @property
    def rows(self) -> int:
        return self.nbr_w.shape[0]


# --------------------------------------------------------------- patch ops --
@jax.jit
def ell_append(ell: EllState, rows: jax.Array, kpos: jax.Array,
               src: jax.Array, w: jax.Array) -> EllState:
    """Write fresh edges into planner-assigned cells (idempotent scatter —
    pad_pow2 repeats of the same (row, kpos, src, w) are no-ops)."""
    return EllState(
        nbr_idx=ell.nbr_idx.at[rows, kpos].set(src),
        nbr_w=ell.nbr_w.at[rows, kpos].set(w),
        fill=ell.fill.at[rows].max(kpos + 1),
    )


def _match_cell(ell: EllState, rows: jax.Array, src: jax.Array):
    """Locate each (src -> rows) edge's live cell: (kpos, found).

    Live edges are unique per (row, src) — the slot allocator dedups — so at
    most one finite-weight cell matches.
    """
    row_idx = ell.nbr_idx[rows]                      # (m, K)
    row_w = ell.nbr_w[rows]                          # (m, K)
    hit = (row_idx == src[:, None]) & jnp.isfinite(row_w)
    return jnp.argmax(hit, axis=1), jnp.any(hit, axis=1)


@jax.jit
def ell_delete(ell: EllState, rows: jax.Array, src: jax.Array) -> EllState:
    """Tombstone deleted edges (w := +inf), located on device by source-id
    match.  Duplicate (row, src) pairs from batch padding collapse to the
    same cell; the max-combine makes the scatter order-free."""
    kpos, found = _match_cell(ell, rows, src)
    val = jnp.where(found, INF, _NEG_INF)            # -inf = no-op under max
    return dataclasses.replace(
        ell, nbr_w=ell.nbr_w.at[rows, kpos].max(val))


@jax.jit
def ell_update_min(ell: EllState, rows: jax.Array, src: jax.Array,
                   w: jax.Array) -> EllState:
    """Weight-decrease of existing edges (on_duplicate="min"): device-side
    match + min-scatter (+inf = no-op for unmatched/padded entries)."""
    kpos, found = _match_cell(ell, rows, src)
    val = jnp.where(found, w, INF)
    return dataclasses.replace(
        ell, nbr_w=ell.nbr_w.at[rows, kpos].min(val))


@jax.jit
def ell_invariants(ell: EllState) -> dict[str, jax.Array]:
    """Occupancy invariants over the device fill marks (diagnostics/tests):
    every cell at or past a row's fill mark must be empty (+inf), and fill
    must stay within the block width.  Guards the device copy of the fill
    state against drifting from the host planner's."""
    k_iota = jax.lax.broadcasted_iota(jnp.int32, ell.nbr_w.shape, 1)
    beyond = k_iota >= ell.fill[:, None]
    return {
        "beyond_fill_empty": jnp.all(jnp.where(beyond, jnp.isinf(ell.nbr_w),
                                               True)),
        "fill_in_range": jnp.all((ell.fill >= 0)
                                 & (ell.fill <= ell.nbr_w.shape[1])),
    }


# ------------------------------------------------------------ host planner --
class EllPlanner:
    """Host control plane for the ELL block: assigns append cells, detects
    overflow, and rebuilds (with capacity doubling) from the host COO mirror.

    Keeps only dense per-row fill counts — deletions and weight updates are
    resolved on device, so there is no host map of ELL cell positions.

    ``row0`` makes the planner window-local (DESIGN.md §7.2): it plans rows
    for the vertex window ``[row0, row0 + num_vertices)`` and accepts
    *global* destination ids everywhere — the sharded engine runs one
    planner per partition over its owned window.
    """

    def __init__(self, num_vertices: int, *, block_rows: int = 256,
                 init_k: int = 8, row0: int = 0):
        self.n = num_vertices
        self.row0 = row0
        bm = min(block_rows, _next_pow2(max(num_vertices, 1)))
        self.rows = -(-num_vertices // bm) * bm      # ceil to block multiple
        self.k = max(1, init_k)
        self.fill = np.zeros(self.rows, np.int32)
        self.rebuilds = 0
        self._warned_blowup = False

    def empty_state(self) -> EllState:
        idx, ww, fill = self.empty_host()
        return EllState(nbr_idx=jnp.asarray(idx), nbr_w=jnp.asarray(ww),
                        fill=jnp.asarray(fill))

    def empty_host(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        return (np.zeros((self.rows, self.k), np.int32),
                np.full((self.rows, self.k), INF, np.float32),
                np.zeros(self.rows, np.int32))

    def plan_appends(self, rows: np.ndarray) -> np.ndarray | None:
        """Assign a distinct cell past the fill mark to each fresh edge
        (``rows``: global dst ids within this planner's window).

        Returns kpos i32[m] (and advances the fill marks), or None when any
        row would overflow K — the caller must rebuild instead.
        """
        m = len(rows)
        if m == 0:
            return np.empty(0, np.int32)
        rows = np.asarray(rows, np.int64) - self.row0
        counts = np.bincount(rows, minlength=self.n)
        if int((self.fill[:self.n] + counts[:self.n]).max(initial=0)) > self.k:
            return None
        kpos = self.fill[rows] + rank_within_rows(rows)
        np.maximum.at(self.fill, rows, kpos + 1)
        return kpos.astype(np.int32)

    def required_k(self, dst: np.ndarray) -> int:
        """The K this planner's doubling policy wants for a live edge set
        (global dst ids) — used by the sharded coordinator to synchronize K
        across partitions before a coupled rebuild."""
        deg = self._local_deg(dst)
        return max(self.k, _next_pow2(max(2 * int(deg.max(initial=0)), 1)))

    def _local_deg(self, dst: np.ndarray) -> np.ndarray:
        if not len(dst):
            return np.zeros(self.n, np.int64)
        return np.bincount(np.asarray(dst, np.int64) - self.row0,
                           minlength=self.n)

    def rebuild_host(self, src: np.ndarray, dst: np.ndarray, w: np.ndarray
                     ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Numpy half of ``rebuild`` — the sharded coordinator concatenates
        these blocks partition-major before one sharded transfer."""
        self.k = self.required_k(dst)
        cells, live = self.rows * self.k, len(dst)
        if (live and cells > ELL_BLOWUP_RATIO * live
                and not self._warned_blowup):
            # The power-law-hub pathology (DESIGN.md §6): a few hub rows set
            # the global K and the dense block is mostly +inf padding.
            warnings.warn(
                f"dense-ELL rebuild allocates {cells} cells (K={self.k} x "
                f"{self.rows} rows) for {live} live edges — more than "
                f"{ELL_BLOWUP_RATIO}x blowup; the hub-aware "
                f"relax_backend='sliced' layout (or relax_backend='auto') "
                f"avoids this", RuntimeWarning, stacklevel=3)
            self._warned_blowup = True
        idx, ww, fill = csr_mod.ell_from_coo(
            self.n, src, dst, w, k=self.k, n_rows=self.rows, row0=self.row0)
        self.fill = fill
        self.rebuilds += 1
        return idx, ww, fill

    def rebuild(self, src: np.ndarray, dst: np.ndarray, w: np.ndarray
                ) -> EllState:
        """Rebuild the device block from the live COO edge set (host mirror):
        compacts tombstones and doubles K to the next pow2 of 2x the max
        in-degree when the degree itself (not churn) caused the overflow."""
        idx, ww, fill = self.rebuild_host(src, dst, w)
        return EllState(nbr_idx=jnp.asarray(idx), nbr_w=jnp.asarray(ww),
                        fill=jnp.asarray(fill))


# ------------------------------------------------------------------ epochs --
@partial(jax.jit, static_argnames=("num_vertices", "max_rounds",
                                   "use_kernel", "interpret"))
def ell_relax_until_converged(
    sssp: SSSPState,
    nbr_idx: jax.Array,
    nbr_w: jax.Array,
    frontier: jax.Array,
    *,
    num_vertices: int,
    max_rounds: int = 0,
    use_kernel: bool = False,
    interpret: bool = True,
) -> tuple[SSSPState, RelaxStats]:
    """ELL rendering of relax.relax_until_converged: frontier-masked waves to
    fixpoint.  Same candidate sets, same tie-break => bit-identical results."""

    def cond(carry):
        _, _, frontier, rounds, _ = carry
        go = jnp.any(frontier)
        if max_rounds:
            go = go & (rounds < max_rounds)
        return go

    def body(carry):
        dist, parent, frontier, rounds, msgs = carry
        dist, parent, improved = relax_wave(
            dist, parent, nbr_idx, nbr_w, frontier=frontier,
            use_kernel=use_kernel, interpret=interpret)
        return (dist, parent, improved, rounds + 1,
                msgs + jnp.sum(improved.astype(jnp.int32)))

    dist, parent, _, rounds, msgs = jax.lax.while_loop(
        cond, body,
        (sssp.dist, sssp.parent, frontier, jnp.int32(0), jnp.int32(0)),
    )
    return (
        SSSPState(dist=dist, parent=parent, source=sssp.source),
        RelaxStats(rounds=rounds, messages=msgs),
    )


@partial(jax.jit, static_argnames=("num_vertices", "use_kernel",
                                   "interpret"))
def ell_relax_batched(sssp, nbr_idx, nbr_w, frontier, *, num_vertices: int,
                      use_kernel: bool = False, interpret: bool = True):
    """Batched multi-source rendering (DESIGN.md §8): jit(vmap(epoch)) over
    the [S, N] tree stack, the shared ELL block captured unbatched."""
    return jax.vmap(
        lambda s: ell_relax_until_converged(
            s, nbr_idx, nbr_w, frontier, num_vertices=num_vertices,
            use_kernel=use_kernel, interpret=interpret))(sssp)


@partial(jax.jit, static_argnames=("num_vertices", "use_doubling",
                                   "use_kernel", "interpret"))
def ell_delete_batched(sssp, nbr_idx, nbr_w, seed, *, num_vertices: int,
                       use_doubling: bool = True, use_kernel: bool = False,
                       interpret: bool = True):
    """Batched deletion epoch: per-lane [S, N] seeds over the shared block."""
    return jax.vmap(
        lambda s, sd: ell_invalidate_and_recompute(
            s, nbr_idx, nbr_w, sd, num_vertices=num_vertices,
            use_doubling=use_doubling, use_kernel=use_kernel,
            interpret=interpret))(sssp, seed)


@partial(jax.jit, static_argnames=("num_vertices", "use_doubling",
                                   "use_kernel", "interpret"))
def ell_invalidate_and_recompute(
    sssp: SSSPState,
    nbr_idx: jax.Array,
    nbr_w: jax.Array,
    seed: jax.Array,
    *,
    num_vertices: int,
    use_doubling: bool = True,
    use_kernel: bool = False,
    interpret: bool = True,
) -> tuple[SSSPState, del_mod.DeleteStats]:
    """Deletion epoch on the ELL block (paper Listings 4/8/9).

    Invalidation reuses the parent-forest marking from core/delete.py (it
    does not touch edges).  The bulk DistanceQuery pull is ONE ELL wave: every
    affected row gathers offers from all in-neighbors at once (+inf sources —
    other affected vertices — and tombstones offer nothing), then ordinary
    frontier-masked waves drain the epoch.

    Safe to call with an all-false seed (non-tree deletions): the state is
    returned unchanged and every stat is 0, which lets the engine skip the
    blocking ``bool(jnp.any(seed))`` host sync entirely (DESIGN.md §2.4).
    """
    any_seed = jnp.any(seed)
    mark = (del_mod.mark_subtree_doubling if use_doubling
            else del_mod.mark_subtree_flood)
    aff, inv_rounds = mark(sssp.parent, seed)
    aff = aff.at[sssp.source].set(False)

    dist = jnp.where(aff, INF, sssp.dist)
    parent = jnp.where(aff, NO_PARENT, sssp.parent)

    # Bulk pull: one unmasked wave, improvements applied to affected rows
    # only (matching the segment path's ``aff[dst]`` edge mask; unaffected
    # rows cannot improve anyway — the pre-deletion state was converged).
    dist_p, parent_p, improved = relax_wave(
        dist, parent, nbr_idx, nbr_w,
        use_kernel=use_kernel, interpret=interpret)
    improved = improved & aff
    dist = jnp.where(improved, dist_p, dist)
    parent = jnp.where(improved, parent_p, parent)

    state1 = SSSPState(dist=dist, parent=parent, source=sssp.source)
    state2, stats = ell_relax_until_converged(
        state1, nbr_idx, nbr_w, improved, num_vertices=num_vertices,
        use_kernel=use_kernel, interpret=interpret)
    zero = jnp.int32(0)
    return state2, del_mod.DeleteStats(
        invalidation_rounds=jnp.where(any_seed, inv_rounds, zero),
        affected=jnp.sum(aff.astype(jnp.int32)),
        recompute_rounds=jnp.where(any_seed, stats.rounds + 1, zero),
        recompute_messages=jnp.where(
            any_seed,
            stats.messages + jnp.sum(improved.astype(jnp.int32)), zero),
    )


@partial(jax.jit, static_argnames=("num_vertices", "bucket_width",
                                   "use_kernel", "interpret"))
def ell_drain(sssp, nbr_idx, nbr_w, pend, *, num_vertices: int,
              bucket_width: float, use_kernel: bool = False,
              interpret: bool = True):
    """Bucketed drain on the ELL block (DESIGN.md §9): the pull is the same
    one-unmasked-wave-then-``improved &= aff`` pattern as the deletion epoch,
    so the drain's improved sets — hence its wave sequence and stats — stay
    bit-identical to the segment drain's."""

    def wave(dist, parent, active):
        return relax_wave(dist, parent, nbr_idx, nbr_w, frontier=active,
                          use_kernel=use_kernel, interpret=interpret)

    def pull_wave(dist, parent, aff):
        dist_p, parent_p, improved = relax_wave(
            dist, parent, nbr_idx, nbr_w,
            use_kernel=use_kernel, interpret=interpret)
        improved = improved & aff
        return (jnp.where(improved, dist_p, dist),
                jnp.where(improved, parent_p, parent), improved)

    dist, parent, stats = buckets.run_drain(
        sssp.dist, sssp.parent, pend, bucket_width=bucket_width,
        wave=wave, pull_wave=pull_wave)
    return (SSSPState(dist=dist, parent=parent, source=sssp.source),
            buckets.empty_pending(num_vertices), stats)


@partial(jax.jit, static_argnames=("num_vertices", "bucket_width",
                                   "use_kernel", "interpret"))
def ell_drain_batched(sssp, nbr_idx, nbr_w, pend, *, num_vertices: int,
                      bucket_width: float, use_kernel: bool = False,
                      interpret: bool = True):
    return jax.vmap(
        lambda s, pd: ell_drain(
            s, nbr_idx, nbr_w, pd, num_vertices=num_vertices,
            bucket_width=bucket_width, use_kernel=use_kernel,
            interpret=interpret))(sssp, pend)


# ----------------------------------------------------------------- backend --
@register
class EllpackBackend(RelaxBackend):
    """RelaxBackend over the dense ELL block: EllPlanner host control plane,
    jitted patch ops, ELL epoch waves, doubling rebuilds from the mirror."""

    name = "ellpack"

    def __init__(self, cfg, num_vertices, *, use_kernel=False, interpret=True):
        super().__init__(cfg, num_vertices, use_kernel=use_kernel,
                         interpret=interpret)
        self.planner = EllPlanner(
            num_vertices, block_rows=cfg.ell_block_rows,
            init_k=cfg.ell_init_k)
        self.state = self.planner.empty_state()
        self.blowup = False   # set by rebuilds; read by the "auto" fallback

    def apply_adds(self, plan, alloc):
        """Incremental ELL maintenance for one ADD batch (DESIGN.md §2.3).

        Fresh edges get planner-assigned cells (one idempotent device
        scatter); weight-decreases resolve their cell on device.  Overflow of
        any row's fill mark triggers a full rebuild from the host COO mirror
        — which already contains this batch, so no patch follows.
        """
        fresh = plan.fresh
        rows = plan.dst[fresh].astype(np.int64)
        kpos = self.planner.plan_appends(rows)
        if kpos is None:
            src, dst, w = alloc.active_coo()
            self.state = self.planner.rebuild(src, dst, w)
            # host-visible blowup flag for relax_backend="auto" fallback
            self.blowup = (self.planner.rows * self.planner.k
                           > ELL_BLOWUP_RATIO * max(len(dst), 1))
            return
        if len(rows):
            rows_p, kpos_p, src_p, w_p = ingest.pad_pow2(
                rows.astype(np.int32), kpos, plan.src[fresh], plan.w[fresh])
            self.state = ell_append(
                self.state, jnp.asarray(rows_p), jnp.asarray(kpos_p),
                jnp.asarray(src_p), jnp.asarray(w_p))
        if not fresh.all():
            upd = ~fresh
            rows_p, src_p, w_p = ingest.pad_pow2(
                plan.dst[upd], plan.src[upd], plan.w[upd])
            self.state = ell_update_min(
                self.state, jnp.asarray(rows_p), jnp.asarray(src_p),
                jnp.asarray(w_p))

    def apply_dels(self, rows, src):
        self.state = ell_delete(self.state, jnp.asarray(rows),
                                jnp.asarray(src))

    def relax(self, sssp, edges, frontier):
        return ell_relax_until_converged(
            sssp, self.state.nbr_idx, self.state.nbr_w, frontier,
            num_vertices=self.n, use_kernel=self.use_kernel,
            interpret=self.interpret)

    def delete(self, sssp, edges, seed):
        return ell_invalidate_and_recompute(
            sssp, self.state.nbr_idx, self.state.nbr_w, seed,
            num_vertices=self.n, use_doubling=self.cfg.use_doubling,
            use_kernel=self.use_kernel, interpret=self.interpret)

    def relax_batched(self, sssp, edges, frontier):
        return ell_relax_batched(
            sssp, self.state.nbr_idx, self.state.nbr_w, frontier,
            num_vertices=self.n, use_kernel=self.use_kernel,
            interpret=self.interpret)

    def delete_batched(self, sssp, edges, seed):
        return ell_delete_batched(
            sssp, self.state.nbr_idx, self.state.nbr_w, seed,
            num_vertices=self.n, use_doubling=self.cfg.use_doubling,
            use_kernel=self.use_kernel, interpret=self.interpret)

    def drain(self, sssp, edges, pend, *, bucket_width):
        return ell_drain(
            sssp, self.state.nbr_idx, self.state.nbr_w, pend,
            num_vertices=self.n, bucket_width=bucket_width,
            use_kernel=self.use_kernel, interpret=self.interpret)

    def drain_batched(self, sssp, edges, pend, *, bucket_width):
        return ell_drain_batched(
            sssp, self.state.nbr_idx, self.state.nbr_w, pend,
            num_vertices=self.n, bucket_width=bucket_width,
            use_kernel=self.use_kernel, interpret=self.interpret)

    def restore(self, alloc):
        self.planner = EllPlanner(
            self.n, block_rows=self.cfg.ell_block_rows,
            init_k=self.cfg.ell_init_k)
        self.state = self.planner.rebuild(*alloc.active_coo())

    def invariants(self):
        return ell_invariants(self.state)


# ----------------------------------------------------------- sharded side --
@register_sharded
class ShardedEllpack(ShardedBackend):
    """One shard-local EllPlanner per partition + the per-shard ELL blocks
    concatenated partition-major into globally sharded device arrays.

    Global addressing: vertex ``v`` (owner ``p = v // npp``) lives in ELL
    row ``p * rows_pp + (v % npp)`` — ``rows_pp`` is each shard's
    block-padded row count, identical across shards.  K is synchronized at
    rebuild time (max of the per-shard doubling policies) so shard_map sees
    one static block shape; any shard's overflow triggers a coupled rebuild
    of all shards from the per-partition mirrors.
    """

    name = "ellpack"
    n_extra = 2   # (nbr_idx, nbr_w) — what the wave reads

    def __init__(self, cfg, ds, allocs):
        super().__init__(cfg, ds, allocs)
        self.P, self.npp = ds.P, ds.npp
        on_tpu = jax.default_backend() == "tpu"
        self.use_kernel = (on_tpu if cfg.ell_use_kernel is None
                           else cfg.ell_use_kernel)
        self.interpret = not on_tpu
        self.planners = [
            EllPlanner(self.npp, block_rows=cfg.ell_block_rows,
                       init_k=cfg.ell_init_k, row0=p * self.npp)
            for p in range(self.P)]
        self.rows_pp = self.planners[0].rows
        self._sh = ds.vertex_sharding()   # dim-0 sharding, any rank
        self._put_blocks([pl.empty_host() for pl in self.planners])

    # ---- assembly
    def _put_blocks(self, blocks) -> None:
        idx = np.concatenate([b[0] for b in blocks])
        ww = np.concatenate([b[1] for b in blocks])
        fill = np.concatenate([b[2] for b in blocks])
        self.state = EllState(
            nbr_idx=jax.device_put(idx, self._sh),
            nbr_w=jax.device_put(ww, self._sh),
            fill=jax.device_put(fill, self._sh))

    def _pin(self) -> None:
        """Re-pin the patched arrays to the partition sharding (device-to-
        device, async — the ingest loop stays host-sync free).  On a P=1
        mesh any layout is trivially correctly sharded, so the per-batch
        device_put dispatches would be pure overhead — skip them."""
        if self.P == 1:
            return
        self.state = EllState(
            nbr_idx=jax.device_put(self.state.nbr_idx, self._sh),
            nbr_w=jax.device_put(self.state.nbr_w, self._sh),
            fill=jax.device_put(self.state.fill, self._sh))

    def _ellrows(self, p: int, dst: np.ndarray) -> np.ndarray:
        return (p * self.rows_pp
                + (np.asarray(dst, np.int64) - p * self.npp)).astype(np.int32)

    def arrays(self):
        return (self.state.nbr_idx, self.state.nbr_w)

    def static_key(self):
        return (self.name, self.use_kernel, self.interpret)

    # ---- patch staging
    def stage_adds(self, plans) -> None:
        app, upd = [], []
        for p, plan in plans:
            fresh = plan.fresh
            rows_v = plan.dst[fresh].astype(np.int64)
            kpos = self.planners[p].plan_appends(rows_v)
            if kpos is None:
                self._rebuild_all()   # mirrors already contain this batch
                return
            if len(rows_v):
                app.append((self._ellrows(p, rows_v), kpos,
                            plan.src[fresh], plan.w[fresh]))
            if not fresh.all():
                u = ~fresh
                upd.append((self._ellrows(p, plan.dst[u]),
                            plan.src[u], plan.w[u]))
        if app:
            rows, kpos, src, w = (np.concatenate(x) for x in zip(*app))
            rows, kpos, src, w = ingest.pad_pow2(rows, kpos, src, w)
            self.state = ell_append(
                self.state, jnp.asarray(rows), jnp.asarray(kpos),
                jnp.asarray(src), jnp.asarray(w))
        if upd:
            rows, src, w = (np.concatenate(x) for x in zip(*upd))
            rows, src, w = ingest.pad_pow2(rows, src, w)
            self.state = ell_update_min(
                self.state, jnp.asarray(rows), jnp.asarray(src),
                jnp.asarray(w))
        if app or upd:
            self._pin()

    def update_del_arrays(self, new_vals) -> None:
        (nbr_w,) = new_vals
        self.state = dataclasses.replace(self.state, nbr_w=nbr_w)

    # ---- coupled rebuild / restore
    def _rebuild_all(self) -> None:
        k = max(pl.required_k(alloc.active_coo()[1])
                for pl, alloc in zip(self.planners, self.allocs))
        for pl in self.planners:
            pl.k = k
        self._put_blocks([pl.rebuild_host(*alloc.active_coo())
                          for pl, alloc in zip(self.planners, self.allocs)])

    def restore(self) -> None:
        self.planners = [
            EllPlanner(self.npp, block_rows=self.cfg.ell_block_rows,
                       init_k=self.cfg.ell_init_k, row0=p * self.npp)
            for p in range(self.P)]
        self._rebuild_all()

    # ---- wave / in-epoch DEL patch
    @classmethod
    def shard_wave_factory(cls, static, npp):
        _, use_kernel, interpret = static
        from repro.kernels.relax.ref import ellpack_relax_ref
        from repro.kernels.relax.relax import ellpack_relax

        def make_wave(esrc, edst, ew, eact, extras, my_p):
            nbr_idx, nbr_w = extras

            def wave(offers):
                if use_kernel:
                    best, arg = ellpack_relax(offers, nbr_idx, nbr_w,
                                              interpret=interpret)
                else:
                    best, arg = ellpack_relax_ref(offers, nbr_idx, nbr_w)
                return best[:npp], arg[:npp]

            return wave

        return make_wave

    del_mutated = (1,)   # nbr_w

    @classmethod
    def shard_del_patch(cls, static, npp):
        def patch(extras, psrc, pdst, my_p):
            """Tombstone deleted edges in this shard's ELL block: local
            src-id match (the in-epoch rendering of ``ell_delete``), with
            foreign/unmatched entries no-ops under the -inf/max combine."""
            nbr_idx, nbr_w = extras
            lrow = pdst - my_p * npp
            in_r = (lrow >= 0) & (lrow < npp)
            rows = jnp.clip(lrow, 0, nbr_idx.shape[0] - 1)
            row_idx = nbr_idx[rows]                   # (m, K)
            row_w = nbr_w[rows]
            hit = (in_r[:, None] & (row_idx == psrc[:, None])
                   & jnp.isfinite(row_w))
            kpos = jnp.argmax(hit, axis=1)
            found = jnp.any(hit, axis=1)
            val = jnp.where(found, INF, _NEG_INF)
            return (nbr_w.at[rows, kpos].max(val),)

        return patch
