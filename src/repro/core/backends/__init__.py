"""Relaxation backends for the dynamic engines (DESIGN.md §7).

One ``RelaxBackend`` = layout state + host planner + jitted patch ops +
wave computation + rebuild policy + checkpoint participation.  Importing
this package populates the registries with the three stock backends:

  * ``segment`` — portable COO scatter-min (backends/segment.py);
  * ``ellpack`` — dense by-destination ELL block, incrementally maintained
    (backends/ellpack.py, DESIGN.md §2);
  * ``sliced``  — hub-aware sliced-ELL + overflow-COO hybrid
    (backends/sliced.py, DESIGN.md §6).

``SSSPDelEngine`` consumes single-device backends via ``make_backend``;
``ShardedSSSPDelEngine`` consumes their sharded coordinators via
``make_sharded_backend`` (one shard-local planner per partition, globally
sharded layout arrays, per-partition wave plugged into the shard_map
epochs).
"""
from repro.core.backends.base import (AUTO_BACKEND, BACKENDS,
                                      ELL_BLOWUP_RATIO, SHARDED_BACKENDS,
                                      WAVE_SCHEDULES, RelaxBackend,
                                      ShardedBackend, make_backend,
                                      make_sharded_backend,
                                      validate_backend_config)
from repro.core.backends.segment import SegmentBackend, shard_segment_wave
from repro.core.backends.ellpack import (EllPlanner, EllState, EllpackBackend,
                                         ell_append, ell_delete,
                                         ell_invariants, ell_update_min)
from repro.core.backends.sliced import (SlicedBackend, SlicedEllPlanner,
                                        SlicedEllState, sliced_invariants)

RELAX_BACKENDS = tuple(sorted(BACKENDS))

__all__ = [
    "AUTO_BACKEND", "ELL_BLOWUP_RATIO", "WAVE_SCHEDULES",
    "BACKENDS", "SHARDED_BACKENDS", "RELAX_BACKENDS",
    "RelaxBackend", "ShardedBackend",
    "make_backend", "make_sharded_backend", "validate_backend_config",
    "SegmentBackend", "EllpackBackend", "SlicedBackend",
    "EllPlanner", "EllState", "SlicedEllPlanner", "SlicedEllState",
    "ell_append", "ell_delete", "ell_update_min", "ell_invariants",
    "sliced_invariants", "shard_segment_wave",
]
