"""RelaxBackend protocol — one relaxation backend = layout state + host
planner + jitted patch ops + wave computation + rebuild policy + checkpoint
participation (DESIGN.md §7).

Both dynamic engines consume backends through this seam:

  * ``SSSPDelEngine`` (core/engine.py) holds ONE ``RelaxBackend`` instance
    and calls ``apply_adds`` / ``apply_dels`` / ``relax`` / ``delete`` /
    ``restore`` — no per-backend branching in the ingest path;
  * ``ShardedSSSPDelEngine`` (core/dist_engine.py) holds one
    ``ShardedBackend`` coordinator, which in turn owns one shard-local
    planner per partition plus the globally sharded device layout arrays,
    and plugs the backend's wave into the shard_map epochs' relaxation body
    in place of the hardwired segment-min (DESIGN.md §7.2).

The equivalence contract travels with the protocol: every backend's wave
evaluates the same candidate set (all live in-edges of each row, offers
masked by the frontier) with the same smallest-src-id tie-break, so
``(dist, parent)`` and the round/message counters are bit-identical across
backends AND across the partition-count axis (test_backend_equiv.py,
test_dist_engine.py).

Registries: ``BACKENDS`` (single-device classes) and ``SHARDED_BACKENDS``
(their sharded coordinators), populated by the ``@register`` /
``@register_sharded`` decorators when the package imports its submodules.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Callable, ClassVar

import jax
import numpy as np

if TYPE_CHECKING:  # only for annotations; no runtime import cycles
    from repro.core.ingest import PlannedAdds, SlotAllocator
    from repro.core.relax import RelaxStats
    from repro.core.state import EdgePool, SSSPState
    from repro.core.delete import DeleteStats


BACKENDS: dict[str, type["RelaxBackend"]] = {}
SHARDED_BACKENDS: dict[str, type["ShardedBackend"]] = {}


def register(cls: type["RelaxBackend"]) -> type["RelaxBackend"]:
    BACKENDS[cls.name] = cls
    return cls


def register_sharded(cls: type["ShardedBackend"]) -> type["ShardedBackend"]:
    SHARDED_BACKENDS[cls.name] = cls
    return cls


# ------------------------------------------------------------- validation --
# Knobs that only make sense for a particular backend: setting one away from
# its dataclass default while selecting a different backend is a config bug
# that used to surface as a confusing failure deep inside layout init.
# ``ell_use_kernel`` is the one genuinely shared knob: both ELL-layout
# backends (ellpack, sliced) consume it.
_SLICED_KNOBS = ("sliced_slice_rows", "sliced_hub_k", "sliced_init_k",
                 "sliced_fused")
_ELLPACK_KNOBS = ("ell_block_rows", "ell_init_k")
_ELL_SHARED_KNOBS = ("ell_use_kernel",)

# ``relax_backend="auto"`` (single-device engines only): start on the dense
# ELL layout and fall back to the sliced/hybrid layout when a rebuild's
# ``K*N`` cell allocation blows past ``ELL_BLOWUP_RATIO`` times the live
# edge count — the power-law-hub pathology (DESIGN.md §6).  Both layouts'
# knobs are therefore legitimate under "auto".
AUTO_BACKEND = "auto"
ELL_BLOWUP_RATIO = 16

WAVE_SCHEDULES = ("rounds", "buckets")
FRONTIER_MODES = ("dense", "sparse", "auto")


def validate_backend_config(cfg: Any) -> None:
    """Raise ``ValueError`` at construction time for an unknown
    ``relax_backend`` or backend knobs that don't apply to the selected
    backend — instead of failing deep inside layout init (or, worse,
    silently ignoring a knob the user believes they tuned).  Shared by
    ``EngineConfig`` and ``ShardedEngineConfig`` (__post_init__)."""
    name = getattr(cfg, "relax_backend", "segment")
    if name not in BACKENDS and name != AUTO_BACKEND:
        raise ValueError(
            f"unknown relax_backend {name!r}; valid backends: "
            f"{sorted(BACKENDS) + [AUTO_BACKEND]}")
    defaults = {f.name: f.default for f in dataclasses.fields(cfg)}
    schedule = getattr(cfg, "wave_schedule", "rounds")
    if schedule not in WAVE_SCHEDULES:
        raise ValueError(
            f"unknown wave_schedule {schedule!r}; valid schedules: "
            f"{list(WAVE_SCHEDULES)}")
    width = getattr(cfg, "bucket_width", 1.0)
    # "auto" = pick delta from the live weight distribution at drain time
    # (DESIGN.md §9.5); any other string — and non-positive/NaN numbers —
    # is a config bug.  The string check must precede the numeric compare
    # (a str/float ``>`` would raise the wrong exception type).
    if isinstance(width, str):
        if width != "auto":
            raise ValueError(
                f"bucket_width must be > 0 or 'auto'; got {width!r}")
    elif not width > 0:   # also rejects NaN
        raise ValueError(
            f"bucket_width must be > 0 (inf = one bucket); got {width!r}")
    if (schedule == "rounds" and "bucket_width" in defaults
            and width != defaults["bucket_width"]):
        raise ValueError(
            f"bucket_width={width!r} configures the buckets schedule; "
            f"remove it or select wave_schedule='buckets'")
    mode = getattr(cfg, "frontier_mode", "dense")
    if mode not in FRONTIER_MODES:
        raise ValueError(
            f"unknown frontier_mode {mode!r}; valid modes: "
            f"{list(FRONTIER_MODES)}")
    cap = getattr(cfg, "frontier_cap", 0)
    if cap < 0:
        raise ValueError(f"frontier_cap must be >= 0 (0 = derive); got {cap}")
    if mode == "dense":
        for k in ("frontier_cap", "frontier_kernel"):
            if k in defaults and getattr(cfg, k) != defaults[k]:
                raise ValueError(
                    f"{k}={getattr(cfg, k)!r} configures the sparse "
                    f"frontier path; remove it or select "
                    f"frontier_mode='sparse'/'auto'")
    misapplied: list[tuple[tuple[str, ...], str]] = []
    if name not in ("sliced", AUTO_BACKEND):
        misapplied.append((_SLICED_KNOBS, "sliced"))
    if name not in ("ellpack", AUTO_BACKEND):
        misapplied.append((_ELLPACK_KNOBS, "dense-ELL"))
    if name == "segment":
        misapplied.append((_ELL_SHARED_KNOBS, "ELL-layout"))
    for knobs, layout in misapplied:
        for k in knobs:
            if k in defaults and getattr(cfg, k) != defaults[k]:
                raise ValueError(
                    f"{k}={getattr(cfg, k)!r} is a backend knob that does "
                    f"not apply to relax_backend={name!r} (it configures "
                    f"the {layout} layout); remove it or select the "
                    f"matching backend")


# ------------------------------------------------------ single-device side --
class RelaxBackend:
    """One relaxation backend for the single-device engine.

    Owns the device layout state (if any), the host planner that assigns
    incremental patch positions, the jitted patch ops (ADD append / DEL
    tombstone / min-update), the epoch wave computation, and the rebuild
    policy.  Checkpoint participation is via ``restore``: layout state is a
    derived view and is never serialized — it is rebuilt from the edge-pool
    mirror (``SlotAllocator``) on restore.
    """

    name: ClassVar[str]

    def __init__(self, cfg: Any, num_vertices: int, *,
                 use_kernel: bool = False, interpret: bool = True):
        self.cfg = cfg
        self.n = num_vertices
        self.use_kernel = use_kernel
        self.interpret = interpret

    # --- incremental layout maintenance (device patch ops; no host sync)
    def apply_adds(self, plan: "PlannedAdds", alloc: "SlotAllocator") -> None:
        """Patch the layout for one planned ADD batch (or rebuild from the
        alloc's host mirror on capacity overflow — the mirror already
        contains the batch).  No-op for layouts derived per-epoch."""

    def apply_dels(self, rows: np.ndarray, src: np.ndarray) -> None:
        """Tombstone deleted edges (padded batch; located on device)."""

    # --- epochs (jitted; same candidate sets + tie-break as segment)
    def relax(self, sssp: "SSSPState", edges: "EdgePool",
              frontier: jax.Array) -> tuple["SSSPState", "RelaxStats"]:
        raise NotImplementedError

    def delete(self, sssp: "SSSPState", edges: "EdgePool",
               seed: jax.Array) -> tuple["SSSPState", "DeleteStats"]:
        raise NotImplementedError

    # --- batched multi-source epochs (serving layer, DESIGN.md §8)
    # One shared graph layout, S stacked trees: ``sssp`` carries [S, N]
    # dist/parent and an [S] source vector; the wave is vmapped over the
    # source axis.  jax's while_loop batching rule freezes each lane's
    # carry once ITS OWN convergence predicate goes false, so every lane —
    # dist, parent, AND the [S] per-lane round/message stats — is
    # bit-identical to an unbatched run (tests/test_serving.py).
    #
    # The implementations below are the generic fallback: an UNJITTED
    # per-call vmap (it must close over the CURRENT layout state, which a
    # jit closure would staleley capture).  Every built-in backend
    # overrides them with a module-level jitted jit(vmap(epoch)) entry
    # point that takes its layout arrays as explicit arguments — the
    # per-call vmap re-trace otherwise dominates batched ingest (~8x).
    def relax_batched(self, sssp: "SSSPState", edges: "EdgePool",
                      frontier: jax.Array
                      ) -> tuple["SSSPState", "RelaxStats"]:
        """Batched ``relax``: frontier is shared (ADD tails are
        source-independent), the trees are vmapped."""
        return jax.vmap(self.relax, in_axes=(0, None, None))(
            sssp, edges, frontier)

    def delete_batched(self, sssp: "SSSPState", edges: "EdgePool",
                       seed: jax.Array
                       ) -> tuple["SSSPState", "DeleteStats"]:
        """Batched ``delete``: seeds are per-lane ([S, N] — whether a
        deleted edge is a tree edge depends on each lane's parent forest)."""
        return jax.vmap(self.delete, in_axes=(0, None, 0))(sssp, edges, seed)

    # --- bucketed drains (wave_schedule="buckets", DESIGN.md §9)
    # ``drain`` settles the engine's deferred PendingState bucket-by-bucket
    # (core/buckets.py run_drain discipline): one cond-gated recompute pull
    # into the accumulated invalidated set, then threshold-paced push waves.
    # Same candidate sets + tie rule as ``relax``/``delete``, so the drained
    # (dist, parent) — and the wave sequence itself — is bit-identical
    # across backends.
    def drain(self, sssp: "SSSPState", edges: "EdgePool", pend: Any,
              *, bucket_width: float
              ) -> tuple["SSSPState", Any, "RelaxStats"]:
        raise NotImplementedError

    def drain_batched(self, sssp: "SSSPState", edges: "EdgePool", pend: Any,
                      *, bucket_width: float
                      ) -> tuple["SSSPState", Any, "RelaxStats"]:
        """Batched [S, N] drain (generic unjitted-vmap fallback; built-ins
        override with a module-level jitted entry, as for relax_batched)."""
        return jax.vmap(
            lambda s, pd: self.drain(s, edges, pd, bucket_width=bucket_width)
        )(sssp, pend)

    # --- checkpoint participation / diagnostics
    def restore(self, alloc: "SlotAllocator") -> None:
        """Rebuild layout state from the pool mirror after a restore."""

    def invariants(self) -> dict[str, jax.Array]:
        """Device-side occupancy invariants (diagnostics/tests)."""
        return {}

    def layout_counters(self) -> dict[str, int]:
        """Monotone host-side layout event totals for the obs layer
        (DESIGN.md §10): rebuild count and overflow-lane placements so far.
        Engines diff successive calls (``EngineObs.note_layout``); totals
        may reset when the "auto" policy swaps layouts — deltas clamp.
        Works for all three backends: segment has no planner (zeros), the
        ELL-family planners carry ``rebuilds``, sliced also ``spills``."""
        pl = getattr(self, "planner", None)
        return {"rebuilds": int(getattr(pl, "rebuilds", 0)),
                "overflow_hits": int(getattr(pl, "spills", 0))}


def make_backend(name: str, cfg: Any, *, num_vertices: int | None = None,
                 use_kernel: bool = False, interpret: bool = True
                 ) -> RelaxBackend:
    if name not in BACKENDS:
        raise ValueError(f"unknown relax_backend {name!r}; valid backends: "
                         f"{sorted(BACKENDS)}")
    return BACKENDS[name](
        cfg, cfg.num_vertices if num_vertices is None else num_vertices,
        use_kernel=use_kernel, interpret=interpret)


# ------------------------------------------------------------ sharded side --
class ShardedBackend:
    """Sharded coordinator for one backend: per-partition shard-local
    planners plus the globally sharded device layout arrays (DESIGN.md §7.2).

    dst-owner edge placement makes every shard's in-edges local, so shard
    ``p``'s layout rows are exactly its owned vertex window
    ``[p*npp, (p+1)*npp)``; the global device arrays are the per-shard
    blocks concatenated partition-major and sharded along dim 0, so the
    shard_map epochs see each shard's own block.

    Layout patches run as separate jitted scatters on the global arrays
    *before* the fused epoch (indices are exact — no foreign-entry masking
    needed) and never read device memory back; rebuilds come from the
    per-partition ``SlotAllocator`` host mirrors.  Geometry (ELL width K /
    per-slice widths / overflow capacity) is synchronized across shards at
    rebuild time — shard_map needs one static per-shard block shape.
    """

    name: ClassVar[str]
    n_extra: ClassVar[int] = 0   # sharded layout arrays fed to the epochs

    def __init__(self, cfg: Any, ds: Any, allocs: list["SlotAllocator"]):
        self.cfg = cfg
        self.ds = ds
        self.allocs = allocs

    def arrays(self) -> tuple[jax.Array, ...]:
        """The global sharded layout arrays, in wave-factory order."""
        return ()

    def static_key(self) -> tuple:
        """Static geometry the epoch closures bake in (epoch-cache key
        suffix; array *shapes* re-trace automatically and need not appear)."""
        return (self.name,)

    def stage_adds(self, plans: list[tuple[int, "PlannedAdds"]]) -> None:
        """Patch the layout for one ADD batch (list of per-partition plans),
        rebuilding all shards from the mirrors on any shard's overflow."""

    def restore(self) -> None:
        """Rebuild the sharded layout from the per-partition mirrors."""

    # wave/patch factories: classmethods so epoch closures capture only
    # static config (never a coordinator instance — the epoch cache must not
    # pin device buffers or host mirrors of dead engines).
    @classmethod
    def shard_wave_factory(cls, static: tuple, npp: int) -> Callable:
        """Return ``make_wave(esrc, edst, ew, eact, extras, my_p) -> wave``
        where ``wave(offers) -> (best f32[npp], arg i32[npp])`` evaluates
        one local relaxation wave: per-row min over the shard's in-edges of
        ``offers[src] + w`` and the smallest minimizing global src id."""
        raise NotImplementedError

    # DEL tombstoning runs INSIDE the fused del epoch (not as a staged
    # patch): deletions are per-event under the paper-faithful mode, so an
    # extra device dispatch per deletion would dominate the sharded ingest
    # overhead.  ``del_mutated`` names the extras the patch replaces; the
    # epoch returns them and the engine hands them back via
    # ``update_del_arrays``.
    del_mutated: ClassVar[tuple[int, ...]] = ()

    @classmethod
    def shard_del_patch(cls, static: tuple, npp: int) -> Callable | None:
        """Return ``patch(extras, psrc, pdst, my_p) -> mutated`` tombstoning
        the (padded, replicated, global-vertex-id) deleted edges in this
        shard's layout block — foreign entries no-op via the -inf/max trick
        — or None when the backend has no layout to patch."""
        return None

    def update_del_arrays(self, new_vals: tuple) -> None:
        """Fold the del epoch's mutated layout arrays back into the
        coordinator state (order matches ``del_mutated``)."""

    def layout_counters(self) -> dict[str, int]:
        """Sharded twin of ``RelaxBackend.layout_counters``.  Rebuilds are
        coupled (any shard's overflow rebuilds ALL shards, so every planner
        advances together) — the max over planners counts global rebuild
        EVENTS, matching the single-device figure.  Overflow-lane
        placements are genuinely per-partition and sum."""
        pls = getattr(self, "planners", None) or []
        return {
            "rebuilds": max((int(getattr(p, "rebuilds", 0)) for p in pls),
                            default=0),
            "overflow_hits": sum(int(getattr(p, "spills", 0)) for p in pls),
        }


def make_sharded_backend(name: str, cfg: Any, ds: Any,
                         allocs: list["SlotAllocator"]) -> ShardedBackend:
    if name not in SHARDED_BACKENDS:
        raise ValueError(f"unknown relax_backend {name!r}; valid backends: "
                         f"{sorted(SHARDED_BACKENDS)}")
    return SHARDED_BACKENDS[name](cfg, ds, allocs)


# ------------------------------------------------------- planner utilities --
def rank_within_rows(rows: np.ndarray) -> np.ndarray:
    """Rank of each batch entry among the entries targeting the same row,
    in stable batch order — the cell-offset assignment all ELL-family
    planners use (kpos candidate = fill[row] + rank)."""
    m = len(rows)
    order = np.argsort(rows, kind="stable")
    sr = rows[order]
    starts = np.nonzero(np.r_[True, sr[1:] != sr[:-1]])[0]
    sizes = np.diff(np.r_[starts, m])
    rank = np.empty(m, np.int64)
    rank[order] = np.arange(m) - np.repeat(starts, sizes)
    return rank
