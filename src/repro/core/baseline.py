"""Baselines from the paper's evaluation.

* ``ReMoBaseline`` (paper §5.2): maintains only the topology as the stream
  arrives; on every query it *cold-starts* the increment-only ReMo relaxation
  from scratch on the current snapshot.  This is exactly the paper's baseline
  construction ("temporarily pause ingestion, run ReMo SSSP on the current
  graph snapshot, collect results after convergence").

* ``BatchedBSPEngine`` (paper §5.6, GraphBolt's processing model): updates are
  applied in fixed-size batches; the solution is only (re)converged at batch
  boundaries, starting from the previous snapshot's state — dependency-driven
  refinement à la GraphBolt, but implemented on our substrate so the
  comparison isolates the *processing model* (async on-demand vs. BSP batch).

* ``StaticSolver`` (paper §5.2 / Table 2, the Galois analogue): one-shot CSR
  build ("conversion") + static solve; used by benchmarks/static_baseline.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev
from repro.core import ingest, relax
from repro.core.engine import QueryResult
from repro.core.state import EdgePool, SSSPState


class ReMoBaseline:
    """Topology-only ingestion; ReMo-from-scratch on every query.

    ``randomize_ties=True`` draws a fresh tie-break permutation per query —
    the BSP stand-in for the async runtime's run-to-run arbitrariness among
    equally valid shortest-path trees (the effect the paper's Fig. 4
    stability comparison measures; with unit weights ties are pervasive).
    Distances are unaffected; only the parent choice among equal-cost
    predecessors varies.
    """

    def __init__(self, num_vertices: int, edge_capacity: int, source: int,
                 randomize_ties: bool = False, seed: int = 0):
        self.num_vertices = num_vertices
        self.source = source
        self.alloc = ingest.SlotAllocator(edge_capacity)
        self.edges = EdgePool.empty(edge_capacity)
        self._last_parent: np.ndarray | None = None
        self.randomize_ties = randomize_ties
        self._rng = np.random.default_rng(seed)

    def ingest_log(self, log: ev.EventLog) -> list[QueryResult]:
        results = []
        for batch in log.runs():
            if batch.kind == ev.ADD:
                plan = self.alloc.plan_adds(batch.src, batch.dst, batch.w)
                if len(plan.slots):
                    self.edges = ingest.apply_adds(
                        self.edges, jnp.asarray(plan.slots),
                        jnp.asarray(plan.src), jnp.asarray(plan.dst),
                        jnp.asarray(plan.w))
            elif batch.kind == ev.DEL:
                slots, _, _ = self.alloc.plan_dels(batch.src, batch.dst)
                if len(slots):
                    self.edges = ingest.apply_dels(self.edges, jnp.asarray(slots))
            else:
                results.append(self.query())
        return results

    def query(self) -> QueryResult:
        t0 = time.perf_counter()
        sssp = SSSPState.init(self.num_vertices, self.source)
        frontier = relax.frontier_from_vertices(
            jnp.asarray([self.source]), self.num_vertices)
        tie_perm = None
        if self.randomize_ties:
            tie_perm = jnp.asarray(
                self._rng.permutation(self.num_vertices).astype(np.int32))
        sssp, stats = relax.relax_until_converged(
            sssp, self.edges, frontier, num_vertices=self.num_vertices,
            tie_perm=tie_perm)
        dist = np.asarray(jax.device_get(sssp.dist))
        parent = np.asarray(jax.device_get(sssp.parent))
        dt = time.perf_counter() - t0
        return QueryResult(dist=dist, parent=parent, latency_s=dt,
                           epoch_stats={"rounds": int(stats.rounds),
                                        "messages": int(stats.messages)})

    def stability_vs_prev(self, parent: np.ndarray) -> float:
        if self._last_parent is None:
            self._last_parent = parent.copy()
            return 1.0
        prev = self._last_parent
        both = (prev >= 0) & (parent >= 0)
        frac = float(np.mean(prev[both] == parent[both])) if both.any() else 1.0
        self._last_parent = parent.copy()
        return frac


class BatchedBSPEngine:
    """GraphBolt-style batch processing model on our substrate (paper §5.6).

    Events accumulate host-side; at each batch boundary we apply the whole
    batch, then reconverge starting from the *previous* snapshot's state
    (incremental like GraphBolt, but only at batch granularity).  Deletions
    force the same invalidate+recompute as the main engine, but only at the
    batch boundary — queries between boundaries must wait (that wait is the
    latency the paper's Figure 6 measures).
    """

    def __init__(self, num_vertices: int, edge_capacity: int, source: int,
                 batch_size: int):
        from repro.core.engine import EngineConfig, SSSPDelEngine
        self.inner = SSSPDelEngine(EngineConfig(
            num_vertices=num_vertices, edge_capacity=edge_capacity,
            source=source, batch_deletions=True))
        self.batch_size = batch_size
        self._pending: list[ev.EventLog] = []
        self._pending_n = 0

    def push(self, log: ev.EventLog) -> None:
        self._pending.append(log)
        self._pending_n += len(log)

    def maybe_flush(self) -> float | None:
        """If a full batch accumulated, apply + reconverge; returns latency."""
        if self._pending_n < self.batch_size:
            return None
        merged = ev.EventLog.concatenate(self._pending)
        self._pending, self._pending_n = [], 0
        t0 = time.perf_counter()
        self.inner.ingest_log(merged)
        jax.block_until_ready(self.inner.state.sssp.dist)
        return time.perf_counter() - t0

    def force_flush(self) -> float:
        if not self._pending:
            return 0.0
        merged = ev.EventLog.concatenate(self._pending)
        self._pending, self._pending_n = [], 0
        t0 = time.perf_counter()
        self.inner.ingest_log(merged)
        jax.block_until_ready(self.inner.state.sssp.dist)
        return time.perf_counter() - t0


@dataclasses.dataclass
class StaticSolveReport:
    convert_s: float   # event-log -> CSR ("Conv" column of Table 2)
    solve_s: float     # static SSSP solve ("SP" column)
    dist: np.ndarray
    parent: np.ndarray


class StaticSolver:
    """Static CSR Bellman-Ford/frontier solver — the Galois analogue.

    ``convert``: one-shot CSR build from the final event log (the cost Table 2
    charges to Galois's event-log->CSR conversion).  ``solve``: frontier-based
    relaxation on the static arrays (delta-stepping-like behaviour emerges
    from the frontier masking; weights here are small so one bucket suffices).
    """

    def __init__(self, num_vertices: int):
        self.num_vertices = num_vertices
        self.edges: EdgePool | None = None

    def convert(self, log: ev.EventLog) -> float:
        t0 = time.perf_counter()
        # apply adds/dels in order, host-side (numpy), then freeze to device
        alive: dict[tuple[int, int], float] = {}
        for k, u, v, w in zip(log.kind.tolist(), log.src.tolist(),
                              log.dst.tolist(), log.w.tolist()):
            if k == ev.ADD:
                alive.setdefault((u, v), w)
            elif k == ev.DEL:
                alive.pop((u, v), None)
        n = len(alive)
        src = np.fromiter((k[0] for k in alive), np.int32, n)
        dst = np.fromiter((k[1] for k in alive), np.int32, n)
        w = np.fromiter(alive.values(), np.float32, n)
        order = np.argsort(dst, kind="stable")  # CSR-by-dst layout
        self.edges = EdgePool(
            src=jnp.asarray(src[order]), dst=jnp.asarray(dst[order]),
            w=jnp.asarray(w[order]), active=jnp.ones(n, jnp.bool_))
        jax.block_until_ready(self.edges.src)
        return time.perf_counter() - t0

    def solve(self, source: int) -> StaticSolveReport:
        assert self.edges is not None, "convert() first"
        t0 = time.perf_counter()
        sssp = SSSPState.init(self.num_vertices, source)
        frontier = relax.frontier_from_vertices(
            jnp.asarray([source]), self.num_vertices)
        sssp, _ = relax.relax_until_converged(
            sssp, self.edges, frontier, num_vertices=self.num_vertices)
        dist = np.asarray(jax.device_get(sssp.dist))
        parent = np.asarray(jax.device_get(sssp.parent))
        dt = time.perf_counter() - t0
        return StaticSolveReport(convert_s=0.0, solve_s=dt, dist=dist, parent=parent)
