"""ShardedSSSPDelEngine — the fully dynamic engine over the vertex-partitioned
device mesh (DESIGN.md §5).

This is the convergence of the repo's two halves: ``core/engine.py`` ingests
ADD/DEL/QUERY streams on one device; ``core/distributed.py`` solves static
graphs over a shard_map mesh.  Here the *same* ``EventLog`` stream drives
per-partition edge pools living across the mesh:

  * **Ownership**: vertices are range-partitioned over the flattened mesh
    axes (``npp`` per shard); an edge lives with the owner of its **dst** so
    the per-round scatter-min is shard-local (paper §3's shared-nothing
    mapping, same as ``DistributedSSSP``).
  * **Control plane**: one host-side ``SlotAllocator`` per partition (the
    ingest.py mirror/planning machinery, keyed by dst-owner) plans where each
    topology event lands in its owner's fixed ``Epp``-slot pool.  Global slot
    ``p*Epp + local`` addresses the sharded device arrays directly.
  * **Data plane**: one jitted shard_map epoch per batch patches the pools in
    place (masked writes routed through a sacrificial slot so foreign batch
    entries never collide with real ones) and immediately runs the
    relaxation / deletion epoch seeded from the batch — frontier = tails of
    inserted edges; seeds = heads of deleted tree edges — reusing
    ``DistributedSSSP``'s allgather/delta exchange rounds.
  * **Host-sync rules** (DESIGN.md §2.4): the ingest loop never blocks on a
    device value.  Round/message counters thread through the epochs as
    replicated device scalars and are read back only in ``query()``;
    deletion epochs dispatch unconditionally (all-false seed = cheap no-op).

Equivalence contract: with ``exchange="allgather"`` the engine is
**bit-identical** in ``(dist, parent)`` — and equal in rounds/messages — to
``SSSPDelEngine`` on any event stream, for any partition count (frontier
evolution, candidate sets and smallest-src-id tie-breaks are the same wave
for wave; float min is exact).  The ``"delta"`` exchange reaches the same
``(dist, parent)`` fixpoint with compressed traffic (overflow rounds fall
back to dense gathers — still exact, see tests/test_sssp_distributed.py).

Optional **edge-balanced placement**: pass the ``(perm, inv, npp)`` triple
from ``graphs.partition.edge_balanced_relabeling`` (built for this mesh's
partition count) as ``relabel`` — events are permuted on ingest and results
un-permuted at query, so shards own ~equal in-edge mass instead of ~equal
vertex counts.  Distances are unchanged (same paths, same float sums);
parent ties may resolve differently (smallest *relabeled* id).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import events as ev
from repro.core import ingest
from repro.core.distributed import (DistConfig, DistributedSSSP,
                                    _SHARD_MAP_KW, _shard_map,
                                    inactive_dst_layout)
from repro.core.state import INF, NO_PARENT
from repro.core.stream import QueryResult, StreamEngineBase
from repro.launch import mesh as mesh_mod


EXCHANGES = ("allgather", "delta")

# Jitted epoch builders keyed by everything their traces depend on, shared
# across engine instances: the closures are per-instance, so without this a
# fresh engine (benchmark warm/timed pairs, test sweeps) would re-trace and
# re-lower every batch shape it has already seen.
_EPOCH_CACHE: dict[tuple, tuple] = {}


@dataclasses.dataclass
class ShardedEngineConfig:
    num_vertices: int        # logical |V| (pre-padding, pre-relabel)
    edges_per_part: int      # static per-partition edge-pool capacity (Epp)
    source: int
    exchange: str = "allgather"   # or "delta" (DESIGN.md §5.3)
    delta_cap: int = 4096    # per-part (idx,val) slots for "delta" exchange
    use_doubling: bool = True     # False = paper's wave-by-wave flood
    batch_deletions: bool = False
    on_duplicate: str = "ignore"  # or "min" (weight decreases)


class ShardedSSSPDelEngine(StreamEngineBase):
    """Host orchestrator over shard_map ingest+epoch device code.

    ``mesh=None`` flattens every local device onto one "graph" axis; any
    explicit mesh works — all its axes are flattened into the vertex
    partition (launch/mesh.graph_axes), exactly like ``DistributedSSSP``.
    """

    def __init__(self, cfg: ShardedEngineConfig, mesh: Mesh | None = None,
                 relabel: tuple[np.ndarray, np.ndarray, int] | None = None):
        assert cfg.exchange in EXCHANGES, cfg.exchange
        super().__init__()
        self.cfg = cfg
        if mesh is None:
            mesh = mesh_mod._mk((len(jax.devices()),), ("graph",))
        axes = tuple(mesh.axis_names)
        P_ = 1
        for a in axes:
            P_ *= mesh.shape[a]
        if relabel is not None:
            perm, inv, npp_r = relabel
            self.perm = np.asarray(perm, np.int32)
            self.inv = np.asarray(inv, np.int32)
            assert len(self.perm) == cfg.num_vertices, "perm must cover |V|"
            assert npp_r * P_ == len(self.inv), (
                f"relabeling was built for {len(self.inv) // max(npp_r, 1)} "
                f"partitions (npp={npp_r}); this mesh flattens to P={P_} — "
                "rebuild with edge_balanced_relabeling(n, dst, P)")
            n_pad = len(self.inv)
        else:
            self.perm = self.inv = None
            n_pad = P_ * (-(-cfg.num_vertices // P_))
        self.ds = DistributedSSSP(mesh, DistConfig(
            num_vertices=n_pad, edges_per_part=cfg.edges_per_part,
            mesh_axes=axes, exchange=cfg.exchange, delta_cap=cfg.delta_cap))
        self.P, self.npp, self.epp = self.ds.P, self.ds.npp, cfg.edges_per_part
        self._source_pad = int(cfg.source if self.perm is None
                               else self.perm[cfg.source])
        # control plane: one planner per partition, local Epp-slot pools
        self.allocs = [ingest.SlotAllocator(cfg.edges_per_part,
                                            cfg.on_duplicate)
                       for _ in range(self.P)]
        # data plane: sharded vertex + edge-pool arrays
        self.dist, self.parent = self.ds.init_vertex_arrays(self._source_pad)
        self.esrc, self.edst, self.ew, self.eact = self.ds.put_edges(
            np.zeros(self.P * self.epp, np.int32),
            inactive_dst_layout(self.P, self.npp, self.epp),
            np.zeros(self.P * self.epp, np.float32),
            np.zeros(self.P * self.epp, np.bool_))
        key = (mesh, n_pad, cfg.edges_per_part, cfg.exchange, cfg.delta_cap,
               cfg.use_doubling, self._source_pad)
        if key not in _EPOCH_CACHE:
            _EPOCH_CACHE[key] = _build_epochs(
                self.ds, self.epp, cfg.use_doubling, self._source_pad)
        self._add_epoch, self._del_epoch = _EPOCH_CACHE[key]

    # ------------------------------------------------------------------ adds
    def _ingest_adds(self, batch: ev.EventBatch) -> None:
        src, dst, w = batch.src, batch.dst, batch.w
        if self.perm is not None:
            src, dst = self.perm[src], self.perm[dst]
        owner = np.asarray(dst, np.int64) // self.npp
        parts = []
        for p in np.unique(owner):
            sel = owner == p
            plan = self.allocs[p].plan_adds(src[sel], dst[sel], w[sel])
            if len(plan.slots):
                parts.append((int(p) * self.epp + plan.slots.astype(np.int64),
                              plan.src, plan.dst, plan.w))
        if not parts:
            return
        gslot, bsrc, bdst, bw = (np.concatenate(x) for x in zip(*parts))
        n_acc = len(gslot)
        gslot, bsrc, bdst, bw = ingest.pad_pow2(
            gslot.astype(np.int32), bsrc, bdst, bw)
        (self.dist, self.parent, self.esrc, self.edst, self.ew, self.eact,
         self._dev_rounds, self._dev_messages) = self._add_epoch(
            self.dist, self.parent, self.esrc, self.edst, self.ew, self.eact,
            jnp.asarray(gslot), jnp.asarray(bsrc), jnp.asarray(bdst),
            jnp.asarray(bw), self._dev_rounds, self._dev_messages)
        self.n_adds += n_acc
        self.n_epochs += 1

    # ------------------------------------------------------------------ dels
    def _ingest_dels(self, batch: ev.EventBatch) -> None:
        if self.cfg.batch_deletions:
            groups = [(batch.src, batch.dst)]
        else:
            groups = [(batch.src[i:i + 1], batch.dst[i:i + 1])
                      for i in range(len(batch.src))]
        for gsrc, gdst in groups:
            if self.perm is not None:
                gsrc, gdst = self.perm[gsrc], self.perm[gdst]
            owner = np.asarray(gdst, np.int64) // self.npp
            parts = []
            for p in np.unique(owner):
                sel = owner == p
                slots, psrc, pdst = self.allocs[p].plan_dels(
                    gsrc[sel], gdst[sel])
                if len(slots):
                    parts.append((int(p) * self.epp + slots.astype(np.int64),
                                  psrc, pdst))
            if not parts:
                continue
            gslot, psrc, pdst = (np.concatenate(x) for x in zip(*parts))
            n_del = len(gslot)
            gslot, psrc, pdst = ingest.pad_pow2(
                gslot.astype(np.int32), psrc, pdst)
            (self.dist, self.parent, self.eact,
             self._dev_rounds, self._dev_messages) = self._del_epoch(
                self.dist, self.parent, self.esrc, self.edst, self.ew,
                self.eact, jnp.asarray(gslot), jnp.asarray(psrc),
                jnp.asarray(pdst), self._dev_rounds, self._dev_messages)
            self.n_dels += n_del
            self.n_epochs += 1

    # ----------------------------------------------------------------- query
    def query(self) -> QueryResult:
        """State collection: epoch already enforced (every batch ran to
        convergence) — cost is the sharded device->host readback plus the
        inverse relabeling, if any."""
        t0 = time.perf_counter()
        dist = np.asarray(jax.device_get(self.dist))
        parent = np.asarray(jax.device_get(self.parent))
        n = self.cfg.num_vertices
        if self.perm is not None:
            dist = dist[self.perm]
            p = parent[self.perm]
            parent = np.where(p >= 0, self.inv[np.clip(p, 0, None)],
                              NO_PARENT).astype(np.int32)
        else:
            dist, parent = dist[:n], parent[:n]
        dt = time.perf_counter() - t0
        return QueryResult(dist=dist, parent=parent, latency_s=dt,
                           epoch_stats=self._stream_stats())

    # ------------------------------------------------------------ diagnostics
    def partition_fill(self) -> np.ndarray:
        """Live edges per partition, from the host mirrors (no device sync)."""
        return np.array([int(a.mactive.sum()) for a in self.allocs])


def _build_epochs(ds: DistributedSSSP, epp: int, use_doubling: bool,
                  source_pad: int):
    """Build the (add_epoch, del_epoch) jitted shard_map pair.

    Module-level on purpose: the closures capture only ``ds`` (mesh + config
    + specs, no device buffers) and scalars, so ``_EPOCH_CACHE`` entries
    never pin an engine's device state or host mirrors.
    """
    npp = ds.npp
    ax = ds.cfg.mesh_axes
    exchange = ds.cfg.exchange
    v, e, r = ds.vspec, ds.espec, ds.rspec

    def masked_write(arr, loc, val):
        """Scatter batch values into this shard's pool slice.  Foreign batch
        entries are routed to a sacrificial extra slot (index epp) instead of
        a masked in-range index — a masked write at a real index would race
        with a genuine write to the same slot."""
        pad = jnp.zeros((1,), arr.dtype)
        return jnp.concatenate([arr, pad]).at[loc].set(
            val.astype(arr.dtype))[:epp]

    def local_slots(gslot, my_p):
        mine = (gslot // epp) == my_p
        return jnp.where(mine, gslot - my_p * epp, epp)

    @jax.jit
    @partial(_shard_map, mesh=ds.mesh,
             in_specs=(v, v, e, e, e, e, r, r, r, r, r, r),
             out_specs=(v, v, e, e, e, e, r, r),
             **_SHARD_MAP_KW)
    def add_epoch(dist, parent, esrc, edst, ew, eact,
                  gslot, bsrc, bdst, bw, racc, macc):
        """patch pools + relax from the inserted tails, one fused epoch."""
        my_p = jnp.int32(ds._flat_index())
        row0 = my_p * npp
        loc = local_slots(gslot, my_p)
        esrc = masked_write(esrc, loc, bsrc)
        edst = masked_write(edst, loc, bdst)
        ew = masked_write(ew, loc, bw)
        eact = masked_write(eact, loc, jnp.ones_like(gslot, jnp.bool_))
        # Frontier = tails of the inserted edges (paper Listing 3); each
        # shard keeps its own window of the global bool frontier.
        in_r = (bsrc >= row0) & (bsrc < row0 + npp)
        fr = jnp.zeros((npp,), jnp.bool_).at[
            jnp.clip(bsrc - row0, 0, npp - 1)].max(in_r)
        dist, parent, rounds, msgs = ds._relax_body(
            dist, parent, fr, esrc, edst, ew, eact)
        return (dist, parent, esrc, edst, ew, eact,
                racc + rounds, macc + msgs)

    @jax.jit
    @partial(_shard_map, mesh=ds.mesh,
             in_specs=(v, v, e, e, e, e, r, r, r, r, r),
             out_specs=(v, v, e, r, r),
             **_SHARD_MAP_KW)
    def del_epoch(dist, parent, esrc, edst, ew, eact,
                  gslot, psrc, pdst, racc, macc):
        """seed from pre-deletion tree + deactivate + invalidate + recompute,
        one fused epoch.  Stats mirror core/delete.DeleteStats exactly."""
        my_p = jnp.int32(ds._flat_index())
        row0 = my_p * npp
        # Listing 4: only deletions of tree edges (parent[head]==tail)
        # seed invalidation — judged against the PRE-deletion tree.
        in_r = (pdst >= row0) & (pdst < row0 + npp)
        lds = jnp.clip(pdst - row0, 0, npp - 1)
        seed = jnp.zeros((npp,), jnp.bool_).at[lds].max(
            in_r & (parent[lds] == psrc))
        any_seed = jax.lax.psum(jnp.sum(seed.astype(jnp.int32)), ax) > 0
        # deactivate the deleted slots (dst stays in-range)
        loc = local_slots(gslot, my_p)
        eact = masked_write(eact, loc, jnp.zeros_like(gslot, jnp.bool_))
        # --- invalidation over the parent forest
        if use_doubling:
            aff, inv_rounds = ds._invalidate_doubling(parent, seed)
        elif exchange == "delta":
            aff, inv_rounds = ds._invalidate_delta(parent, seed, row0)
        else:
            aff, inv_rounds = ds._invalidate_flood_dense(parent, seed)
        # never invalidate the source (parity with single-device engine)
        local_ids = row0 + jnp.arange(npp, dtype=jnp.int32)
        aff = aff & (local_ids != source_pad)
        affected = jax.lax.psum(jnp.sum(aff.astype(jnp.int32)), ax)
        dist = jnp.where(aff, INF, dist)
        parent = jnp.where(aff, NO_PARENT, parent)
        # --- recomputation (shared with the static delete epoch; the
        # distributed rendering of delete.invalidate_and_recompute)
        if exchange == "delta":
            dist, parent, rec_rounds, rec_msgs = ds._recompute_delta(
                dist, parent, aff, esrc, edst, ew, eact, row0)
        else:
            dist, parent, rec_rounds, rec_msgs = ds._recompute_pull_push(
                dist, parent, aff, esrc, edst, ew, eact, row0)
        zero = jnp.int32(0)
        d_rounds = jnp.where(any_seed, inv_rounds + rec_rounds, zero)
        d_msgs = jnp.where(any_seed, rec_msgs, zero) + affected
        return dist, parent, eact, racc + d_rounds, macc + d_msgs

    return add_epoch, del_epoch
