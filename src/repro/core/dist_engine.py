"""ShardedSSSPDelEngine — the fully dynamic engine over the vertex-partitioned
device mesh (DESIGN.md §5, §7.2).

This is the convergence of the repo's two halves: ``core/engine.py`` ingests
ADD/DEL/QUERY streams on one device; ``core/distributed.py`` solves static
graphs over a shard_map mesh.  Here the *same* ``EventLog`` stream drives
per-partition edge pools living across the mesh:

  * **Ownership**: vertices are range-partitioned over the flattened mesh
    axes (``npp`` per shard); an edge lives with the owner of its **dst** so
    the per-round scatter-min is shard-local (paper §3's shared-nothing
    mapping, same as ``DistributedSSSP``).
  * **Control plane**: one host-side ``SlotAllocator`` per partition (the
    ingest.py mirror/planning machinery, keyed by dst-owner) plans where each
    topology event lands in its owner's fixed ``Epp``-slot pool.  Global slot
    ``p*Epp + local`` addresses the sharded device arrays directly.
  * **Relaxation backend** (DESIGN.md §7.2): ``relax_backend=`` selects any
    registered backend.  The coordinator (core/backends/) holds one
    shard-local planner per partition — dst-owner placement makes every
    shard's in-edges local, so per-shard layout rows are exactly the owned
    vertex window — plus the per-shard layout blocks concatenated into
    globally sharded device arrays.  ADD patches run as separate jitted
    scatters before the fused epoch (amortized over the batch); DEL
    tombstones run INSIDE the fused deletion epoch (per-event hot path);
    the backend's wave replaces the hardwired segment-min inside the
    shard_map epochs' relaxation body.
  * **Data plane**: one jitted shard_map epoch per batch patches the pools in
    place (masked writes routed through a sacrificial slot so foreign batch
    entries never collide with real ones) and immediately runs the
    relaxation / deletion epoch seeded from the batch — frontier = tails of
    inserted edges; seeds = heads of deleted tree edges — reusing
    ``DistributedSSSP``'s allgather/delta exchange rounds.
  * **Host-sync rules** (DESIGN.md §2.4): the ingest loop never blocks on a
    device value.  Round/message counters thread through the epochs as
    replicated device scalars and are read back only in ``query()``;
    deletion epochs dispatch unconditionally (all-false seed = cheap no-op).
  * **Batched multi-source serving** (DESIGN.md §8): ``sources=(s0, ...)``
    stacks S trees as [S, N] dist/parent arrays sharded along the vertex
    axis; the ``_build_epochs_ms`` builder patches the shared pool/layout
    once per batch and runs the ``*_ms`` relaxation bodies
    (core/distributed.py) with the backend's wave vmapped over the source
    axis — bit-identical per lane to S single-source engines, same
    host-sync rules.

Equivalence contract: with ``exchange="allgather"`` the engine is
**bit-identical** in ``(dist, parent)`` — and equal in rounds/messages — to
``SSSPDelEngine`` *with the same relax_backend* on any event stream, for any
partition count (frontier evolution, candidate sets and smallest-src-id
tie-breaks are the same wave for wave; float min is exact) — and all
backends are bit-identical to each other (test_backend_equiv.py), so the
contract holds across the full backend x partition-count grid.  The
``"delta"`` exchange reaches the same ``(dist, parent)`` fixpoint with
compressed traffic (overflow rounds fall back to dense gathers — still
exact, see tests/test_sssp_distributed.py).

Optional **edge-balanced placement**: pass the ``(perm, inv, npp)`` triple
from ``graphs.partition.edge_balanced_relabeling`` (built for this mesh's
partition count) as ``relabel`` — events are permuted on ingest and results
un-permuted at query, so shards own ~equal in-edge mass instead of ~equal
vertex counts.  Distances are unchanged (same paths, same float sums);
parent ties may resolve differently (smallest *relabeled* id).

Checkpoint/restore reuses the single-device schema (pool snapshot +
dist/parent windows); backend layout state is a derived view and is rebuilt
from the per-partition mirrors on restore, never serialized.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import backends as bk_mod
from repro.core import events as ev
from repro.core import frontier as frontier_mod
from repro.core import ingest
from repro.core.backends.base import SHARDED_BACKENDS
from repro.core.distributed import (DistConfig, DistributedSSSP,
                                    _SHARD_MAP_KW, _shard_map,
                                    inactive_dst_layout,
                                    per_partition_occupancy)
from repro.core.state import INF, NO_PARENT
from repro.core.stream import StreamEngineBase
from repro.launch import mesh as mesh_mod
from repro.obs import WatchdogConfig


EXCHANGES = ("allgather", "delta")

# Jitted epoch builders keyed by everything their traces depend on — the
# mesh/exchange config plus the backend's static geometry key — shared
# across engine instances: the closures are per-instance, so without this a
# fresh engine (benchmark warm/timed pairs, test sweeps) would re-trace and
# re-lower every batch shape it has already seen.  Layout arrays flow
# through epoch *arguments* (their shapes re-trace automatically); only
# truly static geometry (e.g. the sliced widths tuple) lives in the key.
_EPOCH_CACHE: dict[tuple, tuple] = {}


@dataclasses.dataclass
class ShardedEngineConfig:
    num_vertices: int        # logical |V| (pre-padding, pre-relabel)
    edges_per_part: int      # static per-partition edge-pool capacity (Epp)
    source: int
    exchange: str = "allgather"   # or "delta" (DESIGN.md §5.3)
    delta_cap: int = 4096    # per-part (idx,val) slots for "delta" exchange
    use_doubling: bool = True     # False = paper's wave-by-wave flood
    batch_deletions: bool = False
    on_duplicate: str = "ignore"  # or "min" (weight decreases)
    # Relaxation backend (DESIGN.md §7.2) + its knobs — same fields and
    # defaults as EngineConfig so the two validate identically.
    relax_backend: str = "segment"
    ell_block_rows: int = 256
    ell_init_k: int = 8
    ell_use_kernel: bool | None = None  # None = Pallas kernel iff on TPU
    sliced_slice_rows: int = 256
    sliced_hub_k: int = 32
    sliced_init_k: int = 2
    # wave schedule (DESIGN.md §9): "rounds" settles every epoch to
    # fixpoint; "buckets" defers settling into delta-stepping drains run at
    # query/checkpoint — the bucket threshold is a replicated scalar, so the
    # sharded drain reuses the existing allgather/delta exchanges unchanged
    wave_schedule: str = "rounds"
    # delta; inf = one bucket; "auto" = pow2-quantized live-weight median
    # resolved host-side from the per-partition mirrors (DESIGN.md §9.5)
    bucket_width: float | str = 1.0
    # frontier-compacted sparse waves (DESIGN.md §12.4): "sparse" compacts
    # each partition's live-offer edges into a bounded worklist inside the
    # wave body (the backend's own dense wave is the in-cond fallback);
    # "auto" routes dense here — per-partition occupancy is device-only
    # knowledge, and the single-rung cond already bounds the regression
    frontier_mode: str = "dense"
    frontier_cap: int = 0    # per-partition edge-worklist cap; 0 = Epp/64
    # batched multi-source serving (DESIGN.md §8); None = single-source
    sources: tuple[int, ...] | None = None
    # observability (DESIGN.md §10) — same contract as EngineConfig; the
    # sharded registry folds per-partition [P] vectors, no new collectives
    observability: bool = False
    obs_flight_capacity: int = 128
    # stall/divergence watchdog (§10.8); None = off
    obs_watchdog: "WatchdogConfig | None" = None
    # control-plane implementation (DESIGN.md §11); same knob as
    # EngineConfig.alloc_impl, applied to every per-partition planner
    alloc_impl: str = "columnar"

    def __post_init__(self):
        bk_mod.validate_backend_config(self)
        ingest.allocator_cls(self.alloc_impl)  # raises on unknown impl
        if self.exchange not in EXCHANGES:
            raise ValueError(f"unknown exchange {self.exchange!r}; valid: "
                             f"{EXCHANGES}")
        if self.obs_flight_capacity < 1:
            raise ValueError(f"obs_flight_capacity must be >= 1; got "
                             f"{self.obs_flight_capacity}")
        if self.sources is not None:
            self.sources = tuple(int(s) for s in self.sources)
            bad = [s for s in self.sources
                   if not 0 <= s < self.num_vertices]
            if not self.sources or bad:
                raise ValueError(
                    f"sources must be non-empty vertex ids in "
                    f"[0, {self.num_vertices}); got {self.sources}")


class ShardedSSSPDelEngine(StreamEngineBase):
    """Host orchestrator over shard_map ingest+epoch device code.

    ``mesh=None`` flattens every local device onto one "graph" axis; any
    explicit mesh works — all its axes are flattened into the vertex
    partition (launch/mesh.graph_axes), exactly like ``DistributedSSSP``.
    """

    def __init__(self, cfg: ShardedEngineConfig, mesh: Mesh | None = None,
                 relabel: tuple[np.ndarray, np.ndarray, int] | None = None):
        super().__init__(sources=cfg.sources,
                         observability=cfg.observability,
                         flight_capacity=cfg.obs_flight_capacity,
                         watchdog=cfg.obs_watchdog)
        self.cfg = cfg
        if mesh is None:
            mesh = mesh_mod._mk((len(jax.devices()),), ("graph",))
        axes = tuple(mesh.axis_names)
        P_ = 1
        for a in axes:
            P_ *= mesh.shape[a]
        if relabel is not None:
            perm, inv, npp_r = relabel
            self.perm = np.asarray(perm, np.int32)
            self.inv = np.asarray(inv, np.int32)
            assert len(self.perm) == cfg.num_vertices, "perm must cover |V|"
            assert npp_r * P_ == len(self.inv), (
                f"relabeling was built for {len(self.inv) // max(npp_r, 1)} "
                f"partitions (npp={npp_r}); this mesh flattens to P={P_} — "
                "rebuild with edge_balanced_relabeling(n, dst, P)")
            n_pad = len(self.inv)
        else:
            self.perm = self.inv = None
            n_pad = P_ * (-(-cfg.num_vertices // P_))
        self.ds = DistributedSSSP(mesh, DistConfig(
            num_vertices=n_pad, edges_per_part=cfg.edges_per_part,
            mesh_axes=axes, exchange=cfg.exchange, delta_cap=cfg.delta_cap))
        self.P, self.npp, self.epp = self.ds.P, self.ds.npp, cfg.edges_per_part
        # single-source: one padded/relabeled source id; batched serving: a
        # static tuple of them (the epoch-cache key and the epochs' "never
        # invalidate the source" mask are per lane)
        if self.sources is None:
            self._source_pad = int(cfg.source if self.perm is None
                                   else self.perm[cfg.source])
        else:
            self._source_pad = tuple(
                int(s if self.perm is None else self.perm[s])
                for s in self.sources)
        # control plane: one planner per partition, local Epp-slot pools
        self.allocs = [ingest.make_allocator(cfg.edges_per_part,
                                             cfg.on_duplicate,
                                             cfg.alloc_impl)
                       for _ in range(self.P)]
        # relaxation backend: per-shard planners + sharded layout arrays
        self.bk = bk_mod.make_sharded_backend(
            cfg.relax_backend, cfg, self.ds, self.allocs)
        # data plane: sharded vertex + edge-pool arrays ([S, N] stacked
        # trees over the one sharded pool in batched serving mode)
        if self.sources is None:
            self.dist, self.parent = self.ds.init_vertex_arrays(
                self._source_pad)
        else:
            self.dist, self.parent = self.ds.init_vertex_arrays_ms(
                self._source_pad)
        self.esrc, self.edst, self.ew, self.eact = self.ds.put_edges(
            np.zeros(self.P * self.epp, np.int32),
            inactive_dst_layout(self.P, self.npp, self.epp),
            np.zeros(self.P * self.epp, np.float32),
            np.zeros(self.P * self.epp, np.bool_))
        # frontier-compacted sparse waves (DESIGN.md §12.4): "sparse"
        # compacts inside every wave body (single rung + in-cond dense
        # fallback); "auto" routes dense — the occupancy signal is
        # device-only here and must not be synced per epoch (§2.4)
        self._fcap = 0
        if cfg.frontier_mode == "sparse":
            self._fcap = frontier_mod.capacity_ladder(
                cfg.edges_per_part, cfg.frontier_cap)[-1]
        # bucket_width="auto" resolution cache (same policy as the
        # single-device engine: pow2-quantized live-weight median,
        # re-resolved when the live-edge estimate doubles/halves)
        self._bw_cache: tuple[float, int] | None = None
        self._base_key = (mesh, n_pad, cfg.edges_per_part, cfg.exchange,
                          cfg.delta_cap, cfg.use_doubling, self._source_pad,
                          cfg.wave_schedule, self._fcap)
        # bucketed schedule: sharded pending masks (bool per owned vertex,
        # [S, N] stacked in serving mode), reset to the cached zeros after
        # every drain
        self.bucketed = cfg.wave_schedule == "buckets"
        if self.bucketed:
            shape = ((self.P * self.npp,) if self.sources is None
                     else (len(self.sources), self.P * self.npp))
            sh = (self.ds.vertex_sharding() if self.sources is None
                  else self.ds.vertex_sharding_ms())
            self._zero_pend = jax.device_put(np.zeros(shape, np.bool_), sh)
            self._push = self._pull = self._zero_pend
        # touched-vertex attribution baseline (§10.5): dist as of the last
        # metrics readout; compared once per snapshot, never per epoch
        self._obs_dist_mark = self.dist if self.obs.enabled else None

    def _epoch_pair(self):
        """The (add_epoch, del_epoch, drain_epoch) triple for the CURRENT
        backend geometry — looked up per batch because a coupled rebuild may
        change the backend's static key (e.g. the sliced widths tuple).
        ``drain_epoch`` is None under the rounds schedule."""
        bw = self._bucket_width()
        key = self._base_key + (bw,) + self.bk.static_key()
        if key not in _EPOCH_CACHE:
            build = (_build_epochs if self.sources is None
                     else _build_epochs_ms)
            _EPOCH_CACHE[key] = build(
                self.ds, self.epp, self.cfg.use_doubling, self._source_pad,
                self.cfg.relax_backend, self.bk.static_key(),
                self.cfg.wave_schedule, bw, self._fcap)
        return _EPOCH_CACHE[key]

    def _bucket_width(self) -> float:
        """Resolve ``bucket_width="auto"`` host-side from the concatenated
        per-partition mirror weights — same quantize/re-resolve policy as
        ``SSSPDelEngine._bucket_width`` so the two engines pick the same
        width on the same stream (no device sync; mirrors are host state)."""
        if self.cfg.bucket_width != "auto":
            return self.cfg.bucket_width
        live_est = max(1, self.n_adds - self.n_dels)
        if self._bw_cache is not None:
            width, at = self._bw_cache
            if at / 2 <= live_est <= at * 2:
                return width
        w = np.concatenate([a.active_coo()[2] for a in self.allocs]) \
            if self.allocs else np.empty(0, np.float32)
        if len(w) == 0:
            width = 1.0
        else:
            med = max(float(np.percentile(w, 50.0)), 1e-6)
            width = float(2.0 ** np.round(np.log2(med)))
        self._bw_cache = (width, live_est)
        return width

    # ------------------------------------------------------- per-epoch obs
    def _fold_epoch_obs(self) -> None:
        """Post-epoch §10.6 recording, ZERO device dispatches: the epochs
        return updated CUMULATIVE round/message counters, so appending the
        returned array references is enough — consecutive diffs (the same
        deltas ``drain_waves`` uses) become the per-epoch histogram
        samples in one stacked fold at snapshot flush."""
        self.obs.hist_cumulative("hist_waves_per_epoch", self._dev_rounds)
        self.obs.hist_cumulative("hist_messages_per_epoch",
                                 self._dev_messages)

    def _obs_pre_snapshot(self) -> None:
        """Per-partition touched-vertex attribution (§10.5): vertices whose
        dist changed since the LAST metrics readout, reduced shard-locally
        to a [P] vector ([S] per-lane batched).  One compare per READOUT —
        per-epoch diffing would dominate the tiny sharded epochs and break
        the §10.4 overhead contract."""
        mark = self._obs_dist_mark
        if mark is not None and mark.shape == self.dist.shape:
            upd = per_partition_occupancy(self.dist != mark, self.P,
                                          self.npp)
            if self.sources is None:
                self.obs.counters.add("updates_per_part", upd,
                                      dim="partition")
            else:
                self.obs.counters.add("updates_per_lane", upd, dim="lane")
        self._obs_dist_mark = self.dist

    # ------------------------------------------------------------------ adds
    def _ingest_adds(self, batch: ev.EventBatch) -> None:
        src, dst, w = batch.src, batch.dst, batch.w
        if self.perm is not None:
            src, dst = self.perm[src], self.perm[dst]
        owner = np.asarray(dst, np.int64) // self.npp
        parts, plans = [], []
        for p in np.unique(owner):
            sel = owner == p
            plan = self.allocs[p].plan_adds(src[sel], dst[sel], w[sel])
            if len(plan.slots):
                plans.append((int(p), plan))
                parts.append((int(p) * self.epp + plan.slots.astype(np.int64),
                              plan.src, plan.dst, plan.w))
        if not parts:
            return
        gslot, bsrc, bdst, bw = (np.concatenate(x) for x in zip(*parts))
        n_acc = len(gslot)
        with self.obs.epoch("add_epoch", events=n_acc):
            self.bk.stage_adds(plans)  # layout patches (or coupled rebuild)
            self.obs.note_layout(self.bk.layout_counters())
            if self.obs.enabled:
                # host-planned figures (§10.1): frontier = distinct inserted
                # tails; adds_per_part = a [P] numpy tally — no device work
                tails = np.unique(bsrc)
                nf = len(tails)
                self.obs.counters.inc("frontier", nf)
                # occupancy histogram sample + per-partition frontier
                # attribution (owners of the tail vertices) — §10.5/§10.6;
                # owners partition the tails, so sum(frontier_per_part)
                # stays == the flat "frontier" counter
                self.obs.hist_host("hist_frontier_occupancy", nf)
                self.obs.counters.inc(
                    "frontier_per_part",
                    np.bincount(tails.astype(np.int64) // self.npp,
                                minlength=self.P).astype(np.int64),
                    dim="partition")
                per_part = np.zeros(self.P, np.int64)
                for p, plan in plans:
                    per_part[p] = len(plan.slots)
                self.obs.counters.inc("adds_per_part", per_part,
                                      dim="partition")
                if self.obs.watchdog is not None:
                    self.obs.watchdog.observe(
                        "add_epoch", 0.0, {"frontier": nf})
            gslot, bsrc, bdst, bw = ingest.pad_pow2(
                gslot.astype(np.int32), bsrc, bdst, bw)
            add_epoch, _, _ = self._epoch_pair()
            if self.bucketed:
                # deferred settle (DESIGN.md §9): patch the pools, enqueue
                # the inserted tails as push obligations, no relaxation —
                # and so no waves/messages histogram sample (the drain's
                # delta carries those figures)
                (self.esrc, self.edst, self.ew, self.eact,
                 self._push) = add_epoch(
                    self.dist, self.esrc, self.edst, self.ew, self.eact,
                    self._push, jnp.asarray(gslot), jnp.asarray(bsrc),
                    jnp.asarray(bdst), jnp.asarray(bw))
            else:
                (self.dist, self.parent, self.esrc, self.edst, self.ew,
                 self.eact, self._dev_rounds, self._dev_messages) = add_epoch(
                    self.dist, self.parent, self.esrc, self.edst, self.ew,
                    self.eact, *self.bk.arrays(),
                    jnp.asarray(gslot), jnp.asarray(bsrc), jnp.asarray(bdst),
                    jnp.asarray(bw), self._dev_rounds, self._dev_messages)
                if self.obs.enabled:
                    self._fold_epoch_obs()
            self.n_adds += n_acc
            self.n_epochs += 1

    # ------------------------------------------------------------------ dels
    def _ingest_dels(self, batch: ev.EventBatch) -> None:
        for gsrc, gdst in self._deletion_groups(batch):
            if self.perm is not None:
                gsrc, gdst = self.perm[gsrc], self.perm[gdst]
            owner = np.asarray(gdst, np.int64) // self.npp
            parts = []
            for p in np.unique(owner):
                sel = owner == p
                slots, psrc, pdst = self.allocs[p].plan_dels(
                    gsrc[sel], gdst[sel])
                if len(slots):
                    parts.append((int(p) * self.epp + slots.astype(np.int64),
                                  psrc, pdst))
            if not parts:
                continue
            gslot, psrc, pdst = (np.concatenate(x) for x in zip(*parts))
            n_del = len(gslot)
            with self.obs.epoch("del_epoch", events=n_del):
                if self.obs.enabled:
                    per_part = np.zeros(self.P, np.int64)
                    for g, _, _ in parts:
                        per_part[int(g[0] // self.epp)] = len(g)
                    self.obs.counters.inc("dels_per_part", per_part,
                                          dim="partition")
                gslot, psrc, pdst = ingest.pad_pow2(
                    gslot.astype(np.int32), psrc, pdst)
                _, del_epoch, _ = self._epoch_pair()
                # the layout tombstone runs INSIDE the fused epoch (before
                # the recompute wave; the seed reads only the parent forest)
                # — a staged patch would cost one extra dispatch per
                # deletion, and deletions are per-event in the
                # paper-faithful mode
                n_mut = len(type(self.bk).del_mutated)
                if self.bucketed:
                    # invalidation-only epoch: seed + mark + SetToInfinity +
                    # tombstone; the recompute pull and push waves are
                    # deferred into the pending masks (DESIGN.md §9)
                    out = del_epoch(
                        self.dist, self.parent, self.eact, *self.bk.arrays(),
                        self._push, self._pull, jnp.asarray(gslot),
                        jnp.asarray(psrc), jnp.asarray(pdst),
                        self._dev_rounds, self._dev_messages)
                    self.dist, self.parent, self.eact = out[:3]
                    if n_mut:
                        self.bk.update_del_arrays(out[3:3 + n_mut])
                    (self._push, self._pull, self._dev_rounds,
                     self._dev_messages) = out[3 + n_mut:]
                else:
                    out = del_epoch(
                        self.dist, self.parent, self.esrc, self.edst,
                        self.ew, self.eact, *self.bk.arrays(),
                        jnp.asarray(gslot), jnp.asarray(psrc),
                        jnp.asarray(pdst), self._dev_rounds,
                        self._dev_messages)
                    self.dist, self.parent, self.eact = out[:3]
                    if n_mut:
                        self.bk.update_del_arrays(out[3:3 + n_mut])
                    self._dev_rounds, self._dev_messages = out[3 + n_mut:]
                if self.obs.enabled:
                    self._fold_epoch_obs()
                self.n_dels += n_del
                self.n_epochs += 1

    # ----------------------------------------------------------------- query
    def drain(self) -> None:
        """Settle the bucketed schedule's pending work (no-op under the
        rounds schedule; with nothing pending the epoch is one cheap
        dispatch — the drain loop exits immediately, no host sync).  Same
        contract as the single-device ``SSSPDelEngine.drain``."""
        if not self.bucketed:
            return
        if self.obs.enabled:
            # bucket occupancy at drain entry (lazy shard-local sums, §10.1):
            # [P] per-partition row counts, or [S] per-lane totals batched —
            # accumulated on device, drained with the registry snapshot
            occ_dim = "partition" if self.sources is None else "lane"
            self.obs.counters.add("pending_push", per_partition_occupancy(
                self._push, self.P, self.npp), dim=occ_dim)
            self.obs.counters.add("pending_pull", per_partition_occupancy(
                self._pull, self.P, self.npp), dim=occ_dim)
        with self.obs.epoch("drain"):
            _, _, drain_epoch = self._epoch_pair()
            r0 = self._dev_rounds
            (self.dist, self.parent, self._dev_rounds,
             self._dev_messages) = drain_epoch(
                self.dist, self.parent, self.esrc, self.edst, self.ew,
                self.eact, *self.bk.arrays(), self._push, self._pull,
                self._dev_rounds, self._dev_messages)
            self._push = self._pull = self._zero_pend
            if self.obs.enabled:
                # waves this drain spent — a lazy device delta of the same
                # counter n_rounds reads (bit-consistent by construction)
                self.obs.counters.add("drain_waves", self._dev_rounds - r0)
                self._fold_epoch_obs()

    def _snapshot(self, lane: int | None) -> tuple[np.ndarray, np.ndarray]:
        """Sharded device->host readback plus the inverse relabeling, if
        any (latency is timed by the base query()); a routed lane query
        transfers only that source's padded [N] pair."""
        self.drain()
        d, p = (self.dist, self.parent) if lane is None else \
            (self.dist[lane], self.parent[lane])
        dist = np.asarray(jax.device_get(d))
        parent = np.asarray(jax.device_get(p))
        n = self.cfg.num_vertices
        if self.perm is not None:
            dist = dist[..., self.perm]
            pp = parent[..., self.perm]
            parent = np.where(pp >= 0, self.inv[np.clip(pp, 0, None)],
                              NO_PARENT).astype(np.int32)
        else:
            dist, parent = dist[..., :n], parent[..., :n]
        return dist, parent

    # ------------------------------------------------------------ checkpoint
    def checkpoint(self) -> dict[str, np.ndarray]:
        """Single-device-schema snapshot (engine.SSSPDelEngine.checkpoint):
        pool arrays in partition-major global-slot order (from the host
        mirrors — no device readback for the pool) plus the padded
        dist/parent windows.  Backend layout state is rebuilt on restore,
        never serialized."""
        with self.obs.epoch("checkpoint"):
            self.drain()   # a checkpoint must capture a converged tree
            return {
                "src": np.concatenate([a.msrc for a in self.allocs]),
                "dst": np.concatenate([a.mdst for a in self.allocs]),
                "w": np.concatenate([a.mw for a in self.allocs]),
                "active": np.concatenate([a.mactive for a in self.allocs]),
                "dist": np.asarray(jax.device_get(self.dist)),
                "parent": np.asarray(jax.device_get(self.parent)),
                "source": np.asarray(self._source_pad),
                "cursor": np.asarray(0),
            }

    def restore(self, ckpt: dict[str, np.ndarray]) -> None:
        """Crash-restart from a ``checkpoint()`` snapshot taken by an engine
        with the same config/mesh/relabel.  Rebuilds the per-partition
        planners from the pool slices, re-shards the device arrays, and
        rebuilds the backend layout from the mirrors."""
        src_ck = np.atleast_1d(np.asarray(ckpt["source"])).tolist()
        src_now = np.atleast_1d(np.asarray(self._source_pad)).tolist()
        assert src_ck == src_now, "source mismatch"
        assert ckpt["dist"].shape[-1] == self.P * self.npp, (
            f"checkpoint has {ckpt['dist'].shape[-1]} vertex rows; this "
            f"engine pads to {self.P * self.npp} — same P/mesh required")
        assert len(ckpt["src"]) == self.P * self.epp, (
            f"checkpoint has {len(ckpt['src'])} pool slots; this engine "
            f"expects {self.P * self.epp} — same edges_per_part required")
        epp = self.epp
        alloc_cls = ingest.allocator_cls(self.cfg.alloc_impl)
        self.allocs = [
            alloc_cls.from_pool(
                epp, self.cfg.on_duplicate,
                ckpt["src"][p * epp:(p + 1) * epp],
                ckpt["dst"][p * epp:(p + 1) * epp],
                ckpt["w"][p * epp:(p + 1) * epp],
                ckpt["active"][p * epp:(p + 1) * epp])
            for p in range(self.P)]
        # inactive slots must keep the padding-row invariant for the
        # shard-local segment ids (see inactive_dst_layout)
        dst = np.where(ckpt["active"], ckpt["dst"],
                       inactive_dst_layout(self.P, self.npp, epp))
        self.esrc, self.edst, self.ew, self.eact = self.ds.put_edges(
            np.asarray(ckpt["src"], np.int32), dst.astype(np.int32),
            np.asarray(ckpt["w"], np.float32),
            np.asarray(ckpt["active"], np.bool_))
        sh = (self.ds.vertex_sharding() if self.sources is None
              else self.ds.vertex_sharding_ms())
        self.dist = jax.device_put(
            np.asarray(ckpt["dist"], np.float32), sh)
        self.parent = jax.device_put(
            np.asarray(ckpt["parent"], np.int32), sh)
        self.bk.allocs = self.allocs
        self.bk.restore()
        # the restore's layout rebuild is a real rebuild event (§10)
        self.obs.note_layout(self.bk.layout_counters())
        # checkpoints are taken post-drain, so nothing was pending
        if self.bucketed:
            self._push = self._pull = self._zero_pend

    # ------------------------------------------------------------ diagnostics
    def partition_fill(self) -> np.ndarray:
        """Live edges per partition, from the host mirrors (no device sync)."""
        return np.array([int(a.mactive.sum()) for a in self.allocs])


def _build_epochs(ds: DistributedSSSP, epp: int, use_doubling: bool,
                  source_pad: int, backend: str, backend_static: tuple,
                  wave_schedule: str = "rounds", bucket_width: float = 1.0,
                  frontier_cap: int = 0):
    """Build the (add_epoch, del_epoch, drain_epoch) jitted shard_map triple
    for one backend geometry.  Under the rounds schedule the epochs settle
    in place and ``drain_epoch`` is None; under the bucketed schedule the
    add/del epochs are the lazy (invalidation-only) variants and the drain
    epoch settles the pending masks (DESIGN.md §9).

    Module-level on purpose: the closures capture only ``ds`` (mesh + config
    + specs, no device buffers), scalars, and the backend's *static* wave
    factory — layout arrays arrive as epoch arguments — so ``_EPOCH_CACHE``
    entries never pin an engine's device state or host mirrors.
    """
    npp = ds.npp
    ax = ds.cfg.mesh_axes
    exchange = ds.cfg.exchange
    v, e, r = ds.vspec, ds.espec, ds.rspec
    bk_cls = SHARDED_BACKENDS[backend]
    n_extra = bk_cls.n_extra
    make_wave = bk_cls.shard_wave_factory(backend_static, npp)
    if frontier_cap:
        # frontier-compacted sparse waves (DESIGN.md §12.4): compact this
        # partition's live-offer edges inside the wave body; the backend's
        # own dense wave is the in-cond fallback, so every epoch below is
        # unchanged — delta exchange already ships sparse offers
        make_wave = frontier_mod.wrap_shard_wave(make_wave, npp, frontier_cap)
    del_patch = bk_cls.shard_del_patch(backend_static, npp)
    del_mutated = bk_cls.del_mutated
    extra_specs = (v,) * n_extra

    def masked_write(arr, loc, val):
        """Scatter batch values into this shard's pool slice.  Foreign batch
        entries are routed to a sacrificial extra slot (index epp) instead of
        a masked in-range index — a masked write at a real index would race
        with a genuine write to the same slot."""
        pad = jnp.zeros((1,), arr.dtype)
        return jnp.concatenate([arr, pad]).at[loc].set(
            val.astype(arr.dtype))[:epp]

    def local_slots(gslot, my_p):
        mine = (gslot // epp) == my_p
        return jnp.where(mine, gslot - my_p * epp, epp)

    @jax.jit
    @partial(_shard_map, mesh=ds.mesh,
             in_specs=(v, v, e, e, e, e) + extra_specs + (r, r, r, r, r, r),
             out_specs=(v, v, e, e, e, e, r, r),
             **_SHARD_MAP_KW)
    def add_epoch(dist, parent, esrc, edst, ew, eact, *rest):
        """patch pools + relax from the inserted tails, one fused epoch.
        Layout extras arrive already patched (staged before the epoch)."""
        extras = rest[:n_extra]
        gslot, bsrc, bdst, bw, racc, macc = rest[n_extra:]
        my_p = jnp.int32(ds._flat_index())
        row0 = my_p * npp
        loc = local_slots(gslot, my_p)
        esrc = masked_write(esrc, loc, bsrc)
        edst = masked_write(edst, loc, bdst)
        ew = masked_write(ew, loc, bw)
        eact = masked_write(eact, loc, jnp.ones_like(gslot, jnp.bool_))
        # Frontier = tails of the inserted edges (paper Listing 3); each
        # shard keeps its own window of the global bool frontier.
        in_r = (bsrc >= row0) & (bsrc < row0 + npp)
        fr = jnp.zeros((npp,), jnp.bool_).at[
            jnp.clip(bsrc - row0, 0, npp - 1)].max(in_r)
        wave = make_wave(esrc, edst, ew, eact, extras, my_p)
        dist, parent, rounds, msgs = ds._relax_body(dist, parent, fr, wave)
        return (dist, parent, esrc, edst, ew, eact,
                racc + rounds, macc + msgs)

    @jax.jit
    @partial(_shard_map, mesh=ds.mesh,
             in_specs=(v, v, e, e, e, e) + extra_specs + (r, r, r, r, r),
             out_specs=(v, v, e) + (v,) * len(del_mutated) + (r, r),
             **_SHARD_MAP_KW)
    def del_epoch(dist, parent, esrc, edst, ew, eact, *rest):
        """seed from pre-deletion tree + deactivate + tombstone layout +
        invalidate + recompute, one fused epoch.  Stats mirror
        core/delete.DeleteStats exactly.  The backend's layout tombstone
        (``shard_del_patch``) runs in-epoch; the mutated layout arrays are
        returned after (dist, parent, eact)."""
        extras = list(rest[:n_extra])
        gslot, psrc, pdst, racc, macc = rest[n_extra:]
        my_p = jnp.int32(ds._flat_index())
        row0 = my_p * npp
        # Listing 4: only deletions of tree edges (parent[head]==tail)
        # seed invalidation — judged against the PRE-deletion tree.
        in_r = (pdst >= row0) & (pdst < row0 + npp)
        lds = jnp.clip(pdst - row0, 0, npp - 1)
        seed = jnp.zeros((npp,), jnp.bool_).at[lds].max(
            in_r & (parent[lds] == psrc))
        any_seed = jax.lax.psum(jnp.sum(seed.astype(jnp.int32)), ax) > 0
        # deactivate the deleted slots (dst stays in-range)
        loc = local_slots(gslot, my_p)
        eact = masked_write(eact, loc, jnp.zeros_like(gslot, jnp.bool_))
        # tombstone the backend layout (the recompute must not see the
        # deleted edges; the seed above reads only the parent forest)
        if del_patch is not None:
            new_vals = del_patch(tuple(extras), psrc, pdst, my_p)
            for i, val in zip(del_mutated, new_vals):
                extras[i] = val
        # --- invalidation over the parent forest
        if use_doubling:
            aff, inv_rounds = ds._invalidate_doubling(parent, seed)
        elif exchange == "delta":
            aff, inv_rounds = ds._invalidate_delta(parent, seed, row0)
        else:
            aff, inv_rounds = ds._invalidate_flood_dense(parent, seed)
        # never invalidate the source (parity with single-device engine)
        local_ids = row0 + jnp.arange(npp, dtype=jnp.int32)
        aff = aff & (local_ids != source_pad)
        affected = jax.lax.psum(jnp.sum(aff.astype(jnp.int32)), ax)
        dist = jnp.where(aff, INF, dist)
        parent = jnp.where(aff, NO_PARENT, parent)
        # --- recomputation (shared with the static delete epoch; the
        # distributed rendering of delete.invalidate_and_recompute), with
        # the backend's wave in place of the hardwired segment-min
        wave = make_wave(esrc, edst, ew, eact, tuple(extras), my_p)
        if exchange == "delta":
            dist, parent, rec_rounds, rec_msgs = ds._recompute_delta(
                dist, parent, aff, esrc, edst, eact, wave, row0)
        else:
            dist, parent, rec_rounds, rec_msgs = ds._recompute_pull_push(
                dist, parent, aff, wave)
        zero = jnp.int32(0)
        d_rounds = jnp.where(any_seed, inv_rounds + rec_rounds, zero)
        d_msgs = jnp.where(any_seed, rec_msgs, zero) + affected
        return (dist, parent, eact, *(extras[i] for i in del_mutated),
                racc + d_rounds, macc + d_msgs)

    if wave_schedule == "rounds":
        return add_epoch, del_epoch, None

    # ---------------------------------------- bucketed (lazy) epoch variants
    @jax.jit
    @partial(_shard_map, mesh=ds.mesh,
             in_specs=(v, e, e, e, e, v, r, r, r, r),
             out_specs=(e, e, e, e, v),
             **_SHARD_MAP_KW)
    def add_epoch_lazy(dist, esrc, edst, ew, eact, push,
                       gslot, bsrc, bdst, bw):
        """Bucketed ADD: patch the pools + enqueue the inserted tails as
        push obligations (pruned to currently-reachable tails, the sharded
        ``buckets.enqueue_push``) — no relaxation until the drain."""
        my_p = jnp.int32(ds._flat_index())
        row0 = my_p * npp
        loc = local_slots(gslot, my_p)
        esrc = masked_write(esrc, loc, bsrc)
        edst = masked_write(edst, loc, bdst)
        ew = masked_write(ew, loc, bw)
        eact = masked_write(eact, loc, jnp.ones_like(gslot, jnp.bool_))
        in_r = (bsrc >= row0) & (bsrc < row0 + npp)
        fr = jnp.zeros((npp,), jnp.bool_).at[
            jnp.clip(bsrc - row0, 0, npp - 1)].max(in_r)
        push = push | (fr & jnp.isfinite(dist))
        return esrc, edst, ew, eact, push

    @jax.jit
    @partial(_shard_map, mesh=ds.mesh,
             in_specs=(v, v, e) + extra_specs + (v, v, r, r, r, r, r),
             out_specs=(v, v, e) + (v,) * len(del_mutated) + (v, v, r, r),
             **_SHARD_MAP_KW)
    def del_epoch_lazy(dist, parent, eact, *rest):
        """Bucketed DEL: seed + deactivate + tombstone + invalidate — the
        immediate work the witness-invariant argument requires — with the
        recompute deferred into (push, pull).  The sharded rendering of
        ``buckets.lazy_delete``; stats mirror its DeleteStats exactly."""
        extras = list(rest[:n_extra])
        push, pull, gslot, psrc, pdst, racc, macc = rest[n_extra:]
        my_p = jnp.int32(ds._flat_index())
        row0 = my_p * npp
        in_r = (pdst >= row0) & (pdst < row0 + npp)
        lds = jnp.clip(pdst - row0, 0, npp - 1)
        seed = jnp.zeros((npp,), jnp.bool_).at[lds].max(
            in_r & (parent[lds] == psrc))
        any_seed = jax.lax.psum(jnp.sum(seed.astype(jnp.int32)), ax) > 0
        loc = local_slots(gslot, my_p)
        eact = masked_write(eact, loc, jnp.zeros_like(gslot, jnp.bool_))
        if del_patch is not None:
            new_vals = del_patch(tuple(extras), psrc, pdst, my_p)
            for i, val in zip(del_mutated, new_vals):
                extras[i] = val
        if use_doubling:
            aff, inv_rounds = ds._invalidate_doubling(parent, seed,
                                                      gate=any_seed)
        elif exchange == "delta":
            aff, inv_rounds = ds._invalidate_delta(parent, seed, row0,
                                                   gate=any_seed)
        else:
            aff, inv_rounds = ds._invalidate_flood_dense(parent, seed,
                                                         gate=any_seed)
        local_ids = row0 + jnp.arange(npp, dtype=jnp.int32)
        aff = aff & (local_ids != source_pad)
        affected = jax.lax.psum(jnp.sum(aff.astype(jnp.int32)), ax)
        dist = jnp.where(aff, INF, dist)
        parent = jnp.where(aff, NO_PARENT, parent)
        # invalidated vertices stop offering; they re-enter via the drain
        push = push & jnp.isfinite(dist)
        pull = pull | aff
        d_rounds = jnp.where(any_seed, inv_rounds, jnp.int32(0))
        return (dist, parent, eact, *(extras[i] for i in del_mutated),
                push, pull, racc + d_rounds, macc + affected)

    @jax.jit
    @partial(_shard_map, mesh=ds.mesh,
             in_specs=(v, v, e, e, e, e) + extra_specs + (v, v, r, r),
             out_specs=(v, v, r, r),
             **_SHARD_MAP_KW)
    def drain_epoch(dist, parent, esrc, edst, ew, eact, *rest):
        """Settle the pending masks bucket-by-bucket with the backend's
        wave; the caller resets (push, pull) to zeros afterwards."""
        extras = rest[:n_extra]
        push, pull, racc, macc = rest[n_extra:]
        my_p = jnp.int32(ds._flat_index())
        row0 = my_p * npp
        wave = make_wave(esrc, edst, ew, eact, extras, my_p)
        dist, parent, rounds, msgs = ds._drain_body(
            dist, parent, push, pull, wave, row0, bucket_width)
        return dist, parent, racc + rounds, macc + msgs

    return add_epoch_lazy, del_epoch_lazy, drain_epoch


def _build_epochs_ms(ds: DistributedSSSP, epp: int, use_doubling: bool,
                     sources_pad: tuple[int, ...], backend: str,
                     backend_static: tuple,
                     wave_schedule: str = "rounds", bucket_width: float = 1.0,
                     frontier_cap: int = 0):
    """Batched multi-source rendering of ``_build_epochs`` (DESIGN.md §8):
    the (add_epoch, del_epoch, drain_epoch) triple for S stacked trees over
    one shared sharded pool + layout.

    Same contract as the single-source builder — module-level, closures
    capture only static config — plus the serving-mode shape rules: vertex
    state is [S, npp] per shard (``ds.vspec_ms``), per-source stat counters
    are replicated [S] vectors, the pool/layout patches run ONCE (shared
    graph), and each lane's relax/invalidate/recompute is the ``*_ms`` body
    with the backend's pure shard-local wave vmapped over the source axis.
    Per lane the results are bit-identical to the single-source epochs for
    that lane's source (tests/test_serving.py).
    """
    npp = ds.npp
    ax = ds.cfg.mesh_axes
    exchange = ds.cfg.exchange
    S = len(sources_pad)
    v, vb, e, r = ds.vspec, ds.vspec_ms, ds.espec, ds.rspec
    bk_cls = SHARDED_BACKENDS[backend]
    n_extra = bk_cls.n_extra
    make_wave = bk_cls.shard_wave_factory(backend_static, npp)
    if frontier_cap:
        # per-lane sparse waves under vmap lower the cond to select (both
        # branches execute) — correctness-grade, same §12.3 batched caveat
        make_wave = frontier_mod.wrap_shard_wave(make_wave, npp, frontier_cap)
    del_patch = bk_cls.shard_del_patch(backend_static, npp)
    del_mutated = bk_cls.del_mutated
    extra_specs = (v,) * n_extra

    def masked_write(arr, loc, val):
        pad = jnp.zeros((1,), arr.dtype)
        return jnp.concatenate([arr, pad]).at[loc].set(
            val.astype(arr.dtype))[:epp]

    def local_slots(gslot, my_p):
        mine = (gslot // epp) == my_p
        return jnp.where(mine, gslot - my_p * epp, epp)

    @jax.jit
    @partial(_shard_map, mesh=ds.mesh,
             in_specs=(vb, vb, e, e, e, e) + extra_specs + (r, r, r, r, r, r),
             out_specs=(vb, vb, e, e, e, e, r, r),
             **_SHARD_MAP_KW)
    def add_epoch(dist, parent, esrc, edst, ew, eact, *rest):
        """One shared pool patch + the SAME insertion frontier broadcast to
        every lane (ADD tails are source-independent), then the batched
        relax body to per-lane fixpoints."""
        extras = rest[:n_extra]
        gslot, bsrc, bdst, bw, racc, macc = rest[n_extra:]
        my_p = jnp.int32(ds._flat_index())
        row0 = my_p * npp
        loc = local_slots(gslot, my_p)
        esrc = masked_write(esrc, loc, bsrc)
        edst = masked_write(edst, loc, bdst)
        ew = masked_write(ew, loc, bw)
        eact = masked_write(eact, loc, jnp.ones_like(gslot, jnp.bool_))
        in_r = (bsrc >= row0) & (bsrc < row0 + npp)
        fr = jnp.zeros((npp,), jnp.bool_).at[
            jnp.clip(bsrc - row0, 0, npp - 1)].max(in_r)
        fr_b = jnp.broadcast_to(fr, (S, npp))
        wave = make_wave(esrc, edst, ew, eact, extras, my_p)
        dist, parent, rounds, msgs = ds._relax_body_ms(
            dist, parent, fr_b, jax.vmap(wave))
        return (dist, parent, esrc, edst, ew, eact,
                racc + rounds, macc + msgs)

    @jax.jit
    @partial(_shard_map, mesh=ds.mesh,
             in_specs=(vb, vb, e, e, e, e) + extra_specs + (r, r, r, r, r),
             out_specs=(vb, vb, e) + (v,) * len(del_mutated) + (r, r),
             **_SHARD_MAP_KW)
    def del_epoch(dist, parent, esrc, edst, ew, eact, *rest):
        """Per-lane seeds (a deletion is a tree edge per lane or not) +
        ONE shared deactivate/tombstone + per-lane invalidate/recompute.
        Stats mirror the single-source del epoch per lane, gated on each
        lane's own any_seed."""
        extras = list(rest[:n_extra])
        gslot, psrc, pdst, racc, macc = rest[n_extra:]
        my_p = jnp.int32(ds._flat_index())
        row0 = my_p * npp
        in_r = (pdst >= row0) & (pdst < row0 + npp)
        lds = jnp.clip(pdst - row0, 0, npp - 1)
        seed = jax.vmap(
            lambda par: jnp.zeros((npp,), jnp.bool_).at[lds].max(
                in_r & (par[lds] == psrc)))(parent)
        any_seed = jax.lax.psum(
            jnp.sum(seed.astype(jnp.int32), axis=1), ax) > 0        # [S]
        loc = local_slots(gslot, my_p)
        eact = masked_write(eact, loc, jnp.zeros_like(gslot, jnp.bool_))
        if del_patch is not None:
            new_vals = del_patch(tuple(extras), psrc, pdst, my_p)
            for i, val in zip(del_mutated, new_vals):
                extras[i] = val
        if use_doubling:
            aff, inv_rounds = ds._invalidate_doubling_ms(parent, seed)
        elif exchange == "delta":
            aff, inv_rounds = ds._invalidate_delta_ms(parent, seed, row0)
        else:
            aff, inv_rounds = ds._invalidate_flood_dense_ms(parent, seed)
        # never invalidate each lane's own source
        local_ids = row0 + jnp.arange(npp, dtype=jnp.int32)
        src_arr = jnp.asarray(sources_pad, jnp.int32)
        aff = aff & (local_ids[None, :] != src_arr[:, None])
        affected = jax.lax.psum(jnp.sum(aff.astype(jnp.int32), axis=1), ax)
        dist = jnp.where(aff, INF, dist)
        parent = jnp.where(aff, NO_PARENT, parent)
        wave = make_wave(esrc, edst, ew, eact, tuple(extras), my_p)
        wave_b = jax.vmap(wave)
        if exchange == "delta":
            dist, parent, rec_rounds, rec_msgs = ds._recompute_delta_ms(
                dist, parent, aff, esrc, edst, eact, wave_b, row0)
        else:
            dist, parent, rec_rounds, rec_msgs = ds._recompute_pull_push_ms(
                dist, parent, aff, wave_b)
        zero = jnp.zeros((S,), jnp.int32)
        d_rounds = jnp.where(any_seed, inv_rounds + rec_rounds, zero)
        d_msgs = jnp.where(any_seed, rec_msgs, zero) + affected
        return (dist, parent, eact, *(extras[i] for i in del_mutated),
                racc + d_rounds, macc + d_msgs)

    if wave_schedule == "rounds":
        return add_epoch, del_epoch, None

    # ---------------------------------------- bucketed (lazy) epoch variants
    @jax.jit
    @partial(_shard_map, mesh=ds.mesh,
             in_specs=(vb, e, e, e, e, vb, r, r, r, r),
             out_specs=(e, e, e, e, vb),
             **_SHARD_MAP_KW)
    def add_epoch_lazy(dist, esrc, edst, ew, eact, push,
                       gslot, bsrc, bdst, bw):
        """Bucketed ADD: one shared pool patch + the shared tail frontier
        enqueued per lane, pruned to each lane's reachable tails."""
        my_p = jnp.int32(ds._flat_index())
        row0 = my_p * npp
        loc = local_slots(gslot, my_p)
        esrc = masked_write(esrc, loc, bsrc)
        edst = masked_write(edst, loc, bdst)
        ew = masked_write(ew, loc, bw)
        eact = masked_write(eact, loc, jnp.ones_like(gslot, jnp.bool_))
        in_r = (bsrc >= row0) & (bsrc < row0 + npp)
        fr = jnp.zeros((npp,), jnp.bool_).at[
            jnp.clip(bsrc - row0, 0, npp - 1)].max(in_r)
        push = push | (fr[None, :] & jnp.isfinite(dist))
        return esrc, edst, ew, eact, push

    @jax.jit
    @partial(_shard_map, mesh=ds.mesh,
             in_specs=(vb, vb, e) + extra_specs + (vb, vb, r, r, r, r, r),
             out_specs=(vb, vb, e) + (v,) * len(del_mutated) + (vb, vb, r, r),
             **_SHARD_MAP_KW)
    def del_epoch_lazy(dist, parent, eact, *rest):
        """Bucketed DEL: per-lane seeds + ONE shared deactivate/tombstone +
        per-lane gated invalidation; recompute deferred into (push, pull)."""
        extras = list(rest[:n_extra])
        push, pull, gslot, psrc, pdst, racc, macc = rest[n_extra:]
        my_p = jnp.int32(ds._flat_index())
        row0 = my_p * npp
        in_r = (pdst >= row0) & (pdst < row0 + npp)
        lds = jnp.clip(pdst - row0, 0, npp - 1)
        seed = jax.vmap(
            lambda par: jnp.zeros((npp,), jnp.bool_).at[lds].max(
                in_r & (par[lds] == psrc)))(parent)
        any_seed = jax.lax.psum(
            jnp.sum(seed.astype(jnp.int32), axis=1), ax) > 0        # [S]
        loc = local_slots(gslot, my_p)
        eact = masked_write(eact, loc, jnp.zeros_like(gslot, jnp.bool_))
        if del_patch is not None:
            new_vals = del_patch(tuple(extras), psrc, pdst, my_p)
            for i, val in zip(del_mutated, new_vals):
                extras[i] = val
        if use_doubling:
            aff, inv_rounds = ds._invalidate_doubling_ms(parent, seed,
                                                         gate=any_seed)
        elif exchange == "delta":
            aff, inv_rounds = ds._invalidate_delta_ms(parent, seed, row0,
                                                      gate=any_seed)
        else:
            aff, inv_rounds = ds._invalidate_flood_dense_ms(parent, seed,
                                                            gate=any_seed)
        local_ids = row0 + jnp.arange(npp, dtype=jnp.int32)
        src_arr = jnp.asarray(sources_pad, jnp.int32)
        aff = aff & (local_ids[None, :] != src_arr[:, None])
        affected = jax.lax.psum(jnp.sum(aff.astype(jnp.int32), axis=1), ax)
        dist = jnp.where(aff, INF, dist)
        parent = jnp.where(aff, NO_PARENT, parent)
        push = push & jnp.isfinite(dist)
        pull = pull | aff
        zero = jnp.zeros((S,), jnp.int32)
        d_rounds = jnp.where(any_seed, inv_rounds, zero)
        return (dist, parent, eact, *(extras[i] for i in del_mutated),
                push, pull, racc + d_rounds, macc + affected)

    @jax.jit
    @partial(_shard_map, mesh=ds.mesh,
             in_specs=(vb, vb, e, e, e, e) + extra_specs + (vb, vb, r, r),
             out_specs=(vb, vb, r, r),
             **_SHARD_MAP_KW)
    def drain_epoch(dist, parent, esrc, edst, ew, eact, *rest):
        """Batched drain: per-lane bucket pacing with the vmapped wave."""
        extras = rest[:n_extra]
        push, pull, racc, macc = rest[n_extra:]
        my_p = jnp.int32(ds._flat_index())
        row0 = my_p * npp
        wave = make_wave(esrc, edst, ew, eact, extras, my_p)
        dist, parent, rounds, msgs = ds._drain_body_ms(
            dist, parent, push, pull, jax.vmap(wave), row0, bucket_width)
        return dist, parent, racc + rounds, macc + msgs

    return add_epoch_lazy, del_epoch_lazy, drain_epoch
