"""Frontier-compacted sparse epochs — pay for the affected region, not the
graph (DESIGN.md §12).

Every dense wave in this repo dispatches over all N vertices and all E edge
slots with a boolean [N] frontier mask gating the gather, so a 3-edge ADD on
an N=1M graph pays cold-recompute cost per wave.  This module adds the
sparse execution path selected by ``frontier_mode="sparse"|"auto"``:

  * ``compact_mask`` — device-side cumsum-scan compaction of the [N]
    frontier/pending mask into a bounded [F] ascending, -1-padded
    active-vertex worklist (plus the exact occupancy count);
  * a **capacity ladder** — the wave compacts once at the largest rung and
    dispatches the smallest rung whose vertex count AND edge budgets fit
    via nested ``lax.cond``; when occupancy exceeds every rung the final
    branch IS the dense ``relax.relax_round`` computation over the edge
    pool, so the path is jit-stable and correct at any occupancy;
  * gather-style waves that touch only the OUT-adjacency rows of worklist
    vertices.  All backend layouts are dst-keyed (in-adjacency), so the
    sparse path maintains one backend-independent OUT-adjacency *sidecar*
    (``OutAdjacency``): a ``SlicedEllPlanner`` with the src/dst roles
    swapped — rows are edge *sources*, cells hold destinations, and
    high-out-degree hubs spill to the overflow COO lane which the wave
    filters by frontier membership (``frontier[odst]``);
  * sparse renderings of all three epoch types (relax-to-fixpoint /
    delete / bucketed drain) plus vmapped [S, N] batched variants, each
    mirroring its dense twin's loop carry and stat gating exactly; and
  * ``wrap_shard_wave`` for the sharded engines: per-partition *edge*
    worklists compacted inside the wave body from
    ``eact & isfinite(offers[esrc])`` (the delta exchange already ships
    sparse offers, so only the wave body changes), with the exact dense
    shard wave as the in-``cond`` fallback.

Why this is bit-identical to the dense path (the repo's standard contract):
a wave's result is determined by its candidate multiset ``{(dist[src]+w,
src, dst)}`` plus the smallest-src-id tie rule.  The sparse wave's
candidates are exactly the live out-edges of frontier vertices — the same
set the dense wave's ``active & frontier[src]`` mask selects — and exact
float min is evaluation-order-free, so (dist, parent) match bit-for-bit.
The sparse loops keep the same [N] mask in their carry as the dense loops
(only each wave's *execution* is compacted), so (rounds, messages) match
trivially.  Correctness is therefore rung-independent: the ladder is purely
a cost policy.

Cost model: one sparse wave is O(N + C) cheap elementwise work for the
compaction scans (C = hub overflow capacity) plus O(edge budget) for the
gathers AND the scatter-min — the wave binary-searches its rung's edge
budget over the worklist's degree cumsum, so no F x max-width padding is
ever materialized and the scatter volume (the dominant cost: XLA:CPU
scatters run ~100ns/element) tracks the edges actually touched.  The
dense wave pays O(N + E) gathers/segment reductions — the gap is the win
the paper's small-affected-region premise promises.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import buckets
from repro.core import delete as del_mod
from repro.core import relax
from repro.core.backends.sliced import (SlicedEllPlanner, sliced_append,
                                        sliced_delete, sliced_spill,
                                        sliced_update_min)
from repro.core.relax import RelaxStats
from repro.core.state import INF, NO_PARENT, EdgePool, SSSPState
from repro.graphs import csr as csr_mod
from repro.kernels.relax.gather import (gathered_rows_relax,
                                        gathered_rows_relax_ref)

_INT_MAX = jnp.int32(2**31 - 1)

FRONTIER_MODES = ("dense", "sparse", "auto")


# ------------------------------------------------------ compaction primitive --
@partial(jax.jit, static_argnames=("cap",))
def compact_mask(mask: jax.Array, *, cap: int
                 ) -> tuple[jax.Array, jax.Array]:
    """Compact a bool[N] mask into an ascending i32[cap] vertex worklist.

    Cumsum-scan compaction, gather-flavoured: the i-th set vertex
    (1-based) is the first index whose inclusive prefix count reaches i,
    recovered by a vectorized binary search of ``cap`` slot numbers over
    the [N] cumsum — O(N) elementwise work plus O(cap log N) searches, and
    crucially NO [N]-element scatter (XLA:CPU scatters cost ~100ns/elem,
    which would dwarf every other per-wave cost).  Returns (worklist,
    count) where the worklist is -1-padded and ``count`` is the EXACT
    occupancy ``sum(mask)`` — when ``count > cap`` the worklist is
    truncated and the caller must fall back dense (the capacity ladder's
    job)."""
    cs = jnp.cumsum(mask.astype(jnp.int32))
    count = cs[-1]
    slots = jnp.arange(1, cap + 1, dtype=jnp.int32)
    wl = jnp.searchsorted(cs, slots, side="left").astype(jnp.int32)
    return jnp.where(slots <= count, wl, -1), count


def worklist_to_mask(wl: jax.Array, num_vertices: int) -> jax.Array:
    """Inverse of ``compact_mask`` for in-capacity masks: -1 padding is
    ignored (the round-trip property the tests pin)."""
    safe = jnp.clip(wl, 0, num_vertices - 1)
    return jnp.zeros((num_vertices,), jnp.bool_).at[safe].max(wl >= 0)


def capacity_ladder(num_vertices: int, cap: int = 0) -> tuple[int, ...]:
    """Worklist capacity rungs (ascending).  ``cap=0`` derives the top rung
    as N/64 (>= 256, pow2-rounded); a small first rung keeps the common
    few-vertex waves cheap while the top rung absorbs moderate cascades
    before the dense fallback."""
    if cap <= 0:
        cap = max(256, csr_mod.next_pow2(max(num_vertices, 1)) // 64)
    cap = min(csr_mod.next_pow2(cap), csr_mod.next_pow2(max(num_vertices, 1)))
    low = max(256, cap // 16)
    return (low, cap) if low < cap else (cap,)


def edge_budget(cap: int) -> int:
    """Per-rung edge/overflow capacity: 8 out-edges per worklist slot.  A
    rung is taken only when the frontier's vertex count, its total ELL
    cells AND its live hub-overflow entries all fit (``ladder_wave``), so
    the budget bounds the wave's scatter volume — the dominant cost on
    XLA:CPU — while dense-degree frontiers simply escalate a rung."""
    return 8 * cap


# ------------------------------------------------------ OUT-adjacency sidecar --
class OutAdjacency:
    """Backend-independent OUT-adjacency sidecar for the sparse push waves.

    A ``SlicedEllPlanner`` with the roles swapped: planner *rows* are edge
    SOURCES and the cells hold destination ids, so gathering a worklist
    vertex's row yields its out-neighbors.  High-out-degree hubs spill to
    the overflow COO lane exactly as in the sliced backend — there
    ``osrc`` holds the *destination* (the scatter target) and ``odst`` the
    *source row* (the frontier-membership filter).  Maintenance mirrors
    ``SlicedBackend.apply_adds``/``apply_dels`` with the arguments swapped;
    the sidecar is a derived view and rebuilds from the allocator's host
    mirror on capacity exhaustion or restore (never serialized)."""

    # Per-row slices + a high hub threshold.  Two costs force this corner
    # of the geometry space: (a) every wave pays O(overflow slots) cheap
    # elementwise work for the COO lane regardless of frontier size, so
    # spill must stay rare even on skewed out-degree graphs; (b) every
    # ADD batch functionally rewrites the flat cell arrays (XLA:CPU can't
    # donate buffers), so the flat footprint IS the per-batch maintenance
    # cost — slice_rows=1 gives exact pow2 per-row widths, ~4x fewer
    # cells than 256-row slices on RMAT where one hub inflates 255
    # neighbours.
    def __init__(self, num_vertices: int, *, slice_rows: int = 1,
                 hub_k: int = 1024, init_k: int = 2):
        self.n = num_vertices
        self._knobs = dict(slice_rows=slice_rows, hub_k=hub_k, init_k=init_k)
        self.planner = SlicedEllPlanner(num_vertices, **self._knobs)
        self.state = self.planner.empty_state()

    @property
    def max_width(self) -> int:
        return self.planner.max_width

    def apply_adds(self, plan, alloc) -> None:
        from repro.core import ingest
        fresh = plan.fresh
        sp = self.planner.plan_appends(
            plan.src[fresh].astype(np.int64), plan.dst[fresh], plan.w[fresh])
        if sp is None:
            src, dst, w = alloc.active_coo()
            self.state = self.planner.rebuild(dst, src, w)  # swapped roles
            return
        if len(sp.pos):
            pos_p, rows_p, kpos_p, dst_p, w_p = ingest.pad_pow2(
                sp.pos, sp.rows, sp.kpos, sp.src, sp.w)
            self.state = sliced_append(
                self.state, jnp.asarray(pos_p), jnp.asarray(rows_p),
                jnp.asarray(kpos_p), jnp.asarray(dst_p), jnp.asarray(w_p))
        if len(sp.opos):
            opos_p, odst_p, orows_p, ow_p = ingest.pad_pow2(
                sp.opos, sp.osrc, sp.orows, sp.ow)
            self.state = sliced_spill(
                self.state, jnp.asarray(opos_p), jnp.asarray(odst_p),
                jnp.asarray(orows_p), jnp.asarray(ow_p))
        if not fresh.all():
            upd = ~fresh
            rows_p, dst_p, w_p = ingest.pad_pow2(
                plan.src[upd], plan.dst[upd], plan.w[upd])
            self.state = sliced_update_min(
                self.state, jnp.asarray(rows_p), jnp.asarray(dst_p),
                jnp.asarray(w_p), width=self.planner.max_width)

    def apply_dels(self, src: np.ndarray, dst: np.ndarray) -> None:
        """Tombstone deleted (padded) edges; rows are the edge SOURCES."""
        self.state = sliced_delete(
            self.state, jnp.asarray(src), jnp.asarray(dst),
            width=self.planner.max_width)

    def restore(self, alloc) -> None:
        self.planner = SlicedEllPlanner(self.n, **self._knobs)
        src, dst, w = alloc.active_coo()
        self.state = self.planner.rebuild(dst, src, w)


# ------------------------------------------------------------- sparse waves --
def sparse_push_wave(dist: jax.Array, parent: jax.Array, wl: jax.Array,
                     ecs: jax.Array, ocs: jax.Array, st, *, ecap: int,
                     ocap: int, num_vertices: int, use_kernel: bool = False,
                     interpret: bool = True
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One gathered-edges relaxation wave over the worklist's OUT rows.

    Edge-level compaction: each of the ``ecap`` edge slots binary-searches
    the worklist's inclusive degree cumsum ``ecs`` for its (row, cell)
    coordinate, so the candidate list covers exactly the worklist rows'
    occupied ELL cells — no F x max-width padding.  The hub-overflow COO
    entries whose source row is on the frontier are compacted the same way
    through ``ocs`` (the inclusive cumsum of the live-overflow mask) into
    ``ocap`` slots.  Both lanes concatenate into ONE compacted edge list
    relaxed by the jnp reference or the Pallas gathered-edges kernel
    (kernels/relax/gather.py) — a single scatter-min + key scatter whose
    volume is O(edges touched), with the smallest-src-id rule falling out
    of the shared min over the union multiset exactly as
    ``combine_lanes`` resolves the dense sliced backend's lanes.  The
    caller (``ladder_wave``) guarantees both budgets fit."""
    n = num_vertices
    c = wl.shape[0]
    valid = wl >= 0
    rows = jnp.clip(wl, 0, st.fill.shape[0] - 1)
    rk = jnp.where(valid, st.fill[rows], 0)
    excl = ecs - rk                               # exclusive degree prefix
    j = jnp.arange(ecap, dtype=jnp.int32)
    r = jnp.clip(jnp.searchsorted(ecs, j, side="right"),
                 0, c - 1).astype(jnp.int32)
    evalid = j < ecs[-1]
    kk = j - excl[r]
    src = rows[r]
    pos = jnp.clip(st.base[src] + kk, 0, st.flat_w.shape[0] - 1)
    e_src, e_nbr, e_w, e_val = src, st.flat_idx[pos], st.flat_w[pos], evalid
    if ocap and st.ow.shape[0]:
        # overflow lane (osrc = destination / scatter target, odst = source
        # row under the sidecar's swapped roles); ocs already folds in the
        # frontier filter, so the selected entries are live by construction
        oslots = jnp.arange(1, ocap + 1, dtype=jnp.int32)
        osel = jnp.clip(jnp.searchsorted(ocs, oslots, side="left"),
                        0, st.ow.shape[0] - 1)
        e_src = jnp.concatenate([e_src, st.odst[osel]])
        e_nbr = jnp.concatenate([e_nbr, st.osrc[osel]])
        e_w = jnp.concatenate([e_w, st.ow[osel]])
        e_val = jnp.concatenate([e_val, oslots <= ocs[-1]])
    fn = (partial(gathered_rows_relax, interpret=interpret) if use_kernel
          else gathered_rows_relax_ref)
    best, arg = fn(dist[e_src], e_src, e_nbr, e_w, e_val, num_rows=n)
    improved = best < dist
    return (jnp.where(improved, best, dist),
            jnp.where(improved, arg, parent), improved)


def ladder_wave(dist: jax.Array, parent: jax.Array, frontier: jax.Array,
                st, edges: EdgePool, *, caps: tuple[int, ...],
                num_vertices: int, use_kernel: bool = False,
                interpret: bool = True
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One wave through the capacity ladder: compact once at the top rung,
    dispatch the smallest rung whose vertex count, ELL cell total AND live
    hub-overflow count all fit its budgets, else the exact dense
    ``relax_round`` computation over the pool.  All branches are
    bit-identical, so the rung choice is purely a cost decision."""
    wl, count = compact_mask(frontier, cap=caps[-1])
    valid = wl >= 0
    rows = jnp.clip(wl, 0, st.fill.shape[0] - 1)
    ecs = jnp.cumsum(jnp.where(valid, st.fill[rows], 0)
                     .astype(jnp.int32))
    if st.ow.shape[0]:
        olive = frontier[st.odst] & (st.ow < INF)
        ocs = jnp.cumsum(olive.astype(jnp.int32))
    else:
        ocs = jnp.zeros((1,), jnp.int32)
    etotal, ocnt = ecs[-1], ocs[-1]

    def dense_branch(_):
        d, p, improved, _ = relax.relax_round(
            dist, parent, edges, frontier, num_vertices=num_vertices)
        return d, p, improved

    def build(levels):
        if not levels:
            return dense_branch
        c, rest = levels[0], levels[1:]
        eb = edge_budget(c)

        def rung(_):
            return sparse_push_wave(
                dist, parent, wl[:c], ecs[:c], ocs, st, ecap=eb, ocap=eb,
                num_vertices=num_vertices, use_kernel=use_kernel,
                interpret=interpret)

        nxt = build(rest)
        fits = (count <= c) & (etotal <= eb) & (ocnt <= eb)
        return lambda op: jax.lax.cond(fits, rung, nxt, op)

    return build(list(caps))(0)


# ------------------------------------------------------------ sparse epochs --
@partial(jax.jit, static_argnames=("num_vertices", "caps", "max_rounds",
                                   "use_kernel", "interpret"))
def sparse_relax_until_converged(
    sssp: SSSPState, edges: EdgePool, st, frontier: jax.Array, *,
    num_vertices: int, caps: tuple[int, ...],
    max_rounds: int = 0, use_kernel: bool = False, interpret: bool = True,
) -> tuple[SSSPState, RelaxStats, jax.Array]:
    """Sparse rendering of ``relax.relax_until_converged``: the same
    converged-loop driver and [N]-mask carry, each wave executed through
    the capacity ladder.  Returns the epoch's summed per-wave occupancy as
    a third device scalar (the ``frontier_occupancy`` obs counter)."""

    def wave(dist, parent, frontier):
        return ladder_wave(
            dist, parent, frontier, st, edges, caps=caps,
            num_vertices=num_vertices, use_kernel=use_kernel,
            interpret=interpret)

    dist, parent, rounds, msgs, occ = relax.converged_loop(
        sssp.dist, sssp.parent, frontier, wave, max_rounds=max_rounds,
        track_occupancy=True)
    return (SSSPState(dist=dist, parent=parent, source=sssp.source),
            RelaxStats(rounds=rounds, messages=msgs), occ)


@partial(jax.jit, static_argnames=("num_vertices", "caps", "use_doubling",
                                   "use_kernel", "interpret"))
def sparse_invalidate_and_recompute(
    sssp: SSSPState, edges: EdgePool, st, seed: jax.Array, *,
    num_vertices: int, caps: tuple[int, ...],
    use_doubling: bool = True, use_kernel: bool = False,
    interpret: bool = True,
) -> tuple[SSSPState, del_mod.DeleteStats, jax.Array]:
    """Sparse deletion epoch — structurally identical to
    ``delete.invalidate_and_recompute`` (same marking, same dense bulk-pull
    over the pool's in-edges, same stat gating on ``any(seed)``); only the
    push recompute waves run through the ladder.  The pull stays dense
    because it is keyed by IN-edges of the affected set, which is exactly
    what the pool / backend layouts already index — and it runs once per
    epoch, not per wave."""
    any_seed = jnp.any(seed)
    mark = (del_mod.mark_subtree_doubling if use_doubling
            else del_mod.mark_subtree_flood)
    aff, inv_rounds = mark(sssp.parent, seed)
    aff = aff.at[sssp.source].set(False)

    dist = jnp.where(aff, INF, sssp.dist)
    parent = jnp.where(aff, NO_PARENT, sssp.parent)
    dist, parent, improved = del_mod.pull_once(dist, parent, edges, aff,
                                               num_vertices)

    state1 = SSSPState(dist=dist, parent=parent, source=sssp.source)
    state2, stats, occ = sparse_relax_until_converged(
        state1, edges, st, improved, num_vertices=num_vertices, caps=caps,
        use_kernel=use_kernel, interpret=interpret)
    zero = jnp.int32(0)
    return state2, del_mod.DeleteStats(
        invalidation_rounds=jnp.where(any_seed, inv_rounds, zero),
        affected=jnp.sum(aff.astype(jnp.int32)),
        recompute_rounds=jnp.where(any_seed, stats.rounds + 1, zero),
        recompute_messages=jnp.where(
            any_seed,
            stats.messages + jnp.sum(improved.astype(jnp.int32)), zero),
    ), occ


@partial(jax.jit, static_argnames=("num_vertices", "caps", "bucket_width",
                                   "use_kernel", "interpret"))
def sparse_drain(sssp: SSSPState, edges: EdgePool, st,
                 pend: buckets.PendingState, *, num_vertices: int,
                 caps: tuple[int, ...], bucket_width: float,
                 use_kernel: bool = False, interpret: bool = True
                 ) -> tuple[SSSPState, buckets.PendingState, RelaxStats,
                            jax.Array]:
    """Sparse bucketed drain: ``buckets.run_drain`` with each per-bucket
    active mask compacted through the ladder (pending-mask compaction per
    bucket).  Pull wave and drain discipline are byte-identical to
    ``segment_drain``, so the wave sequence and stats match by
    construction."""

    def wave(dist, parent, active):
        return ladder_wave(
            dist, parent, active, st, edges, caps=caps,
            num_vertices=num_vertices, use_kernel=use_kernel,
            interpret=interpret)

    def pull_wave(dist, parent, aff):
        return del_mod.pull_once(dist, parent, edges, aff, num_vertices)

    dist, parent, stats, occ = buckets.run_drain(
        sssp.dist, sssp.parent, pend, bucket_width=bucket_width,
        wave=wave, pull_wave=pull_wave, track_occupancy=True)
    return (SSSPState(dist=dist, parent=parent, source=sssp.source),
            buckets.empty_pending(num_vertices), stats, occ)


# ------------------------------------------------ batched [S, N] renderings --
# jax's while_loop batching freezes converged lanes exactly as in the dense
# batched epochs, so per-lane stats match unbatched runs.  Note that under
# vmap ``lax.cond`` lowers to ``select`` (both ladder branches execute), so
# batched sparse epochs are correctness-grade: bit-identical, but without
# the sparse cost win — the auto policy routes batched engines dense.
@partial(jax.jit, static_argnames=("num_vertices", "caps", "use_kernel",
                                   "interpret"))
def sparse_relax_batched(sssp, edges, st, frontier, *, num_vertices, caps,
                         use_kernel=False, interpret=True):
    return jax.vmap(
        lambda s: sparse_relax_until_converged(
            s, edges, st, frontier, num_vertices=num_vertices, caps=caps,
            use_kernel=use_kernel, interpret=interpret))(sssp)


@partial(jax.jit, static_argnames=("num_vertices", "caps", "use_doubling",
                                   "use_kernel", "interpret"))
def sparse_delete_batched(sssp, edges, st, seed, *, num_vertices, caps,
                          use_doubling=True, use_kernel=False,
                          interpret=True):
    return jax.vmap(
        lambda s, sd: sparse_invalidate_and_recompute(
            s, edges, st, sd, num_vertices=num_vertices, caps=caps,
            use_doubling=use_doubling, use_kernel=use_kernel,
            interpret=interpret))(sssp, seed)


@partial(jax.jit, static_argnames=("num_vertices", "caps", "bucket_width",
                                   "use_kernel", "interpret"))
def sparse_drain_batched(sssp, edges, st, pend, *, num_vertices, caps,
                         bucket_width, use_kernel=False, interpret=True):
    return jax.vmap(
        lambda s, pd: sparse_drain(
            s, edges, st, pd, num_vertices=num_vertices, caps=caps,
            bucket_width=bucket_width, use_kernel=use_kernel,
            interpret=interpret))(sssp, pend)


# ------------------------------------------------------------- sharded wave --
def wrap_shard_wave(make_wave, npp: int, cap: int):
    """Wrap a sharded backend's ``make_wave`` factory with per-partition
    edge-worklist compaction (DESIGN.md §12.4).

    The shard epochs patch the partition's COO pool arrays for EVERY
    backend, so the sparse branch can evaluate the segment-style wave over
    the compacted live-offer edges regardless of which layout the dense
    branch uses — identical candidate multiset + tie rule => bit-identical.
    ``offers`` already carry the frontier masking (the exchanges ship
    ``where(frontier, dist, INF)``), so membership is just
    ``isfinite(offers[esrc])``; unmasked pull waves naturally overflow the
    cap and take the dense branch."""

    def make(esrc, edst, ew, eact, extras, my_p):
        dense_wave = make_wave(esrc, edst, ew, eact, extras, my_p)
        row0 = my_p * npp
        n_edges = esrc.shape[0]

        def wave(offers):
            live = eact & jnp.isfinite(offers[esrc])
            ecs = jnp.cumsum(live.astype(jnp.int32))
            cnt = ecs[-1]

            def sparse(_):
                slots = jnp.arange(1, cap + 1, dtype=jnp.int32)
                safe = jnp.clip(jnp.searchsorted(ecs, slots, side="left"),
                                0, n_edges - 1)
                valid = slots <= cnt
                cs, cd, cw = esrc[safe], edst[safe], ew[safe]
                cand = jnp.where(valid, offers[cs] + cw, INF)
                dl = jnp.clip(cd - row0, 0, npp - 1)
                best = jnp.minimum(
                    jax.ops.segment_min(cand, dl, num_segments=npp), INF)
                hit = (cand == best[dl]) & (cand < INF)
                arg = jax.ops.segment_min(
                    jnp.where(hit, cs, _INT_MAX), dl, num_segments=npp)
                return best, arg

            return jax.lax.cond(cnt <= cap, sparse,
                                lambda _: dense_wave(offers), 0)

        return wave

    return make
